"""Paper Fig. 8 / §4.3: incentive structures. Collection phase (replay,
--accounts) accumulates per-account behavior; redeeming phase reprioritizes
by descending avg power / ascending avg power / EDP / Fugaku points."""
from __future__ import annotations

import numpy as np

from benchmarks.common import hist_stats, save, timed
from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.loaders import load_marconi100
from repro.systems.config import get_system

REDEEM = ["acct_avg_power", "acct_low_avg_power", "acct_edp",
          "acct_fugaku_pts"]


def run(quick: bool = False):
    sys_ = get_system("marconi100")
    js = load_marconi100(n_jobs=600 if quick else 1500,
                         days=0.5 if quick else 1.0, seed=8)
    js.assign_prepop_placement(0.0, sys_.n_nodes)
    table = js.to_table()
    t1 = (0.35 if quick else 0.8) * 86400.0

    # collection phase: replay with account tracking
    (final0, hist0), wall0 = timed(eng.simulate, sys_, table,
                                   T.Scenario.make("replay"), 0.0, t1,
                                   num_accounts=32)
    acc = final0.accounts
    rows = [dict(name="fig8/replay-collect", wall_s=wall0,
                 jobs_done=float(np.asarray(acc.jobs_done).sum()),
                 **hist_stats(hist0))]

    # redeeming phase: account-derived priorities + first-fit backfill
    scens = [T.Scenario.make(p, "first-fit") for p in REDEEM]
    (finals, hists), wall = timed(eng.simulate_sweep, sys_, table, scens,
                                  0.0, t1, acc, 32)
    pts = np.asarray(acc.fugaku_pts)
    avg_pw = np.asarray(acc.power_sum) / np.maximum(
        np.asarray(acc.jobs_done), 1.0)
    for i, p in enumerate(REDEEM):
        st = hist_stats(hists, i)
        # mean start time of jobs from the top-quartile accounts under this
        # policy's own ranking — shows the reordering took effect
        final_start = np.asarray(finals.start)[i][:len(js)]
        started = np.isfinite(final_start)
        rank = {"acct_avg_power": -avg_pw, "acct_low_avg_power": avg_pw,
                "acct_edp": np.asarray(acc.edp),
                "acct_fugaku_pts": -pts}[p]
        top_accounts = np.argsort(rank)[:8]
        m_top = np.isin(js.account, top_accounts) & started
        m_rest = ~np.isin(js.account, top_accounts) & started
        adv = float(final_start[m_rest].mean() - final_start[m_top].mean()) \
            if m_top.any() and m_rest.any() else 0.0
        st.update(name=f"fig8/{p}", wall_s=wall / len(REDEEM),
                  favored_start_advantage_s=adv)
        rows.append(st)
    save("fig8_incentives", {"rows": rows})
    return rows
