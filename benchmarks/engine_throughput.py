"""Twin-engine throughput: simulated-seconds per wall-second and scenario
sweep scaling — the compiled-scan engine vs the paper's Python simulators
(paper baseline: FastSim sequential at 688x real-time; original RAPS figure
runs take ~3-25 min per scenario)."""
from __future__ import annotations

import time

import numpy as np
import jax

if __package__ in (None, ""):  # `python benchmarks/engine_throughput.py`
    import pathlib
    import sys
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

from benchmarks.common import save
from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system


def _run_once(sys_, table, scens, t1):
    final, hist = eng.simulate_sweep(sys_, table, scens, 0.0, t1)
    jax.block_until_ready(final.t)
    return final


def run(quick: bool = False):
    rows = []
    for sys_name, n_jobs, hours in [("marconi100", 600, 12),
                                    ("frontier", 800, 6)]:
        sys_ = get_system(sys_name)
        js = generate(sys_, WorkloadSpec(
            n_jobs=n_jobs, duration_s=hours * 3600.0, load=1.0,
            trace_len=16, seed=1))
        table = js.to_table()
        t1 = hours * 3600.0
        n_steps = int(t1 / sys_.dt)
        for n_scen in ([1, 4] if quick else [1, 4, 16]):
            scens = [T.Scenario.make("fcfs", "easy")] * n_scen
            _run_once(sys_, table, scens, t1)  # compile
            t0 = time.perf_counter()
            _run_once(sys_, table, scens, t1)
            wall = time.perf_counter() - t0
            rows.append({
                "name": f"engine/{sys_name}-x{n_scen}",
                "us_per_call": wall / (n_steps * n_scen) * 1e6,
                "wall_s": wall,
                "steps_per_s": n_steps * n_scen / wall,
                "speedup_vs_realtime": t1 * n_scen / wall,
                "scenarios": n_scen,
                "nodes": sys_.n_nodes,
                "jobs": n_jobs,
            })
        # static-scenario fast path (compile-time policy; §Perf-twin)
        f, _ = eng.simulate_static(sys_, table, "fcfs", "first-fit", 0.0, t1)
        jax.block_until_ready(f.t)
        t0 = time.perf_counter()
        f, _ = eng.simulate_static(sys_, table, "fcfs", "first-fit", 0.0, t1)
        jax.block_until_ready(f.t)
        wall = time.perf_counter() - t0
        rows.append({
            "name": f"engine/{sys_name}-static",
            "us_per_call": wall / n_steps * 1e6,
            "wall_s": wall,
            "steps_per_s": n_steps / wall,
            "speedup_vs_realtime": t1 / wall,
            "scenarios": 1,
            "nodes": sys_.n_nodes,
            "jobs": n_jobs,
        })
    save("engine_throughput", {"rows": rows})
    return rows


def wire_bench(big: bool = False, seed: int = 0):
    """Scheduler-wire codec throughput over a local socketpair: the reset
    envelope (the big one — six f64/i64 job columns) and poll roundtrips,
    NDJSON vs RBW1 binary frames, plus the batched ``poll_batch``
    envelope. ``big=True`` adds a ~1e6-job reset (one shot per dialect —
    the JSON spelling alone is tens of MB). Returns rows whose
    ``bytes_per_s`` / ``roundtrips_per_s`` leaves feed the perf gate."""
    import socket
    import threading

    from repro.core import external as ext
    from repro.core import transport as tr

    rng = np.random.default_rng(seed)

    def job_cols(n):
        return {
            "submit": np.sort(rng.uniform(0, 1e5, n)),
            "limit": rng.uniform(60.0, 86400.0, n),
            "wall": rng.uniform(30.0, 43200.0, n),
            "nodes": rng.integers(1, 64, n).astype(np.int64),
            "priority": rng.uniform(0.0, 1.0, n),
            "account": rng.integers(0, 16, n).astype(np.int64),
        }

    def peer_loop(rfile, wfile, binary, n_running):
        """Minimal scheduler peer: ack resets, answer polls/batches."""
        ids = np.arange(n_running, dtype=np.int64)
        write = tr.write_bin_frame if binary else tr.write_frame
        while True:
            try:
                msg = tr.read_any_frame(rfile, as_arrays=True)
            except (ConnectionError, ext.ProtocolError, OSError,
                    ValueError):
                return
            kind = msg.get("kind")
            if kind == "reset":
                reply = {"version": tr.WIRE_VERSION, "kind": "reset_ack",
                         "n_jobs": int(np.asarray(
                             msg["jobs"]["submit"]).shape[0])}
            elif kind == "poll":
                reply = {"version": tr.WIRE_VERSION, "kind": "running",
                         "job_ids": ids if binary else ids.tolist()}
            elif kind == "poll_batch":
                sets = [ids if binary else ids.tolist()
                        for _ in msg["ts"]]
                reply = {"version": tr.WIRE_VERSION,
                         "kind": ext.WIRE_KIND_RUNNING_SETS, "sets": sets}
            else:  # "bye"
                return
            write(wfile, reply)

    def session(binary, n_running=200):
        """(counters, send, recv, close) over a fresh socketpair peer."""
        a, b = socket.socketpair()
        rf_a, wf_a = a.makefile("rb"), a.makefile("wb")
        rf_b, wf_b = b.makefile("rb"), b.makefile("wb")
        t = threading.Thread(target=peer_loop,
                             args=(rf_b, wf_b, binary, n_running),
                             daemon=True)
        t.start()
        counters = tr.WireCounters()
        write = tr.write_bin_frame if binary else tr.write_frame

        def send(msg):
            write(wf_a, msg, counters)

        def recv():
            return tr.read_any_frame(rf_a, counters)

        def close():
            try:
                send({"version": tr.WIRE_VERSION, "kind": "bye"})
            except (OSError, ext.ProtocolError):
                pass
            for f in (wf_a, rf_a, wf_b, rf_b):
                try:
                    f.close()
                except OSError:
                    pass
            a.close()
            b.close()
            t.join(timeout=5)

        return counters, send, recv, close

    rows = []
    scales = [("pm100", 4_000, 20)] + ([("1m", 1_000_000, 2)] if big else [])
    for tag, n_jobs, reps in scales:
        cols = job_cols(n_jobs)
        for dialect, binary in (("ndjson", False), ("binary", True)):
            payload = cols if binary else \
                {k: v.tolist() for k, v in cols.items()}
            msg = {"version": tr.WIRE_VERSION, "kind": "reset", "t0": 0.0,
                   "policy": "fcfs", "backfill": "firstfit",
                   "system": {"n_nodes": 1024, "dt": 30.0, "name": tag},
                   "system_digest": "bench", "job_digest": "bench",
                   "jobs": payload}
            counters, send, recv, close = session(binary)
            send(msg)          # warm the pipe (and the peer thread)
            recv()
            envelope_bytes = counters.bytes_out
            best = 0.0         # best-of per rep: scheduling noise on a
            for _ in range(reps):   # sub-ms envelope would swamp a sum
                t0 = time.perf_counter()
                send(msg)
                recv()
                best = max(best, envelope_bytes
                           / (time.perf_counter() - t0))
            close()
            rows.append({
                "name": f"wire/reset-{dialect}-{tag}",
                "bytes_per_s": best,
                "envelope_mb": envelope_bytes / 1e6,
                "envelopes": reps, "jobs": n_jobs,
            })

    n_polls, batch = 200, 20
    for dialect, binary in (("ndjson", False), ("binary", True)):
        counters, send, recv, close = session(binary)
        poll = {"version": tr.WIRE_VERSION, "kind": "poll", "t": 0.0}
        send(poll)
        recv()
        t0 = time.perf_counter()
        for i in range(n_polls):
            send(dict(poll, t=float(i)))
            recv()
        wall = time.perf_counter() - t0
        rows.append({"name": f"wire/poll-{dialect}",
                     "roundtrips_per_s": n_polls / wall,
                     "polls": n_polls})
        if binary:   # the batched envelope rides the binary session
            t0 = time.perf_counter()
            for i in range(n_polls // batch):
                send({"version": tr.WIRE_VERSION, "kind": "poll_batch",
                      "ts": [float(i * batch + j) for j in range(batch)]})
                recv()
            wall = time.perf_counter() - t0
            rows.append({"name": "wire/poll-batch",
                         "roundtrips_per_s": n_polls / wall,
                         "polls": n_polls, "batch": batch})
        close()
    return rows


def kernel_bench(n_iters: int | None = None):
    """Power-topology kernel throughput at Frontier scale: the Pallas
    fused cooling pass vs the unfused XLA reference, plus the bare
    segment-reduce. On GPU/TPU the Pallas rows run compiled
    (``interpret=False``); on CPU they take the interpreter with a
    scaled-down plant (same code path, far slower — the row records
    which, and the perf gate only compares same-backend entries)."""
    import jax.numpy as jnp

    from repro.cooling import model as cool
    from repro.kernels.power_topo import ops

    compiled = jax.default_backend() in ("gpu", "tpu")
    interpret = not compiled
    sys_ = get_system("frontier") if compiled else \
        get_system("frontier").scaled(512)
    if n_iters is None:
        n_iters = 100 if compiled else 50
    cfg = sys_.cooling
    N, G, H = sys_.n_nodes, cfg.n_groups, cfg.topology.n_halls
    rng = np.random.default_rng(0)
    node_pw = jnp.asarray(rng.uniform(100.0, 1000.0, N), jnp.float32)
    t_supply = jnp.full((G,), 25.0, jnp.float32)
    mdot = jnp.full((G,), cfg.mdot_kg_s, jnp.float32)
    t_basin = jnp.full((H,), 22.0, jnp.float32)
    hog = cfg.hall_of_group()
    params = cool.cdu_params(cfg, sys_.dt)

    variants = {
        "kernel/group-power": jax.jit(lambda p: ops.group_power(
            p, G, use_pallas=True, interpret=interpret)),
        "kernel/fused": jax.jit(lambda p: ops.fused_cooling_hier(
            p, t_supply, mdot, t_basin, jnp.float32(24.0), hog, G,
            params, use_pallas=True, interpret=interpret)),
        "kernel/unfused": jax.jit(lambda p: ops.fused_cooling_hier(
            p, t_supply, mdot, t_basin, jnp.float32(24.0), hog, G,
            params, use_pallas=False)),
    }
    rows = []
    for name, fn in variants.items():
        jax.block_until_ready(fn(node_pw))   # compile
        wall = float("inf")                  # best-of-3: dodge CI noise
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(n_iters):
                out = fn(node_pw)
            jax.block_until_ready(out)
            wall = min(wall, time.perf_counter() - t0)
        rows.append({"name": name, "calls_per_s": n_iters / wall,
                     "us_per_call": wall / n_iters * 1e6,
                     "nodes": N, "groups": G, "iters": n_iters,
                     "interpret": interpret,
                     "backend": jax.default_backend()})
    return rows


def smoke(n_steps: int = 50, bench_json: str = "BENCH_engine.json",
          wire_big: bool = False):
    """CI perf canary: a tiny 2-scenario sweep (grid signals active) plus a
    flat-vs-multi-hall topology comparison at the same scaled config, for
    ``n_steps`` engine steps each, then the wire-codec and power-topology
    kernel sections (``wire/*``, ``kernel/*``). Fails loudly on compile
    errors, emits CSV rows so perf regressions surface in PR logs, and
    writes ``BENCH_engine.json`` (throughput per variant) — the artifact
    the CI workflow uploads so the perf trajectory is tracked across
    PRs. ``wire_big`` adds the ~1e6-job reset-envelope rows."""
    import dataclasses
    import json

    from repro.grid import signals as gsig
    from repro.systems.config import FacilityTopology

    sys_ = get_system("marconi100").scaled(64)
    js = generate(sys_, WorkloadSpec(n_jobs=64, duration_s=n_steps * sys_.dt,
                                     load=1.2, trace_len=8, seed=1))
    table = js.to_table()
    t1 = n_steps * sys_.dt
    sig = gsig.synthetic_signals(
        sys_.grid, n_steps, sys_.dt, seed=1,
        cap_base_w=0.5 * sys_.n_nodes * sys_.power.peak_node_w)
    scens = [T.Scenario.make("fcfs", "easy"),
             T.Scenario.make("carbon_aware", "easy", carbon_weight=4.0)]

    def timed_sweep(name, system, **kw):
        tc = time.perf_counter()
        eng.simulate_sweep(system, table, scens, 0.0, t1, **kw)  # compile
        compile_s = time.perf_counter() - tc
        wall = float("inf")     # best-of-2: least-disturbed run counts
        for _ in range(2):
            t0 = time.perf_counter()
            final, _ = eng.simulate_sweep(system, table, scens, 0.0, t1,
                                          **kw)
            jax.block_until_ready(final.t)
            wall = min(wall, time.perf_counter() - t0)
        return {"name": name, "us_per_call": wall / n_steps * 1e6,
                "wall_s": wall, "compile_s": compile_s, "steps": n_steps,
                "scenarios": len(scens),
                "steps_per_s": n_steps * len(scens) / wall,
                "jobs_done": float(np.asarray(final.completed).sum())}

    rows = [timed_sweep("engine/smoke", sys_, signals=sig)]
    # flat vs multi-hall: ONE re-rated plant (4 groups, 4 cells, total
    # capacity/flow/conductance preserved), run with 1-hall vs 4-hall
    # topology and otherwise identical settings — the delta between these
    # two rows isolates the hierarchy's cost (hall segment sums, per-hall
    # basins, hall-aware placement ordering), which is what the canary
    # tracks; the grid-signal row above stays the legacy baseline
    c = sys_.cooling
    base_cool = dataclasses.replace(
        c, n_groups=4, mdot_kg_s=c.mdot_kg_s * c.n_groups / 4,
        ua_w_k=c.ua_w_k * c.n_groups / 4,
        pump_w_per_group=c.pump_w_per_group * c.n_groups / 4,
        n_tower_cells=4,
        cell_rated_heat_w=c.cell_rated_heat_w * c.n_tower_cells / 4,
        fan_rated_w=c.fan_rated_w * c.n_tower_cells / 4)
    for name, halls in [("engine/smoke-flat", 1), ("engine/smoke-4hall", 4)]:
        sys_h = dataclasses.replace(
            sys_, cooling=dataclasses.replace(
                base_cool, topology=FacilityTopology(n_halls=halls)))
        rows.append(timed_sweep(name, sys_h))
    for row in rows:
        derived = ";".join(f"{k}={v}" for k, v in row.items()
                           if k not in ("name", "us_per_call"))
        print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
    side_rows = wire_bench(big=wire_big) + kernel_bench()
    for row in side_rows:
        derived = ";".join(f"{k}={v}" for k, v in row.items()
                           if k != "name")
        print(f"{row['name']},{derived}")
    if bench_json:
        from benchmarks.common import bench_meta
        payload = {r["name"]: {"steps_per_s": r["steps_per_s"],
                               "wall_s": r["wall_s"],
                               "compile_s": r["compile_s"],
                               "scenarios": r["scenarios"],
                               "steps": r["steps"]} for r in rows}
        for row in side_rows:
            payload[row["name"]] = {k: v for k, v in row.items()
                                    if k != "name"}
        payload["meta"] = bench_meta()
        with open(bench_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {bench_json}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="50-step CI canary instead of the full benchmark")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--bench-json", default="BENCH_engine.json")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--wire-big", action="store_true",
                    help="include the ~1e6-job reset-envelope wire rows")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.steps, args.bench_json, wire_big=args.wire_big)
    else:
        from benchmarks.common import emit_csv
        emit_csv(run(quick=args.quick))
