"""Twin-engine throughput: simulated-seconds per wall-second and scenario
sweep scaling — the compiled-scan engine vs the paper's Python simulators
(paper baseline: FastSim sequential at 688x real-time; original RAPS figure
runs take ~3-25 min per scenario)."""
from __future__ import annotations

import time

import numpy as np
import jax

if __package__ in (None, ""):  # `python benchmarks/engine_throughput.py`
    import pathlib
    import sys
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

from benchmarks.common import save
from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system


def _run_once(sys_, table, scens, t1):
    final, hist = eng.simulate_sweep(sys_, table, scens, 0.0, t1)
    jax.block_until_ready(final.t)
    return final


def run(quick: bool = False):
    rows = []
    for sys_name, n_jobs, hours in [("marconi100", 600, 12),
                                    ("frontier", 800, 6)]:
        sys_ = get_system(sys_name)
        js = generate(sys_, WorkloadSpec(
            n_jobs=n_jobs, duration_s=hours * 3600.0, load=1.0,
            trace_len=16, seed=1))
        table = js.to_table()
        t1 = hours * 3600.0
        n_steps = int(t1 / sys_.dt)
        for n_scen in ([1, 4] if quick else [1, 4, 16]):
            scens = [T.Scenario.make("fcfs", "easy")] * n_scen
            _run_once(sys_, table, scens, t1)  # compile
            t0 = time.perf_counter()
            _run_once(sys_, table, scens, t1)
            wall = time.perf_counter() - t0
            rows.append({
                "name": f"engine/{sys_name}-x{n_scen}",
                "us_per_call": wall / (n_steps * n_scen) * 1e6,
                "wall_s": wall,
                "steps_per_s": n_steps * n_scen / wall,
                "speedup_vs_realtime": t1 * n_scen / wall,
                "scenarios": n_scen,
                "nodes": sys_.n_nodes,
                "jobs": n_jobs,
            })
        # static-scenario fast path (compile-time policy; §Perf-twin)
        f, _ = eng.simulate_static(sys_, table, "fcfs", "first-fit", 0.0, t1)
        jax.block_until_ready(f.t)
        t0 = time.perf_counter()
        f, _ = eng.simulate_static(sys_, table, "fcfs", "first-fit", 0.0, t1)
        jax.block_until_ready(f.t)
        wall = time.perf_counter() - t0
        rows.append({
            "name": f"engine/{sys_name}-static",
            "us_per_call": wall / n_steps * 1e6,
            "wall_s": wall,
            "steps_per_s": n_steps / wall,
            "speedup_vs_realtime": t1 / wall,
            "scenarios": 1,
            "nodes": sys_.n_nodes,
            "jobs": n_jobs,
        })
    save("engine_throughput", {"rows": rows})
    return rows


def smoke(n_steps: int = 50, bench_json: str = "BENCH_engine.json"):
    """CI perf canary: a tiny 2-scenario sweep (grid signals active) plus a
    flat-vs-multi-hall topology comparison at the same scaled config, for
    ``n_steps`` engine steps each. Fails loudly on compile errors, emits
    CSV rows so perf regressions surface in PR logs, and writes
    ``BENCH_engine.json`` (steps/s per variant) — the artifact the CI
    workflow uploads so the perf trajectory is tracked across PRs."""
    import dataclasses
    import json

    from repro.grid import signals as gsig
    from repro.systems.config import FacilityTopology

    sys_ = get_system("marconi100").scaled(64)
    js = generate(sys_, WorkloadSpec(n_jobs=64, duration_s=n_steps * sys_.dt,
                                     load=1.2, trace_len=8, seed=1))
    table = js.to_table()
    t1 = n_steps * sys_.dt
    sig = gsig.synthetic_signals(
        sys_.grid, n_steps, sys_.dt, seed=1,
        cap_base_w=0.5 * sys_.n_nodes * sys_.power.peak_node_w)
    scens = [T.Scenario.make("fcfs", "easy"),
             T.Scenario.make("carbon_aware", "easy", carbon_weight=4.0)]

    def timed_sweep(name, system, **kw):
        tc = time.perf_counter()
        eng.simulate_sweep(system, table, scens, 0.0, t1, **kw)  # compile
        compile_s = time.perf_counter() - tc
        t0 = time.perf_counter()
        final, _ = eng.simulate_sweep(system, table, scens, 0.0, t1, **kw)
        jax.block_until_ready(final.t)
        wall = time.perf_counter() - t0
        return {"name": name, "us_per_call": wall / n_steps * 1e6,
                "wall_s": wall, "compile_s": compile_s, "steps": n_steps,
                "scenarios": len(scens),
                "steps_per_s": n_steps * len(scens) / wall,
                "jobs_done": float(np.asarray(final.completed).sum())}

    rows = [timed_sweep("engine/smoke", sys_, signals=sig)]
    # flat vs multi-hall: ONE re-rated plant (4 groups, 4 cells, total
    # capacity/flow/conductance preserved), run with 1-hall vs 4-hall
    # topology and otherwise identical settings — the delta between these
    # two rows isolates the hierarchy's cost (hall segment sums, per-hall
    # basins, hall-aware placement ordering), which is what the canary
    # tracks; the grid-signal row above stays the legacy baseline
    c = sys_.cooling
    base_cool = dataclasses.replace(
        c, n_groups=4, mdot_kg_s=c.mdot_kg_s * c.n_groups / 4,
        ua_w_k=c.ua_w_k * c.n_groups / 4,
        pump_w_per_group=c.pump_w_per_group * c.n_groups / 4,
        n_tower_cells=4,
        cell_rated_heat_w=c.cell_rated_heat_w * c.n_tower_cells / 4,
        fan_rated_w=c.fan_rated_w * c.n_tower_cells / 4)
    for name, halls in [("engine/smoke-flat", 1), ("engine/smoke-4hall", 4)]:
        sys_h = dataclasses.replace(
            sys_, cooling=dataclasses.replace(
                base_cool, topology=FacilityTopology(n_halls=halls)))
        rows.append(timed_sweep(name, sys_h))
    for row in rows:
        derived = ";".join(f"{k}={v}" for k, v in row.items()
                           if k not in ("name", "us_per_call"))
        print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
    if bench_json:
        from benchmarks.common import bench_meta
        payload = {r["name"]: {"steps_per_s": r["steps_per_s"],
                               "wall_s": r["wall_s"],
                               "compile_s": r["compile_s"],
                               "scenarios": r["scenarios"],
                               "steps": r["steps"]} for r in rows}
        payload["meta"] = bench_meta()
        with open(bench_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {bench_json}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="50-step CI canary instead of the full benchmark")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--bench-json", default="BENCH_engine.json")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.steps, args.bench_json)
    else:
        from benchmarks.common import emit_csv
        emit_csv(run(quick=args.quick))
