"""Render markdown result tables from results/dryrun artifacts.

  PYTHONPATH=src python -m benchmarks.make_tables [--tag final]

Emits markdown: the per-cell roofline table (baseline vs tagged/optimized)
and the multi-pod compile-health matrix.
"""
from __future__ import annotations

import argparse
import glob
import json
import pathlib

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(tag: str):
    base, opt = {}, {}
    for f in glob.glob(str(DRYRUN / "*extrap*.json")):
        r = json.load(open(f))
        parts = r["cell"].split("__")
        key = (parts[0], parts[1])
        if r["cell"].endswith("__extrap"):
            base[key] = r
        elif r["cell"].endswith(f"__{tag}"):
            opt[key] = r
    return base, opt


def roofline_table(tag: str) -> str:
    base, opt = load(tag)
    out = ["| arch / shape | bottleneck | t_comp (s) base→opt | "
           "t_coll (s) base→opt | t_mem (s) | useful base→opt | "
           "roofline frac |",
           "|---|---|---|---|---|---|---|"]
    fracs = []
    for key in sorted(set(base) | set(opt)):
        b = base.get(key)
        o = opt.get(key, b)
        if o is None:
            continue
        if o["status"] == "SKIP":
            out.append(f"| {key[0]}/{key[1]} | — | SKIP | | | | |")
            continue
        rb = (b or o)["roofline"]
        ro = o["roofline"]
        dom = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        frac = ro["t_compute_s"] / dom if dom > 0 else 0.0
        fracs.append((frac, ro["useful_flops_ratio"], key, o["kind"]))
        out.append(
            f"| {key[0]}/{key[1]} | {ro['bottleneck']} | "
            f"{rb['t_compute_s']:.2f}→{ro['t_compute_s']:.2f} | "
            f"{rb['t_collective_s']:.2f}→{ro['t_collective_s']:.2f} | "
            f"{ro['t_memory_s']:.3f} | "
            f"{rb['useful_flops_ratio']:.3f}→{ro['useful_flops_ratio']:.3f} | "
            f"{frac:.2f} |")
    # fleet MFU-style summary for the train cells (the scored number):
    # useful_flops_ratio x compute-share-of-dominant-term
    trains = [(f, u, k) for f, u, k, kind in fracs if kind == "train"]
    if trains:
        mfus = [f * u for f, u, k in trains]
        out.append("")
        out.append(f"**Train-cell roofline summary (MFU upper bound = "
                   f"useful × compute/dominant):** mean "
                   f"{sum(mfus) / len(mfus):.3f}, "
                   f"best {max(mfus):.3f}, worst {min(mfus):.3f} over "
                   f"{len(mfus)} archs.")
    return "\n".join(out)


def compile_matrix() -> str:
    rows = {}
    for f in glob.glob(str(DRYRUN / "*.json")):
        r = json.load(open(f))
        parts = r["cell"].split("__")
        if len(parts) != 3 or parts[2] not in ("16x16", "2x16x16"):
            continue
        rows.setdefault((parts[0], parts[1]), {})[parts[2]] = r["status"]
    out = ["| arch / shape | 16x16 | 2x16x16 |", "|---|---|---|"]
    for key in sorted(rows):
        m = rows[key]
        out.append(f"| {key[0]}/{key[1]} | {m.get('16x16', '—')} | "
                   f"{m.get('2x16x16', '—')} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="final")
    args = ap.parse_args()
    print("### Multi-pod compile matrix\n")
    print(compile_matrix())
    print("\n### Roofline (single-pod, extrapolated; baseline → optimized)\n")
    print(roofline_table(args.tag))


if __name__ == "__main__":
    main()
