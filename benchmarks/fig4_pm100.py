"""Paper Fig. 4: PM100 (Marconi100) day-50 window, replay vs fcfs-nobf vs
fcfs-easy vs priority-ffbf — system power and utilization.

Claims checked: rescheduled runs reach higher utilization with backfill;
backfilled policies smooth the aggregate load (smaller power swing than
fcfs-nobf).

``trace=`` swaps the synthetic PM100 workload for a *real* ingested job
table (repro.traces) — with telemetry, the sweep runs in replay-power
mode (measured per-job power gathered per step) so Fig. 4 compares
policies over the recorded load instead of the calibrated model."""
from __future__ import annotations

import numpy as np

from benchmarks.common import hist_stats, save, timed
from repro.core import engine as eng
from repro.core import stats as stats_mod
from repro.core import types as T
from repro.datasets.loaders import load_marconi100, load_trace
from repro.systems.config import get_system

POLICIES = [("replay", "none"), ("fcfs", "none"), ("fcfs", "easy"),
            ("priority", "first-fit")]


def run(quick: bool = False, trace=None):
    sys_ = get_system("marconi100")
    replay_power = False
    if trace:
        js = load_trace(trace, prof_dt=sys_.prof_dt)
        replay_power = js.power_profile is not None
        t0 = 0.0
        t1 = min(float(js.rec_end[np.isfinite(js.rec_end)].max()),
                 6 * 3600.0 if quick else 17 * 3600.0)
    else:
        js = load_marconi100(n_jobs=700 if quick else 2000,
                             days=0.75 if quick else 1.5, seed=2)
        t0 = 2 * 3600.0
        t1 = t0 + (6 * 3600.0 if quick else 17 * 3600.0)
    js.assign_prepop_placement(t0, sys_.n_nodes)
    table = js.to_table(replay_power=replay_power)
    scens = [T.Scenario.make(p, b) for p, b in POLICIES]
    (final, hist), wall = timed(eng.simulate_sweep, sys_, table, scens,
                                t0, t1)
    rows = []
    for i, (p, b) in enumerate(POLICIES):
        idx = i
        st = hist_stats(hist, idx)
        st.update(name=f"fig4/{p}-{b}", wall_s=wall / len(POLICIES),
                  completed=float(np.asarray(final.completed)[i]))
        rows.append(st)
    save("fig4_pm100", {"rows": rows})
    # paper-claim assertions (soft): backfill >= nobf utilization
    u = {r["name"]: r["util"] for r in rows}
    assert u["fig4/fcfs-easy"] >= u["fig4/fcfs-none"] - 1e-6
    return rows
