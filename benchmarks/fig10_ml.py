"""Paper Fig. 10 / §4.4: ML-guided scheduling on Fugaku (F-Data), plus the
closed training loop (contribution (5), repro.ml.train).

(a) under high load the ML policy lowers power per timestep by prioritizing
smaller jobs; (b) L2-normalized multi-objective comparison across policies
(wait, turnaround, energy, EDP, power peak — lower is better).

Closed loop: ES-train the scoring alpha on a *validation* workload, then
sweep the trained policy against the fcfs / priority / incentive (acct_edp)
/ thermal_aware / carbon_aware baselines and the hand-set default alpha on
the held-out test workload — the trained-vs-baseline comparison of the MIT
SuperCloud trace-replay study (arXiv:2509.16513). ``--smoke`` is the CI
variant: tiny seeded config, emits ``BENCH_ml.json`` (generations/s +
trained-vs-baseline reward deltas) as a tracked artifact next to
``BENCH_engine.json``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import hist_stats, save, timed
from repro.core import engine as eng
from repro.core import stats as stats_mod
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.ml import train as ml_train
from repro.ml.pipeline import MLSchedulerModel, attach_basis
from repro.systems.config import get_system

POLICIES = ["fcfs", "sjf", "priority", "ljf", "ml"]
BASELINES = ["fcfs", "priority", "acct_edp", "thermal_aware",
             "carbon_aware"]
OBJECTIVES = ["avg_wait_s", "avg_turnaround_s", "avg_job_energy_j", "edp",
              "max_power_mw"]
REWARD = ml_train.DEFAULT_REWARD_SPEC


def _scen(policy: str, alpha=0.0) -> T.Scenario:
    return T.Scenario.make(policy, "first-fit", alpha=alpha)


def run(quick: bool = False):
    sys_full = get_system("fugaku")
    sys_ = sys_full.scaled(8192) if quick else sys_full.scaled(32768)

    # train phase on historical month; test on a high-load week
    train_js = generate(sys_, WorkloadSpec(
        n_jobs=1500 if quick else 4000, duration_s=14 * 86400.0, load=0.8,
        trace_len=8, n_accounts=64, seed=30))
    model = MLSchedulerModel.fit(train_js, k=5, n_trees=8, depth=6)
    test_js = generate(sys_, WorkloadSpec(
        n_jobs=500 if quick else 1500,
        duration_s=(1.0 if quick else 2.0) * 86400.0, load=1.8,
        trace_len=8, n_accounts=64, seed=31, max_frac_nodes=0.15))
    # basis (not baked scores): the same table serves the hand-set alpha
    # (Scenario.alpha = model.alpha) and the trained one
    attach_basis(test_js, model)
    test_js.assign_prepop_placement(0.0, sys_.n_nodes)
    table = test_js.to_table()
    t1 = (0.5 if quick else 1.5) * 86400.0

    scens = [_scen(p, alpha=np.asarray(model.alpha) if p == "ml" else 0.0)
             for p in POLICIES]
    (finals, hists), wall = timed(eng.simulate_sweep, sys_, table, scens,
                                  0.0, t1)
    rows = []
    obj = np.zeros((len(POLICIES), len(OBJECTIVES)))
    for i, p in enumerate(POLICIES):
        final_i = jaxtree_index(finals, i)
        hist_i = jaxtree_index(hists, i)
        s = stats_mod.summarize(sys_, table, final_i, hist_i)
        obj[i] = [s[o] for o in OBJECTIVES]
        st = hist_stats(hists, i)
        st.update(name=f"fig10/{p}", wall_s=wall / len(POLICIES),
                  completed=s["jobs_completed"],
                  avg_wait_s=s["avg_wait_s"],
                  avg_turnaround_s=s["avg_turnaround_s"],
                  edp=s["edp"])
        rows.append(st)

    # L2-normalized multi-objective score (paper Fig. 10b; lower = better)
    norm = np.linalg.norm(obj, axis=0) + 1e-9
    scores = (obj / norm).mean(axis=1)
    for i, p in enumerate(POLICIES):
        rows[i]["l2_multiobjective"] = float(scores[i])

    # ---- closed loop: train on a validation workload, evaluate held-out --
    val_js = generate(sys_, WorkloadSpec(
        n_jobs=300 if quick else 800, duration_s=0.5 * 86400.0, load=1.8,
        trace_len=8, n_accounts=64, seed=32, max_frac_nodes=0.15))
    attach_basis(val_js, model)
    val_js.assign_prepop_placement(0.0, sys_.n_nodes)
    res, train_wall = timed(
        ml_train.train, sys_, val_js.to_table(), 0.0, 0.25 * 86400.0,
        reward=REWARD, generations=4 if quick else 8, population=8,
        seed=33, log=None)
    rows.append({
        "name": "fig10/train", "wall_s": train_wall,
        "generations": res.generations,
        "generations_per_s": res.generations / train_wall,
        "reward_best": res.reward_best,
        "reward_default": res.reward_default,
        "gain": res.reward_best - res.reward_default,
    })
    trained_rows, _ = sweep_trained(sys_, table, t1, model, res.alpha,
                                    prefix="fig10")
    rows += trained_rows

    save("fig10_ml", {"rows": rows, "objectives": OBJECTIVES})
    # ML should beat LJF on the multi-objective score under high load
    s = {p: scores[i] for i, p in enumerate(POLICIES)}
    assert s["ml"] <= s["ljf"] + 0.02
    return rows


def sweep_trained(sys_, table, t1, model, trained_alpha, prefix,
                  signals=None):
    """ONE batched sweep: baselines + default-alpha ml + trained ml.

    Returns (rows, deltas): per-policy summary rows (reward under the
    training objective included) and trained-vs-baseline reward deltas
    (positive = trained better)."""
    names = BASELINES + ["ml_default", "ml_trained"]
    scens = [_scen(p) for p in BASELINES] + \
        [_scen("ml", alpha=np.asarray(model.alpha)),
         _scen("ml", alpha=np.asarray(trained_alpha))]
    (finals, hists), wall = timed(eng.simulate_sweep_sharded, sys_, table,
                                  scens, 0.0, t1, signals=signals)
    reward = ml_train.Reward.parse(REWARD)
    metrics = ml_train.rollout_metrics(sys_, table, finals, hists)
    refs = reward.refs(metrics, names.index("ml_default"))
    rewards = reward.evaluate(metrics, refs)
    rows, deltas = [], {}
    for i, p in enumerate(names):
        s = stats_mod.summarize(sys_, table, jaxtree_index(finals, i),
                                jaxtree_index(hists, i))
        rows.append({
            "name": f"{prefix}/eval/{p}", "wall_s": wall / len(names),
            "completed": s["jobs_completed"],
            "avg_wait_s": s["avg_wait_s"],
            "avg_turnaround_s": s["avg_turnaround_s"],
            "total_energy_mwh": s["total_energy_mwh"],
            "emissions_kg": s["emissions_kg"],
            "reward": float(rewards[i]),
        })
        if p != "ml_trained":
            deltas[f"trained_vs_{p}"] = float(rewards[-1] - rewards[i])
    return rows, deltas


def smoke(bench_json: str = "BENCH_ml.json"):
    """CI canary for the closed loop: train a few ES generations on a tiny
    seeded workload (one batched rollout per generation), then sweep the
    trained alpha against the baselines under synthetic grid signals.
    Emits CSV rows + ``BENCH_ml.json`` (generations/s, reward gain,
    trained-vs-baseline deltas) — uploaded next to ``BENCH_engine.json``
    so the training-loop trajectory is tracked across PRs."""
    import json

    from repro.datasets import loaders
    from repro.grid import signals as gsig

    # one seeded tiny config, shared with `simulate train --smoke`
    from repro.launch.simulate import _parse_time

    cfg = ml_train.SMOKE_CONFIG
    sys_ = get_system(cfg["system"]).scaled(cfg["scale"])
    t1 = _parse_time(cfg["time"])
    days = max((t1 / 86400.0) * 1.2, 0.02)    # the CLI smoke's formula
    js = loaders.load(cfg["system"], n_jobs=cfg["jobs"], days=days, seed=0)
    # loaders size jobs for the full machine; drop what can't fit at
    # this scale (mirrors the CLI smoke)
    js = js.select(np.asarray(js.nodes) <= sys_.n_nodes)
    model = MLSchedulerModel.fit(js, k=4, n_trees=6, depth=5, seed=0)
    attach_basis(js, model)
    js.assign_prepop_placement(0.0, sys_.n_nodes)
    table = js.to_table()
    n_steps = int(round(t1 / sys_.dt))
    sig = gsig.synthetic_signals(
        sys_.grid, n_steps, sys_.dt, seed=1,
        cap_base_w=0.8 * sys_.n_nodes * sys_.power.peak_node_w)

    res, train_wall = timed(
        ml_train.train, sys_, table, 0.0, t1, reward=REWARD,
        generations=cfg["generations"], population=cfg["population"],
        sigma=cfg["sigma"], lr=cfg["lr"], seed=0, signals=sig, log=None)
    rows = [{
        "name": "fig10/smoke-train", "wall_s": train_wall,
        "generations": res.generations,
        "generations_per_s": res.generations / train_wall,
        "rollouts_per_gen": cfg["population"] + 2,
        "reward_best": res.reward_best,
        "reward_default": res.reward_default,
        "gain": res.reward_best - res.reward_default,
    }]
    eval_rows, deltas = sweep_trained(sys_, table, t1, model, res.alpha,
                                      prefix="fig10/smoke", signals=sig)
    rows += eval_rows
    for r in rows:
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "wall_s"))
        print(f"{r['name']},{r['wall_s'] * 1e6:.1f},{derived}")
    if bench_json:
        from benchmarks.common import bench_meta
        payload = {"train": rows[0], "eval": eval_rows, "deltas": deltas,
                   "trained_alpha": [float(a) for a in res.alpha],
                   "reward": REWARD, "meta": bench_meta()}
        with open(bench_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {bench_json}")
    assert res.reward_best >= res.reward_default, \
        "elite policy must not be worse than the hand-set default"
    return rows


def jaxtree_index(tree, i):
    import jax
    return jax.tree_util.tree_map(lambda x, i=i: x[i], tree)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI canary: tiny train + eval, writes BENCH_ml.json")
    ap.add_argument("--bench-json", default="BENCH_ml.json")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.bench_json)
    else:
        from benchmarks.common import emit_csv
        emit_csv(run(quick=args.quick))
