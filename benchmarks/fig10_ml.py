"""Paper Fig. 10 / §4.4: ML-guided scheduling on Fugaku (F-Data).

(a) under high load the ML policy lowers power per timestep by prioritizing
smaller jobs; (b) L2-normalized multi-objective comparison across policies
(wait, turnaround, energy, EDP, power peak — lower is better)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import hist_stats, save, timed
from repro.core import engine as eng
from repro.core import stats as stats_mod
from repro.core import types as T
from repro.datasets.loaders import load_fugaku
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.ml.pipeline import MLSchedulerModel, attach_scores
from repro.systems.config import get_system

POLICIES = ["fcfs", "sjf", "priority", "ljf", "ml"]
OBJECTIVES = ["avg_wait_s", "avg_turnaround_s", "avg_job_energy_j", "edp",
              "max_power_mw"]


def run(quick: bool = False):
    sys_full = get_system("fugaku")
    sys_ = sys_full.scaled(8192) if quick else sys_full.scaled(32768)

    # train phase on historical month; test on a high-load week
    train_js = generate(sys_, WorkloadSpec(
        n_jobs=1500 if quick else 4000, duration_s=14 * 86400.0, load=0.8,
        trace_len=8, n_accounts=64, seed=30))
    (model, fit_wall) = (MLSchedulerModel.fit(train_js, k=5,
                                              n_trees=8, depth=6), 0.0)
    test_js = generate(sys_, WorkloadSpec(
        n_jobs=500 if quick else 1500,
        duration_s=(1.0 if quick else 2.0) * 86400.0, load=1.8,
        trace_len=8, n_accounts=64, seed=31, max_frac_nodes=0.15))
    attach_scores(test_js, model)
    test_js.assign_prepop_placement(0.0, sys_.n_nodes)
    table = test_js.to_table()
    t1 = (0.5 if quick else 1.5) * 86400.0

    scens = [T.Scenario.make(p, "first-fit") for p in POLICIES]
    (finals, hists), wall = timed(eng.simulate_sweep, sys_, table, scens,
                                  0.0, t1)
    rows = []
    obj = np.zeros((len(POLICIES), len(OBJECTIVES)))
    for i, p in enumerate(POLICIES):
        final_i = jaxtree_index(finals, i)
        hist_i = jaxtree_index(hists, i)
        s = stats_mod.summarize(sys_, table, final_i, hist_i)
        obj[i] = [s[o] for o in OBJECTIVES]
        st = hist_stats(hists, i)
        st.update(name=f"fig10/{p}", wall_s=wall / len(POLICIES),
                  completed=s["jobs_completed"],
                  avg_wait_s=s["avg_wait_s"],
                  avg_turnaround_s=s["avg_turnaround_s"],
                  edp=s["edp"])
        rows.append(st)

    # L2-normalized multi-objective score (paper Fig. 10b; lower = better)
    norm = np.linalg.norm(obj, axis=0) + 1e-9
    scores = (obj / norm).mean(axis=1)
    for i, p in enumerate(POLICIES):
        rows[i]["l2_multiobjective"] = float(scores[i])
    save("fig10_ml", {"rows": rows, "objectives": OBJECTIVES})
    # ML should beat LJF on the multi-objective score under high load
    s = {p: scores[i] for i, p in enumerate(POLICIES)}
    assert s["ml"] <= s["ljf"] + 0.02
    return rows


def jaxtree_index(tree, i):
    import jax
    return jax.tree_util.tree_map(lambda x: x[i], tree)
