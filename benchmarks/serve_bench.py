"""Twin-service throughput: fork rate, coalesced advance rate, and wire
round-trips against a live server — the serving-layer companion to
``engine_throughput.py``.

The serve stack's perf claims (docs/serving.md): forks are O(1) (carry
shared by reference, no replay), concurrent branch advances coalesce
into one batched sweep per tick, and the NDJSON wire adds negligible
latency on top. The smoke mode measures all three and writes
``BENCH_serve.json`` (``*_per_s`` leaves + backend meta) for the CI
perf-trajectory gate (tools/bench_compare.py vs
benchmarks/baselines/serve_history.ndjson).
"""
from __future__ import annotations

import tempfile
import time

if __package__ in (None, ""):  # `python benchmarks/serve_bench.py`
    import pathlib
    import sys
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.serve.server import TwinServer
from repro.serve.session import TwinSession
from repro.systems.config import get_system

INTERVAL = 8


def make_session(n_steps: int) -> TwinSession:
    system = get_system("marconi100").scaled(64)
    js = generate(system, WorkloadSpec(
        n_jobs=64, duration_s=n_steps * system.dt, load=1.2,
        trace_len=8, n_accounts=8, mean_wall_s=1200.0, seed=1))
    js.assign_prepop_placement(0.0, system.n_nodes)
    return TwinSession(system, js.to_table(80),
                       T.Scenario.make("fcfs", "easy"), 0.0,
                       n_steps * system.dt, interval_steps=INTERVAL,
                       num_accounts=8)


def smoke(bench_json: str = "BENCH_serve.json", n_forks: int = 200,
          n_roundtrips: int = 200):
    rows = []

    # -- fork rate: O(1) branch creation, no prefix replay ------------------
    sess = make_session(n_steps=INTERVAL * 12)
    sess.advance_many({0: 2})           # give the root a checkpoint or two
    t0 = time.perf_counter()
    for i in range(n_forks):
        sess.fork(0, {"setpoint_delta_c": 0.01 * (i + 1)})
    wall = time.perf_counter() - t0
    rows.append({"name": "serve/forks", "wall_s": wall,
                 "forks_per_s": n_forks / wall, "count": n_forks})

    # -- coalesced advance: 4 divergent branches, one sweep per tick --------
    sess = make_session(n_steps=INTERVAL * 12)
    sess.advance_many({0: 1})
    for d in ({"setpoint_delta_c": 2.0}, {"cap_scale": 0.9},
              {"cells_offline": 1.0}):
        sess.fork(0, d)
    ids = list(sess.branches)
    sess.advance_many({b: 1 for b in ids})      # compile the 4-wide sweep
    n_intervals = 8
    t0 = time.perf_counter()
    sess.advance_many({b: n_intervals for b in ids})
    wall = time.perf_counter() - t0
    steps = len(ids) * n_intervals * INTERVAL
    rows.append({"name": "serve/advance-coalesced", "wall_s": wall,
                 "advance_steps_per_s": steps / wall,
                 "branches": len(ids), "steps": steps,
                 "coalesced_batches": sess.counters["coalesced_batches"]})

    # -- wire round-trips: state requests against a live server ------------
    # the roundtrip conflates three costs: session work, JSON codec, and
    # socket hops. Time the same verb through the inline handler first
    # (no wire at all) so the row splits session time from wire+codec
    # overhead instead of burying the codec in one number.
    from repro.serve import protocol as proto
    from tools.twin_client import TwinClient
    sess = make_session(n_steps=INTERVAL * 4)
    req = {"version": proto.WIRE_VERSION, "kind": "state", "id": 0}
    proto.handle_inline(sess, proto.validate_request(req))  # warm
    t0 = time.perf_counter()
    for _ in range(n_roundtrips):
        proto.handle_inline(sess, proto.validate_request(req))
    inline_wall = time.perf_counter() - t0
    with TwinServer(sess, f"unix:{tempfile.mkdtemp()}/bench.sock") as srv:
        with TwinClient(srv.address) as client:
            client.state()              # warm the path
            t0 = time.perf_counter()
            for _ in range(n_roundtrips):
                client.state()
            wall = time.perf_counter() - t0
            rows.append({
                "name": "serve/wire-roundtrip", "wall_s": wall,
                "roundtrips_per_s": n_roundtrips / wall,
                "session_per_s": n_roundtrips / inline_wall,
                "wire_overhead_us":
                    (wall - inline_wall) / n_roundtrips * 1e6,
                "count": n_roundtrips})

            # snapshot codec: base64-JSON spelling vs RBW1 binary
            # leaves, same branch, same live server — the delta is
            # pure codec (the session hands both the same checkpoint)
            client.advance(0, 1)        # ensure a checkpoint exists
            for label, binary in (("json", False), ("binary", True)):
                client.snapshot(0, binary=binary)   # warm
                t0 = time.perf_counter()
                for _ in range(n_roundtrips // 4):
                    client.snapshot(0, binary=binary)
                wall = time.perf_counter() - t0
                rows.append({
                    "name": f"serve/snapshot-{label}",
                    "wall_s": wall,
                    "roundtrips_per_s": (n_roundtrips // 4) / wall,
                    "count": n_roundtrips // 4})

    for row in rows:
        derived = ";".join(f"{k}={v}" for k, v in row.items()
                           if k not in ("name",))
        print(f"{row['name']},{derived}")
    if bench_json:
        import json

        from benchmarks.common import bench_meta
        payload = {r["name"]: {k: v for k, v in r.items() if k != "name"}
                   for r in rows}
        payload["meta"] = bench_meta()
        with open(bench_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {bench_json}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI canary (currently the only mode)")
    ap.add_argument("--bench-json", default="BENCH_serve.json")
    args = ap.parse_args()
    smoke(args.bench_json)
