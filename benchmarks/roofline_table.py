"""Render the model-zoo roofline table from the dry-run
artifacts in results/dryrun/."""
from __future__ import annotations

import glob
import json
import pathlib

from benchmarks.common import save

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


_TAG_RANK = {"final": 4, "extrap": 1, "unroll": 2, "": 0}


def load_cells(mesh: str = "16x16", prefer_unroll: bool = True):
    """Pick the best available record per (arch, shape): the final optimized
    extrapolation outranks intermediate perf tags and the rolled compile."""
    cells = {}
    ranks = {}
    for f in sorted(glob.glob(str(DRYRUN / "*.json"))):
        rec = json.load(open(f))
        cell = rec["cell"]
        if cell.startswith("twin-") or (
                "roofline" not in rec and rec.get("status") == "OK"):
            continue  # twin sweep cells have their own schema
        parts = cell.split("__")
        if len(parts) < 3 or parts[2] != mesh:
            continue
        key = (parts[0], parts[1])
        tag = parts[-1] if len(parts) > 3 else ""
        rank = _TAG_RANK.get(tag, 3 if tag.startswith("opt") else 0)
        if rec["status"] != "OK" and rec["status"] != "SKIP":
            rank = -1
        if key not in cells or rank > ranks[key]:
            rec["_unrolled"] = tag in ("unroll", "final") or \
                tag.startswith("opt") or tag == "extrap"
            cells[key] = rec
            ranks[key] = rank
    return cells


def run(quick: bool = False):
    cells = load_cells()
    rows = []
    for (arch, shape), rec in sorted(cells.items()):
        if rec["status"] == "SKIP":
            rows.append({"name": f"roofline/{arch}/{shape}", "wall_s": 0.0,
                         "status": "SKIP", "reason": rec.get("reason", "")})
            continue
        if rec["status"] != "OK":
            rows.append({"name": f"roofline/{arch}/{shape}", "wall_s": 0.0,
                         "status": "FAIL"})
            continue
        rf = rec["roofline"]
        rows.append({
            "name": f"roofline/{arch}/{shape}", "wall_s": 0.0,
            "status": "OK" + ("/unrolled" if rec.get("_unrolled") else ""),
            "bottleneck": rf["bottleneck"],
            "t_compute_ms": rf["t_compute_s"] * 1e3,
            "t_memory_ms": rf["t_memory_s"] * 1e3,
            "t_collective_ms": rf["t_collective_s"] * 1e3,
            "useful_flops_ratio": rf["useful_flops_ratio"],
            "mfu_upper_bound": min(
                1.0, rf["model_flops"] /
                max(rf["flops_per_device"] * rf["chips"], 1.0)) *
            (rf["t_compute_s"] /
             max(rf["t_compute_s"], rf["t_memory_s"],
                 rf["t_collective_s"])),
        })
    save("roofline_table", {"rows": rows})
    return rows
