"""Paper Fig. 7 / §4.2.2: external FastSim-like scheduler driving the twin.

Reproduces the sequential-mode experiment: a synthetic Frontier job trace is
scheduled by the fast event-based external simulator, the schedule is
replayed through the DCDT, and we report the end-to-end simulation speedup
over real time (paper: 5,324 jobs / 15 days in 31m24s = 688x)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import save
from repro.core import external as ext
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system


def run(quick: bool = False):
    sys_ = get_system("frontier")
    days = 2.0 if quick else 15.0
    n_jobs = 1200 if quick else 5324
    spec = WorkloadSpec(n_jobs=n_jobs, duration_s=days * 86400.0, load=0.9,
                        trace_len=1, n_accounts=64, mean_wall_s=7200.0,
                        seed=42)
    js = generate(sys_, spec)

    t0 = time.perf_counter()
    sched = ext.FastSimLike(policy="fcfs", backfill="firstfit")
    final, hist = ext.run_sequential_mode(sys_, js, sched, 0.0,
                                          days * 86400.0)
    float(final.completed)  # block
    wall = time.perf_counter() - t0
    speedup = days * 86400.0 / wall

    # plugin mode on a shorter window for comparison; explicit bridge so
    # its wire counters (polls, latency histogram) land in the results
    t0 = time.perf_counter()
    bridge = ext.SchedulerBridge(
        ext.FastSimLike(policy="fcfs", backfill="firstfit"))
    _, _, wall_plugin = ext.run_plugin_mode(sys_, js, bridge, 0.0,
                                            0.25 * 86400.0)
    speedup_plugin = 0.25 * 86400.0 / wall_plugin
    bstats = bridge.stats()
    lat = bstats["poll_latency"]

    p = np.asarray(hist.power_it, np.float64)
    rows = [{
        "name": "fig7/fastsim-sequential", "wall_s": wall,
        "jobs": n_jobs, "sim_days": days,
        "speedup_vs_realtime": float(speedup),
        "paper_speedup": 688.0,
        "completed": float(final.completed),
        "p_avg_mw": float(p.mean() / 1e6),
        "p_swing_mw": float((p.max() - p.min()) / 1e6),
    }, {
        "name": "fig7/fastsim-plugin", "wall_s": wall_plugin,
        "speedup_vs_realtime": float(speedup_plugin),
        "polls": bstats["polls"],
        "poll_failures": bstats["poll_failures"],
        "reconnects": bstats["reconnects"],
        "poll_p_max_ms": (lat["max_s"] or 0.0) * 1e3,
        "poll_mean_ms": (lat["total_s"] / lat["count"] * 1e3
                         if lat["count"] else 0.0),
    }]
    save("fig7_external", {"rows": rows, "bridge": bstats})
    assert speedup > 688.0, "compiled twin should beat the paper's 688x"
    return rows
