"""Grid-aware what-if sweep: (policy x cap-level x carbon-weight) as ONE
compiled, vmapped program against a shared synthetic grid-signal set
(diurnal carbon + price, evening cap dip) — the sustainability studies the
MIT SuperCloud trace-replay work (arXiv:2509.16513) runs one scenario at a
time, batched on the scenario axis."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import hist_stats, save, timed
from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.loaders import load_marconi100
from repro.grid import signals as gsig
from repro.systems.config import get_system

CAP_SCALES = [1.0, 0.85, 0.7]
CARBON_WEIGHTS = [0.0, 2.0, 8.0]


def run(quick: bool = False):
    # aggressive DVFS floor so every cap above the idle floor is fully
    # enforceable (the default c_min=0.5 can only shave half the dynamic
    # power, which profile ramps can outrun)
    sys_ = get_system("marconi100")
    sys_ = dataclasses.replace(
        sys_, grid=dataclasses.replace(sys_.grid, c_min=0.05))
    js = load_marconi100(n_jobs=500 if quick else 1200,
                         days=0.5 if quick else 1.0, seed=11)
    js.assign_prepop_placement(0.0, sys_.n_nodes)
    table = js.to_table()
    t1 = (0.3 if quick else 0.9) * 86400.0
    n_steps = int(t1 / sys_.dt)

    # cap schedule: generous baseline, evening dip to ~55% of peak IT draw
    peak_it = sys_.n_nodes * sys_.power.peak_node_w
    sig = gsig.synthetic_signals(sys_.grid, n_steps, sys_.dt, seed=11,
                                 cap_base_w=0.9 * peak_it,
                                 cap_peak_w=0.55 * peak_it)

    scens, names = [], []
    for cs in CAP_SCALES:
        for w in CARBON_WEIGHTS:
            pol = "fcfs" if w == 0.0 else "carbon_aware"
            scens.append(T.Scenario.make(pol, "first-fit", carbon_weight=w,
                                         cap_scale=cs))
            names.append(f"cap{cs:.2f}-w{w:g}")

    (finals, hists), wall = timed(eng.simulate_sweep, sys_, table, scens,
                                  0.0, t1, None, 32, sig)
    rows = []
    cap = np.asarray(hists.cap_w)
    p_it = np.asarray(hists.power_it)
    assert (p_it <= cap + 1.0).all(), "cap violated in sweep"
    for i, n in enumerate(names):
        st = hist_stats(hists, i)
        st.update(
            name=f"fig_carbon/{n}", wall_s=wall / len(scens),
            jobs_done=float(np.asarray(finals.completed)[i]),
            emissions_kg=float(np.asarray(finals.emissions_kg)[i]),
            energy_cost_usd=float(np.asarray(finals.energy_cost)[i]),
            throttle_frac=float(np.asarray(hists.throttle_frac)[i].mean()),
        )
        rows.append(st)
    save("fig_carbon", {"rows": rows})
    return rows
