"""Risk-grid sweep: (failure-seed x failure-rate x demand-response) as ONE
``simulate_sweep_sharded`` program with the stochastic event layer on
(repro.events). Every scenario row carries its own failure universe
through the traced ``failure_seed``/rate knobs, so the whole risk grid —
the paper's "events not easily realizable in production" — compiles
once; per-scenario ride-through scores (jobs killed/requeued, energy not
served, node downtime, recovery time) come out of ``stats.summarize``.

``--smoke`` is the CI canary: a 64-node scaled config for 50 steps,
emitting ``BENCH_risk.json`` for the perf-trajectory gate
(tools/bench_compare.py against benchmarks/baselines/risk_history.ndjson).
"""
from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import bench_meta, hist_stats, save, timed
from repro.core import engine as eng
from repro.core import stats as stats_mod
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.events import EventConfig
from repro.grid import signals as gsig
from repro.systems.config import get_system

# hazards in failures per node-day (converted to 1/s below)
FAIL_RATES_PER_DAY = [0.0, 2.0, 8.0]
FAIL_SEEDS = [3, 4]
DR_CAPS = [None, 0.6]   # None = no DR event; else cap as frac of peak IT


def _grid(sys_, t0, t1):
    """The (seed x rate x DR) scenario grid + row names."""
    per_day = 1.0 / 86400.0
    peak_it = sys_.n_nodes * sys_.power.peak_node_w
    scens, names = [], []
    for sd in FAIL_SEEDS:
        for rate in FAIL_RATES_PER_DAY:
            for cap in DR_CAPS:
                kw = dict(failure_seed=float(sd),
                          node_fail_rate=rate * per_day,
                          cdu_fail_rate=0.25 * rate * per_day,
                          failure_corr=0.5, repair_s=1800.0)
                if cap is not None:
                    kw.update(dr_announce_s=t0 + 0.1 * (t1 - t0),
                              dr_notice_s=0.1 * (t1 - t0),
                              dr_duration_s=0.3 * (t1 - t0),
                              dr_cap_w=cap * peak_it)
                scens.append(T.Scenario.make("fcfs", "easy", **kw))
                names.append(f"seed{sd}-rate{rate:g}-"
                             f"dr{'off' if cap is None else cap}")
    return scens, names


def run(quick: bool = False, n_steps: int = 0, bench_json: str = ""):
    sys_ = get_system("marconi100").scaled(64)
    n_steps = n_steps or (50 if quick else 480)
    t1 = n_steps * sys_.dt
    js = generate(sys_, WorkloadSpec(n_jobs=64, duration_s=t1, load=1.2,
                                     trace_len=8, seed=1))
    js.assign_prepop_placement(0.0, sys_.n_nodes)
    table = js.to_table()
    scens, names = _grid(sys_, 0.0, t1)
    # DR rides the grid-cap machinery: neutral signals keep the non-DR
    # rows uncapped while the DR rows see their cap step
    sig = gsig.neutral(n_steps)
    events = EventConfig()

    tc = time.perf_counter()
    eng.simulate_sweep_sharded(sys_, table, scens, 0.0, t1, None, 32, sig,
                               events=events)  # compile
    compile_s = time.perf_counter() - tc
    (finals, hists), wall = timed(eng.simulate_sweep_sharded, sys_, table,
                                  scens, 0.0, t1, None, 32, sig,
                                  events=events)
    jax.block_until_ready(finals.t)

    rows = []
    for i, n in enumerate(names):
        final_i = jax.tree_util.tree_map(lambda x, i=i: x[i], finals)
        hist_i = jax.tree_util.tree_map(lambda x, i=i: x[i], hists)
        s = stats_mod.summarize(sys_, table, final_i, hist_i)
        st = hist_stats(hists, i)
        st.update(
            name=f"fig_risk/{n}", wall_s=wall / len(scens),
            jobs_done=float(np.asarray(finals.completed)[i]),
            ride_jobs_killed=s["ride_jobs_killed"],
            ride_jobs_requeued=s["ride_jobs_requeued"],
            ride_energy_unserved_mwh=s["ride_energy_unserved_mwh"],
            ride_node_downtime_h=s["ride_node_downtime_h"],
            ride_recovery_s=s["ride_recovery_s"],
        )
        rows.append(st)
    for row in rows:
        derived = ";".join(f"{k}={v}" for k, v in row.items()
                           if k not in ("name", "wall_s"))
        print(f"{row['name']},{row['wall_s'] * 1e6:.1f},{derived}")
    save("fig_risk", {"rows": rows})

    if bench_json:
        import json
        payload = {
            "risk/sweep": {
                "steps_per_s": n_steps * len(scens) / wall,
                "wall_s": wall, "compile_s": compile_s,
                "scenarios": len(scens), "steps": n_steps,
            },
            "meta": bench_meta(),
        }
        with open(bench_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {bench_json}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (50 steps)")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--bench-json", default="")
    a = ap.parse_args()
    run(quick=a.smoke, n_steps=a.steps, bench_json=a.bench_json)
