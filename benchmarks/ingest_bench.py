"""Trace-ingestion throughput: job-table parse rate, cached-NPZ restart
speedup, and telemetry-replay engine rate — the repro.traces companion
to ``engine_throughput.py``.

The ingestion layer's perf claims (docs/datasets.md): parquet job tables
parse at O(100k) jobs/s, a content-addressed NPZ cache makes the second
load of a raw telemetry tree much cheaper than the first, and replay
mode (measured ``power_profile`` gathered per step) keeps engine
throughput in the same regime as the synthetic power model. The smoke
mode measures all three on the committed golden fixtures and writes
``BENCH_ingest.json`` (``*_per_s`` leaves + backend meta) for the CI
perf-trajectory gate (tools/bench_compare.py vs
benchmarks/baselines/ingest_history.ndjson).
"""
from __future__ import annotations

import pathlib
import shutil
import tempfile
import time

if __package__ in (None, ""):  # `python benchmarks/ingest_bench.py`
    import sys
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import numpy as np

from repro.core import engine as eng
from repro.core import types as T
from repro.systems.config import get_system
from repro.traces import load_telemetry, read_job_table, source_digest

DATA = pathlib.Path(__file__).resolve().parents[1] / "tests" / "data"
HORIZON = 240  # replay engine steps per timed run


def smoke(bench_json: str = "BENCH_ingest.json", n_parses: int = 20):
    rows = []

    # -- job-table parse rate ----------------------------------------------
    js = read_job_table(DATA / "pm100_small.parquet")   # warm pandas/arrow
    t0 = time.perf_counter()
    for _ in range(n_parses):
        js = read_job_table(DATA / "pm100_small.parquet")
    wall = time.perf_counter() - t0
    rows.append({"name": "ingest/parse-parquet", "wall_s": wall,
                 "jobs_per_s": n_parses * len(js) / wall,
                 "jobs": len(js), "parses": n_parses})

    # -- cached-NPZ restart speedup ----------------------------------------
    cache = pathlib.Path(tempfile.mkdtemp(prefix="ingest_bench_"))
    try:
        t0 = time.perf_counter()
        tjs = load_telemetry(DATA / "joblive", DATA / "jobprofile",
                             prof_dt=20.0, cache_dir=cache)
        cold_wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(n_parses):
            load_telemetry(DATA / "joblive", DATA / "jobprofile",
                           prof_dt=20.0, cache_dir=cache)
        hit_wall = (time.perf_counter() - t0) / n_parses
        digest = source_digest(DATA / "joblive", DATA / "jobprofile")
        rows.append({"name": "ingest/telemetry-cache",
                     "wall_s": cold_wall,
                     "cold_parses_per_s": 1.0 / cold_wall,
                     "cached_loads_per_s": 1.0 / hit_wall,
                     "cache_speedup": cold_wall / hit_wall,
                     "digest": digest[:16]})
    finally:
        shutil.rmtree(cache, ignore_errors=True)

    # -- replay engine rate: measured profiles gathered per step ------------
    system = get_system("marconi100").scaled(64)
    scen = T.Scenario.make("fcfs", "easy")
    t1 = HORIZON * system.dt
    for label, table in (
            ("model", tjs.to_table(len(tjs) + 8)),
            ("replay", tjs.to_table(len(tjs) + 8, replay_power=True))):
        final, _ = eng.simulate(system, table, scen, 0.0, t1)  # compile
        t0 = time.perf_counter()
        final, _ = eng.simulate(system, table, scen, 0.0, t1)
        np.asarray(final.energy_total)                         # sync
        wall = time.perf_counter() - t0
        rows.append({"name": f"ingest/engine-{label}", "wall_s": wall,
                     "steps_per_s": HORIZON / wall, "steps": HORIZON})

    for row in rows:
        derived = ";".join(f"{k}={v}" for k, v in row.items()
                           if k not in ("name",))
        print(f"{row['name']},{derived}")
    if bench_json:
        import json

        from benchmarks.common import bench_meta
        payload = {r["name"]: {k: v for k, v in r.items() if k != "name"}
                   for r in rows}
        payload["meta"] = bench_meta()
        with open(bench_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {bench_json}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI canary (currently the only mode)")
    ap.add_argument("--bench-json", default="BENCH_ingest.json")
    args = ap.parse_args()
    smoke(args.bench_json)
