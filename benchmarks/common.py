"""Shared benchmark plumbing: timing, result records, CSV emission."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, List

import numpy as np

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"


def bench_meta() -> dict:
    """Environment block for BENCH_*.json artifacts: what ran where.

    The perf-trajectory gate (tools/bench_compare.py) only compares runs
    with the same backend, so a laptop-CPU run never gates a GPU baseline."""
    import platform
    import jax
    dev = jax.devices()[0]
    return {
        "backend": jax.default_backend(),
        "device": getattr(dev, "device_kind", str(dev)),
        "n_devices": jax.device_count(),
        "jax": jax.__version__,
        "python": platform.python_version(),
    }


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def save(name: str, payload: dict) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(
        json.dumps(payload, indent=1, default=float))


def emit_csv(rows: List[Dict]) -> None:
    """name,us_per_call,derived CSV per the harness contract."""
    for r in rows:
        name = r["name"]
        us = r.get("us_per_call", r.get("wall_s", 0.0) * 1e6)
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call", "wall_s"))
        print(f"{name},{us:.1f},{derived}")


def hist_stats(hist, idx=None):
    def pick(x):
        a = np.asarray(x, np.float64)
        return a if idx is None else a[idx]
    p = pick(hist.power_total)
    return {
        "util": float(pick(hist.util).mean()),
        "p_avg_mw": float(p.mean() / 1e6),
        "p_max_mw": float(p.max() / 1e6),
        "p_swing_mw": float((p.max() - p.min()) / 1e6),
        "pue": float(pick(hist.pue).mean()),
        "t_tower_c": float(pick(hist.t_tower_return).mean()),
    }
