"""Paper Fig. 5: 15 days of Adastra — at low load all rescheduled policies
overlap and, with known job power profiles, the simulator matches the
observed (replay) power profile's swings."""
from __future__ import annotations

import numpy as np

from benchmarks.common import hist_stats, save, timed
from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.loaders import load_adastra
from repro.systems.config import get_system

POLICIES = [("replay", "none"), ("fcfs", "none"), ("fcfs", "easy"),
            ("priority", "first-fit")]


def run(quick: bool = False):
    sys_ = get_system("adastraMI250")
    days = 4.0 if quick else 15.0
    js = load_adastra(n_jobs=300 if quick else 1000, days=days, seed=5)
    js.assign_prepop_placement(0.0, sys_.n_nodes)
    table = js.to_table()
    scens = [T.Scenario.make(p, b) for p, b in POLICIES]
    (final, hist), wall = timed(eng.simulate_sweep, sys_, table, scens,
                                0.0, days * 86400.0)
    p = np.asarray(hist.power_it, np.float64)
    rows = []
    for i, (pol, b) in enumerate(POLICIES):
        st = hist_stats(hist, i)
        st.update(name=f"fig5/{pol}-{b}", wall_s=wall / len(POLICIES))
        if i > 0:
            # replay/reschedule agreement at low load (the Fig. 5 claim)
            corr = np.corrcoef(p[0], p[i])[0, 1]
            st["corr_vs_replay"] = float(corr)
        rows.append(st)
    save("fig5_adastra", {"rows": rows})
    # reschedule at low load tracks replay closely
    assert all(r.get("corr_vs_replay", 1.0) > 0.55 for r in rows)
    return rows
