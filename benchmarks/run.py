"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4_pm100]

Prints ``name,us_per_call,derived`` CSV per benchmark row; JSON artifacts go
to results/bench/.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import emit_csv

BENCHES = [
    "table1_datasets",
    "fig4_pm100",
    "fig5_adastra",
    "fig6_frontier",
    "fig7_external",
    "fig8_incentives",
    "fig_carbon",
    "fig10_ml",
    "engine_throughput",
    "roofline_table",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced windows/job counts (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    names = [args.only] if args.only else BENCHES
    failures = []
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        try:
            rows = mod.run(quick=args.quick)
            emit_csv(rows)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
            print(f"{name},0,status=FAIL;error={e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
