"""Paper Fig. 6: Frontier snapshot with the cooling model — the system
drains for three full-system runs; policies differ in how they clear the
system; PUE and cooling-tower return temperature respond to the power
swings; backfilled policies smooth the post-run jump.

Weather-sweep mode (the transient-cooling extension): the same policy set
re-runs under a synthetic summer trace and a heat-wave overlay, all
stacked into ONE vmapped sweep — peak tower return temperature and fan
energy become functions of (policy x weather).

Hall-sweep mode (the facility-topology extension): Frontier split into a
4-hall FacilityTopology, with a (maintenance x policy) sweep that knocks
tower cells out of hall 0 — per-hall IT-load share and basin peaks become
rows (``fig6/hall/*``), showing the hall-aware placement shedding the
degraded hall."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import hist_stats, save, timed
from repro.cooling import weather as wx
from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.loaders import load_frontier
from repro.systems.config import FacilityTopology, get_system

POLICIES = [("replay", "none"), ("fcfs", "none"), ("fcfs", "easy"),
            ("priority", "first-fit")]

WEATHER_POLICIES = [("fcfs", "first-fit"), ("thermal_aware", "first-fit")]


def run(quick: bool = False):
    sys_ = get_system("frontier")
    js = load_frontier(n_jobs=500 if quick else 1238,
                       days=0.5 if quick else 1.0, seed=1,
                       full_system_jobs=3)
    js.assign_prepop_placement(0.0, sys_.n_nodes)
    table = js.to_table()
    t1 = (0.5 if quick else 1.0) * 86400.0
    scens = [T.Scenario.make(p, b) for p, b in POLICIES]
    (final, hist), wall = timed(eng.simulate_sweep, sys_, table, scens,
                                0.0, t1)
    rows = []
    for i, (p, b) in enumerate(POLICIES):
        st = hist_stats(hist, i)
        st.update(name=f"fig6/{p}-{b}", wall_s=wall / len(POLICIES),
                  completed=float(np.asarray(final.completed)[i]))
        rows.append(st)
    # the full-system runs must be visible as power peaks near system max
    p_replay = np.asarray(hist.power_it, np.float64)[0]
    peak_frac = p_replay.max() / (sys_.n_nodes * sys_.power.peak_node_w)
    rows.append({"name": "fig6/full-system-peak", "wall_s": 0.0,
                 "peak_fraction": float(peak_frac)})

    wrows, t_ret = run_weather(sys_, table, t1, quick)
    rows += wrows
    rows += run_halls(sys_, table, t1, quick=quick)
    # persist the artifact BEFORE the claim checks: a failed claim should
    # leave the telemetry needed to diagnose it
    save("fig6_frontier", {"rows": rows})
    assert peak_frac > 0.65, "full-system runs should drive power near max"
    # tower return temp must move with the power swing
    t_tower = np.asarray(hist.t_tower_return, np.float64)[0]
    assert t_tower.max() - t_tower.min() > 0.5
    # the heat wave must show up in the loop
    assert t_ret[1].max() > t_ret[0].max() + 1.0
    return rows


def run_weather(sys_, table, t1, quick: bool):
    """(policy x weather) sweep: typical summer vs heat wave, one program.

    Returns (rows, per-scenario tower-return-temp array) — the claim
    checks on the temperatures happen in ``run`` after the artifact is
    saved."""
    n_steps = int(round(t1 / sys_.dt))
    summer = wx.synthetic_weather(n_steps, sys_.dt, t_wb_mean_c=22.0,
                                  seed=2)
    wave = wx.heat_wave(summer, sys_.dt, start_s=0.15 * t1,
                        duration_s=0.6 * t1, peak_amp_c=8.0)
    scens, weathers, names = [], [], []
    for p, b in WEATHER_POLICIES:
        for wname, w in [("summer", summer), ("heatwave", wave)]:
            scens.append(T.Scenario.make(p, b, thermal_weight=20.0))
            weathers.append(w)
            names.append(f"fig6/weather/{p}-{wname}")
    (final, hist), wall = timed(eng.simulate_sweep, sys_, table, scens,
                                0.0, t1, weather=weathers)
    t_ret = np.asarray(hist.t_tower_return, np.float64)
    fan = np.asarray(hist.power_fan, np.float64)
    rows = []
    for i, name in enumerate(names):
        st = hist_stats(hist, i)
        st.update(name=name, wall_s=wall / len(names),
                  completed=float(np.asarray(final.completed)[i]),
                  t_ret_max_c=float(t_ret[i].max()),
                  fan_energy_mwh=float(fan[i].sum() * sys_.dt / 3.6e9))
        rows.append(st)
    return rows, t_ret


def run_halls(sys_, table, t1, n_halls: int = 4, quick: bool = False):
    """(maintenance x policy) sweep on a 4-hall Frontier: per-hall rows.

    The cooling plant is re-rated so the tower fleet sits ~2x above the
    replayed load (stock Frontier cells are sized for the full 29 MW
    machine — maintenance on a drained snapshot would be invisible).
    ``quick`` keeps only the fcfs pair (the CI-budget configuration)."""
    hsys = dataclasses.replace(
        sys_, cooling=dataclasses.replace(
            sys_.cooling, cell_rated_heat_w=1.5e6, fan_rated_w=2.4e4,
            t_return_limit_c=40.0, thermal_margin_c=5.0,
            t_supply_margin_c=5.0,
            topology=FacilityTopology(n_halls=n_halls)))
    degraded = tuple([hsys.cooling.cells_per_hall()[0] / 2.0] +
                     [0.0] * (n_halls - 1))
    scens, names = [], []
    for p, b in (WEATHER_POLICIES[:1] if quick else WEATHER_POLICIES):
        for mname, cells in [("allup", 0.0), ("hall0-degraded", degraded)]:
            scens.append(T.Scenario.make(p, b, thermal_weight=20.0,
                                         cells_offline=cells))
            names.append(f"fig6/hall/{p}-{mname}")
    (final, hist), wall = timed(eng.simulate_sweep, hsys, table, scens,
                                0.0, t1)
    p_hall = np.asarray(hist.power_it_hall, np.float64)
    tb_hall = np.asarray(hist.t_basin_hall, np.float64)
    rows = []
    for i, name in enumerate(names):
        st = hist_stats(hist, i)
        share = p_hall[i].sum(0) / max(p_hall[i].sum(), 1.0)
        st.update(name=name, wall_s=wall / len(names),
                  completed=float(np.asarray(final.completed)[i]),
                  hall0_share=float(share[0]),
                  hall0_basin_max_c=float(tb_hall[i, :, 0].max()),
                  hall_share_spread=float(share.max() - share.min()))
        rows.append(st)
    return rows
