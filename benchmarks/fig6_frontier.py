"""Paper Fig. 6: Frontier snapshot with the cooling model — the system
drains for three full-system runs; policies differ in how they clear the
system; PUE and cooling-tower return temperature respond to the power
swings; backfilled policies smooth the post-run jump."""
from __future__ import annotations

import numpy as np

from benchmarks.common import hist_stats, save, timed
from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.loaders import load_frontier
from repro.systems.config import get_system

POLICIES = [("replay", "none"), ("fcfs", "none"), ("fcfs", "easy"),
            ("priority", "first-fit")]


def run(quick: bool = False):
    sys_ = get_system("frontier")
    js = load_frontier(n_jobs=500 if quick else 1238,
                       days=0.5 if quick else 1.0, seed=1,
                       full_system_jobs=3)
    js.assign_prepop_placement(0.0, sys_.n_nodes)
    table = js.to_table()
    t1 = (0.5 if quick else 1.0) * 86400.0
    scens = [T.Scenario.make(p, b) for p, b in POLICIES]
    (final, hist), wall = timed(eng.simulate_sweep, sys_, table, scens,
                                0.0, t1)
    rows = []
    for i, (p, b) in enumerate(POLICIES):
        st = hist_stats(hist, i)
        st.update(name=f"fig6/{p}-{b}", wall_s=wall / len(POLICIES),
                  completed=float(np.asarray(final.completed)[i]))
        rows.append(st)
    # the full-system runs must be visible as power peaks near system max
    p_replay = np.asarray(hist.power_it, np.float64)[0]
    peak_frac = p_replay.max() / (sys_.n_nodes * sys_.power.peak_node_w)
    rows.append({"name": "fig6/full-system-peak", "wall_s": 0.0,
                 "peak_fraction": float(peak_frac)})
    save("fig6_frontier", {"rows": rows})
    assert peak_frac > 0.65, "full-system runs should drive power near max"
    # tower return temp must move with the power swing
    t_tower = np.asarray(hist.t_tower_return, np.float64)[0]
    assert t_tower.max() - t_tower.min() > 0.5
    return rows
