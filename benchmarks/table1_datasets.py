"""Paper Table 1: systems and datasets used in the study — verify the
synthetic generators reproduce the documented characteristics."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save
from repro.datasets import loaders
from repro.systems.config import get_system

TABLE1 = {
    # system: (nodes, scheduler, has_traces, prof_dt)
    "frontier": (9600, "slurm", True, 15.0),
    "marconi100": (980, "slurm", True, 20.0),
    "fugaku": (158976, "tcs", False, 60.0),
    "lassen": (792, "lsf", False, 60.0),
    "adastraMI250": (356, "slurm", False, 30.0),
}


def run(quick: bool = False):
    rows = []
    for name, (nodes, sched, traces, dt) in TABLE1.items():
        sys_ = get_system(name)
        assert sys_.n_nodes == nodes, (name, sys_.n_nodes)
        assert sys_.scheduler == sched
        assert sys_.has_traces == traces
        js = loaders.load(name, n_jobs=200, days=0.5)
        rows.append({
            "name": f"table1/{name}", "wall_s": 0.0,
            "nodes": nodes, "scheduler": sched,
            "trace_channels": int(js.power_prof.shape[1]),
            "jobs": len(js),
            "mean_job_nodes": float(js.nodes.mean()),
            "mean_wall_h": float(js.wall.mean() / 3600.0),
            "mean_node_power_w": float(js.power_prof.mean()),
        })
    save("table1_datasets", {"rows": rows})
    return rows
