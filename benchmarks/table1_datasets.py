"""Paper Table 1: systems and datasets used in the study — verify the
synthetic generators reproduce the documented characteristics.

With ``--trace`` (CLI) / ``trace=`` (``run``), a *real* ingested job
table (repro.traces) joins the table as a ``table1/trace-real`` row next
to a ``table1/trace-synthetic`` twin generated at the same job count, so
the real-vs-synthetic gap (job count, mean wait, total energy) is one
diff away."""
from __future__ import annotations

if __package__ in (None, ""):  # `python benchmarks/table1_datasets.py`
    import pathlib
    import sys
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import numpy as np

from benchmarks.common import save
from repro.datasets import loaders
from repro.systems.config import get_system

TABLE1 = {
    # system: (nodes, scheduler, has_traces, prof_dt)
    "frontier": (9600, "slurm", True, 15.0),
    "marconi100": (980, "slurm", True, 20.0),
    "fugaku": (158976, "tcs", False, 60.0),
    "lassen": (792, "lsf", False, 60.0),
    "adastraMI250": (356, "slurm", False, 30.0),
}


def _jobset_row(name: str, js) -> dict:
    """Shared characterization of a JobSet: the Table-1 columns plus the
    real-vs-synthetic comparison triplet (jobs / mean wait / energy)."""
    started = np.isfinite(js.rec_start)
    wait = js.rec_start[started] - js.submit[started]
    mean_pw = js.power_prof.mean(axis=1)
    energy_j = float((js.nodes * js.wall * mean_pw).sum())
    return {
        "name": name, "wall_s": 0.0,
        "trace_channels": int(js.power_prof.shape[1]),
        "jobs": len(js),
        "mean_job_nodes": float(js.nodes.mean()),
        "mean_wall_h": float(js.wall.mean() / 3600.0),
        "mean_node_power_w": float(js.power_prof.mean()),
        "mean_wait_h": float(wait.mean() / 3600.0) if started.any() else 0.0,
        "total_energy_mwh": energy_j / 3.6e9,
    }


def run(quick: bool = False, trace=None):
    rows = []
    for name, (nodes, sched, traces, dt) in TABLE1.items():
        sys_ = get_system(name)
        assert sys_.n_nodes == nodes, (name, sys_.n_nodes)
        assert sys_.scheduler == sched
        assert sys_.has_traces == traces
        js = loaders.load(name, n_jobs=200, days=0.5)
        rows.append({**_jobset_row(f"table1/{name}", js),
                     "nodes": nodes, "scheduler": sched})
    if trace:
        real = loaders.load_trace(trace)
        days = max(float(real.submit.max()) / 86400.0, 1e-6)
        synth = loaders.load("marconi100", n_jobs=len(real), days=days)
        rows.append(_jobset_row("table1/trace-real", real))
        rows.append(_jobset_row("table1/trace-synthetic", synth))
    save("table1_datasets", {"rows": rows})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--trace", nargs="+", default=None,
                    help="real job table / telemetry paths (repro.traces) "
                         "to characterize against a synthetic twin")
    args = ap.parse_args()
    for r in run(quick=args.quick, trace=args.trace):
        print(",".join(f"{k}={v}" for k, v in r.items()))
