"""Calibration: known-parameter recovery + the regression envelope gate.

Two promises (repro.traces.calibrate):

* **Recovery.** Fitting the frontier plant against telemetry generated
  by a *known-parameter* plant (the committed
  ``tests/data/calibration/telemetry.npz``, truth stored as ``true_*``
  keys) recovers every fitted parameter within the documented tolerance
  — 2% for ``ua_w_k`` / ``tau_hx_s`` and ``basin_margin_c`` (the actual
  fixture errors are 0.05% / 0.27% / 0.00%; the tolerance leaves room
  for toolchain jitter, not physics drift).
* **Regression gate.** The committed ``fitted_params.json`` must keep
  reproducing the committed telemetry: ``check_envelope`` re-simulates
  with the committed parameters and fails if any channel's RMSE widens
  beyond the 5% numerical slack. A cooling-model change that silently
  degrades calibration fails tier-1 here.
"""
import json

import numpy as np
import pytest

import repro.traces.calibrate as cal
from conftest import DATA_DIR
from repro.systems.config import SYSTEMS
from repro.traces import TraceError

CAL_DIR = DATA_DIR / "calibration"
# documented recovery tolerance (relative) per fitted parameter
RECOVERY_RTOL = {"ua_w_k": 0.02, "tau_hx_s": 0.02, "basin_margin_c": 0.02}


@pytest.fixture(scope="module")
def telemetry():
    z = np.load(CAL_DIR / "telemetry.npz", allow_pickle=False)
    return {k: z[k] for k in z.files}


@pytest.fixture(scope="module")
def fitted():
    return cal.FittedParams.load(CAL_DIR / "fitted_params.json")


def _obs(tel):
    return {ch: tel[ch] for ch in ("t_basin_c", "t_supply_c",
                                   "t_return_c", "pue")}


def test_known_parameter_recovery(telemetry):
    tel = telemetry
    cfg = SYSTEMS["frontier"].cooling
    out = cal.calibrate(cfg, tel["p_it_w"], float(tel["dt"]),
                        tel["t_wetbulb_c"], _obs(tel))
    assert set(out.params) == set(RECOVERY_RTOL)
    for name, rtol in RECOVERY_RTOL.items():
        truth = float(tel[f"true_{name}"])
        got = out.params[name]
        err = abs(got - truth) / truth
        assert err <= rtol, (f"{name}: fitted {got:.6g} vs truth "
                             f"{truth:.6g} — {err:.2%} > {rtol:.0%}")
    # the fit must actually move: truth differs from the config defaults
    for name in ("ua_w_k", "tau_hx_s"):
        assert abs(out.params[name] - float(getattr(cfg, name))) > \
            0.05 * float(getattr(cfg, name))


def test_committed_envelope_holds(telemetry, fitted):
    """THE regression gate: committed params still reproduce the
    committed telemetry within the committed envelope * 5% slack."""
    tel = telemetry
    cfg = SYSTEMS["frontier"].cooling
    fresh = cal.check_envelope(fitted, cfg, tel["p_it_w"],
                               float(tel["dt"]), tel["t_wetbulb_c"],
                               _obs(tel))
    assert set(fresh) == set(fitted.envelope)


def test_envelope_gate_trips_on_degraded_physics(telemetry, fitted):
    """A plant that drifted from the calibration must fail the gate —
    proves the check has teeth, not just a vacuous pass."""
    import dataclasses
    tel = telemetry
    broken = dataclasses.replace(fitted,
                                 params={**fitted.params,
                                         "ua_w_k":
                                         fitted.params["ua_w_k"] * 2.0})
    with pytest.raises(TraceError, match="envelope widened"):
        cal.check_envelope(broken, SYSTEMS["frontier"].cooling,
                           tel["p_it_w"], float(tel["dt"]),
                           tel["t_wetbulb_c"], _obs(tel))


def test_fitted_params_json_is_self_describing(fitted):
    blob = json.loads((CAL_DIR / "fitted_params.json").read_text())
    assert blob["params"] == fitted.params
    assert fitted.meta["system"] == "frontier"
    assert sorted(fitted.meta["fit"]) == sorted(RECOVERY_RTOL)
    assert fitted.meta["channels"] == ["pue", "t_basin_c", "t_return_c",
                                      "t_supply_c"]
    for ch, v in fitted.envelope.items():
        assert np.isfinite(v) and v > 0.0, \
            f"{ch}: a zero/non-finite envelope makes the gate degenerate"


def test_simulate_plant_overrides_change_the_rollout(telemetry):
    tel = telemetry
    cfg = SYSTEMS["frontier"].cooling
    S = 500
    heat, wb = tel["p_it_w"][:S], tel["t_wetbulb_c"][:S]
    base = cal.simulate_plant(cfg, heat, float(tel["dt"]), wb)
    warm = cal.simulate_plant(cfg, heat, float(tel["dt"]), wb,
                              overrides={"ua_w_k": cfg.ua_w_k * 0.5})
    assert not np.array_equal(base["t_supply_c"], warm["t_supply_c"])
    for sim in (base, warm):
        for ch, v in sim.items():
            assert np.isfinite(v).all(), ch


def test_calibrate_rejects_mismatched_traces(telemetry):
    tel = telemetry
    cfg = SYSTEMS["frontier"].cooling
    with pytest.raises(TraceError):
        cal.calibrate(cfg, tel["p_it_w"][:100], float(tel["dt"]),
                      tel["t_wetbulb_c"], _obs(tel))
    with pytest.raises(TraceError):
        cal.calibrate(cfg, tel["p_it_w"], float(tel["dt"]),
                      tel["t_wetbulb_c"], {})
    with pytest.raises(TraceError):
        cal.calibrate(cfg, tel["p_it_w"], float(tel["dt"]),
                      tel["t_wetbulb_c"], _obs(tel), fit=("not_a_field",))


def test_calibrate_cli_check_gate(capsys):
    rc = cal.main(["--telemetry", str(CAL_DIR / "telemetry.npz"),
                   "--system", "frontier",
                   "--check", str(CAL_DIR / "fitted_params.json")])
    assert rc == 0
    assert "envelope holds" in capsys.readouterr().out
