"""Physics substrate tests: power model, conversion losses, cooling ODE."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.cooling import model as cooling
from repro.core import types as T
from repro.power import losses as pl
from repro.power import model as pm
from repro.systems.config import get_system

SYS = get_system("marconi100").scaled(64)


def test_locf_profile_lookup():
    table = T.JobTable(
        submit=jnp.zeros(2), limit=jnp.ones(2), wall=jnp.ones(2) * 100,
        nodes=jnp.ones(2, jnp.int32), priority=jnp.zeros(2),
        account=jnp.zeros(2, jnp.int32), rec_start=jnp.zeros(2),
        first_node=jnp.zeros(2, jnp.int32), score=jnp.zeros(2),
        power_prof=jnp.asarray([[100.0, 200.0, 300.0],
                                [50.0, 50.0, 50.0]]),
        util_prof=jnp.ones((2, 3)) * 0.5, valid=jnp.ones(2, bool))
    jstate = jnp.asarray([T.RUNNING, T.RUNNING], jnp.int32)
    start = jnp.zeros(2)
    # mid-trace
    p = pm.job_node_power(table, jstate, start, jnp.float32(20.0), 20.0)
    np.testing.assert_allclose(np.asarray(p), [200.0, 50.0])
    # beyond the trace -> last observation carried forward (paper §3.2.2)
    p = pm.job_node_power(table, jstate, start, jnp.float32(500.0), 20.0)
    np.testing.assert_allclose(np.asarray(p), [300.0, 50.0])
    # before start clamps to first sample
    p = pm.job_node_power(table, jstate, start + 100.0, jnp.float32(0.0),
                          20.0)
    np.testing.assert_allclose(np.asarray(p), [100.0, 50.0])


def test_idle_nodes_draw_idle_power():
    node_job = jnp.asarray([-1, 0, -1], jnp.int32)
    job_pw = jnp.asarray([900.0])
    table = None
    p = pm.node_power(SYS, table, node_job, job_pw)
    np.testing.assert_allclose(
        np.asarray(p), [SYS.power.idle_node_w, 900.0, SYS.power.idle_node_w])


def test_conversion_losses_positive_and_bounded():
    for load_w in [1e3, 1e5, 1e6, 5e6]:
        p_in, loss = pl.conversion(SYS.power, jnp.float32(load_w), 10.0)
        assert float(p_in) > load_w           # losses are positive
        assert float(loss) / load_w < 0.6     # efficiency floor respected
        assert np.isclose(float(p_in) - load_w, float(loss), rtol=1e-6)


def test_efficiency_improves_with_load():
    """Fractional loss at higher rectifier load must be lower (up to rated):
    this is what makes scheduling visible in the loss curve."""
    frac = []
    for load_w in [1e4, 1e5, 1e6]:
        p_in, loss = pl.conversion(SYS.power, jnp.float32(load_w), 10.0)
        frac.append(float(loss) / load_w)
    assert frac[0] > frac[1] > frac[2]


def test_cooling_steady_state_tracks_load():
    cfg = SYS.cooling
    state = cooling.init_state(cfg)
    lo = jnp.full((cfg.n_groups,), 2e4)
    hi = jnp.full((cfg.n_groups,), 2e5)
    for _ in range(500):
        state, out_lo = cooling.step(cfg, state, lo, 30.0)
    state_hi = cooling.init_state(cfg)
    for _ in range(500):
        state_hi, out_hi = cooling.step(cfg, state_hi, hi, 30.0)
    # hotter water under load
    assert float(out_hi.t_tower_return) > float(out_lo.t_tower_return)
    # more fan power under load
    assert float(out_hi.p_cooling) > float(out_lo.p_cooling)
    assert float(state_hi.t_basin[0]) > float(state.t_basin[0])
    # return temperature always above wet bulb
    assert float(out_lo.t_tower_return) > cfg.t_wetbulb_c


def test_pue_above_one_and_reasonable():
    p_it = jnp.float32(1.5e6)
    _, loss = pl.conversion(SYS.power, p_it, 15.0)
    pue = cooling.pue(p_it, loss, jnp.float32(5e4))
    assert 1.0 < float(pue) < 1.5
