"""Trace ingestion + telemetry replay (repro.traces): oracle tests.

The contract with the rest of the twin, in test form:

* **Roundtrip digest invariance** — a PM100-style parquet job table, its
  SWF export and a ``write_job_table`` re-export all ingest to the same
  ``transport.job_digest`` (whole-second rounding is the shared
  canonical form).
* **Cache identity** — the content-addressed NPZ cache serves the exact
  bytes of the cold parse: cold-with-cache, cache-hit and a direct
  ``jobset_from_npz`` load are leaf-for-leaf bit-identical.
* **Replay exactness** — with ``to_table(replay_power=True)`` the
  per-step power of a measured job pointwise-equals its recorded
  profile sample (LOCF work-time indexing), while profile-less jobs
  (all ``-1`` sentinel rows) reproduce the model **bit-for-bit**, both
  at the kernel and through a full engine rollout.
* **Replay composes with events** — killing a profiled job moves its
  measured-accrued energy into the energy-not-served ledger; nothing
  is double-counted.
* **Weather traces** — the measured-weather loader hands the cooling
  model a finite wet-bulb that never exceeds its dry-bulb.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import DATA_DIR, assert_trees_equal
from repro.core import engine as eng
from repro.core import transport
from repro.core import types as T
from repro.datasets import loaders, swf
from repro.events import EventConfig
from repro.power import model as pm
from repro.traces import (TraceError, jobset_from_npz, load_telemetry,
                          read_job_table, source_digest, write_job_table)

HORIZON = 120  # engine steps per rollout test


# ---------------------------------------------------------------------------
# Roundtrip digest invariance
# ---------------------------------------------------------------------------
def test_parquet_and_swf_ingest_to_same_digest():
    js_pq = read_job_table(DATA_DIR / "pm100_small.parquet")
    js_swf = swf.read_swf(DATA_DIR / "pm100_small.swf")
    assert len(js_pq) == 200
    assert transport.job_digest(js_pq) == transport.job_digest(js_swf)


def test_write_job_table_roundtrip_digest_stable(tmp_path):
    js = read_job_table(DATA_DIR / "pm100_small.parquet")
    for ext in ("parquet", "csv"):
        out = tmp_path / f"rt.{ext}"
        write_job_table(js, out)
        back = read_job_table(out)
        assert transport.job_digest(back) == transport.job_digest(js), ext
        # the digest-covered columns are exactly equal, not merely
        # digest-colliding
        for col in ("submit", "limit", "wall", "nodes", "account"):
            np.testing.assert_array_equal(getattr(back, col),
                                          getattr(js, col), err_msg=col)


def test_swf_export_roundtrips_through_datasets_swf(tmp_path):
    js = read_job_table(DATA_DIR / "pm100_small.parquet")
    swf.write_swf(js, tmp_path / "rt.swf")
    back = swf.read_swf(tmp_path / "rt.swf")
    assert transport.job_digest(back) == transport.job_digest(js)


def test_malformed_rows_raise_trace_error(tmp_path):
    import pandas as pd
    df = pd.read_parquet(DATA_DIR / "pm100_small.parquet")
    bad = df.copy()
    bad.loc[3, "num_nodes"] = 0
    bad.to_parquet(tmp_path / "bad.parquet", index=False)
    with pytest.raises(TraceError):
        read_job_table(tmp_path / "bad.parquet")


# ---------------------------------------------------------------------------
# Telemetry parse + NPZ cache identity
# ---------------------------------------------------------------------------
def test_telemetry_parse_shape_and_sentinels(trace_jobset):
    js = trace_jobset
    assert len(js) == 30
    assert js.power_profile is not None
    prof = np.asarray(js.power_profile)
    measured = (prof >= 0).any(axis=1)
    # fixture: two thirds of the jobs are profiled, the rest are all -1
    assert 0 < measured.sum() < len(js)
    profileless = prof[~measured]
    assert (profileless < 0).all(), "profile-less rows must be all-sentinel"
    # measured rows are fully populated (LOCF fills the job's whole wall)
    assert np.isfinite(prof[measured]).all()


def test_npz_cache_bit_identical_to_cold_parse(tmp_path, trace_jobset):
    cache = tmp_path / "cache"
    cold = load_telemetry(DATA_DIR / "joblive", DATA_DIR / "jobprofile",
                          prof_dt=20.0, cache_dir=cache)
    digest = source_digest(DATA_DIR / "joblive", DATA_DIR / "jobprofile")
    npz = cache / f"trace-{digest[:16]}.npz"
    assert npz.exists(), "cache file must be content-addressed by digest"
    hit = load_telemetry(DATA_DIR / "joblive", DATA_DIR / "jobprofile",
                         prof_dt=20.0, cache_dir=cache)
    direct = jobset_from_npz(npz)
    nocache = load_telemetry(DATA_DIR / "joblive", DATA_DIR / "jobprofile",
                             prof_dt=20.0)
    for name, other in (("cache hit", hit), ("direct npz", direct),
                        ("no-cache parse", nocache),
                        ("session fixture", trace_jobset)):
        assert_trees_equal(vars(cold), vars(other), f"cold vs {name}")


def test_load_trace_dispatch(tmp_path):
    js_dir = loaders.load_trace([DATA_DIR / "joblive", DATA_DIR / "jobprofile"],
                                cache_dir=tmp_path)
    js_pq = loaders.load_trace([DATA_DIR / "pm100_small.parquet"])
    assert js_dir.power_profile is not None
    assert js_pq.power_profile is None and len(js_pq) == 200
    digest = source_digest(DATA_DIR / "joblive", DATA_DIR / "jobprofile")
    js_npz = loaders.load_trace([tmp_path / f"trace-{digest[:16]}.npz"])
    assert_trees_equal(vars(js_dir), vars(js_npz), "dir vs cached npz")
    with pytest.raises(TraceError):
        loaders.load_trace([DATA_DIR / "does_not_exist.xyz"])


# ---------------------------------------------------------------------------
# Replay exactness
# ---------------------------------------------------------------------------
def test_to_table_replay_gate(trace_jobset):
    js = trace_jobset
    plain = js.to_table(len(js) + 8)
    assert plain.power_profile is None, "replay must be off by default"
    table = js.to_table(len(js) + 8, replay_power=True)
    prof = np.asarray(table.power_profile)
    assert prof.shape[0] == len(js) + 8
    assert (prof[len(js):] == -1.0).all(), "padded rows must be sentinel"
    bare = dataclasses.replace(js, power_profile=None)
    with pytest.raises(ValueError):
        bare.to_table(replay_power=True)


def test_replay_power_pointwise_equals_measurement(trace_jobset):
    js = trace_jobset
    table = js.to_table(replay_power=True)
    J, Q = np.asarray(table.power_profile).shape
    running = jnp.full((J,), T.RUNNING, jnp.int32)
    prof = np.asarray(table.power_profile)
    model = np.asarray(table.power_prof)
    measured = (prof >= 0).any(axis=1)
    for elapsed_s in (0.0, 10.0, 45.0, 300.0, 1e6):
        el = jnp.full((J,), elapsed_s, jnp.float32)
        p = np.asarray(pm.job_node_power_elapsed(table, running, el, 20.0))
        idx = min(int(elapsed_s / 20.0), Q - 1)
        # measured jobs play back the recorded sample verbatim
        np.testing.assert_array_equal(p[measured], prof[measured, idx],
                                      err_msg=f"elapsed={elapsed_s}")
        # profile-less jobs keep the model bit-for-bit
        np.testing.assert_array_equal(p[~measured], model[~measured, 0],
                                      err_msg=f"elapsed={elapsed_s}")


def test_all_sentinel_profile_is_bit_identical_to_model(small_system,
                                                        trace_jobset):
    """Attaching an all--1 ``power_profile`` compiles the replay graph but
    must reproduce the no-field run bit-for-bit — the fallback path is the
    model, exactly."""
    js = trace_jobset
    table = js.to_table(len(js) + 8)
    Q = np.asarray(js.power_profile).shape[1]
    sentinel = jnp.full((table.num_jobs, Q), -1.0, jnp.float32)
    table_neg = dataclasses.replace(table, power_profile=sentinel)
    scen = T.Scenario.make("fcfs", "easy")
    t1 = HORIZON * small_system.dt
    f_off, h_off = eng.simulate(small_system, table, scen, 0.0, t1)
    f_neg, h_neg = eng.simulate(small_system, table_neg, scen, 0.0, t1)
    assert_trees_equal(h_off, h_neg, "all-sentinel replay hist")
    assert_trees_equal(f_off, f_neg, "all-sentinel replay final")


@pytest.fixture(scope="module")
def replay_run(small_system, trace_jobset):
    table = trace_jobset.to_table(len(trace_jobset) + 8, replay_power=True)
    scen = T.Scenario.make("fcfs", "easy")
    t1 = HORIZON * small_system.dt
    final, hist = eng.simulate(small_system, table, scen, 0.0, t1)
    return table, final, hist


def test_replay_changes_power_and_stays_finite(small_system, trace_jobset,
                                               replay_run):
    _, final, hist = replay_run
    plain = trace_jobset.to_table(len(trace_jobset) + 8)
    scen = T.Scenario.make("fcfs", "easy")
    f0, h0 = eng.simulate(small_system, plain, scen, 0.0,
                          HORIZON * small_system.dt)
    p_rep = np.asarray(hist.power_total, np.float64)
    p_mod = np.asarray(h0.power_total, np.float64)
    assert np.isfinite(p_rep).all()
    # the fixture's measured powers differ from the synthetic model, so
    # replay must actually move the power trajectory
    assert not np.array_equal(p_rep, p_mod), \
        "replay mode changed nothing — measured profiles were ignored"
    # ... without touching the schedule: same jobs started at same times
    np.testing.assert_array_equal(np.asarray(final.jstate),
                                  np.asarray(f0.jstate))
    np.testing.assert_array_equal(np.asarray(final.start),
                                  np.asarray(f0.start))


def test_replay_energy_ledger_integrates_measured_power(small_system,
                                                        replay_run):
    _, final, hist = replay_run
    np.testing.assert_allclose(
        float(np.asarray(final.energy_total)),
        float(np.asarray(hist.power_total, np.float64).sum()
              * small_system.dt),
        rtol=1e-4)


def test_replay_composes_with_events(small_system, trace_jobset):
    """Killed profiled jobs hand their measured-accrued energy to the
    energy-not-served ledger — replay and the failure engine compose."""
    table = trace_jobset.to_table(len(trace_jobset) + 8, replay_power=True)
    scen = T.Scenario.make("fcfs", "easy", failure_seed=3.0,
                           node_fail_rate=5e-4, cdu_fail_rate=2e-5,
                           failure_corr=0.5, repair_s=900.0)
    t1 = HORIZON * small_system.dt
    final, hist = eng.simulate(small_system, table, scen, 0.0, t1,
                               events=EventConfig())
    assert float(np.asarray(final.events.jobs_killed)) > 0, \
        "kill fixture drew no failures — the composition test is vacuous"
    lost_j = float(np.asarray(final.events.energy_lost_j))
    assert lost_j > 0.0
    # conservation: surviving accrual + not-served never exceeds the IT
    # integral (accrual excludes the idle floor, hence <=)
    jobs_j = float(np.asarray(final.jenergy, np.float64).sum())
    energy_it = float(np.asarray(final.energy_it))
    assert jobs_j + lost_j <= energy_it * (1.0 + 1e-5)


# ---------------------------------------------------------------------------
# Measured weather
# ---------------------------------------------------------------------------
def test_weather_trace_is_finite_and_physical(trace_weather):
    wb = np.asarray(trace_weather.t_wetbulb_c)
    db = np.asarray(trace_weather.t_drybulb_c)
    assert wb.shape == (360,) and db.shape == (360,)
    assert np.isfinite(wb).all() and np.isfinite(db).all()
    assert (wb <= db + 1e-6).all(), "wet-bulb must not exceed dry-bulb"


def test_weather_trace_drives_the_engine(small_system, trace_jobset,
                                         trace_weather):
    table = trace_jobset.to_table(len(trace_jobset) + 8, replay_power=True)
    scen = T.Scenario.make("fcfs", "easy")
    t1 = 360 * small_system.dt
    _, h_wx = eng.simulate(small_system, table, scen, 0.0, t1,
                           weather=trace_weather)
    _, h0 = eng.simulate(small_system, table, scen, 0.0, t1)
    assert np.isfinite(np.asarray(h_wx.power_total)).all()
    assert not np.array_equal(np.asarray(h_wx.power_cooling),
                              np.asarray(h0.power_cooling)), \
        "measured weather did not reach the cooling model"
