"""Facility-topology tests: 1-hall equivalence with the pre-refactor flat
plant, hall-level energy conservation, maintenance (cells_offline)
monotonicity, hierarchical fused-kernel parity at Frontier scale, and the
hall-aware scheduler shifting load away from a degraded hall."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import make_table, with_topology
from repro.cooling import model as cooling
from repro.cooling import weather as wx
from repro.core import engine as eng
from repro.core import types as T
from repro.kernels.power_topo import ops as topo_ops
from repro.kernels.power_topo import ref as topo_ref
from repro.systems.config import FacilityTopology, get_system


@pytest.fixture(scope="module")
def system():
    return get_system("marconi100").scaled(64)


# ---------------------------------------------------------------------------
# Pre-refactor equivalence: the flat (1-hall) plant must reproduce the
# original scalar-basin model trajectory.
# ---------------------------------------------------------------------------
def flat_reference_step(cfg, state, q, dt, t_wb=None, t_set=None):
    """The pre-hierarchy scalar tower/basin update, transcribed from the
    flat model (one basin, one fan-staging scalar, global reuse split) as
    the equivalence oracle. Returns (t_basin, fan, q_reject, fan_w)."""
    t_wb = cfg.t_wetbulb_c if t_wb is None else t_wb
    t_set = cfg.t_supply_setpoint_c if t_set is None else t_set
    q_tot = float(np.sum(q))
    mdot = np.asarray(state["mdot"])
    t_return = np.asarray(state["t_return"])
    t_ret_mix = float((mdot * t_return).sum() / max(mdot.sum(), 1e-6))
    q_reuse = min(cfg.reuse_frac * q_tot, cfg.reuse_max_w) \
        if t_ret_mix >= cfg.reuse_t_min_c else 0.0
    q_tower = q_tot - q_reuse
    cell_ua = cfg.cell_ua()
    mcp_b = cfg.basin_mcp()
    passive_ua = cfg.passive_ua_frac * cfg.n_tower_cells * cell_ua
    q_passive = passive_ua * (state["t_basin"] - t_wb)
    t_b_tgt = max(t_wb + cfg.tower_approach_c, t_set - cfg.basin_margin_c)
    drive = max(state["t_basin"] - t_wb, 0.5)
    q_need = q_tower - q_passive + \
        mcp_b * (state["t_basin"] - t_b_tgt) / cfg.tower_tau_s
    s_tgt = np.clip(q_need / (cell_ua * drive), 0.0,
                    float(cfg.n_tower_cells))
    fan = state["fan"] + (s_tgt - state["fan"]) * \
        min(dt / cfg.tau_fan_s, 1.0)
    q_rej = max(fan * cell_ua * (state["t_basin"] - t_wb), 0.0) + q_passive
    t_basin = state["t_basin"] + (q_tower - q_rej) * dt / mcp_b
    k = np.floor(fan)
    fan_w = cfg.fan_rated_w * (k + (fan - k) ** 3)
    return t_basin, fan, q_rej, fan_w


def test_one_hall_matches_pre_refactor_flat_model(system):
    """The hierarchical plant with H = 1 must track the original scalar
    model to <= 1e-5 (relative) over a random load transient — the
    refactor is behavior-preserving where the old model applied."""
    cfg = system.cooling
    assert cfg.n_halls == 1
    dt = 30.0
    rng = np.random.default_rng(11)
    state = cooling.init_state(cfg)
    ref = {"t_basin": float(state.t_basin[0]), "fan": 0.0,
           "mdot": np.asarray(state.mdot),
           "t_return": np.asarray(state.t_return)}
    p = cooling.cdu_params(cfg, dt)
    for k in range(400):
        q = rng.uniform(1e4, 2.5e5, cfg.n_groups).astype(np.float32)
        # oracle: flat CDU update (scalar basin broadcast) + scalar tower
        qj, t_ret_r, t_sup_r, md_r = topo_ref.cdu_update_ref(
            jnp.asarray(q), jnp.asarray(ref.get("t_supply",
                                                np.asarray(state.t_supply))),
            jnp.asarray(ref["mdot"]), jnp.float32(ref["t_basin"]),
            jnp.float32(cfg.t_supply_setpoint_c), p)
        ref["mdot"], ref["t_return"] = np.asarray(md_r), np.asarray(t_ret_r)
        ref["t_supply"] = np.asarray(t_sup_r)
        tb, fan, q_rej, fan_w = flat_reference_step(cfg, ref,
                                                    np.asarray(qj), dt)
        ref["t_basin"], ref["fan"] = float(tb), float(fan)
        # system under test: the hierarchical path
        state, out = cooling.step(cfg, state, jnp.asarray(q), dt)
        np.testing.assert_allclose(float(state.t_basin[0]), ref["t_basin"],
                                   rtol=1e-5, err_msg=f"basin @step {k}")
        np.testing.assert_allclose(float(state.fan_stages[0]), ref["fan"],
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f"fan @step {k}")
        np.testing.assert_allclose(np.asarray(state.t_supply),
                                   ref["t_supply"], rtol=1e-5)
        np.testing.assert_allclose(float(out.q_reject_w), q_rej,
                                   rtol=1e-4, atol=1.0)
        np.testing.assert_allclose(float(out.p_fan), fan_w,
                                   rtol=1e-4, atol=1e-2)


def test_symmetric_halls_mirror_each_other(system):
    """Two identical halls fed identical loads must produce identical
    per-hall trajectories (no hidden cross-hall coupling)."""
    cfg = with_topology(system.cooling, 2, n_groups=4, n_cells=2)
    state = cooling.init_state(cfg)
    q = jnp.asarray([1.5e5, 0.7e5, 1.5e5, 0.7e5], jnp.float32)
    for _ in range(300):
        state, out = cooling.step(cfg, state, q, 30.0)
    np.testing.assert_allclose(float(state.t_basin[0]),
                               float(state.t_basin[1]), rtol=1e-6)
    np.testing.assert_allclose(float(out.fan_w_hall[0]),
                               float(out.fan_w_hall[1]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Hall-level energy conservation.
# ---------------------------------------------------------------------------
def test_hall_energy_balance_per_hall_and_summed(system):
    """Over any transient, each hall's basin stored-energy change equals
    the integral of (its tower-bound heat - its rejection), and the
    hall-summed telemetry conserves facility energy."""
    cfg = with_topology(system.cooling, 3, n_groups=6, n_cells=3)
    dt = 30.0
    rng = np.random.default_rng(5)
    state = cooling.init_state(cfg)
    mcp_h = np.asarray(cfg.basin_mcp_per_hall())
    t0 = np.asarray(state.t_basin)
    acc = np.zeros(3)
    for _ in range(300):
        q = jnp.asarray(rng.uniform(1e4, 2e5, cfg.n_groups), jnp.float32)
        state, out = cooling.step(cfg, state, q, dt)
        q_tower_h = np.asarray(out.q_hall_w) - 0.0  # reuse off by default
        acc += (q_tower_h - np.asarray(out.q_reject_hall_w)) * dt
    stored = mcp_h * (np.asarray(state.t_basin) - t0)
    np.testing.assert_allclose(acc, stored, rtol=1e-3, atol=1e3)
    np.testing.assert_allclose(acc.sum(), stored.sum(), rtol=1e-3, atol=1e3)


# ---------------------------------------------------------------------------
# Maintenance what-if: cells offline.
# ---------------------------------------------------------------------------
def test_cells_offline_monotonically_heats_that_hall(system):
    """Taking tower cells offline in one hall monotonically raises that
    hall's steady basin temperature and leaves the other halls' untouched
    (their loops are independent given the same group heat)."""
    cfg = with_topology(system.cooling, 3, n_groups=6, n_cells=6)
    q = jnp.full((cfg.n_groups,), 1.5e5, jnp.float32)
    finals = []
    for off in (0.0, 1.0, 2.0):
        state = cooling.init_state(cfg)
        for _ in range(600):
            state, out = cooling.step(
                cfg, state, q, 30.0,
                cells_offline=jnp.asarray([off, 0.0, 0.0], jnp.float32))
        finals.append(np.asarray(state.t_basin))
        assert float(out.cells_online[0]) == cfg.cells_per_hall()[0] - off
    t_hall0 = [f[0] for f in finals]
    assert t_hall0[0] < t_hall0[1] < t_hall0[2]
    for a, b in zip(finals[:-1], finals[1:]):
        np.testing.assert_allclose(a[1:], b[1:], rtol=1e-6)


# ---------------------------------------------------------------------------
# Hierarchical fused-kernel parity at Frontier scale (acceptance: <= 1e-4
# at >= 4 halls and the full Frontier node count).
# ---------------------------------------------------------------------------
def test_hier_fused_kernel_parity_frontier_scale():
    sysc = get_system("frontier")
    N, G, H, S = sysc.n_nodes, sysc.cooling.n_groups, 5, 8
    topo = FacilityTopology(n_halls=H)
    hog = topo.hall_of_group(G)
    rng = np.random.default_rng(17)
    node_pw = jnp.asarray(rng.uniform(700.0, 3200.0, (S, N)), jnp.float32)
    ts = jnp.asarray(rng.uniform(28.0, 40.0, (S, G)), jnp.float32)
    md = jnp.asarray(rng.uniform(12.0, 60.0, (S, G)), jnp.float32)
    tb = jnp.asarray(rng.uniform(18.0, 30.0, (S, H)), jnp.float32)
    tset = jnp.asarray(rng.uniform(30.0, 34.0, (S,)), jnp.float32)
    p = cooling.cdu_params(sysc.cooling, sysc.dt)
    want = topo_ref.fused_cooling_hier_ref(node_pw, ts, md, tb, tset, hog,
                                           G, p)
    got = topo_ops.fused_cooling_hier(node_pw, ts, md, tb, tset, hog, G, p,
                                      use_pallas=True, interpret=True)
    for w, g, name in zip(want, got,
                          ("q", "t_return", "t_supply", "mdot", "q_hall")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


def test_hier_fused_unbatched_matches_ref():
    cfg = with_topology(get_system("marconi100").scaled(64).cooling, 2,
                        n_groups=4)
    hog = cfg.hall_of_group()
    p = cooling.cdu_params(cfg, 20.0)
    node_pw = jnp.full((64,), 900.0, jnp.float32)
    ts = jnp.full((4,), 25.0)
    md = jnp.full((4,), 10.0)
    tb = jnp.asarray([22.0, 24.0], jnp.float32)
    want = topo_ref.fused_cooling_hier_ref(node_pw, ts, md, tb,
                                           jnp.float32(25.0), hog, 4, p)
    got = topo_ops.fused_cooling_hier(node_pw, ts, md, tb, jnp.float32(25.0),
                                      hog, 4, p, use_pallas=True,
                                      interpret=True)
    assert got[4].shape == (2,)
    for w, g in zip(want, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4)


# ---------------------------------------------------------------------------
# Engine integration: telemetry consistency + the hall-aware scheduler.
# ---------------------------------------------------------------------------
T1 = 4 * 3600.0


def test_engine_hall_telemetry_consistent(system):
    """Per-hall IT power sums to the facility IT power and the scalar
    basin telemetry is the hottest hall."""
    sys4 = dataclasses.replace(
        system, cooling=with_topology(system.cooling, 4, n_groups=4,
                                      n_cells=4))
    table = make_table(sys4, 2)
    scen = T.Scenario.make("fcfs", "first-fit")
    _, h = eng.simulate(sys4, table, scen, 0.0, T1, num_accounts=8)
    assert h.power_it_hall.shape[-1] == 4
    np.testing.assert_allclose(np.asarray(h.power_it_hall).sum(-1),
                               np.asarray(h.power_it), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h.t_basin_hall).max(-1),
                               np.asarray(h.t_basin), rtol=1e-6)


def test_neutral_cells_offline_is_identity(system):
    """cells_offline=0 must not perturb a multi-hall trajectory (neutral
    default of the new Scenario knob)."""
    sys2 = dataclasses.replace(
        system, cooling=with_topology(system.cooling, 2, n_groups=4,
                                      n_cells=2))
    table = make_table(sys2, 3)
    scens = [T.Scenario.make("fcfs", "first-fit"),
             T.Scenario.make("fcfs", "first-fit",
                             cells_offline=(0.0, 0.0))]
    _, h = eng.simulate_sweep(sys2, table, scens, 0.0, T1, num_accounts=8)
    np.testing.assert_allclose(np.asarray(h.power_it)[0],
                               np.asarray(h.power_it)[1], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h.t_basin_hall)[0],
                               np.asarray(h.t_basin_hall)[1], rtol=1e-6)


def test_scheduler_shifts_load_away_from_degraded_hall(system):
    """Acceptance: with one hall's tower cells knocked out, the hall-aware
    placement (+ per-hall admission gate) moves work into the healthy
    hall — the degraded hall's share of IT power drops vs the healthy
    run, while the healthy hall's share rises."""
    sys2 = dataclasses.replace(
        system, cooling=with_topology(
            system.cooling, 2, n_groups=4, n_cells=4,
            # towers sized ~2x the nominal load (losing half of hall 0's
            # cells must hurt) and a tight soft band so cooling pressure
            # is visible to the scheduler well before the hard limit
            cell_rated_heat_w=5e4, fan_rated_w=2e3,
            t_return_limit_c=34.0, thermal_margin_c=4.0,
            t_supply_margin_c=4.0))
    table = make_table(sys2, 4, load=1.6)
    n_steps = int(T1 / sys2.dt)
    warm = wx.constant_weather(n_steps, sys2.cooling.t_wetbulb_c + 4.0)
    scens = [T.Scenario.make("fcfs", "first-fit"),
             T.Scenario.make("fcfs", "first-fit",
                             cells_offline=(2.0, 0.0))]
    _, h = eng.simulate_sweep(sys2, table, scens, 0.0, T1, num_accounts=8,
                              weather=[warm, warm])
    p_hall = np.asarray(h.power_it_hall, np.float64)   # [S, steps, H]
    # compare the back half (after the degraded basin has heated up)
    half = p_hall.shape[1] // 2
    share = p_hall[:, half:, :].sum(1) / \
        np.maximum(p_hall[:, half:, :].sum((1, 2))[:, None], 1.0)
    assert share[1, 0] < share[0, 0] - 0.02, \
        f"degraded hall kept its load share: {share}"
    assert share[1, 1] > share[0, 1] + 0.02
    # the degraded hall runs hotter despite shedding load
    t_basin = np.asarray(h.t_basin_hall)
    assert t_basin[1, :, 0].max() > t_basin[0, :, 0].max() + 0.5


# ---------------------------------------------------------------------------
# Sharded scenario sweeps (shard_map over a ("scenario",) mesh).
# ---------------------------------------------------------------------------
def test_sharded_sweep_matches_vmap_on_forced_devices():
    """With the host platform forced to 4 devices, simulate_sweep_sharded
    must reproduce the plain vmapped sweep row-for-row — including a
    scenario count that does not divide the device count (padding)."""
    import subprocess
    import sys as _sys
    prog = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=4")
import numpy as np
import jax
from repro.core import engine as eng, types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system
assert len(jax.devices()) == 4
system = get_system("marconi100").scaled(64)
t1 = 60 * system.dt
js = generate(system, WorkloadSpec(n_jobs=32, duration_s=t1, load=1.2,
                                   trace_len=4, n_accounts=8, seed=3))
js.assign_prepop_placement(0.0, system.n_nodes)
table = js.to_table(40)
scens = [T.Scenario.make("fcfs", "first-fit"),
         T.Scenario.make("sjf", "first-fit"),
         T.Scenario.make("fcfs", "easy")]          # 3 rows on 4 devices
f_v, h_v = eng.simulate_sweep(system, table, scens, 0.0, t1, num_accounts=8)
f_s, h_s = eng.simulate_sweep_sharded(system, table, scens, 0.0, t1,
                                      num_accounts=8)
assert np.asarray(h_s.power_it).shape == np.asarray(h_v.power_it).shape
np.testing.assert_allclose(np.asarray(h_s.power_it),
                           np.asarray(h_v.power_it), rtol=1e-5)
np.testing.assert_allclose(np.asarray(f_s.completed),
                           np.asarray(f_v.completed))
print("SHARDED_OK")
"""
    env = dict(__import__("os").environ)
    env["PYTHONPATH"] = "src" + (":" + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else "")
    out = subprocess.run([_sys.executable, "-c", prog], cwd=".",
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout
