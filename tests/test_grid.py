"""Grid-aware power management: cap enforcement, throttle monotonicity,
carbon/cost accounting identities, and sweepability of the new policies."""
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.grid import signals as gsig
from repro.grid.powercap import enforce_cap, throttle_power
from repro.systems.config import get_system

T1 = 4 * 3600.0


def make_case(system, seed, load=1.2):
    js = generate(system, WorkloadSpec(
        n_jobs=64, duration_s=T1, load=load, trace_len=8, n_accounts=8,
        mean_wall_s=1800.0, seed=seed))
    js.assign_prepop_placement(0.0, system.n_nodes)
    return js, js.to_table(80)


@pytest.fixture(scope="module")
def system():
    return get_system("marconi100").scaled(64)


def idle_floor_w(system):
    return system.n_nodes * system.power.idle_node_w


def test_cap_enforcement_never_exceeded_random_tables(system):
    """Property over random tables and random cap schedules: per-step
    power_it never exceeds the active cap, as long as the cap stays above
    the machine's idle floor (the DVFS-addressable range; c_min ~ 0 so the
    throttle can always reach the cap)."""
    import dataclasses
    system = dataclasses.replace(
        system, grid=dataclasses.replace(system.grid, c_min=1e-3))
    n_steps = int(T1 / system.dt)
    floor = idle_floor_w(system)
    for seed in range(5):
        rng = np.random.default_rng(seed)
        _, table = make_case(system, seed)
        # random piecewise cap schedule, always above the idle floor
        levels = rng.uniform(1.3 * floor, 6.0 * floor, 8)
        cap = np.repeat(levels, -(-n_steps // 8))[:n_steps]
        sig = gsig.constant_signals(n_steps, carbon_gkwh=300.0,
                                    price_kwh=0.1)
        sig = gsig.GridSignals(**{**vars(sig),
                                  "cap_w": np.asarray(cap, np.float32)})
        _, hist = eng.simulate(system, table,
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, T1, num_accounts=8, signals=sig)
        p_it = np.asarray(hist.power_it)
        assert (p_it <= cap + 1.0).all(), \
            f"seed {seed}: cap violated by {(p_it - cap).max():.1f} W"


def test_zero_headroom_throttles_monotonically(system):
    """Tighter caps -> throttle factor monotonically deeper and p_it
    monotonically lower, down to the c_min floor."""
    idle = system.power.idle_node_w
    rng = np.random.default_rng(0)
    node_pw = rng.uniform(idle, 2200.0, system.n_nodes).astype(np.float32)
    raw = float(node_pw.sum())
    last_c, last_p = 1.0 + 1e-6, np.inf
    for cap in np.linspace(raw * 1.1, idle_floor_w(system), 12):
        res = enforce_cap(system, node_pw, np.float32(cap))
        c, p = float(res.c), float(res.p_it)
        assert c <= last_c + 1e-6 and p <= last_p + 1.0
        assert system.grid.c_min - 1e-6 <= c <= 1.0 + 1e-6
        assert p <= max(cap, float(
            np.minimum(node_pw, idle).sum()) +
            system.grid.c_min * float(np.maximum(node_pw - idle, 0).sum())
        ) + 1.0
        last_c, last_p = c, p
    # zero headroom (cap at the idle floor): full throttle
    res = enforce_cap(system, node_pw, np.float32(idle_floor_w(system)))
    assert float(res.c) == pytest.approx(system.grid.c_min)


def test_throttle_preserves_idle_floor():
    pw = np.array([100.0, 240.0, 1000.0], np.float32)
    out = np.asarray(throttle_power(pw, 240.0, np.float32(0.5)))
    np.testing.assert_allclose(out, [100.0, 240.0, 620.0])


def test_carbon_accounting_identity(system):
    """emissions_kg == sum over steps of power_total * dt * intensity/3.6e6
    (intensity in kg/kWh), and the telemetry column sums to the final
    accumulator."""
    n_steps = int(T1 / system.dt)
    _, table = make_case(system, 3)
    sig = gsig.synthetic_signals(system.grid, n_steps, system.dt, seed=3)
    final, hist = eng.simulate(system, table,
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, T1, num_accounts=8, signals=sig)
    p = np.asarray(hist.power_total, np.float64)
    intensity_kg = np.asarray(sig.carbon_gkwh, np.float64) / 1e3
    expect = (p * system.dt * intensity_kg[:n_steps]).sum() / 3.6e6
    assert np.isclose(float(final.emissions_kg), expect, rtol=1e-4)
    assert np.isclose(np.asarray(hist.emissions_kg, np.float64).sum(),
                      expect, rtol=1e-4)
    # cost identity, same shape
    price = np.asarray(sig.price_kwh, np.float64)
    expect_cost = (p * system.dt * price[:n_steps]).sum() / 3.6e6
    assert np.isclose(float(final.energy_cost), expect_cost, rtol=1e-4)


def test_account_carbon_accrual_tracks_it_energy(system):
    """Per-account carbon under a constant signal equals total IT energy x
    intensity (accounts accrue the attributable IT share, not parasitics)."""
    n_steps = int(T1 / system.dt)
    _, table = make_case(system, 4)
    sig = gsig.constant_signals(n_steps, carbon_gkwh=500.0, price_kwh=0.2)
    final, hist = eng.simulate(system, table,
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, T1, num_accounts=8, signals=sig)
    je = np.asarray(final.jenergy, np.float64).sum()
    acct_kg = np.asarray(final.accounts.carbon_kg, np.float64).sum()
    assert np.isclose(acct_kg, je / 3.6e6 * 0.5, rtol=1e-4)
    acct_cost = np.asarray(final.accounts.cost, np.float64).sum()
    assert np.isclose(acct_cost, je / 3.6e6 * 0.2, rtol=1e-4)


def test_generous_cap_matches_uncapped(system):
    """At a generous cap the throttle never engages and completed jobs stay
    within 5% of the uncapped run (acceptance criterion)."""
    n_steps = int(T1 / system.dt)
    _, table = make_case(system, 5)
    f0, _ = eng.simulate(system, table, T.Scenario.make("fcfs", "first-fit"),
                         0.0, T1, num_accounts=8)
    sig = gsig.constant_signals(n_steps, carbon_gkwh=300.0, price_kwh=0.1,
                                cap_w=20.0 * idle_floor_w(system))
    f1, h1 = eng.simulate(system, table,
                          T.Scenario.make("fcfs", "first-fit"),
                          0.0, T1, num_accounts=8, signals=sig)
    assert float(np.asarray(h1.throttle_frac).max()) == 0.0
    assert abs(float(f1.completed) - float(f0.completed)) <= \
        0.05 * max(float(f0.completed), 1.0)


def test_throttle_dilates_runtime(system):
    """A job admitted at a low draw whose profile then ramps into the cap
    gets throttled and finishes later than uncapped, bounded by the
    total dilation wall*(1/c_min - 1)."""
    from repro.datasets.base import JobSet
    wall = 1800.0
    idle = system.power.idle_node_w
    # profile: cheap first sample (admits under the cap), then a hot ramp
    prof = np.array([[500.0] + [2000.0] * 7], np.float32)
    js = JobSet(submit=np.array([0.0]), limit=np.array([wall * 4]),
                wall=np.array([wall]), nodes=np.array([32], np.int64),
                priority=np.zeros(1), account=np.zeros(1, np.int64),
                rec_start=np.array([0.0]),
                power_prof=prof,
                util_prof=np.full((1, 8), 1.0, np.float32))
    table = js.to_table(4)
    n_steps = int(T1 / system.dt)
    f0, _ = eng.simulate(system, table, T.Scenario.make("fcfs", "first-fit"),
                         0.0, T1, num_accounts=8)
    # headroom admits the first sample (32*(500-idle)) but not the ramp
    cap = idle_floor_w(system) + 32 * (500.0 - idle) + 2000.0
    sig = gsig.constant_signals(n_steps, cap_w=cap)
    f1, h1 = eng.simulate(system, table,
                          T.Scenario.make("fcfs", "first-fit"),
                          0.0, T1, num_accounts=8, signals=sig)
    end0, end1 = float(np.asarray(f0.end)[0]), float(np.asarray(f1.end)[0])
    c_min = system.grid.c_min
    assert np.isfinite(end1)
    assert float(np.asarray(h1.throttle_frac).max()) > 0.0
    assert end1 > end0 + system.dt  # visibly later
    # dilation bound: stretched by at most wall*(1/c_min - 1)
    assert end1 - end0 <= wall * (1.0 / c_min - 1.0) + system.dt + 1e-3


def test_cap_aware_admission_blocks_breaching_job(system):
    """A queued job whose estimated added power would breach the cap is not
    started even though nodes are free."""
    from repro.datasets.base import JobSet
    idle = system.power.idle_node_w
    floor = idle_floor_w(system)
    # one job wanting half the machine at 2 kW/node: adds 32*(2000-240) W
    js = JobSet(submit=np.array([0.0]), limit=np.array([3600.0]),
                wall=np.array([1800.0]), nodes=np.array([32], np.int64),
                priority=np.zeros(1), account=np.zeros(1, np.int64),
                rec_start=np.array([0.0]),
                power_prof=np.full((1, 1), 2000.0, np.float32),
                util_prof=np.full((1, 1), 1.0, np.float32))
    table = js.to_table(4)
    n_steps = int(T1 / system.dt)
    added = 32 * (2000.0 - idle)
    sig = gsig.constant_signals(n_steps, cap_w=floor + 0.5 * added)
    final, hist = eng.simulate(system, table,
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, T1, num_accounts=8, signals=sig)
    assert int(np.asarray(final.jstate)[0]) == T.QUEUED
    # and the cap was honored throughout
    assert (np.asarray(hist.power_it) <= floor + 0.5 * added + 1.0).all()


def test_easy_head_capped_is_not_starved_by_backfill(system):
    """A head job blocked only by the power cap must not be starved under
    EASY: admission halts behind it (backfill would eat its headroom), and
    it starts as soon as the cap rises. First-fit stays greedy."""
    from repro.datasets.base import JobSet
    idle = system.power.idle_node_w
    floor = idle_floor_w(system)
    n_steps = int(T1 / system.dt)
    head_add = 32 * (2000.0 - idle)
    light_add = 4 * (500.0 - idle)  # negative dynamic? no: 500 > 240
    assert light_add > 0
    # cap admits only lights for the first hour, then everything
    cap = np.where(np.arange(n_steps) * system.dt < 3600.0,
                   floor + 0.5 * head_add,
                   floor + 2.0 * head_add).astype(np.float32)
    base = gsig.constant_signals(n_steps)
    sig = gsig.GridSignals(**{**vars(base), "cap_w": cap})
    n_light = 5
    submit = np.zeros(1 + n_light)
    nodes = np.array([32] + [4] * n_light, np.int64)
    wall = np.array([1800.0] + [600.0] * n_light)
    prof = np.array([[2000.0]] + [[500.0]] * n_light, np.float32)
    J = len(submit)
    js = JobSet(submit=submit, limit=wall, wall=wall, nodes=nodes,
                priority=np.zeros(J), account=np.zeros(J, np.int64),
                rec_start=submit, power_prof=prof,
                util_prof=np.full((J, 1), 0.9, np.float32))
    table = js.to_table(8)
    f_easy, _ = eng.simulate(system, table, T.Scenario.make("fcfs", "easy"),
                             0.0, T1, num_accounts=8, signals=sig)
    start = np.asarray(f_easy.start)
    # head starts right when the cap rises, not starved
    assert abs(start[0] - 3600.0) <= 2 * system.dt
    # and no light job jumped it while it waited for headroom
    assert (start[1:1 + n_light] >= start[0] - 1e-3).all()
    # first-fit makes no such promise: lights start immediately
    f_ff, _ = eng.simulate(system, table,
                           T.Scenario.make("fcfs", "first-fit"),
                           0.0, T1, num_accounts=8, signals=sig)
    assert np.asarray(f_ff.start)[1:1 + n_light].min() < 3600.0


def test_carbon_aware_defers_heavy_jobs_in_dirty_window(system):
    """carbon_aware vs fcfs under a step carbon signal: the energy-heavy
    job submitted as the grid turns dirty (intensity far above its rolling
    mean) yields to the light jobs behind it, and total emissions do not
    increase."""
    from repro.datasets.base import JobSet
    n_steps = int(T1 / system.dt)
    # clean first hour, dirty afterwards: at dirty onset the trailing
    # rolling mean is still low, so the deferral excess is large
    carbon = np.where(np.arange(n_steps) * system.dt < 3600.0,
                      50.0, 900.0).astype(np.float32)
    base = gsig.constant_signals(n_steps, price_kwh=0.1)
    from repro.grid.signals import _rolling_mean
    sig = gsig.GridSignals(**{
        **vars(base), "carbon_gkwh": carbon,
        "carbon_ref": _rolling_mean(carbon, int(6 * 3600 / system.dt))})
    # a heavy hog and a stream of light jobs submitted together at the
    # dirty onset; together they oversubscribe the machine, so the queue
    # ORDER decides who waits
    n_light = 12
    submit = np.array([3600.0] + [3600.0] * n_light)
    nodes = np.array([48] + [4] * n_light, np.int64)
    wall = np.array([3600.0] + [900.0] * n_light)
    J = len(submit)
    js = JobSet(submit=submit, limit=wall * 1.2, wall=wall, nodes=nodes,
                priority=np.zeros(J), account=np.zeros(J, np.int64),
                rec_start=submit,
                power_prof=np.full((J, 1), 1500.0, np.float32),
                util_prof=np.full((J, 1), 0.9, np.float32))
    table = js.to_table(16)
    scens = [T.Scenario.make("fcfs", "first-fit"),
             T.Scenario.make("carbon_aware", "first-fit",
                             carbon_weight=50.0)]
    finals, hists = eng.simulate_sweep(system, table, scens, 0.0, T1,
                                       num_accounts=8, signals=sig)
    start = np.asarray(finals.start)
    assert start[1, 0] > start[0, 0] + system.dt  # hog deferred
    em = np.asarray(finals.emissions_kg)
    assert em[1] <= em[0] * 1.01


def test_policy_cap_sweep_is_one_batched_program(system):
    """(policy x cap-level x carbon-weight) sweep runs as ONE vmapped
    Scenario batch against shared signals, and matches single runs."""
    n_steps = int(T1 / system.dt)
    _, table = make_case(system, 6)
    sig = gsig.synthetic_signals(
        system.grid, n_steps, system.dt, seed=6,
        cap_base_w=4.0 * idle_floor_w(system),
        cap_peak_w=2.0 * idle_floor_w(system))
    combos = [("fcfs", 0.0, 1.0), ("carbon_aware", 4.0, 1.0),
              ("carbon_aware", 4.0, 0.7), ("price_aware", 4.0, 0.7)]
    scens = [T.Scenario.make(p, "first-fit", carbon_weight=w,
                             price_weight=w, cap_scale=s)
             for p, w, s in combos]
    finals, hists = eng.simulate_sweep(system, table, scens, 0.0, T1,
                                       num_accounts=8, signals=sig)
    assert np.asarray(finals.completed).shape == (len(combos),)
    assert np.isfinite(np.asarray(finals.emissions_kg)).all()
    # batched row 0 == the same scenario run alone
    f_solo, h_solo = eng.simulate(system, table, scens[0], 0.0, T1,
                                  num_accounts=8, signals=sig)
    np.testing.assert_allclose(np.asarray(h_solo.power_it),
                               np.asarray(hists.power_it)[0], rtol=1e-6)
    assert float(f_solo.completed) == float(np.asarray(finals.completed)[0])
    # every scenario honors its own scaled cap
    cap = np.asarray(hists.cap_w)
    p_it = np.asarray(hists.power_it)
    assert (p_it <= cap + 1.0).all()


def test_neutral_signals_are_inert(system):
    """Default (no signals) == explicit neutral signals == pre-grid
    behavior: zero emissions/cost/throttle, identical schedule."""
    _, table = make_case(system, 7)
    f0, h0 = eng.simulate(system, table, T.Scenario.make("sjf", "easy"),
                          0.0, T1, num_accounts=8)
    f1, h1 = eng.simulate(system, table, T.Scenario.make("sjf", "easy"),
                          0.0, T1, num_accounts=8,
                          signals=gsig.neutral(int(T1 / system.dt)))
    np.testing.assert_allclose(np.asarray(h0.power_it),
                               np.asarray(h1.power_it), rtol=1e-6)
    assert float(f0.emissions_kg) == 0.0
    assert float(np.asarray(h0.throttle_frac).max()) == 0.0
