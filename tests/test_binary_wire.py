"""Binary wire fast path: RBW1 codec units, dialect equivalence, and
end-to-end conformance.

The binary dialect is an *optimization*, never a semantic change: every
test here pins some face of that claim — codec roundtrips preserve the
exact bytes (NaN, ±inf, empty arrays included), both dialects decode to
identical messages, plugin-mode telemetry is bit-equal whether the peer
speaks NDJSON or binary frames, and the serve layer's binary snapshots
carry the same digest-checked state as the base64 spelling.
"""
import importlib.util
import io
import pathlib
import sys

import numpy as np
import pytest

from repro.core import external as ext
from repro.core import transport as tr
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system

ROOT = pathlib.Path(__file__).resolve().parents[1]
PEER = [sys.executable, str(ROOT / "tools" / "reference_peer.py")]
SYS = get_system("frontier").scaled(64)

pytestmark = pytest.mark.timeout(180)


def make_jobs(seed=0, n=30):
    spec = WorkloadSpec(n_jobs=n, duration_s=2 * 3600.0, load=1.2,
                        trace_len=4, seed=seed)
    return generate(SYS, spec)


def make_peer(*fault, **kw):
    cmd = PEER + (["--fault", fault[0]] if fault else [])
    kw.setdefault("handshake_timeout_s", 30.0)
    return tr.SubprocessPeer(cmd=cmd, **kw)


def roundtrip(msg, as_arrays=True):
    """Encode as an RBW1 frame, read it back through the byte layer."""
    buf = io.BytesIO()
    tr.write_bin_frame(buf, msg)
    buf.seek(0)
    return tr.read_any_frame(buf, as_arrays=as_arrays)


# ---------------------------------------------------------------------------
# Codec units.
# ---------------------------------------------------------------------------
def test_binary_roundtrip_preserves_special_floats_exactly():
    arr = np.array([np.nan, np.inf, -np.inf, 0.0, -0.0, 1e-308],
                   np.float64)
    out = roundtrip({"version": 1, "kind": "x", "a": arr})
    got = out["a"]
    assert isinstance(got, np.ndarray) and got.dtype == np.float64
    # bit-exact, not just value-equal (NaN payloads, signed zero)
    assert got.tobytes() == arr.tobytes()


def test_binary_roundtrip_empty_and_zero_d_arrays():
    msg = {"version": 1, "kind": "x",
           "empty": np.zeros((0,), np.int64),
           "mat": np.arange(6, dtype=np.float32).reshape(2, 3)}
    out = roundtrip(msg)
    assert out["empty"].shape == (0,) and out["empty"].dtype == np.int64
    assert out["mat"].shape == (2, 3)
    assert np.array_equal(out["mat"], msg["mat"])


def test_binary_as_lists_matches_ndjson_spelling():
    """as_arrays=False must yield exactly what json.dumps/.tolist()
    would have shipped (f64 repr roundtrips losslessly)."""
    vals = np.array([0.1, 1.0 / 3.0, 2.0 ** 52 + 1], np.float64)
    out = roundtrip({"version": 1, "kind": "x", "v": vals},
                    as_arrays=False)
    assert out["v"] == vals.tolist()


def test_binary_rejects_reserved_key_and_bad_dtype():
    with pytest.raises(ext.ProtocolError):
        tr.encode_bin_frame({"version": 1, "__bin__": 0})
    with pytest.raises(ext.ProtocolError):
        tr.encode_bin_frame({"version": 1,
                             "a": np.zeros(2, np.complex128)})


def test_binary_oversize_frame_rejected_before_write():
    buf = io.BytesIO()
    big = np.zeros(tr.MAX_FRAME_BYTES // 8 + 16, np.float64)
    counters = tr.WireCounters()
    with pytest.raises(ext.ProtocolError):
        tr.write_bin_frame(buf, {"version": 1, "a": big}, counters)
    assert counters.frames_rejected == 1
    assert buf.getvalue() == b"", "oversize frame leaked bytes"


def test_truncated_binary_frame_is_protocol_error():
    buf = io.BytesIO()
    tr.write_bin_frame(buf, {"version": 1, "a": np.arange(8)})
    whole = buf.getvalue()
    with pytest.raises(ext.ProtocolError):
        tr.read_any_frame(io.BytesIO(whole[:-3]))
    # EOF before any byte stays a ConnectionError (clean close)
    with pytest.raises(ConnectionError):
        tr.read_any_frame(io.BytesIO(b""))


def test_read_any_frame_passes_ndjson_through():
    buf = io.BytesIO(b'{"version": 1, "kind": "x", "v": [1, 2]}\n')
    out = tr.read_any_frame(buf)
    assert out == {"version": 1, "kind": "x", "v": [1, 2]}


def test_ndarray_schedule_decodes_like_list_schedule():
    start = np.array([0.0, 30.0, np.inf], np.float64)
    as_bin = tr.decode_schedule(
        {"version": ext.WIRE_VERSION, "kind": "schedule", "start": start},
        3)
    as_json = tr.decode_schedule(
        {"version": ext.WIRE_VERSION, "kind": "schedule",
         "start": [0.0, 30.0, None]}, 3)
    assert np.array_equal(as_bin, as_json)
    with pytest.raises(ext.ProtocolError):
        tr.decode_schedule(
            {"version": ext.WIRE_VERSION, "kind": "schedule",
             "start": np.array([np.nan, 0.0, 0.0])}, 3)


def test_running_sets_envelope_roundtrip_and_validation():
    msg = ext.encode_running_sets([[0, 2], [], [5]])
    sets = ext.decode_running_sets(msg, n_jobs=8, n_expected=3)
    assert [s.tolist() for s in sets] == [[0, 2], [], [5]]
    with pytest.raises(ext.ProtocolError):
        ext.decode_running_sets(msg, n_jobs=8, n_expected=2)
    bad = {"version": ext.WIRE_VERSION,
           "kind": ext.WIRE_KIND_RUNNING_SETS, "sets": [[True]]}
    with pytest.raises(ext.ProtocolError):
        ext.decode_running_sets(bad, n_jobs=8, n_expected=1)


# ---------------------------------------------------------------------------
# Throughput claim (acceptance: binary >= 2x NDJSON bytes/s on a large
# reset envelope, CPU-only).
# ---------------------------------------------------------------------------
def test_binary_reset_envelope_at_least_2x_ndjson_bytes_per_s():
    import json
    import time

    n = 100_000
    rng = np.random.default_rng(0)
    cols = {
        "submit": np.sort(rng.uniform(0, 1e5, n)),
        "limit": rng.uniform(60.0, 86400.0, n),
        "wall": rng.uniform(30.0, 43200.0, n),
        "nodes": rng.integers(1, 64, n).astype(np.int64),
        "priority": rng.uniform(0.0, 1.0, n),
        "account": rng.integers(0, 16, n).astype(np.int64),
    }

    def envelope(payload):
        return {"version": tr.WIRE_VERSION, "kind": "reset", "t0": 0.0,
                "jobs": payload}

    def measure(encode):
        best = 0.0
        for _ in range(3):
            t0 = time.perf_counter()
            nbytes = encode()
            best = max(best, nbytes / (time.perf_counter() - t0))
        return best

    def enc_json():
        buf = io.BytesIO()
        tr.write_frame(buf, envelope(
            {k: v.tolist() for k, v in cols.items()}))
        return len(buf.getvalue())

    def enc_bin():
        buf = io.BytesIO()
        tr.write_bin_frame(buf, envelope(cols))
        return len(buf.getvalue())

    json_rate, bin_rate = measure(enc_json), measure(enc_bin)
    assert bin_rate >= 2.0 * json_rate, \
        f"binary {bin_rate:.0f} B/s < 2x ndjson {json_rate:.0f} B/s"
    # and the decoded payloads agree, so the speed is not bought with
    # a lossy spelling
    buf = io.BytesIO()
    tr.write_bin_frame(buf, envelope(cols))
    buf.seek(0)
    back = tr.read_any_frame(buf, as_arrays=False)
    assert back["jobs"]["submit"] == cols["submit"].tolist()
    assert json.loads(json.dumps(back)) == back


# ---------------------------------------------------------------------------
# Negotiation + end-to-end conformance over a real subprocess peer.
# ---------------------------------------------------------------------------
def test_plugin_telemetry_bit_equal_across_all_transports():
    """In-process, NDJSON-pinned, and binary peers must be physically
    indistinguishable: every telemetry channel bit-equal."""
    js = make_jobs(seed=31)
    t1 = 1800.0
    inproc = ext.FastSimLike(policy="fcfs", backfill="firstfit")
    _, h_ref, _ = ext.run_plugin_mode(SYS, js, inproc, 0.0, t1)
    for wire, expect in (("ndjson", "ndjson"), ("auto", "binary"),
                         ("binary", "binary")):
        peer = make_peer(wire=wire)
        try:
            _, h, _ = ext.run_plugin_mode(SYS, js, peer, 0.0, t1)
            assert peer.stats()["wire"] == expect
        finally:
            peer.close()
        assert set(h_ref) == set(h)
        for k in h_ref:
            assert np.array_equal(np.asarray(h_ref[k]), np.asarray(h[k])), \
                f"channel {k!r} diverged over wire={wire}"


def test_legacy_peer_falls_back_to_ndjson_and_binary_demand_fails():
    js = make_jobs(seed=32, n=10)
    peer = make_peer("legacy")      # no caps advertised
    try:
        peer.reset(SYS, js, 0.0)
        assert peer.stats()["wire"] == "ndjson"
        assert peer.batch_capable is False
    finally:
        peer.close()
    strict = make_peer("legacy", wire="binary")
    try:
        with pytest.raises(ext.ProtocolError, match="wire=binary"):
            strict.reset(SYS, js, 0.0)
    finally:
        strict.close()


def test_poll_many_matches_individual_polls_both_paths():
    js = make_jobs(seed=33)
    ts = [float(k * SYS.dt) for k in range(12)]
    for fault in ((), ("legacy",)):
        peer = make_peer(*fault)
        try:
            bridge = ext.SchedulerBridge(peer)
            bridge.reset(SYS, js, 0.0)
            batched = bridge.poll_many(ts)
            single = [ext.decode_running(peer.poll_wire(t), len(js))
                      for t in ts]
        finally:
            peer.close()
        assert len(batched) == len(ts)
        for b, s in zip(batched, single):
            assert np.array_equal(np.sort(b), np.sort(s))


def test_schedule_fetch_equal_across_dialects():
    js = make_jobs(seed=34, n=40)
    starts = {}
    for wire in ("ndjson", "binary"):
        peer = make_peer(policy="sjf", wire=wire)
        try:
            peer.reset(SYS, js, 0.0)
            starts[wire] = np.asarray(peer.start, np.float64)
        finally:
            peer.close()
    a, b = starts["ndjson"], starts["binary"]
    fin = np.isfinite(a)
    assert np.array_equal(fin, np.isfinite(b))
    assert np.array_equal(a[fin], b[fin])


# ---------------------------------------------------------------------------
# Serve layer: binary snapshots / fetch are the same state, cheaper bytes.
# ---------------------------------------------------------------------------
def _make_session(n_intervals=3, interval=4):
    from repro.core import types as T
    from repro.serve.session import TwinSession
    sys_ = get_system("marconi100").scaled(32)
    js = generate(sys_, WorkloadSpec(
        n_jobs=24, duration_s=n_intervals * interval * sys_.dt, load=1.2,
        trace_len=4, seed=5))
    return TwinSession(sys_, js.to_table(32),
                       T.Scenario.make("fcfs", "easy"), 0.0,
                       n_intervals * interval * sys_.dt,
                       interval_steps=interval)


def test_serve_snapshot_binary_parity_with_base64_dialect():
    from repro.serve import snapshot as snap
    sess = _make_session()
    sess.advance_many({0: 2})
    as_json = sess.snapshot(0, binary=False)
    as_bin = sess.snapshot(0, binary=True)
    assert as_json["step"] == as_bin["step"]
    # one digest speaks both dialects: raw bytes, not spelling
    assert as_json["raw_digest"] == as_bin["raw_digest"]
    assert "digest" in as_json and "digest" not in as_bin
    leaves_j = snap.encode_carry(
        snap.decode_carry(as_json["snapshot"], sess.carry_template))
    leaves_b = snap.encode_carry(
        snap.decode_carry(as_bin["snapshot"], sess.carry_template))
    assert snap.carry_digest(leaves_j) == snap.carry_digest(leaves_b)


def test_serve_fetch_binary_cols_equal_ndjson_rows():
    sess = _make_session()
    sess.advance_many({0: 3})
    rows = sess.fetch(0)["rows"]
    cols = sess.fetch(0, binary=True)["cols"]
    assert isinstance(cols["step"], np.ndarray)
    assert len(rows) == cols["step"].shape[0]
    for i, row in enumerate(rows):
        assert row["step"] == int(cols["step"][i])
        for k, v in row.items():
            if k == "step":
                continue
            assert v == float(cols[k][i]), (k, i)


def test_twin_client_binary_snapshot_over_live_server(tmp_path):
    from repro.serve.server import TwinServer
    from tools.twin_client import TwinClient
    sess = _make_session()
    with TwinServer(sess, f"unix:{tmp_path}/twin.sock") as srv:
        with TwinClient(srv.address) as c:
            c.advance(0, 1)
            sj = c.snapshot(0)
            sb = c.snapshot(0, binary=True)
            fb = c.fetch(0, binary=True)
    assert sj["raw_digest"] == sb["raw_digest"]
    # binary leaves arrive as {"dtype", "shape", "values"} dicts in the
    # stdlib client; same leaf set as the base64 spelling
    assert set(sb["snapshot"]["leaves"]) == set(sj["snapshot"]["leaves"])
    assert "cols" in fb and "rows" not in fb
