"""Property battery for the stochastic event layer (repro.events).

The process module promises exact structural invariants — not
statistical tendencies — because the per-step draws share one stateless
key and thresholds nest:

* **Rate monotonicity.** For a fixed seed, growing any hazard rate can
  only grow the failure set: the realized availability mask at the
  higher rate is a pointwise subset of the lower-rate mask, every step,
  every entity. Delivered capacity (available node-steps) is therefore
  non-increasing in rate; completed work at the engine level is checked
  at the ladder endpoints (requeue reshuffling makes the interior
  non-monotone in general, the zero-failure run still dominates).
* **Repair monotonicity.** Same draws, shorter mean repair ⇒ repairs
  complete no later ⇒ downtime shrinks pointwise.
* **No resurrection.** ``*_down_until`` never decreases, and the
  realized mask is exactly ``(t < down_until) | group_down[gid]`` — a
  failed node cannot come back before its drawn repair completes.
* **Determinism.** The same scenario realizes the same universe on
  every call.
* **Finite scores.** Ride-through stats stay finite/non-NaN under
  adversarial (absurdly large) hazard and repair draws.

Runs under hypothesis where installed; every property also runs with
fixed seeds so the battery works without the dev extras (mirroring
tests/test_serve_properties.py).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import stats as stats_mod
from repro.core import types as T
from repro.events import EventConfig, realize_masks
from repro.events import process as ev_proc
from repro.grid import signals as gsig
from repro.systems.config import get_system

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:       # local runs without the dev extras
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Inert stand-in so @given/strategy expressions still import."""
        def __call__(self, *a, **k):
            return self

        def __or__(self, other):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*a, **k):
        return lambda f: f

    settings = given

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="hypothesis not installed")

MSYS = get_system("marconi100").scaled(32)   # mask-level oracle machine
STEPS = 48                                    # mask-realization horizon
HORIZON = 120                                 # engine-run horizon (steps)

SEEDS = st.integers(min_value=0, max_value=2 ** 16)
RATES = st.floats(min_value=0.0, max_value=3e-4,
                  allow_nan=False, allow_infinity=False)


def _scen(seed, rate, corr=0.5, repair_s=1500.0, cell_rate=0.0):
    return T.Scenario.make(
        "fcfs", "easy", failure_seed=float(seed), node_fail_rate=rate,
        cdu_fail_rate=0.5 * rate, cell_fail_rate=cell_rate,
        failure_corr=corr, repair_s=repair_s)


# ---------------------------------------------------------------------------
# Rate monotonicity: failure sets nest, capacity shrinks.
# ---------------------------------------------------------------------------
def _check_rate_subset(seed, lo, hi, corr):
    a = realize_masks(MSYS, _scen(seed, lo, corr), STEPS)
    b = realize_masks(MSYS, _scen(seed, hi, corr), STEPS)
    # pointwise: anything down at the low rate is down at the high rate
    assert np.all(b["node_avail"] <= a["node_avail"])
    assert np.all(a["group_down"] <= b["group_down"])
    # hence delivered capacity is non-increasing in rate
    assert b["node_avail"].sum() <= a["node_avail"].sum()
    assert np.all(b["nodes_down"] >= a["nodes_down"])


@needs_hypothesis
@settings(max_examples=20, deadline=None)
@given(seed=SEEDS, r1=RATES, r2=RATES,
       corr=st.floats(min_value=0.0, max_value=1.0, allow_nan=False))
def test_rate_monotonicity_hypothesis(seed, r1, r2, corr):
    lo, hi = sorted((r1, r2))
    _check_rate_subset(seed, lo, hi, corr)


def test_rate_monotonicity_seeded():
    for seed in (0, 3, 12345):
        for lo, hi in ((0.0, 5e-5), (5e-5, 2e-4), (2e-4, 1e-3)):
            _check_rate_subset(seed, lo, hi, corr=0.5)


def test_completed_work_zero_rate_dominates(small_system, small_table):
    """Engine-level endpoint check: the zero-failure run completes at
    least as much work as a heavily-failing one, per seed."""
    t1 = HORIZON * small_system.dt
    nodes = np.asarray(small_table.nodes, np.float64)
    wall = np.asarray(small_table.wall, np.float64)

    def work(rate, seed):
        f, _ = eng.simulate(small_system, small_table,
                            _scen(seed, rate), 0.0, t1,
                            events=EventConfig())
        done = np.asarray(f.jstate) == T.DONE
        return float((nodes * np.where(done, wall, 0.0)).sum()), \
            float(np.asarray(f.completed))

    for seed in (3, 5, 11):
        w0, d0 = work(0.0, seed)
        w1, d1 = work(5e-4, seed)
        assert w1 <= w0 and d1 <= d0


# ---------------------------------------------------------------------------
# Repair monotonicity: shorter repairs, less downtime, pointwise.
# ---------------------------------------------------------------------------
def _check_repair_subset(seed, rate, rep_lo, rep_hi):
    a = realize_masks(MSYS, _scen(seed, rate, repair_s=rep_lo), STEPS)
    b = realize_masks(MSYS, _scen(seed, rate, repair_s=rep_hi), STEPS)
    assert np.all(b["node_avail"] <= a["node_avail"])
    assert b["nodes_down"].sum() >= a["nodes_down"].sum()


@needs_hypothesis
@settings(max_examples=20, deadline=None)
@given(seed=SEEDS, rate=st.floats(min_value=1e-5, max_value=3e-4),
       p1=st.floats(min_value=0.0, max_value=7200.0),
       p2=st.floats(min_value=0.0, max_value=7200.0))
def test_repair_monotonicity_hypothesis(seed, rate, p1, p2):
    lo, hi = sorted((p1, p2))
    _check_repair_subset(seed, rate, lo, hi)


def test_repair_monotonicity_seeded():
    for seed in (1, 7):
        for lo, hi in ((300.0, 1500.0), (1500.0, 6000.0)):
            _check_repair_subset(seed, 2e-4, lo, hi)


# ---------------------------------------------------------------------------
# No resurrection: down_until never shrinks and the mask is exactly the
# down_until/group composition.
# ---------------------------------------------------------------------------
def _check_no_resurrection(seed, rate, corr, steps=STEPS):
    scen = _scen(seed, rate, corr, repair_s=900.0, cell_rate=0.2 * rate)
    gid, hog, _ = ev_proc._maps(MSYS)
    ev = ev_proc.init_event_state(MSYS)
    prev_n = np.asarray(ev.node_down_until)
    prev_g = np.asarray(ev.group_down_until)
    t = 0.0
    for k in range(steps):
        (nu, gu, cu), (unavail, gdown, _) = ev_proc._advance_masks(
            MSYS, ev, scen, jnp.float32(t), jnp.int32(k))
        nu_h, gu_h = np.asarray(nu), np.asarray(gu)
        # repair-complete times only ever grow: a failed entity cannot
        # come back before its drawn repair time
        assert np.all(nu_h >= prev_n) and np.all(gu_h >= prev_g)
        # the realized mask is exactly the down_until composition
        np.testing.assert_array_equal(
            np.asarray(unavail), (t < nu_h) | np.asarray(gdown)[gid])
        np.testing.assert_array_equal(np.asarray(gdown), t < gu_h)
        prev_n, prev_g = nu_h, gu_h
        ev = dataclasses.replace(ev, node_down_until=nu,
                                 group_down_until=gu, cell_down_until=cu)
        t += MSYS.dt


@needs_hypothesis
@settings(max_examples=10, deadline=None)
@given(seed=SEEDS, rate=st.floats(min_value=5e-5, max_value=1e-3),
       corr=st.floats(min_value=0.0, max_value=1.0, allow_nan=False))
def test_no_resurrection_hypothesis(seed, rate, corr):
    _check_no_resurrection(seed, rate, corr, steps=24)


def test_no_resurrection_seeded():
    _check_no_resurrection(9, 4e-4, 0.5)


# ---------------------------------------------------------------------------
# Determinism + finite ride-through scores under adversarial draws.
# ---------------------------------------------------------------------------
@needs_hypothesis
@settings(max_examples=15, deadline=None)
@given(seed=SEEDS, rate=RATES)
def test_masks_deterministic_hypothesis(seed, rate):
    a = realize_masks(MSYS, _scen(seed, rate), STEPS)
    b = realize_masks(MSYS, _scen(seed, rate), STEPS)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_masks_deterministic_seeded():
    a = realize_masks(MSYS, _scen(42, 2e-4), STEPS)
    b = realize_masks(MSYS, _scen(42, 2e-4), STEPS)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def _check_finite_scores(system, table, seed, rate, corr, repair_s):
    scen = T.Scenario.make(
        "fcfs", "easy", failure_seed=float(seed), node_fail_rate=rate,
        cdu_fail_rate=rate, cell_fail_rate=rate, failure_corr=corr,
        repair_s=repair_s,
        dr_announce_s=0.0, dr_notice_s=300.0, dr_duration_s=1800.0,
        dr_cap_w=1e5)
    t1 = HORIZON * system.dt
    final, hist = eng.simulate(system, table, scen, 0.0, t1,
                               signals=gsig.neutral(HORIZON),
                               events=EventConfig())
    s = stats_mod.summarize(system, table, final, hist)
    ride = {k: v for k, v in s.items()
            if k.startswith("ride_") or k.endswith("_overheat_s")}
    assert ride, "ride-through scores missing from summarize()"
    for k, v in ride.items():
        assert np.isfinite(v), f"{k} = {v} not finite"
    assert np.isfinite(np.asarray(hist.power_total)).all()


@needs_hypothesis
@settings(max_examples=8, deadline=None)
@given(seed=SEEDS,
       rate=st.floats(min_value=0.0, max_value=0.5, allow_nan=False),
       corr=st.floats(min_value=0.0, max_value=2.0, allow_nan=False),
       repair_s=st.floats(min_value=0.0, max_value=1e5, allow_nan=False))
def test_ride_scores_finite_hypothesis(small_system, small_table, seed,
                                       rate, corr, repair_s):
    _check_finite_scores(small_system, small_table, seed, rate, corr,
                         repair_s)


def test_ride_scores_finite_seeded(small_system, small_table):
    # everything-fails-constantly corner: hazard ~ once per node-step,
    # zero-length repairs, over-unity correlation (clipped inside)
    _check_finite_scores(small_system, small_table, 17, 0.5, 2.0, 0.0)
    _check_finite_scores(small_system, small_table, 17, 0.3, 1.0, 1e5)
