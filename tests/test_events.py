"""Failure & demand-response scenario engine (repro.events): oracle tests.

The event layer's contract with the rest of the twin, in test form:

* **Zero-failure bit-identity** — enabling the layer with all hazard
  rates at zero reproduces the pre-event trajectory bit-for-bit (the
  acceptance bound is <= 1e-5; we assert exact equality), on the flat
  plant and on a 4-hall topology.
* **Energy conservation** — killed jobs move their accrued energy into
  the energy-not-served ledger; nothing is double-counted and the
  per-step telemetry sums to the final-ledger totals.
* **Requeue accounting** — every valid job lands in exactly one
  terminal/queue state, kills == requeues when requeue is on, and the
  no-requeue config dismisses instead.
* **Demand-response** — a cap step with a notice window: the scheduler
  refuses jobs that would run into the announced event, and admission
  stops while the cap is in force.
* **Seeded determinism** — the same failure seed replays the same
  universe across runs and across the ``simulate`` vs ``simulate_sweep``
  lanes.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import assert_trees_equal, make_table
from repro.core import engine as eng
from repro.core import types as T
from repro.events import EventConfig
from repro.grid import signals as gsig
from repro.launch.simulate import build_system

HORIZON = 120  # engine steps per run


def _final_sans_events(final):
    """Final carry with the event ledger dropped, for comparison against
    an events-off run (whose ``events`` leaf is None)."""
    return dataclasses.replace(final, events=None)


def _assert_trees_close(a, b, what="", rtol=1e-5, atol=1e-3):
    """Integer/bool leaves bit-equal, float leaves within the acceptance
    bound (<= 1e-5 relative: the event layer keeps the math identical but
    XLA may re-fuse the gated cooling path, moving the last ulp)."""
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (path, la), (_, lb) in zip(fa, fb):
        la, lb = np.asarray(la), np.asarray(lb)
        name = f"{what}: leaf {jax.tree_util.keystr(path)}"
        if np.issubdtype(la.dtype, np.floating):
            np.testing.assert_allclose(la, lb, rtol=rtol, atol=atol,
                                       equal_nan=True, err_msg=name)
        else:
            np.testing.assert_array_equal(la, lb, err_msg=name)


def _zero_rate_case(system, table):
    scen = T.Scenario.make("fcfs", "easy")
    t1 = HORIZON * system.dt
    f_off, h_off = eng.simulate(system, table, scen, 0.0, t1)
    f_on, h_on = eng.simulate(system, table, scen, 0.0, t1,
                              events=EventConfig())
    assert f_on.events is not None
    assert float(np.asarray(f_on.events.jobs_killed)) == 0.0
    assert float(np.asarray(f_on.events.node_downtime_s)) == 0.0
    _assert_trees_close(h_off, h_on, "zero-rate hist")
    _assert_trees_close(f_off, _final_sans_events(f_on), "zero-rate final")


def test_zero_rate_is_bit_identical_flat(small_system, small_table):
    _zero_rate_case(small_system, small_table)


def test_zero_rate_is_bit_identical_4hall():
    sys4 = build_system("marconi100", scale=64, halls=4)
    table = make_table(sys4, seed=2)
    _zero_rate_case(sys4, table)


@pytest.fixture(scope="module")
def outage_run(small_system, small_table):
    """One run with correlated CDU outages actually firing mid-trajectory
    (several jobs killed), shared by the conservation/accounting tests."""
    scen = T.Scenario.make("fcfs", "easy", failure_seed=3.0,
                           node_fail_rate=5e-5, cdu_fail_rate=2e-5,
                           failure_corr=0.5, repair_s=900.0)
    t1 = HORIZON * small_system.dt
    final, hist = eng.simulate(small_system, small_table, scen, 0.0, t1,
                               events=EventConfig())
    assert float(np.asarray(final.events.jobs_killed)) > 0, \
        "outage fixture drew no failures — tests below would be vacuous"
    return scen, final, hist


def test_energy_conservation_under_cdu_outages(small_system, small_table,
                                               outage_run):
    _, final, hist = outage_run
    dt = small_system.dt
    # total-energy ledger still integrates the telemetry exactly as in
    # the failure-free engine
    np.testing.assert_allclose(
        float(np.asarray(final.energy_total)),
        float(np.asarray(hist.power_total, np.float64).sum() * dt),
        rtol=1e-4)
    # energy-not-served: killed jobs hand their accrued energy to the
    # ledger, so surviving job energy + lost energy never exceeds the IT
    # integral (job accrual excludes the idle floor, hence <=)
    energy_it = float(np.asarray(final.energy_it))
    jobs_j = float(np.asarray(final.jenergy, np.float64).sum())
    lost_j = float(np.asarray(final.events.energy_lost_j))
    assert lost_j > 0.0
    assert jobs_j + lost_j <= energy_it * (1.0 + 1e-5)
    # per-step telemetry sums to the final ledger
    np.testing.assert_allclose(
        float(np.asarray(hist.n_killed, np.float64).sum()),
        float(np.asarray(final.events.jobs_killed)), rtol=1e-6)
    np.testing.assert_allclose(
        float(np.asarray(hist.nodes_down, np.float64).sum() * dt),
        float(np.asarray(final.events.node_downtime_s)), rtol=1e-5)


def test_killed_job_requeue_accounting(small_system, small_table,
                                       outage_run):
    scen, final, _ = outage_run
    valid = np.asarray(small_table.valid)
    js = np.asarray(final.jstate)[valid]
    known = (T.PENDING, T.QUEUED, T.RUNNING, T.DONE, T.DISMISSED)
    counts = {s: int((js == s).sum()) for s in known}
    # every submitted job is in exactly one lifecycle state
    assert sum(counts.values()) == int(valid.sum())
    # requeue=True: every kill is a requeue and no job is dismissed by
    # the event layer (the window-dismissal path is off in this horizon)
    assert float(np.asarray(final.events.jobs_requeued)) == \
        float(np.asarray(final.events.jobs_killed))
    # the no-requeue config loses the killed jobs instead: same draws,
    # zero requeues, and at least one DISMISSED job appears
    t1 = HORIZON * small_system.dt
    f2, _ = eng.simulate(small_system, small_table, scen, 0.0, t1,
                         events=EventConfig(requeue=False))
    assert float(np.asarray(f2.events.jobs_killed)) > 0
    assert float(np.asarray(f2.events.jobs_requeued)) == 0.0
    js2 = np.asarray(f2.jstate)[valid]
    assert int((js2 == T.DISMISSED).sum()) > counts[T.DISMISSED]


def test_dr_cap_step_honors_notice_window(small_system, small_table):
    """A demand-response cap far below any job's draw: no job admitted
    during the notice window may run into the event, and admission stops
    entirely while the cap is in force."""
    t1 = HORIZON * small_system.dt
    announce, notice, duration = 0.25 * t1, 0.25 * t1, 0.4 * t1
    start_s, end_s = announce + notice, announce + notice + duration
    floor = small_system.n_nodes * small_system.power.idle_node_w
    scen = T.Scenario.make("fcfs", "easy",
                           dr_announce_s=announce, dr_notice_s=notice,
                           dr_duration_s=duration, dr_cap_w=0.01 * floor)
    final, hist = eng.simulate(small_system, small_table, scen, 0.0, t1,
                               signals=gsig.neutral(HORIZON),
                               events=EventConfig())
    valid = np.asarray(small_table.valid)
    start = np.asarray(final.start)[valid]
    limit = np.asarray(small_table.limit)[valid]
    started = np.isfinite(start)
    # notice window honored: nothing that starts in [announce, start_s)
    # is allowed to still be running when the cap engages
    in_notice = started & (start >= announce) & (start < start_s)
    assert not np.any(in_notice & (start + limit > start_s)), \
        "job admitted during the notice window runs into the DR event"
    # cap in force: the cap is below every job's projected draw, so no
    # job starts inside [start_s, end_s)
    assert not np.any(started & (start >= start_s) & (start < end_s))
    # sanity: the run is not degenerate — jobs do start before and the
    # queue picks back up after the event
    assert np.any(started & (start < announce))
    assert np.any(started & (start >= end_s))
    # power telemetry shows the shed: active-window IT power sits well
    # below the pre-announce plateau
    sl = slice(int(start_s / small_system.dt) + 1,
               int(end_s / small_system.dt))
    pre = np.asarray(hist.power_it, np.float64)[:int(announce /
                                                     small_system.dt)]
    act = np.asarray(hist.power_it, np.float64)[sl]
    assert act.mean() < pre.mean()


def test_seeded_determinism_and_sweep_lane_parity(small_system,
                                                 small_table):
    scen = T.Scenario.make("fcfs", "easy", failure_seed=5.0,
                           node_fail_rate=8e-5, cdu_fail_rate=2e-5,
                           failure_corr=0.5, repair_s=1200.0)
    t1 = HORIZON * small_system.dt
    f1, h1 = eng.simulate(small_system, small_table, scen, 0.0, t1,
                          events=EventConfig())
    f2, h2 = eng.simulate(small_system, small_table, scen, 0.0, t1,
                          events=EventConfig())
    assert_trees_equal(h1, h2, "rerun hist")
    assert_trees_equal(f1, f2, "rerun final")
    assert float(np.asarray(f1.events.jobs_killed)) > 0
    # the vmapped sweep lane replays the same universe row-for-row
    other = T.Scenario.make("fcfs", "easy", failure_seed=6.0,
                            node_fail_rate=8e-5)
    fs, hs = eng.simulate_sweep(small_system, small_table, [scen, other],
                                0.0, t1, events=EventConfig())
    np.testing.assert_allclose(np.asarray(hs.power_it)[0],
                               np.asarray(h1.power_it), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(fs.jstate)[0],
                                  np.asarray(f1.jstate))
    assert float(np.asarray(fs.events.jobs_killed)[0]) == \
        float(np.asarray(f1.events.jobs_killed))
