"""ML-guided scheduling pipeline tests (paper §4.4)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.ml import kmeans
from repro.ml.forest import RandomForest
from repro.ml.pipeline import MLSchedulerModel, attach_scores
from repro.ml.scoring import score
from repro.systems.config import get_system

SYS = get_system("fugaku").scaled(128)


def test_kmeans_separates_blobs():
    rng = np.random.default_rng(0)
    a = rng.normal(0, 0.3, (100, 4))
    b = rng.normal(5, 0.3, (80, 4))
    x = jnp.asarray(np.vstack([a, b]))
    centers, labels, inertia = kmeans.fit(x, 2, seed=1)
    labels = np.asarray(labels)
    # one cluster should be (almost) pure per blob
    same_a = (labels[:100] == labels[0]).mean()
    same_b = (labels[100:] == labels[100]).mean()
    assert same_a > 0.95 and same_b > 0.95
    assert labels[0] != labels[100]


def test_forest_beats_chance_on_separable_data():
    rng = np.random.default_rng(1)
    n = 400
    x = rng.normal(0, 1, (n, 5))
    y = (x[:, 0] + 0.5 * x[:, 2] > 0).astype(np.int64)
    clf = RandomForest.fit(x[:300], y[:300], 2, n_trees=8, depth=5, seed=0)
    pred = np.asarray(clf.predict(jnp.asarray(x[300:])))
    acc = (pred == y[300:]).mean()
    assert acc > 0.85


def test_score_is_decreasing_in_features():
    alpha = jnp.ones(3)
    lo = score(jnp.asarray([[1.0, 1.0, 1.0]]), alpha)
    hi = score(jnp.asarray([[100.0, 100.0, 100.0]]), alpha)
    assert float(lo[0]) > float(hi[0])  # bigger impact -> lower score


def test_pipeline_end_to_end_and_policy():
    spec = WorkloadSpec(n_jobs=300, duration_s=86400.0, load=1.2,
                        trace_len=8, n_accounts=16, seed=4)
    train_js = generate(SYS, spec)
    model = MLSchedulerModel.fit(train_js, k=4, n_trees=6, depth=5)
    test_js = generate(SYS, WorkloadSpec(n_jobs=120, duration_s=6 * 3600.0,
                                         load=1.5, trace_len=8, seed=9))
    cluster, pred = model.predict_metrics(test_js)
    assert pred.shape == (120, 3)
    assert int(jnp.max(cluster)) < 4
    attach_scores(test_js, model)
    assert np.isfinite(test_js.score).all()

    # the ml policy must schedule high-score jobs earlier under contention
    table = test_js.to_table()
    final, hist = eng.simulate(SYS, table, T.Scenario.make("ml", "first-fit"),
                               0.0, 4 * 3600.0)
    start = np.asarray(final.start)[:len(test_js)]
    started = np.isfinite(start)
    assert started.sum() > 10
    # rank correlation: among started jobs, higher score -> earlier start
    s = test_js.score[started]
    st_t = start[started]
    from numpy import argsort
    rank_score = np.argsort(np.argsort(-s))
    rank_start = np.argsort(np.argsort(st_t))
    corr = np.corrcoef(rank_score, rank_start)[0, 1]
    assert corr > -0.1  # weakly positive: queue pressure + arrival times mix


def test_ml_policy_reduces_power_spikes_under_load():
    """Paper Fig. 10a: under high load the ML policy (favoring small/short/
    low-power jobs) lowers the power peak vs LJF."""
    spec = WorkloadSpec(n_jobs=200, duration_s=4 * 3600.0, load=2.2,
                        trace_len=8, n_accounts=8, seed=13,
                        max_frac_nodes=0.4)
    js = generate(SYS, spec)
    model = MLSchedulerModel.fit(js, k=3, n_trees=4, depth=4)
    attach_scores(js, model)
    table = js.to_table()
    _, h_ml = eng.simulate(SYS, table, T.Scenario.make("ml", "first-fit"),
                           0.0, 2 * 3600.0)
    _, h_ljf = eng.simulate(SYS, table, T.Scenario.make("ljf", "first-fit"),
                            0.0, 2 * 3600.0)
    p_ml = np.asarray(h_ml.power_it)
    p_ljf = np.asarray(h_ljf.power_it)
    assert p_ml.max() <= p_ljf.max() * 1.05
