"""Property-based tests (hypothesis) on the engine's invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.base import JobSet
from repro.systems.config import get_system

SYSTEM = get_system("lassen").scaled(16)
N = SYSTEM.n_nodes
DT = SYSTEM.dt


@st.composite
def jobsets(draw):
    n = draw(st.integers(4, 24))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31 - 1)))
    submit = np.sort(rng.uniform(0, 1800, n))
    wall = np.maximum(np.round(rng.uniform(DT, 2400, n) / DT), 1) * DT
    nodes = rng.integers(1, N + 1, n)
    limit = wall * rng.uniform(1.0, 2.5, n)
    return JobSet(submit=submit, limit=limit, wall=wall,
                  nodes=nodes.astype(np.int64),
                  priority=rng.uniform(0, 10, n),
                  account=rng.integers(0, 4, n),
                  rec_start=submit + rng.uniform(0, 600, n),
                  power_prof=rng.uniform(300, 2000, (n, 1)).astype(np.float32),
                  util_prof=rng.uniform(0.2, 1.0, (n, 1)).astype(np.float32))


POLICIES = ["fcfs", "sjf", "ljf", "priority"]
BACKFILLS = ["none", "first-fit", "easy"]


@settings(max_examples=20, deadline=None)
@given(js=jobsets(), pol=st.sampled_from(POLICIES),
       bf=st.sampled_from(BACKFILLS))
def test_engine_invariants(js, pol, bf):
    table = js.to_table(32)
    scen = T.Scenario.make(pol, bf)
    final, hist = eng.simulate(SYSTEM, table, scen, 0.0, 3600.0,
                               num_accounts=8)
    jstate = np.asarray(final.jstate)[:len(js)]
    start = np.asarray(final.start)[:len(js)]
    end = np.asarray(final.end)[:len(js)]
    util = np.asarray(hist.util)

    # utilization is a fraction
    assert (util >= -1e-6).all() and (util <= 1.0 + 1e-6).all()
    # no job starts before submission
    started = np.isfinite(start)
    assert (start[started] >= js.submit[started] - 1e-3).all()
    # realized runtime == ground-truth wall
    fin = np.isfinite(end) & started
    np.testing.assert_allclose(end[fin] - start[fin], js.wall[fin],
                               rtol=1e-5)
    # done jobs completed within the horizon
    done = jstate == T.DONE
    assert (end[done] <= 3600.0 + 1e-3).all()
    # free count consistent at the end
    node_job = np.asarray(final.node_job)
    assert int(final.free_count) == (node_job < 0).sum()
    # energy accounting non-negative and consistent
    assert float(final.energy_total) >= float(final.energy_it) >= 0.0


@settings(max_examples=10, deadline=None)
@given(js=jobsets())
def test_replay_is_deterministic_fixed_point(js):
    """Rescheduling with the same policy the generator used (fcfs/first-fit)
    from t0=0 reproduces the recorded starts when recorded starts came from
    the same capacity semantics."""
    from repro.datasets.synthetic import event_schedule
    rec = event_schedule(js.submit, js.limit, js.wall, js.nodes, N, DT,
                         policy="fcfs", backfill="firstfit")
    ok = np.isfinite(rec)
    js.rec_start = np.where(ok, rec, 7200.0)
    table = js.to_table(32)
    final, _ = eng.simulate(SYSTEM, table, T.Scenario.make("fcfs",
                                                           "first-fit"),
                            0.0, 3600.0, num_accounts=8)
    start = np.asarray(final.start)[:len(js)]
    both = np.isfinite(start) & ok & (rec < 3600.0 - DT)
    np.testing.assert_allclose(start[both], rec[both], atol=DT)


@settings(max_examples=10, deadline=None)
@given(js=jobsets(), cap_mult=st.floats(1.3, 6.0))
def test_power_cap_never_exceeded(js, cap_mult):
    """Under any job table and any cap above the idle floor (with the DVFS
    floor c_min ~ 0), per-step IT power never exceeds the cap, and the
    emissions accumulator matches the telemetry integral."""
    import dataclasses
    from repro.grid import signals as gsig
    sys2 = dataclasses.replace(
        SYSTEM, grid=dataclasses.replace(SYSTEM.grid, c_min=1e-3))
    n_steps = int(3600.0 / DT)
    floor = SYSTEM.n_nodes * SYSTEM.power.idle_node_w
    cap = cap_mult * floor
    sig = gsig.constant_signals(n_steps, carbon_gkwh=400.0, price_kwh=0.1,
                                cap_w=cap)
    table = js.to_table(32)
    final, hist = eng.simulate(sys2, table,
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, 3600.0, num_accounts=8, signals=sig)
    assert (np.asarray(hist.power_it) <= cap + 1.0).all()
    p = np.asarray(hist.power_total, np.float64)
    expect = (p * DT * 0.4).sum() / 3.6e6
    assert np.isclose(float(final.emissions_kg), expect, rtol=1e-4, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(js=jobsets())
def test_account_energy_conservation(js):
    """Sum of per-account energy of completed jobs equals the sum of their
    job energies."""
    table = js.to_table(32)
    final, _ = eng.simulate(SYSTEM, table, T.Scenario.make("fcfs",
                                                           "first-fit"),
                            0.0, 3600.0, num_accounts=8)
    done = np.asarray(final.jstate)[:len(js)] == T.DONE
    je = np.asarray(final.jenergy)[:len(js)]
    acct_e = float(np.asarray(final.accounts.energy).sum())
    assert np.isclose(acct_e, je[done].sum(), rtol=1e-4, atol=1.0)
