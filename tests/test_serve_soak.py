"""Multi-client conformance + fault-injection soak for the twin server.

The serving claims under test, in the style of the PR 5 transport
conformance suite:

* N concurrent ``tools/twin_client`` subprocesses can advance and fork
  one shared session, each driving its own what-if branch, and every
  one of them exits cleanly;
* misbehaving clients — dying mid-stream, sending garbage, requesting
  branches that don't exist, hanging silently — get the documented
  error envelopes (or are reaped by the read timeout) and NEVER take
  the server down or corrupt the session for well-behaved clients;
* the zero-zombie ledger holds: every spawned client subprocess is
  ``wait()``ed, and the server's connection ledger is fully closed
  after ``close()`` (``n_open == 0``, no live handler threads — the
  ``SubprocessPeer.spawned`` pattern, applied to the serving side);
* the coalescing executor is a pure throughput optimization: branches
  advanced as one batched sweep are **bitwise identical** to the same
  branches advanced one at a time;
* fault soak (repro.events): a session forked into nominal vs
  failure-injected branches keeps the nominal branch byte-identical to
  a never-forked session — injected outages cannot leak across the
  fork.
"""
import json
import pathlib
import subprocess
import sys
import time

import pytest

from repro.core import types as T
from repro.events import EventConfig
from repro.serve.server import TwinServer
from repro.serve.session import SessionError, TwinSession

REPO = pathlib.Path(__file__).resolve().parents[1]
INTERVAL = 8
HORIZON_S = 2 * 3600.0


@pytest.fixture()
def session(small_system, small_table):
    return TwinSession(small_system, small_table,
                       T.Scenario.make("fcfs", "easy"), 0.0, HORIZON_S,
                       interval_steps=INTERVAL, num_accounts=8)


def spawn_client(addr, script=None, fault=None, timeout=30.0):
    cmd = [sys.executable, "-m", "tools.twin_client", "--connect", addr,
           "--timeout", str(timeout)]
    if script is not None:
        cmd += ["--script", script]
    if fault is not None:
        cmd += ["--fault", fault]
    return subprocess.Popen(cmd, cwd=REPO, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)


def drain(procs, deadline_s=120.0):
    """wait() every spawned client; return (rc, stdout-lines) per proc."""
    out = []
    t_end = time.monotonic() + deadline_s
    for p in procs:
        left = max(1.0, t_end - time.monotonic())
        stdout, stderr = p.communicate(timeout=left)
        out.append((p.returncode, stdout.splitlines(), stderr))
    return out


def assert_reaped(procs, server_stats):
    """Zero zombies: every client wait()ed, every connection closed."""
    for p in procs:
        assert p.poll() is not None, f"client pid {p.pid} not reaped"
    assert server_stats["n_open"] == 0, \
        f"server ledger leaked connections: {server_stats['clients']}"


@pytest.mark.timeout(300)
def test_concurrent_clients_fork_and_advance(session, small_jobs,
                                             tmp_path):
    """Four clients, one session: each forks its own what-if and drives
    it to a different depth; all succeed, the fork count is exact, and
    the obs manifest records the traffic."""
    addr = f"unix:{tmp_path}/twin.sock"
    n_clients = 4
    with TwinServer(session, addr, jobs=small_jobs,
                    batch_window_s=0.05, obs_dir=tmp_path) as srv:
        procs = [spawn_client(
            addr,
            script=(f"advance 0 1; "
                    f"fork 0 setpoint_delta_c={0.5 * (i + 1)}; "
                    f"advance last {1 + i % 3}; fetch last; "
                    f"snapshot last; bye"))
            for i in range(n_clients)]
        results = drain(procs)
        stats = srv.stats()
    final = srv.close()

    for rc, lines, stderr in results:
        assert rc == 0, stderr
        kinds = [json.loads(l)["kind"] for l in lines]
        assert kinds[0] == "hello"
        assert "error" not in kinds, lines
        assert kinds[-1] == "bye_ok"
    assert stats["session"]["forks"] == n_clients
    assert final["n_clients"] == n_clients
    assert_reaped(procs, final)

    # flight recorder: manifest + event log exist and saw the traffic
    manifest = json.loads((tmp_path / "serve_manifest.json").read_text())
    assert manifest["command"] == "serve"
    assert manifest["counters"]["session"]["forks"] == n_clients
    events = (tmp_path / "serve_events.ndjson").read_text().splitlines()
    what = [json.loads(e)["event"] for e in events]
    assert what.count("client_connect") == n_clients
    assert what.count("client_disconnect") == n_clients
    assert "advance_batch" in what and "fork" in what


@pytest.mark.timeout(300)
def test_fault_injection_never_kills_the_server(session, small_jobs,
                                                tmp_path):
    """Every documented client misbehavior at once, against one server:
    the faults get their envelopes, the session survives, and a healthy
    client arriving *after* the chaos still gets full service."""
    addr = f"unix:{tmp_path}/twin.sock"
    with TwinServer(session, addr, jobs=small_jobs,
                    batch_window_s=0.02,
                    client_timeout_s=2.0) as srv:   # reap hangers fast
        procs = [
            spawn_client(addr, script="advance 0 1; fork 0 cap_scale=0.9;"
                                      " advance last 2; bye"),   # healthy
            spawn_client(addr, fault="die:2",
                         script="advance 0 1; state; state; state"),
            spawn_client(addr, fault="garbage"),
            spawn_client(addr, fault="badbranch"),
            spawn_client(addr, fault="hang", timeout=10.0),
        ]
        results = drain(procs)
        # a session error on a live connection must not end it: the
        # same connection keeps working after the error envelope —
        # including a wrong-shape fork delta, which must be rejected at
        # fork time instead of crashing the shared executor later
        late = spawn_client(addr, script="advance 999999 1; "
                                         "fork 0 cells_offline=1,2; "
                                         "state; advance 0 1; bye")
        late_rc, late_lines, late_err = drain([late])[0]
        final_state = session.describe()
    stats = srv.close()

    healthy_rc, healthy_lines, healthy_err = results[0]
    assert healthy_rc == 0, healthy_err
    assert "error" not in [json.loads(l)["kind"] for l in healthy_lines]

    die_rc = results[1][0]
    assert die_rc == 1                      # os._exit(1), mid-stream

    garbage_lines = results[2][1]
    garbage_reply = json.loads(garbage_lines[-1])
    assert garbage_reply["kind"] == "error"
    assert garbage_reply["error"] == "protocol"

    bad_lines = results[3][1]
    bad_reply = json.loads(bad_lines[-1])
    assert bad_reply == {"version": 1, "kind": "error",
                         "error": "session", "id": 0,
                         "message": bad_reply["message"]}
    assert "unknown branch" in bad_reply["message"]

    assert results[4][0] == 0               # hanger reaped by timeout

    assert late_rc == 0, late_err
    late_kinds = [json.loads(l)["kind"] for l in late_lines]
    assert late_kinds == ["hello", "error", "error", "state_ok",
                          "advance_ok", "bye_ok"]
    shape_reply = json.loads(late_lines[2])
    assert shape_reply["error"] == "session"
    assert "scalar in this session" in shape_reply["message"]

    # the chaos left a coherent session: healthy fork exists, advanced
    branches = {b["branch"]: b for b in final_state["branches"]}
    assert len(branches) == 2               # root + the healthy fork
    fork_id = max(branches)
    assert branches[fork_id]["delta"] == {"cap_scale": 0.9}
    assert branches[fork_id]["step"] > branches[fork_id]["born_step"]
    assert stats["session"]["errors"] >= 2  # badbranch + late client
    assert_reaped(procs + [late], stats)
    # the ledger kept one row per connection, each with its ending;
    # badbranch says bye too — its session error did not end the
    # connection, so its polite close still goes through
    reasons = sorted(c["reason"] for c in stats["clients"])
    assert reasons.count("bye") == 3        # healthy, badbranch, late
    assert "protocol-error" in reasons      # the garbage speaker


@pytest.mark.timeout(300)
def test_coalesced_advance_is_bitwise_identical_to_serial(
        small_system, small_table):
    """The executor's batching must be unobservable: the same fork tree
    advanced (a) with all branches coalesced per tick and (b) one branch
    at a time produces identical telemetry and snapshot digests."""
    deltas = [{}, {"setpoint_delta_c": 2.0}, {"cap_scale": 0.85},
              {"cells_offline": 1.0}]

    def build(coalesce: bool) -> TwinSession:
        sess = TwinSession(small_system, small_table,
                           T.Scenario.make("fcfs", "easy"), 0.0,
                           HORIZON_S, interval_steps=INTERVAL,
                           num_accounts=8)
        sess.advance_many({0: 2})
        for d in deltas:
            sess.fork(0, d)
        ids = list(sess.branches)
        if coalesce:
            sess.advance_many({b: 3 for b in ids})
        else:
            for b in ids:
                sess.advance_many({b: 3})
        return sess

    batched, serial = build(True), build(False)
    assert batched.counters["coalesced_batches"] >= 3
    assert serial.counters["coalesced_batches"] == 0
    for b in batched.branches:
        rows_a = batched.fetch(b)["rows"]
        rows_b = serial.fetch(b)["rows"]
        assert rows_a == rows_b, f"branch {b} diverged under batching"
        assert (batched.snapshot(b)["digest"]
                == serial.snapshot(b)["digest"]), f"branch {b} carry"


@pytest.mark.timeout(300)
def test_fault_soak_nominal_branch_unaffected_by_failure_fork(
        small_system, small_table):
    """What-if failure branches are isolated: fork one session into a
    nominal branch and a failure-injected branch (the fork delta alone
    turns on the hazard — the session itself runs with the event layer
    compiled in but all rates at zero), then advance both. The nominal
    branch must stay byte-identical — rows and snapshot digest — to a
    session that never forked at all."""
    def build() -> TwinSession:
        return TwinSession(small_system, small_table,
                           T.Scenario.make("fcfs", "easy"), 0.0,
                           HORIZON_S, interval_steps=INTERVAL,
                           num_accounts=8, events=EventConfig())

    soaked = build()
    soaked.advance_many({0: 2})
    soaked.fork(0, {"node_fail_rate": 2e-4, "cdu_fail_rate": 5e-5,
                    "failure_corr": 0.5, "failure_seed": 7.0,
                    "repair_s": 600.0})
    fault = max(soaked.branches)
    soaked.advance_many({0: 3, fault: 3})    # one coalesced sweep

    pristine = build()
    pristine.advance_many({0: 5})

    assert soaked.fetch(0)["rows"] == pristine.fetch(0)["rows"], \
        "failure fork leaked into the nominal branch"
    assert soaked.snapshot(0)["digest"] == pristine.snapshot(0)["digest"]

    # and the failure branch is a real failure universe, not a copy
    rows = soaked.fetch(fault)["rows"]
    assert sum(r["nodes_down"] for r in rows) > 0
    assert rows != soaked.fetch(0)["rows"]


@pytest.mark.timeout(120)
def test_session_error_taxonomy(session):
    """Library-level error contract: unknown ids, bad fork points and
    bad knobs raise ``SessionError`` and corrupt nothing."""
    session.advance_many({0: 1})
    with pytest.raises(SessionError, match="unknown branch"):
        session.advance_many({42: 1})
    with pytest.raises(SessionError, match="no checkpoint"):
        session.fork(0, {}, at_step=3)      # not an interval boundary
    with pytest.raises(SessionError, match="unknown scenario knob"):
        session.fork(0, {"flux_capacitor": 1.21})
    with pytest.raises(SessionError, match="no checkpoint"):
        session.snapshot(0, at_step=999)
    # a delta that would reshape a traced knob is a fork-time error,
    # not a later trace error inside the coalesced sweep
    with pytest.raises(SessionError, match="scalar in this session"):
        session.fork(0, {"cells_offline": [1.0, 0.0]})
    # the session still works after every rejection
    assert session.advance_many({0: 1})[0]["advanced_steps"] == INTERVAL
    assert len(session.branches) == 1
    assert session.counters["errors"] == 5


@pytest.mark.timeout(120)
def test_executor_survives_unexpected_dispatch_failure(session, tmp_path,
                                                       monkeypatch):
    """Defense in depth: if a batch dispatch blows up with something
    that is NOT a ``SessionError`` (e.g. a shape error that slipped
    past fork-time validation), the batch gets error envelopes and the
    executor keeps serving — it must never die and strand every later
    advance on an unanswered queue."""
    addr = f"unix:{tmp_path}/twin.sock"
    with TwinServer(session, addr) as srv:
        real = session.advance_many
        monkeypatch.setattr(
            session, "advance_many",
            lambda requests: (_ for _ in ()).throw(
                RuntimeError("synthetic trace error")))
        with pytest.raises(SessionError, match="synthetic trace error"):
            srv._advance(0, 1)
        assert srv._exec_thread.is_alive()
        monkeypatch.setattr(session, "advance_many", real)
        out = srv._advance(0, 1)
        assert out["advanced_steps"] == INTERVAL
    assert session.counters["errors"] == 1


@pytest.mark.timeout(120)
def test_advance_racing_shutdown_fails_fast(session, tmp_path):
    """An advance that arrives once shutdown is underway gets a
    ``SessionError`` immediately instead of enqueueing a request the
    executor will never answer (which would hang the handler thread
    and break close()'s zero-zombie assertion)."""
    addr = f"unix:{tmp_path}/twin.sock"
    srv = TwinServer(session, addr)
    srv.close()
    with pytest.raises(SessionError, match="shutting down"):
        srv._advance(0, 1)
