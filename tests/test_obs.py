"""Flight recorder: manifests, NDJSON streams, span timers, bridge and
sweep-cache counters, and the perf-trajectory gate (tools/bench_compare)."""
import json
import pathlib
import socket
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import external as ext
from repro.core import transport
from repro.core import types as T
from repro.obs import (MetricsSink, RunRecorder, SpanTimer, build_manifest,
                       load_manifest, read_frames, schema, stream_history,
                       timing, use)
from repro.obs.timing import LatencyHistogram

ROOT = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# Manifest + schema
# ---------------------------------------------------------------------------
def test_manifest_valid_and_digest_deterministic(small_system, small_jobs):
    kw = dict(command="simulate", argv=["-t", "1h"],
              scenario={"policy": "fcfs"}, seed=7, jobs=small_jobs)
    m1 = build_manifest(small_system, **kw)
    m2 = build_manifest(small_system, **kw)
    assert m1["system"]["digest"] == m2["system"]["digest"]
    assert m1["jobs"]["digest"] == m2["jobs"]["digest"]
    assert m1["system"]["n_nodes"] == small_system.n_nodes
    assert m1["jobs"]["n_jobs"] == len(small_jobs)
    for k in ("python", "numpy", "jax", "backend"):
        assert k in m1["versions"]
    # distinct runs still mint distinct run ids
    assert m1["run_id"] != m2["run_id"]


def test_manifest_validation_names_missing_fields(small_system):
    m = build_manifest(small_system, command="simulate", argv=[],
                       scenario={})
    del m["seed"]
    m["argv"] = "not-a-list"
    with pytest.raises(schema.SchemaError) as e:
        schema.validate_manifest(m)
    assert "seed" in str(e.value) and "argv" in str(e.value)


def test_jsonable_strips_nonfinite():
    out = schema.jsonable({"cap_w": float("inf"),
                           "arr": np.array([1.0, np.nan]),
                           "n": np.int32(3)})
    assert out == {"cap_w": None, "arr": [1.0, None], "n": 3}
    json.dumps(out)  # strict-JSON safe


def test_frame_envelopes_validate():
    f = schema.metrics_frame("r", 0, 15.0, {"pue": 1.1}, label="fcfs:easy")
    assert schema.validate_frame(f) is f
    with pytest.raises(schema.SchemaError):
        schema.validate_frame({"v": 99, "kind": "metrics", "run_id": "r"})
    with pytest.raises(schema.SchemaError):
        schema.validate_frame(schema.event_frame("r", 0, 0.0, "x")
                              | {"kind": "nope"})


# ---------------------------------------------------------------------------
# Recorder: manifest + event log on disk
# ---------------------------------------------------------------------------
def test_recorder_writes_manifest_and_events(tmp_path, small_system):
    mpath, epath = tmp_path / "run.json", tmp_path / "events.ndjson"
    clock = iter(float(i) for i in range(100))
    with RunRecorder(manifest_path=mpath, events_path=epath,
                     clock=lambda: next(clock)) as rec:
        rec.begin(small_system, command="simulate", argv=["-t", "1h"],
                  scenario={"policy": "fcfs"}, seed=0)
        rec.event("run_start")
        rec.event("checkpoint", path="ck.json", generation=2)
        rec.finalize(spans={"spans": {}, "counters": {}}, wall_s=1.25)
    m = load_manifest(mpath)
    assert m["n_events"] == 2 and m["wall_s"] == 1.25
    frames = read_frames(epath)
    assert [f["event"] for f in frames] == ["run_start", "checkpoint"]
    assert frames[1]["generation"] == 2
    assert all(f["run_id"] == m["run_id"] for f in frames)
    assert [f["seq"] for f in frames] == [0, 1]


def test_recorder_survives_missing_finalize(tmp_path, small_system):
    """A crash before finalize still leaves the event log behind."""
    epath = tmp_path / "events.ndjson"
    rec = RunRecorder(events_path=epath)
    rec.begin(small_system, command="train", argv=[], scenario={})
    rec.event("run_start")
    rec.close()  # simulated crash: no finalize
    assert [f["event"] for f in read_frames(epath)] == ["run_start"]


# ---------------------------------------------------------------------------
# Metrics sink: file + socket targets
# ---------------------------------------------------------------------------
def _tiny_run(small_system, small_table, n_steps=8):
    t1 = n_steps * small_system.dt
    final, hist = eng.simulate(small_system, small_table,
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, t1)
    return final, hist, n_steps


def test_metrics_sink_file_one_frame_per_interval(tmp_path, small_system,
                                                  small_table):
    final, hist, n_steps = _tiny_run(small_system, small_table)
    path = tmp_path / "metrics.ndjson"
    with MetricsSink(str(path)) as sink:
        n = stream_history(sink, "run-1", small_system, small_table,
                           final, hist, label="fcfs:none")
    assert n == n_steps + 1 == sink.n_frames
    frames = read_frames(path)
    assert len(frames) == n_steps + 1
    metrics = [f for f in frames if f["kind"] == schema.KIND_METRICS]
    assert len(metrics) == n_steps
    assert [f["seq"] for f in metrics] == list(range(n_steps))
    for f in metrics:
        assert f["label"] == "fcfs:none"
        assert f["data"]["pue"] >= 1.0
        # per-hall vectors have the topology's width
        assert len(f["data"]["t_basin_hall"]) == \
            small_system.cooling.n_halls
    summary = frames[-1]
    assert summary["kind"] == schema.KIND_SUMMARY
    assert summary["data"]["jobs_completed"] >= 0.0


def test_metrics_sink_socket_roundtrip(tmp_path, small_system, small_table):
    final, hist, n_steps = _tiny_run(small_system, small_table, n_steps=4)
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]
    got = []

    def serve():
        conn, _ = srv.accept()
        with conn, conn.makefile("rb") as rf:
            while True:
                try:
                    got.append(transport.read_frame(rf))
                except ConnectionError:
                    break

    th = threading.Thread(target=serve, daemon=True)
    th.start()
    with MetricsSink(f"tcp:127.0.0.1:{port}") as sink:
        stream_history(sink, "run-s", small_system, small_table,
                       final, hist)
    th.join(timeout=10.0)
    srv.close()
    assert len(got) == n_steps + 1
    assert got[0]["kind"] == schema.KIND_METRICS
    assert got[-1]["kind"] == schema.KIND_SUMMARY


def test_metrics_sink_rejects_bad_tcp_target():
    with pytest.raises(ValueError):
        MetricsSink("tcp:no-port-here")


# ---------------------------------------------------------------------------
# Span timers: observed engine path is bit-identical to the default one
# ---------------------------------------------------------------------------
def test_timer_spans_and_parity(small_system, small_table):
    scen = T.Scenario.make("fcfs", "first-fit")
    t1 = 8 * small_system.dt
    final0, hist0 = eng.simulate(small_system, small_table, scen, 0.0, t1)
    timer = SpanTimer()
    with use(timer):
        final1, hist1 = eng.simulate(small_system, small_table, scen,
                                     0.0, t1)
    np.testing.assert_array_equal(np.asarray(hist0.power_total),
                                  np.asarray(hist1.power_total))
    spans = timer.summary()["spans"]
    for name in ("engine.lower", "engine.compile", "engine.scan"):
        assert spans[name]["count"] == 1
        assert spans[name]["total_s"] >= 0.0
    assert timing.current() is None  # uninstalled on exit


def test_static_path_counters_and_parity(small_system, small_table):
    t1 = 6 * small_system.dt
    f0, _ = eng.simulate_static(small_system, small_table, "fcfs",
                                "first-fit", 0.0, t1)
    timer = SpanTimer()
    with use(timer):
        f1, _ = eng.simulate_static(small_system, small_table, "fcfs",
                                    "first-fit", 0.0, t1)
        f2, _ = eng.simulate_static(small_system, small_table, "fcfs",
                                    "first-fit", 0.0, t1)
    np.testing.assert_array_equal(np.asarray(f0.t), np.asarray(f1.t))
    counts = timer.summary()["counters"]
    # first call above already populated the cache: both observed calls hit
    assert counts.get("static_cache_hit", 0) == 2


def test_sweep_cache_stats_monotonic(small_system, small_table):
    before = dict(eng.SWEEP_CACHE_STATS)
    scens = [T.Scenario.make("fcfs"), T.Scenario.make("sjf")]
    t1 = 4 * small_system.dt
    eng.simulate_sweep(small_system, small_table, scens, 0.0, t1)
    eng.simulate_sweep(small_system, small_table, scens, 0.0, t1)
    after = eng.SWEEP_CACHE_STATS
    assert after["hits"] + after["misses"] >= \
        before["hits"] + before["misses"] + 2
    assert after["hits"] >= before["hits"] + 1  # second call reuses


def test_span_timer_deterministic_clock_and_listener():
    events = []
    clock = iter([0.0, 1.5, 2.0, 2.25]).__next__
    timer = SpanTimer(clock=clock,
                      listener=lambda what, f: events.append((what, f)))
    with timer.span("engine.compile", system="x"):
        pass
    with timer.span("engine.scan"):
        pass
    s = timer.summary()["spans"]
    assert s["engine.compile"]["total_s"] == 1.5
    assert s["engine.scan"]["total_s"] == 0.25
    assert [e[0] for e in events] == ["span_start", "span_end"] * 2
    assert events[1][1]["dur_s"] == 1.5


def test_latency_histogram_buckets():
    h = LatencyHistogram()
    for d in (5e-4, 0.02, 0.02, 250.0):
        h.record(d)
    s = h.summary()
    assert s["count"] == 4
    assert s["buckets"]["le_0.001s"] == 1
    assert s["buckets"]["le_0.1s"] == 2
    assert s["buckets"]["overflow"] == 1
    assert s["max_s"] == 250.0


# ---------------------------------------------------------------------------
# Bridge counters
# ---------------------------------------------------------------------------
def test_bridge_counters_surface_polls(small_system, small_jobs):
    events = []
    bridge = ext.SchedulerBridge(
        ext.FastSimLike(policy="fcfs", backfill="firstfit"),
        on_event=lambda ev, f: events.append(ev))
    t1 = 6 * small_system.dt
    ext.run_plugin_mode(small_system, small_jobs, bridge, 0.0, t1)
    s = bridge.stats()
    assert s["polls"] >= 1
    assert s["poll_failures"] == 0 and s["reconnects"] == 0
    assert s["poll_latency"]["count"] == s["polls"]
    assert events == []  # no reconnects -> no bridge events


# ---------------------------------------------------------------------------
# Perf-trajectory gate (tools/bench_compare.py)
# ---------------------------------------------------------------------------
def _gate(tmp_path, payload, history, append=False):
    bench = tmp_path / "BENCH.json"
    bench.write_text(json.dumps(payload))
    cmd = [sys.executable, str(ROOT / "tools" / "bench_compare.py"),
           str(bench), "--history", str(history)]
    if append:
        cmd.append("--append")
    return subprocess.run(cmd, capture_output=True, text=True)


def test_bench_compare_gate_paths(tmp_path):
    hist = tmp_path / "hist.ndjson"
    ok = {"engine/smoke": {"steps_per_s": 100.0, "wall_s": 1.0},
          "meta": {"backend": "cpu", "device": "cpu"}}
    # 1. no history: free pass, --append seeds the trajectory
    r = _gate(tmp_path, ok, hist, append=True)
    assert r.returncode == 0, r.stderr
    assert "no history" in r.stdout
    assert len(hist.read_text().splitlines()) == 1
    # 2. identical run gates green
    r = _gate(tmp_path, ok, hist)
    assert r.returncode == 0, r.stderr
    # 3. synthetic 2x regression gates red
    bad = {"engine/smoke": {"steps_per_s": 50.0}, "meta": ok["meta"]}
    r = _gate(tmp_path, bad, hist)
    assert r.returncode == 1
    assert "REGRESSION" in r.stderr
    # 4. small wobble (within 30%) stays green
    wobble = {"engine/smoke": {"steps_per_s": 85.0}, "meta": ok["meta"]}
    assert _gate(tmp_path, wobble, hist).returncode == 0
    # 5. different backend never gates against cpu history
    gpu = {"engine/smoke": {"steps_per_s": 1.0},
           "meta": {"backend": "gpu", "device": "H100"}}
    r = _gate(tmp_path, gpu, hist)
    assert r.returncode == 0
    assert "no history" in r.stdout
    # 6. a file with no *_per_s metrics is a usage error
    assert _gate(tmp_path, {"meta": ok["meta"]}, hist).returncode == 2


def test_bench_compare_gates_committed_baselines():
    """CI runs the gate against benchmarks/baselines/ — the committed
    history must parse and carry the engine/ml throughput metrics."""
    base = ROOT / "benchmarks" / "baselines"
    for name, metric in (("engine_history.ndjson", "steps_per_s"),
                         ("ml_history.ndjson", "generations_per_s")):
        lines = (base / name).read_text().splitlines()
        assert lines, f"{name} is empty"
        e = json.loads(lines[-1])
        assert e["backend"]
        assert any(k.endswith(metric) for k in e["metrics"])


# ---------------------------------------------------------------------------
# CLI: the acceptance path (tiny twin, manifest + metrics, run twice)
# ---------------------------------------------------------------------------
def test_simulate_cli_flight_recorder_deterministic(tmp_path):
    from repro.launch import simulate as cli
    outs = []
    for i in (1, 2):
        m = tmp_path / f"run{i}.json"
        mx = tmp_path / f"metrics{i}.ndjson"
        ev = tmp_path / f"events{i}.ndjson"
        cli.main(["--system", "marconi100", "--scale", "64", "--jobs",
                  "20", "-t", "10m", "--policy", "fcfs",
                  "--manifest", str(m), "--metrics", str(mx),
                  "--events", str(ev), "--quiet"])
        outs.append((load_manifest(m), read_frames(mx), read_frames(ev)))
    (m1, fr1, ev1), (m2, fr2, _) = outs
    # identical configuration -> identical system digest (acceptance)
    assert m1["system"]["digest"] == m2["system"]["digest"]
    assert m1["jobs"]["digest"] == m2["jobs"]["digest"]
    n_steps = int(round(600.0 / m1["system"]["dt"]))
    metrics1 = [f for f in fr1 if f["kind"] == schema.KIND_METRICS]
    assert len(metrics1) == n_steps  # >= 1 frame per interval
    assert len(fr1) == len(fr2)
    assert m1["counters"]["metrics_frames"] == len(fr1)
    assert "engine.scan" in m1["spans"]["spans"]
    assert any(f["event"] == "run_start" for f in ev1)
    assert any(f["event"] == "run_end" for f in ev1)
