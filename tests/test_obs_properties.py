"""Property-based tests (hypothesis) for the flight-recorder frames.

The metrics/event streams ride the same transport framing as the
scheduler wire, so the invariants are the same: every frame the obs
layer can construct must survive a ``write_frame``/``read_frame`` round
trip byte-for-byte, stay strict-JSON (no NaN/Infinity on the wire), and
fit ``MAX_FRAME_BYTES`` even at Frontier-scale hall counts.
"""
import io
import math

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import transport as tr  # noqa: E402
from repro.obs import schema  # noqa: E402

# scalar telemetry: any float the engine can emit, including the
# non-finite values (+inf cap_w, NaN from a masked reduction)
any_float = st.floats(allow_nan=True, allow_infinity=True, width=64)
field_names = st.text(
    alphabet="abcdefghijklmnopqrstuvwxyz_", min_size=1, max_size=24)

# per-hall vectors up to well past Frontier scale (Frontier's topology
# is O(10) halls; 512 leaves margin for synthetic what-ifs)
hall_vectors = st.lists(any_float, min_size=1, max_size=512)

telemetry = st.dictionaries(
    field_names,
    st.one_of(any_float, hall_vectors, st.integers(-2**40, 2**40)),
    max_size=24)


def _roundtrip(frame: dict) -> dict:
    buf = io.BytesIO()
    tr.write_frame(buf, frame)
    assert buf.tell() <= tr.MAX_FRAME_BYTES
    buf.seek(0)
    return tr.read_frame(buf)


def _assert_finite(x):
    if isinstance(x, float):
        assert math.isfinite(x)
    elif isinstance(x, list):
        for v in x:
            _assert_finite(v)
    elif isinstance(x, dict):
        for v in x.values():
            _assert_finite(v)


@given(telemetry, st.integers(0, 2**31), any_float)
@settings(max_examples=200, deadline=None)
def test_metrics_frame_roundtrips_and_is_strict_json(data, seq, t_sim):
    t_sim = t_sim if math.isfinite(t_sim) else 0.0
    frame = schema.metrics_frame("run-prop", seq, t_sim, data,
                                 label="fcfs:easy")
    schema.validate_frame(frame)
    back = _roundtrip(frame)
    assert back == frame          # byte-faithful wire trip
    _assert_finite(back["data"])  # NaN/inf never reach the wire


@given(field_names, telemetry, st.integers(0, 2**31))
@settings(max_examples=100, deadline=None)
def test_event_frame_roundtrips(event, fields, seq):
    fields.pop("run_id", None)  # envelope keys are the frame's own
    fields.pop("kind", None)
    fields.pop("v", None)
    fields.pop("seq", None)
    fields.pop("event", None)
    fields.pop("t_wall", None)
    frame = schema.event_frame("run-prop", seq, 1.5, event, **fields)
    back = _roundtrip(schema.validate_frame(frame))
    assert back == frame
    assert back["event"] == event and back["seq"] == seq


@given(st.integers(1, 512), st.integers(1, 40))
@settings(max_examples=50, deadline=None)
def test_frontier_scale_frames_fit_the_wire(n_halls, n_fields):
    """A full StepRecord frame with every hall vector at width
    ``n_halls`` stays far below MAX_FRAME_BYTES."""
    data = {f"scalar_{i}": 1.0e6 for i in range(n_fields)}
    for name in ("power_it_hall", "t_basin_hall", "t_supply_max_hall",
                 "cells_online"):
        data[name] = [293.15] * n_halls
    frame = schema.metrics_frame("run-prop", 0, 0.0, data)
    buf = io.BytesIO()
    tr.write_frame(buf, frame)
    assert buf.tell() <= tr.MAX_FRAME_BYTES
    assert _roundtrip(frame) == frame
