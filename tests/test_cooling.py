"""Transient cooling twin tests: energy conservation, PUE calibration,
monotone load-step response, fused-kernel parity, weather what-ifs and the
thermal-aware scheduling hooks."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.cooling import model as cooling
from repro.cooling import weather as wx
from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.kernels.power_topo import ops as topo_ops
from repro.kernels.power_topo import ref as topo_ref
from repro.power import losses as pl
from repro.systems.config import get_system


@pytest.fixture(scope="module")
def system():
    return get_system("marconi100").scaled(64)


def run_to_steady(cfg, q, dt=30.0, n=2000, state=None):
    state = cooling.init_state(cfg) if state is None else state
    out = None
    for _ in range(n):
        state, out = cooling.step(cfg, state, q, dt)
    return state, out


# ---------------------------------------------------------------------------
# Energy conservation.
# ---------------------------------------------------------------------------
def test_basin_energy_balance_discrete_identity(system):
    """Over any transient, the basin's stored-energy change equals the
    integral of (heat in - heat rejected): the basin update conserves
    energy exactly (float tolerance)."""
    cfg = system.cooling
    dt = 30.0
    rng = np.random.default_rng(0)
    state = cooling.init_state(cfg)
    t0 = float(state.t_basin[0])
    acc = 0.0
    for k in range(400):
        q = jnp.asarray(rng.uniform(1e4, 2e5, cfg.n_groups), jnp.float32)
        state, out = cooling.step(cfg, state, q, dt)
        q_tower = float(jnp.sum(q)) - float(out.q_reuse_w)
        acc += (q_tower - float(out.q_reject_w)) * dt
    stored = cfg.basin_mcp() * (float(state.t_basin[0]) - t0)
    assert np.isclose(acc, stored, rtol=1e-3, atol=1e3)


def test_steady_state_rejects_plus_reuses_all_heat(system):
    """At steady state the tower + heat-export streams carry away all the
    IT heat (global energy balance within 2%)."""
    cfg = system.cooling
    q = jnp.full((cfg.n_groups,), 8e4, jnp.float32)
    _, out = run_to_steady(cfg, q)
    q_tot = float(jnp.sum(q))
    q_out = float(out.q_reject_w) + float(out.q_reuse_w)
    assert abs(q_out - q_tot) / q_tot < 0.02


# ---------------------------------------------------------------------------
# PUE calibration.
# ---------------------------------------------------------------------------
def test_pue_nominal_near_paper_value():
    """PUE >= 1 always, and ~1.06 at nominal (70%) load on the full
    Frontier config — the paper notes the real system averages ~1.06."""
    sysc = get_system("frontier")
    cfg = sysc.cooling
    p_it = 0.7 * sysc.n_nodes * sysc.power.peak_node_w
    q = jnp.full((cfg.n_groups,), p_it / cfg.n_groups, jnp.float32)
    _, out = run_to_steady(cfg, q, dt=sysc.dt)
    n_racks = max(sysc.n_nodes // sysc.power.nodes_per_rack, 1)
    _, loss = pl.conversion(sysc.power, jnp.float32(p_it), float(n_racks))
    pue = float(cooling.pue(jnp.float32(p_it), loss, out.p_cooling))
    assert 1.0 < pue
    assert 1.03 < pue < 1.09


def test_pue_at_least_one_across_loads(system):
    cfg = system.cooling
    n_racks = max(system.n_nodes // system.power.nodes_per_rack, 1)
    for frac in (0.1, 0.4, 0.8, 1.0):
        p_it = frac * system.n_nodes * system.power.peak_node_w
        q = jnp.full((cfg.n_groups,), p_it / cfg.n_groups, jnp.float32)
        _, out = run_to_steady(cfg, q, n=800)
        _, loss = pl.conversion(system.power, jnp.float32(p_it),
                                float(n_racks))
        assert float(cooling.pue(jnp.float32(p_it), loss,
                                 out.p_cooling)) >= 1.0


# ---------------------------------------------------------------------------
# Transient response.
# ---------------------------------------------------------------------------
def test_monotone_tower_temp_response_to_load_step(system):
    """After a step increase in heat load, the tower return temperature
    rises monotonically (no oscillation/overshoot) to a hotter steady
    state."""
    cfg = system.cooling
    dt = 30.0
    lo = jnp.full((cfg.n_groups,), 2e4, jnp.float32)
    hi = jnp.full((cfg.n_groups,), 1.2e5, jnp.float32)
    state, out_lo = run_to_steady(cfg, lo, dt=dt)
    t_lo = float(out_lo.t_tower_return)
    trace = []
    for _ in range(600):
        state, out = cooling.step(cfg, state, hi, dt)
        trace.append(float(out.t_tower_return))
    trace = np.asarray(trace)
    assert trace[-1] > t_lo + 1.0                 # visibly hotter
    assert (np.diff(trace) >= -1e-3).all()        # monotone rise
    # settled: last 10% of the window moves < 0.05 °C
    assert trace[-1] - trace[int(0.9 * len(trace))] < 0.05


def test_valve_flow_tracks_demand(system):
    """CDU flow slews toward q/(cp·ΔT_design) and respects its bounds."""
    cfg = system.cooling
    # demand above the floor but below full-open
    q_g = 0.5 * cfg.mdot_kg_s * cfg.cp_j_kg_k * cfg.delta_t_design_c
    q = jnp.full((cfg.n_groups,), q_g, jnp.float32)
    state, _ = run_to_steady(cfg, q, n=400)
    expect = q_g / (cfg.cp_j_kg_k * cfg.delta_t_design_c)
    np.testing.assert_allclose(np.asarray(state.mdot), expect, rtol=1e-3)
    # design ΔT holds when the valve is in its control range
    d = np.asarray(state.t_return) - np.asarray(state.t_supply)
    np.testing.assert_allclose(d, cfg.delta_t_design_c, rtol=1e-3)
    state, _ = run_to_steady(cfg, jnp.zeros((cfg.n_groups,), jnp.float32),
                             n=400)
    np.testing.assert_allclose(np.asarray(state.mdot),
                               cfg.mdot_min_frac * cfg.mdot_kg_s, rtol=1e-3)


def test_heat_reuse_engages_only_when_hot(system):
    """The export stream carries heat only when the return water is hot
    enough to be useful, and never exceeds its capacity cap."""
    cfg = dataclasses.replace(system.cooling, reuse_frac=0.3,
                              reuse_max_w=5e4, reuse_t_min_c=30.0)
    cold, out_cold = run_to_steady(cfg, jnp.full((cfg.n_groups,), 1e4,
                                                 jnp.float32))
    assert float(out_cold.q_reuse_w) == 0.0
    hot, out_hot = run_to_steady(cfg, jnp.full((cfg.n_groups,), 2e5,
                                               jnp.float32))
    assert float(out_hot.t_tower_return) >= 30.0
    assert 0.0 < float(out_hot.q_reuse_w) <= 5e4 + 1.0


# ---------------------------------------------------------------------------
# Fused kernel parity (acceptance: <= 1e-4).
# ---------------------------------------------------------------------------
def test_fused_cooling_kernel_matches_ref():
    rng = np.random.default_rng(7)
    for S, N, G in [(3, 100, 4), (8, 256, 8), (1, 37, 5)]:
        node_pw = jnp.asarray(rng.uniform(200.0, 2500.0, (S, N)), jnp.float32)
        ts = jnp.asarray(rng.uniform(20.0, 35.0, (S, G)), jnp.float32)
        md = jnp.asarray(rng.uniform(8.0, 40.0, (S, G)), jnp.float32)
        tb = jnp.asarray(rng.uniform(18.0, 30.0, (S,)), jnp.float32)
        tset = jnp.asarray(rng.uniform(24.0, 32.0, (S,)), jnp.float32)
        p = topo_ref.CduParams(cp_j_kg_k=4186.0, ua_w_k=4e5, dt=15.0,
                               tau_hx_s=120.0, tau_valve_s=60.0,
                               delta_t_design_c=8.0, mdot_min_kg_s=8.0,
                               mdot_max_kg_s=40.0)
        want = topo_ref.fused_cooling_ref(node_pw, ts, md, tb, tset, G, p)
        got = topo_ops.fused_cooling(node_pw, ts, md, tb, tset, G, p,
                                     use_pallas=True, interpret=True)
        for w, g, name in zip(want, got,
                              ("q", "t_return", "t_supply", "mdot")):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-4, atol=1e-4, err_msg=name)


def test_fused_cooling_kernel_unbatched_shapes():
    p = topo_ref.CduParams(cp_j_kg_k=4186.0, ua_w_k=4e5, dt=15.0,
                           tau_hx_s=120.0, tau_valve_s=60.0,
                           delta_t_design_c=8.0, mdot_min_kg_s=8.0,
                           mdot_max_kg_s=40.0)
    node_pw = jnp.full((64,), 900.0)
    ts = jnp.full((4,), 25.0)
    md = jnp.full((4,), 10.0)
    want = topo_ref.fused_cooling_ref(node_pw, ts, md, jnp.float32(22.0),
                                      jnp.float32(25.0), 4, p)
    got = topo_ops.fused_cooling(node_pw, ts, md, jnp.float32(22.0),
                                 jnp.float32(25.0), 4, p, use_pallas=True,
                                 interpret=True)
    for w, g in zip(want, got):
        assert g.shape == (4,)
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4)


def test_engine_fused_path_matches_plain_step(system):
    """The engine's fused no-grid cooling path must equal step() fed with
    the separate segment reduction (same math, one pass)."""
    rng = np.random.default_rng(3)
    cfg = system.cooling
    node_pw = jnp.asarray(rng.uniform(200.0, 2200.0, system.n_nodes),
                          jnp.float32)
    state = cooling.init_state(cfg)
    gh = topo_ops.group_power(node_pw, cfg.n_groups)
    s_a, out_a = cooling.step(cfg, state, gh, system.dt)
    s_b, out_b, p_it = cooling.step_from_node_power(cfg, state, node_pw,
                                                    system.dt)
    np.testing.assert_allclose(np.asarray(s_a.t_supply),
                               np.asarray(s_b.t_supply), rtol=1e-6)
    np.testing.assert_allclose(float(out_a.t_tower_return),
                               float(out_b.t_tower_return), rtol=1e-6)
    np.testing.assert_allclose(float(p_it), float(jnp.sum(node_pw)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Weather + scheduling integration.
# ---------------------------------------------------------------------------
T1 = 4 * 3600.0


def make_table(system, seed, load=1.2, trace_len=8):
    js = generate(system, WorkloadSpec(
        n_jobs=64, duration_s=T1, load=load, trace_len=trace_len,
        n_accounts=8, mean_wall_s=1800.0, seed=seed))
    js.assign_prepop_placement(0.0, system.n_nodes)
    return js.to_table(80)


def test_heat_wave_raises_tower_temps(system):
    table = make_table(system, 1)
    n_steps = int(T1 / system.dt)
    base = wx.constant_weather(n_steps, system.cooling.t_wetbulb_c)
    wave = wx.heat_wave(base, system.dt, start_s=3600.0, duration_s=7200.0,
                        peak_amp_c=10.0)
    scen = T.Scenario.make("fcfs", "first-fit")
    _, h0 = eng.simulate(system, table, scen, 0.0, T1, num_accounts=8,
                         weather=base)
    _, h1 = eng.simulate(system, table, scen, 0.0, T1, num_accounts=8,
                         weather=wave)
    # baseline equals the no-weather run (constant trace == static config)
    _, h2 = eng.simulate(system, table, scen, 0.0, T1, num_accounts=8)
    np.testing.assert_allclose(np.asarray(h0.t_tower_return),
                               np.asarray(h2.t_tower_return), rtol=1e-6)
    assert float(np.asarray(h1.t_tower_return).max()) > \
        float(np.asarray(h0.t_tower_return).max()) + 3.0
    assert float(np.asarray(h1.t_basin).max()) > \
        float(np.asarray(h0.t_basin).max()) + 3.0


def test_thermal_aware_cuts_peak_return_temp_under_heat_wave(system):
    """Acceptance: thermal_aware defers heat-dense jobs inside the soft
    band and lowers the peak tower return temperature vs FCFS under a
    heat-wave trace, without the admission gate doing the work.

    A heat-dense hog and a stream of light jobs are submitted together as
    the wave peaks; together they oversubscribe the machine, so the queue
    ORDER decides whose heat lands in the hottest hours (the same
    contention pattern as the carbon_aware test in test_grid)."""
    from repro.datasets.base import JobSet
    sysc = dataclasses.replace(
        system, cooling=dataclasses.replace(
            system.cooling, t_return_limit_c=35.0, thermal_margin_c=4.0,
            t_supply_margin_c=25.0))   # gate effectively off: policy only
    n_steps = int(T1 / sysc.dt)
    base = wx.constant_weather(n_steps, sysc.cooling.t_wetbulb_c)
    wave = wx.heat_wave(base, sysc.dt, start_s=1800.0, duration_s=10800.0,
                        peak_amp_c=14.0)
    # submitted well inside the wave so ambient alone has already pushed
    # the loop into the soft band (the basin lags the wet-bulb through the
    # passive-coupling time constant)
    n_light = 12
    submit = np.array([9000.0] + [9000.0] * n_light)
    nodes = np.array([48] + [4] * n_light, np.int64)
    wall = np.array([3600.0] + [900.0] * n_light)
    prof = np.array([[2200.0]] + [[400.0]] * n_light, np.float32)
    J = len(submit)
    js = JobSet(submit=submit, limit=wall * 1.2, wall=wall, nodes=nodes,
                priority=np.zeros(J), account=np.zeros(J, np.int64),
                rec_start=submit, power_prof=prof,
                util_prof=np.full((J, 1), 0.9, np.float32))
    table = js.to_table(16)
    scens = [T.Scenario.make("fcfs", "first-fit"),
             T.Scenario.make("thermal_aware", "first-fit",
                             thermal_weight=50.0)]
    finals, hists = eng.simulate_sweep(sysc, table, scens, 0.0, T1,
                                       num_accounts=8, weather=wave)
    t_ret = np.asarray(hists.t_tower_return)
    start = np.asarray(finals.start)
    assert start[1, 0] > start[0, 0] + sysc.dt   # hog deferred
    assert t_ret[1].max() < t_ret[0].max() - 0.1
    # weight 0 == FCFS (sanity for the sweepable knob)
    scens0 = [T.Scenario.make("fcfs", "first-fit"),
              T.Scenario.make("thermal_aware", "first-fit",
                              thermal_weight=0.0)]
    _, h0 = eng.simulate_sweep(sysc, table, scens0, 0.0, T1,
                               num_accounts=8, weather=wave)
    np.testing.assert_allclose(np.asarray(h0.power_it)[0],
                               np.asarray(h0.power_it)[1], rtol=1e-6)


def test_supply_overheat_gates_admission(system):
    """When the wave pushes supply past setpoint + margin, non-replay
    admission halts (thermal_throttled telemetry goes high) and resumes
    after the wave passes."""
    sysc = dataclasses.replace(
        system, cooling=dataclasses.replace(system.cooling,
                                            t_supply_margin_c=3.0))
    table = make_table(sysc, 3)
    n_steps = int(T1 / sysc.dt)
    base = wx.constant_weather(n_steps, sysc.cooling.t_wetbulb_c)
    wave = wx.heat_wave(base, sysc.dt, start_s=3600.0, duration_s=5400.0,
                        peak_amp_c=14.0)
    scen = T.Scenario.make("fcfs", "first-fit")
    _, hist = eng.simulate(sysc, table, scen, 0.0, T1, num_accounts=8,
                           weather=wave)
    gated = np.asarray(hist.thermal_throttled)
    assert gated.max() == 1.0          # gate engaged during the wave
    assert gated[-10:].max() == 0.0    # and released afterwards
    assert gated.sum() < len(gated)    # never permanently stuck


def test_per_scenario_weather_sweep_matches_solo_runs(system):
    """A stacked (scenario, weather) sweep row-for-row equals the same
    scenario run alone with its own trace."""
    table = make_table(system, 4)
    n_steps = int(T1 / system.dt)
    base = wx.synthetic_weather(n_steps, system.dt, seed=4)
    wave = wx.heat_wave(base, system.dt, start_s=3600.0, duration_s=7200.0,
                        peak_amp_c=8.0)
    scens = [T.Scenario.make("fcfs", "first-fit"),
             T.Scenario.make("fcfs", "first-fit")]
    finals, hists = eng.simulate_sweep(system, table, scens, 0.0, T1,
                                       num_accounts=8, weather=[base, wave])
    _, h_solo = eng.simulate(system, table, scens[1], 0.0, T1,
                             num_accounts=8, weather=wave)
    np.testing.assert_allclose(np.asarray(hists.t_tower_return)[1],
                               np.asarray(h_solo.t_tower_return), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hists.power_it)[1],
                               np.asarray(h_solo.power_it), rtol=1e-5)


def test_setpoint_delta_sweep_shifts_supply(system):
    """Scenario.setpoint_delta_c raises the effective supply setpoint in a
    vmapped sweep: warmer supply water, same schedule physics otherwise."""
    table = make_table(system, 5)
    scens = [T.Scenario.make("fcfs", "first-fit"),
             T.Scenario.make("fcfs", "first-fit", setpoint_delta_c=4.0)]
    finals, hists = eng.simulate_sweep(system, table, scens, 0.0, T1,
                                       num_accounts=8)
    ts = np.asarray(hists.t_supply_max)
    assert ts[1].mean() > ts[0].mean() + 2.0
