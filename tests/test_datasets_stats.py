"""Dataloaders, SWF round-trip, generator calibration, stats summaries."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import stats as stats_mod
from repro.core import types as T
from repro.datasets import loaders
from repro.datasets.swf import read_swf, write_swf
from repro.datasets.synthetic import WorkloadSpec, event_schedule, generate
from repro.systems.config import SYSTEMS, get_system


def test_all_loaders_produce_valid_jobsets():
    for name in ("frontier", "marconi100", "fugaku", "lassen", "adastra"):
        js = loaders.load(name, n_jobs=50, days=0.25)
        sys_ = get_system(name if name != "adastra" else "adastraMI250")
        assert len(js) == 50
        assert (js.nodes >= 1).all() and (js.nodes <= sys_.n_nodes).all()
        assert (js.wall > 0).all()
        assert (js.limit >= js.wall).all()
        assert np.isfinite(js.rec_start).all()
        # trace datasets carry time series; summary datasets scalars
        if sys_.has_traces:
            assert js.power_prof.shape[1] > 1
        else:
            assert js.power_prof.shape[1] == 1
        # recorded schedule is capacity-feasible: never more nodes in use
        # than the system has
        t_grid = np.arange(0.0, js.rec_end.max(), sys_.dt * 20)
        for t in t_grid[:30]:
            running = (js.rec_start <= t) & (js.rec_end > t)
            assert js.nodes[running].sum() <= sys_.n_nodes


def test_event_schedule_respects_capacity_and_order():
    rng = np.random.default_rng(0)
    n, N = 30, 16
    submit = np.sort(rng.uniform(0, 600, n))
    wall = np.maximum(np.round(rng.uniform(60, 600, n) / 30), 1) * 30
    nodes = rng.integers(1, N + 1, n)
    start = event_schedule(submit, wall * 2, wall, nodes, N, 30.0)
    assert np.isfinite(start).all()
    assert (start >= np.ceil(submit / 30) * 30 - 1e-6).all()
    ends = start + wall
    for t in np.unique(np.concatenate([start, ends])):
        running = (start <= t) & (ends > t)
        assert nodes[running].sum() <= N


def test_swf_roundtrip():
    js = loaders.load("lassen", n_jobs=20, days=0.2)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.swf")
        write_swf(js, path)
        back = read_swf(path)
        assert len(back) == 20
        np.testing.assert_allclose(back.wall, np.round(js.wall), atol=1.0)
        np.testing.assert_allclose(back.nodes, js.nodes)
        np.testing.assert_allclose(back.rec_start,
                                   np.round(js.rec_start), atol=2.0)


def test_generator_hits_target_load():
    sys_ = get_system("marconi100").scaled(128)
    spec = WorkloadSpec(n_jobs=400, duration_s=86400.0, load=0.7, seed=0,
                        trace_len=1)
    js = generate(sys_, spec)
    offered = (js.nodes * js.wall).sum() / (sys_.n_nodes * 86400.0)
    assert 0.4 < offered < 1.0


def test_stats_summary_fields(small_system, small_table):
    final, hist = eng.simulate(small_system, small_table,
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, 3600.0)
    s = stats_mod.summarize(small_system, small_table, final, hist)
    for k in ("jobs_completed", "avg_wait_s", "avg_turnaround_s", "awrt_s",
              "psrt_s", "avg_system_power_mw", "avg_pue",
              "total_energy_mwh", "power_swing_mw", "hist_small"):
        assert k in s
        assert np.isfinite(s[k])
    assert s["avg_pue"] > 1.0
    assert s["power_efficiency"] <= 1.0
    out = stats_mod.format_stats(s)
    assert "avg_pue" in out


def test_stats_empty_job_set(small_system, small_jobs):
    """An all-padding table (zero real jobs) summarizes to finite zeros."""
    empty = small_jobs.select(np.zeros(len(small_jobs), dtype=bool))
    table = empty.to_table(16)
    final, hist = eng.simulate(small_system, table,
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, 10 * small_system.dt)
    s = stats_mod.summarize(small_system, table, final, hist)
    assert s["jobs_completed"] == 0.0
    assert s["avg_wait_s"] == 0.0 and s["avg_turnaround_s"] == 0.0
    assert s["hist_small"] + s["hist_medium"] + s["hist_large"] == 0
    for v in s.values():
        assert np.isfinite(v)


def test_stats_all_unfinished_jobs(small_system, small_table):
    """A window shorter than any job's runtime: nothing completes, and
    the per-job means must not divide by an empty set."""
    final, hist = eng.simulate(small_system, small_table,
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, 2 * small_system.dt)
    s = stats_mod.summarize(small_system, small_table, final, hist)
    assert s["jobs_completed"] == 0.0
    assert s["avg_job_energy_j"] == 0.0 and s["avg_job_nodes"] == 0.0
    assert s["edp"] == 0.0
    assert s["avg_system_power_mw"] >= 0.0
    for v in s.values():
        assert np.isfinite(v)


def test_stats_single_interval_run(small_system, small_table):
    """One engine step: telemetry reductions over a length-1 history."""
    final, hist = eng.simulate(small_system, small_table,
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, small_system.dt)
    assert np.asarray(hist.power_total).shape[-1] == 1
    s = stats_mod.summarize(small_system, small_table, final, hist)
    assert s["power_swing_mw"] == 0.0  # max == min over one sample
    assert s["throughput_per_hour"] >= 0.0
    for v in s.values():
        assert np.isfinite(v)


def test_lm_workload_from_roofline_artifacts():
    """The AI-workload dataset ties the twin to the compiled LM layer:
    per-node power comes from each cell's roofline utilization."""
    from repro.core import engine as eng
    from repro.core import types as T
    from repro.datasets.lmjobs import generate_lm_workload

    sys_ = get_system("frontier").scaled(256)
    js = generate_lm_workload(sys_, n_jobs=60, duration_s=6 * 3600.0, seed=3)
    assert len(js) == 60
    assert (js.power_prof >= sys_.power.idle_node_w - 1e-3).all()
    assert (js.power_prof <= sys_.power.peak_node_w + 1e-3).all()
    assert len(js.arch_ids) == 60
    final, hist = eng.simulate(sys_, js.to_table(64),
                               T.Scenario.make("fcfs", "first-fit"),
                               0.0, 4 * 3600.0)
    assert float(final.completed) > 0
    assert np.isfinite(np.asarray(hist.power_total)).all()
