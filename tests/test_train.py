"""Closed ML-scheduling training loop tests (paper contribution (5),
repro.ml.train + the Scenario.alpha / JobTable.ml_basis machinery)."""
import copy

import numpy as np
import jax

from conftest import make_jobs
from repro.core import engine as eng
from repro.core import types as T
from repro.ml import scoring
from repro.ml import train as ml_train
from repro.ml.pipeline import MLSchedulerModel, attach_basis, attach_scores
from repro.systems.config import get_system

SYS = get_system("marconi100").scaled(64)
T1 = 3600.0


def _fitted(seed=7, n_jobs=90, load=1.6):
    js = make_jobs(SYS, seed=seed, n_jobs=n_jobs, load=load,
                   duration_s=T1, mean_wall_s=3600.0, prepop=False)
    model = MLSchedulerModel.fit(js, k=3, n_trees=4, depth=4, seed=0)
    return js, model


def test_score_is_linear_in_alpha():
    feats = np.abs(np.random.default_rng(0).normal(
        100.0, 50.0, (40, scoring.K_SCORE)))
    a1 = np.asarray([1.0, 0.5, 2.0, 0.1], np.float32)
    a2 = np.asarray([0.2, 1.5, 0.0, 1.0], np.float32)
    s_sum = scoring.score(feats, a1 + a2)
    s_parts = scoring.score(feats, a1) + scoring.score(feats, a2)
    np.testing.assert_allclose(np.asarray(s_sum), np.asarray(s_parts),
                               rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(scoring.score(feats, a1)),
        np.asarray(scoring.basis(feats)) @ a1, rtol=1e-5)


def test_alpha_scenario_matches_baked_score_static_parity():
    """Scenario.alpha on a basis table == attach_scores + simulate_static:
    the traced parameterization reproduces the legacy path bit-for-bit."""
    js, model = _fitted()
    js_baked = copy.deepcopy(js)
    attach_scores(js_baked, model)
    t_baked = js_baked.to_table()
    js_basis = copy.deepcopy(js)
    attach_basis(js_basis, model)
    t_basis = js_basis.to_table()

    f_static, h_static = eng.simulate_static(SYS, t_baked, "ml",
                                             "first-fit", 0.0, T1)
    f_alpha, h_alpha = eng.simulate(
        SYS, t_basis,
        T.Scenario.make("ml", "first-fit", alpha=np.asarray(model.alpha)),
        0.0, T1)
    np.testing.assert_array_equal(np.asarray(f_static.jstate),
                                  np.asarray(f_alpha.jstate))
    np.testing.assert_allclose(np.asarray(f_static.start),
                               np.asarray(f_alpha.start))
    np.testing.assert_allclose(np.asarray(h_static.power_it),
                               np.asarray(h_alpha.power_it))


def test_neutral_alpha_keeps_legacy_ranking():
    """alpha=0 on a basis-carrying table must not disturb non-ml policies
    (and leaves the ml key at the baked score)."""
    js, model = _fitted()
    attach_basis(js, model)
    table = js.to_table()
    f1, _ = eng.simulate(SYS, table, T.Scenario.make("fcfs", "first-fit"),
                         0.0, T1)
    f2, _ = eng.simulate_static(SYS, table, "fcfs", "first-fit", 0.0, T1)
    np.testing.assert_array_equal(np.asarray(f1.jstate),
                                  np.asarray(f2.jstate))


def test_es_generation_is_seeded_deterministic():
    """Same seed -> bit-identical candidates, rewards and updated mean."""
    js, model = _fitted()
    attach_basis(js, model)
    table = js.to_table()
    runs = []
    for _ in range(2):
        res = ml_train.train(SYS, table, 0.0, T1, reward="wait=1",
                             generations=1, population=4, sigma=0.3,
                             lr=0.5, seed=123, checkpoint=None, log=None)
        runs.append(res)
    np.testing.assert_array_equal(runs[0].mu, runs[1].mu)
    assert runs[0].reward_best == runs[1].reward_best
    assert runs[0].history[0]["reward_mu"] == runs[1].history[0]["reward_mu"]


def test_antithetic_population_structure():
    rng = np.random.default_rng(0)
    mu = np.asarray([1.0, 1.0, 1.0, 0.5])
    pop = ml_train.antithetic_population(mu, 0.3, rng, 8)
    assert pop.shape == (8, 4)
    # antithetic pairing: row i and row i+4 mirror around mu
    np.testing.assert_allclose(pop[:4] + pop[4:],
                               np.broadcast_to(2 * mu, (4, 4)), atol=1e-6)


def test_centered_ranks_and_es_update_direction():
    """The ES step must move mu toward the better antithetic twin."""
    mu = np.zeros(2)
    eps = np.asarray([[1.0, 0.0]])
    cands = np.concatenate([mu + 0.5 * eps, mu - 0.5 * eps], 0)
    # +eps wins -> mu should move in +eps direction
    new = ml_train.es_update(mu, cands, np.asarray([1.0, 0.0]), 0.5, 1.0)
    assert new[0] > 0.0 and abs(new[1]) < 1e-12
    u = ml_train.centered_ranks(np.asarray([3.0, -1.0, 7.0]))
    assert u.min() == -0.5 and u.max() == 0.5 and abs(u.sum()) < 1e-12


def test_trained_alpha_beats_default_on_its_objective():
    """Reward monotonicity: the elite returned by train() achieves at
    least the hand-set DEFAULT_ALPHA's reward on the training objective
    (the baseline rides in every batched generation), and on this seeded
    workload strictly improves it."""
    from repro.datasets.loaders import load_marconi100
    js = load_marconi100(n_jobs=90, days=0.1, seed=0)
    js = js.select(np.asarray(js.nodes) <= SYS.n_nodes)  # as the CLI does
    model = MLSchedulerModel.fit(js, k=4, n_trees=6, depth=5, seed=0)
    attach_basis(js, model)
    js.assign_prepop_placement(0.0, SYS.n_nodes)
    table = js.to_table()
    res = ml_train.train(SYS, table, 0.0, 7200.0,
                         reward="wait=1,turnaround=0.5", generations=3,
                         population=8, sigma=0.35, lr=0.8, seed=0,
                         checkpoint=None, log=None)
    assert res.reward_best >= res.reward_default
    assert res.reward_best > res.reward_default, \
        "ES failed to improve on the default alpha on the seeded workload"
    # baseline normalization: the default-alpha reward is exactly -sum(w)
    assert abs(res.reward_default - (-1.5)) < 1e-9


def test_one_generation_is_one_batched_rollout():
    """No Python loop over candidates: a generation with population P
    enters the engine exactly once (population + mean + baseline rows on
    the scenario axis of a single sweep)."""
    js, model = _fitted()
    attach_basis(js, model)
    table = js.to_table()
    calls = []
    orig = eng.simulate_sweep

    def spy(system, table_, scens, *a, **kw):
        calls.append(len(scens))
        return orig(system, table_, scens, *a, **kw)

    old_sharded = eng.simulate_sweep_sharded
    try:
        eng.simulate_sweep = spy
        # sharded falls through to simulate_sweep on one device; spy both
        eng.simulate_sweep_sharded = spy
        ml_train.train(SYS, table, 0.0, T1, reward="wait=1",
                       generations=2, population=6, sigma=0.3, lr=0.5,
                       seed=0, checkpoint=None, log=None)
    finally:
        eng.simulate_sweep = orig
        eng.simulate_sweep_sharded = old_sharded
    assert calls == [8, 8]   # one rollout per generation, P + 2 rows each


def test_checkpoint_resume_roundtrip(tmp_path):
    """A resumed run continues the trajectory exactly where it stopped."""
    js, model = _fitted()
    attach_basis(js, model)
    table = js.to_table()
    ck = tmp_path / "ck.json"
    kw = dict(reward="wait=1", population=4, sigma=0.3, lr=0.5, seed=5,
              log=None)
    full = ml_train.train(SYS, table, 0.0, T1, generations=3,
                          checkpoint=None, **kw)
    ml_train.train(SYS, table, 0.0, T1, generations=2, checkpoint=ck, **kw)
    resumed = ml_train.train(SYS, table, 0.0, T1, generations=3,
                             checkpoint=ck, resume=True, **kw)
    np.testing.assert_allclose(resumed.mu, full.mu, rtol=1e-12)
    assert resumed.reward_best == full.reward_best
    assert ml_train.load_alpha(ck).shape == (scoring.K_SCORE,)


def test_reward_spec_parsing():
    r = ml_train.Reward.parse("wait=2, energy=0.5 ,pue")
    assert dict(r.weights) == {"wait": 2.0, "energy": 0.5, "pue": 1.0}
    import pytest
    with pytest.raises(ValueError):
        ml_train.Reward.parse("no_such_metric=1")
    with pytest.raises(ValueError):
        ml_train.Reward.parse("")


def test_train_cli_smoke_improves_reward(tmp_path):
    """`simulate train --smoke` end to end: asserts internally that the
    trained reward improves on the default alpha and writes a checkpoint."""
    from repro.launch import simulate as cli
    ck = tmp_path / "smoke.json"
    res = cli.main(["train", "--smoke", "--checkpoint", str(ck)])
    assert res.reward_best > res.reward_default
    assert ck.exists()
    # the checkpointed elite reloads to the same alpha the run returned
    np.testing.assert_allclose(ml_train.load_alpha(ck), res.alpha,
                               rtol=1e-6)


def test_sweep_population_rows_are_independent():
    """Batched rows match solo runs: evaluating [a_default, a_other] in
    one sweep gives the same telemetry as two single simulations."""
    js, model = _fitted()
    attach_basis(js, model)
    table = js.to_table()
    a0 = np.asarray(model.alpha)
    a1 = np.asarray([2.0, 0.2, 0.4, 1.5], np.float32)
    finals, hists = eng.simulate_sweep(
        SYS, table,
        [T.Scenario.make("ml", "first-fit", alpha=a0),
         T.Scenario.make("ml", "first-fit", alpha=a1)], 0.0, T1)
    for i, a in enumerate([a0, a1]):
        f_solo, h_solo = eng.simulate(
            SYS, table, T.Scenario.make("ml", "first-fit", alpha=a),
            0.0, T1)
        np.testing.assert_allclose(
            np.asarray(hists.power_it)[i], np.asarray(h_solo.power_it))
        pick = jax.tree_util.tree_map(lambda x, i=i: x[i], finals)
        np.testing.assert_array_equal(np.asarray(pick.jstate),
                                      np.asarray(f_solo.jstate))
