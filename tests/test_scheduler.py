"""Unit tests for policy keys, queue ordering, EASY shadow machinery."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import engine as eng
from repro.core import scheduler as sched
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system


@pytest.fixture(scope="module")
def system():
    return get_system("marconi100").scaled(32)


def make_table(system, **kw):
    spec = WorkloadSpec(n_jobs=40, duration_s=7200.0, trace_len=4,
                        seed=kw.pop("seed", 1), **kw)
    return generate(system, spec).to_table()


def test_policy_key_orderings(system):
    table = make_table(system)
    accounts = T.AccountStats.zeros(64)
    for name, expect in [
        ("fcfs", np.asarray(table.submit)),
        ("sjf", np.asarray(table.limit)),
        ("ljf", -np.asarray(table.nodes, np.float64)),
        ("priority", -np.asarray(table.priority)),
    ]:
        scen = T.Scenario.make(name)
        key = np.asarray(sched.policy_key(table, accounts, scen))
        np.testing.assert_allclose(key, expect.astype(np.float32), rtol=1e-6)


def test_queue_order_puts_eligible_first(system):
    table = make_table(system)
    st = eng.init_state(system, table, 0.0, 7200.0)
    # force a known time so some jobs are queued
    st = T.SimState(**{**vars(st), "t": jnp.float32(1800.0),
                       "jstate": jnp.where(table.submit <= 1800.0,
                                           T.QUEUED, T.PENDING)})
    order, elig = sched.queue_order(table, st, st.accounts,
                                    T.Scenario.make("fcfs"))
    order = np.asarray(order)
    elig = np.asarray(elig)
    n = elig.sum()
    assert elig[order[:n]].all()
    assert not elig[order[n:]].any()
    submits = np.asarray(table.submit)[order[:n]]
    assert (np.diff(submits) >= 0).all()


def test_shadow_time_computation(system):
    """Craft a running set and verify the EASY shadow: 3 running jobs
    releasing 8 nodes each at t=100/200/300; free=4. A job needing 16 nodes
    waits until t=200 (4+8+8 >= 16); extra = 4."""
    table = make_table(system)
    J = table.num_jobs
    jstate = jnp.full((J,), T.DISMISSED, jnp.int32)
    end = jnp.full((J,), jnp.inf, jnp.float32)
    nodes = np.asarray(table.nodes).copy()
    limit = np.asarray(table.limit).copy()
    for i, e in enumerate([100.0, 200.0, 300.0]):
        jstate = jstate.at[i].set(T.RUNNING)
        end = end.at[i].set(e)
        nodes[i] = 8
    table2 = T.JobTable(**{**vars(table),
                           "nodes": jnp.asarray(nodes, jnp.int32),
                           "limit": jnp.asarray(limit)})
    st = eng.init_state(system, table2, 0.0, 7200.0)
    st = T.SimState(**{**vars(st), "jstate": jstate,
                       "start": jnp.where(end < jnp.inf, 0.0, jnp.inf),
                       "end": end})
    # release profile uses start+limit as the EASY estimate; set limit=end
    limit[:3] = [100.0, 200.0, 300.0]
    table3 = T.JobTable(**{**vars(table2), "limit": jnp.asarray(
        limit, jnp.float32)})
    end_sorted, cum = sched.release_profile(table3, st)
    shadow_t, extra = sched.shadow_for(end_sorted, cum, jnp.int32(4),
                                       jnp.int32(16))
    assert float(shadow_t) == 200.0
    assert int(extra) == 4


def test_easy_never_delays_head_job(system):
    """The head job's start under fcfs-easy must not be later than under
    fcfs-nobf (EASY's defining property, given truthful limits)."""
    spec = WorkloadSpec(n_jobs=60, duration_s=7200.0, load=1.8, trace_len=4,
                        mean_wall_s=1800.0, seed=5, max_frac_nodes=0.6)
    js = generate(system, spec)
    # truthful limits: EASY's no-delay guarantee assumes limit == wall
    js.limit = js.wall.copy()
    table = js.to_table()
    f_none, _ = eng.simulate(system, table, T.Scenario.make("fcfs", "none"),
                             0.0, 7200.0)
    f_easy, _ = eng.simulate(system, table, T.Scenario.make("fcfs", "easy"),
                             0.0, 7200.0)
    s_none = np.asarray(f_none.start)
    s_easy = np.asarray(f_easy.start)
    started_both = np.isfinite(s_none) & np.isfinite(s_easy)
    # identify head jobs: in FCFS order, jobs that were delayed by capacity
    # under no-backfill. EASY must start them no later.
    assert (s_easy[started_both] <= s_none[started_both] + 1e-3).all()


def test_account_policy_uses_ledger(system):
    table = make_table(system)
    accounts = T.AccountStats.zeros(64)
    # account 0: high power, account 1: low power
    accounts = T.AccountStats(**{**vars(accounts),
                                 "power_sum": accounts.power_sum.at[0]
                                 .set(1000.0).at[1].set(10.0),
                                 "jobs_done": accounts.jobs_done.at[0]
                                 .set(1.0).at[1].set(1.0)})
    scen_hi = T.Scenario.make("acct_avg_power")
    scen_lo = T.Scenario.make("acct_low_avg_power")
    k_hi = np.asarray(sched.policy_key(table, accounts, scen_hi))
    k_lo = np.asarray(sched.policy_key(table, accounts, scen_lo))
    acct = np.asarray(table.account)
    if (acct == 0).any() and (acct == 1).any():
        j0 = np.nonzero(acct == 0)[0][0]
        j1 = np.nonzero(acct == 1)[0][0]
        assert k_hi[j0] < k_hi[j1]   # high-power account first
        assert k_lo[j1] < k_lo[j0]   # low-power account first
