"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU, asserting output shapes
and no NaNs; plus prefill/forward logits consistency and a decode step."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import arch_names, get_config
from repro.models.common import split_tree
from repro.models.zoo import get_api


def make_batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, 16, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", arch_names())
def test_forward_and_grad_finite(name, rng):
    cfg = get_config(name + "-smoke")
    api = get_api(cfg)
    params, _ = split_tree(api.init(rng))
    batch = make_batch(cfg, rng)
    logits = api.forward(params, batch)
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1]
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.value_and_grad(lambda p: api.loss(p, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", arch_names())
def test_prefill_matches_forward_last_logits(name, rng):
    """prefill's returned logits must equal forward's last-position logits
    (same math, different caching path) — strong serving-path check."""
    cfg = get_config(name + "-smoke")
    api = get_api(cfg)
    params, _ = split_tree(api.init(rng))
    batch = make_batch(cfg, rng)
    full = api.forward(params, batch)
    pre, state = api.prefill(params, batch, max_len=48)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", arch_names())
def test_decode_step_advances_state(name, rng):
    cfg = get_config(name + "-smoke")
    api = get_api(cfg)
    params, _ = split_tree(api.init(rng))
    batch = make_batch(cfg, rng)
    logits, state = api.prefill(params, batch, max_len=48)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    l1, state = api.decode(params, tok, state)
    l2, state = api.decode(params, jnp.argmax(l1, -1).astype(jnp.int32),
                           state)
    assert l1.shape == l2.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(l1).all()) and bool(jnp.isfinite(l2).all())
    assert int(state.pos) == batch["tokens"].shape[1] + 2 if \
        cfg.family != "vlm" else True


@pytest.mark.parametrize("name", ["rwkv6-7b", "zamba2-7b"])
def test_recurrent_decode_matches_teacher_forcing(name, rng):
    """For the stateful families, decoding token-by-token must reproduce the
    teacher-forced forward logits (recurrence <-> chunked equivalence)."""
    cfg = get_config(name + "-smoke")
    api = get_api(cfg)
    params, _ = split_tree(api.init(rng))
    B, S = 1, 8
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    full = api.forward(params, {"tokens": tokens})   # [B, S, V]
    # decode positions 1..S-1 from scratch state
    state = api.init_cache(B, 16, pos=0)
    logits = []
    for t in range(S):
        lg, state = api.decode(params, tokens[:, t], state)
        logits.append(lg)
    dec = jnp.stack(logits, axis=1)                  # [B, S, V]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=4e-3, atol=4e-3)


def test_param_counts_match_full_configs():
    """Analytic param counts should be in the right ballpark for the
    headline sizes (sanity on config dims)."""
    expect = {
        "rwkv6-7b": (6e9, 9e9),
        "mistral-nemo-12b": (10e9, 14e9),
        "phi3-medium-14b": (12e9, 16e9),
        "yi-9b": (8e9, 10e9),
        "qwen2.5-3b": (2.5e9, 4e9),
        "mixtral-8x7b": (42e9, 50e9),
        "llama4-maverick-400b-a17b": (350e9, 440e9),
        "zamba2-7b": (6e9, 9e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count
        assert lo <= n <= hi, (name, n)
    # MoE active params
    mix = get_config("mixtral-8x7b")
    assert 10e9 < mix.active_param_count < 16e9
    mav = get_config("llama4-maverick-400b-a17b")
    assert 9e9 < mav.active_param_count < 25e9
