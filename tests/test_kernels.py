"""Per-kernel allclose tests: Pallas (interpret=True) and the chunked jnp
paths against the pure-jnp oracles, swept over shapes and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention, mha_ref
from repro.kernels.mamba2_ssd import ssd_chunked, ssd_ref
from repro.kernels.mamba2_ssd.mamba2_ssd import ssd_pallas
from repro.kernels.power_topo import group_power, group_power_ref
from repro.kernels.rwkv6_wkv import wkv_chunked, wkv_ref
from repro.kernels.rwkv6_wkv.rwkv6_wkv import wkv_pallas


# ---------------------------------------------------------------------------
# power_topo
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_nodes,n_groups", [(64, 4), (980, 10),
                                              (356, 4), (129, 7)])
@pytest.mark.parametrize("batch", [None, 3])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_power_topo_pallas_vs_ref(n_nodes, n_groups, batch, dtype):
    rng = np.random.default_rng(0)
    shape = (n_nodes,) if batch is None else (batch, n_nodes)
    x = jnp.asarray(rng.uniform(100, 2000, shape), dtype)
    ref = group_power_ref(x if batch else x[None])[
        0] if False else group_power(x, n_groups, use_pallas=False)
    out = group_power(x, n_groups, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_power_topo_group_sums_conserve_total():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(0, 1000, (5, 200)), jnp.float32)
    g = group_power(x, 8)
    np.testing.assert_allclose(np.asarray(g.sum(-1)), np.asarray(x.sum(-1)),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------
def _wkv_inputs(B, S, H, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = (jax.random.normal(ks[0], (B, S, H, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, H, hd)) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd)).astype(dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd)) - 1.0)
         * 0.97 + 0.02).astype(dtype)
    u = (jax.random.normal(ks[4], (H, hd)) * 0.3).astype(jnp.float32)
    return r, k, v, w, u


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 32, 1, 8, 8), (2, 64, 3, 16, 16), (1, 128, 2, 64, 32),
])
def test_wkv_chunked_vs_ref(B, S, H, hd, chunk):
    r, k, v, w, u = _wkv_inputs(B, S, H, hd, jnp.float32)
    y0, s0 = wkv_ref(r, k, v, w, u)
    y1, s1 = wkv_chunked(r, k, v, w, u, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,S,H,hd,chunk", [(2, 64, 2, 16, 16),
                                            (1, 64, 4, 64, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv_pallas_vs_ref(B, S, H, hd, chunk, dtype):
    r, k, v, w, u = _wkv_inputs(B, S, H, hd, dtype)
    y0, _ = wkv_ref(r, k, v, w, u)
    y2, _ = wkv_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(y2, np.float32),
                               np.asarray(y0, np.float32),
                               rtol=tol, atol=tol)


def test_wkv_strong_decay_is_stable():
    """Log-space chunking must survive near-zero decay (strong forgetting)."""
    B, S, H, hd = 1, 64, 1, 8
    r, k, v, w, u = _wkv_inputs(B, S, H, hd, jnp.float32)
    w = jnp.full_like(w, 1e-6)
    y0, _ = wkv_ref(r, k, v, w, u)
    y1, _ = wkv_chunked(r, k, v, w, u, 16)
    assert np.isfinite(np.asarray(y1)).all()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)


# ---------------------------------------------------------------------------
# mamba2 ssd
# ---------------------------------------------------------------------------
def _ssd_inputs(Bz, S, H, P, N, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (Bz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S, H)))
    a = jnp.exp(-jax.nn.softplus(jax.random.normal(ks[2], (Bz, S, H))))
    B = jax.random.normal(ks[3], (Bz, S, N)) * 0.5
    C = jax.random.normal(ks[4], (Bz, S, N)) * 0.5
    return x, dt, a, B, C


@pytest.mark.parametrize("Bz,S,H,P,N,chunk", [
    (1, 32, 1, 8, 4, 8), (2, 128, 3, 16, 8, 32), (1, 64, 2, 64, 64, 64),
])
def test_ssd_chunked_vs_ref(Bz, S, H, P, N, chunk):
    x, dt, a, B, C = _ssd_inputs(Bz, S, H, P, N)
    y0, s0 = ssd_ref(x, dt, a, B, C)
    y1, s1 = ssd_chunked(x, dt, a, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("Bz,S,H,P,N,chunk", [(2, 64, 2, 16, 8, 32),
                                              (1, 128, 2, 64, 64, 64)])
def test_ssd_pallas_vs_ref(Bz, S, H, P, N, chunk):
    x, dt, a, B, C = _ssd_inputs(Bz, S, H, P, N)
    y0, _ = ssd_ref(x, dt, a, B, C)
    y2 = ssd_pallas(x, dt, a, B, C, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y0),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
def _attn_inputs(B, S, T, H, KV, hd, dtype, seed=2):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, KV, hd)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk", [
    (1, 128, 2, 2, 64, 64, 64),     # MHA
    (2, 256, 4, 2, 32, 128, 128),   # GQA
    (1, 256, 8, 2, 64, 64, 128),    # GQA, rectangular blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_vs_ref(B, S, H, KV, hd, bq, bk, dtype):
    q, k, v = _attn_inputs(B, S, S, H, KV, hd, dtype)
    ref = mha_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, True, 0, bq, bk, True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_sliding_window():
    q, k, v = _attn_inputs(1, 256, 256, 2, 2, 32, jnp.float32)
    ref = mha_ref(q, k, v, causal=True, window=64)
    out = flash_attention(q, k, v, True, 64, 64, 64, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    q, k, v = _attn_inputs(1, 128, 128, 2, 2, 32, jnp.float32)
    ref = mha_ref(q, k, v, causal=False)
    out = flash_attention(q, k, v, False, 0, 64, 64, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
