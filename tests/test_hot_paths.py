"""Scan-loop roofline paths: donated carries, compact job tables, and
the env-preset audit trail.

Buffer donation lets XLA write each scan step's carry in place instead
of allocating a fresh state tree per segment — but a donated input is
*consumed*, so every resume path must hand the runner a buffer it is
allowed to lose. These tests pin the contract: donation changes nothing
numerically, resume-from-segment stays bit-identical, the
``REPRO_NO_DONATE`` kill switch works, and the serve layer's checkpoint
templates survive their carries being eaten.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system

SYS = get_system("marconi100").scaled(32)


def make_table(seed=0, n=24, hours=1.0):
    js = generate(SYS, WorkloadSpec(n_jobs=n, duration_s=hours * 3600.0,
                                    load=1.2, trace_len=4, seed=seed))
    return js, js.to_table()


def tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(la, lb))


def test_donation_enabled_by_default_and_killable():
    assert eng.DONATE_CARRIES is True
    assert eng._donate(1) == (1,)
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.core import engine as e; "
         "assert e.DONATE_CARRIES is False; "
         "assert e._donate(1) == ()"],
        env={**os.environ, "REPRO_NO_DONATE": "1",
             "PYTHONPATH": os.environ.get("PYTHONPATH", "src")},
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr


def test_segment_resume_bit_identical_to_unsegmented_run():
    """Two 30-step segments (carry donated between them) must equal one
    60-step run exactly — donation may not perturb a single bit."""
    _, table = make_table(seed=11)
    scen = T.Scenario.make("fcfs", "easy")
    n = 60
    t1 = n * SYS.dt
    whole = eng.init_state(SYS, table, 0.0, t1)
    whole, _ = eng.simulate_segment(SYS, table, whole, scen, 30)
    whole, hist2 = eng.simulate_segment(SYS, table, whole, scen, 30)

    ref = eng.init_state(SYS, table, 0.0, t1)
    ref, _ = eng.simulate_segment(SYS, table, ref, scen, n)
    assert tree_equal(whole, ref)
    assert float(whole.t) == float(ref.t)
    # the returned history covers the second half only
    assert np.asarray(hist2.power_total).shape[0] == 30


def test_donated_carry_is_consumed_and_copies_protect_it():
    """The perf contract made visible: after a segment run the input
    carry's buffers are gone (donated), and tree_map(copy) is the
    documented way to keep a live reference."""
    _, table = make_table(seed=12)
    scen = T.Scenario.make("fcfs", "easy")
    carry = eng.init_state(SYS, table, 0.0, 8 * SYS.dt)
    keep = jax.tree_util.tree_map(jnp.copy, carry)
    out, _ = eng.simulate_segment(SYS, table, carry, scen, 8)
    assert bool(carry.t.is_deleted()), \
        "carry not donated — the in-place scan path regressed"
    # the copy is untouched and resumable
    assert float(keep.t) == 0.0
    out2, _ = eng.simulate_segment(SYS, table, keep, scen, 8)
    assert tree_equal(out, out2)


def test_simulate_and_static_unaffected_by_donation():
    _, table = make_table(seed=13)
    scen = T.Scenario.make("fcfs", "easy")
    t1 = 40 * SYS.dt
    f1, h1 = eng.simulate(SYS, table, scen, 0.0, t1)
    f2, h2 = eng.simulate(SYS, table, scen, 0.0, t1)
    assert tree_equal(f1, f2) and tree_equal(h1, h2)
    s1 = eng.simulate_static(SYS, table, "fcfs", "first-fit", 0.0, t1)
    s2 = eng.simulate_static(SYS, table, "fcfs", "first-fit", 0.0, t1)
    assert tree_equal(s1[0], s2[0])


def test_warm_start_accounts_survive_two_donated_runs():
    """A caller-owned ledger passed via ``accounts=`` must not be eaten
    by donation: ``init_state`` copies it into the carry, so the same
    ledger can seed back-to-back runs (the collect-then-redeem flow)."""
    _, table = make_table(seed=5)
    final, _ = eng.simulate(SYS, table, T.Scenario.make("replay"),
                            0.0, 1800.0, num_accounts=4)
    acc = final.accounts
    f1, _ = eng.simulate(SYS, table, T.Scenario.make("fcfs", "easy"),
                         0.0, 1800.0, accounts=acc, num_accounts=4)
    f2, _ = eng.simulate(SYS, table, T.Scenario.make("fcfs", "easy"),
                         0.0, 1800.0, accounts=acc, num_accounts=4)
    assert not any(x.is_deleted() for x in jax.tree_util.tree_leaves(acc))
    assert tree_equal(f1.accounts, f2.accounts)


def test_account_ledger_leaves_are_distinct_buffers():
    """Donation requires every carry leaf to own its buffer; the ledger
    zeros must not alias one shared array across fields."""
    import dataclasses
    zeros = T.AccountStats.zeros(4)
    ptrs = set()
    for f in dataclasses.fields(T.AccountStats):
        leaf = getattr(zeros, f.name)
        ptrs.add(leaf.unsafe_buffer_pointer())
    assert len(ptrs) == len(dataclasses.fields(T.AccountStats)), \
        "AccountStats.zeros shares a buffer between fields"


# ---------------------------------------------------------------------------
# Compact job tables (int32 time columns behind the compat flag).
# ---------------------------------------------------------------------------
def test_compact_time_table_is_bit_compatible_end_to_end():
    js, _ = make_table(seed=14)
    # SWF contract: whole seconds
    for f in ("submit", "limit", "wall", "rec_start"):
        setattr(js, f, np.round(getattr(js, f)))
    t_f32 = js.to_table()
    t_i32 = js.to_table(compact_time=True)
    for f in ("submit", "limit", "wall", "rec_start"):
        assert getattr(t_i32, f).dtype == jnp.int32
    scen = T.Scenario.make("fcfs", "easy")
    t1 = 48 * SYS.dt
    f_a, h_a = eng.simulate(SYS, t_f32, scen, 0.0, t1)
    f_b, h_b = eng.simulate(SYS, t_i32, scen, 0.0, t1)
    assert tree_equal(f_a, f_b)
    assert tree_equal(h_a, h_b)


def test_compact_time_falls_back_to_f32_on_fractional_columns():
    js, _ = make_table(seed=15)
    js.submit = np.round(js.submit) + 0.25       # not whole seconds
    js.wall = np.round(js.wall)
    table = js.to_table(compact_time=True)
    assert table.submit.dtype == jnp.float32     # fell back
    assert table.wall.dtype == jnp.int32         # still narrowed
    # padded +inf spelling: sentinel on the int column, far past any t1
    padded = js.to_table(pad_to=len(js) + 3, compact_time=True)
    assert int(np.asarray(padded.rec_start)[-1]) == 1 << 30


# ---------------------------------------------------------------------------
# Env preset: report-only, embedded in manifests.
# ---------------------------------------------------------------------------
def test_env_preset_report_and_manifest_embedding(tmp_path):
    from repro.launch import env as launch_env
    from repro.obs import recorder as rec
    from repro.obs import schema

    rep = launch_env.report("throughput")
    assert rep["preset"] == "throughput"
    assert "XLA_FLAGS" in rep["recommended"]
    assert rep["allocator"] in ("tcmalloc", "jemalloc", "glibc",
                                "unknown")
    m = rec.build_manifest(SYS, "simulate", ["bench"], {},
                           extra={"env_preset": rep})
    assert m["env_preset"]["preset"] == "throughput"
    schema.validate_manifest(m)                  # extra keys validate

    import json
    json.dumps(m)                                # and serialize

    import pytest
    with pytest.raises(KeyError):
        launch_env.preset("nope")
    # apply() never clobbers what the user already exported
    os.environ["XLA_FLAGS"] = "--user-set"
    try:
        written = launch_env.apply("throughput")
        assert "XLA_FLAGS" not in written
        assert os.environ["XLA_FLAGS"] == "--user-set"
    finally:
        del os.environ["XLA_FLAGS"]
