"""Incentive structures (paper §4.3): collection + redeeming phases."""
import numpy as np
import jax.numpy as jnp

from repro.core import accounts as acct_mod
from repro.core import engine as eng
from repro.core import types as T
from repro.core.incentives import fugaku_points
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system

SYS = get_system("marconi100").scaled(64)


def test_fugaku_points_reward_low_power():
    nh = jnp.asarray([10.0, 10.0])
    pts = fugaku_points(SYS, nh, jnp.asarray([SYS.power.ref_node_w * 0.5,
                                              SYS.power.ref_node_w * 1.5]))
    assert float(pts[0]) > 0.0
    assert float(pts[1]) == 0.0   # above reference earns nothing


def test_collection_then_redeem_reorders_accounts():
    """Collection run accumulates per-account stats; redeeming with
    acct_fugaku_pts prioritizes the frugal account's jobs (Fig. 8)."""
    spec = WorkloadSpec(n_jobs=120, duration_s=6 * 3600.0, load=1.5,
                        trace_len=4, n_accounts=6, seed=11)
    js = generate(SYS, spec)
    table = js.to_table()
    final, _ = eng.simulate(SYS, table, T.Scenario.make("replay"),
                            0.0, 6 * 3600.0, num_accounts=6)
    acc = final.accounts
    jd = np.asarray(acc.jobs_done)
    assert jd.sum() > 10
    pts = np.asarray(acc.fugaku_pts)
    avg_pw = np.asarray(acc.power_sum) / np.maximum(jd, 1)

    # accounts persist and reload (paper --accounts / --accounts-json)
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "accounts.json")
        acct_mod.save_json(acc, path)
        acc2 = acct_mod.load_json(path)
        np.testing.assert_allclose(np.asarray(acc2.fugaku_pts), pts)

    # redeem: frugal accounts (more pts) wait less than low-point accounts,
    # and the advantage flips relative to the fcfs baseline
    def acct_waits(policy):
        f, _ = eng.simulate(SYS, table,
                            T.Scenario.make(policy, "first-fit"),
                            0.0, 6 * 3600.0, accounts=acc, num_accounts=6)
        start = np.asarray(f.start)[:len(js)]
        started = np.isfinite(start)
        wait = start - js.submit
        hi = np.argsort(-pts)[:2]
        lo = np.argsort(-pts)[-2:]
        m_hi = started & np.isin(js.account, hi)
        m_lo = started & np.isin(js.account, lo)
        return wait[m_hi].mean(), wait[m_lo].mean()

    w_hi, w_lo = acct_waits("acct_fugaku_pts")
    assert w_hi < w_lo, "high-point accounts must wait less when redeeming"
    w_hi_f, w_lo_f = acct_waits("fcfs")
    # redeeming must improve the favored accounts' relative position vs fcfs
    assert (w_hi - w_lo) < (w_hi_f - w_lo_f)


def test_fold_completions_matches_manual():
    spec = WorkloadSpec(n_jobs=30, duration_s=3600.0, trace_len=4,
                        n_accounts=4, seed=2)
    js = generate(SYS, spec)
    table = js.to_table()
    final, _ = eng.simulate(SYS, table, T.Scenario.make("fcfs", "first-fit"),
                            0.0, 3600.0, num_accounts=4)
    done = np.asarray(final.jstate)[:len(js)] == T.DONE
    nh_manual = (js.nodes * js.wall / 3600.0)[done]
    by_acct = np.zeros(4)
    for a, v in zip(js.account[done], nh_manual):
        by_acct[a] += v
    np.testing.assert_allclose(np.asarray(final.accounts.node_hours),
                               by_acct, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(float(np.asarray(
        final.accounts.jobs_done).sum()), done.sum())
