"""External-scheduler integration tests (paper §4.2): plugin + sequential."""
import numpy as np

from repro.core import external as ext
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system

SYS = get_system("frontier").scaled(64)


def make_jobs(seed=0, n=40):
    spec = WorkloadSpec(n_jobs=n, duration_s=2 * 3600.0, load=1.2,
                        trace_len=4, seed=seed)
    return generate(SYS, spec)


def test_fastsim_like_sequential_mode():
    js = make_jobs()
    sched = ext.FastSimLike(policy="fcfs", backfill="firstfit")
    final, hist = ext.run_sequential_mode(SYS, js, sched, 0.0, 2 * 3600.0)
    assert float(final.completed) > 0
    p = np.asarray(hist.power_it)
    assert p.shape[0] == int(2 * 3600.0 / SYS.dt)
    assert (p > 0).all()


def test_plugin_mode_tracks_external_running_set():
    js = make_jobs(seed=3)
    sched = ext.FastSimLike(policy="sjf", backfill="firstfit")
    final, hist, wall = ext.run_plugin_mode(SYS, js, sched, 0.0, 3600.0)
    jstate = np.asarray(final.jstate)[:len(js)]
    # the twin executed at least the jobs the external scheduler started
    want = set(np.nonzero(sched.start <= 3600.0 - SYS.dt)[0].tolist())
    got = set(np.nonzero(jstate >= T.RUNNING)[0].tolist())
    missing = want - got - set(np.nonzero(~np.isfinite(sched.start))[0].tolist())
    assert len(missing) <= max(1, len(want) // 10)


def test_plugin_and_sequential_agree_on_power():
    """Plugin mode and sequential mode couple the same schedule, so the
    simulated power histories should agree closely (paper §4.2.2)."""
    js = make_jobs(seed=5)
    s1 = ext.FastSimLike(policy="fcfs", backfill="firstfit")
    s2 = ext.FastSimLike(policy="fcfs", backfill="firstfit")
    _, h_seq = ext.run_sequential_mode(SYS, js, s1, 0.0, 3600.0)
    _, h_plug, _ = ext.run_plugin_mode(SYS, js, s2, 0.0, 3600.0)
    p_seq = np.asarray(h_seq.power_it)
    p_plug = np.asarray(h_plug["power_it"])
    # mean power within a few percent (placement-order effects allowed)
    assert abs(p_seq.mean() - p_plug.mean()) / p_seq.mean() < 0.05


def test_scheduleflow_like_recomputes_every_poll():
    js = make_jobs(seed=7, n=20)
    sched = ext.ScheduleFlowLike()
    final, hist, wall = ext.run_plugin_mode(SYS, js, sched, 0.0, 1800.0)
    n_steps = int(1800.0 / SYS.dt)
    # the paper's observed overhead: a full recompute per trigger
    assert sched.recompute_count == n_steps
    assert float(final.completed) >= 0
