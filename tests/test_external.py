"""External-scheduler integration tests (paper §4.2): plugin + sequential,
plus the hardened bridge's wire-format / timeout / reconnect conformance."""
import time

import numpy as np
import pytest

from repro.core import external as ext
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system

SYS = get_system("frontier").scaled(64)


def make_jobs(seed=0, n=40):
    spec = WorkloadSpec(n_jobs=n, duration_s=2 * 3600.0, load=1.2,
                        trace_len=4, seed=seed)
    return generate(SYS, spec)


def test_fastsim_like_sequential_mode():
    js = make_jobs()
    sched = ext.FastSimLike(policy="fcfs", backfill="firstfit")
    final, hist = ext.run_sequential_mode(SYS, js, sched, 0.0, 2 * 3600.0)
    assert float(final.completed) > 0
    p = np.asarray(hist.power_it)
    assert p.shape[0] == int(2 * 3600.0 / SYS.dt)
    assert (p > 0).all()


def test_plugin_mode_tracks_external_running_set():
    js = make_jobs(seed=3)
    sched = ext.FastSimLike(policy="sjf", backfill="firstfit")
    final, hist, wall = ext.run_plugin_mode(SYS, js, sched, 0.0, 3600.0)
    jstate = np.asarray(final.jstate)[:len(js)]
    # the twin executed at least the jobs the external scheduler started
    want = set(np.nonzero(sched.start <= 3600.0 - SYS.dt)[0].tolist())
    got = set(np.nonzero(jstate >= T.RUNNING)[0].tolist())
    missing = want - got - set(np.nonzero(~np.isfinite(sched.start))[0].tolist())
    assert len(missing) <= max(1, len(want) // 10)


def test_plugin_and_sequential_agree_on_power():
    """Plugin mode and sequential mode couple the same schedule, so the
    simulated power histories should agree closely (paper §4.2.2)."""
    js = make_jobs(seed=5)
    s1 = ext.FastSimLike(policy="fcfs", backfill="firstfit")
    s2 = ext.FastSimLike(policy="fcfs", backfill="firstfit")
    _, h_seq = ext.run_sequential_mode(SYS, js, s1, 0.0, 3600.0)
    _, h_plug, _ = ext.run_plugin_mode(SYS, js, s2, 0.0, 3600.0)
    p_seq = np.asarray(h_seq.power_it)
    p_plug = np.asarray(h_plug["power_it"])
    # mean power within a few percent (placement-order effects allowed)
    assert abs(p_seq.mean() - p_plug.mean()) / p_seq.mean() < 0.05


def test_scheduleflow_like_recomputes_every_poll():
    js = make_jobs(seed=7, n=20)
    sched = ext.ScheduleFlowLike()
    final, hist, wall = ext.run_plugin_mode(SYS, js, sched, 0.0, 1800.0)
    n_steps = int(1800.0 / SYS.dt)
    # the paper's observed overhead: a full recompute per trigger
    assert sched.recompute_count == n_steps
    assert float(final.completed) >= 0


# ---------------------------------------------------------------------------
# Bridge hardening: versioned wire format, timeout/reconnect, conformance.
# ---------------------------------------------------------------------------
def test_wire_roundtrip_and_decode_validation():
    msg = ext.encode_running([3, 1, 2])
    assert msg["version"] == ext.WIRE_VERSION
    ids = ext.decode_running(msg, n_jobs=10)
    assert ids.tolist() == [3, 1, 2]
    assert ext.decode_running(ext.encode_running([]), 10).size == 0


def test_malformed_peer_conformance():
    """A confused or wrong-version peer must raise ProtocolError before
    anything touches engine state — and must NOT be retried."""
    js = make_jobs(seed=9, n=10)

    class MalformedPeer:
        def __init__(self, answer):
            self.answer = answer
            self.polls = 0

        def reset(self, system, jobs, t0):
            pass

        def poll_wire(self, t):
            self.polls += 1
            return self.answer

    bad_answers = [
        {"version": 99, "kind": "running_set", "job_ids": [0]},  # version
        {"version": ext.WIRE_VERSION, "kind": "plan", "job_ids": [0]},
        {"version": ext.WIRE_VERSION, "kind": "running_set",
         "job_ids": [0.5]},                                     # floats
        {"version": ext.WIRE_VERSION, "kind": "running_set",
         "job_ids": [0, 0]},                                    # duplicates
        {"version": ext.WIRE_VERSION, "kind": "running_set",
         "job_ids": [len(js) + 5]},                             # range
        [0, 1, 2],                                              # no envelope
    ]
    for answer in bad_answers:
        peer = MalformedPeer(answer)
        with pytest.raises(ext.ProtocolError):
            ext.run_plugin_mode(SYS, js, peer, 0.0, 2 * SYS.dt)
        assert peer.polls == 1          # malformed speech is not retried


def test_slow_peer_triggers_reconnect_then_recovers():
    """A peer that blows the per-call budget once is reconnected (reset
    replay) and the poll retried; the run then completes normally."""
    js = make_jobs(seed=11, n=10)

    class SlowOncePeer(ext.FastSimLike):
        slow_polls: int = 0

        def poll_wire(self, t):
            if self.slow_polls == 0:
                self.slow_polls += 1
                time.sleep(0.05)        # exceeds the 10 ms budget below
            return super().poll_wire(t)

    peer = SlowOncePeer(policy="fcfs", backfill="firstfit")
    bridge = ext.SchedulerBridge(peer, ext.BridgeConfig(timeout_s=0.01,
                                                        max_retries=2))
    final, hist, wall = ext.run_plugin_mode(SYS, js, bridge, 0.0, 1800.0)
    assert bridge.reconnects == 1
    assert float(final.completed) >= 0
    p = np.asarray(hist["power_it"])
    assert (p > 0).all()


def test_dead_peer_raises_bridge_timeout():
    js = make_jobs(seed=13, n=10)

    class DeadPeer:
        def reset(self, system, jobs, t0):
            pass

        def running_at(self, t):
            raise ConnectionError("peer went away")

    with pytest.raises(ext.BridgeTimeout):
        ext.run_plugin_mode(SYS, js, DeadPeer(), 0.0, 2 * SYS.dt)

