"""Checkpoint-parity oracle for the twin service (repro.serve).

The serving architecture rests on one claim: a trajectory advanced in
interval-sized segments — with the carry serialized to JSON and decoded
back between every pair of segments — is **bit-identical** to the same
trajectory as one uninterrupted ``lax.scan``. Not "close", identical:
the scan body is the same ``engine_step`` and grid/weather inputs are
gathered at the carry's absolute step cursor, so segmentation must be
unobservable. These tests assert exact equality (``np.array_equal``, no
tolerances) on every telemetry field and every final-carry leaf, for a
flat plant and a 4-hall topology, with time-varying grid signals and
weather in the loop so the absolute-step gather is actually exercised
across segment boundaries.

Also here: the neutral-delta fork oracle (a fork that changes nothing
must *be* its parent, row for row) and the LRU regression test for the
runner cache a long-lived server leans on.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (assert_trees_equal, concat_hists, make_case,
                      make_signals)
from repro.core import engine as eng
from repro.core import types as T
from repro.cooling import weather as wsig
from repro.launch.simulate import build_system
from repro.serve import session as serve_session
from repro.serve import snapshot as snap

INTERVAL = 8          # engine steps per segment
N_INTERVALS = 6
HORIZON = INTERVAL * N_INTERVALS


@pytest.fixture(scope="module", params=["flat", "halls"])
def topo_case(request):
    """(system, table, scenario, signals, weather) for both plant shapes."""
    if request.param == "flat":
        system = build_system("marconi100", scale=64)
        scen = T.Scenario.make("fcfs", "easy", setpoint_delta_c=1.0)
    else:
        system = build_system("marconi100", scale=64, halls=4)
        scen = T.Scenario.make("thermal_aware", "firstfit",
                               cells_offline=(1.0, 0.0, 0.0, 0.0))
    js, table = make_case(system)
    signals = make_signals(system, HORIZON)
    weather = wsig.synthetic_weather(HORIZON, system.dt, seed=5)
    return system, table, scen, signals, weather


@pytest.mark.timeout(300)
def test_segmented_resume_is_bit_identical(topo_case):
    """Segment at EVERY interval boundary, serialize/deserialize the
    carry between segments, and require the concatenated telemetry and
    the final carry to match one uninterrupted ``simulate`` bitwise."""
    system, table, scen, signals, weather = topo_case
    t1 = HORIZON * system.dt
    ref_final, ref_hist = eng.simulate(system, table, scen, 0.0, t1,
                                       num_accounts=8, signals=signals,
                                       weather=weather)

    carry = eng.init_state(system, table, 0.0, t1, num_accounts=8)
    hists = []
    for _ in range(N_INTERVALS):
        # the wire trip a served checkpoint takes: encode -> JSON text ->
        # decode against the template (strict JSON, byte-faithful)
        payload = json.loads(json.dumps(snap.encode_carry(carry)))
        carry = snap.decode_carry(payload, carry)
        carry, hist = eng.simulate_segment(system, table, carry, scen,
                                           INTERVAL, signals, weather)
        hists.append(hist)

    assert_trees_equal(concat_hists(hists), ref_hist, "telemetry")
    assert_trees_equal(carry, ref_final, "final carry")


@pytest.mark.timeout(300)
def test_neutral_fork_equals_parent(topo_case):
    """A fork with an empty Scenario delta IS its parent: same rows,
    same checkpoints, same snapshot digests, from the fork point on."""
    system, table, scen, signals, weather = topo_case
    t1 = HORIZON * system.dt
    sess = serve_session.TwinSession(system, table, scen, 0.0, t1,
                                     interval_steps=INTERVAL,
                                     signals=signals, weather=weather,
                                     num_accounts=8)
    sess.advance_many({0: 2})
    child = sess.fork(0, {})                    # neutral delta
    sess.advance_many({0: N_INTERVALS - 2,
                       child.branch_id: N_INTERVALS - 2})

    parent_rows = {r["step"]: r for r in sess.fetch(0)["rows"]}
    child_rows = sess.fetch(child.branch_id)["rows"]
    assert len(child_rows) == HORIZON - child.born_step
    for row in child_rows:
        assert row == parent_rows[row["step"]], f"step {row['step']}"

    for step in sess.branches[child.branch_id].checkpoints:
        assert (sess.snapshot(0, at_step=step)["digest"]
                == sess.snapshot(child.branch_id, at_step=step)["digest"])


@pytest.mark.timeout(300)
def test_divergent_fork_shares_prefix_and_diverges(topo_case):
    """Sanity for the other direction: a *non*-neutral delta must match
    the parent before the fork point and actually change the physics
    after it (a delta the engine ignores would make every parity test
    above pass vacuously)."""
    system, table, scen, signals, weather = topo_case
    t1 = HORIZON * system.dt
    sess = serve_session.TwinSession(system, table, scen, 0.0, t1,
                                     interval_steps=INTERVAL,
                                     signals=signals, weather=weather,
                                     num_accounts=8)
    sess.advance_many({0: 3})
    child = sess.fork(0, {"setpoint_delta_c": 4.0})
    sess.advance_many({0: 3, child.branch_id: 3})
    parent = {r["step"]: r for r in sess.fetch(0)["rows"]}
    child_rows = sess.fetch(child.branch_id)["rows"]
    assert any(row != parent[row["step"]] for row in child_rows), \
        "setpoint_delta_c=4.0 produced bit-identical telemetry"
    # and the shared prefix stayed shared: fork point checkpoint digests
    assert (sess.snapshot(0, at_step=child.born_step)["digest"]
            == sess.snapshot(child.branch_id,
                             at_step=child.born_step)["digest"])


@pytest.mark.timeout(120)
def test_fork_from_earlier_checkpoint(topo_case):
    """Forking at a historical boundary resumes from *that* carry: the
    child's first telemetry rows equal the parent's rows at those steps
    (neutral delta), even though the parent is far ahead by then."""
    system, table, scen, signals, weather = topo_case
    t1 = HORIZON * system.dt
    sess = serve_session.TwinSession(system, table, scen, 0.0, t1,
                                     interval_steps=INTERVAL,
                                     signals=signals, weather=weather,
                                     num_accounts=8)
    sess.advance_many({0: N_INTERVALS})        # run the root to the end
    child = sess.fork(0, {}, at_step=INTERVAL)  # rewind to boundary 1
    assert child.step == INTERVAL
    sess.advance_many({child.branch_id: 2})
    parent = {r["step"]: r for r in sess.fetch(0)["rows"]}
    for row in sess.fetch(child.branch_id)["rows"]:
        assert row == parent[row["step"]], f"step {row['step']}"


@pytest.mark.timeout(120)
def test_checkpoints_live_on_host(topo_case):
    """Interval checkpoints are moved to host numpy on commit — a
    long-lived session holds one per tick per branch, and only the live
    carry should pin device memory. Forking from a host checkpoint is
    still bit-identical (the parity tests above run through this path)."""
    system, table, scen, signals, weather = topo_case
    t1 = HORIZON * system.dt
    sess = serve_session.TwinSession(system, table, scen, 0.0, t1,
                                     interval_steps=INTERVAL,
                                     signals=signals, weather=weather,
                                     num_accounts=8)
    sess.advance_many({0: 2})
    child = sess.fork(0, {})
    sess.advance_many({child.branch_id: 1})
    for br in sess.branches.values():
        assert len(br.checkpoints) >= 2
        for step, ck in br.checkpoints.items():
            for leaf in jax.tree_util.tree_leaves(ck):
                assert isinstance(leaf, np.ndarray), \
                    f"branch {br.branch_id} step {step}: device leaf"


def test_rejects_partial_interval_horizon(topo_case):
    """A horizon that is not a whole number of intervals has an
    unreachable tail (advances land on interval boundaries) — the
    session must refuse it loudly instead of silently stopping short."""
    system, table, scen, signals, weather = topo_case
    with pytest.raises(ValueError, match="multiple of interval_steps"):
        serve_session.TwinSession(system, table, scen, 0.0,
                                  (HORIZON + 1) * system.dt,
                                  interval_steps=INTERVAL)


# ---------------------------------------------------------------------------
# Satellite: the runner cache must stay bounded under a long-lived server.
# ---------------------------------------------------------------------------
def test_sweep_cache_lru_bound(monkeypatch):
    """Regression: ``_SWEEP_CACHE`` evicts least-recently-used runners
    past ``SWEEP_CACHE_LIMIT`` (counted), instead of growing forever."""
    import collections
    monkeypatch.setattr(eng, "_SWEEP_CACHE", collections.OrderedDict())
    monkeypatch.setattr(eng, "SWEEP_CACHE_LIMIT", 4)
    monkeypatch.setattr(eng, "SWEEP_CACHE_STATS",
                        {"hits": 0, "misses": 0, "evictions": 0})

    for i in range(10):
        assert eng._cache_lookup(("k", i)) is None
        eng._cache_store(("k", i), f"runner{i}")
    assert len(eng._SWEEP_CACHE) == 4
    assert eng.SWEEP_CACHE_STATS["evictions"] == 6
    assert eng.SWEEP_CACHE_STATS["misses"] == 10
    # survivors are the most recently stored
    assert list(eng._SWEEP_CACHE) == [("k", i) for i in range(6, 10)]

    # a hit refreshes recency: ("k", 6) must now outlive ("k", 7)
    assert eng._cache_lookup(("k", 6)) == "runner6"
    assert eng.SWEEP_CACHE_STATS["hits"] == 1
    eng._cache_store(("k", 99), "runner99")
    assert ("k", 6) in eng._SWEEP_CACHE
    assert ("k", 7) not in eng._SWEEP_CACHE


@pytest.mark.timeout(300)
def test_sweep_cache_lru_bound_end_to_end(monkeypatch):
    """Same bound through the public API: many distinct segment lengths
    (what a server with many interval configs would compile) never hold
    more than the limit, and evicted runners re-compile on demand."""
    import collections
    monkeypatch.setattr(eng, "_SWEEP_CACHE", collections.OrderedDict())
    monkeypatch.setattr(eng, "SWEEP_CACHE_LIMIT", 3)
    monkeypatch.setattr(eng, "SWEEP_CACHE_STATS",
                        {"hits": 0, "misses": 0, "evictions": 0})
    system = build_system("marconi100", scale=64)
    _, table = make_case(system, n_jobs=16, pad=24)
    scen = T.Scenario.make("fcfs")
    carry = eng.init_state(system, table, 0.0, 64 * system.dt,
                           num_accounts=8)
    step0 = int(carry.step)
    # simulate_segment *donates* the carry it is given (the scan writes
    # in place — engine.DONATE_CARRIES), so each call gets its own copy
    fresh = lambda: jax.tree_util.tree_map(jnp.copy, carry)
    for n in (1, 2, 3, 4, 5):
        eng.simulate_segment(system, table, fresh(), scen, n)
    assert len(eng._SWEEP_CACHE) == 3
    assert eng.SWEEP_CACHE_STATS["evictions"] == 2
    # the evicted n=1 runner comes back transparently (a fresh miss)
    misses_before = eng.SWEEP_CACHE_STATS["misses"]
    out, _ = eng.simulate_segment(system, table, fresh(), scen, 1)
    assert int(out.step) == step0 + 1
    assert eng.SWEEP_CACHE_STATS["misses"] == misses_before + 1
