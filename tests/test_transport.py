"""Out-of-process peer transport: conformance + fault injection.

The conformance half runs the *real* ``tools/reference_peer.py``
subprocess end-to-end and asserts the process boundary is behaviorally
invisible: plugin-mode telemetry must match the in-process
``FastSimLike`` bit-for-bit on the same seed. The fault half drives the
bridge through every way a peer can go wrong — dies mid-stream, hangs
past the budget, writes garbage or truncated frames, speaks the wrong
wire version — and asserts the failure surfaces as ``ProtocolError`` /
``BridgeTimeout`` (never a hang) and that no peer process is left
unreaped (no zombies).
"""
import importlib.util
import io
import pathlib
import socket
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import external as ext
from repro.core import transport as tr
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system

pytestmark = pytest.mark.timeout(180)

ROOT = pathlib.Path(__file__).resolve().parents[1]
PEER = [sys.executable, str(ROOT / "tools" / "reference_peer.py")]
SYS = get_system("frontier").scaled(64)


def load_peer_module():
    """Import tools/reference_peer.py by path (tests run from src/)."""
    spec = importlib.util.spec_from_file_location(
        "reference_peer", ROOT / "tools" / "reference_peer.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def make_jobs(seed=0, n=30):
    spec = WorkloadSpec(n_jobs=n, duration_s=2 * 3600.0, load=1.2,
                        trace_len=4, seed=seed)
    return generate(SYS, spec)


def make_peer(*fault, **kw):
    cmd = PEER + (["--fault", fault[0]] if fault else [])
    kw.setdefault("handshake_timeout_s", 30.0)
    return tr.SubprocessPeer(cmd=cmd, **kw)


def assert_reaped(peer):
    """Every process the peer ever spawned has been wait()ed."""
    assert peer._proc is None, "peer process still attached after close"
    assert peer.spawned, "no peer process was ever spawned"
    for p in peer.spawned:
        assert p.returncode is not None, \
            f"pid {p.pid} never reaped (zombie)"


# ---------------------------------------------------------------------------
# Conformance: the process boundary must be behaviorally invisible.
# ---------------------------------------------------------------------------
def test_subprocess_plugin_mode_matches_in_process_fastsim():
    js = make_jobs(seed=21)
    t1 = 1800.0
    inproc = ext.FastSimLike(policy="fcfs", backfill="firstfit")
    _, h_ref, _ = ext.run_plugin_mode(SYS, js, inproc, 0.0, t1)
    peer = make_peer(policy="fcfs", backfill="firstfit")
    try:
        _, h_sub, _ = ext.run_plugin_mode(SYS, js, peer, 0.0, t1)
    finally:
        peer.close()
    assert_reaped(peer)
    assert set(h_ref) == set(h_sub)
    for k in h_ref:
        assert np.array_equal(np.asarray(h_ref[k]), np.asarray(h_sub[k])), \
            f"telemetry channel {k!r} diverged across the process boundary"


def test_subprocess_schedule_matches_in_process_event_schedule():
    """Sequential mode: the peer's full schedule equals FastSimLike's."""
    js = make_jobs(seed=22, n=40)
    inproc = ext.FastSimLike(policy="sjf", backfill="firstfit")
    inproc.reset(SYS, js, 0.0)
    peer = make_peer(policy="sjf", backfill="firstfit")
    try:
        peer.reset(SYS, js, 0.0)
        remote_start = peer.start
    finally:
        peer.close()
    assert_reaped(peer)
    ref = np.asarray(inproc.start, np.float64)
    both_inf = ~np.isfinite(ref) & ~np.isfinite(remote_start)
    assert np.array_equal(ref[~both_inf], remote_start[~both_inf])
    assert (np.isfinite(ref) == np.isfinite(remote_start)).all()


def test_sequential_mode_over_subprocess_peer():
    js = make_jobs(seed=23)
    peer = make_peer()
    try:
        final, hist = ext.run_sequential_mode(SYS, js, peer, 0.0, 1800.0)
    finally:
        peer.close()
    assert_reaped(peer)
    s1 = ext.FastSimLike(policy="fcfs", backfill="firstfit")
    _, h_ref = ext.run_sequential_mode(SYS, js, s1, 0.0, 1800.0)
    assert np.array_equal(np.asarray(h_ref.power_it),
                          np.asarray(hist.power_it))


def test_handshake_hello_and_digest_checked():
    js = make_jobs(seed=24, n=8)
    peer = make_peer()
    try:
        peer.reset(SYS, js, 0.0)
        assert peer.peer_hello["name"] == "reference-peer"
        assert peer.peer_hello["version"] == ext.WIRE_VERSION
        # digest helpers agree with the peer's stdlib reimplementation
        mod = load_peer_module()
        assert tr.job_digest(js) == mod.job_digest(
            js.submit, js.limit, js.wall, js.nodes, js.account)
        assert tr.system_digest(SYS) == mod.system_digest(SYS.n_nodes,
                                                          SYS.dt)
    finally:
        peer.close()
    assert_reaped(peer)


def test_bridge_polls_subprocess_through_wire_validation():
    """The bridge path decodes every subprocess answer (spot check)."""
    js = make_jobs(seed=25, n=12)
    peer = make_peer()
    bridge = ext.SchedulerBridge(peer)
    try:
        bridge.reset(SYS, js, 0.0)
        ids = bridge.poll(600.0)
        assert ids.dtype == np.int64
        assert np.unique(ids).size == ids.size
        inproc = ext.FastSimLike(policy="fcfs", backfill="firstfit")
        inproc.reset(SYS, js, 0.0)
        assert sorted(ids.tolist()) == \
            sorted(inproc.running_at(600.0).tolist())
    finally:
        peer.close()
    assert_reaped(peer)


def test_listen_mode_socket_peer_roundtrip(tmp_path):
    """--listen serving + SocketPeer dialing (the --external-socket path)."""
    addr = f"unix:{tmp_path / 'peer.sock'}"
    server = subprocess.Popen(PEER + ["--listen", addr],
                              stdin=subprocess.DEVNULL,
                              stdout=subprocess.DEVNULL,
                              stderr=subprocess.DEVNULL)
    try:
        deadline = time.monotonic() + 20.0
        js = make_jobs(seed=26, n=10)
        peer = tr.SocketPeer(address=addr)
        while True:  # wait for the server to bind
            try:
                peer.reset(SYS, js, 0.0)
                break
            except (ConnectionError, OSError, FileNotFoundError):
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.05)
        inproc = ext.FastSimLike(policy="fcfs", backfill="firstfit")
        inproc.reset(SYS, js, 0.0)
        for t in (0.0, 900.0, 3600.0):
            assert sorted(peer.running_at(t).tolist()) == \
                sorted(inproc.running_at(t).tolist())
        peer.close()
        # a listen-mode server survives the session and accepts a new one
        peer2 = tr.SocketPeer(address=addr)
        peer2.reset(SYS, js, 0.0)
        assert peer2.running_at(0.0) is not None
        peer2.close()
    finally:
        server.terminate()
        server.wait(timeout=10.0)
    assert server.returncode is not None


# ---------------------------------------------------------------------------
# Fault injection: every failure mode surfaces, nothing hangs, no zombies.
# ---------------------------------------------------------------------------
def test_peer_dying_immediately_raises_bridge_timeout():
    js = make_jobs(seed=30, n=8)
    peer = make_peer("die:0")
    try:
        with pytest.raises(ext.BridgeTimeout):
            ext.run_plugin_mode(SYS, js, peer, 0.0, 4 * SYS.dt)
    finally:
        peer.close()
    # one spawn per attempt, and no pointless respawn after the final
    # failure (that answer could never be used)
    assert len(peer.spawned) == ext.BridgeConfig().max_retries + 1
    assert_reaped(peer)


def test_peer_dying_mid_stream_heals_via_respawn():
    """A peer that dies every few polls is respawned+resynced each time
    and the run still completes — reconnect-with-resync end-to-end."""
    js = make_jobs(seed=31, n=10)
    peer = make_peer("die:3")
    bridge = ext.SchedulerBridge(peer)
    try:
        final, hist, _ = ext.run_plugin_mode(SYS, js, bridge, 0.0,
                                             10 * SYS.dt)
    finally:
        peer.close()
    assert bridge.reconnects >= 2
    assert len(peer.spawned) == bridge.reconnects + 1
    assert_reaped(peer)
    assert np.asarray(hist["power_it"]).shape[0] == 10


def test_hanging_peer_times_out_not_deadlocks():
    js = make_jobs(seed=32, n=8)
    peer = make_peer("hang", timeout_s=0.5)
    bridge = ext.SchedulerBridge(peer, ext.BridgeConfig(timeout_s=0.5,
                                                        max_retries=1))
    t_wall = time.monotonic()
    try:
        with pytest.raises(ext.BridgeTimeout):
            ext.run_plugin_mode(SYS, js, bridge, 0.0, 4 * SYS.dt)
    finally:
        peer.close()
    assert time.monotonic() - t_wall < 60.0, "bridge deadlocked on a hang"
    assert_reaped(peer)


def test_garbage_frames_raise_protocol_error_not_retried():
    js = make_jobs(seed=33, n=8)
    peer = make_peer("garbage")
    try:
        with pytest.raises(ext.ProtocolError):
            ext.run_plugin_mode(SYS, js, peer, 0.0, 4 * SYS.dt)
    finally:
        peer.close()
    assert len(peer.spawned) == 1, "broken speech must not be retried"
    assert_reaped(peer)


def test_truncated_frame_raises_protocol_error():
    js = make_jobs(seed=34, n=8)
    peer = make_peer("truncate")
    try:
        with pytest.raises(ext.ProtocolError):
            ext.run_plugin_mode(SYS, js, peer, 0.0, 4 * SYS.dt)
    finally:
        peer.close()
    assert_reaped(peer)


def test_wrong_wire_version_rejected_at_handshake():
    """A peer advertising version 2 must be refused before any poll —
    and the refused process must already be reaped (no leak on the
    ProtocolError path)."""
    js = make_jobs(seed=35, n=8)
    peer = make_peer("version")
    try:
        with pytest.raises(ext.ProtocolError, match="version"):
            ext.run_plugin_mode(SYS, js, peer, 0.0, 4 * SYS.dt)
    finally:
        peer.close()
    assert len(peer.spawned) == 1
    assert_reaped(peer)


def test_missing_peer_command_times_out_cleanly():
    js = make_jobs(seed=36, n=8)
    peer = tr.SubprocessPeer(
        cmd=[sys.executable, "-c", "import time; time.sleep(60)"],
        handshake_timeout_s=1.0)
    try:
        with pytest.raises(ext.BridgeTimeout):
            ext.run_plugin_mode(SYS, js, peer, 0.0, 2 * SYS.dt)
    finally:
        peer.close()
    assert_reaped(peer)


def test_unsupported_policy_surfaces_peer_error_envelope():
    """A reset the peer cannot honor comes back as the protocol's error
    envelope with the real cause, not a wordless death + BridgeTimeout."""
    js = make_jobs(seed=38, n=8)
    peer = make_peer(policy="not-a-policy")
    try:
        with pytest.raises(ext.ProtocolError, match="rejected"):
            peer.reset(SYS, js, 0.0)
    finally:
        peer.close()
    assert_reaped(peer)


def test_nonexistent_peer_command_fails_cleanly():
    """Popen itself failing (bad command) must not leak the listener
    socket or the per-attempt tmpdir across bridge retries."""
    js = make_jobs(seed=37, n=8)
    peer = tr.SubprocessPeer(cmd=["/nonexistent/peer-binary"])
    try:
        with pytest.raises(ext.BridgeTimeout):
            ext.run_plugin_mode(SYS, js, peer, 0.0, 2 * SYS.dt)
    finally:
        peer.close()
    assert peer.spawned == []          # nothing ever started
    assert peer._tmpdir is None and peer._proc is None


# ---------------------------------------------------------------------------
# Framing / codec unit coverage (socket-free).
# ---------------------------------------------------------------------------
def test_read_frame_classifies_failures(monkeypatch):
    ok = io.BytesIO(b'{"version":1,"kind":"hello"}\n')
    assert tr.read_frame(ok)["kind"] == "hello"
    with pytest.raises(ConnectionError):          # EOF: peer died
        tr.read_frame(io.BytesIO(b""))
    with pytest.raises(ext.ProtocolError):        # garbage
        tr.read_frame(io.BytesIO(b"}{ nope\n"))
    with pytest.raises(ext.ProtocolError):        # truncated
        tr.read_frame(io.BytesIO(b'{"version":1'))
    with pytest.raises(ext.ProtocolError):        # non-object frame
        tr.read_frame(io.BytesIO(b"[1,2,3]\n"))
    monkeypatch.setattr(tr, "MAX_FRAME_BYTES", 1024)
    huge = b'{"pad":"' + b"x" * 2048 + b'"}\n'
    with pytest.raises(ext.ProtocolError):        # over-long inbound
        tr.read_frame(io.BytesIO(huge))
    with pytest.raises(ext.ProtocolError):        # over-long outbound
        tr.write_frame(io.BytesIO(), {"pad": "x" * 2048})


def test_decode_schedule_validation():
    msg = {"version": 1, "kind": "schedule", "start": [0.0, None, 30.5]}
    out = tr.decode_schedule(msg, 3)
    assert out[0] == 0.0 and np.isinf(out[1]) and out[2] == 30.5
    for bad in [
        {"version": 2, "kind": "schedule", "start": [0.0]},
        {"version": 1, "kind": "running_set", "start": [0.0]},
        {"version": 1, "kind": "schedule", "start": [0.0, 1.0]},
        {"version": 1, "kind": "schedule", "start": "soon"},
        {"version": 1, "kind": "schedule", "start": [True]},
        {"version": 1, "kind": "schedule", "start": ["0.0"]},
        {"version": 1, "kind": "schedule", "start": [float("nan")]},
        {"version": 1, "kind": "schedule", "start": [float("inf")]},
        {"version": 1, "kind": "schedule", "start": [10 ** 400]},
    ]:
        with pytest.raises(ext.ProtocolError):
            tr.decode_schedule(bad, 1 if len(bad.get("start", [])) == 1
                               else 3)


def test_parse_address_forms():
    if hasattr(socket, "AF_UNIX"):
        assert tr.parse_address("unix:/tmp/x.sock") == \
            (socket.AF_UNIX, "/tmp/x.sock")
        assert tr.parse_address("/tmp/x.sock") == \
            (socket.AF_UNIX, "/tmp/x.sock")
    assert tr.parse_address("127.0.0.1:7700") == \
        (socket.AF_INET, ("127.0.0.1", 7700))
    assert tr.parse_address("tcp:localhost:80") == \
        (socket.AF_INET, ("localhost", 80))
    with pytest.raises(ValueError):
        tr.parse_address("not-an-address")


def test_pure_python_event_schedule_matches_numpy_reference():
    """The peer's stdlib scheduler is decision-identical to the twin's."""
    from repro.datasets.synthetic import event_schedule as np_sched
    mod = load_peer_module()
    for seed in range(4):
        js = make_jobs(seed=seed, n=25)
        for policy in ("fcfs", "sjf", "ljf", "priority"):
            for backfill in ("none", "firstfit"):
                ref = np_sched(js.submit, js.limit, js.wall, js.nodes,
                               SYS.n_nodes, SYS.dt, policy=policy,
                               backfill=backfill, priority=js.priority)
                got = np.asarray(mod.event_schedule(
                    [float(x) for x in js.submit],
                    [float(x) for x in js.limit],
                    [float(x) for x in js.wall],
                    [int(x) for x in js.nodes],
                    SYS.n_nodes, SYS.dt, policy=policy, backfill=backfill,
                    priority=[float(x) for x in js.priority]))
                finite = np.isfinite(ref)
                assert (finite == np.isfinite(got)).all(), \
                    (seed, policy, backfill)
                assert np.array_equal(ref[finite], got[finite]), \
                    (seed, policy, backfill)
