"""Property-based tests (hypothesis) for the external-bridge wire codec.

The envelope codec is the trust boundary between the twin and an
arbitrary out-of-process peer: whatever bytes arrive, ``decode_running``
/ ``decode_schedule`` must either return a validated array or raise
``ProtocolError`` — never crash with something else, never silently
accept a malformed payload.
"""
import json

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import external as ext  # noqa: E402
from repro.core import transport as tr  # noqa: E402

N_JOBS = 64


@st.composite
def id_sets(draw):
    """Arbitrary duplicate-free id sets in [0, N_JOBS)."""
    ids = draw(st.lists(st.integers(0, N_JOBS - 1), unique=True,
                        max_size=N_JOBS))
    return ids


@given(id_sets())
@settings(max_examples=200, deadline=None)
def test_encode_decode_roundtrip(ids):
    msg = ext.encode_running(ids)
    out = ext.decode_running(msg, N_JOBS)
    assert sorted(out.tolist()) == sorted(ids)
    assert out.dtype == np.int64
    # and the envelope survives an actual JSON wire trip
    out2 = ext.decode_running(json.loads(json.dumps(msg)), N_JOBS)
    assert np.array_equal(out, out2)


@given(id_sets())
@settings(max_examples=100, deadline=None)
def test_decode_rejects_shifted_version_and_kind(ids):
    msg = ext.encode_running(ids)
    with pytest.raises(ext.ProtocolError):
        ext.decode_running({**msg, "version": ext.WIRE_VERSION + 1}, N_JOBS)
    with pytest.raises(ext.ProtocolError):
        ext.decode_running({**msg, "kind": "plan"}, N_JOBS)


@given(st.lists(st.integers(N_JOBS, N_JOBS + 1000), min_size=1, max_size=8,
                unique=True))
@settings(max_examples=100, deadline=None)
def test_decode_rejects_out_of_range_ids(ids):
    with pytest.raises(ext.ProtocolError):
        ext.decode_running(ext.encode_running(ids), N_JOBS)


@given(st.lists(st.integers(0, N_JOBS - 1), min_size=1, max_size=16))
@settings(max_examples=100, deadline=None)
def test_decode_rejects_duplicates(ids):
    dup = ids + [ids[0]]
    with pytest.raises(ext.ProtocolError):
        ext.decode_running(ext.encode_running(dup), N_JOBS)


# Anything JSON can spell: scalars, strings, nested lists, objects.
json_values = st.recursive(
    st.none() | st.booleans() | st.integers(-2**40, 2**40) |
    st.floats(allow_nan=False) | st.text(max_size=8),
    lambda inner: st.lists(inner, max_size=5) |
    st.dictionaries(st.text(max_size=8), inner, max_size=5),
    max_leaves=10)


@given(json_values)
@settings(max_examples=300, deadline=None)
def test_fuzzed_job_ids_never_crash_never_silently_pass(payload):
    """Arbitrary JSON in the job_ids slot: either it is a genuinely valid
    flat unique in-range integer list, or ProtocolError — nothing else."""
    msg = {"version": ext.WIRE_VERSION, "kind": ext.WIRE_KIND_RUNNING,
           "job_ids": payload}
    try:
        out = ext.decode_running(msg, N_JOBS)
    except ext.ProtocolError:
        return
    ids = out.tolist()
    assert isinstance(payload, list)
    assert all(isinstance(x, int) and not isinstance(x, bool)
               for x in payload)
    assert sorted(ids) == sorted(payload)
    assert len(set(ids)) == len(ids)
    assert all(0 <= x < N_JOBS for x in ids)


@given(json_values)
@settings(max_examples=300, deadline=None)
def test_fuzzed_envelope_never_crashes(payload):
    """The whole envelope slot fuzzed (not just job_ids)."""
    try:
        ext.decode_running(payload, N_JOBS)
    except ext.ProtocolError:
        pass


@given(st.lists(st.none() | st.floats(allow_nan=False, allow_infinity=False,
                                      width=32),
                max_size=32))
@settings(max_examples=150, deadline=None)
def test_schedule_roundtrip(start):
    msg = {"version": ext.WIRE_VERSION, "kind": "schedule",
           "start": [None if s is None else float(s) for s in start]}
    out = tr.decode_schedule(json.loads(json.dumps(msg)), len(start))
    for s, o in zip(start, out):
        if s is None:
            assert np.isinf(o)
        else:
            assert o == s


@given(json_values)
@settings(max_examples=200, deadline=None)
def test_fuzzed_schedule_never_crashes(payload):
    msg = {"version": ext.WIRE_VERSION, "kind": "schedule",
           "start": payload}
    try:
        out = tr.decode_schedule(msg, 4)
    except ext.ProtocolError:
        return
    assert isinstance(payload, list) and len(payload) == 4
    assert out.shape == (4,)


# ---------------------------------------------------------------------------
# Binary dialect: both spellings of a message must decode identically.
# ---------------------------------------------------------------------------
_bin_dtypes = st.sampled_from(["<f8", "<f4", "<i8", "<i4", "<u8", "<u4",
                               "|b1"])


@st.composite
def wire_arrays(draw):
    """A numpy array any reset/schedule envelope could carry."""
    import numpy as np
    dt = np.dtype(draw(_bin_dtypes))
    n = draw(st.integers(0, 32))
    if dt.kind == "f":
        vals = draw(st.lists(st.floats(width=32, allow_nan=False),
                             min_size=n, max_size=n))
    elif dt.kind == "b":
        vals = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    elif dt.kind == "u":
        vals = draw(st.lists(st.integers(0, 2**31 - 1),
                             min_size=n, max_size=n))
    else:
        vals = draw(st.lists(st.integers(-2**31, 2**31 - 1),
                             min_size=n, max_size=n))
    return np.asarray(vals, dt)


@given(st.dictionaries(
    st.text(st.characters(min_codepoint=97, max_codepoint=122),
            min_size=1, max_size=8),
    wire_arrays() | st.floats(allow_nan=False) | st.integers(-10, 10)
    | st.text(max_size=8),
    max_size=5))
@settings(max_examples=150, deadline=None)
def test_binary_and_ndjson_decode_to_the_same_message(fields):
    """One message, two wires: an RBW1 frame decoded with
    ``as_arrays=False`` equals the NDJSON spelling of the same message
    (arrays spelled via .tolist()), read back through the same
    dialect-agnostic reader."""
    import io

    import numpy as np

    msg = {"version": ext.WIRE_VERSION, "kind": "prop", **fields}
    as_json = {k: v.tolist() if isinstance(v, np.ndarray) else v
               for k, v in msg.items()}

    b = io.BytesIO()
    tr.write_bin_frame(b, msg)
    b.seek(0)
    from_bin = tr.read_any_frame(b, as_arrays=False)

    j = io.BytesIO()
    tr.write_frame(j, as_json)
    j.seek(0)
    from_json = tr.read_any_frame(j)

    assert from_bin == from_json
