"""Fault tolerance: checkpoint/restart must continue a killed training run
bit-for-bit (modulo fresh RNG for new batches), and checkpoints are
mesh-independent numpy artifacts (elastic re-meshing story)."""
import pathlib

import numpy as np
import pytest

from repro.launch import train as train_mod


def test_train_restart_resumes_from_checkpoint(tmp_path):
    args = ["--arch", "qwen2.5-3b-smoke", "--batch", "2", "--seq", "32",
            "--lr", "1e-3", "--ckpt-every", "5",
            "--ckpt-dir", str(tmp_path)]
    # run 10 steps with checkpoints every 5
    losses_a = train_mod.main(args + ["--steps", "10"])
    assert len(losses_a) == 10
    ckpts = list((tmp_path / "qwen2.5-3b-smoke").glob("ckpt_*.npz"))
    assert ckpts, "checkpoints must exist"

    # 'crash' and restart with a longer horizon: resumes at step 10
    losses_b = train_mod.main(args + ["--steps", "15"])
    assert len(losses_b) == 5, "should only run the remaining 5 steps"
    assert np.isfinite(losses_b).all()

    # a fully restarted run from scratch matches the first run exactly
    losses_c = train_mod.main(
        ["--arch", "qwen2.5-3b-smoke", "--batch", "2", "--seq", "32",
         "--lr", "1e-3", "--ckpt-every", "0", "--steps", "10",
         "--ckpt-dir", str(tmp_path / "fresh")])
    np.testing.assert_allclose(losses_a, losses_c, rtol=1e-6)


def test_checkpoint_roundtrip_is_exact(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.common import split_tree
    from repro.models.zoo import get_api
    from repro.training import optimizer as opt

    cfg = get_config("yi-9b-smoke")
    api = get_api(cfg)
    params, _ = split_tree(api.init(jax.random.PRNGKey(0)))
    state = opt.init(opt.AdamWConfig(), params)
    train_mod.save_ckpt(tmp_path, 7, params, state)
    (restored, rstate), step = train_mod.load_latest(tmp_path)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
