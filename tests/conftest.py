"""Shared test fixtures and builder factories.

The session fixtures (``small_system``/``small_jobs``/``small_table``)
cover the common "one small machine, one workload" case. The module
functions below are the consolidated system/jobset builders that used to
be copy-pasted across test_topology.py, test_serve_checkpoint.py and
test_train.py — import them directly (``from conftest import make_case``);
pytest's prepend import mode puts this directory on ``sys.path``.
"""
import dataclasses
import pathlib

import jax
import numpy as np
import pytest

from repro.datasets.synthetic import WorkloadSpec, generate
from repro.grid import signals as gsig
from repro.systems.config import FacilityTopology, get_system

# golden trace fixtures (tools/make_trace_fixtures.py) — committed bytes,
# consumed by test_traces*.py / test_calibrate.py and the docs quickstart
DATA_DIR = pathlib.Path(__file__).resolve().parent / "data"


def pytest_configure(config):
    # pytest-timeout provides enforcement in CI (ci.yml passes
    # --timeout); registering the marker keeps plugin-less local runs
    # warning-free so the subprocess tests stay runnable anywhere
    config.addinivalue_line(
        "markers",
        "timeout(seconds): hard per-test deadline, enforced by "
        "pytest-timeout where installed (kills a deadlocked bridge "
        "instead of stalling the suite)")


@pytest.fixture(scope="session")
def small_system():
    return get_system("marconi100").scaled(64)


@pytest.fixture(scope="session")
def small_jobs(small_system):
    spec = WorkloadSpec(n_jobs=80, duration_s=4 * 3600.0, load=1.0,
                        trace_len=8, n_accounts=8, mean_wall_s=1800.0,
                        seed=7)
    return generate(small_system, spec)


@pytest.fixture(scope="session")
def small_table(small_jobs, small_system):
    small_jobs.assign_prepop_placement(0.0, small_system.n_nodes)
    return small_jobs.to_table(96)


@pytest.fixture(scope="session")
def trace_jobset(tmp_path_factory):
    """The joblive/jobprofile golden fixture as a replay-capable JobSet
    (measured ``power_profile`` attached; NPZ cache in a session tmp
    dir so the repo stays clean)."""
    from repro.traces import load_telemetry
    return load_telemetry(
        DATA_DIR / "joblive", DATA_DIR / "jobprofile", prof_dt=20.0,
        cache_dir=tmp_path_factory.mktemp("trace_cache"))


@pytest.fixture(scope="session")
def trace_weather():
    """The weather-week golden fixture resampled to a 2 h / 20 s grid."""
    from repro.traces import load_weather
    return load_weather(DATA_DIR / "weather_week.csv", n_steps=360,
                        dt=20.0)


# ---------------------------------------------------------------------------
# Builder factories (shared across test modules).
# ---------------------------------------------------------------------------
def with_topology(cfg, n_halls, n_groups=None, n_cells=None, **over):
    """A copy of cooling config ``cfg`` reshaped to ``n_halls`` halls."""
    return dataclasses.replace(
        cfg, n_groups=n_groups or cfg.n_groups,
        n_tower_cells=n_cells or cfg.n_tower_cells,
        topology=FacilityTopology(n_halls=n_halls), **over)


def make_jobs(system, seed=3, n_jobs=64, load=1.2, duration_s=4 * 3600.0,
              mean_wall_s=1800.0, prepop=True):
    """One calibrated synthetic JobSet sized to ``system``."""
    js = generate(system, WorkloadSpec(
        n_jobs=n_jobs, duration_s=duration_s, load=load, trace_len=8,
        n_accounts=8, mean_wall_s=mean_wall_s, seed=seed))
    if prepop:
        js.assign_prepop_placement(0.0, system.n_nodes)
    return js


def make_case(system, seed=3, n_jobs=64, pad=80, load=1.2):
    """(JobSet, JobTable) pair — the serve/checkpoint test workload."""
    js = make_jobs(system, seed=seed, n_jobs=n_jobs, load=load)
    return js, js.to_table(pad)


def make_table(system, seed, load=1.4, n_jobs=64):
    """JobTable only, padded just past ``n_jobs`` — the topology-test
    workload (hotter default load so halls saturate)."""
    js = make_jobs(system, seed=seed, n_jobs=n_jobs, load=load)
    return js.to_table(n_jobs + 16)


def make_signals(system, n_steps, seed=11):
    """Time-varying carbon + a cap schedule (above the idle floor so the
    run is throttled sometimes, never starved)."""
    rng = np.random.default_rng(seed)
    floor = system.n_nodes * system.power.idle_node_w
    sig = gsig.constant_signals(n_steps, carbon_gkwh=300.0, price_kwh=0.1)
    carbon = (300.0 + 200.0 * np.sin(np.linspace(0, 6.0, n_steps))
              ).astype(np.float32)
    cap = rng.uniform(1.5 * floor, 6.0 * floor, n_steps).astype(np.float32)
    return gsig.GridSignals(**{**vars(sig), "carbon_gkwh": carbon,
                               "cap_w": cap})


def assert_trees_equal(a, b, what=""):
    """Bitwise equality of two pytrees, leaf by leaf, path in the diff."""
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (path, la), (_, lb) in zip(fa, fb):
        la, lb = np.asarray(la), np.asarray(lb)
        eq = (np.array_equal(la, lb, equal_nan=True)
              if np.issubdtype(la.dtype, np.floating)
              else np.array_equal(la, lb))
        assert eq, (f"{what}: leaf {jax.tree_util.keystr(path)} diverges "
                    f"(max |d| = "
                    f"{np.max(np.abs(la.astype(np.float64) - lb.astype(np.float64)))})")


def concat_hists(hists):
    return jax.tree_util.tree_map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs]), *hists)
