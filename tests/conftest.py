import numpy as np
import pytest

from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system


def pytest_configure(config):
    # pytest-timeout provides enforcement in CI (ci.yml passes
    # --timeout); registering the marker keeps plugin-less local runs
    # warning-free so the subprocess tests stay runnable anywhere
    config.addinivalue_line(
        "markers",
        "timeout(seconds): hard per-test deadline, enforced by "
        "pytest-timeout where installed (kills a deadlocked bridge "
        "instead of stalling the suite)")


@pytest.fixture(scope="session")
def small_system():
    return get_system("marconi100").scaled(64)


@pytest.fixture(scope="session")
def small_jobs(small_system):
    spec = WorkloadSpec(n_jobs=80, duration_s=4 * 3600.0, load=1.0,
                        trace_len=8, n_accounts=8, mean_wall_s=1800.0,
                        seed=7)
    return generate(small_system, spec)


@pytest.fixture(scope="session")
def small_table(small_jobs, small_system):
    small_jobs.assign_prepop_placement(0.0, small_system.n_nodes)
    return small_jobs.to_table(96)
