"""Sharding rules, optimizer, and a tiny end-to-end training run."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.common import split_tree
from repro.models.zoo import get_api
from repro.parallel import sharding as shd
from repro.training import optimizer as opt
from repro.training import train_step as ts


def test_spec_divisibility_guard():
    mesh = make_host_mesh()  # (1,1): everything degenerates to replication
    cfg = get_config("qwen2.5-3b-smoke")
    rules = shd.rules_for(cfg, mesh)
    spec = shd.spec_for(mesh, rules, ("embed", "mlp"), (128, 256))
    assert spec == P(None, None)


def test_rules_fallbacks():
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    mesh = FakeMesh()
    # phi3 is padded 40 -> 48 heads (divides 16): heads stay sharded
    cfg = get_config("phi3-medium-14b")
    rules = shd.rules_for(cfg, mesh)
    assert rules["heads"] == "model"
    # without padding the guard must fall back to replication
    import dataclasses
    cfg0 = dataclasses.replace(cfg, pad_heads_to=0)
    assert shd.rules_for(cfg0, mesh)["heads"] is None
    # mixtral: 8 experts % 16 != 0 -> expert dim replicated (TP inside)
    cfg = get_config("mixtral-8x7b")
    rules = shd.rules_for(cfg, mesh)
    assert rules["expert"] is None
    # llama4: 128 experts divide -> EP stays
    cfg = get_config("llama4-maverick-400b-a17b")
    rules = shd.rules_for(cfg, mesh)
    assert rules["expert"] == "model"
    # long-context decode turns on KV sequence sharding
    rules = shd.rules_for(get_config("zamba2-7b"), mesh, "long_decode")
    assert rules["kv_seq"] == "data"


def test_spec_for_padded_leading_layer_dim():
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 4)
    spec = shd.spec_for(FakeMesh(), shd.DEFAULT_RULES,
                        ("embed", "mlp"), (8, 128, 256))
    assert spec == P(None, "data", "model")


def test_adamw_decreases_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, warmup=0, total_steps=100,
                          weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(cfg, params)
    target = jnp.asarray([1.0, 1.0])

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss_fn(params))
    for _ in range(50):
        g = jax.grad(loss_fn)(params)
        params, state, m = opt.apply(cfg, g, state, params)
    assert float(loss_fn(params)) < l0 * 0.05


def test_grad_clip_bounds_update():
    cfg = opt.AdamWConfig(lr=1.0, clip_norm=1e-3, warmup=0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(cfg, params)
    huge = {"w": jnp.full(3, 1e9)}
    new, state, m = opt.apply(cfg, huge, state, params)
    assert float(m["grad_norm"]) > 1e8
    assert np.abs(np.asarray(new["w"])).max() < 2.0  # clipped step


def test_tiny_training_loss_decreases():
    """End-to-end: a few steps on a tiny transformer reduce LM loss on a
    repeated batch."""
    cfg = get_config("qwen2.5-3b-smoke")
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = split_tree(api.init(key))
    ocfg = opt.AdamWConfig(lr=3e-3, warmup=2, total_steps=50,
                           weight_decay=0.0)
    state = opt.init(ocfg, params)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab)}

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(lambda p: api.loss(p, batch))(params)
        params, state, _ = opt.apply(ocfg, grads, state, params)
        return params, state, loss

    losses = []
    for _ in range(12):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5
    assert np.isfinite(losses).all()


def test_make_train_step_on_host_mesh():
    """The same builder the dry-run uses works on the 1-device mesh with
    real arrays (allocates, runs one step)."""
    mesh = make_host_mesh()
    cfg = get_config("internvl2-1b-smoke")
    with mesh:
        step, shardings, structs = ts.make_train_step(cfg, mesh, seq_len=40,
                                                      global_batch=2)
        api = get_api(cfg)
        key = jax.random.PRNGKey(1)
        params, _ = split_tree(api.init(key))
        ocfg = opt.AdamWConfig(moment_dtype=cfg.moment_dtype)
        opt_state = opt.init(ocfg, params)
        batch = {
            "tokens": jax.random.randint(key, structs["batch"]["tokens"].shape,
                                         0, cfg.vocab),
            "patches": jax.random.normal(key,
                                         structs["batch"]["patches"].shape),
        }
        params, opt_state, metrics = step(params, opt_state, batch)
        assert np.isfinite(float(metrics["loss"]))


def test_batch_struct_covers_all_families():
    for name in ("qwen2.5-3b", "seamless-m4t-large-v2", "internvl2-1b"):
        cfg = get_config(name)
        bs = ts.batch_struct(cfg, 128, 4, "train")
        assert "tokens" in bs
        if cfg.family == "encdec":
            assert "frames" in bs
        if cfg.family == "vlm":
            assert "patches" in bs


def test_grad_accumulation_matches_full_batch():
    """accum_steps=2 must produce (numerically) the same update as the
    full-batch step when the loss is a mean over tokens."""
    mesh = make_host_mesh()
    cfg = get_config("yi-9b-smoke")
    api = get_api(cfg)
    key = jax.random.PRNGKey(7)
    params, _ = split_tree(api.init(key))
    ocfg = opt.AdamWConfig(lr=1e-3, warmup=0, weight_decay=0.0)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab)}
    with mesh:
        s1, _, _ = ts.make_train_step(cfg, mesh, 32, 4, ocfg, accum_steps=1)
        s2, _, _ = ts.make_train_step(cfg, mesh, 32, 4, ocfg, accum_steps=2)
        # steps donate their inputs: give each its own copy
        copy = lambda t: jax.tree_util.tree_map(jnp.copy, t)
        p1, o1, m1 = s1(copy(params), opt.init(ocfg, params), batch)
        p2, o2, m2 = s2(copy(params), opt.init(ocfg, params), batch)
    assert np.isclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree_util.tree_leaves(diffs)) < 5e-5
