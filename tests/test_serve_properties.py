"""Property suite for the twin service: fork trees and the snapshot codec.

Two invariants carry the whole serving design:

1. **Fork-tree oracle.** However a branch came to be — forked from a
   fork of a fork, at random interval boundaries, with random Scenario
   deltas, advanced through the session's coalescing batcher — its
   telemetry must equal a *phase-wise oracle*: one plain
   ``simulate_segment`` per tree edge, no segmentation, no
   serialization, no batching. Exact float equality, not tolerance.
2. **Snapshot codec.** ``encode_carry``/``decode_carry`` roundtrip any
   carry byte-faithfully (including NaN/±inf bit patterns) through
   strict JSON, malformed payloads fail with ``SnapshotError`` (never
   anything else), and a Frontier-scale snapshot reply still fits the
   transport's ``MAX_FRAME_BYTES`` frame cap.

The randomized exploration runs under hypothesis where installed (CI:
requirements-dev.txt); the same properties are also exercised with
fixed seeds so the oracle runs everywhere.
"""
import io
import json
import random

import jax
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import transport as tr
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.serve import protocol as proto
from repro.serve import snapshot as snap
from repro.serve.session import TwinSession
from repro.systems.config import get_system

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:       # local runs without the dev extras
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Inert stand-in so @given/strategy expressions still import."""
        def __call__(self, *a, **k):
            return self

        def __or__(self, other):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*a, **k):
        return lambda f: f

    settings = given

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="hypothesis not installed")

INTERVAL = 6
MAX_INTERVALS = 4
HORIZON = INTERVAL * MAX_INTERVALS

# knobs a random fork delta may draw from, with their value ranges
KNOB_DRAWS = {
    "setpoint_delta_c": (-3.0, 3.0),
    "cap_scale": (0.7, 1.2),
    "cells_offline": (0.0, 2.0),
    "alpha": (-1.0, 1.0),
}


@pytest.fixture(scope="module")
def case():
    system = get_system("marconi100").scaled(64)
    js = generate(system, WorkloadSpec(
        n_jobs=48, duration_s=2 * 3600.0, load=1.2, trace_len=8,
        n_accounts=8, mean_wall_s=1200.0, seed=9))
    js.assign_prepop_placement(0.0, system.n_nodes)
    return system, js.to_table(64)


def random_delta(rng: random.Random) -> dict:
    knobs = rng.sample(sorted(KNOB_DRAWS), rng.randint(1, 2))
    return {k: round(rng.uniform(*KNOB_DRAWS[k]), 3) for k in knobs}


def build_random_tree(rng: random.Random, sess: TwinSession,
                      n_forks: int) -> None:
    """Random interleaving of advances and forks against ``sess``."""
    for _ in range(n_forks):
        # advance a random subset of branches a random number of ticks
        branches = list(sess.branches)
        picks = rng.sample(branches, rng.randint(1, len(branches)))
        sess.advance_many({b: rng.randint(1, 2) for b in picks})
        parent = rng.choice(branches)
        ck = sorted(sess.branches[parent].checkpoints)
        sess.fork(parent, random_delta(rng), at_step=rng.choice(ck))
    # run every branch out to the horizon so each leaf has history
    sess.advance_many({b: MAX_INTERVALS for b in sess.branches})


def oracle_rows(sess: TwinSession, branch_id: int):
    """Phase-wise oracle for one branch: replay its ancestry with one
    plain ``simulate_segment`` per tree edge, return the branch's own
    rows (born_step .. step) in the session's fetch format."""
    system, table = sess.system, sess.table
    chain = []
    b = sess.branches[branch_id]
    while b is not None:
        chain.append(b)
        b = sess.branches[b.parent] if b.parent is not None else None
    chain.reverse()

    carry = eng.init_state(system, table, sess.t0, sess.t1, num_accounts=8)
    rows = []
    pos = 0
    leaf = chain[-1]
    for k, edge in enumerate(chain):
        stop = leaf.step if edge is leaf else chain[k + 1].born_step
        if stop == pos:
            continue
        carry, hist = eng.simulate_segment(system, table, carry,
                                           edge.scenario, stop - pos,
                                           sess.signals, sess.weather)
        if edge is leaf:
            from repro.obs import sink as obs_sink
            cat = {k: np.asarray(getattr(hist, k), np.float64)
                   for k in ("t",) + obs_sink.SCALAR_FIELDS}
            skip = leaf.born_step - pos
            for i in range(skip, stop - pos):
                row = {"step": pos + i}
                row.update({k: float(v[i]) for k, v in cat.items()})
                rows.append(row)
        pos = stop
    return rows


def check_fork_tree(case, seed: int, n_forks: int) -> None:
    system, table = case
    rng = random.Random(seed)
    sess = TwinSession(system, table, T.Scenario.make("fcfs", "easy"),
                       0.0, HORIZON * system.dt, interval_steps=INTERVAL,
                       num_accounts=8)
    build_random_tree(rng, sess, n_forks)
    assert len(sess.branches) == n_forks + 1
    for branch_id in sess.branches:
        got = sess.fetch(branch_id)["rows"]
        want = oracle_rows(sess, branch_id)
        assert len(got) == len(want), f"branch {branch_id}"
        for g, w in zip(got, want):
            assert g == w, (f"branch {branch_id} step {g['step']}: "
                            f"{g} != {w}")


@pytest.mark.timeout(600)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fork_tree_matches_phasewise_oracle(case, seed):
    check_fork_tree(case, seed, n_forks=3)


@needs_hypothesis
@pytest.mark.timeout(600)
@given(seed=st.integers(0, 2**32 - 1), n_forks=st.integers(1, 4))
@settings(max_examples=5, deadline=None)
def test_fork_tree_matches_phasewise_oracle_hypothesis(case, seed, n_forks):
    check_fork_tree(case, seed, n_forks)


# ---------------------------------------------------------------------------
# Snapshot codec properties.
# ---------------------------------------------------------------------------
def randomized_carry(template, seed: int):
    """A carry with every leaf's bytes randomized (same dtype/shape),
    seasoned with NaN/±inf in the float leaves — the adversarial case
    for a JSON codec, trivial for a raw-bytes one."""
    rng = np.random.default_rng(seed)
    def scramble(x):
        a = np.asarray(x)
        if np.issubdtype(a.dtype, np.floating):
            out = rng.normal(size=a.shape).astype(a.dtype)
            flat = out.reshape(-1)
            if flat.size >= 4:
                flat[0], flat[1], flat[2] = np.nan, np.inf, -np.inf
            return flat.reshape(a.shape)
        info = np.iinfo(a.dtype)
        return rng.integers(info.min, info.max, size=a.shape,
                            dtype=a.dtype, endpoint=True)
    return jax.tree_util.tree_map(scramble, template)


def check_roundtrip(template, seed: int) -> None:
    carry = randomized_carry(template, seed)
    payload = json.loads(json.dumps(snap.encode_carry(carry)))
    out = snap.decode_carry(payload, template)
    for (p, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(carry)[0],
            jax.tree_util.tree_flatten_with_path(out)[0]):
        a, b = np.asarray(a), np.asarray(b)
        assert a.tobytes() == b.tobytes(), jax.tree_util.keystr(p)
    # digest is a function of the bytes alone: stable across re-encodes
    assert (snap.snapshot_digest(snap.encode_carry(out))
            == snap.snapshot_digest(payload))


@pytest.fixture(scope="module")
def template(case):
    system, table = case
    return eng.init_state(system, table, 0.0, HORIZON * 20.0,
                          num_accounts=8)


@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_snapshot_roundtrip_byte_faithful(template, seed):
    check_roundtrip(template, seed)


@needs_hypothesis
@given(seed=st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_snapshot_roundtrip_byte_faithful_hypothesis(template, seed):
    check_roundtrip(template, seed)


@needs_hypothesis
@given(st.recursive(
    st.none() | st.booleans() | st.integers() | st.floats(allow_nan=False)
    | st.text(max_size=8),
    lambda inner: st.lists(inner, max_size=4)
    | st.dictionaries(st.text(max_size=8), inner, max_size=4),
    max_leaves=20))
@settings(max_examples=100, deadline=None)
def test_decode_rejects_garbage_with_snapshot_error(template, payload):
    """Whatever JSON arrives, decode either succeeds or raises
    ``SnapshotError`` — never KeyError/TypeError/ValueError leakage."""
    try:
        snap.decode_carry(payload, template)
    except snap.SnapshotError:
        pass


def test_decode_rejects_wrong_shape_and_version(template):
    good = snap.encode_carry(template)
    with pytest.raises(snap.SnapshotError, match="version"):
        snap.decode_carry({**good, "v": 99}, template)
    mangled = json.loads(json.dumps(good))
    mangled["leaves"]["t"]["shape"] = [3]
    with pytest.raises(snap.SnapshotError):
        snap.decode_carry(mangled, template)
    dropped = json.loads(json.dumps(good))
    del dropped["leaves"]["node_job"]
    with pytest.raises(snap.SnapshotError, match="node_job"):
        snap.decode_carry(dropped, template)


def test_scenario_delta_rejects_unknown_knobs():
    base = T.Scenario.make("fcfs")
    with pytest.raises(snap.SnapshotError, match="unknown scenario knob"):
        snap.apply_scenario_delta(base, {"warp_factor": 9})
    with pytest.raises(snap.SnapshotError):
        snap.apply_scenario_delta(base, {"policy": "telepathy"})
    with pytest.raises(snap.SnapshotError):
        snap.apply_scenario_delta(base, {"cap_scale": "big"})
    # and the happy path maps names to traced ids
    scen = snap.apply_scenario_delta(base, {"policy": "thermal_aware",
                                            "cap_scale": 0.9})
    assert int(scen.policy) == T.POLICY_NAMES["thermal_aware"]
    assert float(scen.cap_scale) == pytest.approx(0.9)


def test_scenario_delta_validates_vector_shapes():
    """A delta that would reshape a traced knob must fail at fork time
    with ``SnapshotError`` — not escape into the server's coalesced
    sweep and kill the executor as a JAX trace error (the batch stacks
    every branch's scenario leaf-wise, so shapes must agree)."""
    flat = T.Scenario.make("fcfs")                          # scalar knobs
    halls = T.Scenario.make("fcfs",
                            cells_offline=(0.0, 0.0, 0.0, 0.0))
    with pytest.raises(snap.SnapshotError, match="scalar in this session"):
        snap.apply_scenario_delta(flat, {"cells_offline": [1.0, 0.0]})
    with pytest.raises(snap.SnapshotError, match="length 4"):
        snap.apply_scenario_delta(halls, {"cells_offline": [1.0]})
    with pytest.raises(snap.SnapshotError, match="length 4"):
        snap.apply_scenario_delta(
            halls, {"cells_offline": [1.0, 0.0, 0.0, 0.0, 0.0]})
    with pytest.raises(snap.SnapshotError, match="scalar in this session"):
        snap.apply_scenario_delta(flat, {"alpha": [0.1, 0.2, 0.3]})
    # a matching-length vector keeps the shape ...
    out = snap.apply_scenario_delta(halls,
                                    {"cells_offline": [1.0, 0.0, 0.0, 0.0]})
    assert out.cells_offline.shape == (4,)
    # ... and a scalar broadcasts explicitly over a vector knob
    out = snap.apply_scenario_delta(halls, {"cells_offline": 2.0})
    assert out.cells_offline.shape == (4,)
    assert np.array_equal(np.asarray(out.cells_offline),
                          np.full(4, 2.0, np.float32))


@pytest.mark.timeout(300)
def test_frontier_scale_snapshot_fits_one_frame():
    """A full Frontier-scale carry (9408-node class system, 1k-job padded
    table), wrapped in a complete ``snapshot_ok`` reply envelope, must
    ride the existing transport framing — ``write_frame`` enforces
    ``MAX_FRAME_BYTES`` outbound, so this is the real cap, not an
    estimate."""
    system = get_system("frontier")
    js = generate(system, WorkloadSpec(
        n_jobs=512, duration_s=4 * 3600.0, load=1.0, trace_len=8,
        n_accounts=32, mean_wall_s=1800.0, seed=1))
    js.assign_prepop_placement(0.0, system.n_nodes)
    table = js.to_table(1024)
    carry = eng.init_state(system, table, 0.0, 4 * 3600.0,
                           num_accounts=64)
    payload = snap.encode_carry(carry)
    frame = proto.ok_frame("snapshot", 0, {
        "branch": 0, "step": 0, "snapshot": payload,
        "digest": snap.snapshot_digest(payload)})
    buf = io.BytesIO()
    counters = tr.WireCounters()
    tr.write_frame(buf, frame, counters)      # raises past the cap
    assert counters.frames_rejected == 0
    assert buf.tell() < tr.MAX_FRAME_BYTES
    # sanity: it decodes back bitwise
    out = snap.decode_carry(
        json.loads(buf.getvalue())["snapshot"], carry)
    for (p, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(carry)[0],
            jax.tree_util.tree_flatten_with_path(out)[0]):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
