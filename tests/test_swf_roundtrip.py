"""SWF export/import roundtrip regression (Chapin et al. [13]).

``write_swf`` → ``read_swf`` must preserve the scheduling-relevant
columns (submit / wall / nodes / limit / account) within whole-second
rounding: this is the dataloader contract the out-of-process handshake's
job digest (``core/transport.job_digest``) is computed over, so drift
here silently breaks digest-checked peer resyncs.
"""
import numpy as np
import pytest

from repro.core import transport as tr
from repro.datasets.base import JobSet
from repro.datasets.swf import read_swf, write_swf


def synth_jobset(seed=0, n=50, n_accounts=32):
    rng = np.random.default_rng(seed)
    submit = np.sort(rng.uniform(0.0, 86400.0, n))
    wall = rng.uniform(60.0, 7200.0, n)
    nodes = rng.integers(1, 128, n)
    wait = rng.uniform(0.0, 3600.0, n)
    rec_start = submit + wait
    # a tail of jobs that never started (SWF wait = -1 on export)
    rec_start[-3:] = np.inf
    J = n
    return JobSet(submit=submit, limit=wall * rng.uniform(1.1, 3.0, n),
                  wall=wall, nodes=nodes.astype(np.int64),
                  priority=rng.uniform(0, 10, n),
                  account=rng.integers(0, n_accounts, n),
                  rec_start=rec_start,
                  power_prof=np.full((J, 1), 500.0, np.float32),
                  util_prof=np.full((J, 1), 0.7, np.float32),
                  name="synthetic")


def test_roundtrip_preserves_columns_within_rounding(tmp_path):
    js = synth_jobset(seed=3)
    path = str(tmp_path / "trace.swf")
    write_swf(js, path)
    back = read_swf(path)
    assert len(back) == len(js)
    # :.0f export rounds each time to the nearest whole second
    assert np.abs(back.submit - js.submit).max() <= 0.5
    assert np.abs(back.wall - js.wall).max() <= 0.5
    assert np.abs(back.limit - js.limit).max() <= 0.5
    assert np.array_equal(back.nodes, js.nodes)
    assert np.array_equal(back.account, js.account)


def test_roundtrip_preserves_never_started_jobs(tmp_path):
    """inf rec_start must survive as inf, not parse as a bogus wait."""
    js = synth_jobset(seed=4)
    path = str(tmp_path / "trace.swf")
    write_swf(js, path)
    back = read_swf(path)
    assert (np.isfinite(back.rec_start) == np.isfinite(js.rec_start)).all()
    fin = np.isfinite(js.rec_start)
    # submit and wait each round independently: at most 1 s of drift
    assert np.abs(back.rec_start[fin] - js.rec_start[fin]).max() <= 1.0
    # and the file itself contains no inf/nan tokens (SWF is numeric)
    text = (tmp_path / "trace.swf").read_text()
    assert "inf" not in text and "nan" not in text


def test_roundtrip_preserves_job_digest(tmp_path):
    """The handshake digest is whole-second canonical, so an SWF trip
    (which rounds with the same half-even rule) must not change it."""
    js = synth_jobset(seed=5)
    path = str(tmp_path / "trace.swf")
    write_swf(js, path)
    back = read_swf(path)
    assert tr.job_digest(back) == tr.job_digest(js)


def test_read_swf_skips_comments_and_short_rows(tmp_path):
    path = tmp_path / "messy.swf"
    path.write_text(
        "; header comment\n"
        "\n"
        "1 2 3\n"  # short row: ignored
        "1 100 50 3600 16 0 0 16 7200 0 1 5 5 0 0 0 0 0\n")
    js = read_swf(str(path))
    assert len(js) == 1
    assert js.submit[0] == 100.0 and js.wall[0] == 3600.0
    assert js.nodes[0] == 16 and js.limit[0] == 7200.0
    assert js.account[0] == 4
    assert js.rec_start[0] == 150.0


def test_read_swf_falls_back_to_allocated_procs(tmp_path):
    """Requested procs 0/missing -> allocated procs column (SWF spec)."""
    path = tmp_path / "alloc.swf"
    path.write_text("1 0 0 600 8 0 0 0 0 0 1 1 1 0 0 0 0 0\n")
    js = read_swf(str(path))
    assert js.nodes[0] == 8
    assert js.limit[0] == 1200.0  # missing limit -> 2x runtime fallback
