"""End-to-end behaviour tests for the S-RAPS twin engine."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system


def run(system, table, policy, backfill, t0, t1):
    scen = T.Scenario.make(policy, backfill)
    return eng.simulate(system, table, scen, t0, t1)


def test_replay_matches_recorded_schedule(small_system, small_jobs,
                                          small_table):
    """Replay must start every in-window job at its recorded start (to one
    engine step of resolution) — paper §3.2.2."""
    t0, t1 = 0.0, 4 * 3600.0
    final, hist = run(small_system, small_table, "replay", "none", t0, t1)
    jstate = np.asarray(final.jstate)
    start = np.asarray(final.start)
    rec = small_jobs.rec_start
    J = len(small_jobs)
    started = (jstate[:J] == T.RUNNING) | (jstate[:J] == T.DONE)
    in_window = (rec + small_jobs.wall > t0) & (rec < t1 - small_system.dt)
    # every in-window recorded job actually started
    assert (started[in_window]).all()
    err = np.abs(start[:J][started & in_window] - rec[started & in_window])
    assert err.max() <= small_system.dt + 1e-3


def test_energy_is_integral_of_power(small_system, small_table):
    final, hist = run(small_system, small_table, "fcfs", "first-fit",
                      0.0, 2 * 3600.0)
    p = np.asarray(hist.power_total, np.float64)
    e = p.sum() * small_system.dt
    assert np.isclose(e, float(final.energy_total), rtol=1e-4)
    e_it = np.asarray(hist.power_it, np.float64).sum() * small_system.dt
    assert np.isclose(e_it, float(final.energy_it), rtol=1e-4)


def test_no_double_allocation_and_capacity(small_system, small_table):
    """Node occupancy equals the summed node counts of running jobs."""
    scen = T.Scenario.make("fcfs", "easy")
    st = eng.init_state(small_system, small_table, 0.0, 7200.0)
    for _ in range(60):
        st, _ = jax.jit(eng.engine_step, static_argnums=0)(
            small_system, small_table, st, scen)
        node_job = np.asarray(st.node_job)
        jstate = np.asarray(st.jstate)
        running = np.nonzero(jstate == T.RUNNING)[0]
        occ = node_job[node_job >= 0]
        # every occupied node belongs to a running job
        assert set(np.unique(occ)).issubset(set(running.tolist()))
        # each running job occupies exactly its requested nodes
        nodes = np.asarray(small_table.nodes)
        for j in running:
            assert (node_job == j).sum() == nodes[j]
        assert int(st.free_count) == (node_job < 0).sum()


def test_jobs_never_start_before_submit(small_system, small_table):
    final, _ = run(small_system, small_table, "sjf", "first-fit",
                   0.0, 4 * 3600.0)
    start = np.asarray(final.start)
    submit = np.asarray(small_table.submit)
    done = np.asarray(final.jstate) >= T.RUNNING
    started = np.isfinite(start) & done
    # prepopulated jobs (recorded start before window) are exempt
    prepop = np.asarray(small_table.rec_start) < 0.0
    m = started & ~prepop & (start > 0)
    assert (start[m] >= submit[m] - 1e-3).all()


def test_dismissal_outside_window(small_system, small_jobs):
    t0 = 3600.0
    table = small_jobs.to_table()
    st = eng.init_state(small_system, table, t0, 2 * 3600.0)
    jstate = np.asarray(st.jstate)
    rec_end = small_jobs.rec_start + small_jobs.wall
    ended_before = rec_end <= t0
    assert (jstate[:len(small_jobs)][ended_before] == T.DISMISSED).all()


def test_prepopulation_occupies_nodes(small_system, small_jobs):
    t0 = 2 * 3600.0
    small_jobs.assign_prepop_placement(t0, small_system.n_nodes)
    table = small_jobs.to_table()
    st = eng.init_state(small_system, table, t0, 4 * 3600.0)
    running0 = (small_jobs.rec_start <= t0) & \
               (small_jobs.rec_start + small_jobs.wall > t0) & \
               (small_jobs.first_node >= 0)
    expected = small_jobs.nodes[running0].sum()
    assert int(small_system.n_nodes - st.free_count) == expected


def test_sweep_matches_individual_runs(small_system, small_table):
    scens = [T.Scenario.make("fcfs", "none"),
             T.Scenario.make("fcfs", "easy")]
    f_sweep, h_sweep = eng.simulate_sweep(small_system, small_table, scens,
                                          0.0, 3600.0)
    for i, (p, b) in enumerate([("fcfs", "none"), ("fcfs", "easy")]):
        f, h = run(small_system, small_table, p, b, 0.0, 3600.0)
        np.testing.assert_allclose(np.asarray(h.power_it),
                                   np.asarray(h_sweep.power_it)[i],
                                   rtol=1e-6)
        assert float(f.completed) == float(f_sweep.completed[i])


def test_backfill_improves_utilization_under_backlog(small_system):
    """Paper Fig. 4: a wide job blocks the strict-FIFO queue; first-fit and
    EASY backfill the small jobs into the hole and raise utilization."""
    from repro.datasets.base import JobSet
    N = small_system.n_nodes  # 64
    # j0 runs (48 nodes); j1 (32 nodes) blocks; j2.. (8 nodes) can backfill
    n_small = 8
    submit = np.array([0.0, 30.0] + [60.0] * n_small)
    nodes = np.array([48, 32] + [8] * n_small, np.int64)
    wall = np.array([1800.0, 900.0] + [600.0] * n_small)
    limit = wall.copy()
    J = len(submit)
    js = JobSet(submit=submit, limit=limit, wall=wall, nodes=nodes,
                priority=np.zeros(J), account=np.zeros(J, np.int64),
                rec_start=submit,
                power_prof=np.full((J, 1), 1000.0, np.float32),
                util_prof=np.full((J, 1), 0.8, np.float32))
    table = js.to_table(16)
    _, h_none = run(small_system, table, "fcfs", "none", 0.0, 3600.0)
    _, h_ff = run(small_system, table, "fcfs", "first-fit", 0.0, 3600.0)
    _, h_easy = run(small_system, table, "fcfs", "easy", 0.0, 3600.0)
    # compare over the blocking interval (while j0 still runs): that is
    # where backfill fills the hole; over a long-enough window total work is
    # conserved and the averages converge.
    k = int(1800.0 / small_system.dt)
    u_none = np.asarray(h_none.util)[:k].mean()
    u_ff = np.asarray(h_ff.util)[:k].mean()
    u_easy = np.asarray(h_easy.util)[:k].mean()
    assert u_ff > u_none + 0.02   # strictly better under backlog
    assert u_easy > u_none + 0.02
    # EASY with truthful limits must not delay the blocked head job (j1)
    f_none, _ = run(small_system, table, "fcfs", "none", 0.0, 3600.0)
    f_easy, _ = run(small_system, table, "fcfs", "easy", 0.0, 3600.0)
    assert float(np.asarray(f_easy.start)[1]) <= \
        float(np.asarray(f_none.start)[1]) + 1e-3


def test_external_step_places_requested_jobs(small_system, small_table):
    st = eng.init_state(small_system, small_table, 0.0, 3600.0)
    # advance once to enqueue arrivals
    st, _ = eng.external_step(small_system, small_table, st,
                              jnp.full((8,), -1, jnp.int32))
    queued = np.nonzero(np.asarray(st.jstate) == T.QUEUED)[0]
    nodes = np.asarray(small_table.nodes)
    pick = [int(j) for j in queued if nodes[j] <= int(st.free_count)][:2]
    if not pick:
        pytest.skip("no queued jobs fit at t0")
    ids = np.full((8,), -1, np.int32)
    ids[:len(pick)] = pick
    st2, _ = eng.external_step(small_system, small_table, st,
                               jnp.asarray(ids))
    jstate = np.asarray(st2.jstate)
    assert (jstate[pick] == T.RUNNING).all()


def test_static_fast_path_matches_traced(small_system, small_table):
    """simulate_static (compile-time policy) must produce identical physics
    to the traced-scenario engine."""
    for pol, bf in [("fcfs", "first-fit"), ("sjf", "easy"),
                    ("replay", "none")]:
        f1, h1 = run(small_system, small_table, pol, bf, 0.0, 2 * 3600.0)
        f2, h2 = eng.simulate_static(small_system, small_table, pol, bf,
                                     0.0, 2 * 3600.0)
        np.testing.assert_allclose(np.asarray(h1.power_it),
                                   np.asarray(h2.power_it), rtol=1e-6)
        assert float(f1.completed) == float(f2.completed)
