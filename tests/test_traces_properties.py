"""Property battery for trace ingestion (repro.traces).

The loaders promise exact structural invariants, not best-effort parsing:

* **Roundtrip closure.** Any valid job table — arbitrary times, node
  counts, user labels, never-started jobs — survives a
  ``read -> write_job_table -> read`` cycle with its ``job_digest``
  (and every digest-covered column) unchanged: whole-second rounding is
  idempotent and first-seen account densification is a fixed point.
* **Loud failure.** A malformed row (NaN time, non-positive duration,
  fractional or zero nodes, start before submit) raises ``TraceError``
  naming the row. Rows are never silently dropped: a frame either loads
  with *all* its rows or not at all.
* **Physical weather.** For any monotone trace, the resampled wet-bulb
  is finite everywhere and never exceeds its dry-bulb, on and off the
  source grid; non-monotone timestamps and out-of-range humidity raise
  ``TraceError`` instead of interpolating garbage.

Runs under hypothesis where installed; every property also runs with
fixed seeds so the battery works without the dev extras (mirroring
tests/test_events_properties.py).
"""
import numpy as np
import pandas as pd
import pytest

from repro.core import transport
from repro.traces import (TraceError, jobset_from_frame, load_weather,
                          read_job_table, wet_bulb_stull, write_job_table)

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:       # local runs without the dev extras
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Inert stand-in so @given/strategy expressions still import."""
        def __call__(self, *a, **k):
            return self

        def __or__(self, other):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*a, **k):
        return lambda f: f

    settings = given

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="hypothesis not installed")

SEEDS = st.integers(min_value=0, max_value=2 ** 16)
SIZES = st.integers(min_value=1, max_value=64)


# ---------------------------------------------------------------------------
# Frame generator shared by the hypothesis and seeded lanes.
# ---------------------------------------------------------------------------
def random_frame(seed: int, n: int) -> pd.DataFrame:
    """A valid random job table: exponential-ish times, duplicate and
    exotic user labels, a sprinkle of never-started jobs (NaN start/end
    with a recorded run_time)."""
    rng = np.random.default_rng(seed)
    submit = np.sort(rng.uniform(0, 1e6, n))
    wall = rng.uniform(1.0, 1e5, n)
    start = submit + rng.exponential(1e3, n)
    never = rng.random(n) < 0.15
    start[never] = np.nan
    users = rng.choice(
        ["alice", "bob", "u-10", "u-2", "群", "x" * 30, "9", "10"], n)
    return pd.DataFrame({
        "job_id": np.arange(n),
        "submit_time": submit,
        "start_time": start,
        "end_time": start + wall,
        "run_time": wall,
        "num_nodes": rng.integers(1, 128, n),
        "time_limit": np.ceil(wall / 60.0) * rng.uniform(1.0, 4.0, n),
        "user_id": users,
    })


def _check_roundtrip(seed, n, tmp_path, ext):
    src = tmp_path / f"src_{seed}_{n}.{ext}"
    random_frame(seed, n).to_csv(src, index=False) if ext == "csv" \
        else random_frame(seed, n).to_parquet(src, index=False)
    js = read_job_table(src)
    assert len(js) == n, "valid rows must never be dropped"
    out = tmp_path / f"rt_{seed}_{n}.{ext}"
    write_job_table(js, out)
    back = read_job_table(out)
    assert transport.job_digest(back) == transport.job_digest(js)
    for col in ("submit", "limit", "wall", "nodes", "account"):
        np.testing.assert_array_equal(getattr(back, col), getattr(js, col),
                                      err_msg=f"{col} seed={seed}")
    # rec_start survives too (inf marks never-started on both sides)
    np.testing.assert_array_equal(back.rec_start, js.rec_start)


@needs_hypothesis
@settings(max_examples=20, deadline=None)
@given(seed=SEEDS, n=SIZES)
def test_roundtrip_closure_hypothesis(seed, n, tmp_path_factory):
    _check_roundtrip(seed, n, tmp_path_factory.mktemp("rt"), "csv")


def test_roundtrip_closure_seeded(tmp_path):
    for seed in (0, 7, 12345):
        for n in (1, 13, 64):
            _check_roundtrip(seed, n, tmp_path, "csv")
    _check_roundtrip(99, 40, tmp_path, "parquet")


def test_rounding_is_idempotent(tmp_path):
    """Second ingest of an exported table is byte-stable: whole-second
    rounding applied twice equals once."""
    src = tmp_path / "a.csv"
    random_frame(3, 32).to_csv(src, index=False)
    js1 = read_job_table(src)
    write_job_table(js1, tmp_path / "b.csv")
    js2 = read_job_table(tmp_path / "b.csv")
    write_job_table(js2, tmp_path / "c.csv")
    js3 = read_job_table(tmp_path / "c.csv")
    for col in ("submit", "limit", "wall", "nodes", "account", "rec_start"):
        np.testing.assert_array_equal(getattr(js2, col), getattr(js3, col),
                                      err_msg=col)


# ---------------------------------------------------------------------------
# Loud failure: malformed rows raise, never a silent drop.
# ---------------------------------------------------------------------------
CORRUPTIONS = {
    "nan submit": ("submit_time", 0, np.nan),
    "nan duration": ("run_time", 1, np.nan),
    "negative duration": ("run_time", 2, -5.0),
    "zero duration": ("run_time", 2, 0.0),
    "zero nodes": ("num_nodes", 3, 0),
    "negative nodes": ("num_nodes", 4, -2),
    "start before submit": ("start_time", 5, -1e9),
    "zero limit": ("time_limit", 6, 0.0),
}


def _corrupt(seed, name):
    col, row, val = CORRUPTIONS[name]
    df = random_frame(seed, 16)
    if name.endswith("duration"):
        # duration comes from end-start when end resolves; break both
        df.loc[row, "end_time"] = df.loc[row, "start_time"] + val
    df.loc[row, col] = val
    with pytest.raises(TraceError) as exc:
        jobset_from_frame(df)
    assert str(row) in str(exc.value), \
        f"{name}: error must name the offending row"


@needs_hypothesis
@settings(max_examples=20, deadline=None)
@given(seed=SEEDS, name=st.sampled_from(sorted(CORRUPTIONS)))
def test_malformed_rows_raise_hypothesis(seed, name):
    _corrupt(seed, name)


def test_malformed_rows_raise_seeded():
    for seed in (0, 11):
        for name in CORRUPTIONS:
            _corrupt(seed, name)


def test_missing_columns_raise():
    df = random_frame(5, 8).drop(columns=["num_nodes"])
    with pytest.raises(TraceError):
        jobset_from_frame(df)
    df = random_frame(5, 8).drop(columns=["end_time", "run_time"])
    with pytest.raises(TraceError):
        jobset_from_frame(df)
    with pytest.raises(TraceError):
        jobset_from_frame(pd.DataFrame({"submit_time": []}))


# ---------------------------------------------------------------------------
# Weather: always finite, always physical.
# ---------------------------------------------------------------------------
def random_weather(seed: int, rows: int) -> pd.DataFrame:
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.uniform(60.0, 7200.0, rows))
    return pd.DataFrame({
        "timestamp": t,
        "t_drybulb_c": rng.uniform(-30.0, 48.0, rows),
        "rh_pct": rng.uniform(0.0, 100.0, rows),
    })


def _check_weather(seed, rows, n_steps, dt, tmp_path):
    src = tmp_path / f"wx_{seed}_{rows}.csv"
    random_weather(seed, rows).to_csv(src, index=False)
    w = load_weather(src, n_steps, dt)
    wb = np.asarray(w.t_wetbulb_c, np.float64)
    db = np.asarray(w.t_drybulb_c, np.float64)
    assert wb.shape == (n_steps,) and db.shape == (n_steps,)
    assert np.isfinite(wb).all(), "wet-bulb must be finite everywhere"
    assert np.isfinite(db).all()
    assert (wb <= db + 1e-6).all(), "wet-bulb must not exceed dry-bulb"


@needs_hypothesis
@settings(max_examples=20, deadline=None)
@given(seed=SEEDS, rows=st.integers(min_value=2, max_value=200),
       n_steps=st.integers(min_value=1, max_value=500))
def test_weather_physical_hypothesis(seed, rows, n_steps, tmp_path_factory):
    _check_weather(seed, rows, n_steps, 20.0, tmp_path_factory.mktemp("wx"))


def test_weather_physical_seeded(tmp_path):
    for seed in (0, 4, 99):
        for rows, n_steps, dt in ((2, 1, 20.0), (24, 360, 20.0),
                                  (200, 500, 900.0)):
            _check_weather(seed, rows, n_steps, dt, tmp_path)


def test_weather_stull_clamp_extremes():
    # dry air at the formula's edge: Stull can nominally exceed the
    # dry-bulb near 0% RH — the loader clamp keeps wb <= db
    t = np.array([-40.0, 0.0, 25.0, 50.0])
    for rh in (0.0, 1e-3, 50.0, 100.0):
        wb = wet_bulb_stull(t, np.full_like(t, rh))
        assert np.isfinite(wb).all()
        assert (wb <= t + 1e-9).all()


def test_weather_rejects_non_monotone(tmp_path):
    df = random_weather(1, 16)
    df.loc[7, "timestamp"] = df.loc[3, "timestamp"]   # duplicate -> not
    df = df.sort_values("timestamp")                  # strictly increasing
    df.to_csv(tmp_path / "wx.csv", index=False)
    with pytest.raises(TraceError):
        load_weather(tmp_path / "wx.csv", 10, 20.0)


def test_weather_rejects_bad_humidity(tmp_path):
    df = random_weather(2, 16)
    df.loc[5, "rh_pct"] = 130.0
    df.to_csv(tmp_path / "wx.csv", index=False)
    with pytest.raises(TraceError):
        load_weather(tmp_path / "wx.csv", 10, 20.0)
    df.loc[5, "rh_pct"] = np.nan
    df.to_csv(tmp_path / "wx.csv", index=False)
    with pytest.raises(TraceError):
        load_weather(tmp_path / "wx.csv", 10, 20.0)
