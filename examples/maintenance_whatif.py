"""Maintenance / loop-failure what-if on a hierarchical facility:
"hall A loses half its tower cells during a heat wave — what happens, and
does a cooling-aware schedule help?"

A (policy x per-hall weather x cells-offline) grid over the SAME
oversubscribed half-day of work on a 4-hall machine, all batched into ONE
compiled program — ``engine.simulate_sweep_sharded`` shards the scenario
axis across devices when more than one is visible (shard_map over a
("scenario",) mesh) and degenerates to the single vmapped program
otherwise:

  policy        : fcfs           vs  thermal_aware (defers heat-dense
                                     jobs under cooling pressure)
  weather       : uniform summer vs  the same traces with a 10 °C heat
                                     wave hitting only halls 0-1 (per-hall
                                     traces, ``weather.stack_halls`` — the
                                     sun-side towers)
  cells offline : none           vs  2 of hall 0's 4 tower cells out for
                                     maintenance (``Scenario.cells_offline``)

Whatever the policy, placement itself is hall-aware: the resource manager
drains nodes coolest-hall-first and the per-hall admission gate stops
feeding a hall that has lost its supply setpoint. The run prints per-hall
IT-power shares, basin peaks and gate engagement, then checks the
acceptance claims: the degraded hall sheds load share (placement shifts
work away from it), and thermal_aware lowers the facility's peak tower
return temperature under the degraded heat-wave scenario.

  PYTHONPATH=src python examples/maintenance_whatif.py
"""
import dataclasses

import numpy as np

from repro.cooling import weather as wx
from repro.core import engine, types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import FacilityTopology, get_system

N_HALLS = 4


def build_system():
    base = get_system("marconi100").scaled(128)
    # 4 halls x 2 CDU groups x 2 tower cells; towers sized ~2x nominal so
    # maintenance bites, and a tight soft band so the scheduler sees
    # cooling pressure well before the hard limit
    cooling = dataclasses.replace(
        base.cooling, n_groups=8, n_tower_cells=8,
        cell_rated_heat_w=1.2e5, fan_rated_w=4e3,
        t_return_limit_c=34.0, thermal_margin_c=5.0, t_supply_margin_c=5.0,
        topology=FacilityTopology(n_halls=N_HALLS))
    return dataclasses.replace(base, cooling=cooling)


def build_weather(system, n_steps):
    """Two per-hall weather sets: uniform summer, and the same summer with
    a heat wave hitting only halls 0 and 1."""
    base = [wx.synthetic_weather(n_steps, system.dt, t_wb_mean_c=19.0,
                                 seed=21 + h) for h in range(N_HALLS)]
    uniform = wx.stack_halls(base)
    wave = [wx.heat_wave(tr, system.dt, start_s=0.15 * n_steps * system.dt,
                         duration_s=0.5 * n_steps * system.dt,
                         peak_amp_c=10.0) if h < 2 else tr
            for h, tr in enumerate(base)]
    return uniform, wx.stack_halls(wave)


def main():
    system = build_system()
    t1 = 0.5 * 86400.0
    n_steps = int(t1 / system.dt)
    jobs = generate(system, WorkloadSpec(
        n_jobs=600, duration_s=t1, load=2.0, trace_len=8,
        mean_wall_s=2400.0, n_accounts=16, seed=9))
    jobs.assign_prepop_placement(0.0, system.n_nodes)
    table = jobs.to_table()

    uniform, wavey = build_weather(system, n_steps)
    degraded = tuple([2.0] + [0.0] * (N_HALLS - 1))

    scens, weathers, names = [], [], []
    for pol, weight in [("fcfs", 0.0), ("thermal_aware", 200.0)]:
        for wname, trace in [("uniform", uniform), ("wave01", wavey)]:
            for mname, cells in [("allup", 0.0), ("hall0-2cells", degraded)]:
                scens.append(T.Scenario.make(
                    pol, "first-fit", thermal_weight=weight,
                    cells_offline=cells))
                weathers.append(trace)
                names.append(f"{pol}/{wname}/{mname}")

    finals, hists = engine.simulate_sweep_sharded(
        system, table, scens, 0.0, t1, num_accounts=16, weather=weathers)

    p_hall = np.asarray(hists.power_it_hall, np.float64)   # [S, steps, H]
    t_ret = np.asarray(hists.t_tower_return)
    t_basin_h = np.asarray(hists.t_basin_hall)
    gate = np.asarray(hists.thermal_throttled)
    done = np.asarray(finals.completed)
    half = p_hall.shape[1] // 2
    share = p_hall[:, half:, :].sum(1) / \
        np.maximum(p_hall[:, half:, :].sum((1, 2))[:, None], 1.0)

    hdr = (f"{'scenario':>32s} {'done':>5s} {'hall shares (back half)':>28s} "
           f"{'peak t_ret':>10s} {'peak basin0':>11s} {'gate':>5s}")
    print(hdr)
    for i, n in enumerate(names):
        shares = "/".join(f"{s:.2f}" for s in share[i])
        print(f"{n:>32s} {done[i]:5.0f} {shares:>28s} "
              f"{t_ret[i].max():9.2f}C {t_basin_h[i, :, 0].max():10.2f}C "
              f"{gate[i].sum():5.0f}")

    idx = {n: i for i, n in enumerate(names)}
    # claim 1: under maintenance, placement shifts load away from hall 0
    # (any policy — the resource manager itself is hall-aware)
    for pol in ("fcfs", "thermal_aware"):
        s_up = share[idx[f"{pol}/wave01/allup"], 0]
        s_dn = share[idx[f"{pol}/wave01/hall0-2cells"], 0]
        print(f"\n{pol}: hall-0 load share {s_up:.3f} -> {s_dn:.3f} "
              f"with 2 cells offline")
        assert s_dn < s_up - 0.02, \
            "placement should shift load away from the degraded hall"
    # claim 2: thermal_aware lowers the peak tower return temperature in
    # the degraded heat-wave scenario vs FCFS
    f_peak = t_ret[idx["fcfs/wave01/hall0-2cells"]].max()
    t_peak = t_ret[idx["thermal_aware/wave01/hall0-2cells"]].max()
    print(f"peak tower return under wave+maintenance: "
          f"fcfs={f_peak:.2f}C thermal_aware={t_peak:.2f}C")
    assert t_peak < f_peak, \
        "thermal_aware should cut the peak tower return temperature"


if __name__ == "__main__":
    main()
