"""Close the ML scheduling loop (paper §4.4 + contribution (5)):
cluster -> classify -> predict -> score -> TRAIN -> sweep.

Train-under-stress, evaluate-nominal: the ES loop (repro.ml.train)
optimizes the scoring alpha while the twin simulates a heat wave with two
tower cells out per hall — then the trained policy is judged on the
nominal (typical-weather, full-plant) window against the hand-set default
alpha and the classic policies. Every training generation is ONE batched
rollout (population on the scenario axis).

  PYTHONPATH=src python examples/ml_scheduling.py
"""
import numpy as np

from repro.cooling import weather as wx
from repro.core import engine, stats, types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.ml import train as ml_train
from repro.ml.pipeline import MLSchedulerModel, attach_basis
from repro.systems.config import get_system

REWARD = "wait=1,turnaround=0.5,energy=0.25,unfinished=0.5,overheat=2"


def main():
    system = get_system("marconi100").scaled(64)
    t1 = 3 * 3600.0
    n_steps = int(round(t1 / system.dt))

    print("offline phase: cluster / classify / fit per-cluster predictors")
    hist_jobs = generate(system, WorkloadSpec(
        n_jobs=400, duration_s=2 * 86400.0, load=0.9, trace_len=8,
        n_accounts=16, seed=30))
    model = MLSchedulerModel.fit(hist_jobs, k=4, n_trees=6, depth=5)

    # the training workload: contended, scored via the basis so alpha is a
    # traced Scenario knob (ml.pipeline.attach_basis)
    jobs = generate(system, WorkloadSpec(
        n_jobs=170, duration_s=t1, load=2.4, trace_len=8,
        n_accounts=16, seed=31, mean_wall_s=1500.0, max_frac_nodes=0.4))
    attach_basis(jobs, model)
    table = jobs.to_table()

    print(f"train phase: ES under stress (heat wave + 2 tower cells out), "
          f"reward = {REWARD}")
    nominal = wx.synthetic_weather(n_steps, system.dt, seed=5)
    stress = wx.heat_wave(nominal, system.dt, start_s=0.1 * t1,
                          duration_s=0.7 * t1, peak_amp_c=10.0)
    res = ml_train.train(
        system, table, 0.0, t1, reward=REWARD,
        generations=5, population=8, sigma=0.35, lr=0.8, seed=0,
        weather=stress, scen_kw={"cells_offline": 2.0},
        checkpoint=None, log=lambda s: print("  " + s))
    print(f"trained alpha {np.round(res.alpha, 3).tolist()} "
          f"(default {list(ml_train.scoring.DEFAULT_ALPHA)}); "
          f"stress reward {res.reward_best:+.3f} vs default "
          f"{res.reward_default:+.3f}")

    print("\neval phase: (nominal + stress) x policies — ONE batched sweep "
          "(per-scenario weather)")
    names = ["fcfs", "sjf", "priority", "thermal_aware", "ml (default)",
             "ml (trained)", "ml (default) @stress", "ml (trained) @stress"]
    a_def, a_tr = np.asarray(model.alpha), res.alpha
    scens = [T.Scenario.make(p, "first-fit")
             for p in ["fcfs", "sjf", "priority", "thermal_aware"]] + \
        [T.Scenario.make("ml", "first-fit", alpha=a_def),
         T.Scenario.make("ml", "first-fit", alpha=a_tr),
         T.Scenario.make("ml", "first-fit", alpha=a_def,
                         cells_offline=2.0),
         T.Scenario.make("ml", "first-fit", alpha=a_tr,
                         cells_offline=2.0)]
    weather = [nominal] * 6 + [stress] * 2
    finals, hists = engine.simulate_sweep_sharded(
        system, table, scens, 0.0, t1, weather=weather)

    import jax
    pick = lambda tree, i: jax.tree_util.tree_map(lambda x: x[i], tree)
    rows = {}
    for i, name in enumerate(names):
        s = stats.summarize(system, table, pick(finals, i), pick(hists, i))
        rows[name] = s
        print(f"{name:21s} done={s['jobs_completed']:4.0f} "
              f"wait={s['avg_wait_s']:7.0f}s "
              f"turn={s['avg_turnaround_s']:7.0f}s "
              f"E={s['total_energy_mwh']:6.3f}MWh "
              f"Tret_max={s['t_tower_return_max_c']:5.1f}C")

    objs = ("avg_wait_s", "avg_turnaround_s", "total_energy_mwh")

    def compare(tr, df, label):
        wins = sum(tr[k] < df[k] for k in objs)
        ties = sum(tr[k] == df[k] for k in objs)
        print(f"  {label}: {wins}/3 strictly better, {ties}/3 tied, "
              f"{3 - wins - ties}/3 worse")

    print("\ntrained vs hand-set alpha:")
    compare(rows["ml (trained)"], rows["ml (default)"], "nominal window")
    compare(rows["ml (trained) @stress"], rows["ml (default) @stress"],
            "stress window (heat wave + 2 cells out)")


if __name__ == "__main__":
    main()
