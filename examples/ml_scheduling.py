"""ML-guided scheduling (paper §4.4): cluster -> classify -> predict ->
score S(X) -> schedule, compared against the classic policies under load.

  PYTHONPATH=src python examples/ml_scheduling.py
"""
import numpy as np

from repro.core import engine, stats, types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.ml.pipeline import MLSchedulerModel, attach_scores
from repro.systems.config import get_system


def main():
    system = get_system("fugaku").scaled(8192)

    print("training phase: cluster / classify / fit per-cluster predictors")
    hist_jobs = generate(system, WorkloadSpec(
        n_jobs=2000, duration_s=14 * 86400.0, load=0.8, trace_len=8,
        n_accounts=64, seed=30))
    model = MLSchedulerModel.fit(hist_jobs, k=5, n_trees=8, depth=6)

    print("inference phase: score incoming jobs, schedule under high load")
    test = generate(system, WorkloadSpec(
        n_jobs=600, duration_s=0.5 * 86400.0, load=2.5, trace_len=8,
        n_accounts=64, seed=31, max_frac_nodes=0.35))
    attach_scores(test, model)
    table = test.to_table()

    rows = {}
    for policy in ["fcfs", "sjf", "ljf", "priority", "ml"]:
        final, hist = engine.simulate(system, table,
                                      T.Scenario.make(policy, "first-fit"),
                                      0.0, 0.6 * 86400.0)
        s = stats.summarize(system, table, final, hist)
        rows[policy] = s
        print(f"{policy:9s} done={s['jobs_completed']:5.0f} "
              f"wait={s['avg_wait_s']:8.0f}s turn={s['avg_turnaround_s']:8.0f}s "
              f"Pmax={s['max_power_mw']:6.2f}MW edp={s['edp']:.3e}")

    better = sum(rows["ml"][k] <= rows["ljf"][k]
                 for k in ("avg_wait_s", "avg_turnaround_s", "max_power_mw"))
    print(f"\nml beats ljf on {better}/3 objectives (paper Fig. 10)")


if __name__ == "__main__":
    main()
