"""Grid-aware what-if walkthrough: "what if we cap the machine during the
evening price peak, and defer energy-heavy jobs while the grid is dirty?"

Three scenarios over the SAME day of synthetic grid signals (diurnal carbon
intensity, evening price peak, cap dip during the peak), all batched into
one compiled ``simulate_sweep`` call:

  baseline   : fcfs/first-fit, uncapped        (cap_scale -> generous)
  capped     : fcfs/first-fit under the cap schedule
  carbon     : carbon_aware deferral + the same cap

  PYTHONPATH=src python examples/carbon_whatif.py
"""
import numpy as np

from repro.core import engine, types as T
from repro.datasets.loaders import load_marconi100
from repro.grid import signals as gsig
from repro.systems.config import get_system


def main():
    system = get_system("marconi100")
    jobs = load_marconi100(n_jobs=900, days=1.0, seed=5)
    jobs.assign_prepop_placement(0.0, system.n_nodes)
    table = jobs.to_table()
    t1 = 0.9 * 86400.0
    n_steps = int(t1 / system.dt)

    peak_it = system.n_nodes * system.power.peak_node_w
    signals = gsig.synthetic_signals(
        system.grid, n_steps, system.dt, seed=5,
        cap_base_w=0.9 * peak_it,    # generous off-peak cap
        cap_peak_w=0.5 * peak_it)    # evening dip: "20 MW during the peak"

    scens = [
        # cap_scale=10 pushes the schedule far above any draw -> uncapped
        T.Scenario.make("fcfs", "first-fit", cap_scale=10.0),
        T.Scenario.make("fcfs", "first-fit"),
        T.Scenario.make("carbon_aware", "first-fit", carbon_weight=4.0),
    ]
    names = ["fcfs/uncapped", "fcfs/capped", "carbon_aware/capped"]

    finals, hists = engine.simulate_sweep(system, table, scens, 0.0, t1,
                                          num_accounts=32, signals=signals)

    p_it = np.asarray(hists.power_it)
    cap = np.asarray(hists.cap_w)
    print(f"cap honored in every scenario/step: "
          f"{bool((p_it <= cap + 1.0).all())}\n")
    hdr = (f"{'scenario':>22s} {'done':>6s} {'tCO2':>7s} {'cost $':>9s} "
           f"{'peak MW':>8s} {'thr %':>6s}")
    print(hdr)
    for i, n in enumerate(names):
        print(f"{n:>22s} {float(np.asarray(finals.completed)[i]):6.0f} "
              f"{float(np.asarray(finals.emissions_kg)[i]) / 1e3:7.2f} "
              f"{float(np.asarray(finals.energy_cost)[i]):9.0f} "
              f"{p_it[i].max() / 1e6:8.2f} "
              f"{100 * np.asarray(hists.throttle_frac)[i].mean():6.2f}")

    # per-account sustainability ledger (collect side of a low-carbon
    # incentive: redeem by scheduling frugal accounts first)
    kg = np.asarray(finals.accounts.carbon_kg)[2]
    top = np.argsort(kg)[::-1][:3]
    print("\nhighest-emission accounts under carbon_aware/capped:")
    for a in top:
        print(f"  account {a:3d}: {kg[a]:8.1f} kg CO2")


if __name__ == "__main__":
    main()
