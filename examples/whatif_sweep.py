"""What-if sweep: every (policy x backfill) combination of the built-in
scheduler in ONE compiled, vmapped batch — the paper's what-if studies as a
single XLA program (shard the scenario axis over a pod to scale this to
thousands of concurrent scenarios).

  PYTHONPATH=src python examples/whatif_sweep.py
"""
import time

import numpy as np
import jax

from repro.core import engine, types as T
from repro.datasets.loaders import load_frontier
from repro.systems.config import get_system

POLICIES = ["fcfs", "sjf", "ljf", "priority"]
BACKFILLS = ["none", "first-fit", "easy"]


def main():
    system = get_system("frontier")
    jobs = load_frontier(n_jobs=900, days=0.5, seed=3)
    jobs.assign_prepop_placement(0.0, system.n_nodes)
    table = jobs.to_table()

    scens, names = [], []
    for p in POLICIES:
        for b in BACKFILLS:
            scens.append(T.Scenario.make(p, b))
            names.append(f"{p:8s}/{b:9s}")

    t0 = time.perf_counter()
    final, hist = engine.simulate_sweep(system, table, scens, 0.0,
                                        8 * 3600.0)
    jax.block_until_ready(final.t)
    wall = time.perf_counter() - t0
    sim_s = 8 * 3600.0 * len(scens)
    print(f"{len(scens)} scenarios x 8h simulated in {wall:.1f}s "
          f"({sim_s / wall:,.0f}x realtime aggregate)\n")
    util = np.asarray(hist.util).mean(axis=1)
    swing = np.asarray(hist.power_total)
    swing = (swing.max(axis=1) - swing.min(axis=1)) / 1e6
    done = np.asarray(final.completed)
    print(f"{'scenario':20s} {'util':>7s} {'swing MW':>9s} {'done':>6s}")
    for i, n in enumerate(names):
        print(f"{n:20s} {util[i]:7.3f} {swing[i]:9.2f} {done[i]:6.0f}")


if __name__ == "__main__":
    main()
