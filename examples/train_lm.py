"""End-to-end LM training example (deliverable b): train a ~100M-parameter
decoder-only LM for a few hundred steps with the production train step
(pjit shardings, AdamW, remat, checkpoints + auto-resume).

  PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
  PYTHONPATH=src python examples/train_lm.py --smoke    # tiny, 30 steps

On this CPU container the default takes a while; --smoke finishes in ~1 min.
"""
import argparse
import dataclasses
import sys

import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import train as train_mod
from repro.models.common import ArchConfig


def hundred_m_config() -> ArchConfig:
    """~100M params in the qwen2.5 family (GQA + QKV bias)."""
    base = get_config("qwen2.5-3b")
    return dataclasses.replace(
        base, name="qwen-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=2, head_dim=64, d_ff=2048, vocab=32000,
        dtype=jnp.float32, param_dtype=jnp.float32, remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    import tempfile
    ckpt = tempfile.mkdtemp(prefix="trainlm_")  # fresh run (no auto-resume)
    if args.smoke:
        argv = ["--arch", "qwen2.5-3b-smoke",
                "--steps", str(args.steps or 30),
                "--batch", "4", "--seq", "64", "--lr", "3e-3",
                "--ckpt-every", "10", "--ckpt-dir", ckpt]
        losses = train_mod.main(argv)
    else:
        # register the 100M config under the zoo and train it
        from repro.configs import registry
        cfg = hundred_m_config()
        registry.ARCHS[cfg.name] = cfg
        argv = ["--arch", cfg.name, "--steps", str(args.steps or 300),
                "--batch", "8", "--seq", "256", "--lr", "1e-3",
                "--ckpt-every", "50", "--ckpt-dir", ckpt]
        losses = train_mod.main(argv)
    assert losses[-1] < losses[0], "training must reduce loss"
    print("OK: loss decreased", losses[0], "->", losses[-1])


if __name__ == "__main__":
    main()
