"""Serving example: batched prefill + token-by-token decode with the KV /
recurrent caches, over two different families (GQA transformer and the
attention-free RWKV6).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.common import split_tree
from repro.models.zoo import get_api


def serve(arch: str, batch=4, prompt_len=32, gen=16):
    cfg = get_config(arch)
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = split_tree(api.init(key))
    prompts = {"tokens": jax.random.randint(key, (batch, prompt_len), 0,
                                            cfg.vocab)}
    if cfg.family == "vlm":
        prompts["patches"] = jax.random.normal(
            key, (batch, cfg.frontend_tokens, cfg.d_model))
    if cfg.family == "encdec":
        prompts["frames"] = jax.random.normal(key, (batch, 16, cfg.d_model))

    decode = jax.jit(api.decode)
    logits, state = api.prefill(params, prompts, prompt_len + gen)
    out = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(gen):
        out.append(np.asarray(tok))
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    wall = time.perf_counter() - t0
    toks = np.stack(out, 1)
    print(f"{arch:28s} generated {toks.shape} in {wall:.2f}s "
          f"({batch * gen / wall:,.0f} tok/s) sample={toks[0][:8].tolist()}")


def main():
    for arch in ["qwen2.5-3b-smoke", "rwkv6-7b-smoke", "zamba2-7b-smoke"]:
        serve(arch)


if __name__ == "__main__":
    main()
