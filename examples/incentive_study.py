"""Incentive-structure study (paper §4.3): collect account statistics under
replay, then redeem them as scheduling priority (Fugaku points et al.) and
observe the impact on the power profile and on who runs first.

  PYTHONPATH=src python examples/incentive_study.py
"""
import numpy as np

from repro.core import engine, types as T
from repro.datasets.loaders import load_marconi100
from repro.systems.config import get_system


def main():
    system = get_system("marconi100")
    jobs = load_marconi100(n_jobs=800, days=1.0, seed=8)
    jobs.assign_prepop_placement(0.0, system.n_nodes)
    table = jobs.to_table()
    horizon = 10 * 3600.0

    # --- collection phase (replay + --accounts) ---------------------------
    final, hist = engine.simulate(system, table, T.Scenario.make("replay"),
                                  0.0, horizon, num_accounts=32)
    acc = final.accounts
    jd = np.maximum(np.asarray(acc.jobs_done), 1)
    print("collection phase: jobs done per account (top 5):",
          np.sort(np.asarray(acc.jobs_done))[-5:])

    # --- redeeming phase ---------------------------------------------------
    for policy in ["acct_avg_power", "acct_low_avg_power", "acct_edp",
                   "acct_fugaku_pts"]:
        f2, h2 = engine.simulate(system, table,
                                 T.Scenario.make(policy, "first-fit"),
                                 0.0, horizon, accounts=acc,
                                 num_accounts=32)
        p = np.asarray(h2.power_total)
        print(f"{policy:22s} done={float(f2.completed):5.0f} "
              f"P_avg={p.mean() / 1e6:6.3f}MW swing={np.ptp(p) / 1e6:6.3f}MW")


if __name__ == "__main__":
    main()
