"""Quickstart: simulate one day of a Marconi100-like system under two
scheduling policies and compare the physical response.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import engine, stats, types as T
from repro.datasets.loaders import load_marconi100
from repro.systems.config import get_system


def main():
    system = get_system("marconi100")
    jobs = load_marconi100(n_jobs=800, days=1.0, seed=0)
    jobs.assign_prepop_placement(0.0, system.n_nodes)
    table = jobs.to_table()

    for policy, backfill in [("fcfs", "none"), ("fcfs", "easy")]:
        scen = T.Scenario.make(policy, backfill)
        final, hist = engine.simulate(system, table, scen, 0.0, 12 * 3600.0)
        s = stats.summarize(system, table, final, hist)
        print(f"\n--- {policy} + {backfill} backfill ---")
        for k in ("jobs_completed", "avg_util", "avg_system_power_mw",
                  "power_swing_mw", "avg_pue", "avg_wait_s"):
            print(f"  {k:24s} {s[k]:,.3f}")


if __name__ == "__main__":
    main()
