"""Transient-cooling what-if walkthrough: "what does this schedule do to
the tower loop — and what if it meets a heat wave?"

A (policy x weather x setpoint) grid over the SAME oversubscribed
half-day of work, all batched into ONE compiled ``simulate_sweep`` call —
each scenario row carries its own weather trace (stacked on the vmap
axis), its own supply-setpoint offset (``Scenario.setpoint_delta_c``) and
its own policy:

  policy    : fcfs            vs  thermal_aware (defers heat-dense jobs
                                  while the tower return temp sits inside
                                  the soft band below its limit)
  weather   : typical summer  vs  the same trace + a 12 °C heat wave
  setpoint  : +0 °C           vs  +3 °C on the CDU supply setpoint
                                  (warmer water -> more exportable heat,
                                  hotter loop)

The run prints peak tower return temperature, PUE, fan energy, exported
(reused) heat and how long the supply-temperature admission gate was
engaged — and checks the acceptance claim: under the heat wave,
thermal_aware lowers the peak tower return temperature vs FCFS.

  PYTHONPATH=src python examples/cooling_whatif.py
"""
import dataclasses

import numpy as np

from repro.cooling import weather as wx
from repro.core import engine, types as T
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system


def main():
    base = get_system("marconi100")
    # what-if: the operator tightens the return-water soft band so the
    # thermal_aware policy starts deferring well before the hard limit
    system = dataclasses.replace(
        base, cooling=dataclasses.replace(base.cooling,
                                          t_return_limit_c=42.0,
                                          thermal_margin_c=10.0))
    t1 = 0.5 * 86400.0
    n_steps = int(t1 / system.dt)

    # oversubscribed workload: the queue stays deep, so the policy ORDER
    # decides whose heat lands in the hottest hours
    jobs = generate(system, WorkloadSpec(
        n_jobs=600, duration_s=t1, load=2.0, trace_len=8,
        mean_wall_s=2400.0, n_accounts=16, seed=7))
    jobs.assign_prepop_placement(0.0, system.n_nodes)
    table = jobs.to_table()

    typical = wx.synthetic_weather(n_steps, system.dt, t_wb_mean_c=18.0,
                                   seed=7)
    heatwave = wx.heat_wave(typical, system.dt, start_s=0.2 * t1,
                            duration_s=0.5 * t1, peak_amp_c=12.0)

    scens, weathers, names = [], [], []
    for pol, weight in [("fcfs", 0.0), ("thermal_aware", 200.0)]:
        for wname, trace in [("typical", typical), ("heatwave", heatwave)]:
            for delta in (0.0, 3.0):
                scens.append(T.Scenario.make(
                    pol, "first-fit", thermal_weight=weight,
                    setpoint_delta_c=delta))
                weathers.append(trace)
                names.append(f"{pol}/{wname}/+{delta:.0f}C")

    finals, hists = engine.simulate_sweep(system, table, scens, 0.0, t1,
                                          num_accounts=16, weather=weathers)

    t_ret = np.asarray(hists.t_tower_return)
    pue = np.asarray(hists.pue)
    fan = np.asarray(hists.power_fan)
    gate = np.asarray(hists.thermal_throttled)
    done = np.asarray(finals.completed)
    reuse = np.asarray(finals.heat_reuse_j) / 3.6e9

    hdr = (f"{'scenario':>28s} {'done':>5s} {'peak t_ret':>10s} "
           f"{'PUE':>7s} {'fan MWh':>8s} {'reuse MWh':>9s} {'gate':>5s}")
    print(hdr)
    for i, n in enumerate(names):
        print(f"{n:>28s} {done[i]:5.0f} {t_ret[i].max():9.2f}C "
              f"{pue[i].mean():7.4f} "
              f"{fan[i].sum() * system.dt / 3.6e9:8.2f} {reuse[i]:9.2f} "
              f"{gate[i].sum():5.0f}")

    # acceptance: thermal_aware cuts the peak tower return temperature vs
    # FCFS under the heat-wave trace (compare like-for-like setpoints)
    idx = {n: i for i, n in enumerate(names)}
    for delta in ("+0C", "+3C"):
        fcfs_peak = t_ret[idx[f"fcfs/heatwave/{delta}"]].max()
        ta_peak = t_ret[idx[f"thermal_aware/heatwave/{delta}"]].max()
        print(f"\nheat wave {delta}: peak tower return "
              f"fcfs={fcfs_peak:.2f}C thermal_aware={ta_peak:.2f}C "
              f"(reduction {fcfs_peak - ta_peak:.2f}C)")
        assert ta_peak < fcfs_peak, \
            "thermal_aware should cut the peak tower return temperature"


if __name__ == "__main__":
    main()
