#!/usr/bin/env python
"""Reference external-scheduler peer (stdlib only — no repro, no numpy).

Serves FastSimLike semantics (event-driven FCFS/SJF/LJF/priority with
optional firstfit backfill — a pure-Python port of
``datasets/synthetic.event_schedule`` with identical tie-breaking and
float arithmetic) over the NDJSON wire protocol documented in
docs/external-scheduling.md. Because it only needs the standard
library, it doubles as the porting template for coupling a scheduler
written in any language: speak ``hello``, answer ``reset`` with the
recomputed digests, then answer ``poll`` / ``schedule_req``.

Run modes::

  python -m tools.reference_peer --connect unix:/path/peer.sock
      dial a twin that is listening (how SubprocessPeer drives it);
      serves one session, then exits.

  python -m tools.reference_peer --listen unix:/path/peer.sock
  python -m tools.reference_peer --listen 127.0.0.1:7700
      bind and serve sessions forever (pair with --external-socket).

``--fault MODE`` injects failures for the bridge's fault tests:
``die:N`` (exit abruptly after N polls), ``hang`` (never answer),
``garbage`` (non-JSON frame), ``truncate`` (partial frame then exit),
``version`` (advertise wire version 2 in hello), ``legacy`` (advertise
no capabilities — forces the twin's NDJSON/per-poll fallback).

Capabilities: the hello advertises ``bin1`` (RBW1 length-prefixed
binary frames — raw little-endian arrays instead of JSON lists) and
``batch1`` (``poll_batch`` → ``running_sets``); replies always use the
dialect the request arrived in.
"""
from __future__ import annotations

import argparse
import hashlib
import heapq
import json
import math
import os
import socket
import struct
import sys
import time

WIRE_VERSION = 1
MAX_FRAME_BYTES = 256 << 20  # keep equal to repro.core.transport's cap

# RBW1 binary dialect (keep in sync with repro.core.transport):
#   magic[4] | u32 LE header bytes | u32 LE payload bytes | JSON header |
#   raw little-endian array bytes. Arrays appear in the header as
#   {"__bin__": index, "dtype": "<f8", "shape": [n]} placeholders.
BIN_MAGIC = b"RBW1"
BIN_LENS = struct.Struct("<II")
# struct format char per wire dtype (stdlib-only decode, no numpy)
DTYPE_FMT = {"<f8": "d", "<f4": "f", "<i8": "q", "<i4": "i",
             "<u8": "Q", "<u4": "I", "|b1": "?"}
CAPS = ["bin1", "batch1"]  # binary frames + batched polls


class BinArray:
    """An array-valued reply field: raw bytes on the binary wire, a plain
    JSON list on the NDJSON wire."""

    def __init__(self, dtype, values):
        self.dtype = dtype
        self.values = list(values)


# ---------------------------------------------------------------------------
# FastSimLike semantics, pure Python (port of synthetic.event_schedule).
# ---------------------------------------------------------------------------
def event_schedule(submit, limit, wall, nodes, n_nodes, dt,
                   policy="fcfs", backfill="firstfit", priority=None):
    """Event-driven start times; math.inf marks never-started jobs.

    Mirrors the numpy implementation op-for-op (ceil-to-grid submits,
    release-before-submit event ordering, ``(key, submit, id)`` queue
    sort) so the twin's in-process ``FastSimLike`` and this peer make
    bit-identical scheduling decisions on the same inputs.
    """
    J = len(submit)
    submit_g = [math.ceil(s / dt) * dt for s in submit]
    start = [math.inf] * J
    free = n_nodes
    queue = []
    ev = [(float(submit_g[j]), 1, j) for j in range(J)]
    heapq.heapify(ev)

    if policy == "fcfs":
        key = submit_g
    elif policy == "sjf":
        key = limit
    elif policy == "ljf":
        key = [-float(n) for n in nodes]
    elif policy == "priority":
        if priority is None:
            raise ValueError("priority policy needs a priority column")
        key = [-float(p) for p in priority]
    else:
        raise ValueError(policy)

    while ev:
        t, kind, j = heapq.heappop(ev)
        if kind == 0:
            free += int(nodes[j])
        else:
            queue.append(j)
        if ev and ev[0][0] == t:
            continue
        queue.sort(key=lambda q: (key[q], submit_g[q], q))
        placed = []
        for q in queue:
            need = int(nodes[q])
            if need <= free:
                free -= need
                start[q] = t
                heapq.heappush(ev, (t + float(wall[q]), 0, q))
                placed.append(q)
            elif backfill == "none":
                break
        for q in placed:
            queue.remove(q)
    return start


# ---------------------------------------------------------------------------
# Canonical digests — must match repro.core.transport exactly.
# ---------------------------------------------------------------------------
def _digest(obj):
    blob = json.dumps(obj, separators=(",", ":"), sort_keys=True)
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def system_digest(n_nodes, dt):
    return _digest({"v": WIRE_VERSION, "n_nodes": int(n_nodes),
                    "dt": float(dt)})


def job_digest(submit, limit, wall, nodes, account):
    return _digest({"v": WIRE_VERSION, "jobs": {
        "submit": [int(round(float(x))) for x in submit],
        "limit": [int(round(float(x))) for x in limit],
        "wall": [int(round(float(x))) for x in wall],
        "nodes": [int(x) for x in nodes],
        "account": [int(x) for x in account],
    }})


# ---------------------------------------------------------------------------
# Session: one connected twin.
# ---------------------------------------------------------------------------
class Session:
    def __init__(self, conn, fault=None):
        self.rfile = conn.makefile("rb")
        self.wfile = conn.makefile("wb")
        self.fault, _, n = (fault or "none").partition(":")
        self.fault_arg = int(n) if n else 0
        self.polls = 0
        self.jobs = None
        self.start = None
        self.req_binary = False  # dialect of the last request frame

    # -- framing (both dialects) -------------------------------------------
    def send(self, msg):
        """Answer in the dialect the request arrived in."""
        if self.req_binary:
            self.send_binary(msg)
        else:
            self.send_json(msg)

    def send_json(self, msg):
        self.wfile.write(json.dumps(
            msg, separators=(",", ":"),
            default=lambda o: o.values if isinstance(o, BinArray) else o)
            .encode("utf-8") + b"\n")
        self.wfile.flush()

    def send_binary(self, msg):
        chunks = []

        def hoist(obj):
            if isinstance(obj, BinArray):
                fmt = DTYPE_FMT[obj.dtype]
                chunks.append(struct.pack(
                    "<%d%s" % (len(obj.values), fmt), *obj.values))
                return {"__bin__": len(chunks) - 1, "dtype": obj.dtype,
                        "shape": [len(obj.values)]}
            if isinstance(obj, dict):
                return {k: hoist(v) for k, v in obj.items()}
            if isinstance(obj, list):
                return [hoist(v) for v in obj]
            return obj

        header = json.dumps(hoist(msg),
                            separators=(",", ":")).encode("utf-8")
        self.wfile.write(BIN_MAGIC)
        self.wfile.write(BIN_LENS.pack(len(header),
                                       sum(len(c) for c in chunks)))
        self.wfile.write(header)
        for c in chunks:
            self.wfile.write(c)
        self.wfile.flush()

    def read_request(self):
        """One frame of either dialect; None on EOF, str on parse error."""
        first = self.rfile.read(1)
        if not first:
            return None
        if first == BIN_MAGIC[:1]:
            rest = self.rfile.read(len(BIN_MAGIC) - 1)
            if first + rest != BIN_MAGIC:
                return "bad binary magic"
            lens = self.rfile.read(BIN_LENS.size)
            if len(lens) < BIN_LENS.size:
                return "truncated binary frame"
            header_len, payload_len = BIN_LENS.unpack(lens)
            if header_len + payload_len > MAX_FRAME_BYTES:
                return "frame over protocol cap"
            header = self.rfile.read(header_len)
            payload = self.rfile.read(payload_len)
            if len(header) < header_len or len(payload) < payload_len:
                return "truncated binary frame"
            try:
                msg = self.decode_binary(json.loads(header), payload)
            except (ValueError, KeyError, struct.error) as e:
                return "bad binary frame: %r" % (e,)
            self.req_binary = True
            return msg
        line = first + self.rfile.readline(MAX_FRAME_BYTES + 1)
        try:
            msg = json.loads(line)
        except ValueError:
            return "unparseable frame"
        self.req_binary = False
        return msg

    def decode_binary(self, obj, payload):
        """Placeholders -> Python lists (flat arrays only — all the twin
        ever sends a peer). Two passes: collect per-index sizes, then
        unpack each array at its offset."""
        sizes = {}

        def walk(o):
            if isinstance(o, dict):
                if "__bin__" in o:
                    if len(o["shape"]) != 1:
                        raise ValueError("peer only decodes 1-D arrays")
                    sizes[int(o["__bin__"])] = \
                        int(o["shape"][0]) * struct.calcsize(
                            DTYPE_FMT[o["dtype"]])
                    return
                for v in o.values():
                    walk(v)
            elif isinstance(o, list):
                for v in o:
                    walk(v)

        walk(obj)
        if sorted(sizes) != list(range(len(sizes))):
            raise ValueError("array indices must be dense from 0")
        offsets, off = {}, 0
        for i in range(len(sizes)):
            offsets[i] = off
            off += sizes[i]
        if off != len(payload):
            raise ValueError("payload length mismatch")

        def restore(o):
            if isinstance(o, dict):
                if "__bin__" in o:
                    i = int(o["__bin__"])
                    n = int(o["shape"][0])
                    fmt = "<%d%s" % (n, DTYPE_FMT[o["dtype"]])
                    return list(struct.unpack_from(fmt, payload, offsets[i]))
                return {k: restore(v) for k, v in o.items()}
            if isinstance(o, list):
                return [restore(v) for v in o]
            return o

        return restore(obj)

    def send_error(self, message):
        self.send({"version": WIRE_VERSION, "kind": "error",
                   "message": message})

    def hello(self):
        version = 2 if self.fault == "version" else WIRE_VERSION
        msg = {"version": version, "kind": "hello",
               "name": "reference-peer", "pid": os.getpid()}
        if self.fault != "legacy":  # legacy: pre-capability peer, no caps
            msg["caps"] = list(CAPS)
        self.send(msg)

    def on_reset(self, msg):
        sysd, jobs = msg.get("system") or {}, msg.get("jobs") or {}
        cols = {k: jobs.get(k) or [] for k in
                ("submit", "limit", "wall", "nodes", "priority", "account")}
        lens = {len(v) for v in cols.values()}
        if len(lens) != 1:
            self.send_error(f"ragged job columns: lengths {sorted(lens)}")
            return
        self.jobs = cols
        try:
            self.start = event_schedule(
                cols["submit"], cols["limit"], cols["wall"], cols["nodes"],
                int(sysd.get("n_nodes", 0)), float(sysd.get("dt", 1.0)),
                policy=msg.get("policy", "fcfs"),
                backfill=msg.get("backfill", "firstfit"),
                priority=cols["priority"])
        except (ValueError, TypeError) as e:
            # e.g. a policy this peer doesn't implement: answer with the
            # protocol's error envelope instead of dying wordlessly (the
            # twin surfaces it as ProtocolError with this message)
            self.send_error(f"reset rejected: {e!r}")
            return
        # echo digests recomputed from what we actually deserialized —
        # the twin compares them against its own (handshake contract)
        self.send({
            "version": WIRE_VERSION, "kind": "reset_ack",
            "n_jobs": len(cols["submit"]),
            "system_digest": system_digest(sysd.get("n_nodes", 0),
                                           sysd.get("dt", 1.0)),
            "job_digest": job_digest(cols["submit"], cols["limit"],
                                     cols["wall"], cols["nodes"],
                                     cols["account"]),
        })

    def running_ids(self, t):
        wall = self.jobs["wall"]
        return [j for j, s in enumerate(self.start)
                if s <= t and s + wall[j] > t]

    def on_poll(self, msg):
        self.polls += 1
        if self.fault == "hang":
            time.sleep(3600.0)
        if self.fault == "die" and self.polls > self.fault_arg:
            os._exit(1)                       # no bye, no flush: abrupt
        if self.fault == "garbage":
            self.wfile.write(b"}{ this is not a JSON frame\n")
            self.wfile.flush()
            return
        if self.fault == "truncate":
            self.wfile.write(b'{"version":1,"kind":"running_s')
            self.wfile.flush()
            os._exit(1)                       # frame cut mid-envelope
        if self.start is None:
            self.send_error("poll before reset")
            return
        self.send({"version": WIRE_VERSION, "kind": "running_set",
                   "job_ids": BinArray(
                       "<i8", self.running_ids(float(msg.get("t", 0.0))))})

    def on_poll_batch(self, msg):
        if self.start is None:
            self.send_error("poll_batch before reset")
            return
        ts = msg.get("ts") or []
        self.send({"version": WIRE_VERSION, "kind": "running_sets",
                   "sets": [BinArray("<i8", self.running_ids(float(t)))
                            for t in ts]})

    def on_schedule_req(self):
        if self.start is None:
            self.send_error("schedule_req before reset")
            return
        if self.req_binary:
            # binary spelling: +inf marks never-started (null has no
            # fixed-width encoding); the twin's decode_schedule accepts
            # both spellings identically
            start = BinArray("<f8", self.start)
        else:
            start = [None if math.isinf(s) else s for s in self.start]
        self.send({"version": WIRE_VERSION, "kind": "schedule",
                   "start": start})

    def serve(self):
        self.hello()
        while True:
            msg = self.read_request()
            if msg is None:
                return                        # twin went away
            if isinstance(msg, str):          # framing/parse failure note
                self.send_error(msg)
                return
            kind = msg.get("kind") if isinstance(msg, dict) else None
            if kind == "reset":
                self.on_reset(msg)
            elif kind == "poll":
                self.on_poll(msg)
            elif kind == "poll_batch":
                self.on_poll_batch(msg)
            elif kind == "schedule_req":
                self.on_schedule_req()
            elif kind == "bye":
                return
            else:
                self.send_error(f"unknown message kind {kind!r}")


# ---------------------------------------------------------------------------
def parse_address(addr):
    if addr.startswith("unix:"):
        return socket.AF_UNIX, addr[len("unix:"):]
    if addr.startswith("tcp:"):
        addr = addr[len("tcp:"):]
    if "/" in addr:
        return socket.AF_UNIX, addr
    host, _, port = addr.rpartition(":")
    return socket.AF_INET, (host, int(port))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--connect", help="dial a listening twin "
                                        "(unix:/path or host:port)")
    mode.add_argument("--listen", help="bind and serve sessions forever")
    ap.add_argument("--fault", default=None,
                    help="die:N | hang | garbage | truncate | version")
    args = ap.parse_args(argv)

    if args.connect:
        family, sockaddr = parse_address(args.connect)
        sock = socket.socket(family, socket.SOCK_STREAM)
        sock.connect(sockaddr)
        Session(sock, fault=args.fault).serve()
        sock.close()
        return 0

    family, sockaddr = parse_address(args.listen)
    srv = socket.socket(family, socket.SOCK_STREAM)
    if family == socket.AF_INET:
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    elif os.path.exists(sockaddr):
        os.unlink(sockaddr)
    srv.bind(sockaddr)
    srv.listen(1)
    print(f"reference-peer listening on {args.listen}", flush=True)
    while True:
        conn, _ = srv.accept()
        try:
            Session(conn, fault=args.fault).serve()
        except (BrokenPipeError, ConnectionError):
            pass
        finally:
            conn.close()


if __name__ == "__main__":
    sys.exit(main())
