"""Regenerate the golden trace fixtures under tests/data/.

The fixtures are *committed* — tests consume the bytes in the repo, not
this script — so regeneration must be bit-deterministic (fixed seeds, no
clocks). Rerun after changing a loader's on-disk contract, then re-commit:

    PYTHONPATH=src python tools/make_trace_fixtures.py

Produces:
  pm100_small.parquet / pm100_small.swf   ~200-job PM100-style job table
      (datetime columns) and its SWF export — the roundtrip pair.
  joblive/date=2024-01-18/joblive.csv     RAPS-style telemetry dump:
  jobprofile/date=2024-01-18/jobprofile.csv   scheduler rows + measured
      per-node power samples (two thirds of the jobs are profiled).
  weather_week.csv                        one week of hourly dry-bulb/RH
      including a heat-wave day (drives the calibration fixture into the
      regime where the HX parameters are observable).
  calibration/telemetry.npz               facility telemetry from a
      *known-parameter* plant (truth stored as true_* keys) driven by the
      replayed fixture power + fixture weather.
  calibration/fitted_params.json          the committed calibration and
      its residual envelope — the regression gate tests enforce.
"""
from __future__ import annotations

import pathlib

import numpy as np
import pandas as pd

DATA = pathlib.Path(__file__).resolve().parent.parent / "tests" / "data"
EPOCH = 1705536000.0   # 2024-01-18 00:00:00 UTC — fixed fixture origin

# the known "true" plant the calibration fixture must recover, as
# multipliers / absolutes on the frontier CoolingConfig defaults
CAL_TRUTH = {"ua_w_k": 0.7, "tau_hx_s": 0.6, "basin_margin_c": 4.5}
# calibration window: 48 h from the cool day-3 morning through the
# heat-wave peak — the cool phase observes the fan-staging threshold
# (basin target = setpoint - margin), the hot phase unpins the CDU
# supply from its setpoint and observes UA / tau_hx
CAL_T0 = 3.0 * 86400.0
CAL_STEPS = 8640
CAL_DT = 20.0


def _dt(seconds: np.ndarray) -> pd.Series:
    return pd.to_datetime(np.asarray(seconds, np.float64) + EPOCH,
                          unit="s", utc=True)


def make_pm100(out: pathlib.Path) -> None:
    rng = np.random.default_rng(42)
    J = 200
    submit = np.sort(rng.uniform(0, 2 * 86400, J)).round()
    wall = np.clip(rng.lognormal(7.6, 1.0, J), 600, 6 * 3600).round()
    wait = np.clip(rng.exponential(900, J), 0, 4 * 3600).round()
    start = submit + wait
    nodes = np.clip(rng.geometric(0.12, J), 1, 64)
    limit_min = np.ceil(wall / 60 * rng.uniform(1.1, 3.0, J))
    users = rng.integers(0, 24, J)
    df = pd.DataFrame({
        "job_id": np.arange(1, J + 1),
        "submit_time": _dt(submit),
        "start_time": _dt(start),
        "end_time": _dt(start + wall),
        "num_nodes": nodes.astype(np.int64),
        "time_limit": limit_min,
        "user_id": [f"user{u:02d}" for u in users],
    })
    df.to_parquet(out / "pm100_small.parquet", index=False)

    from repro.datasets import swf
    from repro.traces import read_job_table
    js = read_job_table(out / "pm100_small.parquet")
    swf.write_swf(js, out / "pm100_small.swf")


def make_telemetry(out: pathlib.Path) -> None:
    rng = np.random.default_rng(7)
    J = 30
    live_dir = out / "joblive" / "date=2024-01-18"
    prof_dir = out / "jobprofile" / "date=2024-01-18"
    live_dir.mkdir(parents=True, exist_ok=True)
    prof_dir.mkdir(parents=True, exist_ok=True)

    submit = np.sort(rng.uniform(0, 3 * 3600, J)).round()
    wall = np.clip(rng.lognormal(7.0, 0.8, J), 300, 2 * 3600).round()
    start = submit + np.clip(rng.exponential(300, J), 0, 1800).round()
    nodes = np.clip(rng.geometric(0.3, J), 1, 8)
    pd.DataFrame({
        "job_id": 1000 + np.arange(J),
        "time_submission": submit,
        "time_start": start,
        "time_end": start + wall,
        "time_limit": (wall * rng.uniform(1.2, 2.5, J)).round(),
        "node_count": nodes.astype(np.int64),
        "user": [f"u{rng.integers(0, 8)}" for _ in range(J)],
    }).to_csv(live_dir / "joblive.csv", index=False)

    # measured per-node power for two thirds of the jobs, sampled at a
    # cadence (45 s) deliberately off the engine grid (20 s) so the LOCF
    # resample path is exercised
    rows = []
    for j in range(J):
        if j % 3 == 2:
            continue   # profile-less job: replay falls back to the model
        t = np.arange(start[j], start[j] + wall[j], 45.0)
        base = rng.uniform(350, 1500)
        p = base * (1.0 + 0.2 * np.sin(2 * np.pi * (t - start[j]) / 600.0)
                    + rng.normal(0, 0.03, len(t)))
        rows.append(pd.DataFrame({
            "timestamp": t, "job_id": 1000 + j,
            "node_power_w": np.clip(p, 50.0, None).round(1)}))
    pd.concat(rows, ignore_index=True).to_csv(
        prof_dir / "jobprofile.csv", index=False)


def make_weather(out: pathlib.Path) -> None:
    rng = np.random.default_rng(11)
    hours = np.arange(0, 7 * 24 + 1)
    t = hours * 3600.0
    day = 2 * np.pi * (hours % 24) / 24.0
    db = 24.0 + 7.0 * np.sin(day - 2 * np.pi * 10 / 24) \
        + rng.normal(0, 0.4, len(hours))
    # heat-wave days 3.5-5.5: push dry-bulb toward 40 °C and keep the air
    # humid enough that the wet-bulb clears the tower's comfortable range
    wave = np.clip(1 - np.abs(hours / 24.0 - 4.5) / 1.0, 0, 1)
    db = db + 11.0 * wave
    rh = np.clip(55 + 15 * np.cos(day) + 10 * wave
                 + rng.normal(0, 2, len(hours)), 20, 95)
    pd.DataFrame({
        "timestamp": _dt(t),
        "t_drybulb_c": db.round(2),
        "rh_pct": rh.round(1),
    }).to_csv(out / "weather_week.csv", index=False)


def _replayed_heat(out: pathlib.Path, n_groups: int) -> np.ndarray:
    """Host-side replay of the telemetry fixture's measured power onto
    the calibration grid: at each step, sum nodes x measured node power
    over the jobs recorded as running — the 'replayed power trace' the
    calibration consumes, derived from fixture bytes alone (no engine in
    the loop, so a scheduler change can't invalidate the calibration
    fixture)."""
    live = pd.read_csv(out / "joblive" / "date=2024-01-18" / "joblive.csv")
    prof = pd.read_csv(out / "jobprofile" / "date=2024-01-18"
                       / "jobprofile.csv")
    tgrid = np.arange(CAL_STEPS) * CAL_DT
    # loop the ~4 h telemetry window over the 12 h calibration window
    span = float(live["time_end"].max())
    p_it = np.zeros(CAL_STEPS)
    for jid, g in prof.groupby("job_id"):
        row = live[live["job_id"] == jid].iloc[0]
        ts = g["timestamp"].to_numpy(np.float64)
        pw = g["node_power_w"].to_numpy(np.float64)
        tt = np.mod(tgrid, span)
        running = (tt >= row["time_start"]) & (tt < row["time_end"])
        idx = np.clip(np.searchsorted(ts, tt, side="right") - 1,
                      0, len(ts) - 1)
        p_it += np.where(running, pw[idx] * row["node_count"], 0.0)
    # scale the toy fleet to plant load so the HX actually works
    return p_it * (25e6 / max(p_it.mean(), 1.0))


def make_calibration(out: pathlib.Path) -> None:
    from repro.systems.config import SYSTEMS
    from repro.traces import load_weather
    import repro.traces.calibrate as cal

    cal_dir = out / "calibration"
    cal_dir.mkdir(parents=True, exist_ok=True)
    cfg = SYSTEMS["frontier"].cooling
    heat = _replayed_heat(out, cfg.n_groups)
    wb = np.asarray(load_weather(out / "weather_week.csv", CAL_STEPS,
                                 CAL_DT, t0=CAL_T0).t_wetbulb_c, np.float64)
    truth = {
        "ua_w_k": cfg.ua_w_k * CAL_TRUTH["ua_w_k"],
        "tau_hx_s": cfg.tau_hx_s * CAL_TRUTH["tau_hx_s"],
        "basin_margin_c": CAL_TRUTH["basin_margin_c"],
    }
    obs = cal.simulate_plant(cfg, heat, CAL_DT, wb, overrides=truth)
    # sensor noise on the recorded channels: without it the fit is exact
    # and the committed envelope collapses to zero — a gate that then
    # demands bit-identical floats across backends instead of "the
    # physics still reproduces the calibration"
    nrng = np.random.default_rng(23)
    for ch, sig in (("t_basin_c", 0.05), ("t_supply_c", 0.05),
                    ("t_return_c", 0.05), ("pue", 5e-4)):
        obs[ch] = obs[ch] + nrng.normal(0.0, sig, len(obs[ch]))
    np.savez(cal_dir / "telemetry.npz",
             dt=np.float64(CAL_DT), p_it_w=heat.astype(np.float32),
             t_wetbulb_c=wb.astype(np.float32),
             **{k: v.astype(np.float32) for k, v in obs.items()},
             **{f"true_{k}": np.float64(v) for k, v in truth.items()})

    # fit from the *committed bytes* (f32 NPZ round-trip), not the f64
    # in-memory arrays — the envelope must equal exactly what the
    # regression gate recomputes from the fixture
    z = np.load(cal_dir / "telemetry.npz")
    heat, wb = z["p_it_w"], z["t_wetbulb_c"]
    obs = {ch: z[ch] for ch in ("t_basin_c", "t_supply_c", "t_return_c",
                                "pue")}
    fitted = cal.calibrate(cfg, heat, CAL_DT, wb, obs,
                           meta={"system": "frontier",
                                 "fixture": "tests/data/calibration",
                                 "truth": truth})
    fitted.save(cal_dir / "fitted_params.json")
    for n, v in fitted.params.items():
        err = abs(v - truth[n]) / truth[n]
        print(f"  {n}: fitted {v:.6g} truth {truth[n]:.6g} "
              f"rel err {err:.3%}")
    print(f"  envelope: {fitted.envelope}")


def main() -> None:
    DATA.mkdir(parents=True, exist_ok=True)
    make_pm100(DATA)
    print("pm100_small.parquet / .swf")
    make_telemetry(DATA)
    print("joblive/ + jobprofile/")
    make_weather(DATA)
    print("weather_week.csv")
    make_calibration(DATA)
    print(f"fixtures -> {DATA}")


if __name__ == "__main__":
    main()
