#!/usr/bin/env python
"""Perf-trajectory gate: diff a BENCH_*.json against its committed history.

The smoke benchmarks (``benchmarks/engine_throughput.py --smoke``,
``benchmarks/fig10_ml.py --smoke``) write ``BENCH_engine.json`` /
``BENCH_ml.json``. This tool extracts every throughput metric from such a
file — any numeric JSON leaf whose key ends in ``_per_s``, named by its
path (``engine/smoke.steps_per_s``, ``train.generations_per_s``) — and
compares it against the median of the most recent history entries recorded
on the *same backend* (a laptop-CPU run never gates a GPU baseline; the
``meta`` block written by ``benchmarks.common.bench_meta`` carries the
backend).

History lives in ``benchmarks/baselines/*.ndjson``, one JSON object per
line::

    {"ts": ..., "git_sha": ..., "backend": "cpu", "device": ...,
     "metrics": {"engine/smoke.steps_per_s": 123.4, ...}}

Exit codes: 0 = within threshold (or no comparable history — first run on
a backend is a free pass, noted on stderr); 1 = at least one metric
regressed by more than ``--threshold`` (default 30%) vs its baseline
median; 2 = bad invocation / unreadable input.

``--append`` adds the current run to the history file after the
comparison, so CI extends the trajectory on every green run. See
docs/observability.md for the full workflow.

Usage:
  python tools/bench_compare.py BENCH_engine.json \
      --history benchmarks/baselines/engine_history.ndjson --append
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import subprocess
import sys
import time

WINDOW = 5  # baseline = median over the last <= WINDOW same-backend runs


def extract_metrics(obj, prefix: str = "") -> dict:
    """Numeric leaves whose key ends in ``_per_s``, keyed by JSON path."""
    out: dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, (dict, list)):
                out.update(extract_metrics(v, path))
            elif (isinstance(v, (int, float)) and not isinstance(v, bool)
                  and str(k).endswith("_per_s")):
                out[path] = float(v)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(extract_metrics(v, f"{prefix}[{i}]"))
    return out


def load_history(path: pathlib.Path) -> list[dict]:
    entries = []
    if not path.exists():
        return entries
    for n, line in enumerate(path.read_text().splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        try:
            e = json.loads(line)
        except json.JSONDecodeError as exc:
            print(f"{path}:{n}: skipping unparseable history line ({exc})",
                  file=sys.stderr)
            continue
        if isinstance(e, dict) and isinstance(e.get("metrics"), dict):
            entries.append(e)
    return entries


def _git_sha() -> str | None:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except OSError:
        return None


def compare(current: dict, history: list[dict], backend: str,
            threshold: float) -> tuple[list[str], list[str]]:
    """Return (report lines, regression lines)."""
    same = [e for e in history if e.get("backend") == backend]
    report, regressions = [], []
    if not same:
        report.append(f"no history for backend={backend!r} "
                      f"({len(history)} entries total) — nothing to gate")
        return report, regressions
    window = same[-WINDOW:]
    for name, cur in sorted(current.items()):
        vals = [e["metrics"][name] for e in window
                if isinstance(e["metrics"].get(name), (int, float))]
        if not vals:
            report.append(f"  {name}: {cur:.3f} (new metric, no baseline)")
            continue
        base = statistics.median(vals)
        ratio = cur / base if base else float("inf")
        line = (f"  {name}: {cur:.3f} vs median({len(vals)})="
                f"{base:.3f}  ({ratio * 100:.0f}% of baseline)")
        if base > 0 and cur < base * (1.0 - threshold):
            regressions.append(
                f"REGRESSION {name}: {cur:.3f} < {base:.3f} "
                f"* (1 - {threshold:.0%})")
            line += "  <-- REGRESSION"
        report.append(line)
    return report, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="gate a BENCH_*.json against its committed history")
    ap.add_argument("bench", help="current BENCH_*.json to gate")
    ap.add_argument("--history", required=True,
                    help="NDJSON history file (benchmarks/baselines/...)")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed fractional drop vs baseline median")
    ap.add_argument("--append", action="store_true",
                    help="append this run to the history after comparing")
    args = ap.parse_args(argv)

    bench_path = pathlib.Path(args.bench)
    try:
        payload = json.loads(bench_path.read_text())
    except (OSError, json.JSONDecodeError) as exc:
        print(f"cannot read {bench_path}: {exc}", file=sys.stderr)
        return 2
    meta = payload.get("meta", {}) if isinstance(payload, dict) else {}
    backend = meta.get("backend", "unknown")
    current = extract_metrics(payload)
    if not current:
        print(f"{bench_path}: no *_per_s metrics found", file=sys.stderr)
        return 2

    hist_path = pathlib.Path(args.history)
    history = load_history(hist_path)
    report, regressions = compare(current, history, backend, args.threshold)
    print(f"{bench_path.name} [backend={backend}] vs {hist_path}:")
    for line in report:
        print(line)
    for line in regressions:
        print(line, file=sys.stderr)

    if args.append:
        hist_path.parent.mkdir(parents=True, exist_ok=True)
        entry = {"ts": time.time(), "git_sha": _git_sha(),
                 "backend": backend, "device": meta.get("device"),
                 "metrics": current}
        with hist_path.open("a") as f:
            f.write(json.dumps(entry, sort_keys=True) + "\n")
        print(f"appended run to {hist_path} "
              f"({len(history) + 1} entries)")

    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
