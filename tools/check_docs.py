#!/usr/bin/env python
"""Docs checker: every intra-repo markdown link must resolve.

Two passes:

1. *Markdown links* — scans the repo's *.md files (root + docs/) for
   inline links and images ``[text](target)`` and verifies that non-URL
   targets exist relative to the file that references them (anchors are
   stripped; pure-anchor and mailto / http(s) links are skipped).
2. *Source references* — scans the Python sources (src/, tools/,
   benchmarks/, examples/, tests/) for repo-relative ``*.md`` mentions in
   docstrings and comments (e.g. ``see docs/architecture.md``) and
   verifies the referenced file exists. This is what catches a docstring
   citing a design document that was never committed or later renamed.

Exit code 1 lists every broken reference.

CI runs this plus ``python -m doctest docs/*.md`` (the fenced examples in
the docs are real doctests) — see .github/workflows/ci.yml.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
# a markdown-file token (optionally path-qualified); the trailing
# guard keeps attribute accesses like ``cfg.mdot_kg_s`` from matching
MD_REF_RE = re.compile(r"(?<![\w.])([\w][\w./-]*\.md)(?![\w])")
SRC_DIRS = ("src", "tools", "benchmarks", "examples", "tests")


def md_files() -> list[pathlib.Path]:
    files = sorted(ROOT.glob("*.md"))
    docs = ROOT / "docs"
    if docs.is_dir():
        files += sorted(docs.rglob("*.md"))
    return files


def check_file(path: pathlib.Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    # ignore links inside fenced code blocks (examples, not navigation)
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link -> {target}")
    return errors


def py_files() -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for d in SRC_DIRS:
        p = ROOT / d
        if p.is_dir():
            files += sorted(p.rglob("*.py"))
    return files


def check_source(path: pathlib.Path) -> list[str]:
    """Repo-relative ``*.md`` references in a Python source must exist.

    A bare name (``ROADMAP.md``) resolves against the repo root; a
    path-qualified one (``docs/architecture.md``) resolves as written."""
    errors = []
    text = path.read_text(encoding="utf-8")
    for m in MD_REF_RE.finditer(text):
        target = m.group(1)
        if not (ROOT / target).exists():
            line = text.count("\n", 0, m.start()) + 1
            errors.append(f"{path.relative_to(ROOT)}:{line}: dangling "
                          f"doc reference -> {target}")
    return errors


def main() -> int:
    errors = []
    for path in md_files():
        errors += check_file(path)
    for path in py_files():
        errors += check_source(path)
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{len(errors)} broken doc reference(s)", file=sys.stderr)
        return 1
    print(f"checked {len(md_files())} markdown files and "
          f"{len(py_files())} python sources: all doc references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
