#!/usr/bin/env python
"""Docs checker: every intra-repo markdown link must resolve.

Scans the repo's *.md files (root + docs/) for inline links and images
``[text](target)`` and verifies that non-URL targets exist relative to the
file that references them (anchors are stripped; pure-anchor and mailto /
http(s) links are skipped). Exit code 1 lists every broken link.

CI runs this plus ``python -m doctest docs/*.md`` (the fenced examples in
the docs are real doctests) — see .github/workflows/ci.yml.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files() -> list[pathlib.Path]:
    files = sorted(ROOT.glob("*.md"))
    docs = ROOT / "docs"
    if docs.is_dir():
        files += sorted(docs.rglob("*.md"))
    return files


def check_file(path: pathlib.Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    # ignore links inside fenced code blocks (examples, not navigation)
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link -> {target}")
    return errors


def main() -> int:
    errors = []
    for path in md_files():
        errors += check_file(path)
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{len(errors)} broken markdown link(s)", file=sys.stderr)
        return 1
    print(f"checked {len(md_files())} markdown files: all intra-repo "
          f"links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
