#!/usr/bin/env python
"""Stdlib-only client for the twin service (stdlib only — no repro).

Speaks the ``simulate serve`` dialect documented in docs/serving.md:
newline-delimited JSON frames over a Unix-domain or TCP socket, one
request/reply pair at a time, after reading the server's ``hello``
greeting. Because it only needs the standard library it doubles as the
porting template for driving the twin from any language — and as the
fault-injection vehicle for the serve soak test.

Library use::

    from tools.twin_client import TwinClient
    with TwinClient("unix:/tmp/twin.sock") as c:
        c.advance(0, intervals=3)
        child = c.fork(0, {"setpoint_delta_c": 2.0})
        rows = c.fetch(child["branch"])["rows"]

Scripted CLI (one command per ``;``)::

    python -m tools.twin_client --connect unix:/tmp/twin.sock \\
        --script "advance 0 3; fork 0 setpoint_delta_c=2.0; \\
                  advance 1 2; fetch 1; state; shutdown"

Script grammar: ``advance BRANCH [INTERVALS]`` · ``fork BRANCH
[at=STEP] [knob=value ...]`` · ``snapshot BRANCH [at=STEP]`` ·
``fetch BRANCH [START STOP]`` · ``state`` · ``shutdown`` · ``bye`` ·
``sleep SECONDS``. ``BRANCH`` is an id or ``last`` (the branch created
by this client's most recent fork). Every reply prints as one JSON
line on stdout.

``--fault MODE`` injects client misbehavior (for the soak test):
``die:N`` (exit abruptly after N requests, socket left dangling),
``garbage`` (send a non-JSON line, print the error reply), ``badbranch``
(request a branch id that cannot exist, print the error envelope),
``hang`` (connect, then send nothing until the server drops us).
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import struct
import sys
import time

WIRE_VERSION = 1
MAX_FRAME_BYTES = 256 << 20  # keep equal to repro.core.transport's cap

# RBW1 binary reply frames (requested with "bin": true on snapshot /
# fetch; layout documented in repro.core.transport). Array placeholders
# decode to {"dtype", "shape", "values"} dicts — JSON-printable, with
# the wire dtype preserved for digest checks.
BIN_MAGIC = b"RBW1"
BIN_LENS = struct.Struct("<II")
DTYPE_FMT = {"<f8": "d", "<f4": "f", "<i8": "q", "<i4": "i",
             "<u8": "Q", "<u4": "I", "|b1": "?"}


def decode_bin_payload(obj, payload):
    """Placeholders -> {"dtype", "shape", "values"} dicts (1-D/0-D)."""
    sizes = {}

    def walk(o):
        if isinstance(o, dict):
            if "__bin__" in o:
                n = 1
                for s in o["shape"]:
                    n *= int(s)
                sizes[int(o["__bin__"])] = \
                    n * struct.calcsize(DTYPE_FMT[o["dtype"]])
                return
            for v in o.values():
                walk(v)
        elif isinstance(o, list):
            for v in o:
                walk(v)

    walk(obj)
    offsets, off = {}, 0
    for i in range(len(sizes)):
        offsets[i] = off
        off += sizes[i]
    if off != len(payload):
        raise ValueError("binary payload length mismatch")

    def restore(o):
        if isinstance(o, dict):
            if "__bin__" in o:
                i = int(o["__bin__"])
                n = sizes[i] // struct.calcsize(DTYPE_FMT[o["dtype"]])
                fmt = "<%d%s" % (n, DTYPE_FMT[o["dtype"]])
                return {"dtype": o["dtype"], "shape": list(o["shape"]),
                        "values": list(struct.unpack_from(
                            fmt, payload, offsets[i]))}
            return {k: restore(v) for k, v in o.items()}
        if isinstance(o, list):
            return [restore(v) for v in o]
        return o

    return restore(obj)


def parse_address(addr):
    """``unix:/path`` or a bare path -> AF_UNIX; ``host:port`` -> TCP."""
    if addr.startswith("unix:"):
        return socket.AF_UNIX, addr[len("unix:"):]
    if addr.startswith("tcp:"):
        addr = addr[len("tcp:"):]
    if "/" in addr:
        return socket.AF_UNIX, addr
    host, _, port = addr.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"address must be unix:/path or host:port, "
                         f"got {addr!r}")
    return socket.AF_INET, (host, int(port))


class TwinError(RuntimeError):
    """The twin answered with an ``error`` envelope."""

    def __init__(self, frame):
        super().__init__(frame.get("message", "twin error"))
        self.frame = frame
        self.error = frame.get("error")   # "protocol" | "session"


class TwinClient:
    """One connection to a ``simulate serve`` twin."""

    def __init__(self, address, timeout_s=30.0):
        family, sockaddr = parse_address(address)
        self.sock = socket.socket(family, socket.SOCK_STREAM)
        self.sock.settimeout(timeout_s)
        self.sock.connect(sockaddr)
        self.rfile = self.sock.makefile("rb")
        self.wfile = self.sock.makefile("wb")
        self.n_requests = 0
        self.hello = self._read()
        if self.hello.get("kind") != "hello":
            raise TwinError({"error": "protocol",
                             "message": f"expected hello, got "
                                        f"{self.hello.get('kind')!r}"})

    # -- framing ------------------------------------------------------------
    def _write(self, msg):
        line = json.dumps(msg, separators=(",", ":")).encode() + b"\n"
        self.wfile.write(line)
        self.wfile.flush()

    def _read(self):
        first = self.rfile.read(1)
        if not first:
            raise ConnectionError("twin closed the connection (EOF)")
        if first == BIN_MAGIC[:1]:
            rest = self.rfile.read(len(BIN_MAGIC) - 1)
            if first + rest != BIN_MAGIC:
                raise ValueError(f"bad binary frame magic "
                                 f"{(first + rest)!r}")
            lens = self.rfile.read(BIN_LENS.size)
            header_len, payload_len = BIN_LENS.unpack(lens)
            header = self.rfile.read(header_len)
            payload = self.rfile.read(payload_len)
            if len(header) < header_len or len(payload) < payload_len:
                raise ConnectionError("truncated binary frame")
            return decode_bin_payload(json.loads(header), payload)
        line = first + self.rfile.readline(MAX_FRAME_BYTES + 1)
        return json.loads(line)

    def write_raw(self, data: bytes):
        """Ship arbitrary bytes (the ``garbage`` fault)."""
        self.wfile.write(data)
        self.wfile.flush()

    def request(self, kind, **fields):
        """One request/reply roundtrip; raises ``TwinError`` on an
        error envelope (connection-fatal "protocol" errors also close)."""
        msg = {"version": WIRE_VERSION, "kind": kind,
               "id": self.n_requests}
        msg.update({k: v for k, v in fields.items() if v is not None})
        self.n_requests += 1
        self._write(msg)
        reply = self._read()
        if reply.get("kind") == "error":
            raise TwinError(reply)
        return reply

    # -- verbs --------------------------------------------------------------
    def advance(self, branch, intervals=1):
        return self.request("advance", branch=branch, intervals=intervals)

    def fork(self, branch, delta=None, at_step=None):
        return self.request("fork", branch=branch, delta=delta or {},
                            at_step=at_step)

    def snapshot(self, branch, at_step=None, binary=False):
        return self.request("snapshot", branch=branch, at_step=at_step,
                            bin=True if binary else None)

    def fetch(self, branch, start=None, stop=None, binary=False):
        return self.request("fetch", branch=branch, start=start, stop=stop,
                            bin=True if binary else None)

    def state(self):
        return self.request("state")

    def shutdown(self):
        return self.request("shutdown")

    def close(self, polite=True):
        try:
            if polite:
                self.request("bye")
        except (OSError, ConnectionError, TwinError, ValueError):
            pass
        for f in (self.wfile, self.rfile):
            try:
                f.close()
            except OSError:
                pass
        self.sock.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# Scripted CLI.
# ---------------------------------------------------------------------------
def _parse_value(text):
    """Knob value: number, comma list of numbers, or bare word."""
    if "," in text:
        return [float(x) for x in text.split(",")]
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            pass
    return text


def run_command(client, words):
    """Execute one script command; return the reply (or None)."""
    verb, args = words[0], words[1:]

    def branch(tok):
        if tok == "last":
            if getattr(client, "last_branch", None) is None:
                raise ValueError("'last' before any fork in this script")
            return client.last_branch
        return int(tok)

    if verb == "advance":
        return client.advance(branch(args[0]),
                              int(args[1]) if len(args) > 1 else 1)
    if verb == "fork":
        at_step, delta = None, {}
        for tok in args[1:]:
            key, _, val = tok.partition("=")
            if key == "at":
                at_step = int(val)
            else:
                delta[key] = _parse_value(val)
        reply = client.fork(branch(args[0]), delta, at_step)
        client.last_branch = reply["branch"]
        return reply
    if verb == "snapshot":
        at_step, binary = None, False
        for tok in args[1:]:
            if tok == "bin":
                binary = True
                continue
            key, _, val = tok.partition("=")
            if key == "at":
                at_step = int(val)
        return client.snapshot(branch(args[0]), at_step, binary=binary)
    if verb == "fetch":
        binary = "bin" in args[1:]
        pos = [a for a in args[1:] if a != "bin"]
        return client.fetch(branch(args[0]),
                            int(pos[0]) if len(pos) > 0 else None,
                            int(pos[1]) if len(pos) > 1 else None,
                            binary=binary)
    if verb == "state":
        return client.state()
    if verb == "shutdown":
        return client.shutdown()
    if verb == "bye":
        client.close(polite=True)
        return {"kind": "bye_ok"}
    if verb == "sleep":
        time.sleep(float(args[0]))
        return None
    raise ValueError(f"unknown script verb {verb!r}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse
                                 .RawDescriptionHelpFormatter)
    ap.add_argument("--connect", required=True,
                    help="twin address: unix:/path or host:port")
    ap.add_argument("--script", default="state; bye",
                    help="';'-separated commands (see module docstring)")
    ap.add_argument("--repeat", type=int, default=1,
                    help="run the script this many times on one "
                         "connection")
    ap.add_argument("--timeout", type=float, default=30.0)
    ap.add_argument("--fault", default=None,
                    help="die:N | garbage | badbranch | hang")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress reply JSON on stdout")
    args = ap.parse_args(argv)

    fault = args.fault or ""
    client = TwinClient(args.connect, timeout_s=args.timeout)
    emit = (lambda obj: None) if args.quiet else (
        lambda obj: print(json.dumps(obj), flush=True))
    emit(client.hello)

    if fault == "hang":
        # send nothing; the server's read timeout reaps us
        try:
            client._read()
        except (ConnectionError, OSError, ValueError):
            pass
        return 0
    if fault == "garbage":
        client.write_raw(b"this is not json\n")
        try:
            emit(client._read())
        except (ConnectionError, OSError, ValueError):
            pass
        client.close(polite=False)
        return 0
    if fault == "badbranch":
        try:
            client.advance(999999, 1)
        except TwinError as e:
            emit(e.frame)
        client.close()
        return 0
    die_after = int(fault.split(":", 1)[1]) if fault.startswith("die") \
        else None

    commands = [c.split() for c in args.script.split(";") if c.split()]
    for _ in range(args.repeat):
        for words in commands:
            if die_after is not None and client.n_requests >= die_after:
                os._exit(1)   # abrupt: no bye, no socket shutdown
            try:
                reply = run_command(client, words)
            except TwinError as e:
                emit(e.frame)
                if e.error == "protocol":
                    return 2
                continue
            if reply is not None:
                emit(reply)
            if words[0] in ("bye", "shutdown"):
                if words[0] == "shutdown":
                    client.close(polite=False)
                return 0
    client.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
