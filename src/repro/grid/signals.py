"""Time-varying grid signals for sustainability what-ifs.

A ``GridSignals`` bundle holds three per-step arrays sampled at the engine
``dt`` — carbon intensity (g CO2 / kWh), electricity price ($ / kWh) and a
facility IT power-cap schedule (W, ``inf`` = uncapped) — plus precomputed
trailing rolling means of carbon and price so "is the signal above its
recent average?" is a single in-scan gather, not a windowed reduction.

Signals are *host-precomputed* numpy -> device arrays: the compiled engine
only ever indexes them by step (clamped, LOCF-style, matching the job
profile semantics of paper §3.2.2), so one signal set is shared across an
entire vmapped scenario sweep and per-scenario cap levels are expressed as
a traced multiplier (``Scenario.cap_scale``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import _register
from repro.systems.config import GridConfig


@_register
@dataclass
class GridSignals:
    """Per-step grid signals. Shapes: f32[S] (S = engine steps)."""
    carbon_gkwh: jnp.ndarray   # carbon intensity (g CO2 / kWh)
    price_kwh: jnp.ndarray     # electricity price ($ / kWh)
    cap_w: jnp.ndarray         # facility IT power cap (W); +inf = uncapped
    carbon_ref: jnp.ndarray    # trailing rolling mean of carbon_gkwh
    price_ref: jnp.ndarray     # trailing rolling mean of price_kwh

    @property
    def num_steps(self) -> int:
        return self.carbon_gkwh.shape[0]


class GridNow(NamedTuple):
    """The signal values active at one engine step (scalars, traced)."""
    carbon: jnp.ndarray      # f32[] g CO2 / kWh
    carbon_ref: jnp.ndarray  # f32[] rolling mean
    price: jnp.ndarray       # f32[] $ / kWh
    price_ref: jnp.ndarray   # f32[] rolling mean
    cap_w: jnp.ndarray       # f32[] base cap (pre Scenario.cap_scale)


def at_step(signals: GridSignals, step: jnp.ndarray) -> GridNow:
    """Gather the signal row active at ``step`` (clamped into range,
    LOCF-style like job profiles, paper §3.2.2).

    Args:
      signals: per-step arrays sampled at the engine ``dt``.
      step: i32[] engine step index (``SimState.step``).
    Returns:
      Traced scalars: carbon (g CO2/kWh), price ($/kWh), their rolling
      means, and the base cap (W, before ``Scenario.cap_scale``).
    """
    i = jnp.clip(step, 0, signals.num_steps - 1)
    return GridNow(carbon=signals.carbon_gkwh[i],
                   carbon_ref=signals.carbon_ref[i],
                   price=signals.price_kwh[i],
                   price_ref=signals.price_ref[i],
                   cap_w=signals.cap_w[i])


def now_neutral() -> GridNow:
    """Signal values that make every grid-aware term a no-op."""
    z = jnp.float32(0.0)
    one = jnp.float32(1.0)
    return GridNow(carbon=z, carbon_ref=one, price=z, price_ref=one,
                   cap_w=jnp.float32(jnp.inf))


def _rolling_mean(x: np.ndarray, window: int) -> np.ndarray:
    """Trailing mean over the last ``window`` samples (partial at the start)."""
    w = max(int(window), 1)
    c = np.concatenate([[0.0], np.cumsum(x, dtype=np.float64)])
    i = np.arange(1, len(x) + 1)
    lo = np.maximum(i - w, 0)
    return ((c[i] - c[lo]) / (i - lo)).astype(np.float32)


def constant_signals(n_steps: int, carbon_gkwh: float = 0.0,
                     price_kwh: float = 0.0,
                     cap_w: float = float("inf")) -> GridSignals:
    """Flat signals; refs equal the signal so the deferral excess is zero.

    Args:
      n_steps: number of engine steps to cover.
      carbon_gkwh: constant carbon intensity (g CO2 / kWh).
      price_kwh: constant electricity price ($ / kWh).
      cap_w: constant facility IT power cap (W); ``inf`` = uncapped.
    """
    full = lambda v: jnp.full((max(n_steps, 1),), v, jnp.float32)
    return GridSignals(carbon_gkwh=full(carbon_gkwh),
                       price_kwh=full(price_kwh), cap_w=full(cap_w),
                       carbon_ref=full(max(carbon_gkwh, 1.0)),
                       price_ref=full(max(price_kwh, 1e-6)))


def neutral(n_steps: int) -> GridSignals:
    """Default signals: zero carbon/price, uncapped — grid layer inert."""
    return constant_signals(n_steps)


def synthetic_signals(cfg: GridConfig, n_steps: int, dt: float,
                      t0: float = 0.0, cap_base_w: float = float("inf"),
                      cap_peak_w: float | None = None,
                      seed: int = 0) -> GridSignals:
    """Diurnal + AR(1)-noise generators for carbon, price and the cap.

    Carbon peaks mid-day-ish trough overnight (fossil marginal mix); price
    peaks in the evening window ``cfg.peak_hours``, during which the cap
    schedule drops from ``cap_base_w`` to ``cap_peak_w`` (when given) —
    the "cap the machine during the price peak" what-if.
    """
    rng = np.random.default_rng(seed)
    t = t0 + dt * np.arange(n_steps, dtype=np.float64)
    hours = (t / 3600.0) % 24.0
    day = 2 * np.pi * t / 86400.0

    def ar1_noise(frac):
        e = rng.normal(0.0, frac, n_steps)
        out = np.empty(n_steps)
        acc = 0.0
        rho = 0.95
        for i in range(n_steps):
            acc = rho * acc + np.sqrt(1 - rho * rho) * e[i]
            out[i] = acc
        return out

    carbon = cfg.carbon_mean_gkwh + cfg.carbon_amp_gkwh * np.sin(
        day - np.pi / 2)  # trough at midnight, peak mid-afternoon
    carbon = np.maximum(carbon * (1.0 + ar1_noise(cfg.noise_frac)), 1.0)

    peak_lo, peak_hi = cfg.peak_hours
    evening = np.exp(-0.5 * ((hours - (peak_lo + peak_hi) / 2) / 2.0) ** 2)
    price = cfg.price_mean_kwh + cfg.price_amp_kwh * (
        0.6 * np.sin(day - np.pi / 2) + 1.4 * evening)
    price = np.maximum(price * (1.0 + ar1_noise(cfg.noise_frac)), 1e-4)

    cap = np.full(n_steps, cap_base_w, np.float64)
    if cap_peak_w is not None:
        in_peak = (hours >= peak_lo) & (hours < peak_hi)
        cap = np.where(in_peak, cap_peak_w, cap)

    w = int(round(cfg.ref_window_s / dt))
    return GridSignals(
        carbon_gkwh=jnp.asarray(carbon, jnp.float32),
        price_kwh=jnp.asarray(price, jnp.float32),
        cap_w=jnp.asarray(cap, jnp.float32),
        carbon_ref=jnp.asarray(_rolling_mean(carbon, w)),
        price_ref=jnp.asarray(_rolling_mean(price, w)))
