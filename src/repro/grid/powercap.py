"""DVFS power-cap enforcement (per engine step).

When the projected IT power exceeds the active cap, every running node is
throttled by a common cap factor ``c`` in ``[c_min, 1]``. DVFS only buys
back *dynamic* power: each node keeps its idle floor and scales the draw
above it,

    p_throttled = min(p, idle) + c * max(p - idle, 0)

so the solvable cap range is ``[floor_total, raw_total]`` and

    c = clip((cap - floor_total) / dyn_total, c_min, 1).

Per-group aggregation reuses the ``kernels/power_topo`` segment-reduce (the
same reduction that feeds the cooling model), so the throttled per-CDU heat
loads come out of the enforcement pass for free.

The runtime cost of throttling is modelled as proportional slowdown: the
engine stretches every affected job's remaining runtime by ``1/c`` for the
throttled step (repro.core.engine._tick).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.kernels.power_topo import ops as topo_ops
from repro.systems.config import SystemConfig


class CapResult(NamedTuple):
    c: jnp.ndarray           # f32[]  cap factor in [c_min, 1]
    p_it: jnp.ndarray        # f32[]  throttled total IT power (W)
    group_heat: jnp.ndarray  # f32[G] throttled per-CDU-group heat (W)
    p_it_raw: jnp.ndarray    # f32[]  unthrottled IT power (W)


def throttle_power(pw: jnp.ndarray, idle_w: float,
                   c: jnp.ndarray) -> jnp.ndarray:
    """Scale the dynamic (above-idle) share of a power array by ``c``.

    Args:
      pw: f32[...] power draws (W).
      idle_w: per-node idle floor (W) — not DVFS-addressable.
      c: f32[] cap factor in [c_min, 1].
    Returns:
      f32[...] throttled powers (W): ``min(pw, idle) + c·max(pw−idle, 0)``.
    """
    floor = jnp.minimum(pw, idle_w)
    return floor + c * (pw - floor)


def enforce_cap(system: SystemConfig, node_pw: jnp.ndarray,
                cap_w: jnp.ndarray) -> CapResult:
    """Compute the cap factor for this step and the throttled aggregates.

    Args:
      node_pw: f32[N] per-node power draws (W).
      cap_w: f32[] active facility IT power cap (W); ``inf`` = uncapped
        -> c = 1. A cap below the idle floor saturates at ``c_min``: the
        idle draw is not DVFS-addressable, matching real power-capping
        interfaces.
    Returns:
      ``CapResult``: cap factor c, throttled total IT power (W), throttled
      per-CDU-group heat (W) and the unthrottled total (W).
    """
    idle = system.power.idle_node_w
    floor = jnp.minimum(node_pw, idle)
    dyn = node_pw - floor
    G = system.cooling.n_groups
    floor_g = topo_ops.group_power(floor, G)
    dyn_g = topo_ops.group_power(dyn, G)
    floor_tot = jnp.sum(floor_g)
    dyn_tot = jnp.sum(dyn_g)

    c_raw = (cap_w - floor_tot) / jnp.maximum(dyn_tot, 1.0)
    c = jnp.clip(c_raw, system.grid.c_min, 1.0)
    c = jnp.where(jnp.isfinite(cap_w), c, jnp.float32(1.0))

    group_heat = floor_g + c * dyn_g
    return CapResult(c=c, p_it=floor_tot + c * dyn_tot,
                     group_heat=group_heat, p_it_raw=floor_tot + dyn_tot)
