"""Grid-aware power management: time-varying grid signals (carbon intensity,
electricity price, facility power-cap schedule), DVFS cap enforcement, and
the sustainability-aware scheduling hooks they feed.

``signals``  -- precomputed per-step signal arrays + in-scan indexing.
``powercap`` -- per-step proportional DVFS throttle against the active cap.
"""
from repro.grid.signals import (  # noqa: F401
    GridNow, GridSignals, at_step, constant_signals, neutral,
    synthetic_signals)
from repro.grid.powercap import enforce_cap, throttle_power  # noqa: F401
