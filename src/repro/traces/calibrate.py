"""Calibrate the transient cooling plant against recorded telemetry.

The cooling twin (repro.cooling.model) has a handful of lumped
parameters nobody measures directly — HX conductance ``ua_w_k``, loop
time constants ``tau_hx_s`` / ``tower_tau_s``, the fan-staging threshold
``basin_margin_c``. This module fits them: drive the plant with a
*replayed* power trace (measured IT heat per step, repro.traces
telemetry) and the recorded ambient wet-bulb, and least-squares the
simulated facility observables (basin temperature, PUE) against the
recorded ones over full rollouts.

The forward model is ONE jitted ``lax.scan``: the candidate parameters
enter as traced scalars via ``dataclasses.replace`` on the (frozen)
``CoolingConfig`` — every fitted field is only ever used in jnp
arithmetic, so swapping tracers in costs nothing and scipy's
``least_squares`` iterates without a single recompile.

The result is a ``FittedParams`` JSON: the fitted values plus a
*residual envelope* (per-channel RMSE on the calibration window). The
envelope is a regression gate — tests/test_calibrate.py recomputes the
residuals of the committed fixture and fails if they widened, so a
physics change that silently degrades calibration cannot land.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

from repro.cooling import model as cooling
from repro.systems.config import CoolingConfig
from repro.traces.errors import TraceError

# Fittable CoolingConfig fields and their search bounds (physical, wide).
FIT_BOUNDS: dict[str, tuple[float, float]] = {
    "ua_w_k": (1e4, 1e7),
    "tau_hx_s": (10.0, 2000.0),
    "tau_valve_s": (5.0, 600.0),
    "basin_margin_c": (0.5, 10.0),
    "tower_tau_s": (60.0, 3600.0),
}
DEFAULT_FIT = ("ua_w_k", "tau_hx_s", "basin_margin_c")

# Residual scales: one unit of weighted residual ~ "equally bad" across
# channels (1 °C of water-temperature error vs 0.01 of PUE error).
# Supply/return are the channels that actually observe ``ua_w_k`` /
# ``tau_hx_s`` (the HX sits between basin and supply; the basin only
# sees the heat passthrough), basin + PUE observe the tower-side
# parameters — a useful fit wants at least one from each side.
_SCALES = {"t_basin_c": 1.0, "t_supply_c": 1.0, "t_return_c": 1.0,
           "pue": 0.01}


@dataclasses.dataclass
class FittedParams:
    """A calibration result: fitted values + its regression envelope."""
    params: dict          # fitted CoolingConfig fields -> value
    envelope: dict        # channel -> RMSE on the calibration window
    cost: float           # final least-squares cost (0.5 * sum r^2)
    meta: dict            # n_steps / dt / discard / channels / digests

    def save(self, path: str | pathlib.Path) -> None:
        blob = dataclasses.asdict(self)
        pathlib.Path(path).write_text(json.dumps(blob, indent=2,
                                                 sort_keys=True) + "\n")

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "FittedParams":
        try:
            blob = json.loads(pathlib.Path(path).read_text())
            return cls(params=blob["params"], envelope=blob["envelope"],
                       cost=float(blob["cost"]), meta=blob["meta"])
        except (OSError, KeyError, ValueError) as e:
            raise TraceError(f"cannot read fitted-params JSON "
                             f"{path}: {e}") from e


def _as_group_heat(heat_w: np.ndarray, n_groups: int) -> jnp.ndarray:
    """f32[S] total IT power or f32[S, G] per-group heat -> f32[S, G]."""
    h = np.asarray(heat_w, np.float32)
    if h.ndim == 1:
        h = np.repeat(h[:, None] / n_groups, n_groups, axis=1)
    if h.ndim != 2 or h.shape[1] != n_groups:
        raise TraceError(f"heat trace must be [S] or [S, {n_groups}], "
                         f"got {h.shape}")
    if not np.isfinite(h).all() or (h < 0).any():
        raise TraceError("heat trace has non-finite or negative samples")
    return jnp.asarray(h)


def make_forward(cfg: CoolingConfig, names: tuple[str, ...],
                 group_heat_w: jnp.ndarray, dt: float,
                 t_wetbulb_c: jnp.ndarray):
    """Build the jitted rollout: theta f64[len(names)] -> per-step
    observables {t_basin_c: f32[S], pue: f32[S]}. The parameters are
    traced, so every candidate reuses one compiled graph."""
    for n in names:
        if n not in FIT_BOUNDS:
            raise TraceError(f"unknown fittable parameter {n!r} "
                             f"(know: {sorted(FIT_BOUNDS)})")
    wb = jnp.asarray(t_wetbulb_c, jnp.float32)

    @jax.jit
    def forward(theta):
        c = dataclasses.replace(
            cfg, **{n: theta[i].astype(jnp.float32)
                    for i, n in enumerate(names)})

        def body(state, inp):
            q, w = inp
            new, out = cooling.step(c, state, q, dt, t_wetbulb_c=w)
            p_it = jnp.sum(q)
            return new, (out.t_basin, out.t_supply_max,
                         out.t_tower_return,
                         cooling.pue(p_it, 0.0, out.p_cooling))
        _, (tb, ts, tr, pu) = jax.lax.scan(body, cooling.init_state(cfg),
                                           (group_heat_w, wb))
        return {"t_basin_c": tb, "t_supply_c": ts, "t_return_c": tr,
                "pue": pu}
    return forward


def simulate_plant(cfg: CoolingConfig, heat_w: np.ndarray, dt: float,
                   t_wetbulb_c: np.ndarray,
                   overrides: dict | None = None) -> dict:
    """Roll the cooling plant over a heat + weather trace -> observables
    as numpy arrays. ``overrides`` replaces fittable CoolingConfig
    fields — used both to generate synthetic calibration truth in tests
    and to evaluate a fit's residuals."""
    overrides = overrides or {}
    names = tuple(overrides)
    heat = _as_group_heat(heat_w, cfg.n_groups)
    if len(t_wetbulb_c) != heat.shape[0]:
        raise TraceError(f"weather ({len(t_wetbulb_c)}) and heat "
                         f"({heat.shape[0]}) traces disagree on steps")
    fwd = make_forward(cfg, names, heat, dt, t_wetbulb_c)
    theta = jnp.asarray([float(overrides[n]) for n in names], jnp.float32)
    return {k: np.asarray(v) for k, v in fwd(theta).items()}


def _residuals(sim: dict, obs: dict, discard: int) -> np.ndarray:
    rs = []
    for ch, scale in _SCALES.items():
        if ch in obs:
            r = (np.asarray(sim[ch], np.float64)[discard:]
                 - np.asarray(obs[ch], np.float64)[discard:]) / scale
            rs.append(r)
    if not rs:
        raise TraceError(f"telemetry carries none of the calibration "
                         f"channels {sorted(_SCALES)}")
    return np.concatenate(rs)


def _envelope(sim: dict, obs: dict, discard: int) -> dict:
    env = {}
    for ch in _SCALES:
        if ch in obs:
            r = (np.asarray(sim[ch], np.float64)[discard:]
                 - np.asarray(obs[ch], np.float64)[discard:])
            env[f"{ch}_rmse"] = float(np.sqrt(np.mean(r * r)))
    return env


def calibrate(cfg: CoolingConfig, heat_w: np.ndarray, dt: float,
              t_wetbulb_c: np.ndarray, obs: dict,
              fit: tuple[str, ...] = DEFAULT_FIT,
              discard_frac: float = 0.1,
              meta: dict | None = None) -> FittedParams:
    """Fit ``fit`` CoolingConfig fields to recorded facility telemetry.

    Args:
      cfg: the plant, holding the initial guess in its current values.
      heat_w: replayed IT heat, f32[S] total or f32[S, G] per group (W).
      dt: step (s) — both traces and the plant advance on this grid.
      t_wetbulb_c: recorded ambient wet-bulb, f32[S] (°C).
      obs: recorded observables — any of ``t_basin_c`` (f32[S], °C) and
        ``pue`` (f32[S]); at least one required.
      fit: which fields to fit (subset of ``FIT_BOUNDS``).
      discard_frac: leading fraction of the window excluded from the
        residual (plant spin-up from the idle initial condition).
      meta: extra provenance (trace digests, system name) stored in the
        result.

    Returns:
      ``FittedParams`` — fitted values, residual envelope (per-channel
      RMSE), final cost and provenance.
    """
    from scipy.optimize import least_squares
    heat = _as_group_heat(heat_w, cfg.n_groups)
    S = heat.shape[0]
    if len(t_wetbulb_c) != S:
        raise TraceError(f"weather ({len(t_wetbulb_c)}) and heat ({S}) "
                         f"traces disagree on steps")
    for ch in obs:
        if ch in _SCALES and len(obs[ch]) != S:
            raise TraceError(f"telemetry channel {ch!r} has "
                             f"{len(obs[ch])} steps, heat has {S}")
    discard = int(S * discard_frac)
    fwd = make_forward(cfg, tuple(fit), heat, dt, t_wetbulb_c)

    x0 = np.array([float(getattr(cfg, n)) for n in fit])
    lo = np.array([FIT_BOUNDS[n][0] for n in fit])
    hi = np.array([FIT_BOUNDS[n][1] for n in fit])

    def f(theta):
        sim = fwd(jnp.asarray(theta, jnp.float32))
        return _residuals({k: np.asarray(v) for k, v in sim.items()},
                          obs, discard)

    # diff_step must clear the f32 forward's quantization noise — the
    # default (~sqrt(eps) relative) produces an identically-zero numeric
    # Jacobian and the fit never leaves x0
    res = least_squares(f, np.clip(x0, lo, hi), bounds=(lo, hi),
                        x_scale=np.maximum(np.abs(x0), 1.0),
                        diff_step=1e-3, method="trf")
    params = {n: float(v) for n, v in zip(fit, res.x)}
    sim = {k: np.asarray(v)
           for k, v in fwd(jnp.asarray(res.x, jnp.float32)).items()}
    return FittedParams(
        params=params,
        envelope=_envelope(sim, obs, discard),
        cost=float(res.cost),
        meta={"n_steps": int(S), "dt": float(dt), "discard": discard,
              "fit": list(fit), "channels": sorted(set(obs) & set(_SCALES)),
              **(meta or {})})


def check_envelope(fitted: FittedParams, cfg: CoolingConfig,
                   heat_w: np.ndarray, dt: float,
                   t_wetbulb_c: np.ndarray, obs: dict,
                   slack: float = 1.05) -> dict:
    """The regression gate: re-simulate with the committed fitted params
    and compare fresh residuals against the committed envelope.

    Returns the fresh per-channel RMSEs; raises ``TraceError`` if any
    channel widened beyond ``envelope * slack`` (the documented 5%
    numerical slack — jit/toolchain noise, not physics drift)."""
    sim = simulate_plant(cfg, heat_w, dt, t_wetbulb_c,
                         overrides=fitted.params)
    fresh = _envelope(sim, obs, int(fitted.meta.get("discard", 0)))
    for ch, committed in fitted.envelope.items():
        got = fresh.get(ch)
        if got is None:
            raise TraceError(f"regression telemetry lost channel {ch!r}")
        if got > committed * slack + 1e-12:
            raise TraceError(
                f"calibration envelope widened: {ch} = {got:.6g} > "
                f"{committed:.6g} * {slack} — the cooling physics no "
                f"longer reproduces the committed calibration")
    return fresh


def _load_telemetry_npz(path: pathlib.Path) -> dict:
    try:
        z = np.load(path, allow_pickle=False)
    except Exception as e:
        raise TraceError(f"cannot read telemetry NPZ {path}: {e}") from e
    return {k: z[k] for k in z.files}


def main(argv: list[str] | None = None) -> int:
    """CLI: ``simulate.py calibrate`` — fit or check a plant calibration.

    The facility telemetry NPZ carries ``dt`` (s), a heat trace
    (``p_it_w`` f32[S] or ``group_heat_w`` f32[S, G]), the recorded
    observables (``t_basin_c`` / ``pue``) and, unless ``--weather-trace``
    overrides it, the recorded ``t_wetbulb_c``.
    """
    import argparse
    from repro.systems import config as SC
    ap = argparse.ArgumentParser(
        prog="simulate.py calibrate",
        description="fit cooling-plant parameters to recorded telemetry")
    ap.add_argument("--telemetry", required=True,
                    help="facility telemetry NPZ (see --help)")
    ap.add_argument("--system", default="frontier",
                    choices=sorted(SC.SYSTEMS))
    ap.add_argument("--weather-trace", default=None,
                    help="measured weather CSV/NPZ (repro.traces.weather); "
                         "default: the NPZ's t_wetbulb_c channel")
    ap.add_argument("--fit", default=",".join(DEFAULT_FIT),
                    help=f"comma list from {sorted(FIT_BOUNDS)}")
    ap.add_argument("--out", default=None,
                    help="write fitted-params JSON here")
    ap.add_argument("--check", default=None,
                    help="fitted-params JSON to verify instead of fitting "
                         "(the regression gate; exits 1 on a widened "
                         "envelope)")
    args = ap.parse_args(argv)

    tel = _load_telemetry_npz(pathlib.Path(args.telemetry))
    if "dt" not in tel:
        raise TraceError(f"{args.telemetry}: missing 'dt'")
    dt = float(tel["dt"])
    heat = tel.get("group_heat_w", tel.get("p_it_w"))
    if heat is None:
        raise TraceError(f"{args.telemetry}: missing 'p_it_w' or "
                         f"'group_heat_w'")
    obs = {ch: tel[ch] for ch in _SCALES if ch in tel}
    cfg = SC.SYSTEMS[args.system].cooling
    if args.weather_trace:
        from repro.traces.weather import load_weather
        S = np.asarray(heat).shape[0]
        wb = np.asarray(load_weather(args.weather_trace, S, dt).t_wetbulb_c)
    elif "t_wetbulb_c" in tel:
        wb = np.asarray(tel["t_wetbulb_c"], np.float64)
    else:
        raise TraceError("no weather: pass --weather-trace or include "
                         "t_wetbulb_c in the telemetry NPZ")

    if args.check:
        fitted = FittedParams.load(args.check)
        try:
            fresh = check_envelope(fitted, cfg, heat, dt, wb, obs)
        except TraceError as e:
            print(f"FAIL {e}")
            return 1
        print("calibration envelope holds:")
        for ch, v in sorted(fresh.items()):
            print(f"  {ch}: {v:.6g} (committed "
                  f"{fitted.envelope[ch]:.6g})")
        return 0

    fit = tuple(s for s in args.fit.split(",") if s)
    fitted = calibrate(cfg, heat, dt, wb, obs, fit=fit,
                       meta={"system": args.system,
                             "telemetry": str(args.telemetry)})
    for n, v in sorted(fitted.params.items()):
        print(f"  {n}: {v:.6g}  (initial {float(getattr(cfg, n)):.6g})")
    for ch, v in sorted(fitted.envelope.items()):
        print(f"  {ch}: {v:.6g}")
    if args.out:
        fitted.save(args.out)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
