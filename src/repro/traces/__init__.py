"""Real-trace ingestion and telemetry replay (paper contribution 2).

Everything the synthetic generators fake, this package ingests for real,
behind the same ``JobSet`` interface (``repro.datasets.base``):

- ``jobtable``  — parquet/CSV job tables (PM100/Marconi100-style column
  mapping via a configurable ``TraceSchema``), whole-second rounded to
  the SWF contract so ``core.transport.job_digest`` is stable across
  parquet ↔ ``JobSet`` ↔ SWF roundtrips.
- ``telemetry`` — RAPS-style ``joblive`` + ``jobprofile`` directories
  folded into one cached NPZ per trace, content-addressed by a digest of
  the source bytes; jobs gain a measured ``power_profile`` the engine
  replays verbatim (``JobSet.to_table(replay_power=True)``).
- ``weather``   — measured meteorological traces (CSV/NPZ), resampled to
  the engine ``dt`` with wet-bulb derivation, feeding
  ``cooling.weather.from_arrays``.
- ``calibrate`` — least-squares fit of the transient cooling-loop
  parameters (UA / time constants / fan-staging threshold) to a replayed
  power trace + recorded facility telemetry, emitting a fitted-params
  JSON with residual envelopes (the calibration-regression gate).

Every malformed input raises ``TraceError`` — rows are never silently
dropped. See docs/datasets.md for the end-to-end quickstart.
"""
from repro.traces.errors import TraceError  # noqa: F401
from repro.traces.jobtable import (PM100_SCHEMA, TraceSchema,  # noqa: F401
                                   jobset_from_frame, read_job_table,
                                   write_job_table)
from repro.traces.telemetry import (jobset_from_npz,  # noqa: F401
                                    jobset_to_npz, load_telemetry,
                                    source_digest)
from repro.traces.weather import load_weather, wet_bulb_stull  # noqa: F401
# (the fitting entry point lives at repro.traces.calibrate.calibrate —
#  re-exporting it here would shadow the submodule)
from repro.traces.calibrate import (FittedParams,  # noqa: F401
                                    check_envelope, simulate_plant)
