"""Measured weather traces -> per-step ``WeatherSignals``.

Meteorological records (hourly METAR/ERA5-style rows) arrive as a CSV
(``timestamp, t_drybulb_c, rh_pct`` — or a ready ``t_wetbulb_c`` column)
or an NPZ with the same keys. ``load_weather`` validates them, derives
wet-bulb from dry-bulb + relative humidity where needed (Stull 2011),
linearly resamples onto the engine's step grid (``t0 + k*dt``, clamped
at the record's edges — the LOCF convention every other per-step signal
uses at its boundaries) and hands the arrays to
``cooling.weather.from_arrays``.

Validation: timestamps must be strictly increasing, temperatures and
humidities finite, RH inside [0, 100]; the derived wet-bulb is checked
finite and never above dry-bulb. Violations raise ``TraceError``.
"""
from __future__ import annotations

import pathlib

import numpy as np

from repro.cooling import weather as W
from repro.traces.errors import TraceError
from repro.traces.jobtable import _seconds


def wet_bulb_stull(t_drybulb_c: np.ndarray,
                   rh_pct: np.ndarray) -> np.ndarray:
    """Wet-bulb temperature from dry-bulb (°C) and relative humidity (%)
    via Stull's (2011) empirical fit — accurate to ~0.3 °C over the
    meteorological range, which is ample for a cooling-tower floor."""
    t = np.asarray(t_drybulb_c, np.float64)
    rh = np.asarray(rh_pct, np.float64)
    wb = (t * np.arctan(0.151977 * np.sqrt(rh + 8.313659))
          + np.arctan(t + rh) - np.arctan(rh - 1.676331)
          + 0.00391838 * rh ** 1.5 * np.arctan(0.023101 * rh)
          - 4.686035)
    # the fit can overshoot dry-bulb by a hair at saturation; clamp so the
    # physical invariant (wet-bulb <= dry-bulb) holds exactly
    return np.minimum(wb, t)


def _read_columns(path: pathlib.Path) -> dict[str, np.ndarray]:
    if path.suffix == ".npz":
        try:
            z = np.load(path, allow_pickle=False)
        except Exception as e:
            raise TraceError(f"cannot read weather NPZ {path}: {e}") from e
        return {k: z[k] for k in z.files}
    if path.suffix == ".csv":
        import pandas as pd
        try:
            df = pd.read_csv(path)
        except Exception as e:
            raise TraceError(f"cannot read weather CSV {path}: {e}") from e
        return {k: df[k].to_numpy() for k in df.columns}
    raise TraceError(f"unsupported weather format {path.suffix!r} "
                     f"(want .csv or .npz)")


def load_weather(path: str | pathlib.Path, n_steps: int, dt: float,
                 t0: float = 0.0,
                 origin_s: float | None = None) -> W.WeatherSignals:
    """Load a measured weather trace resampled to the engine grid.

    Args:
      path: ``.csv`` or ``.npz`` with a ``timestamp`` column (numeric
        seconds or datetimes) plus either ``t_wetbulb_c`` or
        ``t_drybulb_c`` + ``rh_pct`` (wet-bulb is then derived via
        ``wet_bulb_stull``).
      n_steps / dt / t0: the engine grid — row ``k`` is the condition at
        simulation time ``t0 + k*dt``.
      origin_s: absolute time the simulation's ``t=0`` corresponds to in
        the record's clock (default: the record's first timestamp, i.e.
        the trace starts when the simulation starts).

    Returns:
      ``WeatherSignals`` (f32[n_steps] wet-bulb and dry-bulb).
    Raises:
      TraceError: unreadable file, missing columns, non-monotone
        timestamps, or any non-finite/out-of-range sample.
    """
    p = pathlib.Path(path)
    cols = _read_columns(p)
    if "timestamp" not in cols:
        raise TraceError(f"{p.name}: missing 'timestamp' column "
                         f"(have: {sorted(cols)})")
    ts = _seconds(np.asarray(cols["timestamp"]), "timestamp")
    if not np.isfinite(ts).all():
        raise TraceError(f"{p.name}: non-finite timestamp")
    if len(ts) < 2:
        raise TraceError(f"{p.name}: need at least 2 weather rows")
    if not (np.diff(ts) > 0).all():
        raise TraceError(f"{p.name}: timestamps must be strictly "
                         f"increasing")

    def finite(name):
        v = np.asarray(cols[name], np.float64)
        if not np.isfinite(v).all():
            raise TraceError(f"{p.name}: non-finite {name}")
        return v

    if "t_wetbulb_c" in cols:
        wb = finite("t_wetbulb_c")
        db = finite("t_drybulb_c") if "t_drybulb_c" in cols else wb + 8.0
    elif "t_drybulb_c" in cols and "rh_pct" in cols:
        db = finite("t_drybulb_c")
        rh = finite("rh_pct")
        if ((rh < 0) | (rh > 100)).any():
            raise TraceError(f"{p.name}: rh_pct outside [0, 100]")
        wb = wet_bulb_stull(db, rh)
    else:
        raise TraceError(f"{p.name}: need 't_wetbulb_c' or 't_drybulb_c' + "
                         f"'rh_pct' (have: {sorted(cols)})")
    if (wb > db).any() or not np.isfinite(wb).all():
        raise TraceError(f"{p.name}: derived wet-bulb is non-physical")

    if origin_s is None:
        origin_s = float(ts[0])
    grid = origin_s + t0 + dt * np.arange(max(n_steps, 1), dtype=np.float64)
    # np.interp clamps at both edges — boundary behavior matches the
    # engine's clamped per-step gathers
    wb_s = np.interp(grid, ts, wb)
    db_s = np.interp(grid, ts, db)
    return W.from_arrays(wb_s, db_s)
