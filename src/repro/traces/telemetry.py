"""RAPS-style telemetry ingestion: ``joblive`` + ``jobprofile`` -> NPZ.

Site telemetry dumps arrive as two directory trees of CSV shards
(``joblive/date=YYYY-MM-DD/*.csv`` with one scheduler row per job, and
``jobprofile/date=YYYY-MM-DD/*.csv`` with timestamped per-node power
samples keyed by job id). ``load_telemetry`` folds both into one
``JobSet`` whose ``power_profile`` channel the engine replays verbatim
(``to_table(replay_power=True)``), and caches the parsed result as a
single NPZ, content-addressed by a digest of the source bytes — the
RAPS workflow ("once the data has been processed, it will be saved as
an NPZ file, which can be more quickly started in subsequent
simulations"). A cache hit reproduces the cold parse bit-for-bit; a
stale cache (edited sources) is simply a different digest, so it can
never be read by mistake.

Expected columns — ``joblive``: job_id, time_submission, time_start,
time_end, time_limit (s), node_count, user. ``jobprofile``: timestamp,
job_id, node_power_w (mean per-node watts at that instant). Timestamps
may be numeric seconds or parseable datetimes. Any malformed row, or a
profile sample whose job id never appears in joblive, raises
``TraceError``.
"""
from __future__ import annotations

import hashlib
import pathlib

import numpy as np

from repro.datasets.base import JobSet
from repro.traces.errors import TraceError
from repro.traces.jobtable import (TraceSchema, _seconds, _whole_seconds,
                                   jobset_from_frame)

# joblive carries its walltime limit in seconds (scheduler export),
# unlike the minutes convention of published job tables.
JOBLIVE_SCHEMA = TraceSchema(
    job_id="job_id", submit_time="time_submission", start_time="time_start",
    end_time="time_end", run_time=None, nodes="node_count",
    time_limit="time_limit", user="user", priority=None, limit_unit="s")

_CACHE_VERSION = 1   # bump to invalidate every cached NPZ


def _iter_files(root: pathlib.Path) -> list[pathlib.Path]:
    if root.is_file():
        return [root]
    files = sorted(q for q in root.rglob("*") if q.is_file())
    if not files:
        raise TraceError(f"no telemetry files under {root}")
    return files


def source_digest(*roots: str | pathlib.Path) -> str:
    """Content digest of a telemetry source (files or directory trees):
    sha256 over (relative name, bytes) of every file, in sorted order.
    Names the NPZ cache entry, and lands in run manifests so an
    experiment records exactly which trace bytes produced it."""
    h = hashlib.sha256()
    for root in roots:
        root = pathlib.Path(root)
        if not root.exists():
            raise TraceError(f"telemetry source {root} does not exist")
        for q in _iter_files(root):
            rel = q.name if root.is_file() else q.relative_to(root).as_posix()
            h.update(rel.encode())
            h.update(b"\0")
            h.update(q.read_bytes())
            h.update(b"\0")
    return h.hexdigest()


def _read_csv_tree(root: pathlib.Path):
    """Concatenate every CSV shard under ``root`` (sorted for
    determinism) into one dataframe."""
    import pandas as pd
    shards = [q for q in _iter_files(root) if q.suffix == ".csv"]
    if not shards:
        raise TraceError(f"no CSV shards under {root}")
    frames = []
    for q in shards:
        try:
            frames.append(pd.read_csv(q))
        except Exception as e:
            raise TraceError(f"cannot read telemetry shard {q}: {e}") from e
    return pd.concat(frames, ignore_index=True)


def _resample_locf(t: np.ndarray, v: np.ndarray,
                   grid: np.ndarray) -> np.ndarray:
    """Last-observation-carried-forward onto ``grid`` (the engine's
    profile-index semantics); grid points before the first sample take
    the first sample."""
    idx = np.searchsorted(t, grid, side="right") - 1
    return v[np.clip(idx, 0, len(v) - 1)]


def jobset_to_npz(js: JobSet, path: str | pathlib.Path,
                  digest: str = "") -> None:
    """Serialize a ``JobSet`` (all channels) to one NPZ."""
    arrays = dict(submit=js.submit, limit=js.limit, wall=js.wall,
                  nodes=js.nodes, priority=js.priority, account=js.account,
                  rec_start=js.rec_start, power_prof=js.power_prof,
                  util_prof=js.util_prof,
                  name=np.array(js.name), digest=np.array(digest),
                  version=np.array(_CACHE_VERSION))
    for opt in ("first_node", "score", "ml_basis", "power_profile"):
        v = getattr(js, opt)
        if v is not None:
            arrays[opt] = v
    tmp = pathlib.Path(path).with_suffix(".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    tmp.replace(path)


def jobset_from_npz(path: str | pathlib.Path) -> JobSet:
    """Load a ``jobset_to_npz`` archive back, bit-for-bit."""
    try:
        z = np.load(path, allow_pickle=False)
    except Exception as e:
        raise TraceError(f"cannot read trace NPZ {path}: {e}") from e
    if "version" not in z or int(z["version"]) != _CACHE_VERSION:
        raise TraceError(f"{path}: unknown trace-NPZ version "
                         f"(want {_CACHE_VERSION})")

    def opt(k):
        return z[k] if k in z.files else None
    return JobSet(submit=z["submit"], limit=z["limit"], wall=z["wall"],
                  nodes=z["nodes"], priority=z["priority"],
                  account=z["account"], rec_start=z["rec_start"],
                  power_prof=z["power_prof"], util_prof=z["util_prof"],
                  first_node=opt("first_node"), score=opt("score"),
                  ml_basis=opt("ml_basis"),
                  power_profile=opt("power_profile"),
                  name=str(z["name"]))


def load_telemetry(joblive: str | pathlib.Path,
                   jobprofile: str | pathlib.Path | None = None,
                   prof_dt: float = 20.0,
                   cache_dir: str | pathlib.Path | None = None,
                   node_power_w: float = 500.0,
                   util: float = 0.7) -> JobSet:
    """Load a telemetry trace into a replay-capable ``JobSet``.

    Args:
      joblive: the ``joblive`` directory (CSV shards) — or a previously
        cached ``.npz``, which short-circuits everything else.
      jobprofile: the matching ``jobprofile`` directory; ``None`` means
        scheduler rows only (no measured power channel).
      prof_dt: grid spacing (s) the measured samples are resampled onto —
        pass ``SystemConfig.prof_dt`` so replay indexing lines up.
      cache_dir: directory for the content-addressed NPZ cache
        (``trace-<digest16>.npz``); ``None`` disables caching.
      node_power_w / util: model fallback for profile-less jobs.

    Returns:
      ``JobSet`` where ``power_prof`` holds each profiled job's measured
      mean (the model view) and ``power_profile`` the full measured
      series on the ``prof_dt`` grid, ``-1`` rows marking profile-less
      jobs.
    """
    joblive = pathlib.Path(joblive)
    if joblive.suffix == ".npz":
        return jobset_from_npz(joblive)

    sources = [joblive] + ([pathlib.Path(jobprofile)] if jobprofile else [])
    digest = source_digest(*sources)
    cache = None
    if cache_dir is not None:
        cache_dir = pathlib.Path(cache_dir)
        cache_dir.mkdir(parents=True, exist_ok=True)
        cache = cache_dir / f"trace-{digest[:16]}.npz"
        if cache.exists():
            return jobset_from_npz(cache)

    live = _read_csv_tree(joblive)
    js = jobset_from_frame(live, JOBLIVE_SCHEMA, node_power_w=node_power_w,
                           util=util, origin_s=None,
                           name=f"telemetry-{digest[:8]}")
    # jobset_from_frame sorts by submit; recover the job_id of each row
    # so profile samples can be joined back on
    raw_submit = _seconds(live[JOBLIVE_SCHEMA.submit_time].to_numpy(),
                          "submit")
    order = np.argsort(_whole_seconds(raw_submit - np.min(raw_submit)),
                       kind="stable")
    job_ids = live[JOBLIVE_SCHEMA.job_id].to_numpy()[order]
    if len(np.unique(job_ids)) != len(job_ids):
        raise TraceError(f"{joblive}: duplicate job ids in joblive")
    origin_s = float(np.min(raw_submit))

    if jobprofile is not None:
        prof = _read_csv_tree(pathlib.Path(jobprofile))
        for col in ("timestamp", "job_id", "node_power_w"):
            if col not in prof.columns:
                raise TraceError(f"jobprofile is missing column {col!r} "
                                 f"(have: {list(prof.columns)})")
        pt = _seconds(prof["timestamp"].to_numpy(), "timestamp") - origin_s
        pw = prof["node_power_w"].to_numpy().astype(np.float64)
        pj = prof["job_id"].to_numpy()
        if not np.isfinite(pt).all():
            raise TraceError("jobprofile: non-finite timestamp")
        if (~np.isfinite(pw) | (pw < 0)).any():
            raise TraceError("jobprofile: non-finite or negative power")
        row_of = {j: i for i, j in enumerate(job_ids)}
        unknown = [j for j in np.unique(pj) if j not in row_of]
        if unknown:
            raise TraceError(f"jobprofile references job ids absent from "
                             f"joblive: {unknown[:5]}")
        rows = np.array([row_of[j] for j in pj])

        Q = max(1, int(np.ceil(float(np.max(js.wall)) / prof_dt)))
        profile = np.full((len(js), Q), -1.0, np.float32)
        mean_w = np.array(js.power_prof[:, 0], np.float64)
        grid = np.arange(Q) * prof_dt
        for r in np.unique(rows):
            sel = rows == r
            t, v = pt[sel], pw[sel]
            srt = np.argsort(t, kind="stable")
            t, v = t[srt], v[srt]
            # samples are timestamped in trace time; replay indexes by
            # elapsed work-time, so rebase onto the job's recorded start
            elapsed = t - (js.rec_start[r] if np.isfinite(js.rec_start[r])
                           else t[0])
            profile[r] = _resample_locf(elapsed, v, grid)
            mean_w[r] = v.mean()
        js.power_profile = profile
        js.power_prof = mean_w[:, None].astype(np.float32)

    if cache is not None:
        jobset_to_npz(js, cache, digest=digest)
        return jobset_from_npz(cache)   # serve the cached bytes everywhere
    return js
