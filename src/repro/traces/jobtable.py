"""Parquet/CSV job-table ingestion (PM100 / Marconi100-style).

A *job table* is one row per job with submit/start/end (or runtime),
node count, walltime limit and user columns — what the PM100 dataset
publishes for Marconi100 and what RAPS ingests with ``--system
marconi100 -f job_table.parquet``. Column names vary per site, so the
mapping is a ``TraceSchema`` dict the caller can override; the shipped
``PM100_SCHEMA`` covers the PM100 column names.

Rounding contract: all time columns are rounded to *whole seconds with
banker's rounding* on ingest — the same rule ``datasets/swf.py`` applies
on export (``:.0f``) and ``core.transport.job_digest`` applies when
canonicalizing, so a parquet → ``JobSet`` → SWF → ``JobSet`` roundtrip
keeps the job digest invariant (tests/test_traces.py).

Validation is strict: a row with a NaN time, a negative duration, a
non-positive node count or an end before its start raises ``TraceError``
naming the row — rows are never silently dropped (the hypothesis battery
in tests/test_traces_properties.py leans on this).
"""
from __future__ import annotations

import pathlib
from dataclasses import dataclass, field

import numpy as np

from repro.datasets.base import JobSet
from repro.traces.errors import TraceError


@dataclass(frozen=True)
class TraceSchema:
    """Column mapping from a site's job table to the ``JobSet`` fields.

    Every value is the *source* column name; optional channels map to
    ``None`` when the site does not publish them. Exactly one of
    ``end_time`` / ``run_time`` must resolve (end wins when both exist in
    the file). Times may be numeric seconds or anything
    ``pandas.to_datetime`` parses; they are re-based to the trace origin
    (min submit) unless ``origin_s`` pins one.
    """
    job_id: str = "job_id"
    submit_time: str = "submit_time"
    start_time: str = "start_time"
    end_time: str | None = "end_time"
    run_time: str | None = "run_time"
    nodes: str = "num_nodes"
    time_limit: str = "time_limit"          # minutes unless limit_unit="s"
    user: str = "user_id"
    priority: str | None = "priority"
    mean_node_power: str | None = None      # optional scalar power column (W)
    limit_unit: str = "min"                 # "min" (Slurm) or "s"
    extra: dict = field(default_factory=dict)


# PM100 (Marconi100 job table, Antici et al.) column names.
PM100_SCHEMA = TraceSchema()

_MAX_ACCOUNTS = 64   # SWF export writes account+1 and re-imports mod 64


def _col(df, name: str, what: str) -> np.ndarray:
    if name not in df.columns:
        raise TraceError(f"job table is missing the {what} column "
                         f"{name!r} (have: {list(df.columns)})")
    return df[name].to_numpy()


def _seconds(raw: np.ndarray, what: str) -> np.ndarray:
    """Column -> float64 epoch/relative seconds (datetimes parsed)."""
    if np.issubdtype(raw.dtype, np.number):
        return raw.astype(np.float64)
    import pandas as pd
    try:
        ts = pd.to_datetime(raw, utc=True)
    except (ValueError, TypeError) as e:
        raise TraceError(f"{what} column is neither numeric seconds nor "
                         f"parseable timestamps: {e}") from e
    out = np.asarray(ts.astype("int64"), np.float64) / 1e9
    # NaT becomes INT64_MIN: map back to NaN so validation names the row
    out[np.asarray(pd.isna(ts))] = np.nan
    return out


def _whole_seconds(x: np.ndarray) -> np.ndarray:
    """Banker's whole-second rounding — the SWF / job_digest rule."""
    return np.round(np.asarray(x, np.float64))


def read_job_table(path: str | pathlib.Path,
                   schema: TraceSchema = PM100_SCHEMA,
                   node_power_w: float = 500.0,
                   util: float = 0.7,
                   origin_s: float | None = None) -> JobSet:
    """Ingest a parquet/CSV job table into a ``JobSet``.

    Args:
      path: ``.parquet`` or ``.csv`` file.
      schema: source-column mapping (default: PM100 names).
      node_power_w / util: scalar power/utilization profile for jobs with
        no power channel (job tables carry scheduling columns; measured
        power arrives via ``repro.traces.telemetry``), or the fallback
        when ``schema.mean_node_power`` is unset.
      origin_s: pin the time origin (absolute seconds). Default: the
        earliest submit, so trace times start near zero.
    Returns:
      ``JobSet`` with whole-second times, ready for ``to_table``.
    Raises:
      TraceError: unreadable file, missing columns, or any malformed row
        (NaN/negative times, non-positive nodes, end before start).
    """
    import pandas as pd
    p = pathlib.Path(path)
    try:
        if p.suffix == ".parquet":
            df = pd.read_parquet(p)
        elif p.suffix == ".csv":
            df = pd.read_csv(p)
        else:
            raise TraceError(f"unsupported job-table format {p.suffix!r} "
                             f"(want .parquet or .csv)")
    except TraceError:
        raise
    except Exception as e:  # pandas/pyarrow parse failures
        raise TraceError(f"cannot read job table {p}: {e}") from e
    return jobset_from_frame(df, schema, node_power_w=node_power_w,
                             util=util, origin_s=origin_s, name=p.stem)


def jobset_from_frame(df, schema: TraceSchema = PM100_SCHEMA,
                      node_power_w: float = 500.0, util: float = 0.7,
                      origin_s: float | None = None,
                      name: str = "trace") -> JobSet:
    """Validate + canonicalize an in-memory dataframe (the shared back
    half of ``read_job_table``; ``repro.traces.telemetry`` feeds the
    concatenated ``joblive`` tables through here)."""
    if len(df) == 0:
        raise TraceError(f"job table {name!r} holds no rows")

    submit = _seconds(_col(df, schema.submit_time, "submit"), "submit")
    start = _seconds(_col(df, schema.start_time, "start"), "start")
    wall = None
    if schema.end_time and schema.end_time in df.columns:
        end = _seconds(_col(df, schema.end_time, "end"), "end")
        wall = end - start
    if schema.run_time and schema.run_time in df.columns:
        run = _col(df, schema.run_time, "run_time").astype(np.float64)
        # end wins where both resolve; run_time covers never-started jobs
        # (NaN start/end but a recorded duration — the write_job_table
        # export shape, and SWF's wait = -1 convention)
        wall = run if wall is None else np.where(np.isfinite(wall),
                                                 wall, run)
    if wall is None:
        raise TraceError(f"job table needs {schema.end_time!r} or "
                         f"{schema.run_time!r}; has {list(df.columns)}")
    nodes = _col(df, schema.nodes, "nodes")
    limit = _col(df, schema.time_limit, "time_limit").astype(np.float64)
    if schema.limit_unit == "min":
        limit = limit * 60.0
    user = _col(df, schema.user, "user")

    # --- strict row validation (never a silent drop) -----------------------
    def bad(mask: np.ndarray, why: str) -> None:
        if mask.any():
            rows = np.nonzero(mask)[0][:5].tolist()
            raise TraceError(f"{name}: {int(mask.sum())} row(s) with "
                             f"{why} (first at rows {rows})")

    bad(~np.isfinite(submit), "non-finite submit time")
    bad(~np.isfinite(wall) | (wall <= 0), "missing or non-positive duration")
    nodes_f = np.asarray(nodes, np.float64)
    bad(~np.isfinite(nodes_f) | (nodes_f < 1) |
        (nodes_f != np.round(nodes_f)), "non-integral or < 1 node count")
    # a never-started job (NaN/inf start) is legal — SWF wait = -1 — but a
    # started job must start at or after submission
    started = np.isfinite(start)
    bad(started & (start < submit), "start before submit")
    bad(np.isfinite(limit) & (limit <= 0), "non-positive time limit")

    # --- canonicalize ------------------------------------------------------
    if origin_s is None:
        origin_s = float(np.min(submit))
    submit = _whole_seconds(submit - origin_s)
    wall = np.maximum(_whole_seconds(wall), 1.0)
    rec_start = np.where(started, _whole_seconds(start - origin_s), np.inf)
    limit = np.where(np.isfinite(limit), _whole_seconds(limit), wall * 2)
    limit = np.maximum(limit, wall)
    nodes = nodes_f.astype(np.int64)

    order = np.argsort(submit, kind="stable")

    # users -> dense account ids in first-seen (submit-sorted) order,
    # folded into the SWF range. First-seen numbering is a fixed point
    # under re-export: a written table stores the dense id and
    # re-densifying maps it back to itself, so the digest survives
    # parquet/CSV/SWF roundtrips. (Sorted-unique numbering is not:
    # "10" < "2" lexicographically, which permutes relabeled accounts.)
    uniq, first, inverse = np.unique(np.asarray(user).astype(str)[order],
                                     return_index=True, return_inverse=True)
    rank = np.empty(len(uniq), np.int64)
    rank[np.argsort(first, kind="stable")] = np.arange(len(uniq))
    account = rank[inverse] % _MAX_ACCOUNTS

    if schema.priority and schema.priority in df.columns:
        priority = _col(df, schema.priority, "priority").astype(np.float64)
        bad(~np.isfinite(priority), "non-finite priority")
    else:
        priority = np.log2(nodes + 1.0)

    J = len(df)
    if schema.mean_node_power and schema.mean_node_power in df.columns:
        pw = _col(df, schema.mean_node_power, "power").astype(np.float64)
        bad(~np.isfinite(pw) | (pw < 0), "non-finite or negative power")
        power = pw[:, None].astype(np.float32)
    else:
        power = np.full((J, 1), node_power_w, np.float32)
    return JobSet(submit=submit[order], limit=limit[order],
                  wall=wall[order], nodes=nodes[order],
                  priority=priority[order], account=account,
                  rec_start=rec_start[order], power_prof=power[order],
                  util_prof=np.full((J, 1), util, np.float32),
                  name=name)


def write_job_table(js: JobSet, path: str | pathlib.Path,
                    schema: TraceSchema = PM100_SCHEMA) -> None:
    """Export a ``JobSet`` as a parquet/CSV job table (roundtrip partner
    of ``read_job_table``; used to build golden fixtures and by the
    property battery). Never-started jobs get a NaN start; the limit is
    written back in the schema's unit."""
    import pandas as pd
    p = pathlib.Path(path)
    limit = np.asarray(js.limit, np.float64)
    if schema.limit_unit == "min":
        limit = limit / 60.0
    df = pd.DataFrame({
        schema.job_id: np.arange(len(js)),
        schema.submit_time: np.asarray(js.submit, np.float64),
        schema.start_time: np.where(np.isfinite(js.rec_start),
                                    js.rec_start, np.nan),
        schema.end_time or "end_time": np.where(
            np.isfinite(js.rec_start), js.rec_start + js.wall, np.nan),
        schema.run_time or "run_time": np.asarray(js.wall, np.float64),
        schema.nodes: np.asarray(js.nodes, np.int64),
        schema.time_limit: limit,
        schema.user: np.asarray(js.account, np.int64),
        schema.priority or "priority": np.asarray(js.priority, np.float64),
    })
    if p.suffix == ".parquet":
        df.to_parquet(p, index=False)
    elif p.suffix == ".csv":
        df.to_csv(p, index=False)
    else:
        raise TraceError(f"unsupported job-table format {p.suffix!r}")
