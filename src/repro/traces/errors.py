"""Typed failure for trace ingestion (repro.traces).

One exception class for the whole package so callers (CLI, tests,
hypothesis batteries) can assert "malformed input fails loudly" without
caring which loader tripped: a NaN submit time, a negative duration, a
non-monotone weather timestamp and a truncated parquet all surface as
``TraceError`` — never as a silently dropped row.
"""
from __future__ import annotations


class TraceError(ValueError):
    """A trace file or row violates the ingestion contract."""
