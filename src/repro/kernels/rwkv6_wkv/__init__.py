from repro.kernels.rwkv6_wkv.ops import wkv, wkv_chunked, wkv_decode_step  # noqa: F401
from repro.kernels.rwkv6_wkv.ref import wkv_ref  # noqa: F401
