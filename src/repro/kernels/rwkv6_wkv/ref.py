"""Pure-jnp oracle for the RWKV6 WKV recurrence (naive scan over time).

Per head (state S in R^{hd x hd}):
    y_t[j]   = sum_i r_t[i] * ( S_t[i,j] + u[i] * k_t[i] * v_t[j] )
    S_{t+1}  = diag(w_t) S_t + k_t (x) v_t
with w_t in (0,1) the data-dependent decay (the "Finch" feature).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, w, u, state0=None):
    """r,k,v,w: f32[B,S,H,hd]; u: f32[H,hd].

    Returns (y f32[B,S,H,hd], final state f32[B,H,hd,hd])."""
    B, S, H, hd = r.shape
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    if state0 is None:
        state0 = jnp.zeros((B, H, hd, hd), f32)

    def step(S_, inp):
        rt, kt, vt, wt = inp  # [B,H,hd]
        kv = kt[..., :, None] * vt[..., None, :]          # [B,H,hd,hd]
        att = S_ + u[None, :, :, None] * kv               # bonus on current
        yt = jnp.einsum("bhi,bhij->bhj", rt, att)
        S_new = wt[..., :, None] * S_ + kv
        return S_new, yt

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, w))  # [S,B,H,hd]
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), state
