"""Chunked WKV: the production formulation (jnp path + Pallas dispatch).

The recurrence is linear attention with per-channel decay, so a chunk of
length L computes as dense algebra (MXU-friendly) instead of S sequential
steps:

  cum_t = sum_{tau<=t} log w_tau                       (inclusive, per chan)
  intra: y_t += sum_{s<t} r_t . exp(cum_{t-1}-cum_s) k_s v_s + u.k_t r_t v_t
  cross: y_t += r_t . exp(cum_{t-1}) S
  state: S' = exp(cum_{L-1}) S + sum_s exp(cum_{L-1}-cum_s) k_s v_s

Everything stays in log space until the last exp, so arbitrarily strong
decay cannot overflow (exponents are always <= 0 within a chunk... the
pairwise differences cum_{t-1}-cum_s for s<t are sums of logs in (-inf, 0]).
The per-chunk [L, L, hd] tensor is the VMEM tile the Pallas kernel holds
(see rwkv6_wkv.py); the jnp path mirrors it exactly so both lower everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnums=(5,))
def wkv_chunked(r, k, v, w, u, chunk: int = 32):
    """Same contract as ref.wkv_ref (state0 = 0). Returns (y, final_state)."""
    B, S, H, hd = r.shape
    f32 = jnp.float32
    dt_out = r.dtype
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nC = S // L

    # [nC, B, H, L, hd]
    def to_chunks(x):
        return x.reshape(B, nC, L, H, hd).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    logw = jnp.log(jnp.clip(wc, 1e-38, 1.0))
    cum = jnp.cumsum(logw, axis=-2)                  # inclusive [.., L, hd]
    cum_prev = cum - logw                            # exclusive (cum_{t-1})
    cum_last = cum[..., -1:, :]                      # [.., 1, hd]

    state0 = jnp.zeros((B, H, hd, hd), f32)

    def chunk_step(S_, inp):
        from repro.parallel.sharding import hint_axes
        rt, kt, vt, cumt, cumpt, cumlast = inp       # [B,H,L,hd]
        S_ = hint_axes(S_, ("batch", "model", None, None))  # pin carry
        # intra-chunk: att[t,s,i] = exp(cumpt[t,i]-cumt[s,i]) for s<t.
        # Mask BEFORE exp: masked pairs have positive diff that overflows to
        # inf under strong decay, and inf * 0 = NaN.
        diff = cumpt[..., :, None, :] - cumt[..., None, :, :]  # [B,H,L,L,hd]
        mask = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :])
        att = jnp.exp(jnp.where(mask[None, None, :, :, None], diff,
                                -jnp.inf))
        a = jnp.einsum("bhti,bhtsi,bhsi->bhts", rt, att, kt)
        y = jnp.einsum("bhts,bhsj->bhtj", a, vt)
        # bonus (current token)
        y += jnp.einsum("bhti,bhti,bhtj->bhtj", rt, u[None, :, None, :] * kt,
                        vt)
        # cross-chunk: state contribution
        rdec = rt * jnp.exp(cumpt)
        y += jnp.einsum("bhti,bhij->bhtj", rdec, S_)
        # state update
        kdec = kt * jnp.exp(cumlast - cumt)
        S_new = jnp.exp(cumlast[..., 0, :])[..., :, None] * S_ + \
            jnp.einsum("bhsi,bhsj->bhij", kdec, vt)
        return S_new, y

    state, ys = jax.lax.scan(
        chunk_step, state0,
        (rc, kc, vc, cum, cum_prev,
         jnp.broadcast_to(cum_last, cum_last.shape)))
    # ys: [nC, B, H, L, hd] -> [B, S, H, hd]
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return y.astype(dt_out), state


def wkv(r, k, v, w, u, chunk: int = 32, use_pallas: bool = False,
        interpret: bool = True):
    """Dispatcher used by the model: jnp chunked (default, lowers on all
    backends) or the Pallas TPU kernel."""
    if use_pallas:
        from repro.kernels.rwkv6_wkv.rwkv6_wkv import wkv_pallas
        return wkv_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return wkv_chunked(r, k, v, w, u, chunk=chunk)


def wkv_decode_step(r1, k1, v1, w1, u, state):
    """Single-token recurrence for serving. r1..w1: [B,H,hd]; state:
    [B,H,hd,hd]. Returns (y [B,H,hd], new_state)."""
    f32 = jnp.float32
    r1, k1, v1, w1 = (x.astype(f32) for x in (r1, k1, v1, w1))
    kv = k1[..., :, None] * v1[..., None, :]
    att = state + u[None, :, :, None].astype(f32) * kv
    y = jnp.einsum("bhi,bhij->bhj", r1, att)
    new_state = w1[..., :, None] * state + kv
    return y, new_state
