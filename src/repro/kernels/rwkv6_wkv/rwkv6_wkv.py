"""Pallas TPU kernel for the chunked RWKV6 WKV recurrence.

Grid: (B*H, S/L) with the chunk axis *sequential* (TPU executes the minor
grid dimension in order), so the recurrent state lives in a VMEM scratch
buffer across chunk steps — HBM traffic is exactly r,k,v,w in and y out.

Per grid step the kernel holds in VMEM:
    r,k,v,logw tiles      4 x (L, hd) f32
    pairwise decay tile   (L, L, hd) f32   <- the working set that makes
                                              this a kernel: hd*L^2*4 bytes
                                              (L=32, hd=64 -> 256 KiB)
    state scratch         (hd, hd) f32
MXU work: the (L,L)@(L,hd) attention matmuls; VPU work: exp/cumsum and the
per-channel decay product-reduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, r_ref, k_ref, v_ref, w_ref, y_ref, state_ref):
    c = pl.program_id(1)  # sequential chunk index

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)       # [L, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)       # [hd]
    L = r.shape[0]

    logw = jnp.log(jnp.clip(w, 1e-38, 1.0))
    cum = jnp.cumsum(logw, axis=0)         # inclusive [L, hd]
    cum_prev = cum - logw
    cum_last = cum[-1:, :]                 # [1, hd]

    # intra-chunk pairwise decay tile [L, L, hd] (the VMEM working set).
    # Mask before exp: masked (s >= t) diffs are positive and can overflow.
    diff = cum_prev[:, None, :] - cum[None, :, :]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >
            jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    att = jnp.exp(jnp.where(mask[:, :, None], diff, -jnp.inf))
    # a[t,s] = sum_i r[t,i] * att[t,s,i] * k[s,i]   (VPU reduce over hd)
    a = jnp.sum(att * r[:, None, :] * k[None, :, :], axis=2)
    y = jnp.dot(a, v, preferred_element_type=jnp.float32)
    # bonus (current token): y_t += (sum_i r_t[i] u[i] k_t[i]) * v_t
    y += jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    # cross-chunk: state contribution
    S = state_ref[...]
    y += jnp.dot(r * jnp.exp(cum_prev), S,
                 preferred_element_type=jnp.float32)
    # state update
    kdec = k * jnp.exp(cum_last - cum)
    state_ref[...] = jnp.exp(cum_last[0])[:, None] * S + \
        jnp.dot(kdec.T, v, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnums=(5, 6))
def wkv_pallas(r, k, v, w, u, chunk: int = 32, interpret: bool = True):
    """r,k,v,w: [B,S,H,hd]; u: [H,hd]. Returns (y [B,S,H,hd], None).

    The Pallas path keeps the recurrent state in scratch and does not return
    it; use the jnp chunked path (ops.wkv_chunked) when the final state is
    needed (e.g. prefill handing off to decode).
    """
    B, S, H, hd = r.shape
    L = chunk
    assert S % L == 0, (S, L)
    BH = B * H

    def bh(x):  # [B,S,H,hd] -> [BH, S, hd]
        return x.transpose(0, 2, 1, 3).reshape(BH, S, hd)

    rb, kb, vb, wb = map(bh, (r, k, v, w))
    ub = jnp.broadcast_to(u[None, :, :], (B, H, hd)).reshape(BH, hd)

    y = pl.pallas_call(
        _kernel,
        grid=(BH, S // L),
        in_specs=[
            pl.BlockSpec((1, hd), lambda b, c: (b, 0)),        # u
            pl.BlockSpec((1, L, hd), lambda b, c: (b, c, 0)),  # r
            pl.BlockSpec((1, L, hd), lambda b, c: (b, c, 0)),  # k
            pl.BlockSpec((1, L, hd), lambda b, c: (b, c, 0)),  # v
            pl.BlockSpec((1, L, hd), lambda b, c: (b, c, 0)),  # w
        ],
        out_specs=pl.BlockSpec((1, L, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(ub, rb, kb, vb, wb)
    yout = y.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return yout, None
