from repro.kernels.flash_attention.ops import mha  # noqa: F401
from repro.kernels.flash_attention.ref import mha_ref  # noqa: F401
from repro.kernels.flash_attention.flash_attention import flash_attention  # noqa: F401
