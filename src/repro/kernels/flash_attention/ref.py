"""Pure-jnp oracle for blockwise (flash) attention: plain f32 softmax
attention with causal + sliding-window masking and GQA head grouping."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_ref(q, k, v, causal: bool = True, window: int = 0):
    """q: [B,S,H,hd]; k,v: [B,T,KV,hd]; returns [B,S,H,hd] (q dtype).

    GQA: H must be a multiple of KV; query group g uses kv head g*KV//H.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bskgh,btkh->bkgst", qf, kf) / jnp.sqrt(hd)
    qi = jnp.arange(S)[:, None] + (T - S)   # right-aligned positions
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= kj > (qi - window)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, vf)
    return out.reshape(B, S, H, hd).astype(q.dtype)
