"""Pallas TPU flash-attention forward (blockwise online softmax).

Grid: (B*H, S/Bq, T/Bk) — the kv-block axis is the sequential minor grid
dimension, so the online-softmax running statistics (m, l) and the output
accumulator live in VMEM scratch across kv steps:

    VMEM per step:  q tile (Bq, hd), k/v tiles (Bk, hd),
                    logits tile (Bq, Bk) f32, acc (Bq, hd) f32, m/l (Bq, 128)
    MXU:            q@k^T  (Bq,hd)x(hd,Bk)  and  p@v  (Bq,Bk)x(Bk,hd)

Bq = Bk = 128 and hd in {64, 128} keep every matmul MXU-aligned. Causal and
sliding-window masking are applied inside the tile; fully-masked tiles are
skipped with pl.when (the dominant win for causal prefill: 2x fewer tiles).
GQA is handled in the index maps (kv head = q head // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, bq: int, bk: int, t_total: int,
            s_total: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions (queries right-aligned when S != T)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + \
        (t_total - s_total)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # tile-level skip: causal tiles entirely above the diagonal, or entirely
    # outside the sliding window
    q_last = qi * bq + bq - 1 + (t_total - s_total)
    q_first = qi * bq + (t_total - s_total)
    k_first = ki * bk
    k_last = ki * bk + bk - 1
    live = True
    if causal:
        live = jnp.logical_and(live, k_first <= q_last)
    if window > 0:
        live = jnp.logical_and(live, k_last > q_first - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale     # [Bq, hd]
        k = k_ref[0].astype(jnp.float32)             # [Bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                         # [Bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: [B,S,H,hd]; k,v: [B,T,KV,hd] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)

    def kv_index(bh, qi, ki):
        # bh = b * H + h  ->  kv row = b * KV + h // G
        return (bh // H) * KV + (bh % H) // G, ki, 0

    kernel = functools.partial(
        _kernel, causal=causal, window=window, bq=bq, bk=bk, t_total=T,
        s_total=S, scale=1.0 / (hd ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // bq, T // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # m
            pltpu.VMEM((bq, 128), jnp.float32),   # l
            pltpu.VMEM((bq, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
