"""Jit'd attention dispatcher: XLA einsum path (lowers everywhere, used by
the dry-run) or the Pallas flash kernel (TPU runtime / interpret validation).
"""
from __future__ import annotations

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import mha_ref


def mha(q, k, v, causal: bool = True, window: int = 0,
        use_pallas: bool = False, interpret: bool = True):
    if use_pallas:
        return flash_attention(q, k, v, causal, window, interpret=interpret)
    return mha_ref(q, k, v, causal, window)
