"""Chunked SSD (Dao & Gu 2024 "state-space duality") — jnp path + Pallas
dispatch. Scalar-per-head decay makes the intra-chunk term a plain [L, L]
matmul per head (fully MXU work on TPU):

  cum_t  = sum_{tau<=t} log a_tau
  att[t,s] = exp(cum_t - cum_s)  for s <= t           (decay t<-s)
  y_t    = sum_{s<=t} att[t,s] (C_t . B_s) (dt_s x_s)  +  exp(cum_t) C_t.S
  S'     = exp(cum_L) S + sum_s exp(cum_L - cum_s) (dt_s x_s) (x) B_s
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnums=(5,))
def ssd_chunked(x, dt, a, B, C, chunk: int = 64):
    """Same contract as ref.ssd_ref (state0 = 0). Returns (y, final_state)."""
    Bz, S, H, P = x.shape
    N = B.shape[-1]
    f32 = jnp.float32
    x, dt, a, B, C = (z.astype(f32) for z in (x, dt, a, B, C))
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nC = S // L

    xc = x.reshape(Bz, nC, L, H, P).transpose(1, 0, 3, 2, 4)   # [nC,Bz,H,L,P]
    dtc = dt.reshape(Bz, nC, L, H).transpose(1, 0, 3, 2)       # [nC,Bz,H,L]
    ac = a.reshape(Bz, nC, L, H).transpose(1, 0, 3, 2)
    Bc = B.reshape(Bz, nC, L, N).transpose(1, 0, 2, 3)         # [nC,Bz,L,N]
    Cc = C.reshape(Bz, nC, L, N).transpose(1, 0, 2, 3)

    loga = jnp.log(jnp.clip(ac, 1e-38, 1.0))
    cum = jnp.cumsum(loga, axis=-1)                            # [nC,Bz,H,L]
    state0 = jnp.zeros((Bz, H, P, N), f32)
    mask = jnp.arange(L)[:, None] >= jnp.arange(L)[None, :]

    def chunk_step(S_, inp):
        from repro.parallel.sharding import hint_axes
        xt, dtt, cumt, Bt, Ct = inp
        S_ = hint_axes(S_, ("batch", "model", None, None))     # pin carry
        dbx = dtt[..., None] * xt                              # [Bz,H,L,P]
        # mask before exp: masked (s > t) diffs are positive -> inf * 0 = NaN
        att = jnp.exp(jnp.where(mask, cumt[..., :, None] - cumt[..., None, :],
                                -jnp.inf))
        g = jnp.einsum("bln,bsn->bls", Ct, Bt)                 # [Bz,L,L]
        y = jnp.einsum("bhls,bls,bhsp->bhlp", att, g, dbx)
        # cross-chunk
        y += jnp.einsum("bhl,bln,bhpn->bhlp", jnp.exp(cumt), Ct, S_)
        # state update
        dec = jnp.exp(cumt[..., -1:] - cumt)                   # [Bz,H,L]
        S_new = jnp.exp(cumt[..., -1])[..., None, None] * S_ + \
            jnp.einsum("bhl,bhlp,bln->bhpn", dec, dbx, Bt)
        return S_new, y

    state, ys = jax.lax.scan(chunk_step, state0, (xc, dtc, cum, Bc, Cc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(Bz, S, H, P)
    return y, state


def ssd(x, dt, a, B, C, chunk: int = 64, use_pallas: bool = False,
        interpret: bool = True):
    """Dispatcher used by the model (returns y only)."""
    if use_pallas:
        from repro.kernels.mamba2_ssd.mamba2_ssd import ssd_pallas
        return ssd_pallas(x, dt, a, B, C, chunk=chunk, interpret=interpret)
    return ssd_chunked(x, dt, a, B, C, chunk=chunk)[0]
