from repro.kernels.mamba2_ssd.ops import ssd, ssd_chunked  # noqa: F401
from repro.kernels.mamba2_ssd.ref import ssd_ref  # noqa: F401
