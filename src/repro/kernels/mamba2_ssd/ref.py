"""Pure-jnp oracle for the Mamba2 SSD recurrence (naive scan over time).

Per head (state S in R^{P x N}, scalar decay a_t):
    S_t = a_t S_{t-1} + (dt_t * x_t) (x) B_t
    y_t = S_t C_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, B, C, state0=None):
    """x: [Bz,S,H,P]; dt,a: [Bz,S,H]; B,C: [Bz,S,N].

    Returns (y f32[Bz,S,H,P], final state f32[Bz,H,P,N])."""
    Bz, S, H, P = x.shape
    N = B.shape[-1]
    f32 = jnp.float32
    x, dt, a, B, C = (z.astype(f32) for z in (x, dt, a, B, C))
    if state0 is None:
        state0 = jnp.zeros((Bz, H, P, N), f32)

    def step(S_, inp):
        xt, dtt, at, Bt, Ct = inp
        dbx = dtt[..., None] * xt                       # [Bz,H,P]
        S_new = at[..., None, None] * S_ + \
            dbx[..., :, None] * Bt[:, None, None, :]    # [Bz,H,P,N]
        yt = jnp.einsum("bhpn,bn->bhp", S_new, Ct)
        return S_new, yt

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          a.transpose(1, 0, 2), B.transpose(1, 0, 2), C.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), state
