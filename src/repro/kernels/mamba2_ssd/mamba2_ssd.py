"""Pallas TPU kernel for chunked Mamba2 SSD.

Grid: (B*H, S/L), chunk axis sequential; state (P x N) lives in VMEM scratch.
Per grid step the VMEM working set is
    x tile (L, P), B/C tiles (L, N), decay (L,), att (L, L), state (P, N)
and the compute is three MXU matmuls:
    g   = C @ B^T                 (L,N)x(N,L)
    y   = (att*g) @ (dt*x)        (L,L)x(L,P)
    S'  = (dec*(dt*x))^T @ B      (P,L)x(L,N)
With L=64, P=64, N=64 the tiles are MXU-shaped and the whole step is
~3*2*L*L*64 FLOPs against ~4*L*64*4 bytes of HBM traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)       # [L, P]
    dt = dt_ref[0].astype(jnp.float32)     # [L]
    a = a_ref[0].astype(jnp.float32)       # [L]
    B = b_ref[0].astype(jnp.float32)       # [L, N]
    C = c_ref[0].astype(jnp.float32)       # [L, N]
    L = x.shape[0]

    loga = jnp.log(jnp.clip(a, 1e-38, 1.0))
    cum = jnp.cumsum(loga)                 # [L]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >=
            jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    # mask before exp: masked (s > t) diffs are positive -> inf * 0 = NaN
    att = jnp.exp(jnp.where(mask, cum[:, None] - cum[None, :], -jnp.inf))
    dbx = dt[:, None] * x                  # [L, P]

    g = jnp.dot(C, B.T, preferred_element_type=jnp.float32)     # [L, L]
    y = jnp.dot(att * g, dbx, preferred_element_type=jnp.float32)
    # cross-chunk contribution
    S = state_ref[...]
    y += jnp.exp(cum)[:, None] * jnp.dot(C, S.T,
                                         preferred_element_type=jnp.float32)
    # state update
    dec = jnp.exp(cum[-1] - cum)           # [L]
    state_ref[...] = jnp.exp(cum[-1]) * S + \
        jnp.dot((dec[:, None] * dbx).T, B, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnums=(5, 6))
def ssd_pallas(x, dt, a, B, C, chunk: int = 64, interpret: bool = True):
    """x: [Bz,S,H,P]; dt,a: [Bz,S,H]; B,C: [Bz,S,N]. Returns y [Bz,S,H,P]."""
    Bz, S, H, P = x.shape
    N = B.shape[-1]
    L = chunk
    assert S % L == 0, (S, L)
    BH = Bz * H

    xb = x.transpose(0, 2, 1, 3).reshape(BH, S, P)
    dtb = dt.transpose(0, 2, 1).reshape(BH, S)
    ab = a.transpose(0, 2, 1).reshape(BH, S)
    # B/C are shared across heads: broadcast up front (HBM cost is modest,
    # N=64; avoids gather indexing inside the kernel)
    Bb = jnp.broadcast_to(B[:, None], (Bz, H, S, N)).reshape(BH, S, N)
    Cb = jnp.broadcast_to(C[:, None], (Bz, H, S, N)).reshape(BH, S, N)

    y = pl.pallas_call(
        _kernel,
        grid=(BH, S // L),
        in_specs=[
            pl.BlockSpec((1, L, P), lambda b, c: (b, c, 0)),   # x
            pl.BlockSpec((1, L), lambda b, c: (b, c)),         # dt
            pl.BlockSpec((1, L), lambda b, c: (b, c)),         # a
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),   # B
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),   # C
        ],
        out_specs=pl.BlockSpec((1, L, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xb, dtb, ab, Bb, Cb)
    return y.reshape(Bz, H, S, P).transpose(0, 2, 1, 3)
