"""Jit'd wrapper for the power-topology reduction.

``group_power`` is what the engine calls. On CPU (this container) it lowers
to the XLA path (the oracle math); on TPU deployments set
``use_pallas=True`` to take the VMEM-tiled kernel. The wrapper owns padding
so the kernel only sees aligned shapes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.power_topo.power_topo import group_power_pallas
from repro.kernels.power_topo.ref import group_power_ref

_LANE = 128


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def group_power(node_pw: jnp.ndarray, n_groups: int,
                use_pallas: bool = False, interpret: bool = True
                ) -> jnp.ndarray:
    """f32[N] or f32[S, N] -> f32[G] / f32[S, G]."""
    squeeze = node_pw.ndim == 1
    x = node_pw[None, :] if squeeze else node_pw
    if use_pallas:
        # Zero padding is exact for a sum reduction. Lay the array out as
        # (S, G, span) so each kernel program sees exactly one ref-group,
        # then pad span to the lane width and S to the sublane width.
        S, N = x.shape
        span = -(-N // n_groups)          # ceil: matches ref.group_ids
        x = _pad_to(x, 1, span * n_groups)
        x = x.reshape(S, n_groups, span)
        x = _pad_to(x, 2, _LANE)
        x = x.reshape(S, -1)
        x = _pad_to(x, 0, 8)
        out = group_power_pallas(x, n_groups, s_block=8, interpret=interpret)
        out = out[:S]
    else:
        out = group_power_ref(x, n_groups)
    return out[0] if squeeze else out
