"""Jit'd wrappers for the power-topology kernels.

``group_power`` (segment reduce) and ``fused_cooling`` (segment reduce +
CDU loop update in one pass) are what the engine calls. On CPU (this
container) they lower to the XLA path (the oracle math); on TPU
deployments set ``use_pallas=True`` to take the VMEM-tiled kernels. The
wrappers own padding so the kernels only see aligned shapes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.power_topo.power_topo import (fused_cooling_pallas,
                                                 group_power_pallas)
from repro.kernels.power_topo.ref import (CduParams, cdu_update_ref,
                                          fused_cooling_hier_ref,
                                          fused_cooling_ref, group_power_ref,
                                          hall_power_ref)

_LANE = 128


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _group_layout(x: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """Lay f32[S, N] out for the per-group kernels: (S, G, span) with span
    padded to the lane width, flattened back to (S, G*span_pad).

    Zero padding is exact for a sum reduction, and the ceil-span grouping
    MUST match ``ref.group_ids`` (node n -> group ``min(n // span, G-1)``)
    — this helper is the single place that encodes it for the Pallas path.
    """
    S, N = x.shape
    span = -(-N // n_groups)          # ceil: matches ref.group_ids
    x = _pad_to(x, 1, span * n_groups)
    x = x.reshape(S, n_groups, span)
    x = _pad_to(x, 2, _LANE)
    return x.reshape(S, -1)


def group_power(node_pw: jnp.ndarray, n_groups: int,
                use_pallas: bool = False, interpret: bool = True
                ) -> jnp.ndarray:
    """f32[N] or f32[S, N] -> f32[G] / f32[S, G]."""
    squeeze = node_pw.ndim == 1
    x = node_pw[None, :] if squeeze else node_pw
    if use_pallas:
        # each kernel program sees exactly one ref-group tile; the batch
        # axis pads to the sublane width
        S = x.shape[0]
        x = _pad_to(_group_layout(x, n_groups), 0, 8)
        out = group_power_pallas(x, n_groups, s_block=8, interpret=interpret)
        out = out[:S]
    else:
        out = group_power_ref(x, n_groups)
    return out[0] if squeeze else out


def fused_cooling(node_pw: jnp.ndarray, t_supply: jnp.ndarray,
                  mdot: jnp.ndarray, t_basin: jnp.ndarray,
                  t_set: jnp.ndarray, n_groups: int, params: CduParams,
                  use_pallas: bool = False, interpret: bool = True):
    """Fused per-step cooling update: per-CDU heat + loop state in one pass.

    Args:
      node_pw: f32[N] or f32[S, N] per-node power (W).
      t_supply, mdot: f32[G] / f32[S, G] CDU supply temps (°C), flows (kg/s).
      t_basin, t_set: basin temp and effective setpoint (°C) — f32[] /
        f32[S] (flat plant: one basin shared by every group) or f32[G] /
        f32[S, G] (hierarchical plant: each group sees its hall's basin,
        see ``fused_cooling_hier``).
      n_groups: number of CDU groups G.
      params: static CduParams scalars.
    Returns:
      (q, t_return, t_supply_new, mdot_new) with the input's batch shape:
      per-group heat (W), return temp (°C), relaxed supply (°C), flow (kg/s).
    """
    squeeze = node_pw.ndim == 1
    if not use_pallas:
        return fused_cooling_ref(node_pw, t_supply, mdot, t_basin, t_set,
                                 n_groups, params)
    x = node_pw[None, :] if squeeze else node_pw
    up = lambda a: a[None, ...] if squeeze else a
    ts, md = up(t_supply), up(mdot)
    # basin/setpoint go to the kernel as per-group columns: broadcast the
    # flat-plant scalar-per-batch form across G
    S0 = x.shape[0]
    col = lambda a: jnp.broadcast_to(
        up(a)[:, None] if up(a).ndim == 1 else up(a), (S0, n_groups))
    tb, tset = col(t_basin), col(t_set)
    S = x.shape[0]
    x = _group_layout(x, n_groups)
    # pad the batch axis to the sublane width; state pads replicate row 0 so
    # padded rows stay finite (they are sliced off below)
    pad_rows = (-S) % 8
    pad = lambda a: jnp.concatenate(
        [a, jnp.broadcast_to(a[:1], (pad_rows,) + a.shape[1:])]) \
        if pad_rows else a
    outs = fused_cooling_pallas(pad(x), pad(ts), pad(md), pad(tb), pad(tset),
                                params, n_groups, s_block=8,
                                interpret=interpret)
    outs = tuple(o[:S] for o in outs)
    return tuple(o[0] for o in outs) if squeeze else outs


def hall_power(group_q: jnp.ndarray, hall_of_group,
               n_halls: int) -> jnp.ndarray:
    """f32[..., G] -> f32[..., H]: the hall level of the node -> CDU ->
    hall segment-reduction hierarchy. G and H are both tiny (tens), so
    this level always runs as the XLA one-hot matmul — only the node ->
    CDU level is worth a kernel."""
    return hall_power_ref(group_q, hall_of_group, n_halls)


def fused_cooling_hier(node_pw: jnp.ndarray, t_supply: jnp.ndarray,
                       mdot: jnp.ndarray, t_basin_hall: jnp.ndarray,
                       t_set, hall_of_group, n_groups: int,
                       params: CduParams, use_pallas: bool = False,
                       interpret: bool = True):
    """Hierarchical fused cooling update: node -> CDU -> hall reduction +
    per-CDU loop update against each group's hall basin.

    Args:
      node_pw: f32[N] or f32[S, N] per-node power (W).
      t_supply, mdot: f32[G] / f32[S, G] CDU loop state.
      t_basin_hall: f32[H] / f32[S, H] per-hall basin temperatures (°C).
      t_set: f32[] / f32[S] effective supply setpoint (°C).
      hall_of_group: static i32[G]-like hall index per CDU group.
    Returns:
      (q, t_return, t_supply_new, mdot_new, q_hall): per-group pieces plus
      per-hall heat sums f32[H] / f32[S, H]. Matches
      ``ref.fused_cooling_hier_ref`` to <= 1e-4 on the Pallas path.
    """
    if not use_pallas:
        return fused_cooling_hier_ref(node_pw, t_supply, mdot, t_basin_hall,
                                      t_set, hall_of_group, n_groups, params)
    hog = jnp.asarray(hall_of_group, jnp.int32)
    t_basin_g = t_basin_hall[..., hog]          # gather: group -> its hall
    tset_g = jnp.broadcast_to(jnp.asarray(t_set, node_pw.dtype)[..., None],
                              t_basin_g.shape)
    q, t_ret, t_sup, md = fused_cooling(node_pw, t_supply, mdot, t_basin_g,
                                        tset_g, n_groups, params,
                                        use_pallas=True, interpret=interpret)
    return q, t_ret, t_sup, md, hall_power_ref(q, hog,
                                               t_basin_hall.shape[-1])
