"""Pallas TPU kernels: batched node-power -> CDU-group segment reduction,
plus the fused per-step cooling update.

This is the twin's per-tick hot spot at scale: with S sharded scenarios and
N nodes (up to 158,976 for Fugaku) the reduction is (S x N) -> (S x G) every
step. Grouping is by contiguous span, so each grid program reduces one
(S_block x span) tile held in VMEM.

Tiling: grid = (G, S/S_block); the input block is (S_block, N/G) resident in
VMEM, output block is (S_block, 1). For TPU, S_block is a multiple of 8 and
N/G is padded to a multiple of 128 by the wrapper (ops.py) so the MXU/VPU
lanes stay aligned.

``fused_cooling_pallas`` extends the reduction kernel with the per-CDU
piece of the transient cooling update (valve slew + heat pickup +
supply-loop relaxation, see ``ref.cdu_update_ref``): the per-group heat
never round-trips to HBM between the reduce and the loop update — one
grid program produces the group heat AND the new CDU temperatures/flows
for its (S_block x group) tile while it is resident in VMEM.

Hierarchical (multi-hall) plants reuse the same kernel: the basin and
setpoint operands are *per-group* columns (the wrapper gathers each
group's hall basin, ``t_basin_hall[..., hall_of_group]``), so each grid
program reads the (S_block, 1) slice for its own group — a flat plant is
just the special case where every column is identical. The CDU -> hall
heat reduction (G -> H, both tiny) stays outside the kernel in XLA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.power_topo.ref import CduParams


def _kernel(x_ref, o_ref):
    # x_ref: (S_block, span) VMEM tile; o_ref: (S_block, 1)
    o_ref[...] = jnp.sum(x_ref[...], axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def group_power_pallas(node_pw: jnp.ndarray, n_groups: int,
                       s_block: int = 8, interpret: bool = True
                       ) -> jnp.ndarray:
    """f32[S, N] -> f32[S, G]; N must be divisible by G (wrapper pads)."""
    S, N = node_pw.shape
    assert N % n_groups == 0, "pad N to a multiple of n_groups first"
    span = N // n_groups
    assert S % s_block == 0, "pad S to a multiple of s_block first"

    grid = (n_groups, S // s_block)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((s_block, span), lambda g, s: (s, g))],
        out_specs=pl.BlockSpec((s_block, 1), lambda g, s: (s, g)),
        out_shape=jax.ShapeDtypeStruct((S, n_groups), node_pw.dtype),
        interpret=interpret,
    )(node_pw)
    return out


def _fused_kernel(p: CduParams, x_ref, ts_ref, md_ref, tb_ref, tset_ref,
                  q_ref, tr_ref, tso_ref, mdo_ref):
    """One (S_block x group) tile: segment-reduce + CDU loop update.

    Refs: x (S_block, span); all others (S_block, 1) — including the
    basin/setpoint columns, which carry this group's *hall* values on the
    hierarchical path. The math must mirror ``ref.cdu_update_ref``
    exactly (the parity test holds it to 1e-4).
    """
    q = jnp.sum(x_ref[...], axis=1, keepdims=True)
    ts = ts_ref[...]
    # slew factors clipped at 1, matching the ref (coarse dt snaps)
    a_valve = min(p.dt / p.tau_valve_s, 1.0)
    a_hx = min(p.dt / p.tau_hx_s, 1.0)
    dem = jnp.clip(q / (p.cp_j_kg_k * p.delta_t_design_c),
                   p.mdot_min_kg_s, p.mdot_max_kg_s)
    md_new = md_ref[...] + (dem - md_ref[...]) * a_valve
    tgt = jnp.maximum(tset_ref[...], tb_ref[...] + q / p.ua_w_k)
    q_ref[...] = q
    tr_ref[...] = ts + q / (md_new * p.cp_j_kg_k)
    tso_ref[...] = ts + (tgt - ts) * a_hx
    mdo_ref[...] = md_new


@functools.partial(jax.jit, static_argnums=(5, 6, 7, 8))
def fused_cooling_pallas(node_pw: jnp.ndarray, t_supply: jnp.ndarray,
                         mdot: jnp.ndarray, t_basin: jnp.ndarray,
                         t_set: jnp.ndarray, params: CduParams,
                         n_groups: int, s_block: int = 8,
                         interpret: bool = True):
    """Fused (segment-reduce + CDU update) over a scenario batch.

    Args:
      node_pw: f32[S, N] per-node power; N divisible by ``n_groups``
        (the wrapper in ops.py owns padding).
      t_supply, mdot: f32[S, G] current CDU loop state.
      t_basin, t_set: f32[S, G] basin temperature / effective setpoint
        seen by each group (per-group columns; a flat plant broadcasts
        its single basin across G — the wrapper owns that).
      params: static CduParams scalars (baked into the kernel).
    Returns:
      (q, t_return, t_supply_new, mdot_new), each f32[S, G].
    """
    S, N = node_pw.shape
    assert N % n_groups == 0, "pad N to a multiple of n_groups first"
    span = N // n_groups
    assert S % s_block == 0, "pad S to a multiple of s_block first"
    assert t_basin.shape == (S, n_groups) and t_set.shape == (S, n_groups), \
        "basin/setpoint must be per-group columns (wrapper broadcasts)"

    grid = (n_groups, S // s_block)
    col = pl.BlockSpec((s_block, 1), lambda g, s: (s, g))
    gshape = jax.ShapeDtypeStruct((S, n_groups), node_pw.dtype)
    return pl.pallas_call(
        functools.partial(_fused_kernel, params),
        grid=grid,
        in_specs=[pl.BlockSpec((s_block, span), lambda g, s: (s, g)),
                  col, col, col, col],
        out_specs=(col, col, col, col),
        out_shape=(gshape, gshape, gshape, gshape),
        interpret=interpret,
    )(node_pw, t_supply, mdot, t_basin, t_set)
