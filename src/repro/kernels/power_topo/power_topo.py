"""Pallas TPU kernel: batched node-power -> CDU-group segment reduction.

This is the twin's per-tick hot spot at scale: with S sharded scenarios and
N nodes (up to 158,976 for Fugaku) the reduction is (S x N) -> (S x G) every
step. Grouping is by contiguous span, so each grid program reduces one
(S_block x span) tile held in VMEM.

Tiling: grid = (G, S/S_block); the input block is (S_block, N/G) resident in
VMEM, output block is (S_block, 1). For TPU, S_block is a multiple of 8 and
N/G is padded to a multiple of 128 by the wrapper (ops.py) so the MXU/VPU
lanes stay aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    # x_ref: (S_block, span) VMEM tile; o_ref: (S_block, 1)
    o_ref[...] = jnp.sum(x_ref[...], axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def group_power_pallas(node_pw: jnp.ndarray, n_groups: int,
                       s_block: int = 8, interpret: bool = True
                       ) -> jnp.ndarray:
    """f32[S, N] -> f32[S, G]; N must be divisible by G (wrapper pads)."""
    S, N = node_pw.shape
    assert N % n_groups == 0, "pad N to a multiple of n_groups first"
    span = N // n_groups
    assert S % s_block == 0, "pad S to a multiple of s_block first"

    grid = (n_groups, S // s_block)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((s_block, span), lambda g, s: (s, g))],
        out_specs=pl.BlockSpec((s_block, 1), lambda g, s: (s, g)),
        out_shape=jax.ShapeDtypeStruct((S, n_groups), node_pw.dtype),
        interpret=interpret,
    )(node_pw)
    return out
