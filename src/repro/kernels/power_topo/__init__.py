from repro.kernels.power_topo.ops import (  # noqa: F401
    fused_cooling, fused_cooling_hier, group_power, hall_power)
from repro.kernels.power_topo.ref import (  # noqa: F401
    CduParams, cdu_update_ref, fused_cooling_hier_ref, fused_cooling_ref,
    group_power_ref, hall_matrix, hall_max_ref, hall_power_ref)
