from repro.kernels.power_topo.ops import group_power  # noqa: F401
from repro.kernels.power_topo.ref import group_power_ref  # noqa: F401
