from repro.kernels.power_topo.ops import fused_cooling, group_power  # noqa: F401
from repro.kernels.power_topo.ref import (  # noqa: F401
    CduParams, cdu_update_ref, fused_cooling_ref, group_power_ref)
