"""Pure-jnp oracles for the power-topology kernels.

Node n belongs to CDU group ``n * G // N`` (contiguous spans, mirroring how
cabinets map to CDUs). Inputs may carry a leading scenario-batch axis.

Two oracles live here:

* ``group_power_ref`` — the plain segment reduction (node power -> per-CDU
  heat), used by the engine's capped path and by the DVFS enforcement pass.
* ``cdu_update_ref`` / ``fused_cooling_ref`` — the per-CDU piece of the
  transient cooling update (valve dynamics + heat pickup + supply-loop
  relaxation), optionally fused with the segment reduction. This is the
  single source of truth for the in-kernel math: ``repro.cooling.model``
  calls ``cdu_update_ref`` directly and the Pallas kernel
  (``power_topo.fused_cooling_pallas``) must match it to <= 1e-4.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class CduParams(NamedTuple):
    """Static scalars of the CDU loop update (units: SI, °C).

    Mirrors the relevant ``CoolingConfig`` fields; kept as a plain tuple so
    the kernel layer does not depend on repro.systems.
    """
    cp_j_kg_k: float      # water specific heat (J/(kg·K))
    ua_w_k: float         # facility HX conductance per group (W/K)
    dt: float             # engine step (s)
    tau_hx_s: float       # supply-loop relaxation time constant (s)
    tau_valve_s: float    # valve/flow slew time constant (s)
    delta_t_design_c: float  # design water ΔT across a CDU (°C)
    mdot_min_kg_s: float  # valve floor (kg/s)
    mdot_max_kg_s: float  # full-open flow (kg/s)


def group_ids(n_nodes: int, n_groups: int) -> jnp.ndarray:
    span = -(-n_nodes // n_groups)  # ceil: groups are equal spans, last ragged
    idx = jnp.arange(n_nodes, dtype=jnp.int32)
    return jnp.minimum(idx // span, n_groups - 1)


def group_power_ref(node_pw: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """f32[..., N] -> f32[..., G] segment sum over contiguous node spans."""
    n_nodes = node_pw.shape[-1]
    gid = group_ids(n_nodes, n_groups)
    one_hot = (gid[:, None] == jnp.arange(n_groups)[None, :]).astype(
        node_pw.dtype)
    return node_pw @ one_hot


def cdu_update_ref(q: jnp.ndarray, t_supply: jnp.ndarray, mdot: jnp.ndarray,
                   t_basin: jnp.ndarray, t_set: jnp.ndarray,
                   p: CduParams):
    """Per-CDU loop update for one engine step (pure jnp, elementwise in G).

    Args:
      q: f32[..., G] heat load per CDU group (W).
      t_supply: f32[..., G] current supply water temperature (°C).
      mdot: f32[..., G] current water mass flow (kg/s).
      t_basin: f32[...] tower basin temperature (°C), broadcast over G.
      t_set: f32[...] effective supply setpoint (°C), broadcast over G.
      p: static scalars (CduParams).
    Returns:
      (q, t_return, t_supply_new, mdot_new), each f32[..., G]:
      the heat passthrough, return water temperature, relaxed supply
      temperature and slewed flow.
    """
    # valve: flow slews toward the demand that holds the design ΔT. The
    # slew factors are clipped at 1 (static Python min — dt and tau are
    # compile-time scalars) so a coarse engine dt > tau snaps to the
    # target instead of overshooting the [min, max] flow bounds
    a_valve = min(p.dt / p.tau_valve_s, 1.0)
    a_hx = min(p.dt / p.tau_hx_s, 1.0)
    dem = jnp.clip(q / (p.cp_j_kg_k * p.delta_t_design_c),
                   p.mdot_min_kg_s, p.mdot_max_kg_s)
    mdot_new = mdot + (dem - mdot) * a_valve
    # heat pickup across the cold plates at the new flow
    t_return = t_supply + q / (mdot_new * p.cp_j_kg_k)
    # supply relaxes toward what the facility HX can deliver: never below
    # basin temperature + HX penalty, never below the setpoint
    tgt = jnp.maximum(t_set[..., None], t_basin[..., None] + q / p.ua_w_k)
    t_supply_new = t_supply + (tgt - t_supply) * a_hx
    return q, t_return, t_supply_new, mdot_new


def fused_cooling_ref(node_pw: jnp.ndarray, t_supply: jnp.ndarray,
                      mdot: jnp.ndarray, t_basin: jnp.ndarray,
                      t_set: jnp.ndarray, n_groups: int, p: CduParams):
    """Segment-reduce heat per CDU group + CDU loop update, one logical pass.

    f32[..., N] node power -> (q, t_return, t_supply_new, mdot_new), each
    f32[..., G]. Oracle for ``power_topo.fused_cooling_pallas``.
    """
    q = group_power_ref(node_pw, n_groups)
    return cdu_update_ref(q, t_supply, mdot, t_basin, t_set, p)
