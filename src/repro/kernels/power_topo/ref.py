"""Pure-jnp oracle for the power-topology segment reduction.

Node n belongs to CDU group ``n * G // N`` (contiguous spans, mirroring how
cabinets map to CDUs). Inputs may carry a leading scenario-batch axis.
"""
from __future__ import annotations

import jax.numpy as jnp


def group_ids(n_nodes: int, n_groups: int) -> jnp.ndarray:
    span = -(-n_nodes // n_groups)  # ceil: groups are equal spans, last ragged
    idx = jnp.arange(n_nodes, dtype=jnp.int32)
    return jnp.minimum(idx // span, n_groups - 1)


def group_power_ref(node_pw: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """f32[..., N] -> f32[..., G] segment sum over contiguous node spans."""
    n_nodes = node_pw.shape[-1]
    gid = group_ids(n_nodes, n_groups)
    one_hot = (gid[:, None] == jnp.arange(n_groups)[None, :]).astype(
        node_pw.dtype)
    return node_pw @ one_hot
