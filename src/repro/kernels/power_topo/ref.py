"""Pure-jnp oracles for the power-topology kernels.

Node n belongs to CDU group ``n * G // N`` (contiguous spans, mirroring how
cabinets map to CDUs). Inputs may carry a leading scenario-batch axis.

Two oracles live here:

* ``group_power_ref`` — the plain segment reduction (node power -> per-CDU
  heat), used by the engine's capped path and by the DVFS enforcement pass.
* ``cdu_update_ref`` / ``fused_cooling_ref`` — the per-CDU piece of the
  transient cooling update (valve dynamics + heat pickup + supply-loop
  relaxation), optionally fused with the segment reduction. This is the
  single source of truth for the in-kernel math: ``repro.cooling.model``
  calls ``cdu_update_ref`` directly and the Pallas kernel
  (``power_topo.fused_cooling_pallas``) must match it to <= 1e-4.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp


class CduParams(NamedTuple):
    """Static scalars of the CDU loop update (units: SI, °C).

    Mirrors the relevant ``CoolingConfig`` fields; kept as a plain tuple so
    the kernel layer does not depend on repro.systems.
    """
    cp_j_kg_k: float      # water specific heat (J/(kg·K))
    ua_w_k: float         # facility HX conductance per group (W/K)
    dt: float             # engine step (s)
    tau_hx_s: float       # supply-loop relaxation time constant (s)
    tau_valve_s: float    # valve/flow slew time constant (s)
    delta_t_design_c: float  # design water ΔT across a CDU (°C)
    mdot_min_kg_s: float  # valve floor (kg/s)
    mdot_max_kg_s: float  # full-open flow (kg/s)


def group_ids(n_nodes: int, n_groups: int) -> np.ndarray:
    """i32[N] CDU group of each node, as *host* numpy: the assignment is
    static, so keeping it concrete lets jnp consumers fold it as a
    constant while host-side planners (the scheduler's hall spans) read
    it without tripping over tracers."""
    span = -(-n_nodes // n_groups)  # ceil: groups are equal spans, last ragged
    idx = np.arange(n_nodes, dtype=np.int32)
    return np.minimum(idx // span, n_groups - 1)


def group_power_ref(node_pw: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """f32[..., N] -> f32[..., G] segment sum over contiguous node spans."""
    n_nodes = node_pw.shape[-1]
    gid = group_ids(n_nodes, n_groups)
    one_hot = (gid[:, None] == jnp.arange(n_groups)[None, :]).astype(
        node_pw.dtype)
    return node_pw @ one_hot


def hall_matrix(hall_of_group, n_halls: int,
                dtype=jnp.float32) -> jnp.ndarray:
    """One-hot group->hall matrix f32[G, H] for the second reduction level
    of the node -> CDU -> hall hierarchy. ``x @ hall_matrix(...)`` is the
    per-hall segment sum of a per-group quantity."""
    hog = jnp.asarray(hall_of_group, jnp.int32)
    return (hog[:, None] == jnp.arange(n_halls)[None, :]).astype(dtype)


def hall_power_ref(group_q: jnp.ndarray, hall_of_group,
                   n_halls: int) -> jnp.ndarray:
    """f32[..., G] -> f32[..., H] segment sum of per-group heat per hall."""
    return group_q @ hall_matrix(hall_of_group, n_halls, group_q.dtype)


def hall_max_ref(group_x: jnp.ndarray, hall_of_group,
                 n_halls: int) -> jnp.ndarray:
    """f32[..., G] -> f32[..., H] per-hall max of a per-group quantity
    (e.g. the hottest CDU return temperature in each hall)."""
    mask = hall_matrix(hall_of_group, n_halls, jnp.bool_)
    masked = jnp.where(mask, group_x[..., :, None], -jnp.inf)
    return jnp.max(masked, axis=-2)


def _per_group(x: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Align a basin/setpoint operand with the per-group heat array ``q``:
    already per-group (same rank as q) -> as is; one rank lower (the flat
    plant's scalar-per-batch form) -> broadcast over the trailing G axis."""
    x = jnp.asarray(x, q.dtype)
    return x if x.ndim == q.ndim else x[..., None]


def cdu_update_ref(q: jnp.ndarray, t_supply: jnp.ndarray, mdot: jnp.ndarray,
                   t_basin: jnp.ndarray, t_set: jnp.ndarray,
                   p: CduParams):
    """Per-CDU loop update for one engine step (pure jnp, elementwise in G).

    Args:
      q: f32[..., G] heat load per CDU group (W).
      t_supply: f32[..., G] current supply water temperature (°C).
      mdot: f32[..., G] current water mass flow (kg/s).
      t_basin: basin temperature feeding each CDU (°C): f32[...] (one
        basin for the whole plant, broadcast over G) or f32[..., G]
        (hierarchical plant — each group sees its *hall's* basin, gathered
        by the caller, e.g. ``t_basin_hall[..., hall_of_group]``).
      t_set: effective supply setpoint (°C), f32[...] or f32[..., G].
      p: static scalars (CduParams).
    Returns:
      (q, t_return, t_supply_new, mdot_new), each f32[..., G]:
      the heat passthrough, return water temperature, relaxed supply
      temperature and slewed flow.
    """
    # valve: flow slews toward the demand that holds the design ΔT. The
    # slew factors are clipped at 1 (static Python min when dt and tau
    # are compile-time scalars — the engine path; traced min when a tau
    # is a calibration candidate, see repro.traces.calibrate) so a
    # coarse engine dt > tau snaps to the target instead of overshooting
    # the [min, max] flow bounds
    def _a(tau):
        if isinstance(tau, (int, float)):
            return min(p.dt / tau, 1.0)
        return jnp.minimum(p.dt / tau, 1.0)
    a_valve = _a(p.tau_valve_s)
    a_hx = _a(p.tau_hx_s)
    dem = jnp.clip(q / (p.cp_j_kg_k * p.delta_t_design_c),
                   p.mdot_min_kg_s, p.mdot_max_kg_s)
    mdot_new = mdot + (dem - mdot) * a_valve
    # heat pickup across the cold plates at the new flow
    t_return = t_supply + q / (mdot_new * p.cp_j_kg_k)
    # supply relaxes toward what the facility HX can deliver: never below
    # basin temperature + HX penalty, never below the setpoint
    tgt = jnp.maximum(_per_group(t_set, q), _per_group(t_basin, q)
                      + q / p.ua_w_k)
    t_supply_new = t_supply + (tgt - t_supply) * a_hx
    return q, t_return, t_supply_new, mdot_new


def fused_cooling_ref(node_pw: jnp.ndarray, t_supply: jnp.ndarray,
                      mdot: jnp.ndarray, t_basin: jnp.ndarray,
                      t_set: jnp.ndarray, n_groups: int, p: CduParams):
    """Segment-reduce heat per CDU group + CDU loop update, one logical pass.

    f32[..., N] node power -> (q, t_return, t_supply_new, mdot_new), each
    f32[..., G]. Oracle for ``power_topo.fused_cooling_pallas``.
    """
    q = group_power_ref(node_pw, n_groups)
    return cdu_update_ref(q, t_supply, mdot, t_basin, t_set, p)


def fused_cooling_hier_ref(node_pw: jnp.ndarray, t_supply: jnp.ndarray,
                           mdot: jnp.ndarray, t_basin_hall: jnp.ndarray,
                           t_set: jnp.ndarray, hall_of_group,
                           n_groups: int, p: CduParams):
    """Hierarchical fused update: node -> CDU -> hall segment reduction +
    per-CDU loop update against each group's *hall* basin, one logical pass.

    Args:
      node_pw: f32[..., N] per-node power (W).
      t_supply, mdot: f32[..., G] CDU loop state.
      t_basin_hall: f32[..., H] per-hall basin temperatures (°C).
      t_set: f32[...] effective supply setpoint (°C, shared across halls).
      hall_of_group: static i32[G]-like hall index of each CDU group.
      n_groups: number of CDU groups G.
      p: static CduParams scalars.
    Returns:
      (q, t_return, t_supply_new, mdot_new, q_hall): the per-group pieces
      f32[..., G] plus the per-hall heat sums f32[..., H]. Oracle for the
      hierarchical Pallas path (``ops.fused_cooling`` with per-group
      basin operands).
    """
    hog = jnp.asarray(hall_of_group, jnp.int32)
    n_halls = t_basin_hall.shape[-1]
    t_basin_g = t_basin_hall[..., hog]           # gather: group -> its hall
    q, t_ret, t_sup, md = fused_cooling_ref(node_pw, t_supply, mdot,
                                            t_basin_g, t_set, n_groups, p)
    return q, t_ret, t_sup, md, hall_power_ref(q, hog, n_halls)
