"""Logical-axis sharding rules -> concrete NamedShardings (DP/FSDP/TP/EP/SP).

Every parameter is annotated at init with logical axis names (see
``repro.models.common.param``). Rules map logical names to mesh axes; a
divisibility guard drops a rule per-leaf-dim when the dim does not divide the
mesh axis (e.g. phi3's 40 heads on a 16-way ``model`` axis, mixtral's 8
experts), falling back to replication for that dim — every (arch x mesh)
cell lowers without hand-tuning; the benchmarks record where the
fallback fired.

Parallelism mapping (production mesh (pod, data, model)):
  DP   : batch over ("pod", "data")
  FSDP : parameter "embed" (d_model) dims over "data"  (ZeRO-3-style; XLA
         inserts the all-gathers at use and reduce-scatters in the backward)
  TP   : "mlp" (d_ff), "heads", "vocab" over "model"
  EP   : "expert" over "model" when divisible
  SP   : "kv_seq" over "data" for long-context decode (sequence-sharded
         KV cache / streaming state)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig, Annotated

Rules = Dict[str, Any]


def mesh_axis_types_kwargs(n_axes: int) -> Dict[str, Any]:
    """kwargs for ``jax.make_mesh`` requesting Auto axis types, across JAX
    versions: ``jax.sharding.AxisType`` (and the ``axis_types`` parameter)
    only exist on newer JAX; older releases (e.g. 0.4.x) are Auto-only, so
    omitting the kwarg is equivalent there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}

# -- scenario-axis sharding (digital-twin sweeps) -----------------------------
def sweep_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D ``("scenario",)`` mesh over the local devices: the what-if sweep
    axis of ``engine.simulate_sweep_sharded``. Scenario rows are
    embarrassingly parallel (they share the job table and signal arrays by
    replication), so a flat mesh is always the right shape."""
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(np.array(devs), ("scenario",),
                **mesh_axis_types_kwargs(1))


def pad_leading_axis(tree, multiple: int):
    """Pad every leaf's leading axis up to a multiple of ``multiple`` by
    replicating the last row (scenario batches must divide the mesh; the
    padded rows are dropped by the caller). Returns (padded_tree, pad)."""
    sizes = {leaf.shape[0] for leaf in jax.tree_util.tree_leaves(tree)}
    if len(sizes) != 1:
        raise ValueError(f"inconsistent leading-axis sizes: {sorted(sizes)}")
    (size,) = sizes
    pad = (-size) % multiple

    def one(x):
        if pad == 0:
            return x
        rep = jax.numpy.broadcast_to(x[-1:], (pad,) + tuple(x.shape[1:]))
        return jax.numpy.concatenate([x, rep], axis=0)
    return jax.tree_util.tree_map(one, tree), pad


def scenario_spec() -> Any:
    """PartitionSpec sharding dim0 over the sweep mesh's scenario axis."""
    return P("scenario")


DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "embed": "data",          # FSDP
    "mlp": "model",           # TP
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "vocab": "model",
    "expert": "model",        # EP
    "heads_x_dim": "model",   # fused H*hd projections (rwkv)
    "seq": None,
    "kv_seq": None,
    "unsharded": None,
}


def rules_for(cfg: ArchConfig, mesh: Mesh, kind: str = "train") -> Rules:
    rules = dict(DEFAULT_RULES)
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = msize.get("model", 1)
    if cfg.n_experts and cfg.n_experts % model != 0:
        rules["expert"] = None            # mixtral: 8 experts on 16-way TP
    if cfg.h_pad % model != 0:
        rules["heads"] = None             # unpadded phi3 (40), internvl (14)
    if cfg.kv_pad % model == 0:
        rules["kv_heads"] = "model"       # MHA archs: shard kv heads too
    if kind == "long_decode":
        rules["kv_seq"] = "data"          # SP over the KV cache / state
    return rules


def _axis_size(mesh: Mesh, axis) -> int:
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([msize.get(a, 1) for a in axis]))
    return msize.get(axis, 1)


def _filter_axis(mesh: Mesh, axis):
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' single-pod)."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in mesh.axis_names)
        return kept if kept else None
    return axis if axis in mesh.axis_names else None


def spec_for(mesh: Mesh, rules: Rules, axes: Tuple[str, ...],
             shape: Tuple[int, ...]) -> P:
    """Map logical axes -> PartitionSpec with divisibility guard."""
    if len(axes) < len(shape):   # stacked layer/block leading dims
        axes = ("unsharded",) * (len(shape) - len(axes)) + tuple(axes)
    out = []
    used = set()
    for dim, name in zip(shape, axes):
        ax = _filter_axis(mesh, rules.get(name))
        if ax is None or _axis_size(mesh, ax) == 1 or \
                dim % _axis_size(mesh, ax) != 0 or str(ax) in used:
            out.append(None)
        else:
            out.append(ax)
            used.add(str(ax))
    return P(*out)


def shard_params(mesh: Mesh, rules: Rules, annotated_tree):
    """Annotated tree -> (value tree, NamedSharding tree)."""
    is_ann = lambda x: isinstance(x, Annotated)
    vals = jax.tree_util.tree_map(lambda a: a.value, annotated_tree,
                                  is_leaf=is_ann)
    shardings = jax.tree_util.tree_map(
        lambda a: NamedSharding(mesh, spec_for(mesh, rules, a.axes,
                                               a.value.shape)),
        annotated_tree, is_leaf=is_ann)
    return vals, shardings


def sharding_tree_from_axes(mesh: Mesh, rules: Rules, axes_tree, shape_tree):
    """axes tree (tuples) + ShapeDtypeStruct tree -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda axes, sds: NamedSharding(
            mesh, spec_for(mesh, rules, axes, sds.shape)),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def batch_spec(mesh: Mesh, rules: Rules, ndim: int) -> NamedSharding:
    """Shard dim0 (global batch) over DP axes, replicate the rest."""
    ax = _filter_axis(mesh, rules["batch"])
    return NamedSharding(mesh, P(ax, *([None] * (ndim - 1))))


def batch_shardings(mesh: Mesh, rules: Rules, batch_struct,
                    global_batch: int) -> Any:
    dp = _axis_size(mesh, _filter_axis(mesh, rules["batch"]))

    def one(sds):
        if sds.shape and sds.shape[0] == global_batch and \
                global_batch % dp == 0:
            return batch_spec(mesh, rules, len(sds.shape))
        return NamedSharding(mesh, P(*([None] * len(sds.shape))))
    return jax.tree_util.tree_map(one, batch_struct)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# -- activation hints --------------------------------------------------------
def hint(x, *spec):
    """Best-effort with_sharding_constraint (no-op outside a mesh ctx)."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


# Batch axes for activation hints inside model code. The step builders set
# this to the mesh's DP axes; without the hint XLA's SPMD partitioner is
# free to replicate the scan-carried activations, which measured as ~4x
# redundant per-device flops.
_BATCH_AXES: tuple = ("data",)


def set_batch_axes(axes) -> None:
    global _BATCH_AXES
    _BATCH_AXES = axes


def hint_batch(x):
    """Constrain dim0 (batch) to the DP axes, rest unspecified."""
    try:
        return jax.lax.with_sharding_constraint(
            x, P(_BATCH_AXES, *([None] * (x.ndim - 1))))
    except Exception:
        return x


def hint_axes(x, spec):
    """Constrain with a symbolic spec: 'batch' -> DP axes, 'model' -> TP
    axis, None -> unspecified. Pins layouts across scan bodies so the SPMD
    partitioner doesn't insert per-iteration reshard collective-permutes
    (saves a transpose on the hot path)."""
    resolved = tuple(_BATCH_AXES if a == "batch" else a for a in spec)
    try:
        return jax.lax.with_sharding_constraint(x, P(*resolved))
    except Exception:
        return x


# -- cache/state shardings ----------------------------------------------------
def state_shardings(mesh: Mesh, rules: Rules, state_struct, cfg: ArchConfig,
                    batch: int, kind: str):
    """Heuristic shardings for decode states: shard the batch dim when it
    divides DP; shard the longest (sequence) dim over 'data' for
    long-context; shard a dim equal to n_heads/n_kv_heads over 'model' when
    divisible."""
    dp_ax = _filter_axis(mesh, rules["batch"])
    dp = _axis_size(mesh, dp_ax)
    model = _axis_size(mesh, "model")
    kv_seq_ax = _filter_axis(mesh, rules.get("kv_seq"))

    def one(sds):
        spec = [None] * len(sds.shape)
        used_data = False
        for i, d in enumerate(sds.shape):
            if d == batch and batch % dp == 0 and dp > 1 and not used_data:
                spec[i] = dp_ax
                used_data = True
                break
        # model axis: heads-like dims
        for i, d in enumerate(sds.shape):
            if spec[i] is None and d in (cfg.n_heads, cfg.n_kv_heads,
                                         2 * cfg.d_model // 64) and \
                    d % model == 0 and model > 1:
                spec[i] = "model"
                break
        if kind == "long_decode" and kv_seq_ax is not None and not used_data:
            # largest dim = the sequence axis of the KV cache
            big = int(np.argmax(sds.shape)) if sds.shape else None
            if big is not None and sds.shape[big] >= 4096 and \
                    spec[big] is None and \
                    sds.shape[big] % _axis_size(mesh, kv_seq_ax) == 0:
                spec[big] = kv_seq_ax
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, state_struct)
