"""Per-system dataloaders (paper §2.2 / Table 1, CLI ``--system``).

Each loader returns a ``JobSet`` with the telemetry characteristics of its
dataset: PM100 and Frontier carry per-job power *traces* (20 s / 15 s); F-Data,
LAST and Cirou's Adastra set carry scalar summaries only (trace_len == 1).
Offline note: data is drawn from the calibrated synthetic generator — see
docs/architecture.md ("Datasets and synthetic calibration") for what is
calibrated and how the recorded ground-truth schedule is produced.
"""
from __future__ import annotations

import pathlib

from repro.datasets.base import JobSet
from repro.datasets.synthetic import WorkloadSpec, generate
from repro.systems.config import get_system

DAY = 86400.0


def load_frontier(n_jobs: int = 1238, days: float = 1.0, seed: int = 1,
                  full_system_jobs: int = 3) -> JobSet:
    """Frontier excerpt: 15 s traces, priority FIFO boosted by node count,
    includes the Fig. 6 pattern of full-system (9,600-node) runs."""
    sys = get_system("frontier")
    spec = WorkloadSpec(n_jobs=n_jobs, duration_s=days * DAY, load=0.92,
                        n_accounts=48, mean_wall_s=5400.0,
                        max_frac_nodes=0.30,
                        full_system_jobs=full_system_jobs,
                        trace_len=96, seed=seed)
    return generate(sys, spec)


def load_marconi100(n_jobs: int = 2000, days: float = 1.0,
                    seed: int = 2) -> JobSet:
    """PM100: 20 s traces; shared-node jobs are filtered upstream (paper),
    so utilization does not reflect full production load; queues fill."""
    sys = get_system("marconi100")
    spec = WorkloadSpec(n_jobs=n_jobs, duration_s=days * DAY, load=1.15,
                        n_accounts=32, mean_wall_s=2700.0,
                        max_frac_nodes=0.20, trace_len=64, seed=seed)
    return generate(sys, spec)


def load_fugaku(n_jobs: int = 4000, days: float = 1.0, seed: int = 3,
                load: float = 0.75) -> JobSet:
    """F-Data: job summaries, node-level power only (scalar profiles)."""
    sys = get_system("fugaku")
    spec = WorkloadSpec(n_jobs=n_jobs, duration_s=days * DAY, load=load,
                        n_accounts=64, mean_wall_s=4500.0,
                        max_frac_nodes=0.10, trace_len=1, seed=seed)
    return generate(sys, spec)


def load_lassen(n_jobs: int = 3000, days: float = 1.0, seed: int = 4) -> JobSet:
    """LAST: job summaries with accumulated energy (scalar profiles)."""
    sys = get_system("lassen")
    spec = WorkloadSpec(n_jobs=n_jobs, duration_s=days * DAY, load=0.8,
                        n_accounts=40, mean_wall_s=7200.0,
                        max_frac_nodes=0.25, trace_len=1, seed=seed)
    return generate(sys, spec)


def load_adastra(n_jobs: int = 1000, days: float = 15.0, seed: int = 5) -> JobSet:
    """Cirou's 15-day Adastra set: scalar component power, *low* system load
    (paper Fig. 5: queues do not fill; policy choice makes little difference)."""
    sys = get_system("adastraMI250")
    spec = WorkloadSpec(n_jobs=n_jobs, duration_s=days * DAY, load=0.55,
                        n_accounts=24, mean_wall_s=10800.0,
                        max_frac_nodes=0.35, trace_len=1, seed=seed)
    return generate(sys, spec)


LOADERS = {
    "frontier": load_frontier,
    "marconi100": load_marconi100,
    "marconi": load_marconi100,
    "fugaku": load_fugaku,
    "lassen": load_lassen,
    "adastraMI250": load_adastra,
    "adastra": load_adastra,
}


def load(system_name: str, **kw) -> JobSet:
    """Dispatch to the per-system loader (CLI ``--system``); ``kw`` is
    forwarded (commonly ``n_jobs``, ``days``, ``seed``)."""
    return LOADERS[system_name](**kw)


def load_trace(paths, prof_dt: float = 20.0,
               cache_dir: str | None = None) -> JobSet:
    """Ingest a *real* trace (CLI ``--trace``) behind the same ``JobSet``
    interface the synthetic loaders produce (repro.traces).

    ``paths`` is one or two paths, RAPS-style:
      - ``[job_table.parquet|.csv]`` — a published job table (PM100
        column mapping by default);
      - ``[trace.npz]`` — a previously cached parse (fast restart);
      - ``[joblive_dir]`` or ``[joblive_dir, jobprofile_dir]`` — raw
        scheduler + power telemetry dumps; with a jobprofile the jobs
        carry measured power for ``to_table(replay_power=True)``.
    """
    from repro import traces
    if not 1 <= len(paths) <= 2:
        raise traces.TraceError(f"--trace wants 1 or 2 paths, got "
                                f"{len(paths)}")
    first = pathlib.Path(paths[0])
    if len(paths) == 2:
        return traces.load_telemetry(first, paths[1], prof_dt=prof_dt,
                                     cache_dir=cache_dir)
    if first.suffix in (".parquet", ".csv") and first.is_file():
        return traces.read_job_table(first)
    if first.suffix == ".npz":
        return traces.jobset_from_npz(first)
    if first.is_dir():
        return traces.load_telemetry(first, None, prof_dt=prof_dt,
                                     cache_dir=cache_dir)
    raise traces.TraceError(f"cannot ingest trace {first}: want a "
                            f".parquet/.csv job table, a cached .npz, or "
                            f"a joblive directory")
