"""Synthetic workload generation calibrated to the paper's Table 1 systems.

The Zenodo/LFS datasets the paper uses are unreachable offline, so each
dataloader (frontier.py, marconi100.py, ...) draws from this generator with
system-specific calibration (arrival intensity, size mix, power levels,
trace vs scalar telemetry). The generator also *records* a ground-truth
schedule by running the event-driven reference scheduler below — giving every
job a ``rec_start`` exactly like production telemetry, so replay/reschedule
semantics (paper §3.2.2, Fig. 3) are exercised faithfully.

``EventScheduler`` is intentionally a standalone, *event-based* simulator in
plain numpy: it doubles as the paper's "external scheduler" (a FastSim-like
fast Slurm emulation) in §4.2 integrations — see repro.core.external.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from repro.datasets.base import JobSet
from repro.systems.config import SystemConfig


# ---------------------------------------------------------------------------
# Event-driven reference scheduler (capacity-based, grid-aligned).
# ---------------------------------------------------------------------------
def event_schedule(submit: np.ndarray, limit: np.ndarray, wall: np.ndarray,
                   nodes: np.ndarray, n_nodes: int, dt: float,
                   policy: str = "fcfs", backfill: str = "firstfit",
                   priority: np.ndarray | None = None) -> np.ndarray:
    """Event-driven schedule: returns start times (grid-aligned).

    Capacity-based admission with the same deterministic semantics as the
    compiled engine (completions release nodes before placements at the same
    instant). Policies: fcfs / sjf / ljf / priority; backfill: none/firstfit.
    """
    J = len(submit)
    submit_g = np.ceil(submit / dt) * dt
    start = np.full(J, np.inf)
    free = n_nodes
    queue: list[int] = []
    # event heap: (time, kind, jid); kind 0=release first, 1=submit
    ev = [(float(submit_g[j]), 1, j) for j in range(J)]
    heapq.heapify(ev)

    if policy == "fcfs":
        key = submit_g
    elif policy == "sjf":
        key = limit
    elif policy == "ljf":
        key = -nodes.astype(np.float64)
    elif policy == "priority":
        assert priority is not None
        key = -priority.astype(np.float64)
    else:
        raise ValueError(policy)

    while ev:
        t, kind, j = heapq.heappop(ev)
        if kind == 0:
            free += int(nodes[j])
        else:
            queue.append(j)
        # drain simultaneous events before scheduling
        if ev and ev[0][0] == t:
            continue
        # admission pass
        queue.sort(key=lambda q: (key[q], submit_g[q], q))
        placed = []
        for q in queue:
            need = int(nodes[q])
            if need <= free:
                free -= need
                start[q] = t
                heapq.heappush(ev, (t + float(wall[q]), 0, q))
                placed.append(q)
            elif backfill == "none":
                break
        for q in placed:
            queue.remove(q)
    return start


# ---------------------------------------------------------------------------
# Workload synthesis.
# ---------------------------------------------------------------------------
@dataclass
class WorkloadSpec:
    """Knobs of the calibrated generator (one spec per paper Table 1
    system — see docs/architecture.md, "Datasets and synthetic
    calibration"). Times in seconds; ``load`` is offered node-seconds
    over capacity node-seconds (dimensionless)."""
    n_jobs: int = 512
    duration_s: float = 24 * 3600.0
    load: float = 0.85              # target offered load (node-seconds ratio)
    n_accounts: int = 16
    mean_wall_s: float = 3600.0
    max_frac_nodes: float = 0.25    # cap on single-job size
    full_system_jobs: int = 0       # paper Fig. 6: occasional 100% runs
    trace_len: int = 64             # P; 1 for scalar-summary datasets
    diurnal: float = 0.3            # arrival-rate modulation amplitude
    seed: int = 0


def generate(system: SystemConfig, spec: WorkloadSpec) -> JobSet:
    """Draw a ``JobSet`` from the calibrated generator: diurnal Poisson
    arrivals (s), log2-mix node counts, lognormal walltimes scaled to hit
    ``spec.load``, correlated per-node power traces (W) at
    ``system.prof_dt``, and a recorded ground-truth schedule
    (``rec_start``) from the event-driven reference scheduler (paper
    §3.2.2 replay semantics)."""
    rng = np.random.default_rng(spec.seed)
    J = spec.n_jobs
    dt = system.dt

    # --- arrivals: Poisson with diurnal modulation -------------------------
    base = rng.exponential(spec.duration_s / J, J)
    submit = np.cumsum(base)
    submit *= spec.duration_s / submit[-1]
    day_phase = 2 * np.pi * submit / 86400.0
    submit = submit + spec.diurnal * spec.mean_wall_s * np.sin(day_phase)
    submit = np.clip(np.sort(submit), 0.0, spec.duration_s)

    # --- sizes: log2-ish mix, a few large, optional full-system runs -------
    max_nodes = max(int(system.n_nodes * spec.max_frac_nodes), 1)
    raw = 2 ** rng.uniform(0, np.log2(max(max_nodes, 2)), J)
    nodes = np.maximum(raw.astype(np.int64), 1)
    if spec.full_system_jobs:
        idx = rng.choice(J // 2, spec.full_system_jobs, replace=False) + J // 4
        nodes[idx] = system.n_nodes

    # --- walltimes: lognormal, grid-aligned; limits overestimate -----------
    wall = rng.lognormal(np.log(spec.mean_wall_s), 0.8, J)
    wall = np.maximum(np.round(wall / dt), 1.0) * dt
    limit = wall * rng.uniform(1.1, 3.0, J)
    limit = np.ceil(limit / dt) * dt

    # rescale sizes to hit the target offered load
    offered = float((nodes * wall).sum())
    capacity = system.n_nodes * spec.duration_s
    scale = spec.load * capacity / offered
    if scale < 1.0:
        nodes = np.maximum((nodes * scale).astype(np.int64), 1)

    # --- accounts: zipf-ish popularity; per-account power temperament ------
    acct_prob = 1.0 / np.arange(1, spec.n_accounts + 1)
    acct_prob /= acct_prob.sum()
    account = rng.choice(spec.n_accounts, J, p=acct_prob)
    # temperament in [0,1]: 0 = frugal codes, 1 = power-hungry codes
    temperament = rng.beta(2, 2, spec.n_accounts)[account]

    # --- priority: bigger jobs boosted (Frontier-style), small noise -------
    priority = np.log2(nodes + 1) + rng.uniform(0, 1, J)

    # --- per-node power / utilization profiles -----------------------------
    P = spec.trace_len
    idle, peak = system.power.idle_node_w, system.power.peak_node_w
    base_util = np.clip(0.35 + 0.55 * temperament +
                        rng.normal(0, 0.1, J), 0.05, 1.0)
    if P == 1:
        util_prof = base_util[:, None].astype(np.float32)
    else:
        walk = rng.normal(0, 0.05, (J, P)).cumsum(1)
        util_prof = np.clip(base_util[:, None] + walk, 0.02, 1.0)
        util_prof = util_prof.astype(np.float32)
    power_prof = (idle + (peak - idle) * util_prof).astype(np.float32)

    # --- ground-truth recorded schedule (event-driven reference) -----------
    rec_start = event_schedule(submit, limit, wall, nodes, system.n_nodes,
                               dt, policy="fcfs", backfill="firstfit",
                               priority=priority)
    # jobs that never started in the recorded horizon: treat as started at
    # the end (they will be dismissed by windows that end earlier)
    never = ~np.isfinite(rec_start)
    rec_start[never] = spec.duration_s * 2

    js = JobSet(submit=submit, limit=limit, wall=wall, nodes=nodes,
                priority=priority, account=account, rec_start=rec_start,
                power_prof=power_prof, util_prof=util_prof,
                name=system.name)
    return js
