"""Standard Workload Format (SWF) import/export (Chapin et al. [13], as the
paper cites for the dataloader contract).

SWF fields used (1-indexed per the spec):
  1 job id, 2 submit, 3 wait, 4 runtime, 5 allocated procs, 8 requested
  procs, 9 requested time (limit), 12 user id, 13 group id
Power channels are not part of SWF; on import jobs get a configurable
constant per-node power (SWF workloads still drive scheduling studies).
"""
from __future__ import annotations

import numpy as np

from repro.datasets.base import JobSet


def write_swf(js: JobSet, path: str) -> None:
    """Export a ``JobSet`` as SWF rows (times in whole seconds; the wait
    column is derived from the recorded start). Power/utilization channels
    are dropped — SWF has no slot for them. Jobs that never started
    (non-finite ``rec_start``) get the SWF missing-value wait of ``-1``
    instead of a non-numeric ``inf`` token."""
    with open(path, "w") as f:
        f.write("; SWF export from repro (S-RAPS JAX twin)\n")
        for i in range(len(js)):
            wait = max(js.rec_start[i] - js.submit[i], 0.0) \
                if np.isfinite(js.rec_start[i]) else -1.0
            f.write(f"{i + 1} {js.submit[i]:.0f} {wait:.0f} "
                    f"{js.wall[i]:.0f} {js.nodes[i]} 0 0 {js.nodes[i]} "
                    f"{js.limit[i]:.0f} 0 1 {js.account[i] + 1} "
                    f"{js.account[i] + 1} 0 0 0 0 0\n")


def read_swf(path: str, node_power_w: float = 500.0,
             util: float = 0.7) -> JobSet:
    """Import an SWF trace into a ``JobSet`` (times s, counts i64).

    SWF carries no power telemetry, so every job gets a scalar profile of
    ``node_power_w`` watts per node at ``util`` utilization — enough to
    drive scheduling studies; swap in measured profiles for power work.
    """
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(";"):
                continue
            parts = line.split()
            if len(parts) < 13:
                continue
            rows.append([float(parts[1]), float(parts[3]), float(parts[2]),
                         float(parts[7]) if float(parts[7]) > 0
                         else float(parts[4]),
                         float(parts[8]), float(parts[11])])
    a = np.asarray(rows)
    submit = a[:, 0]
    wall = np.maximum(a[:, 1], 1.0)
    # SWF marks an unknown/never-happened wait as -1: those jobs never
    # started, which the JobSet contract spells rec_start = inf
    wait = np.where(a[:, 2] >= 0, a[:, 2], np.inf)
    nodes = np.maximum(a[:, 3], 1).astype(np.int64)
    limit = np.where(a[:, 4] > 0, a[:, 4], wall * 2)
    account = (a[:, 5].astype(np.int64) - 1) % 64
    J = len(a)
    power = np.full((J, 1), node_power_w, np.float32)
    up = np.full((J, 1), util, np.float32)
    return JobSet(submit=submit, limit=limit, wall=wall, nodes=nodes,
                  priority=np.log2(nodes + 1.0), account=account,
                  rec_start=submit + wait, power_prof=power, util_prof=up,
                  name="swf")
