"""Host-side job-set schema shared by all dataloaders (paper §3.2.2).

Every dataloader produces a ``JobSet`` (numpy struct-of-arrays) holding, per
job: submit/start/end times, requested walltime, node count, account,
priority, and a per-node power/utilization profile (time series for trace
datasets, single scalar for summary datasets). ``to_table`` pads and packs it
into the fixed-shape ``JobTable`` consumed by the compiled engine.

This mirrors the standard workload format (SWF) fields the paper points to
[13], plus the power/trace channels a DCDT needs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from repro.core import types as T


@dataclass
class JobSet:
    """Host-side struct-of-arrays job set (paper §3.2.2, SWF-style).

    Times are absolute seconds from the dataset origin; ``power_prof`` is
    per-node watts sampled at ``SystemConfig.prof_dt`` (P == 1 for
    scalar-summary datasets); ``util_prof`` is dimensionless in [0, 1].
    """
    submit: np.ndarray       # f64[J] seconds
    limit: np.ndarray        # f64[J] requested walltime
    wall: np.ndarray         # f64[J] true runtime
    nodes: np.ndarray        # i64[J]
    priority: np.ndarray     # f64[J]
    account: np.ndarray      # i64[J]
    rec_start: np.ndarray    # f64[J] recorded start times
    power_prof: np.ndarray   # f32[J, P] per-node power (W)
    util_prof: np.ndarray    # f32[J, P] in [0,1]
    first_node: np.ndarray | None = None  # i32[J], -1 unknown
    score: np.ndarray | None = None       # f32[J] baked ML/external score
    ml_basis: np.ndarray | None = None    # f32[J, K] scoring basis
    #   (repro.ml.scoring.basis of the predicted features; lets the table
    #    score jobs under any Scenario.alpha — see ml.pipeline.attach_basis)
    power_profile: np.ndarray | None = None  # f32[J, Q] measured per-node W
    #   (repro.traces telemetry replay: negative samples mean "no
    #    measurement" — those jobs fall back to ``power_prof``; the field
    #    only reaches the compiled table via to_table(replay_power=True))
    name: str = "jobset"

    def __len__(self) -> int:
        return int(self.submit.shape[0])

    @property
    def rec_end(self) -> np.ndarray:
        return self.rec_start + self.wall

    def window(self, t0: float, t1: float) -> "JobSet":
        """Keep jobs overlapping [t0, t1) (engine handles edge flags)."""
        keep = (self.rec_end > t0) & (self.submit < t1)
        return self.select(keep)

    def select(self, mask: np.ndarray) -> "JobSet":
        def pick(x):
            return None if x is None else x[mask]
        return JobSet(self.submit[mask], self.limit[mask], self.wall[mask],
                      self.nodes[mask], self.priority[mask],
                      self.account[mask], self.rec_start[mask],
                      self.power_prof[mask], self.util_prof[mask],
                      pick(self.first_node), pick(self.score),
                      pick(self.ml_basis), pick(self.power_profile),
                      self.name)

    def assign_prepop_placement(self, t0: float, n_nodes: int) -> None:
        """Give contiguous spans to jobs running at t0 (prepopulation)."""
        first = np.full(len(self), -1, np.int64)
        running0 = (self.rec_start <= t0) & (self.rec_end > t0)
        cursor = 0
        for j in np.nonzero(running0)[0]:
            need = int(self.nodes[j])
            if cursor + need <= n_nodes:
                first[j] = cursor
                cursor += need
        self.first_node = first

    def to_table(self, pad_to: int | None = None,
                 compact_time: bool = False,
                 replay_power: bool = False) -> T.JobTable:
        """Pad and pack into the fixed-shape ``JobTable`` the compiled
        engine consumes (times -> f32 s, power -> f32 W, counts -> i32).
        Padded rows are marked invalid; ``ml_basis`` (if attached) pads
        with zeros, so padded jobs score 0 under every alpha.

        ``compact_time=True`` narrows the broadcast time columns
        (submit / limit / wall / rec_start) from float32 to int32 when
        every value is a whole second below 2^24 (the SWF contract and
        the f32-exact integer range) — integer compares on the scan's
        hot columns, with non-finite entries (and the inf pad fill)
        mapped to a 2^30-second sentinel that every window test
        classifies exactly like +inf. Falls back to float32 silently
        when a column is fractional or too large, so the flag is always
        safe; the engine's weak-typing promotes int32 against f32
        exactly in this range, which the bit-compat test asserts
        end-to-end.

        ``replay_power=True`` carries the measured ``power_profile``
        channel (repro.traces telemetry) into the table, padded with the
        -1 "no measurement" sentinel so padded rows — like profile-less
        jobs — fall back to the ``power_prof`` model. Off by default:
        the table keeps its pre-traces structure (``power_profile is
        None``) and every compiled graph stays bit-identical. Requires
        the JobSet to actually carry measurements."""
        J = len(self)
        Jp = pad_to or J
        assert Jp >= J, f"pad_to={Jp} < {J} jobs"
        P = self.power_prof.shape[1]

        def pad1(x, fill, dtype):
            out = np.full((Jp,), fill, dtype)
            out[:J] = x
            return jnp.asarray(out)

        # far past any simulation window, exactly representable in both
        # int32 and float32; plays the +inf role for compact columns
        TIME_SENTINEL = np.int64(1) << 30

        def pad_time(x, fill):
            if compact_time:
                a = np.asarray(x, np.float64)
                finite = np.isfinite(a)
                vals = a[finite]
                if vals.size == 0 or (np.all(vals == np.round(vals)) and
                                      np.all(np.abs(vals) < (1 << 24))):
                    out = np.full((Jp,), TIME_SENTINEL, np.int32)
                    ai = np.where(finite, a, float(TIME_SENTINEL))
                    out[:J] = ai.astype(np.int32)
                    if np.isfinite(fill):
                        out[J:] = np.int32(fill)
                    return jnp.asarray(out)
            return pad1(x, fill, np.float32)

        def pad2(x, fill, dtype, width=P):
            out = np.full((Jp, width), fill, dtype)
            out[:J] = x
            return jnp.asarray(out)

        first = self.first_node if self.first_node is not None else \
            np.full(J, -1, np.int64)
        score = self.score if self.score is not None else np.zeros(J)
        basis = None if self.ml_basis is None else \
            pad2(self.ml_basis, 0.0, np.float32,
                 width=self.ml_basis.shape[1])
        measured = None
        if replay_power:
            if self.power_profile is None:
                raise ValueError(
                    "replay_power=True but this JobSet carries no measured "
                    "power_profile (load one via repro.traces)")
            measured = pad2(self.power_profile, -1.0, np.float32,
                            width=self.power_profile.shape[1])
        valid = np.zeros((Jp,), bool)
        valid[:J] = True
        return T.JobTable(
            submit=pad_time(self.submit, np.inf),
            limit=pad_time(self.limit, 1.0),
            wall=pad_time(self.wall, 1.0),
            nodes=pad1(self.nodes, 1, np.int32),
            priority=pad1(self.priority, 0.0, np.float32),
            account=pad1(self.account, 0, np.int32),
            rec_start=pad_time(self.rec_start, np.inf),
            first_node=pad1(first, -1, np.int32),
            score=pad1(score, 0.0, np.float32),
            power_prof=pad2(self.power_prof, 0.0, np.float32),
            util_prof=pad2(self.util_prof, 0.0, np.float32),
            valid=jnp.asarray(valid),
            ml_basis=basis,
            power_profile=measured,
        )

    # -- pre-submission feature matrix for the ML pipeline (paper §4.4) -----
    def presubmit_features(self) -> np.ndarray:
        """f64[J, 5] features known at submit time: nodes, limit (s),
        priority, log1p(nodes), log1p(limit). Account aggregates are
        intentionally excluded (they're ledger state)."""
        return np.stack([
            self.nodes.astype(np.float64),
            self.limit.astype(np.float64),
            self.priority.astype(np.float64),
            np.log1p(self.nodes.astype(np.float64)),
            np.log1p(self.limit.astype(np.float64)),
        ], axis=1)

    def behavior_features(self) -> np.ndarray:
        """f64[J, 7] post-hoc features (clustering targets): power trace
        mean/max/min/std (W), utilization mean/std, runtime (s) — summary
        statistics of the noisy time series, as the paper does for PM100
        (§4.4.3)."""
        p = self.power_prof
        u = self.util_prof
        return np.stack([
            p.mean(1), p.max(1), p.min(1), p.std(1),
            u.mean(1), u.std(1),
            self.wall.astype(np.float64),
        ], axis=1)
