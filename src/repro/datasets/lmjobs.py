"""AI-workload dataset: the twin schedules LM training/serving jobs whose
power behavior comes from the *compiled* workload layer.

Each job is a (arch x shape) run from the assigned grid; its per-node power
is derived from the cell's roofline terms (results/dryrun/*__final.json):
compute-bound cells run nodes near peak power, collective/memory-bound cells
idle the compute units proportionally to the dominant-term ratio —
the standard utilization->power proxy, fed by real compiled artifacts.
Falls back to an analytic table when no dry-run artifacts exist.
"""
from __future__ import annotations

import glob
import json
import pathlib

import numpy as np

from repro.datasets.base import JobSet
from repro.datasets.synthetic import event_schedule
from repro.systems.config import SystemConfig

DRYRUN = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# fallback utilization if no dry-run artifacts are present
_FALLBACK_UTIL = 0.6


def _cell_utilization() -> dict:
    """(arch, shape) -> compute-term / dominant-term from the dry-run."""
    out = {}
    for f in glob.glob(str(DRYRUN / "*__extrap__final.json")):
        rec = json.load(open(f))
        if rec.get("status") != "OK":
            continue
        rf = rec["roofline"]
        dom = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        parts = rec["cell"].split("__")
        if dom > 0:
            out[(parts[0], parts[1])] = min(rf["t_compute_s"] / dom, 1.0)
    return out


def generate_lm_workload(system: SystemConfig, n_jobs: int = 256,
                         duration_s: float = 86400.0, seed: int = 0,
                         n_accounts: int = 16) -> JobSet:
    """Jobs = LM runs drawn from the assigned (arch x shape) grid.

    Returns a ``JobSet`` with times in s and scalar per-node power
    profiles (W) derived from each cell's roofline utilization
    (idle + (peak - idle) * util); walltimes are grid-aligned to
    ``system.dt`` and a ground-truth schedule is recorded via
    ``event_schedule`` (replay semantics, paper §3.2.2)."""
    rng = np.random.default_rng(seed)
    cells = _cell_utilization()
    if not cells:
        from repro.configs import ARCHS, SHAPES
        cells = {(a, s): _FALLBACK_UTIL for a in ARCHS for s in SHAPES
                 if s not in ARCHS[a].skip_shapes}
    keys = list(cells.keys())
    pick = rng.integers(0, len(keys), n_jobs)

    # job sizing: training runs are wide + long, decode serving narrow + long,
    # prefill batch jobs short
    kind_of = {"train_4k": (0.10, 6.0), "prefill_32k": (0.02, 1.0),
               "decode_32k": (0.04, 8.0), "long_500k": (0.01, 4.0)}
    nodes = np.empty(n_jobs, np.int64)
    wall = np.empty(n_jobs)
    util = np.empty(n_jobs, np.float32)
    arch_ids = []
    for i, k in enumerate(pick):
        arch, shape = keys[k]
        frac, hours = kind_of.get(shape, (0.05, 2.0))
        nodes[i] = max(int(system.n_nodes * frac * rng.uniform(0.5, 2.0)), 1)
        wall[i] = max(rng.lognormal(np.log(hours * 3600.0), 0.5),
                      system.dt)
        util[i] = np.clip(cells[keys[k]] * rng.uniform(0.9, 1.05), 0.05, 1.0)
        arch_ids.append(f"{arch}:{shape}")
    wall = np.round(wall / system.dt) * system.dt
    nodes = np.minimum(nodes, system.n_nodes)

    submit = np.sort(rng.uniform(0, duration_s, n_jobs))
    limit = wall * rng.uniform(1.1, 2.0, n_jobs)
    idle, peak = system.power.idle_node_w, system.power.peak_node_w
    power = (idle + (peak - idle) * util)[:, None].astype(np.float32)
    rec_start = event_schedule(submit, limit, wall, nodes, system.n_nodes,
                               system.dt)
    rec_start = np.where(np.isfinite(rec_start), rec_start, duration_s * 2)
    js = JobSet(submit=submit, limit=limit, wall=wall, nodes=nodes,
                priority=np.log2(nodes + 1.0),
                account=rng.integers(0, n_accounts, n_jobs),
                rec_start=rec_start, power_prof=power,
                util_prof=util[:, None].astype(np.float32),
                name=f"lmjobs-{system.name}")
    js.arch_ids = arch_ids  # type: ignore[attr-defined]
    return js
