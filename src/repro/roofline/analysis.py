"""Roofline-term extraction from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

``cost_analysis`` of an SPMD executable describes the *per-device* module,
so per-device quantities divide by per-chip rates directly. Collective bytes
are not in cost_analysis: we parse the post-optimization HLO and sum operand
bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

from repro.launch import mesh as M

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None or b == 0:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, by kind.

    We use the op *result* shape (for all-gather that's the gathered size,
    for reduce-scatter the scattered size) as the wire-traffic proxy; the
    result line in post-opt HLO is `shape = op-name(...)`.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*([\w\[\],{}/ ]+?)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", ls)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    out_total = {f"{k}_bytes": v for k, v in out.items()}
    out_total.update({f"{k}_count": counts[k] for k in _COLLECTIVES})
    out_total["total_bytes"] = sum(out.values())
    return out_total


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float            # XLA 'bytes accessed' (unfused bound)
    hbm_bytes_model: float             # fusion-aware analytic traffic model
    collective_bytes_per_device: float
    peak_memory_per_device: Optional[float]
    t_compute_s: float
    t_memory_s: float                  # from hbm_bytes_model
    t_memory_unfused_s: float          # from XLA bytes accessed
    t_collective_s: float
    bottleneck: str
    model_flops: float
    useful_flops_ratio: float
    collective_detail: Dict[str, int]

    def as_dict(self):
        return asdict(self)


def analytic_hbm_bytes(cfg, seq_len: int, global_batch: int, kind: str,
                       chips: int) -> float:
    """Fusion-aware per-device HBM traffic model (what a TPU executes, as
    opposed to XLA CPU's no-fusion 'bytes accessed' upper bound).

    train:   params read (fwd+bwd) + grad write + AdamW moments r/w
             + remat'd activation checkpoints (write + read + recompute)
             + logits/loss traffic
    prefill: params read + KV/state cache write + boundary activations
    decode:  full (active) params read + cache read/update per token
    """
    pb = {2: 2, 4: 4}.get(jnp_bytes(cfg.param_dtype), 4)
    mb = jnp_bytes(cfg.moment_dtype)
    ab = 2  # bf16 activations
    n_total = cfg.param_count
    n_active = cfg.active_param_count
    p_local = n_total * pb / chips
    # activations are sharded over DP ways only (batch axis), not over TP:
    # production meshes here use a 16-way model axis.
    model_ways = min(16, chips)
    dp_ways = max(chips // model_ways, 1)
    toks_local = seq_len * global_batch / dp_ways
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers

    if kind == "train":
        params_t = 3 * p_local                    # fwd read, bwd read, write
        opt_t = 4 * (n_total * mb / chips)        # m,v read+write
        act_t = 3 * L * toks_local * D * ab       # ckpt w + r + recompute w
        head_t = 2 * toks_local * (V / max(chips ** 0.5, 1)) * 4
        return params_t + opt_t + act_t + head_t
    if kind == "prefill":
        cache_t = 2 * L * toks_local * cfg.n_kv_heads * cfg.hd * ab
        return p_local + cache_t + L * toks_local * D * ab
    # decode: one token / sequence; params dominate, plus cache r/w
    b_local = max(global_batch / max(chips, 1), global_batch / chips)
    active_frac = n_active / n_total
    if cfg.n_experts:
        # tiny decode batches touch ~B*top_k experts at most
        import math
        touched = min(global_batch * max(cfg.top_k, 1), cfg.n_experts)
        moe_frac = touched / cfg.n_experts
        active_frac = max(active_frac, min(1.0, moe_frac))
    params_t = n_total * pb * active_frac / chips
    if cfg.family == "ssm":
        cache = L * global_batch * cfg.n_heads * cfg.hd * cfg.hd * 4
    elif cfg.family == "hybrid":
        n_shared = L // max(cfg.shared_attn_every, 1)
        cache = L * global_batch * (2 * D) * cfg.ssm_state * 4 + \
            n_shared * global_batch * seq_len * cfg.n_kv_heads * cfg.hd * 2 * ab
    else:
        layers_with_kv = L
        cache = layers_with_kv * global_batch * seq_len * \
            cfg.n_kv_heads * cfg.hd * 2 * ab
    cache_t = cache * 1.0 / chips   # read once (update is += small)
    return params_t + cache_t


def jnp_bytes(dt) -> int:
    import jax.numpy as jnp
    import numpy as np
    return np.dtype(dt).itemsize if dt not in (jnp.bfloat16,) else 2


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            cost: Dict[str, float], hlo_text: str, model_flops: float,
            peak_memory: Optional[float] = None,
            hbm_model: float = 0.0) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    # XLA reports -1 for unknown
    if flops < 0:
        flops = 0.0
    byts = float(cost.get("bytes accessed", 0.0))
    if byts <= 0:
        # fall back to sum of operand/output traffic estimates
        byts = sum(float(v) for k, v in cost.items()
                   if k.startswith("bytes accessed"))
    coll = collective_bytes(hlo_text)
    cb = float(coll["total_bytes"])

    t_comp = flops / M.PEAK_FLOPS_BF16
    t_mem_unfused = byts / M.HBM_BW
    t_mem = (hbm_model or byts) / M.HBM_BW
    t_coll = cb / M.ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    global_flops = flops * chips
    ratio = model_flops / global_flops if global_flops > 0 else 0.0
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    flops_per_device=flops, bytes_per_device=byts,
                    hbm_bytes_model=hbm_model,
                    collective_bytes_per_device=cb,
                    peak_memory_per_device=peak_memory,
                    t_compute_s=t_comp, t_memory_s=t_mem,
                    t_memory_unfused_s=t_mem_unfused,
                    t_collective_s=t_coll, bottleneck=bottleneck,
                    model_flops=model_flops, useful_flops_ratio=ratio,
                    collective_detail=coll)


def model_flops_for(cfg, seq_len: int, global_batch: int, kind: str) -> float:
    """6*N*D for training (N = active params, D = tokens); 2*N*D for a
    single forward (prefill); 2*N per token for decode."""
    n_active = cfg.active_param_count
    if kind == "train":
        return 6.0 * n_active * seq_len * global_batch
    if kind == "prefill":
        return 2.0 * n_active * seq_len * global_batch
    # decode: one token per sequence
    return 2.0 * n_active * global_batch
