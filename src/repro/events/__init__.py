"""Failure & demand-response scenario engine (docs/architecture.md).

Seeded stochastic outage processes (node / correlated CDU-group / tower-
cell failures with repair times) realized *inside* the scan as
time-indexed availability masks, plus grid demand-response cap steps with
notice windows. Enabled by passing an ``EventConfig`` to the engine
runners; the zero-``EventConfig`` rates are value-neutral and the
``events=None`` default keeps every pre-events graph bit-identical.
"""
from repro.events.process import (DrNow, EventConfig, EventsNow,
                                  apply_failures, dr_now, init_event_state,
                                  realize_masks)

__all__ = ["DrNow", "EventConfig", "EventsNow", "apply_failures", "dr_now",
           "init_event_state", "realize_masks"]
