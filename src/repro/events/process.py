"""Seeded stochastic failure processes + demand-response events.

The hazard model runs *inside* the scan: every engine step draws fresh
failures from a stateless key ``fold_in(PRNGKey(failure_seed), step)`` —
deterministic across the ``simulate`` / ``simulate_sweep`` /
``simulate_segment`` lanes (the step cursor rides the carry, so resumed
and forked trajectories replay the exact same draws), vmap-safe (the
seed is a traced ``Scenario`` leaf, so a sweep carries one failure
universe per scenario row).

Three entity classes fail independently per step with hazard rates from
the ``Scenario`` knobs (probability ``1 - exp(-rate * dt)``), plus one
*correlated common-cause* draw per hall that takes down every CDU group
in the hall together (``failure_corr`` scales its probability relative
to the single-group hazard). Repair times are exponential with mean
``repair_s``. Down-state is a repair-complete time per entity
(``EventState.*_down_until``): an entity is down while ``t <
down_until`` — since ``down_until`` only ever grows and ``t`` is
monotone, a failed entity can never resurrect before its repair time,
and for a fixed seed the realized downtime is pointwise monotone in
both the failure rates (fail sets nest) and ``repair_s`` (durations
scale).

Demand-response events are deterministic cap steps riding the grid-cap
machinery: announced at ``dr_announce_s``, the cap ``dr_cap_w`` engages
``dr_notice_s`` later and holds for ``dr_duration_s``. During the
notice window the scheduler already refuses jobs that would run into
the event unless the system would still fit under the announced cap
(see ``repro.core.scheduler.schedule_step``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import types as T
from repro.kernels.power_topo.ref import group_ids
from repro.systems.config import SystemConfig


@dataclasses.dataclass(frozen=True)
class EventConfig:
    """Static (compile-time) switches of the event layer. Passing an
    ``EventConfig`` to an engine runner enables the failure process; the
    per-scenario hazard rates stay traced ``Scenario`` knobs, so one
    compiled program sweeps a whole (seed x rate x correlation) grid.

    ``requeue``: killed jobs return to the queue (and may reschedule);
    ``False`` dismisses them instead (the job is lost with its energy).
    """
    requeue: bool = True


class EventsNow(NamedTuple):
    """Per-step failure telemetry handed from the failure pass to the
    cooling model and the StepRecord."""
    cells_failed_hall: jnp.ndarray  # f32[H] failed tower cells per hall
    nodes_down: jnp.ndarray         # f32[] nodes unavailable this step
    n_killed: jnp.ndarray           # f32[] jobs killed this step
    groups_down: jnp.ndarray        # f32[] CDU groups down this step


class DrNow(NamedTuple):
    """Demand-response signal at one instant (all from traced knobs)."""
    start_s: jnp.ndarray    # f32[] when the cap engages (announce + notice)
    cap_w: jnp.ndarray      # f32[] announced cap level (inf when no event)
    cap_now_w: jnp.ndarray  # f32[] cap in force right now (inf outside)
    in_notice: jnp.ndarray  # bool[] inside the announced notice window


def dr_now(scen: T.Scenario, t) -> DrNow:
    """Evaluate the demand-response event at time ``t`` (s).

    Sentinel-disabled (``dr_announce_s < 0`` or ``dr_cap_w <= 0``): every
    field is neutral (inf cap, notice never active), so the scheduler and
    cap machinery fold to their pre-event behavior under ``where``.
    """
    enabled = (scen.dr_announce_s >= 0.0) & (scen.dr_cap_w > 0.0)
    start = scen.dr_announce_s + jnp.maximum(scen.dr_notice_s, 0.0)
    end = start + jnp.maximum(scen.dr_duration_s, 0.0)
    active = enabled & (t >= start) & (t < end)
    in_notice = enabled & (t >= scen.dr_announce_s) & (t < start)
    return DrNow(
        start_s=jnp.asarray(start, jnp.float32),
        cap_w=jnp.where(enabled, scen.dr_cap_w, jnp.inf),
        cap_now_w=jnp.where(active, scen.dr_cap_w, jnp.inf),
        in_notice=in_notice)


def init_event_state(system: SystemConfig) -> T.EventState:
    """Everything healthy: every repair-complete time in the far past."""
    neg = -jnp.inf
    return T.EventState(
        node_down_until=jnp.full((system.n_nodes,), neg, jnp.float32),
        group_down_until=jnp.full((system.cooling.n_groups,), neg,
                                  jnp.float32),
        cell_down_until=jnp.full((system.cooling.n_tower_cells,), neg,
                                 jnp.float32),
        jobs_killed=jnp.float32(0.0), jobs_requeued=jnp.float32(0.0),
        energy_lost_j=jnp.float32(0.0), node_downtime_s=jnp.float32(0.0))


@functools.lru_cache(maxsize=None)
def _maps(system: SystemConfig):
    """Static topology maps: node -> CDU group, CDU group -> hall, tower
    cell -> hall. Cached as HOST numpy (trace-time constants at the use
    sites — caching jnp arrays here would leak tracers across jit
    boundaries)."""
    gid = np.asarray(group_ids(system.n_nodes, system.cooling.n_groups),
                     np.int32)
    hog = np.asarray(system.cooling.hall_of_group(), np.int32)
    cell_hall = np.repeat(np.arange(system.cooling.n_halls, dtype=np.int32),
                          system.cooling.cells_per_hall())
    return gid, hog, cell_hall


def _advance_masks(system: SystemConfig, ev: T.EventState, scen: T.Scenario,
                   t, step):
    """One step of the availability-mask process (shared by the in-engine
    ``apply_failures`` and the host-facing ``realize_masks`` oracle).

    Returns ``((node_until, group_until, cell_until),
    (unavail_node bool[N], group_down bool[G], cell_down bool[C]))``.
    """
    dt = system.dt
    gid, hog, _ = _maps(system)
    N, G = system.n_nodes, system.cooling.n_groups
    C, H = system.cooling.n_tower_cells, system.cooling.n_halls
    seed = jnp.round(jnp.asarray(scen.failure_seed, jnp.float32)) \
        .astype(jnp.int32)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kn, kg, kh, kc, krn, krg, krc = jax.random.split(key, 7)

    def p_of(rate):
        r = jnp.maximum(jnp.asarray(rate, jnp.float32), 0.0)
        return jnp.clip(-jnp.expm1(-r * dt), 0.0, 1.0)

    # independent per-entity draws: fail sets nest as a rate grows (same
    # uniforms, larger threshold), which is what makes downtime monotone
    fail_n = jax.random.uniform(kn, (N,)) < p_of(scen.node_fail_rate)
    p_grp = p_of(scen.cdu_fail_rate)
    fail_g = jax.random.uniform(kg, (G,)) < p_grp
    # correlated common-cause: one draw per hall, scaled by failure_corr;
    # on fire, every CDU group in the hall goes down together
    p_hall = jnp.clip(jnp.asarray(scen.failure_corr, jnp.float32),
                      0.0, 1.0) * p_grp
    fail_h = jax.random.uniform(kh, (H,)) < p_hall
    fail_g = fail_g | fail_h[hog]
    fail_c = jax.random.uniform(kc, (C,)) < p_of(scen.cell_fail_rate)

    rep = jnp.maximum(jnp.asarray(scen.repair_s, jnp.float32), 0.0)

    def until(old, fail, k, n):
        # max(old, ...) so a re-failure during repair extends the outage;
        # down_until never shrinks -> no resurrection before repair
        dur = rep * jax.random.exponential(k, (n,))
        return jnp.where(fail, jnp.maximum(old, t + dur), old)

    node_until = until(ev.node_down_until, fail_n, krn, N)
    grp_until = until(ev.group_down_until, fail_g, krg, G)
    cell_until = until(ev.cell_down_until, fail_c, krc, C)

    grp_down = t < grp_until
    cell_down = t < cell_until
    # a node is unavailable when itself down OR its CDU group is down
    unavail = (t < node_until) | grp_down[gid]
    return (node_until, grp_until, cell_until), (unavail, grp_down,
                                                 cell_down)


def apply_failures(cfg: EventConfig, system: SystemConfig,
                   table: T.JobTable, st: T.SimState, scen: T.Scenario
                   ) -> tuple[T.SimState, EventsNow]:
    """Engine phase (2b): draw this step's failures/repairs, kill jobs
    touching unavailable nodes, and update the availability node map.

    Down free nodes are marked ``-2`` in ``node_job`` so first-free
    placement (``resource_manager``) skips them; repaired nodes rejoin
    the ``-1`` free pool. Killed jobs are requeued (``cfg.requeue``) or
    dismissed, their realized start/end/progress reset and their accrued
    energy moved into the ``energy_lost_j`` (energy-not-served) ledger.
    """
    ev = st.events
    (nu, gu, cu), (unavail, grp_down, cell_down) = _advance_masks(
        system, ev, scen, st.t, st.step)
    _, _, cell_hall = _maps(system)
    H = system.cooling.n_halls

    # kill any RUNNING job with at least one node unavailable
    occupied = st.node_job >= 0
    owner = jnp.maximum(st.node_job, 0)
    hit = jnp.zeros((table.num_jobs,), jnp.int32).at[owner].max(
        (unavail & occupied).astype(jnp.int32)) > 0
    kill = hit & (st.jstate == T.RUNNING)
    n_kill = jnp.sum(kill.astype(jnp.float32))

    # release every node of a killed job, then flip availability states:
    # -2 hides a down free node from placement, repair returns it to -1
    node_job = jnp.where(occupied & kill[owner], -1, st.node_job)
    node_job = jnp.where(unavail & (node_job == -1), -2, node_job)
    node_job = jnp.where(~unavail & (node_job == -2), -1, node_job)
    free_count = jnp.sum((node_job == -1).astype(jnp.int32))

    jstate = jnp.where(kill, T.QUEUED if cfg.requeue else T.DISMISSED,
                       st.jstate)
    start = jnp.where(kill, jnp.inf, st.start)
    end = jnp.where(kill, jnp.inf, st.end)
    progress = jnp.where(kill, 0.0, st.progress)
    lost = jnp.sum(jnp.where(kill, st.jenergy, 0.0))
    jenergy = jnp.where(kill, 0.0, st.jenergy)

    nodes_down = jnp.sum(unavail.astype(jnp.float32))
    ev = T.EventState(
        node_down_until=nu, group_down_until=gu, cell_down_until=cu,
        jobs_killed=ev.jobs_killed + n_kill,
        jobs_requeued=ev.jobs_requeued + (n_kill if cfg.requeue else 0.0),
        energy_lost_j=ev.energy_lost_j + lost,
        node_downtime_s=ev.node_downtime_s + nodes_down * system.dt)
    st = dataclasses.replace(
        st, jstate=jstate, start=start, end=end, progress=progress,
        jenergy=jenergy, node_job=node_job, free_count=free_count,
        events=ev)
    cells_failed_hall = jnp.zeros((H,), jnp.float32).at[cell_hall].add(
        cell_down.astype(jnp.float32))
    now = EventsNow(cells_failed_hall=cells_failed_hall,
                    nodes_down=nodes_down, n_killed=n_kill,
                    groups_down=jnp.sum(grp_down.astype(jnp.float32)))
    return st, now


def realize_masks(system: SystemConfig, scen: T.Scenario, n_steps: int,
                  t0: float = 0.0) -> dict:
    """Host-facing oracle: realize the availability masks for ``n_steps``
    engine steps *without* the engine — a pure scan over the mask state
    only (no jobs, no cooling), using the exact per-step draw core the
    engine uses. The property battery (tests/test_events_properties.py)
    checks monotonicity / no-resurrection invariants against this.

    Returns numpy arrays: ``node_avail`` bool[T, N], ``group_down``
    bool[T, G], ``cell_down`` bool[T, C], ``nodes_down`` f32[T].
    """
    ev0 = init_event_state(system)

    def body(carry, _):
        ev, t, step = carry
        (nu, gu, cu), (unavail, grp_down, cell_down) = _advance_masks(
            system, ev, scen, t, step)
        ev = dataclasses.replace(ev, node_down_until=nu,
                                 group_down_until=gu, cell_down_until=cu)
        out = (~unavail, grp_down, cell_down,
               jnp.sum(unavail.astype(jnp.float32)))
        return (ev, t + system.dt, step + 1), out

    carry0 = (ev0, jnp.float32(t0), jnp.int32(0))
    _, (avail, gdown, cdown, ndown) = jax.lax.scan(
        body, carry0, None, length=int(n_steps))
    return {"node_avail": np.asarray(avail),
            "group_down": np.asarray(gdown),
            "cell_down": np.asarray(cdown),
            "nodes_down": np.asarray(ndown)}
