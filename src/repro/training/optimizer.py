"""AdamW with global-norm clipping, cosine schedule, and configurable moment
dtype (bf16 moments for the 400B MoE so optimizer state fits the pod —
no optax in the image). Implemented directly as pure pytree ops
so the optimizer state inherits parameter shardings leaf-for-leaf.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000
    moment_dtype: Any = jnp.float32


def init(cfg: AdamWConfig, params) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree_util.tree_map(z, params),
                      jax.tree_util.tree_map(z, params))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup) /
                    jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return (new_p.astype(p.dtype), m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda o: o[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda o: o[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": lr}
