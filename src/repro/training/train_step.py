"""Training / serving step builders with full pjit sharding.

``make_train_step`` returns (step_fn, param_shardings, opt_shardings,
batch_shardings) ready to ``jax.jit(...).lower(...).compile()`` — the same
path the multi-pod dry-run uses.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig, split_tree
from repro.models.zoo import get_api
from repro.parallel import sharding as shd
from repro.training import optimizer as opt


def batch_struct(cfg: ArchConfig, seq_len: int, global_batch: int,
                 kind: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run pattern:
    weak-type-correct, shardable, no device allocation)."""
    B, S = global_batch, seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.family == "encdec":
        # seq_len is the decoder target length; the audio encoder sees
        # seq_len // 4 frames (w2v-BERT stride stub)
        return {"tokens": sds((B, S), jnp.int32),
                "frames": sds((B, max(S // 4, 8), cfg.d_model), jnp.float32)}
    if cfg.family == "vlm":
        n_text = max(S - cfg.frontend_tokens, 8)
        return {"tokens": sds((B, n_text), jnp.int32),
                "patches": sds((B, cfg.frontend_tokens, cfg.d_model),
                               jnp.float32)}
    return {"tokens": sds((B, S), jnp.int32)}


def make_train_step(cfg: ArchConfig, mesh: Mesh, seq_len: int,
                    global_batch: int,
                    opt_cfg: opt.AdamWConfig | None = None,
                    accum_steps: int = 1):
    """Returns (train_step, shardings dict, structs dict).

    ``accum_steps > 1`` enables gradient accumulation: the global batch is
    split into microbatches scanned sequentially, trading step latency for
    activation memory — the standard way to fit long-sequence training when
    remat alone is not enough."""
    api = get_api(cfg)
    opt_cfg = opt_cfg or opt.AdamWConfig(moment_dtype=cfg.moment_dtype)
    rules = shd.rules_for(cfg, mesh, "train")
    shd.set_batch_axes(shd._filter_axis(mesh, rules["batch"]))
    assert global_batch % accum_steps == 0, (global_batch, accum_steps)

    # -- abstract param/opt trees (no allocation) ---------------------------
    def _init_split(key):
        vals, _ = split_tree(api.init(key))
        return vals
    params_struct = jax.eval_shape(_init_split, jax.random.PRNGKey(0))
    # logical axes are concrete metadata captured during abstract tracing
    axes_tree = _axes_tree(api)

    param_shardings = jax.tree_util.tree_map(
        lambda axes, sds: NamedSharding(
            mesh, shd.spec_for(mesh, rules, axes, sds.shape)),
        axes_tree, params_struct,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    opt_struct = jax.eval_shape(
        functools.partial(opt.init, opt_cfg), params_struct)
    opt_shardings = opt.AdamWState(
        NamedSharding(mesh, P()),
        jax.tree_util.tree_map(lambda s: s, param_shardings),
        jax.tree_util.tree_map(lambda s: s, param_shardings))
    bstruct = batch_struct(cfg, seq_len, global_batch, "train")
    bshard = shd.batch_shardings(mesh, rules, bstruct, global_batch)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            def loss_fn(p):
                return api.loss(p, batch)
            loss, grads = jax.value_and_grad(loss_fn)(params)
        else:
            # microbatch scan: grads accumulate in f32, activations live
            # only for one microbatch at a time
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def one(carry, mb):
                acc, loss_acc = carry
                l, g = jax.value_and_grad(lambda p: api.loss(p, mb))(params)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(one, (zeros, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
        new_params, new_opt, metrics = opt.apply(opt_cfg, grads, opt_state,
                                                 params)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    shardings = dict(params=param_shardings, opt=opt_shardings, batch=bshard)
    structs = dict(params=params_struct, opt=opt_struct, batch=bstruct)
    jitted = jax.jit(
        train_step,
        in_shardings=(param_shardings, opt_shardings, bshard),
        out_shardings=(param_shardings, opt_shardings, None),
        donate_argnums=(0, 1))
    return jitted, shardings, structs


def _axes_tree(api):
    """Extract the logical-axes tree without allocating real params: run init
    under eval_shape but capture axes via the Annotated wrappers, which are
    constructed with concrete axis tuples during tracing."""
    collected = {}

    def probe(key):
        ann = api.init(key)
        vals, axes = split_tree(ann)
        collected["axes"] = axes
        return vals

    jax.eval_shape(probe, jax.random.PRNGKey(0))
    return collected["axes"]


# ---------------------------------------------------------------------------
# Serving steps.
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ArchConfig, mesh: Mesh, seq_len: int,
                      global_batch: int, max_len: int | None = None):
    api = get_api(cfg)
    rules = shd.rules_for(cfg, mesh, "prefill")
    shd.set_batch_axes(shd._filter_axis(mesh, rules["batch"]))
    max_len = max_len or seq_len

    axes_tree = _axes_tree(api)

    def _init_split(key):
        vals, _ = split_tree(api.init(key))
        return vals
    params_struct = jax.eval_shape(_init_split, jax.random.PRNGKey(0))
    param_shardings = jax.tree_util.tree_map(
        lambda axes, sds: NamedSharding(
            mesh, shd.spec_for(mesh, rules, axes, sds.shape)),
        axes_tree, params_struct,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

    bstruct = batch_struct(cfg, seq_len, global_batch, "prefill")
    bshard = shd.batch_shardings(mesh, rules, bstruct, global_batch)

    def prefill(params, batch):
        return api.prefill(params, batch, max_len)

    jitted = jax.jit(prefill, in_shardings=(param_shardings, bshard))
    structs = dict(params=params_struct, batch=bstruct)
    return jitted, dict(params=param_shardings, batch=bshard), structs


def make_decode_step(cfg: ArchConfig, mesh: Mesh, seq_len: int,
                     global_batch: int, kind: str = "decode"):
    """One-token serve step with a KV/state cache of length ``seq_len``."""
    api = get_api(cfg)
    rules = shd.rules_for(cfg, mesh,
                          "long_decode" if kind == "long_decode" else
                          "decode")
    axes_tree = _axes_tree(api)

    def _init_split(key):
        vals, _ = split_tree(api.init(key))
        return vals
    params_struct = jax.eval_shape(_init_split, jax.random.PRNGKey(0))
    param_shardings = jax.tree_util.tree_map(
        lambda axes, sds: NamedSharding(
            mesh, shd.spec_for(mesh, rules, axes, sds.shape)),
        axes_tree, params_struct,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

    state_struct = jax.eval_shape(
        lambda: api.init_cache(global_batch, seq_len))
    state_shardings = shd.state_shardings(mesh, rules, state_struct, cfg,
                                          global_batch, kind)
    tok_struct = jax.ShapeDtypeStruct((global_batch,), jnp.int32)
    tok_shard = shd.batch_shardings(mesh, rules, tok_struct, global_batch)

    def decode(params, tokens, state):
        return api.decode(params, tokens, state)

    jitted = jax.jit(decode,
                     in_shardings=(param_shardings, tok_shard,
                                   state_shardings),
                     out_shardings=(None, state_shardings),
                     donate_argnums=(2,))
    structs = dict(params=params_struct, tokens=tok_struct,
                   state=state_struct)
    return jitted, dict(params=param_shardings, tokens=tok_shard,
                        state=state_shardings), structs
