"""Run manifests + NDJSON event log (the flight recorder proper).

``RunRecorder`` is the per-invocation recorder: it mints a run id,
appends lifecycle events (compile start/end, scan start/end, checkpoint,
bridge respawn, ...) to an NDJSON event log as they happen, and writes
the schema-versioned run manifest (``repro.obs.schema``) when the run
finalizes — so a crash mid-run still leaves the event log behind.

The manifest's identity fields reuse the PR 5 transport digests
(``core.transport.system_digest`` / ``job_digest``): two runs of the same
(system, jobs) produce byte-identical digests, which is what makes the
manifest a *reproducibility* record and not just a log line.

Typical CLI wiring (``launch/simulate.py --manifest run.json --events
run.ndjson``)::

    rec = RunRecorder(manifest_path="run.json", events_path="run.ndjson")
    rec.begin(command="simulate", argv=argv, system=sys_, jobs=js,
              scenario={"policy": "fcfs"}, seed=0)
    rec.event("run_start")
    ... run, with obs.timing spans mirrored via SpanTimer(listener=...) ...
    rec.finalize(spans=timer.summary(), counters={...}, wall_s=wall)
"""
from __future__ import annotations

import json
import pathlib
import platform
import secrets
import subprocess
import time
from typing import IO, Optional

from repro.obs import schema


def _git_sha() -> Optional[str]:
    """Best-effort HEAD sha of the working tree; None outside a repo."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=5.0)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and len(sha) == 40 else None


def runtime_versions() -> dict:
    """python/jax/numpy versions + the active jax backend and device."""
    import numpy as np
    versions = {"python": platform.python_version(),
                "numpy": np.__version__,
                "jax": None, "backend": None, "device": None}
    try:
        import jax
        versions["jax"] = jax.__version__
        versions["backend"] = jax.default_backend()
        dev = jax.devices()[0]
        versions["device"] = getattr(dev, "device_kind", str(dev))
    except Exception:   # jax not importable / no devices: record the gap
        pass
    return versions


def build_manifest(system, command: str, argv: list, scenario: dict,
                   seed: Optional[int] = None, jobs=None,
                   run_id: Optional[str] = None,
                   git_sha: Optional[str] = "auto",
                   created_unix: Optional[float] = None,
                   extra: Optional[dict] = None) -> dict:
    """Assemble a schema-valid run manifest (no I/O besides git).

    Args:
      system: ``SystemConfig`` — digested via ``transport.system_digest``.
      command: invocation kind ("simulate" | "sweep" | "train" | ...).
      argv: the CLI argument list, verbatim.
      scenario: what-if knobs of the run (policies, offsets, ...).
      seed: RNG seed, when the run has one.
      jobs: optional ``JobSet`` — digested via ``transport.job_digest``.
      run_id: externally minted id (default: fresh 16-hex token).
      git_sha: "auto" resolves HEAD; pass None/str to skip/pin.
      created_unix: epoch seconds (default: now; injectable for tests).
      extra: optional JSON-able dict merged in at the top level (e.g.
        ``{"env_preset": launch.env.report()}``); the schema only pins
        required fields, so extra keys validate and round-trip.
    """
    from repro.core import transport as tr

    manifest = {
        "schema_version": schema.SCHEMA_VERSION,
        "kind": schema.KIND_MANIFEST,
        "run_id": run_id or secrets.token_hex(8),
        "command": str(command),
        "argv": [str(a) for a in argv],
        "created_unix": float(time.time() if created_unix is None
                              else created_unix),
        "system": {
            "name": system.name,
            "n_nodes": int(system.n_nodes),
            "dt": float(system.dt),
            "n_halls": int(system.cooling.n_halls),
            "digest": tr.system_digest(system),
        },
        "jobs": {"n_jobs": (len(jobs) if jobs is not None else 0),
                 "digest": (tr.job_digest(jobs) if jobs is not None
                            else None)},
        "scenario": schema.jsonable(scenario),
        "seed": None if seed is None else int(seed),
        "versions": runtime_versions(),
        "git_sha": _git_sha() if git_sha == "auto" else git_sha,
    }
    if extra:
        manifest.update(schema.jsonable(extra))
    return schema.validate_manifest(manifest)


class RunRecorder:
    """Per-run flight recorder: event log now, manifest at finalize."""

    def __init__(self, manifest_path=None, events_path=None,
                 run_id: Optional[str] = None,
                 clock=time.time):
        self.manifest_path = manifest_path
        self.events_path = events_path
        self.run_id = run_id or secrets.token_hex(8)
        self.clock = clock
        self.manifest: Optional[dict] = None
        self.n_events = 0
        self._efile: Optional[IO[bytes]] = None

    # -- lifecycle ----------------------------------------------------------
    def begin(self, system, command: str, argv: list, scenario: dict,
              seed: Optional[int] = None, jobs=None,
              extra: Optional[dict] = None) -> dict:
        """Build the base manifest up front (identity is known at start;
        spans/counters arrive at ``finalize``)."""
        self.manifest = build_manifest(
            system, command=command, argv=argv, scenario=scenario,
            seed=seed, jobs=jobs, run_id=self.run_id, extra=extra)
        return self.manifest

    def event(self, event: str, **fields) -> dict:
        """Append one lifecycle event to the NDJSON log (flushed line by
        line, so a killed run keeps everything recorded so far)."""
        frame = schema.event_frame(self.run_id, self.n_events,
                                   self.clock(), event, **fields)
        self.n_events += 1
        if self.events_path is not None:
            from repro.core.transport import write_frame
            if self._efile is None:
                pathlib.Path(self.events_path).parent.mkdir(
                    parents=True, exist_ok=True)
                self._efile = open(self.events_path, "wb")
            write_frame(self._efile, frame)
        return frame

    def span_listener(self, what: str, fields: dict) -> None:
        """Adapter for ``SpanTimer(listener=...)``: mirrors every span
        start/end into the event log (compile start/end, scan start/end
        arrive this way)."""
        self.event(what, **fields)

    def finalize(self, spans: Optional[dict] = None,
                 counters: Optional[dict] = None, **extra) -> Optional[dict]:
        """Attach spans/counters + extras, write the manifest, close."""
        if self.manifest is not None:
            if spans is not None:
                self.manifest["spans"] = schema.jsonable(spans)
            if counters is not None:
                self.manifest["counters"] = schema.jsonable(counters)
            self.manifest["n_events"] = self.n_events
            for k, v in extra.items():
                self.manifest[k] = schema.jsonable(v)
            if self.manifest_path is not None:
                p = pathlib.Path(self.manifest_path)
                p.parent.mkdir(parents=True, exist_ok=True)
                p.write_text(json.dumps(self.manifest, indent=1,
                                        sort_keys=True) + "\n")
        self.close()
        return self.manifest

    def close(self) -> None:
        if self._efile is not None:
            self._efile.close()
            self._efile = None

    def __enter__(self) -> "RunRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def load_manifest(path) -> dict:
    """Read + schema-validate a manifest JSON from disk."""
    manifest = json.loads(pathlib.Path(path).read_text())
    return schema.validate_manifest(manifest)
