"""Logging-based run reporter: progress to stderr, results to stdout.

The launch CLIs (`simulate`, `train`, `dryrun`) historically printed
everything with bare ``print()``, so machine consumers had to scrape
progress noise out of stdout. The reporter splits the two streams:

* **progress** (`.info` / `.debug`) goes through the stdlib ``logging``
  machinery to **stderr** and is silenced by ``--quiet``;
* **results** (`.result` / `.result_json`) are the program's actual
  output and go to **stdout** — human-formatted by default, or exactly
  one JSON document under ``--json`` (clean stdout for pipelines).

``Reporter.from_flags(args)`` is the one-liner the CLIs use after
``add_output_flags(parser)`` declared ``--quiet`` / ``--json``.
"""
from __future__ import annotations

import json
import logging
import sys
from typing import Optional

from repro.obs import schema

LOGGER_NAME = "repro"


def get_logger(name: str = LOGGER_NAME) -> logging.Logger:
    """The shared ``repro`` logger, initialized to stderr on first use."""
    logger = logging.getLogger(name)
    root = logging.getLogger(LOGGER_NAME)
    if not root.handlers:
        h = logging.StreamHandler(sys.stderr)
        h.setFormatter(logging.Formatter("%(message)s"))
        root.addHandler(h)
        root.setLevel(logging.INFO)
        root.propagate = False
    return logger


def add_output_flags(parser) -> None:
    """Declare the shared output-control flags on an argparse parser."""
    parser.add_argument("--quiet", action="store_true",
                        help="suppress progress output (stderr)")
    parser.add_argument("--json", action="store_true",
                        help="emit results as a single JSON document on "
                             "stdout (implies machine-clean output)")


class Reporter:
    """Two-channel run output: progress (stderr/logging) vs results
    (stdout). Under ``json_mode`` results accumulate and ``flush_json``
    prints them as one document."""

    def __init__(self, quiet: bool = False, json_mode: bool = False,
                 stream=None):
        self.quiet = bool(quiet)
        self.json_mode = bool(json_mode)
        self.stream = stream if stream is not None else sys.stdout
        self.logger = get_logger()
        logging.getLogger(LOGGER_NAME).setLevel(
            logging.WARNING if self.quiet else logging.INFO)
        self._doc: dict = {}

    @classmethod
    def from_flags(cls, args) -> "Reporter":
        return cls(quiet=getattr(args, "quiet", False),
                   json_mode=getattr(args, "json", False))

    # -- progress channel (stderr) -----------------------------------------
    def info(self, msg: str, *fmt) -> None:
        self.logger.info(msg, *fmt)

    def warn(self, msg: str, *fmt) -> None:
        self.logger.warning(msg, *fmt)

    # -- results channel (stdout) ------------------------------------------
    def result(self, text: str, key: Optional[str] = None, value=None) -> None:
        """A human-readable result block; under ``--json`` the text is
        dropped and (key, value) lands in the JSON document instead."""
        if self.json_mode:
            if key is not None:
                self._doc[key] = schema.jsonable(value)
        else:
            print(text, file=self.stream)

    def result_json(self, key: str, value) -> None:
        """A result that only exists in the JSON document (no text)."""
        if self.json_mode:
            self._doc[key] = schema.jsonable(value)

    def flush_json(self) -> None:
        """Print the accumulated JSON document (no-op outside --json)."""
        if self.json_mode:
            print(json.dumps(self._doc, indent=1, sort_keys=True),
                  file=self.stream)

    def log_fn(self):
        """A ``Callable[[str], None]`` view for APIs that take ``log=``
        (e.g. ``ml.train.train``)."""
        return self.logger.info
