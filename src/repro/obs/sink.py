"""Streaming metrics sink: StepRecord telemetry → NDJSON frames.

Converts the engine's per-interval ``StepRecord`` history into
``obs.schema.metrics_frame`` NDJSON frames — power, PUE, utilization,
queue depth, per-hall basin temperatures — and writes them to a file or
a listening socket, reusing the PR 5 transport framing
(``core.transport.write_frame``, same ``MAX_FRAME_BYTES`` cap and
versioned envelopes as the scheduler wire). This is the dashboard-ready
stream for twin-as-a-service: a consumer reads lines of JSON, no
repro import required.

Target syntax (``--metrics`` on the CLIs):

* ``out.ndjson`` (any plain path) — append-less truncating file write;
* ``tcp:host:port`` — dial a TCP listener and stream frames to it;
* ``unix:/path/sock`` — same over a Unix-domain socket.

Note ``transport.parse_address`` is *not* reused for classification: it
treats any string containing "/" as AF_UNIX, which would eat relative
file paths. Here the rule is explicit: a ``tcp:``/``unix:`` prefix means
socket, anything else is a file.
"""
from __future__ import annotations

import pathlib
import socket
from typing import IO, Iterator, Optional

import numpy as np

from repro.obs import schema

# StepRecord scalar fields streamed per interval (field name -> frame key)
SCALAR_FIELDS = (
    "power_it", "power_loss", "power_cooling", "power_total", "pue",
    "util", "n_queued", "n_running", "throttle_frac", "cap_w",
    "t_tower_return", "t_basin", "t_supply_max", "t_wetbulb",
    "emissions_kg", "energy_cost", "nodes_down", "n_killed",
)
# per-hall vector fields (f32[H] per step)
HALL_FIELDS = ("power_it_hall", "t_basin_hall", "t_supply_max_hall",
               "cells_online")


class MetricsSink:
    """Writes schema-versioned NDJSON frames to a file or socket.

    One sink per run; ``emit`` takes an already-built frame dict so the
    recorder/CLI can interleave metrics and summary frames on the same
    wire. Frames are validated on the way out — a producer bug fails
    loudly at the twin, not as a consumer parse error.
    """

    def __init__(self, target: str, connect_timeout_s: float = 10.0):
        self.target = str(target)
        self.n_frames = 0
        self._sock: Optional[socket.socket] = None
        if self.target.startswith(("tcp:", "unix:")):
            if self.target.startswith("unix:"):
                family, sockaddr = socket.AF_UNIX, self.target[len("unix:"):]
            else:
                rest = self.target[len("tcp:"):]
                host, _, port = rest.rpartition(":")
                if not host or not port.isdigit():
                    raise ValueError(f"metrics target must be tcp:host:port,"
                                     f" got {self.target!r}")
                family, sockaddr = socket.AF_INET, (host, int(port))
            self._sock = socket.socket(family, socket.SOCK_STREAM)
            self._sock.settimeout(connect_timeout_s)
            self._sock.connect(sockaddr)
            self._file: IO[bytes] = self._sock.makefile("wb")
        else:
            p = pathlib.Path(self.target)
            if p.parent != pathlib.Path(""):
                p.parent.mkdir(parents=True, exist_ok=True)
            self._file = open(p, "wb")

    def emit(self, frame: dict) -> None:
        from repro.core.transport import write_frame
        write_frame(self._file, schema.validate_frame(frame))
        self.n_frames += 1

    def close(self) -> None:
        if self._file is not None:
            try:
                self._file.close()
            finally:
                self._file = None  # type: ignore[assignment]
        if self._sock is not None:
            self._sock.close()
            self._sock = None

    def __enter__(self) -> "MetricsSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def history_frames(run_id: str, hist, label: Optional[str] = None,
                   seq0: int = 0) -> Iterator[dict]:
    """Yield one metrics frame per simulated interval of ``hist``.

    ``hist`` is the engine's ``StepRecord`` pytree with a leading time
    axis (the ``ys`` of the scan); each frame carries the scalar
    telemetry plus the per-hall vectors for that interval. Non-finite
    values (e.g. the uncapped ``cap_w = +inf``) arrive as ``null``.
    """
    t = np.asarray(hist.t, np.float64)
    scalars = {k: np.asarray(getattr(hist, k), np.float64)
               for k in SCALAR_FIELDS}
    halls = {k: np.asarray(getattr(hist, k), np.float64)
             for k in HALL_FIELDS}
    for i in range(t.shape[0]):
        data = {k: float(v[i]) for k, v in scalars.items()}
        data.update({k: v[i].tolist() for k, v in halls.items()})
        yield schema.metrics_frame(run_id, seq0 + i, float(t[i]), data,
                                   label=label)


def stream_history(sink: MetricsSink, run_id: str, system, table, final,
                   hist, label: Optional[str] = None,
                   summary: Optional[dict] = None) -> int:
    """Stream a whole run: per-interval frames + one summary frame.

    ``summary`` defaults to ``stats.summarize`` over the run (the same
    reductions the CLI prints), so a dashboard tailing the stream gets
    the final scorecard on the same wire. Returns the frame count.
    """
    n = 0
    for frame in history_frames(run_id, hist, label=label):
        sink.emit(frame)
        n += 1
    if summary is None:
        from repro.core import stats as stats_mod
        summary = stats_mod.summarize(system, table, final, hist)
    sink.emit(schema.summary_frame(run_id, summary, label=label))
    return n + 1


def read_frames(path) -> list[dict]:
    """Load and validate every NDJSON frame from a file (test/consumer
    convenience; the stream itself needs no repro code to parse)."""
    from repro.core.transport import read_frame
    frames = []
    with open(path, "rb") as f:
        while True:
            try:
                frames.append(schema.validate_frame(read_frame(f)))
            except ConnectionError:   # clean EOF
                break
    return frames
