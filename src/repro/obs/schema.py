"""Flight-recorder wire schema: run manifests + NDJSON frame envelopes.

Everything the observability layer writes is schema-versioned so a
dashboard (or the CI trajectory gate) can evolve independently of the
twin. Two artifact shapes:

* **Run manifest** — one JSON document per ``simulate``/``sweep``/
  ``train`` invocation: what ran (system/topology + job digests, scenario
  knobs, seed), on what (jax/backend versions, git sha), and how (timing
  spans, bridge/sweep-cache counters). ``validate_manifest`` is the
  contract a consumer can rely on.
* **NDJSON frames** — the event log and the metrics stream are
  newline-delimited JSON frames reusing the PR 5 transport framing
  (``core.transport.write_frame`` / ``read_frame`` / MAX_FRAME_BYTES),
  so the same codec that carries scheduler envelopes carries telemetry —
  the dashboard-ready wire for twin-as-a-service.

Every frame carries ``v`` (== ``SCHEMA_VERSION``) and ``kind`` (one of
``FRAME_KINDS``). Non-finite floats are not JSON: ``jsonable`` maps
NaN/±inf to ``null`` so frames always survive a strict JSON parser.
"""
from __future__ import annotations

import math
from typing import Iterable

import numpy as np

SCHEMA_VERSION = 1

KIND_MANIFEST = "run_manifest"
KIND_EVENT = "event"
KIND_METRICS = "metrics"
KIND_SUMMARY = "summary"
FRAME_KINDS = (KIND_EVENT, KIND_METRICS, KIND_SUMMARY)

# manifest fields a consumer may rely on (name -> required type(s))
MANIFEST_REQUIRED = {
    "schema_version": int,
    "kind": str,
    "run_id": str,
    "command": str,           # "simulate" | "sweep" | "train" | ...
    "argv": list,
    "created_unix": (int, float),
    "system": dict,           # name, n_nodes, dt, n_halls, digest
    "jobs": dict,             # n_jobs, digest (digest may be None)
    "scenario": dict,         # the what-if knobs of the run
    "seed": (int, type(None)),
    "versions": dict,         # python, jax, numpy, backend, device
    "git_sha": (str, type(None)),
}
SYSTEM_REQUIRED = ("name", "n_nodes", "dt", "n_halls", "digest")
VERSIONS_REQUIRED = ("python", "jax", "numpy", "backend")


class SchemaError(ValueError):
    """A manifest or frame violates the flight-recorder schema."""


def jsonable(x):
    """Recursively convert ``x`` to strict-JSON-safe python values.

    numpy scalars/arrays become native lists, non-finite floats become
    ``None`` (strict JSON has no NaN/Infinity — and the engine's
    telemetry legitimately contains +inf, e.g. the uncapped ``cap_w``).
    """
    if isinstance(x, (np.floating, float)):
        f = float(x)
        return f if math.isfinite(f) else None
    if isinstance(x, (np.integer, int)) and not isinstance(x, bool):
        return int(x)
    if isinstance(x, np.ndarray):
        return [jsonable(v) for v in x.tolist()]
    if isinstance(x, dict):
        return {str(k): jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonable(v) for v in x]
    return x


# ---------------------------------------------------------------------------
# Frame constructors.
# ---------------------------------------------------------------------------
def event_frame(run_id: str, seq: int, t_wall: float, event: str,
                **fields) -> dict:
    """One lifecycle-event NDJSON frame (compile start/end, checkpoint,
    respawn, ...). ``t_wall`` is host wall-clock seconds (epoch)."""
    return {"v": SCHEMA_VERSION, "kind": KIND_EVENT, "run_id": run_id,
            "seq": int(seq), "t_wall": float(t_wall), "event": str(event),
            **jsonable(fields)}


def metrics_frame(run_id: str, seq: int, t_sim: float, data: dict,
                  label: str | None = None) -> dict:
    """One per-interval metrics NDJSON frame.

    ``t_sim`` is simulated seconds; ``data`` carries the StepRecord
    telemetry for that interval (scalars and per-hall lists); ``label``
    tags the scenario in a sweep (e.g. ``"fcfs:easy"``)."""
    frame = {"v": SCHEMA_VERSION, "kind": KIND_METRICS, "run_id": run_id,
             "seq": int(seq), "t_sim": float(t_sim),
             "data": jsonable(data)}
    if label is not None:
        frame["label"] = str(label)
    return frame


def summary_frame(run_id: str, data: dict, label: str | None = None) -> dict:
    """End-of-run summary frame (the ``stats.summarize`` reductions)."""
    frame = {"v": SCHEMA_VERSION, "kind": KIND_SUMMARY, "run_id": run_id,
             "data": jsonable(data)}
    if label is not None:
        frame["label"] = str(label)
    return frame


# ---------------------------------------------------------------------------
# Validation.
# ---------------------------------------------------------------------------
def validate_frame(frame: dict) -> dict:
    """Check the envelope of an NDJSON frame; returns it unchanged."""
    if not isinstance(frame, dict):
        raise SchemaError(f"frame must be a JSON object, got "
                          f"{type(frame).__name__}")
    if frame.get("v") != SCHEMA_VERSION:
        raise SchemaError(f"frame schema version mismatch: "
                          f"{frame.get('v')!r} != {SCHEMA_VERSION}")
    if frame.get("kind") not in FRAME_KINDS:
        raise SchemaError(f"unknown frame kind {frame.get('kind')!r}; "
                          f"valid: {', '.join(FRAME_KINDS)}")
    if not isinstance(frame.get("run_id"), str):
        raise SchemaError("frame missing run_id")
    return frame


def _check_fields(obj: dict, required: Iterable[str], where: str) -> None:
    missing = [k for k in required if k not in obj]
    if missing:
        raise SchemaError(f"{where} missing field(s): "
                          f"{', '.join(sorted(missing))}")


def validate_manifest(manifest: dict) -> dict:
    """Check a run manifest against the schema; returns it unchanged.

    Raises ``SchemaError`` naming every missing/ill-typed field, so a
    consumer failure points at the producer bug, not a KeyError."""
    if not isinstance(manifest, dict):
        raise SchemaError(f"manifest must be a JSON object, got "
                          f"{type(manifest).__name__}")
    errors = []
    for name, types in MANIFEST_REQUIRED.items():
        if name not in manifest:
            errors.append(f"missing field {name!r}")
        elif not isinstance(manifest[name], types):
            errors.append(f"field {name!r} has type "
                          f"{type(manifest[name]).__name__}")
    if errors:
        raise SchemaError("invalid manifest: " + "; ".join(errors))
    if manifest["schema_version"] != SCHEMA_VERSION:
        raise SchemaError(f"manifest schema version mismatch: "
                          f"{manifest['schema_version']} != "
                          f"{SCHEMA_VERSION}")
    if manifest["kind"] != KIND_MANIFEST:
        raise SchemaError(f"manifest kind must be {KIND_MANIFEST!r}, got "
                          f"{manifest['kind']!r}")
    _check_fields(manifest["system"], SYSTEM_REQUIRED, "manifest.system")
    _check_fields(manifest["versions"], VERSIONS_REQUIRED,
                  "manifest.versions")
    _check_fields(manifest["jobs"], ("n_jobs", "digest"), "manifest.jobs")
    return manifest
