"""Observability layer (flight recorder) for the digital twin.

Four cooperating pieces, all opt-in (zero overhead when unused):

* ``obs.schema``   — schema-versioned manifest + NDJSON frame formats;
* ``obs.recorder`` — per-run manifest writer + lifecycle event log;
* ``obs.sink``     — StepRecord telemetry → NDJSON metrics stream
  (file or socket, PR 5 transport framing);
* ``obs.timing``   — span timer the engine/trainer consult for
  compile-vs-execute phase timing, plus the bridge's latency histogram;
* ``obs.reporter`` — logging-based CLI output (progress → stderr,
  results → stdout, ``--quiet`` / ``--json``).

See ``docs/observability.md`` for the full formats and workflows.
"""
from repro.obs import schema, timing            # noqa: F401
from repro.obs.recorder import RunRecorder, build_manifest, load_manifest  # noqa: F401
from repro.obs.reporter import Reporter, add_output_flags, get_logger  # noqa: F401
from repro.obs.sink import MetricsSink, history_frames, read_frames, stream_history  # noqa: F401
from repro.obs.timing import LatencyHistogram, SpanTimer, current, maybe_span, use  # noqa: F401
