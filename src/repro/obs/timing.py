"""Phase timing spans + latency histograms (the flight recorder's clock).

A ``SpanTimer`` records named wall-clock spans (``with timer.span("engine
.compile"): ...``) with arbitrary metadata and aggregates them into a
JSON-able summary for the run manifest (``repro.obs.recorder``). The
engine consults the *active* timer (``current()``): when one is installed
via ``use(timer)``, ``engine.simulate`` / ``engine.simulate_static``
split their jit **compile** phase from **execute** (AOT lower+compile, so
the two phases are separately observable instead of fused into the first
call), and ``ml.train`` wraps each generation. With no active timer the
hot paths are untouched.

``LatencyHistogram`` is the fixed-bucket (log-spaced) histogram behind
the external bridge's per-poll latency counters
(``core.external.SchedulerBridge``).

All durations in seconds.
"""
from __future__ import annotations

import contextlib
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class Span:
    """One completed (or in-flight) timed phase."""
    name: str
    t_start: float                 # clock() at entry (s)
    dur_s: float = 0.0             # filled at exit
    meta: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        d = {"name": self.name, "t_start": self.t_start,
             "dur_s": self.dur_s}
        if self.meta:
            d["meta"] = dict(self.meta)
        return d


class SpanTimer:
    """Collects named wall-clock spans and event counters.

    ``clock`` is injectable for deterministic tests/doctests (any
    zero-arg callable returning seconds). ``listener`` (optional) is
    called with an event dict at every span start/end — the hook the run
    recorder uses to mirror phase boundaries into the NDJSON event log.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 listener: Optional[Callable[[str, dict], None]] = None):
        self.clock = clock
        self.listener = listener
        self.spans: List[Span] = []
        self.counts: Dict[str, int] = {}

    @contextlib.contextmanager
    def span(self, name: str, **meta):
        """Time a phase; the span is recorded even if the body raises."""
        sp = Span(name=name, t_start=self.clock(), meta=meta)
        if self.listener is not None:
            self.listener("span_start", {"span": name, **meta})
        try:
            yield sp
        finally:
            sp.dur_s = self.clock() - sp.t_start
            self.spans.append(sp)
            if self.listener is not None:
                self.listener("span_end",
                              {"span": name, "dur_s": sp.dur_s, **meta})

    def count(self, name: str, n: int = 1) -> None:
        """Bump a named event counter (e.g. a cache hit)."""
        self.counts[name] = self.counts.get(name, 0) + n

    def summary(self) -> dict:
        """Aggregate spans by name: {name: {count, total_s, max_s}} plus
        the raw event counters — the shape the manifest embeds."""
        agg: Dict[str, dict] = {}
        for sp in self.spans:
            a = agg.setdefault(sp.name,
                               {"count": 0, "total_s": 0.0, "max_s": 0.0})
            a["count"] += 1
            a["total_s"] += sp.dur_s
            a["max_s"] = max(a["max_s"], sp.dur_s)
        return {"spans": agg, "counters": dict(self.counts)}


# ---------------------------------------------------------------------------
# Active-timer registry (what the engine consults).
# ---------------------------------------------------------------------------
_local = threading.local()


def current() -> Optional[SpanTimer]:
    """The timer installed by the innermost ``use()`` block, or None."""
    return getattr(_local, "timer", None)


@contextlib.contextmanager
def use(timer: SpanTimer):
    """Install ``timer`` as the active span timer for this thread."""
    prev = current()
    _local.timer = timer
    try:
        yield timer
    finally:
        _local.timer = prev


@contextlib.contextmanager
def maybe_span(name: str, **meta):
    """Span on the active timer if one is installed; no-op otherwise."""
    t = current()
    if t is None:
        yield None
    else:
        with t.span(name, **meta) as sp:
            yield sp


# ---------------------------------------------------------------------------
# Latency histogram (bridge poll counters).
# ---------------------------------------------------------------------------
class LatencyHistogram:
    """Fixed log-spaced latency histogram: 100 µs .. 100 s + overflow.

    Monotonic counters only (record / merge); ``summary()`` is JSON-able
    so the external bridge can surface its per-poll latency distribution
    in the run manifest and in ``fig7_external`` rows.
    """

    EDGES = (1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0)  # upper edges (s)

    def __init__(self):
        self.counts = [0] * (len(self.EDGES) + 1)  # last = overflow
        self.n = 0
        self.total_s = 0.0
        self.min_s = float("inf")
        self.max_s = 0.0

    def record(self, dur_s: float) -> None:
        self.n += 1
        self.total_s += dur_s
        self.min_s = min(self.min_s, dur_s)
        self.max_s = max(self.max_s, dur_s)
        for i, edge in enumerate(self.EDGES):
            if dur_s <= edge:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def summary(self) -> dict:
        buckets = {f"le_{e:g}s": c for e, c in zip(self.EDGES, self.counts)}
        buckets["overflow"] = self.counts[-1]
        return {"count": self.n, "total_s": self.total_s,
                "min_s": self.min_s if self.n else 0.0,
                "max_s": self.max_s, "buckets": buckets}
