"""Utilization -> electrical power (paper §3.1: "simulated utilization is
converted to a power profile, with power rectification and conversion losses
applied [42]").

Per-node IT power comes either from the job's recorded per-node power trace
(trace datasets: Frontier, Marconi100) with last-observation-carried-forward
for missing samples, or from a scalar per-job average (summary datasets:
Fugaku, Lassen, Adastra). Idle nodes draw ``idle_node_w``.

Telemetry replay (repro.traces): when the table carries a measured
``power_profile`` channel, jobs with a measurement play it back verbatim —
the scan gathers the recorded sample at the job's work-time index instead
of evaluating the ``power_prof`` model — while profile-less jobs (negative
sentinel rows) keep the model bit-for-bit. ``power_profile is None`` is
the compile-time "replay off" fast path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import types as T
from repro.systems.config import SystemConfig


def job_node_power_elapsed(table: T.JobTable, jstate: jnp.ndarray,
                           elapsed: jnp.ndarray,
                           prof_dt: float) -> jnp.ndarray:
    """Per-node power of each job ``elapsed`` work-seconds into its run
    -> f32[J]. Under DVFS throttling the engine passes its work-time
    progress (which advances at c*dt per step) so a slowed job's profile
    plays at its dilated tempo rather than in wall-clock time.

    LOCF semantics (paper §3.2.2): the profile index is clamped into
    [0, P-1], so times before the first / after the last sample reuse the
    nearest recorded value.

    Replay mode: a measured ``table.power_profile`` sample (same clamped
    work-time indexing, at its own width Q) overrides the model wherever
    one exists — the -1 sentinel marks "no measurement", so the per-job
    switch is traced and profile-less jobs are untouched.
    """
    P = table.prof_len
    idx = jnp.clip((elapsed / prof_dt).astype(jnp.int32), 0, P - 1)
    p = jnp.take_along_axis(table.power_prof, idx[:, None], axis=1)[:, 0]
    if table.power_profile is not None:
        Q = table.power_profile.shape[1]
        qidx = jnp.clip((elapsed / prof_dt).astype(jnp.int32), 0, Q - 1)
        m = jnp.take_along_axis(table.power_profile, qidx[:, None],
                                axis=1)[:, 0]
        p = jnp.where(m >= 0.0, m, p)
    running = jstate == T.RUNNING
    return jnp.where(running, p, 0.0)


def job_node_power(table: T.JobTable, jstate: jnp.ndarray, start: jnp.ndarray,
                   t: jnp.ndarray, prof_dt: float) -> jnp.ndarray:
    """Per-node power drawn by each job at time ``t``  -> f32[J]."""
    return job_node_power_elapsed(table, jstate,
                                  jnp.maximum(t - start, 0.0), prof_dt)


def job_node_util(table: T.JobTable, jstate: jnp.ndarray, start: jnp.ndarray,
                  t: jnp.ndarray, prof_dt: float) -> jnp.ndarray:
    """Per-node utilization of each job at time ``t`` -> f32[J] in [0,1]."""
    P = table.prof_len
    elapsed = jnp.maximum(t - start, 0.0)
    idx = jnp.clip((elapsed / prof_dt).astype(jnp.int32), 0, P - 1)
    u = jnp.take_along_axis(table.util_prof, idx[:, None], axis=1)[:, 0]
    return jnp.where(jstate == T.RUNNING, u, 0.0)


def node_power(system: SystemConfig, table: T.JobTable, node_job: jnp.ndarray,
               job_pw: jnp.ndarray) -> jnp.ndarray:
    """Map per-job power onto the node axis -> f32[N].

    ``node_job[n]`` is the occupying job id (or -1). Free nodes draw idle
    power.
    """
    occupied = node_job >= 0
    safe = jnp.maximum(node_job, 0)
    p = jnp.take(job_pw, safe)
    return jnp.where(occupied, p, system.power.idle_node_w)


def system_it_power(node_pw: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(node_pw)
