"""Power-conversion losses (rectifier + secondary conversion), after
Wojda et al. [42] as used by ExaDigiT: efficiency is a quadratic function of
fractional load, applied in two stages (480V rectification, then on-board
SIVOC / voltage regulation).

Facility input power  P_in = P_IT / (eta_rect(load) * eta_sivoc(load)).
Loss = P_in - P_IT.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.systems.config import PowerConfig


def _eta(coeffs, load):
    c0, c1, c2 = coeffs
    eta = c0 + c1 * load + c2 * load * load
    return jnp.clip(eta, 0.5, 0.999)


def conversion(power_cfg: PowerConfig, p_it: jnp.ndarray,
               n_racks: jnp.ndarray | float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Return (facility_input_power, loss_power) for aggregate IT power.

    ``load`` is the fractional loading of the rectifier fleet: IT power over
    the rated capacity of all racks. Efficiency degrades toward low load,
    which is what makes *scheduling* visible in the loss curve (idle/fragmented
    systems run their rectifiers at poor efficiency).
    """
    rated_w = jnp.asarray(n_racks, jnp.float32) * power_cfg.rated_rack_kw * 1e3
    load = jnp.clip(p_it / jnp.maximum(rated_w, 1.0), 0.0, 1.5)
    eta = _eta(power_cfg.rect_c, load) * _eta(power_cfg.sivoc_c, load)
    p_in = p_it / eta
    return p_in, p_in - p_it
