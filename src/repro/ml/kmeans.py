"""K-means clustering in JAX (paper §4.4.1 step 1: partition historical jobs
into behavioral clusters from static + dynamic features)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnums=(1, 2))
def fit(x: jnp.ndarray, k: int, iters: int = 50, seed: int = 0):
    """Lloyd's algorithm (paper §4.4.1 step 1). x: f32[N, D]
    (standardized, dimensionless). Returns (centers f32[k, D],
    labels i32[N], inertia f32[] — summed squared distances)."""
    n = x.shape[0]
    key = jax.random.PRNGKey(seed)
    # k-means++-ish init: random distinct points
    idx = jax.random.choice(key, n, (k,), replace=False)
    centers0 = x[idx]

    def assign(centers):
        d2 = jnp.sum((x[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
        return jnp.argmin(d2, axis=1), d2

    def body(_, centers):
        labels, _ = assign(centers)
        one_hot = jax.nn.one_hot(labels, k, dtype=x.dtype)  # [N, k]
        counts = one_hot.sum(0)  # [k]
        sums = one_hot.T @ x     # [k, D]
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # keep empty clusters where they were
        return jnp.where(counts[:, None] > 0, new, centers)

    centers = jax.lax.fori_loop(0, iters, body, centers0)
    labels, d2 = assign(centers)
    inertia = jnp.sum(jnp.min(d2, axis=1))
    return centers, labels, inertia


@jax.jit
def predict(centers: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Nearest-center assignment (paper §4.4.1 inference): centers
    f32[k, D], x f32[N, D] (standardized) -> labels i32[N]."""
    d2 = jnp.sum((x[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=1)


def standardize(x, mean=None, std=None):
    """Zero-mean / unit-std feature scaling: x f32[N, D] ->
    (x_std f32[N, D], mean f32[D], std f32[D]); pass the stored moments
    at inference time so train/test share one scale."""
    if mean is None:
        mean = x.mean(0)
        std = x.std(0) + 1e-6
    return (x - mean) / std, mean, std
