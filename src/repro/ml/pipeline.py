"""End-to-end ML-guided scheduling pipeline (paper §4.4, Fig. 9).

Training phase:
  (1) *Clustering*  — K-means over behavioral features (summary statistics of
      the noisy time-series, per §4.4.3) + static features.
  (2) *Classification* — random forest from pre-submission features to the
      cluster label (dynamic features are unavailable at submit time).
  (3) *Prediction* — per-cluster ridge regressors from pre-submission
      features to target metrics (runtime, avg power, energy).

Inference phase: normalize statics -> predict cluster -> invoke that
cluster's regressor -> rank via S(X) (repro.ml.scoring). The resulting score
feeds the twin's ``ml`` policy (higher score = scheduled earlier).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.datasets.base import JobSet
from repro.ml import kmeans
from repro.ml.forest import RandomForest
from repro.ml.scoring import score as s_score

TARGETS = ("wall", "avg_power", "energy")


def _targets(js: JobSet) -> np.ndarray:
    avg_pw = js.power_prof.mean(1)
    energy = avg_pw * js.nodes * js.wall
    return np.stack([js.wall, avg_pw, energy], 1).astype(np.float64)


def _ridge(x: np.ndarray, y: np.ndarray, lam: float = 1e-2) -> np.ndarray:
    """Closed-form ridge with bias: returns W [D+1, T]."""
    xb = np.concatenate([x, np.ones((len(x), 1))], 1)
    d = xb.shape[1]
    w = np.linalg.solve(xb.T @ xb + lam * np.eye(d), xb.T @ y)
    return w


@dataclass
class MLSchedulerModel:
    centers: jnp.ndarray          # [k, Db] cluster centers (behavior space)
    clf: RandomForest             # presubmit features -> cluster
    reg_w: jnp.ndarray            # [k, D+1, T] per-cluster ridge weights
    x_mean: jnp.ndarray
    x_std: jnp.ndarray
    b_mean: jnp.ndarray
    b_std: jnp.ndarray
    alpha: jnp.ndarray            # [K_score] scoring coefficients

    # ------------------------------------------------------------------ fit
    @staticmethod
    def fit(train: JobSet, k: int = 5, n_trees: int = 12, depth: int = 6,
            alpha: np.ndarray | None = None, seed: int = 0
            ) -> "MLSchedulerModel":
        xs = train.presubmit_features()
        xb = train.behavior_features()
        xs_n, x_mean, x_std = kmeans.standardize(jnp.asarray(xs))
        xb_n, b_mean, b_std = kmeans.standardize(jnp.asarray(xb))

        centers, labels, _ = kmeans.fit(xb_n, k, seed=seed)
        labels_np = np.asarray(labels)

        clf = RandomForest.fit(np.asarray(xs_n), labels_np, k,
                               n_trees=n_trees, depth=depth, seed=seed)

        y = _targets(train)
        reg = np.zeros((k, xs.shape[1] + 1, y.shape[1]))
        for c in range(k):
            m = labels_np == c
            if m.sum() >= 4:
                reg[c] = _ridge(np.asarray(xs_n)[m], y[m])
            else:
                reg[c] = _ridge(np.asarray(xs_n), y)

        if alpha is None:
            # default trade-off: favor (predicted) short, low-power, small
            # jobs under load — the paper's observation in Fig. 10(a)
            alpha = np.array([1.0, 1.0, 1.0, 0.5], np.float32)
        return MLSchedulerModel(centers, clf, jnp.asarray(reg),
                                x_mean, x_std,
                                b_mean, b_std, jnp.asarray(alpha))

    # ------------------------------------------------------------- inference
    def predict_metrics(self, js: JobSet):
        """Returns (cluster i32[N], predicted [N, T])."""
        xs = jnp.asarray(js.presubmit_features())
        xs_n = (xs - self.x_mean) / self.x_std
        cluster = self.clf.predict(xs_n)
        xb = jnp.concatenate([xs_n, jnp.ones((xs_n.shape[0], 1))], 1)
        w = self.reg_w[cluster]                     # [N, D+1, T]
        pred = jnp.einsum("nd,ndt->nt", xb, w)
        return cluster, pred

    def score(self, js: JobSet) -> np.ndarray:
        """Ranking score per job (higher = scheduled earlier)."""
        _, pred = self.predict_metrics(js)
        # features for S(X): predicted runtime, power, energy + nodes
        feats = jnp.concatenate(
            [pred, jnp.asarray(js.nodes, jnp.float32)[:, None]], axis=1)
        return np.asarray(s_score(feats, self.alpha))


def attach_scores(js: JobSet, model: MLSchedulerModel) -> JobSet:
    js.score = model.score(js)
    return js
