"""End-to-end ML-guided scheduling pipeline (paper §4.4, Fig. 9).

Training phase:
  (1) *Clustering*  — K-means over behavioral features (summary statistics of
      the noisy time-series, per §4.4.3) + static features.
  (2) *Classification* — random forest from pre-submission features to the
      cluster label (dynamic features are unavailable at submit time).
  (3) *Prediction* — per-cluster ridge regressors from pre-submission
      features to target metrics (runtime s, avg per-node power W, energy J).

Inference phase: normalize statics -> predict cluster -> invoke that
cluster's regressor -> rank via S(X) (repro.ml.scoring). The resulting score
feeds the twin's ``ml`` policy (higher score = scheduled earlier).

Closing the loop (paper contribution (5), repro.ml.train): ``attach_basis``
stores the per-job scoring *basis* in the table instead of a baked score,
so the alpha trade-off vector becomes a traced ``Scenario.alpha`` knob —
trainable against batched twin rollouts without refitting this pipeline.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.datasets.base import JobSet
from repro.ml import kmeans
from repro.ml.forest import RandomForest
from repro.ml import scoring

TARGETS = ("wall", "avg_power", "energy")   # units: s, W, J


def _targets(js: JobSet) -> np.ndarray:
    """Ground-truth regression targets f64[N, 3]: runtime (s), average
    per-node power (W), job energy (J = W * nodes * s)."""
    avg_pw = js.power_prof.mean(1)
    energy = avg_pw * js.nodes * js.wall
    return np.stack([js.wall, avg_pw, energy], 1).astype(np.float64)


def _ridge(x: np.ndarray, y: np.ndarray, lam: float = 1e-2) -> np.ndarray:
    """Closed-form ridge with bias: x f64[N, D], y f64[N, T] ->
    weights f64[D+1, T] (last row is the bias)."""
    xb = np.concatenate([x, np.ones((len(x), 1))], 1)
    d = xb.shape[1]
    w = np.linalg.solve(xb.T @ xb + lam * np.eye(d), xb.T @ y)
    return w


@dataclass
class MLSchedulerModel:
    """Fitted cluster/classify/predict pipeline (paper Fig. 9).

    Shapes: k clusters, D pre-submission features, Db behavior features,
    T = len(TARGETS) predicted metrics, K_score scoring columns.
    """
    centers: jnp.ndarray          # f32[k, Db] cluster centers (behavior space)
    clf: RandomForest             # presubmit features -> cluster
    reg_w: jnp.ndarray            # f32[k, D+1, T] per-cluster ridge weights
    x_mean: jnp.ndarray           # f32[D] presubmit standardization mean
    x_std: jnp.ndarray            # f32[D] presubmit standardization std
    b_mean: jnp.ndarray           # f32[Db] behavior standardization mean
    b_std: jnp.ndarray            # f32[Db] behavior standardization std
    alpha: jnp.ndarray            # f32[K_score] scoring coefficients

    # ------------------------------------------------------------------ fit
    @staticmethod
    def fit(train: JobSet, k: int = 5, n_trees: int = 12, depth: int = 6,
            alpha: np.ndarray | None = None, seed: int = 0
            ) -> "MLSchedulerModel":
        """Fit the three-stage pipeline on a historical ``JobSet``.

        Args:
          train: historical jobs with full (post-hoc) telemetry.
          k: number of K-means behavior clusters (paper uses a handful).
          n_trees, depth: random-forest classifier size.
          alpha: f32[K_score] scoring trade-off; defaults to the paper's
            hand-set ``scoring.DEFAULT_ALPHA`` (the Fig. 10a setting, and
            the baseline the training loop must beat).
          seed: RNG seed for K-means init and forest bagging.
        """
        xs = train.presubmit_features()
        xb = train.behavior_features()
        xs_n, x_mean, x_std = kmeans.standardize(jnp.asarray(xs))
        xb_n, b_mean, b_std = kmeans.standardize(jnp.asarray(xb))

        centers, labels, _ = kmeans.fit(xb_n, k, seed=seed)
        labels_np = np.asarray(labels)

        clf = RandomForest.fit(np.asarray(xs_n), labels_np, k,
                               n_trees=n_trees, depth=depth, seed=seed)

        y = _targets(train)
        reg = np.zeros((k, xs.shape[1] + 1, y.shape[1]))
        for c in range(k):
            m = labels_np == c
            if m.sum() >= 4:
                reg[c] = _ridge(np.asarray(xs_n)[m], y[m])
            else:
                reg[c] = _ridge(np.asarray(xs_n), y)

        if alpha is None:
            alpha = np.asarray(scoring.DEFAULT_ALPHA, np.float32)
        return MLSchedulerModel(centers, clf, jnp.asarray(reg),
                                x_mean, x_std,
                                b_mean, b_std, jnp.asarray(alpha))

    # ------------------------------------------------------------- inference
    def predict_metrics(self, js: JobSet):
        """Predict per-job metrics from pre-submission features.

        Returns (cluster i32[N], predicted f32[N, T]) with T = runtime (s),
        avg per-node power (W), energy (J)."""
        xs = jnp.asarray(js.presubmit_features())
        xs_n = (xs - self.x_mean) / self.x_std
        cluster = self.clf.predict(xs_n)
        xb = jnp.concatenate([xs_n, jnp.ones((xs_n.shape[0], 1))], 1)
        w = self.reg_w[cluster]                     # [N, D+1, T]
        pred = jnp.einsum("nd,ndt->nt", xb, w)
        return cluster, pred

    def score_features(self, js: JobSet) -> jnp.ndarray:
        """f32[N, K_score] raw scoring features: predicted (runtime s,
        power W, energy J) columns + requested node count."""
        _, pred = self.predict_metrics(js)
        return jnp.concatenate(
            [pred, jnp.asarray(js.nodes, jnp.float32)[:, None]], axis=1)

    def score_basis(self, js: JobSet) -> np.ndarray:
        """f32[N, K_score] scoring basis ``exp(1/sqrt(X+1))`` per job.

        The score under any coefficient vector is ``basis @ alpha`` — this
        matrix is what ``repro.ml.train`` bakes into the broadcast job
        table so the alpha population can ride the scenario axis."""
        return np.asarray(scoring.basis(self.score_features(js)))

    def score(self, js: JobSet) -> np.ndarray:
        """f32[N] ranking score per job under the model's own alpha
        (higher = scheduled earlier)."""
        return np.asarray(
            scoring.score(self.score_features(js), self.alpha))


def attach_scores(js: JobSet, model: MLSchedulerModel) -> JobSet:
    """Bake the model's score (its own alpha) into ``js.score``. The
    resulting table ranks jobs statically — the pre-training path."""
    js.score = model.score(js)
    return js


def attach_basis(js: JobSet, model: MLSchedulerModel) -> JobSet:
    """Store the scoring *basis* instead of a baked score.

    ``js.score`` is zeroed and ``js.ml_basis`` set, so the ``ml`` policy key
    becomes ``-(ml_basis @ Scenario.alpha)`` — fully parameterized by the
    traced per-scenario alpha vector. ``Scenario.make("ml",
    alpha=model.alpha)`` then reproduces ``attach_scores`` ranking exactly
    (same key up to the zeroed static part)."""
    js.score = np.zeros(len(js), np.float32)
    js.ml_basis = model.score_basis(js)
    return js
