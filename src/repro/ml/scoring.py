"""Job ranking score (paper §4.4.2):

    S(X_i) = sum_j alpha_j * exp( 1 / sqrt(X_i^j + 1) )

"The exponential function captures fine-grained differences, allowing
prioritization based on predicted system-level impact. Unlike single-
objective schedulers, this supports trade-offs across throughput, wait time,
turnaround, and energy."
"""
from __future__ import annotations

import jax.numpy as jnp


def score(features: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """features: f32[N, K] non-negative predicted metrics + static features;
    alpha: f32[K] coefficients. Returns f32[N]."""
    x = jnp.maximum(features, 0.0)
    return jnp.sum(alpha * jnp.exp(1.0 / jnp.sqrt(x + 1.0)), axis=-1)
