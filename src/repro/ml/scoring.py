"""Job ranking score (paper §4.4.2):

    S(X_i) = sum_j alpha_j * exp( 1 / sqrt(X_i^j + 1) )

"The exponential function captures fine-grained differences, allowing
prioritization based on predicted system-level impact. Unlike single-
objective schedulers, this supports trade-offs across throughput, wait time,
turnaround, and energy."

The score is **linear in alpha**: S = basis(X) @ alpha with
``basis(X) = exp(1 / sqrt(max(X, 0) + 1))``. That factorization is what
closes the training loop (paper contribution (5)): the per-job basis matrix
is computed once and stored in the broadcast ``JobTable.ml_basis``, while
the alpha vector rides the traced ``Scenario.alpha`` axis — so an entire ES
population of candidate alphas evaluates as ONE batched ``simulate_sweep``
rollout (repro.ml.train).

Feature convention (K_SCORE = 4 columns, in order): predicted runtime (s),
predicted average per-node power (W), predicted job energy (J), requested
node count — see ``repro.ml.pipeline.MLSchedulerModel.score_basis``.
"""
from __future__ import annotations

import jax.numpy as jnp

# Number of scoring features: predicted (runtime s, avg power W, energy J)
# + node count. Keep in sync with MLSchedulerModel.score_basis.
K_SCORE = 4

# The paper's hand-set trade-off (Fig. 10a): favor predicted-short,
# low-power, low-energy jobs, with half weight on size. The training loop
# treats this as the starting point / baseline to beat.
DEFAULT_ALPHA = (1.0, 1.0, 1.0, 0.5)


def basis(features: jnp.ndarray) -> jnp.ndarray:
    """Per-job scoring basis: ``exp(1 / sqrt(max(X, 0) + 1))``.

    Args:
      features: f32[N, K] non-negative predicted metrics + static features
        (runtime s, power W, energy J, nodes — see module docstring).
    Returns:
      f32[N, K] basis matrix, each column in (1, e]: large predicted
      impact -> values near 1, tiny impact -> values near e. The score of
      job i under coefficients ``alpha`` is ``basis[i] @ alpha``.
    """
    x = jnp.maximum(features, 0.0)
    return jnp.exp(1.0 / jnp.sqrt(x + 1.0))


def score(features: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """Ranking score S(X) per job (higher = scheduled earlier).

    Args:
      features: f32[N, K] non-negative predicted metrics + static features.
      alpha: f32[K] trade-off coefficients (dimensionless; the features are
        squashed through the basis before weighting).
    Returns:
      f32[N] scores; equals ``basis(features) @ alpha`` exactly.
    """
    return jnp.sum(alpha * basis(features), axis=-1)
