"""Random forest: greedy CART fit on the host (numpy), vectorized JAX
predict (paper §4.4.1 step 2: classify jobs into behavioral clusters from
pre-submission features).

Hardware adaptation (offline image, no scikit-learn): tree
*fitting* is branchy host-side work anyway; *inference* must be traceable so
the ML-guided policy can score jobs inside the compiled twin. Trees are
stored as flat arrays (feature, threshold, left/right child, leaf value) and
evaluated with a bounded ``fori_loop`` descent — O(depth) gathers per sample.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Host-side CART fit.
# ---------------------------------------------------------------------------
def _gini(counts: np.ndarray) -> float:
    n = counts.sum()
    if n == 0:
        return 0.0
    p = counts / n
    return 1.0 - float((p * p).sum())


def _best_split(x: np.ndarray, y: np.ndarray, n_classes: int,
                feat_ids: np.ndarray, n_thresh: int = 16):
    best = (None, None, np.inf)
    n = len(y)
    for f in feat_ids:
        vals = x[:, f]
        qs = np.unique(np.quantile(vals, np.linspace(0.05, 0.95, n_thresh)))
        for t in qs:
            left = vals <= t
            nl = int(left.sum())
            if nl == 0 or nl == n:
                continue
            cl = np.bincount(y[left], minlength=n_classes)
            cr = np.bincount(y[~left], minlength=n_classes)
            score = (nl * _gini(cl) + (n - nl) * _gini(cr)) / n
            if score < best[2]:
                best = (int(f), float(t), score)
    return best


def _fit_tree(x, y, n_classes, depth, rng, max_features):
    """Returns flat arrays sized 2**(depth+1): feature(-1=leaf), thresh,
    leaf class distribution."""
    n_nodes = 2 ** (depth + 1)
    feat = np.full(n_nodes, -1, np.int32)
    thresh = np.zeros(n_nodes, np.float32)
    leaf = np.zeros((n_nodes, n_classes), np.float32)

    def build(node, idx, d):
        ys = y[idx]
        counts = np.bincount(ys, minlength=n_classes).astype(np.float64)
        leaf[node] = (counts / max(counts.sum(), 1)).astype(np.float32)
        if d >= depth or len(idx) < 4 or _gini(counts) < 1e-6:
            return
        feat_ids = rng.choice(x.shape[1], max_features, replace=False)
        f, t, score = _best_split(x[idx], ys, n_classes, feat_ids)
        if f is None:
            return
        feat[node] = f
        thresh[node] = t
        left = idx[x[idx, f] <= t]
        right = idx[x[idx, f] > t]
        if len(left) == 0 or len(right) == 0:
            feat[node] = -1
            return
        build(2 * node + 1, left, d + 1)
        build(2 * node + 2, right, d + 1)

    build(0, np.arange(len(y)), 0)
    return feat, thresh, leaf


@dataclass
class RandomForest:
    feat: jnp.ndarray     # i32[T, M] feature per node (-1 = leaf)
    thresh: jnp.ndarray   # f32[T, M]
    leaf: jnp.ndarray     # f32[T, M, C] class distribution per node
    depth: int
    n_classes: int

    @staticmethod
    def fit(x: np.ndarray, y: np.ndarray, n_classes: int, n_trees: int = 16,
            depth: int = 6, seed: int = 0,
            max_features: int | None = None) -> "RandomForest":
        """Bagged CART fit (paper §4.4.1 step 2): x f64[N, D] standardized
        features, y i64[N] cluster labels. ``max_features`` defaults to
        sqrt(D) per split (the usual forest heuristic)."""
        rng = np.random.default_rng(seed)
        max_features = max_features or max(1, int(np.sqrt(x.shape[1])))
        feats, threshs, leafs = [], [], []
        n = len(y)
        for _ in range(n_trees):
            boot = rng.integers(0, n, n)  # bagging
            f, t, l = _fit_tree(x[boot], y[boot], n_classes, depth, rng,
                                max_features)
            feats.append(f)
            threshs.append(t)
            leafs.append(l)
        return RandomForest(jnp.asarray(np.stack(feats)),
                            jnp.asarray(np.stack(threshs)),
                            jnp.asarray(np.stack(leafs)),
                            depth, n_classes)

    # -- JAX inference ------------------------------------------------------
    def predict_proba(self, x: jnp.ndarray) -> jnp.ndarray:
        """f32[N, D] -> f32[N, C] (mean over trees)."""
        feat, thresh, leaf, depth = self.feat, self.thresh, self.leaf, self.depth

        def one_tree(f_t, th_t, lf_t):
            def descend(xi):
                def body(_, node):
                    fid = f_t[node]
                    is_leaf = fid < 0
                    go_left = xi[jnp.maximum(fid, 0)] <= th_t[node]
                    nxt = jnp.where(go_left, 2 * node + 1, 2 * node + 2)
                    return jnp.where(is_leaf, node, nxt)
                node = jax.lax.fori_loop(0, depth + 1, body, jnp.int32(0))
                return lf_t[node]
            return jax.vmap(descend)(x)

        probs = jax.vmap(one_tree)(feat, thresh, leaf)  # [T, N, C]
        return probs.mean(0)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        """f32[N, D] -> i32[N] majority-vote cluster labels."""
        return jnp.argmax(self.predict_proba(x), axis=-1)
