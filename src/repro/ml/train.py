"""On-device policy training over batched twin rollouts (paper
contribution (5), §4.4; ROADMAP "ML policy training loop").

The whole digital twin is the fitness function. A candidate policy is an
alpha vector for the ranking score S(X) = basis(X) @ alpha
(repro.ml.scoring); its fitness is a ``Reward`` — a weighted sum of
telemetry the twin already emits (mean wait, turnaround, facility energy,
PUE, carbon/cost from the grid ledgers, per-hall overheat). Because the
score is linear in alpha, the per-job basis lives in the *broadcast*
``JobTable.ml_basis`` while alpha rides the traced ``Scenario.alpha`` axis:
one ES generation with population P evaluates as ONE batched
``simulate_sweep`` / ``simulate_sweep_sharded`` program — the population is
just another scenario axis, so training scales across devices exactly like
the maintenance sweeps (docs/architecture.md).

Optimizer: OpenAI-style evolution strategies with antithetic perturbations
and centered-rank fitness shaping (SPARS, arXiv:2512.13268, makes the case
for RL-in-simulator power-aware scheduling; ES keeps the rollout batched
and gradient-free — the scan is full of sorts and discrete admissions).
An elite (best candidate ever evaluated) is tracked alongside the search
mean, so the returned policy is monotonically no worse than the hand-set
``scoring.DEFAULT_ALPHA`` baseline, which is always evaluated in the same
batched program.

CLI (``python -m repro.launch.simulate train ...``):

  train --smoke                       # tiny seeded run, asserts improvement
  train --system marconi100 --jobs 400 -t 12h --reward wait=1,energy=0.5 \\
        --generations 30 --population 16 --checkpoint results/train/run.json

Checkpoints are JSON and resumable (``--resume``): the search state (mu,
sigma, generation, elite, reward normalizers) round-trips exactly.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.core import engine as eng
from repro.core import types as T
from repro.launch import env as launch_env
from repro.ml import scoring
from repro.obs import timing as obs_timing
from repro.systems.config import SystemConfig

# ---------------------------------------------------------------------------
# Reward: telemetry -> scalar fitness (higher is better).
# ---------------------------------------------------------------------------
# Every metric is lower-is-better in raw form; the reward negates the
# weighted, baseline-normalized sum. Units listed per metric.
METRICS: Dict[str, str] = {
    "wait":       "mean wait of completed jobs (s)",
    "turnaround": "mean turnaround of completed jobs (s)",
    "energy":     "total facility energy (J)",
    "pue":        "mean PUE (dimensionless)",
    "carbon":     "grid-signal-weighted emissions (kg CO2)",
    "cost":       "electricity cost at the grid price ($)",
    "overheat":   "fraction of (step, hall) rows past the supply setpoint "
                  "margin (dimensionless)",
    "unfinished": "valid jobs not completed inside the window (count)",
    "power_peak": "max facility power (W)",
}

# ``unfinished`` counterweights window-gaming: without it, ES can "win"
# the completed-jobs-only wait/turnaround means by starving long jobs past
# the end of the rollout window instead of serving them.
DEFAULT_REWARD_SPEC = "wait=1,turnaround=0.5,energy=0.25,unfinished=0.5"

# The seeded tiny config shared by ``train --smoke`` and the CI benchmark
# (benchmarks/fig10_ml.py smoke) — one source so the CLI smoke and the
# tracked BENCH_ml.json rows can never desynchronize.
SMOKE_CONFIG = dict(system="marconi100", scale=64, jobs=90, time="2h",
                    generations=4, population=8, sigma=0.35, lr=0.8)


def rollout_metrics(system: SystemConfig, table: T.JobTable,
                    finals: T.SimState, hists: T.StepRecord,
                    setpoint_delta_c: float = 0.0
                    ) -> Dict[str, np.ndarray]:
    """Per-scenario metric vectors from one batched rollout.

    Args:
      system: the simulated machine (for the overheat threshold, °C).
      table: the (shared) job table of the rollout.
      finals: batched final states — every leaf has leading axis P.
      hists: batched telemetry — leaves are [P, steps] or [P, steps, H].
      setpoint_delta_c: supply-setpoint offset the rollout ran with
        (``Scenario.setpoint_delta_c``), so the ``overheat`` threshold
        matches the engine's own definition (cooling.model.thermal_now).
    Returns:
      {metric name -> f64[P]} for every name in ``METRICS``.
    """
    start = np.asarray(finals.start, np.float64)          # [P, J]
    end = np.asarray(finals.end, np.float64)
    jstate = np.asarray(finals.jstate)
    submit = np.asarray(table.submit, np.float64)[None]   # [1, J]
    valid = np.asarray(table.valid)[None]
    done = (jstate == T.DONE) & np.isfinite(start) & np.isfinite(end)
    n_done = np.maximum(done.sum(-1), 1)
    wait = np.where(done, np.maximum(start - submit, 0.0), 0.0)
    turn = np.where(done, np.maximum(end - submit, 0.0), 0.0)

    cfg = system.cooling
    t_sup = np.asarray(hists.t_supply_max_hall, np.float64)  # [P, S, H]
    hot = t_sup > (cfg.t_supply_setpoint_c + setpoint_delta_c +
                   cfg.t_supply_margin_c)
    return {
        "wait": wait.sum(-1) / n_done,
        "turnaround": turn.sum(-1) / n_done,
        "energy": np.asarray(finals.energy_total, np.float64),
        "pue": np.asarray(hists.pue, np.float64).mean(-1),
        "carbon": np.asarray(finals.emissions_kg, np.float64),
        "cost": np.asarray(finals.energy_cost, np.float64),
        "overheat": hot.mean((-2, -1)),
        "unfinished": (valid & (jstate != T.DONE) &
                       (jstate != T.DISMISSED)).sum(-1).astype(np.float64),
        "power_peak": np.asarray(hists.power_total, np.float64).max(-1),
    }


@dataclass(frozen=True)
class Reward:
    """Weighted telemetry objective, higher is better.

    ``reward = -sum_m w_m * metric_m / ref_m`` where the normalizers
    ``ref_m`` are the *baseline policy's* metric values (so each term is
    1.0 at the baseline and the baseline reward is exactly ``-sum_m w_m``
    — improvement reads directly as reward above that floor). Zero
    baselines fall back to an unnormalized term.
    """
    weights: tuple  # ((metric name, weight), ...)

    @staticmethod
    def parse(spec: str) -> "Reward":
        """Parse ``"wait=1,energy=0.5"`` into a Reward. Unknown metric
        names raise with the list of valid ones."""
        weights = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            name, _, w = part.partition("=")
            name = name.strip()
            if name not in METRICS:
                raise ValueError(
                    f"unknown reward metric {name!r}; "
                    f"valid: {', '.join(sorted(METRICS))}")
            weights.append((name, float(w) if w else 1.0))
        if not weights:
            raise ValueError(f"empty reward spec: {spec!r}")
        return Reward(tuple(weights))

    @property
    def spec(self) -> str:
        return ",".join(f"{n}={w:g}" for n, w in self.weights)

    def refs(self, metrics: Dict[str, np.ndarray], row: int
             ) -> Dict[str, float]:
        """Baseline normalizers: the metric values of scenario ``row``."""
        return {n: float(metrics[n][row]) for n, _ in self.weights}

    def evaluate(self, metrics: Dict[str, np.ndarray],
                 refs: Dict[str, float]) -> np.ndarray:
        """f64[P] rewards for a batched rollout's metric vectors."""
        r = 0.0
        for name, w in self.weights:
            scale = refs.get(name, 0.0)
            scale = scale if abs(scale) > 1e-12 else 1.0
            r = r - w * metrics[name] / scale
        return np.asarray(r, np.float64)


# ---------------------------------------------------------------------------
# Antithetic ES with centered-rank shaping.
# ---------------------------------------------------------------------------
def antithetic_population(mu: np.ndarray, sigma: float,
                          rng: np.random.Generator, population: int
                          ) -> np.ndarray:
    """f32[P, K] candidates: mu +/- sigma * eps in antithetic pairs.

    ``population`` must be even; row i and row i + P/2 share |eps|."""
    assert population % 2 == 0, "ES population must be even (antithetic)"
    half = population // 2
    eps = rng.standard_normal((half, mu.shape[0]))
    return np.concatenate([mu + sigma * eps, mu - sigma * eps],
                          0).astype(np.float32)


def centered_ranks(r: np.ndarray) -> np.ndarray:
    """Map rewards to utilities in [-0.5, 0.5] by rank (robust to reward
    scale and outliers — the standard ES fitness shaping)."""
    ranks = np.empty(len(r), np.float64)
    ranks[np.argsort(r)] = np.arange(len(r), dtype=np.float64)
    return ranks / max(len(r) - 1, 1) - 0.5


def es_update(mu: np.ndarray, candidates: np.ndarray, rewards: np.ndarray,
              sigma: float, lr: float) -> np.ndarray:
    """One ES ascent step on the search mean.

    Args:
      mu: f64[K] current mean.
      candidates: f32[P, K] the antithetic population (mu +/- sigma*eps).
      rewards: f64[P] fitness per candidate (higher better).
      sigma, lr: perturbation scale / learning rate (dimensionless).
    Returns:
      f64[K] updated mean: mu + lr/(P*sigma) * sum_i u_i * eps_i with
      centered-rank utilities u and unit-normal eps (the OpenAI-ES
      estimator).
    """
    P = len(candidates)
    eps = (np.asarray(candidates, np.float64) - mu) / sigma
    u = centered_ranks(rewards)
    return mu + lr / (P * sigma) * (u @ eps)


# ---------------------------------------------------------------------------
# The training loop.
# ---------------------------------------------------------------------------
@dataclass
class TrainResult:
    """Outcome of ``train``: elite policy + search trajectory."""
    alpha: np.ndarray            # f32[K] best candidate ever evaluated
    mu: np.ndarray               # f64[K] final search mean
    reward_best: float           # elite reward (its own objective)
    reward_default: float        # the hand-set DEFAULT_ALPHA baseline
    refs: Dict[str, float]       # reward normalizers (baseline metrics)
    history: List[dict]          # per-generation records
    generations: int


def _rollout(system, table, alphas, t0, t1, *, backfill, scen_kw,
             signals, weather, sharded):
    """Evaluate a stack of alpha vectors as ONE batched rollout program.

    ``alphas`` f32[P, K] -> one Scenario per row, all sharing the job
    table / signals / weather; scenario axis = population axis."""
    scens = [T.Scenario.make("ml", backfill, alpha=a, **(scen_kw or {}))
             for a in alphas]
    run = eng.simulate_sweep_sharded if sharded else eng.simulate_sweep
    return run(system, table, scens, t0, t1, signals=signals,
               weather=weather)


def train(system: SystemConfig, table: T.JobTable, t0: float, t1: float,
          reward: Reward | str = DEFAULT_REWARD_SPEC,
          generations: int = 20, population: int = 16,
          sigma: float = 0.25, lr: float = 0.6,
          alpha0: Sequence[float] | None = None,
          backfill: str = "first-fit", scen_kw: dict | None = None,
          signals=None, weather=None, seed: int = 0,
          checkpoint: str | pathlib.Path | None = None,
          resume: bool = False, sharded: bool = True,
          log: Callable[[str], None] | None = print,
          recorder=None) -> TrainResult:
    """ES-train the scoring alpha against batched twin rollouts.

    Args:
      system: machine config (compile-time constant; one compile total).
      table: job table with ``ml_basis`` attached
        (``ml.pipeline.attach_basis``) — raises otherwise.
      t0, t1: rollout window (s).
      reward: ``Reward`` or spec string, e.g. ``"wait=1,energy=0.5"``.
      generations: ES generations to run (on resume: *total*, including
        the checkpointed ones).
      population: candidates per generation (even; antithetic pairs).
        Each generation evaluates population + 2 scenarios (the search
        mean and the frozen baseline ride along) as one program.
      sigma, lr: ES perturbation scale / learning rate.
      alpha0: f32[K] starting mean; default ``scoring.DEFAULT_ALPHA``.
      backfill: backfill mode for every candidate scenario.
      scen_kw: extra ``Scenario.make`` knobs shared by all candidates
        (e.g. ``cells_offline`` for train-under-stress).
      signals / weather: grid signals / weather trace(s) for the rollouts
        (weather may be a per-scenario list only if it has population + 2
        entries; normally one shared trace).
      seed: RNG seed; generation g draws from ``default_rng([seed, g])``,
        so resumed runs replay the exact same perturbations.
      checkpoint: JSON path written after every generation.
      resume: load ``checkpoint`` and continue to ``generations``.
      sharded: use ``simulate_sweep_sharded`` (population axis across
        devices); identical to ``simulate_sweep`` on one device.
      log: per-generation progress line sink; the default routes through
        the ``repro`` logger (stderr); ``None`` silences.
      recorder: optional ``obs.RunRecorder`` — gets a ``generation``
        event per generation and a ``checkpoint`` event per save.
    Returns:
      ``TrainResult`` with the elite alpha (never worse than the baseline
      on this reward, since the baseline is evaluated in-band).
    """
    if log is print:    # route the default through logging, not stdout
        from repro.obs.reporter import get_logger
        log = get_logger().info
    if table.ml_basis is None:
        raise ValueError("table has no ml_basis; call "
                         "ml.pipeline.attach_basis(js, model) before "
                         "training")
    if isinstance(reward, str):
        reward = Reward.parse(reward)
    K = table.ml_basis.shape[1]
    base_alpha = np.asarray(
        scoring.DEFAULT_ALPHA[:K] if alpha0 is None else alpha0, np.float64)
    mu = base_alpha.copy()
    gen0, history = 0, []
    best_alpha, best_reward = None, -np.inf
    refs = None

    if resume and checkpoint and pathlib.Path(checkpoint).exists():
        ck = json.loads(pathlib.Path(checkpoint).read_text())
        mu = np.asarray(ck["mu"], np.float64)
        base_alpha = np.asarray(ck["alpha0"], np.float64)
        if log and (ck["sigma"] != sigma or ck["lr"] != lr or
                    ck["seed"] != seed):
            log(f"resume: checkpoint sigma={ck['sigma']}, lr={ck['lr']}, "
                f"seed={ck['seed']} override the call's "
                f"sigma={sigma}, lr={lr}, seed={seed}")
        sigma, lr = ck["sigma"], ck["lr"]
        # population shapes the per-generation eps draw: restore it too,
        # or the promised "resume replays the same perturbations" breaks
        population = ck.get("population", population)
        gen0, history = ck["generation"], ck["history"]
        best_alpha = np.asarray(ck["best_alpha"], np.float64)
        best_reward = ck["best_reward"]
        refs = ck["refs"]
        seed = ck["seed"]
        if ck["reward"] != reward.spec and log:
            log(f"resume: checkpoint reward {ck['reward']!r} overrides "
                f"{reward.spec!r}")
            reward = Reward.parse(ck["reward"])

    for gen in range(gen0, generations):
        rng = np.random.default_rng([seed, gen])
        cands = antithetic_population(mu, sigma, rng, population)
        # rows [0:P] = population, row P = search mean, row P+1 = frozen
        # baseline (reward normalizer + the bar the elite must clear)
        stack = np.concatenate(
            [cands, mu[None].astype(np.float32),
             base_alpha[None].astype(np.float32)], 0)
        cache0 = dict(eng.SWEEP_CACHE_STATS)
        wall = time.perf_counter()
        with obs_timing.maybe_span("train.generation", generation=gen):
            finals, hists = _rollout(system, table, stack, t0, t1,
                                     backfill=backfill, scen_kw=scen_kw,
                                     signals=signals, weather=weather,
                                     sharded=sharded)
        wall = time.perf_counter() - wall
        # per-generation sweep-runner cache deltas: steady state is all
        # hits after generation 0 — a miss later means a shape changed
        # and the generation silently recompiled
        cache_hits = eng.SWEEP_CACHE_STATS["hits"] - cache0["hits"]
        cache_misses = eng.SWEEP_CACHE_STATS["misses"] - cache0["misses"]
        metrics = rollout_metrics(
            system, table, finals, hists,
            float((scen_kw or {}).get("setpoint_delta_c", 0.0)))
        if refs is None:   # first generation: pin normalizers to baseline
            refs = reward.refs(metrics, len(stack) - 1)
        rewards = reward.evaluate(metrics, refs)
        r_pop, r_mu, r_base = (rewards[:population], rewards[population],
                               rewards[population + 1])

        gen_best = int(np.argmax(rewards[:population + 1]))
        if rewards[gen_best] > best_reward:
            best_reward = float(rewards[gen_best])
            best_alpha = stack[gen_best].astype(np.float64)

        mu = es_update(mu, cands, r_pop, sigma, lr)
        history.append({
            "generation": gen, "reward_mu": float(r_mu),
            "reward_best": float(best_reward),
            "reward_baseline": float(r_base),
            "reward_pop_mean": float(r_pop.mean()),
            "wall_s": wall, "mu": [float(x) for x in mu],
            "cache_hits": cache_hits, "cache_misses": cache_misses,
        })
        if recorder is not None:
            recorder.event("generation", generation=gen,
                           reward_mu=float(r_mu),
                           reward_best=float(best_reward),
                           wall_s=wall, cache_hits=cache_hits,
                           cache_misses=cache_misses)
        if log:
            log(f"gen {gen:3d}  r(mu)={r_mu:+.4f}  "
                f"r(best)={best_reward:+.4f}  r(base)={r_base:+.4f}  "
                f"pop={population}  {wall:.2f}s/gen")
        if checkpoint:
            _save_checkpoint(checkpoint, mu=mu, alpha0=base_alpha,
                             sigma=sigma, lr=lr, population=population,
                             generation=gen + 1,
                             history=history, best_alpha=best_alpha,
                             best_reward=best_reward, refs=refs,
                             reward=reward.spec, seed=seed)
            if recorder is not None:
                recorder.event("checkpoint", path=str(checkpoint),
                               generation=gen + 1)

    # the baseline reward is deterministic: read it off the last generation
    # (== -sum of weights when every normalizer is nonzero)
    reward_default = (history[-1]["reward_baseline"] if history
                      else -sum(w for _, w in reward.weights))
    if best_alpha is None:      # generations == 0: the baseline is the elite
        best_alpha, best_reward = base_alpha, reward_default
    return TrainResult(alpha=best_alpha.astype(np.float32), mu=mu,
                       reward_best=float(best_reward),
                       reward_default=float(reward_default),
                       refs=refs or {}, history=history,
                       generations=len(history))


def _save_checkpoint(path, **state) -> None:
    """Atomic-ish JSON checkpoint (write then replace)."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(p.suffix + ".tmp")
    tmp.write_text(json.dumps(state, indent=1, default=_jsonable))
    tmp.replace(p)


def _jsonable(x):
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, (np.floating, np.integer)):
        return x.item()
    raise TypeError(f"not JSON-serializable: {type(x)}")


def load_alpha(path: str | pathlib.Path) -> np.ndarray:
    """f32[K] elite alpha from a training checkpoint JSON."""
    ck = json.loads(pathlib.Path(path).read_text())
    return np.asarray(ck["best_alpha"], np.float32)


# ---------------------------------------------------------------------------
# CLI (dispatched from ``python -m repro.launch.simulate train ...``).
# ---------------------------------------------------------------------------
def main(argv=None) -> TrainResult:
    import argparse

    from repro.datasets import loaders
    from repro.ml.pipeline import MLSchedulerModel, attach_basis
    from repro.systems.config import get_system

    ap = argparse.ArgumentParser(
        prog="simulate train",
        description="ES-train the ML scheduling policy inside the twin")
    ap.add_argument("--system", default="marconi100")
    ap.add_argument("--jobs", type=int, default=300)
    ap.add_argument("--scale", type=int, default=0,
                    help="scale the system to N nodes (CPU-friendly)")
    ap.add_argument("-t", "--time", default="6h",
                    help="rollout window (s/m/h/d suffix)")
    ap.add_argument("--reward", default=DEFAULT_REWARD_SPEC,
                    help="metric=weight list; metrics: " +
                         ", ".join(sorted(METRICS)))
    ap.add_argument("--generations", type=int, default=12)
    ap.add_argument("--population", type=int, default=16)
    ap.add_argument("--sigma", type=float, default=0.25)
    ap.add_argument("--lr", type=float, default=0.6)
    ap.add_argument("--backfill", default="first-fit")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--heat-wave-c", type=float, default=0.0,
                    help="train under a heat wave of this amplitude (°C)")
    ap.add_argument("--cells-offline", type=float, default=0.0,
                    help="train with N tower cells out per hall")
    ap.add_argument("--checkpoint", default="results/train/ml_alpha.json")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny seeded config; asserts the trained reward "
                         "improves on the default alpha")
    ap.add_argument("--manifest", default=None, metavar="FILE",
                    help="write a schema-versioned run manifest JSON")
    ap.add_argument("--events", default=None, metavar="FILE",
                    help="write lifecycle events as NDJSON")
    from repro.obs.reporter import add_output_flags
    add_output_flags(ap)
    import sys as _sys
    argv = list(_sys.argv[1:]) if argv is None else list(argv)
    if "--smoke" in argv:
        # presets via set_defaults so explicit flags still win (e.g.
        # ``train --smoke --resume --generations 8`` extends the run)
        ap.set_defaults(**SMOKE_CONFIG)
    args = ap.parse_args(argv)

    from repro.launch.simulate import _parse_time

    sys_ = get_system(args.system)
    if args.scale:
        sys_ = sys_.scaled(args.scale)
    t1 = _parse_time(args.time)
    # arrivals span ~the rollout window so the queue actually fills — the
    # policy can only move the reward when there is contention to arbitrate
    days = max((t1 / 86400.0) * 1.2, 0.02)
    js = loaders.load(args.system, n_jobs=args.jobs, days=days,
                      seed=args.seed)
    # loaders size jobs against the full-scale system; on a --scale'd one,
    # drop jobs that can never fit (they would sit QUEUED forever and put
    # a constant floor under the wait/unfinished reward terms)
    js = js.select(np.asarray(js.nodes) <= sys_.n_nodes)
    # the offline pipeline provides the basis; training only moves alpha
    model = MLSchedulerModel.fit(js, k=4, n_trees=6, depth=5,
                                 seed=args.seed)
    attach_basis(js, model)
    js.assign_prepop_placement(0.0, sys_.n_nodes)
    table = js.to_table()

    weather = None
    if args.heat_wave_c > 0.0:
        from repro.cooling import weather as wsig
        n_steps = int(round(t1 / sys_.dt))
        base = wsig.synthetic_weather(n_steps, sys_.dt, seed=args.seed)
        weather = wsig.heat_wave(base, sys_.dt, start_s=0.1 * t1,
                                 duration_s=0.6 * t1,
                                 peak_amp_c=args.heat_wave_c)
    scen_kw = {}
    if args.cells_offline:
        scen_kw["cells_offline"] = args.cells_offline

    from repro import obs
    rep = obs.Reporter.from_flags(args)
    recorder = None
    if args.manifest or args.events:
        recorder = obs.RunRecorder(manifest_path=args.manifest,
                                   events_path=args.events)
        recorder.begin(sys_, command="train", argv=argv,
                       scenario={"reward": args.reward,
                                 "generations": args.generations,
                                 "population": args.population,
                                 "sigma": args.sigma, "lr": args.lr,
                                 "backfill": args.backfill,
                                 "heat_wave_c": args.heat_wave_c,
                                 "cells_offline": args.cells_offline},
                       seed=args.seed, jobs=js,
                       extra={"env_preset": launch_env.report("sweep")})
        recorder.event("run_start", command="train")
    timer = obs.SpanTimer(listener=recorder.span_listener
                          if recorder else None)
    with obs.use(timer):
        res = train(sys_, table, 0.0, t1, reward=args.reward,
                    generations=args.generations,
                    population=args.population,
                    sigma=args.sigma, lr=args.lr, backfill=args.backfill,
                    scen_kw=scen_kw, weather=weather, seed=args.seed,
                    checkpoint=args.checkpoint, resume=args.resume,
                    log=rep.log_fn(), recorder=recorder)
    gain = res.reward_best - res.reward_default
    rep.result(f"trained alpha: {np.round(res.alpha, 4).tolist()}  "
               f"reward {res.reward_best:+.4f} vs default "
               f"{res.reward_default:+.4f}  (gain {gain:+.4f})",
               key="train",
               value={"alpha": res.alpha, "reward_best": res.reward_best,
                      "reward_default": res.reward_default, "gain": gain,
                      "generations": res.generations})
    if args.checkpoint:
        rep.info(f"checkpoint -> {args.checkpoint}")
        rep.result_json("checkpoint", str(args.checkpoint))
    if recorder is not None:
        recorder.event("run_end", generations=res.generations)
        recorder.finalize(
            spans=timer.summary(),
            counters={"sweep_cache": dict(eng.SWEEP_CACHE_STATS)},
            result={"reward_best": res.reward_best,
                    "reward_default": res.reward_default, "gain": gain,
                    "generations": res.generations})
    rep.flush_json()
    if args.smoke:
        assert gain > 0.0, (
            f"smoke training failed to improve on the default alpha "
            f"(gain {gain:+.5f})")
        rep.info("smoke OK: trained policy improves the reward "
                 f"by {gain:+.4f} over the default alpha")
    return res


if __name__ == "__main__":
    main()
