"""Node allocation / release (paper §3.2.3: "the resource manager then
completes the job placement, allocating nodes").

Node state is a single int32 array ``node_job[N]`` (occupying job id, -1 when
free, -2 when down for repair — the failure layer ``repro.events`` parks
unavailable free nodes there so placement skips them). Placement is
vectorized:

* reschedule mode: first-free placement by prefix-sum rank over the free mask;
* hall-aware mode: the same prefix-sum rank, taken in a caller-supplied
  node *preference order* (``firstfree_mask_ordered``) — the scheduler
  orders nodes by their hall's cooling pressure so placement drains into
  the coolest hall first (repro.systems.config.FacilityTopology);
* replay mode: the exact recorded contiguous span ``[first_node,
  first_node+need)`` (paper §3.2.3: "the exact node placement as specified in
  the telemetry is used in replay mode").
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import types as T


def release_done(node_job: jnp.ndarray, done_now: jnp.ndarray) -> jnp.ndarray:
    """Free every node whose occupying job just completed."""
    occupied = node_job >= 0
    safe = jnp.maximum(node_job, 0)
    freed = occupied & jnp.take(done_now, safe)
    return jnp.where(freed, -1, node_job)


def firstfree_mask(node_job: jnp.ndarray, need: jnp.ndarray) -> jnp.ndarray:
    """Boolean mask selecting the first ``need`` free nodes (a -2 down
    node is not free)."""
    free = node_job == -1
    rank = jnp.cumsum(free.astype(jnp.int32))
    return free & (rank <= need)


def firstfree_mask_ordered(node_job: jnp.ndarray, need: jnp.ndarray,
                           order: jnp.ndarray) -> jnp.ndarray:
    """Boolean mask selecting the first ``need`` free nodes *in preference
    order* (``order``: i32[N] permutation of node indices; identity order
    reproduces ``firstfree_mask`` exactly)."""
    free = node_job == -1
    free_o = free[order]
    rank = jnp.cumsum(free_o.astype(jnp.int32))
    sel_o = free_o & (rank <= need)
    return jnp.zeros_like(free).at[order].set(sel_o)


def contiguous_mask(n_nodes: int, first: jnp.ndarray,
                    need: jnp.ndarray) -> jnp.ndarray:
    idx = jnp.arange(n_nodes, dtype=jnp.int32)
    return (idx >= first) & (idx < first + need)


def place(node_job: jnp.ndarray, sel: jnp.ndarray, jid: jnp.ndarray,
          do_place: jnp.ndarray) -> jnp.ndarray:
    """Assign job ``jid`` to nodes in ``sel`` when ``do_place``."""
    return jnp.where(sel & do_place, jid, node_job)


def prepopulate(n_nodes: int, first_node: jnp.ndarray, nodes: jnp.ndarray,
                running0: jnp.ndarray) -> jnp.ndarray:
    """Build the initial node_job map from jobs already running at sim start
    (paper §3.2.3 prepopulation). Spans are disjoint by construction.

    Uses a delta-encoding + cumsum fill: O(J + N), no per-job loop.
    """
    J = first_node.shape[0]
    jid = jnp.arange(J, dtype=jnp.int32)
    val = jnp.where(running0, jid + 1, 0)  # 0 == free sentinel
    start = jnp.where(running0, first_node, 0)
    stop = jnp.where(running0, first_node + nodes, 0)
    delta = jnp.zeros((n_nodes + 1,), jnp.int32)
    delta = delta.at[start].add(val)
    delta = delta.at[stop].add(-val)
    fill = jnp.cumsum(delta[:-1])
    return fill - 1  # -1 == free
