"""The S-RAPS simulation engine (paper §3.2.3), as a single ``lax.scan``.

Main loop per step (paper's four well-defined steps):
  (1) prepare     -- clear completed jobs, free their nodes, fold accounting;
  (2) arrivals    -- move submitted jobs into the queue;
  (3) schedule    -- policy sort + bounded admission (repro.core.scheduler),
                     cap-aware when a power-cap schedule is active and
                     thermally throttled when cooling loses its setpoint;
  (4) tick        -- power model (or measured-telemetry replay when the
                     table carries a ``power_profile`` channel —
                     repro.traces) -> DVFS cap enforcement (repro.grid) ->
                     conversion losses -> transient cooling loop
                     (repro.cooling, weather-driven) -> telemetry row;
                     advance time.

The engine is pure: ``simulate`` compiles once per (system, job-table shape)
and a *batch of scenarios* (policy x backfill x incentive weights) runs under
``vmap`` — see ``simulate_sweep``. With more than one device the scenario
axis shards across them as one ``shard_map`` program
(``simulate_sweep_sharded``; the CLI and examples call it by default).

Per-step environment inputs follow one pattern: host-precomputed arrays
(``repro.grid.signals.GridSignals``, ``repro.cooling.weather
.WeatherSignals``) are gathered at ``SimState.step`` inside the scan, so
one signal/weather set is shared by broadcast across a vmapped sweep —
or stacked on the batch axis for weather-scenario sweeps.

``external_step`` supports the paper's §4.2 plugin mode: an event-based
external scheduler decides placements between compiled steps.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.cooling import model as cooling
from repro.cooling import weather as wsig
from repro.core import accounts as acct_mod
from repro.core import resource_manager as rm
from repro.core import scheduler as sched
from repro.core import types as T
from repro.events import process as events_mod
from repro.grid import powercap
from repro.grid import signals as gsig
from repro.kernels.power_topo import ops as topo_ops
from repro.obs import timing as obs_timing
from repro.power import losses as plosses
from repro.power import model as pmodel
from repro.systems.config import SystemConfig


# ---------------------------------------------------------------------------
# Initialization (paper §3.2.1 / §3.2.3 prepopulation + dismissal).
# ---------------------------------------------------------------------------
def init_state(system: SystemConfig, table: T.JobTable, t0: float,
               t1: float, accounts: T.AccountStats | None = None,
               num_accounts: int = 64,
               events: "events_mod.EventConfig | None" = None) -> T.SimState:
    """Initial engine state for the window ``[t0, t1]`` (seconds).

    Dismisses jobs entirely outside the window, prepopulates jobs already
    running at ``t0`` per the telemetry, queues jobs submitted but not yet
    started, and starts the cooling loop from its idle-plant condition.
    ``events`` (an ``EventConfig``) rides the carry as an ``EventState``
    subtree; ``None`` keeps the carry identical to the pre-events layout.
    """
    J = table.num_jobs
    rec_end = table.rec_start + table.wall
    jstate = jnp.full((J,), T.PENDING, jnp.int32)

    # dismiss jobs entirely outside the window (paper Fig. 3 discussion)
    dismissed = (~table.valid) | (rec_end <= t0) | (table.submit >= t1)
    jstate = jnp.where(dismissed, T.DISMISSED, jstate)

    # prepopulate jobs running at t0 per the telemetry
    running0 = (~dismissed) & (table.rec_start <= t0) & (rec_end > t0) & \
               (table.first_node >= 0)
    jstate = jnp.where(running0, T.RUNNING, jstate)

    # jobs already submitted but not yet started at t0 join the queue
    queued0 = (~dismissed) & (~running0) & (table.submit <= t0)
    jstate = jnp.where(queued0, T.QUEUED, jstate)

    start = jnp.where(running0, table.rec_start, jnp.inf)
    end = jnp.where(running0, rec_end, jnp.inf)
    node_job = rm.prepopulate(system.n_nodes, table.first_node, table.nodes,
                              running0)
    free_count = jnp.sum((node_job == -1).astype(jnp.int32))
    if accounts is None:
        accounts = T.AccountStats.zeros(num_accounts)
    else:
        # the ledger is embedded in the scan carry, which the AOT runners
        # donate — copy so the caller's warm-start buffers survive the run
        accounts = jax.tree_util.tree_map(jnp.copy, accounts)
    # prepopulated jobs ran unthrottled before the window: work-time
    # progress equals their wall-clock elapsed at t0
    progress = jnp.where(running0, jnp.maximum(t0 - table.rec_start, 0.0),
                         0.0).astype(jnp.float32)
    return T.SimState(
        t=jnp.float32(t0), step=jnp.int32(0), jstate=jstate, start=start,
        end=end, progress=progress,
        jenergy=jnp.zeros((J,), jnp.float32), node_job=node_job,
        free_count=free_count, accounts=accounts,
        cooling=cooling.init_state(system.cooling),
        energy_total=jnp.float32(0.0), energy_it=jnp.float32(0.0),
        energy_loss=jnp.float32(0.0), completed=jnp.float32(0.0),
        emissions_kg=jnp.float32(0.0), energy_cost=jnp.float32(0.0),
        energy_cooling=jnp.float32(0.0), heat_reuse_j=jnp.float32(0.0),
        events=(None if events is None
                else events_mod.init_event_state(system)))


# ---------------------------------------------------------------------------
# Engine phases.
# ---------------------------------------------------------------------------
def _prepare_and_arrivals(system: SystemConfig, table: T.JobTable,
                          st: T.SimState) -> T.SimState:
    """Phases (1)+(2): completions, node release, accounting, arrivals."""
    t = st.t
    done_now = (st.jstate == T.RUNNING) & (t >= st.end)
    node_job = rm.release_done(st.node_job, done_now)
    freed = jnp.sum(jnp.where(done_now, table.nodes, 0))
    jstate = jnp.where(done_now, T.DONE, st.jstate)
    accounts = acct_mod.fold_completions(system, table, st.accounts, done_now,
                                         st.start, st.end, st.jenergy)
    jstate = jnp.where((jstate == T.PENDING) & (table.submit <= t),
                       T.QUEUED, jstate)
    return dataclasses.replace(
        st, jstate=jstate, node_job=node_job,
        free_count=st.free_count + freed, accounts=accounts,
        completed=st.completed + jnp.sum(done_now))


def _tick(system: SystemConfig, table: T.JobTable, st: T.SimState,
          grid: gsig.GridNow | None, cap_active: jnp.ndarray | None,
          wx: wsig.WeatherNow | None = None,
          setpoint_delta_c=0.0,
          thermal: cooling.ThermalNow | None = None,
          cells_offline=0.0, cells_failed=0.0,
          ev_now: "events_mod.EventsNow | None" = None
          ) -> Tuple[T.SimState, T.StepRecord]:
    """Phase (4): cap enforcement + physics + accounting + telemetry.

    When the projected IT draw exceeds ``cap_active`` the DVFS pass
    (repro.grid.powercap) throttles every running node's dynamic power by a
    common factor c and the affected jobs' remaining runtime dilates by 1/c
    for this step — capping trades completion latency for peak power.
    ``grid is None`` is compile-time "no grid layer": no accrual, no
    dilation, and the node->CDU segment reduction fuses with the cooling
    loop update (repro.kernels.power_topo.fused_cooling) — the seed
    engine's exact cost.

    ``wx`` carries the ambient conditions for this step (°C, scalar or
    per-hall f32[H]); ``None`` is compile-time "no weather trace" and the
    static ``CoolingConfig`` wet-bulb applies. ``setpoint_delta_c`` and
    ``cells_offline`` are the traced sweep knobs
    (``Scenario.setpoint_delta_c`` / ``Scenario.cells_offline``).
    ``cells_failed`` / ``ev_now`` arrive from the failure pass
    (repro.events) when the event layer is on — failed cells degrade the
    cooling plant and the telemetry row picks up the outage counters.
    """
    dt = system.dt
    t = st.t
    has_grid = grid is not None
    t_wb = None if wx is None else wx.t_wetbulb_c
    # profiles are indexed by work-time progress, so a throttled job's
    # trace plays at its dilated tempo instead of wall-clock time
    job_pw = pmodel.job_node_power_elapsed(table, st.jstate, st.progress,
                                           system.prof_dt)
    node_pw = pmodel.node_power(system, table, st.node_job, job_pw)
    running = st.jstate == T.RUNNING
    if has_grid:
        idle = system.power.idle_node_w
        cap = powercap.enforce_cap(system, node_pw, cap_active)
        p_it = cap.p_it
        # DVFS only slows jobs with dynamic (above-idle) draw; a job at or
        # below the idle floor keeps full speed (its power is untouched by
        # throttle_power, so its runtime must be too)
        c_job = jnp.where(running & (job_pw > idle), cap.c, 1.0)
        job_pw = powercap.throttle_power(job_pw, idle, cap.c)
        throttle = 1.0 - cap.c
        cool_state, cool = cooling.step(system.cooling, st.cooling,
                                        cap.group_heat, dt, t_wb,
                                        setpoint_delta_c, cells_offline,
                                        cells_failed)
    else:
        cap_active = T.INF
        throttle = jnp.float32(0.0)
        # fused path: hierarchical (node -> CDU -> hall) segment reduce +
        # CDU loop update in one pass; total IT power falls out of the
        # hall sums
        cool_state, cool, p_it = cooling.step_from_node_power(
            system.cooling, st.cooling, node_pw, dt, t_wb, setpoint_delta_c,
            cells_offline, cells_failed)
    n_racks = max(system.n_nodes // system.power.nodes_per_rack, 1)
    p_in, p_loss = plosses.conversion(system.power, p_it, float(n_racks))
    p_cool = cool.p_cooling
    t_tower_ret = cool.t_tower_return
    p_total = p_in + p_cool
    pue = cooling.pue(p_it, p_loss, p_cool)

    job_e_step = jnp.where(
        running, job_pw * table.nodes.astype(jnp.float32) * dt, 0.0)
    jenergy = st.jenergy + job_e_step

    if has_grid:
        accounts = acct_mod.accrue_grid(table, st.accounts, job_e_step,
                                        grid.carbon, grid.price)
        # runtime dilation: a throttled step advances a job's work-time by
        # only c*dt (each unit of work takes 1/c longer), so its projected
        # end recedes by the shortfall dt*(1 - c). The two views agree:
        # t >= end  <=>  progress >= wall.  A job throttled at c for its
        # whole life runs 1/c times longer in total.
        end = jnp.where(running & jnp.isfinite(st.end),
                        st.end + dt * (1.0 - c_job), st.end)
        progress = st.progress + jnp.where(running, c_job * dt, 0.0)
        emissions = p_total * dt * grid.carbon / 3.6e6 * 1e-3  # g/kWh -> kg
        cost = p_total * dt * grid.price / 3.6e6               # $/kWh
    else:
        accounts = st.accounts
        end = st.end
        progress = st.progress + jnp.where(running, dt, 0.0)
        emissions = jnp.float32(0.0)
        cost = jnp.float32(0.0)

    busy = jnp.float32(system.n_nodes) - st.free_count.astype(jnp.float32)
    if ev_now is not None:
        # down free nodes are parked at -2 (outside the -1 free pool), so
        # they'd otherwise count as busy; utilization should count work
        busy = busy - ev_now.nodes_down
    H = system.cooling.n_halls
    rec = T.StepRecord(
        t=t, power_it=p_it, power_loss=p_loss, power_cooling=p_cool,
        power_total=p_total, pue=pue, t_tower_return=t_tower_ret,
        util=busy / system.n_nodes,
        n_queued=jnp.sum(st.jstate == T.QUEUED).astype(jnp.float32),
        n_running=jnp.sum(running).astype(jnp.float32),
        emissions_kg=emissions, energy_cost=cost, cap_w=cap_active,
        throttle_frac=throttle,
        power_fan=cool.p_fan, power_pump=cool.p_pump,
        q_reuse_w=cool.q_reuse_w, t_basin=cool.t_basin,
        t_supply_max=cool.t_supply_max,
        t_wetbulb=(jnp.float32(system.cooling.t_wetbulb_c) if wx is None
                   else jnp.mean(wx.t_wetbulb_c)),
        thermal_throttled=(jnp.float32(0.0) if thermal is None else
                           thermal.overheat.astype(jnp.float32)),
        # per-hall telemetry: the hall heat sums ARE the per-hall IT power
        # (the cooling plant is fed the (throttled) IT draw per group)
        power_it_hall=cool.q_hall_w, t_basin_hall=cool.t_basin_hall,
        t_supply_max_hall=cool.t_supply_max_hall,
        t_wetbulb_hall=cool.t_wetbulb_hall, cells_online=cool.cells_online,
        nodes_down=(jnp.float32(0.0) if ev_now is None
                    else ev_now.nodes_down),
        n_killed=(jnp.float32(0.0) if ev_now is None else ev_now.n_killed),
        overheat_hall=(jnp.zeros((H,), jnp.float32) if thermal is None
                       else thermal.overheat_hall.astype(jnp.float32)))

    new = dataclasses.replace(
        st, t=t + dt, step=st.step + 1, end=end, progress=progress,
        jenergy=jenergy, accounts=accounts, cooling=cool_state,
        energy_total=st.energy_total + p_total * dt,
        energy_it=st.energy_it + p_it * dt,
        energy_loss=st.energy_loss + p_loss * dt,
        emissions_kg=st.emissions_kg + emissions,
        energy_cost=st.energy_cost + cost,
        energy_cooling=st.energy_cooling + p_cool * dt,
        heat_reuse_j=st.heat_reuse_j + cool.q_reuse_w * dt)
    return new, rec


def engine_step(system: SystemConfig, table: T.JobTable, st: T.SimState,
                scen: T.Scenario, signals: gsig.GridSignals | None = None,
                weather: wsig.WeatherSignals | None = None,
                events: "events_mod.EventConfig | None" = None
                ) -> Tuple[T.SimState, T.StepRecord]:
    """One engine step: phases (1)-(4). ``signals`` enables the grid layer,
    ``weather`` drives the cooling tower's ambient wet-bulb, ``events``
    enables the stochastic failure + demand-response layer (repro.events);
    all three are compile-time ``None`` when absent (their machinery folds
    away and the graph is bit-identical to the pre-events engine)."""
    st = _prepare_and_arrivals(system, table, st)
    if events is not None:
        # phase (2b): draw failures/repairs, kill hit jobs, update the
        # availability map; DR cap steps are evaluated at the same point
        st, ev_now = events_mod.apply_failures(events, system, table, st,
                                               scen)
        dr = events_mod.dr_now(scen, st.t)
        cells_failed = ev_now.cells_failed_hall
    else:
        ev_now = None
        dr = None
        cells_failed = 0.0
    wx = None if weather is None else wsig.at_step(weather, st.step)
    # cooling-pressure signals for the thermal_aware policy + admission gate
    thermal = cooling.thermal_now(system.cooling, st.cooling,
                                  scen.setpoint_delta_c)
    if signals is None:
        # no grid layer: skip the admission power pass and cap machinery
        # (demand-response needs the grid path — the CLI injects neutral
        # signals when DR knobs are set without a grid trace)
        st = sched.schedule_step(system, table, st, scen, thermal=thermal)
        return _tick(system, table, st, None, None, wx,
                     scen.setpoint_delta_c, thermal, scen.cells_offline,
                     cells_failed, ev_now)
    grid = gsig.at_step(signals, st.step)
    cap_active = grid.cap_w * scen.cap_scale
    if dr is not None:
        # an active demand-response event caps below the schedule
        cap_active = jnp.minimum(cap_active, dr.cap_now_w)
    # raw IT draw after completions: the cap-aware admission baseline
    job_pw = pmodel.job_node_power_elapsed(table, st.jstate, st.progress,
                                           system.prof_dt)
    node_pw = pmodel.node_power(system, table, st.node_job, job_pw)
    st = sched.schedule_step(system, table, st, scen, grid,
                             proj_pw=pmodel.system_it_power(node_pw),
                             thermal=thermal, dr=dr)
    return _tick(system, table, st, grid, cap_active, wx,
                 scen.setpoint_delta_c, thermal, scen.cells_offline,
                 cells_failed, ev_now)


# ---------------------------------------------------------------------------
# Plugin mode for external event-based schedulers (paper §4.2).
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=(0,))
def external_step(system: SystemConfig, table: T.JobTable, st: T.SimState,
                  place_ids: jnp.ndarray,
                  signals: gsig.GridSignals | None = None,
                  weather: wsig.WeatherSignals | None = None,
                  scen: T.Scenario | None = None
                  ) -> Tuple[T.SimState, T.StepRecord]:
    """One engine step where placement decisions come from outside.

    ``place_ids``: i32[K] job ids the external scheduler wants started now
    (padded with -1). S-RAPS "interprets the information returned from the
    scheduler ... and triggers the resource manager" (paper §3.2.4).
    The cap schedule (when ``signals`` is given) and the thermal admission
    gate still apply — an external scheduler cannot opt out of facility
    power or thermal management.

    ``scen`` routes the facility what-if knobs the external scheduler has
    no say over — ``cap_scale`` (scales the cap schedule),
    ``setpoint_delta_c`` (shifts the supply setpoint the overheat gate
    measures against) and ``cells_offline`` (tower maintenance); ``None``
    keeps every knob neutral. Policy/backfill fields are ignored: the
    external peer IS the policy.
    """
    grid = None if signals is None else gsig.at_step(signals, st.step)
    wx = None if weather is None else wsig.at_step(weather, st.step)
    setpoint_delta = 0.0 if scen is None else scen.setpoint_delta_c
    cells_offline = 0.0 if scen is None else scen.cells_offline
    cap_scale = 1.0 if scen is None else scen.cap_scale
    st = _prepare_and_arrivals(system, table, st)
    thermal = cooling.thermal_now(system.cooling, st.cooling, setpoint_delta)
    thermal_ok = ~thermal.overheat
    hall_aware = system.cooling.n_halls > 1
    if hall_aware:
        order_nodes, node_ok, free_ok0 = sched.hall_placement_plan(
            system, st, thermal, is_replay=False)
    else:
        free_ok0 = st.free_count

    def body(i, carry):
        node_job, jstate, start, end, free_count, free_ok = carry
        j = place_ids[i]
        ok = j >= 0
        jj = jnp.maximum(j, 0)
        need = table.nodes[jj]
        th_ok = (need <= free_ok) if hall_aware else thermal_ok
        can = ok & (jstate[jj] == T.QUEUED) & (need <= free_count) & th_ok
        if hall_aware:
            sel = rm.firstfree_mask_ordered(node_job, need, order_nodes)
        else:
            sel = rm.firstfree_mask(node_job, need)
        node_job = rm.place(node_job, sel, jj, can)
        free_count = free_count - jnp.where(can, need, 0)
        if hall_aware:
            free_ok = free_ok - jnp.where(
                can, jnp.sum((sel & node_ok).astype(jnp.int32)), 0)
        # (inert carry on a flat plant — the global gate never reads it)
        jstate = jstate.at[jj].set(jnp.where(can, T.RUNNING, jstate[jj]))
        start = start.at[jj].set(jnp.where(can, st.t, start[jj]))
        end = end.at[jj].set(jnp.where(can, st.t + table.wall[jj], end[jj]))
        return node_job, jstate, start, end, free_count, free_ok

    carry = (st.node_job, st.jstate, st.start, st.end, st.free_count,
             jnp.int32(free_ok0))
    node_job, jstate, start, end, free_count, _ = jax.lax.fori_loop(
        0, place_ids.shape[0], body, carry)
    st = dataclasses.replace(st, jstate=jstate, start=start, end=end,
                             node_job=node_job, free_count=free_count)
    return _tick(system, table, st, grid,
                 None if grid is None else grid.cap_w * cap_scale, wx,
                 setpoint_delta, thermal, cells_offline)


# ---------------------------------------------------------------------------
# Full simulation.
# ---------------------------------------------------------------------------
# Buffer donation on the scan-carry runners: the input carry and the
# output carry are the same SimState pytree, so XLA can write the scan
# in place instead of allocating a second full copy of the (node map +
# job lifecycle + ledgers) state per call. Donated *inputs* are
# consumed — every runner below either builds its carry fresh
# (init_state / jnp.stack) or its callers treat the passed carry as
# moved-from (repro.serve reassigns; see docs/serving.md). Only the
# carry argument is donated: tables/signals are broadcast inputs reused
# across calls, and the sweep runners' broadcast st0 cannot alias their
# batched output. REPRO_NO_DONATE=1 disables donation for debugging
# (e.g. to inspect a carry after a call that consumed it).
DONATE_CARRIES = not os.environ.get("REPRO_NO_DONATE")


def _donate(*argnums: int) -> tuple:
    return tuple(argnums) if DONATE_CARRIES else ()


@functools.partial(jax.jit, static_argnums=(0, 6, 7),
                   donate_argnums=_donate(2))
def _simulate_jit(system: SystemConfig, table: T.JobTable, st0: T.SimState,
                  scen: T.Scenario, signals: gsig.GridSignals | None,
                  weather: wsig.WeatherSignals | None, n_steps: int,
                  events: "events_mod.EventConfig | None" = None):
    # signals/weather=None are empty pytrees and events=None is a static
    # arg: the no-grid / no-weather / no-failure fast paths in engine_step
    # are selected at trace time and their machinery vanishes entirely
    def body(st, _):
        return engine_step(system, table, st, scen, signals, weather, events)
    return jax.lax.scan(body, st0, None, length=n_steps)


def _simulate_observed(system, table, st0, scen, signals, weather,
                       n_steps: int, timer, events=None
                       ) -> Tuple[T.SimState, T.StepRecord]:
    """Opt-in observed run: AOT lower/compile so the jit **compile** phase
    is a separate span from the scan **execute** phase (a plain jit call
    fuses both into the first invocation, which is exactly the number a
    flight recorder must split). Uncached on purpose — the observed path
    is for one-shot CLI runs; hot callers never land here because they
    install no timer."""
    meta = {"system": system.name, "n_steps": int(n_steps)}
    with timer.span("engine.lower", **meta):
        lowered = _simulate_jit.lower(system, table, st0, scen, signals,
                                      weather, n_steps, events)
    with timer.span("engine.compile", **meta):
        compiled = lowered.compile()
    with timer.span("engine.scan", **meta):
        out = jax.block_until_ready(
            compiled(table, st0, scen, signals, weather))
    return out


def simulate(system: SystemConfig, table: T.JobTable, scen: T.Scenario,
             t0: float, t1: float,
             accounts: T.AccountStats | None = None,
             num_accounts: int = 64,
             signals: gsig.GridSignals | None = None,
             weather: wsig.WeatherSignals | None = None,
             carry: T.SimState | None = None,
             events: "events_mod.EventConfig | None" = None
             ) -> Tuple[T.SimState, T.StepRecord]:
    """Run the twin from ``t0`` to ``t1`` (seconds).

    Args:
      system: static machine description (compile-time constant).
      table: padded job table (times s, power W).
      scen: traced scenario knobs (policy, backfill, weights).
      t0, t1: simulation window (s).
      accounts: optional warm-start per-account ledgers.
      num_accounts: ledger size when ``accounts`` is None.
      signals: per-step grid signals (g CO2/kWh, $/kWh, cap W) — enables
        carbon/price accounting, the facility power-cap schedule and the
        grid-aware policies. ``None`` = neutral (zero carbon/price,
        uncapped).
      weather: per-step ambient conditions (°C) driving the cooling tower.
        ``None`` = the static ``CoolingConfig.t_wetbulb_c``.
      carry: start from this scan carry instead of ``init_state`` (the
        resume-from-checkpoint path, repro.serve). ``t0``/``t1`` still
        size the window: ``n_steps = (t1 - t0) / dt`` steps run *from
        the carry's own clock*.
      events: static ``EventConfig`` enabling the stochastic failure +
        demand-response layer (repro.events); the per-scenario rates and
        seeds stay traced ``Scenario`` knobs. ``None`` = bit-identical
        pre-events engine. A passed ``carry`` must match (its ``events``
        subtree present iff an ``EventConfig`` is given).
    Returns:
      (final SimState, StepRecord history with one row per step).
    """
    n_steps = int(round((t1 - t0) / system.dt))
    st0 = (init_state(system, table, t0, t1, accounts, num_accounts, events)
           if carry is None else carry)
    timer = obs_timing.current()
    if timer is not None:
        return _simulate_observed(system, table, st0, scen, signals,
                                  weather, n_steps, timer, events)
    return _simulate_jit(system, table, st0, scen, signals, weather, n_steps,
                         events)


_STATIC_CACHE: dict = {}


def simulate_static(system: SystemConfig, table: T.JobTable, policy: str,
                    backfill: str, t0: float, t1: float,
                    accounts: T.AccountStats | None = None,
                    num_accounts: int = 64,
                    signals: gsig.GridSignals | None = None,
                    weather: wsig.WeatherSignals | None = None,
                    carry: T.SimState | None = None):
    """Single-scenario fast path: policy/backfill are *compile-time*
    constants, so only the selected priority key is computed, non-EASY runs
    skip the reservation machinery entirely, and all policy selects fold
    away (docs/architecture.md, "The engine is a single lax.scan").

    ``carry`` starts the scan from an arbitrary checkpointed state
    instead of ``init_state`` (see ``simulate``)."""
    n_steps = int(round((t1 - t0) / system.dt))
    # keyword/default construction with raw Python values (-> static in
    # the closure): every knob past policy/backfill takes its declared
    # neutral default, so growing Scenario can never silently shift knobs
    scen = T.Scenario(policy=T.POLICY_NAMES[policy],
                      backfill=T.BACKFILL_NAMES[backfill])
    key = (system, policy, backfill, n_steps, table.num_jobs,
           table.prof_len, num_accounts, signals is None, weather is None)
    fn = _STATIC_CACHE.get(key)
    timer = obs_timing.current()
    hit = fn is not None
    if fn is None:
        def run(table_, st0_, signals_, weather_):
            def body(st, _):
                return engine_step(system, table_, st, scen, signals_,
                                   weather_)
            return jax.lax.scan(body, st0_, None, length=n_steps)
        fn = jax.jit(run, donate_argnums=_donate(1))
        _STATIC_CACHE[key] = fn
    st0 = (init_state(system, table, t0, t1, accounts, num_accounts)
           if carry is None else carry)
    if timer is None:
        return fn(table, st0, signals, weather)
    # observed path (opt-in): split compile from execute via AOT on a cache
    # miss; a warm hit only times the scan. The AOT executable is NOT
    # cached — the key above doesn't capture signal/weather array shapes,
    # and jit (the cached object) re-specializes on those by itself.
    timer.count("static_cache_hit" if hit else "static_cache_miss")
    meta = {"system": system.name, "policy": policy, "n_steps": int(n_steps)}
    if hit:
        with timer.span("engine.scan", **meta):
            return jax.block_until_ready(fn(table, st0, signals, weather))
    with timer.span("engine.lower", **meta):
        lowered = fn.lower(table, st0, signals, weather)
    with timer.span("engine.compile", **meta):
        compiled = lowered.compile()
    with timer.span("engine.scan", **meta):
        return jax.block_until_ready(compiled(table, st0, signals, weather))


# Jitted-runner cache shared by the sweep, sharded-sweep and segment
# paths, keyed on (kind, system, n_steps, ...). Bounded: a long-lived
# server (repro.serve) advancing many distinct segment lengths would
# otherwise grow it without limit — least-recently-used entries are
# evicted past ``SWEEP_CACHE_LIMIT`` (dropping a compiled runner is
# safe: the next same-shape call re-jits and re-enters the cache).
_SWEEP_CACHE: "collections.OrderedDict" = collections.OrderedDict()
SWEEP_CACHE_LIMIT = 32
# Monotonic hit/miss/eviction counters over the jitted runner cache. A
# steady-state training loop should show hits only after generation 0;
# ``ml.train`` snapshots the deltas per generation and the run manifest
# embeds the totals.
SWEEP_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def _cache_lookup(key):
    """LRU lookup in the runner cache; bumps the hit/miss counters."""
    fn = _SWEEP_CACHE.get(key)
    if fn is not None:
        _SWEEP_CACHE.move_to_end(key)
    SWEEP_CACHE_STATS["hits" if fn is not None else "misses"] += 1
    return fn


def _cache_store(key, fn):
    """Insert a runner, evicting least-recently-used entries past the
    bound (counted in ``SWEEP_CACHE_STATS["evictions"]``)."""
    _SWEEP_CACHE[key] = fn
    _SWEEP_CACHE.move_to_end(key)
    while len(_SWEEP_CACHE) > SWEEP_CACHE_LIMIT:
        _SWEEP_CACHE.popitem(last=False)
        SWEEP_CACHE_STATS["evictions"] += 1
    return fn


def _sweep_fn(system: SystemConfig, n_steps: int, w_axis, events=None):
    """Cached jitted sweep runner keyed on (system, horizon, weather axis,
    event layer).

    ``jax.jit`` caches traces per *function identity*; defining the runner
    inside ``simulate_sweep`` would re-jit on every call. Caching it here
    means repeated same-shape sweeps — notably the per-generation rollouts
    of the ES training loop (repro.ml.train) — compile once and then run
    at steady-state throughput."""
    key = (system, n_steps, w_axis, events)
    fn = _cache_lookup(key)
    if fn is None:
        @jax.jit
        def fn(table_, st0_, scen_, signals_, weather_):
            def one(scen1, weather1):
                def body(st, _):
                    return engine_step(system, table_, st, scen1, signals_,
                                       weather1, events)
                return jax.lax.scan(body, st0_, None, length=n_steps)
            return jax.vmap(one, in_axes=(0, w_axis))(scen_, weather_)
        _cache_store(key, fn)
    return fn


def simulate_sweep(system: SystemConfig, table: T.JobTable,
                   scens: list[T.Scenario], t0: float, t1: float,
                   accounts: T.AccountStats | None = None,
                   num_accounts: int = 64,
                   signals: gsig.GridSignals | None = None,
                   weather=None,
                   events: "events_mod.EventConfig | None" = None,
                   ) -> Tuple[T.SimState, T.StepRecord]:
    """Vectorized what-if sweep: one compiled program, S scenarios.

    The job table, initial state and grid signals are shared (broadcast);
    only the Scenario leaves carry a batch axis — so a (policy x cap-level
    x carbon-weight) sweep reads ONE signal set and scales the cap via
    ``Scenario.cap_scale``.

    ``weather`` may be a single ``WeatherSignals`` (shared by broadcast,
    like signals) or a *list* with one trace per scenario — stacked onto
    the batch axis so a (policy x weather-scenario x setpoint) sweep runs
    as one vmapped program (see examples/cooling_whatif.py).

    ``events`` (static ``EventConfig``) turns on the failure layer for the
    whole sweep; each scenario row then carries its own failure universe
    through the traced ``failure_seed``/rate knobs — a (seed x rate x
    demand-response) risk grid is one compiled program.
    """
    n_steps = int(round((t1 - t0) / system.dt))
    st0 = init_state(system, table, t0, t1, accounts, num_accounts, events)
    batched = T.stack_scenarios(scens)
    if isinstance(weather, (list, tuple)):
        if len(weather) != len(scens):
            raise ValueError(f"need one weather trace per scenario: "
                             f"{len(weather)} != {len(scens)}")
        weather_b, w_axis = wsig.stack_weather(weather), 0
    else:
        weather_b, w_axis = weather, None

    run = _sweep_fn(system, n_steps, w_axis, events)
    return run(table, st0, batched, signals, weather_b)


def simulate_sweep_sharded(system: SystemConfig, table: T.JobTable,
                           scens: list[T.Scenario], t0: float, t1: float,
                           accounts: T.AccountStats | None = None,
                           num_accounts: int = 64,
                           signals: gsig.GridSignals | None = None,
                           weather=None,
                           events: "events_mod.EventConfig | None" = None,
                           ) -> Tuple[T.SimState, T.StepRecord]:
    """``simulate_sweep`` with the scenario axis sharded across devices.

    One ``shard_map`` over a 1-D ``("scenario",)`` mesh
    (repro.parallel.sharding.sweep_mesh): each device scans its slice of
    the scenario batch with the job table, initial state and grid signals
    replicated — scenario rows never communicate, so the program contains
    no collectives and scales linearly across hosts. Per-scenario weather
    (a list, possibly hall-stacked — see ``cooling.weather.stack_halls``)
    is sharded with the scenarios. The batch is padded to the device
    count by replicating the last scenario; padded rows are sliced off
    the result. With a single device this degenerates to exactly
    ``simulate_sweep`` (one vmapped program, no sharding machinery).
    """
    from jax.experimental.shard_map import shard_map

    from repro.parallel import sharding as psh

    n_dev = len(jax.devices())
    if n_dev <= 1:
        return simulate_sweep(system, table, scens, t0, t1, accounts,
                              num_accounts, signals, weather, events)
    n_steps = int(round((t1 - t0) / system.dt))
    st0 = init_state(system, table, t0, t1, accounts, num_accounts, events)
    batched = T.stack_scenarios(scens)
    if isinstance(weather, (list, tuple)):
        if len(weather) != len(scens):
            raise ValueError(f"need one weather trace per scenario: "
                             f"{len(weather)} != {len(scens)}")
        weather_b, w_axis = wsig.stack_weather(weather), 0
    else:
        weather_b, w_axis = weather, None

    S = len(scens)
    batched, _ = psh.pad_leading_axis(batched, n_dev)
    if w_axis == 0:
        weather_b, _ = psh.pad_leading_axis(weather_b, n_dev)

    # compiled-program cache, same rationale as _sweep_fn: per-generation
    # training rollouts re-enter here with identical shapes
    key = ("sharded", system, n_steps, w_axis, n_dev, events)
    run = _cache_lookup(key)
    if run is None:
        mesh = psh.sweep_mesh()
        scen_spec = psh.scenario_spec()
        w_spec = scen_spec if w_axis == 0 else jax.sharding.PartitionSpec()
        rep = jax.sharding.PartitionSpec()

        @jax.jit
        def run(table_, st0_, scen_, signals_, weather_):
            def shard(table_s, st0_s, scen_s, signals_s, weather_s):
                def one(scen1, weather1):
                    def body(st, _):
                        return engine_step(system, table_s, st, scen1,
                                           signals_s, weather1, events)
                    return jax.lax.scan(body, st0_s, None, length=n_steps)
                return jax.vmap(one, in_axes=(0, w_axis))(scen_s, weather_s)
            return shard_map(shard, mesh=mesh,
                             in_specs=(rep, rep, scen_spec, rep, w_spec),
                             out_specs=scen_spec)(
                table_, st0_, scen_, signals_, weather_)
        _cache_store(key, run)

    final, hist = run(table, st0, batched, signals, weather_b)
    trim = lambda x: x[:S]
    return (jax.tree_util.tree_map(trim, final),
            jax.tree_util.tree_map(trim, hist))


# ---------------------------------------------------------------------------
# Segment simulation (resume-from-checkpoint; repro.serve).
# ---------------------------------------------------------------------------
def simulate_segment(system: SystemConfig, table: T.JobTable,
                     carry: T.SimState, scen: T.Scenario, n_steps: int,
                     signals: gsig.GridSignals | None = None,
                     weather: wsig.WeatherSignals | None = None,
                     events: "events_mod.EventConfig | None" = None
                     ) -> Tuple[T.SimState, T.StepRecord]:
    """Advance the twin ``n_steps`` from an arbitrary scan carry.

    The carry IS the complete simulation state (``SimState`` holds the
    job lifecycle, node occupancy, account ledgers, the transient
    ``CoolingState`` and the step cursor), so chaining segments is
    bit-identical to one uninterrupted ``simulate`` scan: the per-step
    body is the same ``engine_step`` and per-step environment inputs
    (grid signals, weather) are gathered at the carry's *absolute*
    ``step`` cursor — pass the same full-horizon arrays to every
    segment. This is the persistent-server primitive: checkpoint the
    carry at interval boundaries, resume or fork later without
    re-simulating the prefix (``repro.serve``, docs/serving.md).

    Args:
      system: static machine description (compile-time constant).
      table: padded job table shared by every segment.
      carry: the scan carry to start from — ``init_state(...)`` for a
        fresh trajectory, or any previously returned carry.
      scen: traced scenario knobs for *this* segment (a fork changes
        them mid-trajectory).
      n_steps: number of engine steps to advance.
      signals / weather: full-horizon per-step inputs (indexed by the
        carry's absolute step, clamped LOCF past the end).
      events: static ``EventConfig``; must match the carry's lineage (an
        ``EventState`` subtree is present iff the layer is on). Serve
        sessions use this to fork failure-injected branches.
    Returns:
      (carry after ``n_steps``, StepRecord history of the segment).
    """
    key = ("segment", system, int(n_steps), events)
    fn = _cache_lookup(key)
    if fn is None:
        @functools.partial(jax.jit, donate_argnums=_donate(1))
        def fn(table_, carry_, scen_, signals_, weather_):
            def body(st, _):
                return engine_step(system, table_, st, scen_, signals_,
                                   weather_, events)
            return jax.lax.scan(body, carry_, None, length=int(n_steps))
        _cache_store(key, fn)
    return fn(table, carry, scen, signals, weather)


def simulate_segment_sweep(system: SystemConfig, table: T.JobTable,
                           carries, scens, n_steps: int,
                           signals: gsig.GridSignals | None = None,
                           weather: wsig.WeatherSignals | None = None,
                           events: "events_mod.EventConfig | None" = None
                           ) -> Tuple[T.SimState, T.StepRecord]:
    """Batched ``simulate_segment``: B divergent branches as one program.

    Unlike ``simulate_sweep`` (one shared ``init_state`` broadcast), the
    *carry* rides the batch axis too, so branches that have already
    diverged — different fork points, different histories — advance
    together: one compiled program per (system, segment length), B
    lock-stepped scans inside. This is what lets a serving session
    coalesce concurrent client what-ifs into a single dispatch
    (repro.serve.session).

    Args:
      carries: list of ``SimState`` carries (stacked on axis 0), one per
        branch. All must come from the same (system, table) lineage.
      scens: list of ``Scenario``, one per branch.
      n_steps: segment length shared by the batch.
    Returns:
      (stacked carries after ``n_steps``, stacked StepRecord histories).
    """
    if len(carries) != len(scens):
        raise ValueError(f"need one carry per scenario: "
                         f"{len(carries)} != {len(scens)}")
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)
    batched = T.stack_scenarios(list(scens))
    key = ("segment_sweep", system, int(n_steps), events)
    fn = _cache_lookup(key)
    if fn is None:
        # the stacked carries are a fresh jnp.stack buffer every call, so
        # donating them is always safe and saves the B-branch copy
        @functools.partial(jax.jit, donate_argnums=_donate(1))
        def fn(table_, carries_, scen_, signals_, weather_):
            def one(carry1, scen1):
                def body(st, _):
                    return engine_step(system, table_, st, scen1, signals_,
                                       weather_, events)
                return jax.lax.scan(body, carry1, None, length=int(n_steps))
            return jax.vmap(one, in_axes=(0, 0))(carries_, scen_)
        _cache_store(key, fn)
    return fn(table, stacked, batched, signals, weather)
