"""Out-of-process scheduler peers: socket/subprocess transport (paper §4.2).

The bridge in ``core/external.py`` speaks a versioned wire format
(``WIRE_VERSION`` envelopes) but until now every peer ran in-process.
This module carries those same envelopes across a real process boundary:
newline-delimited JSON frames (one envelope per line, UTF-8) over a
Unix-domain or TCP socket, so a second scheduler implementation — any
language that can read lines of JSON — can couple to the twin.

Wire protocol (full reference: docs/external-scheduling.md)
-----------------------------------------------------------
Every frame is one JSON object terminated by ``\\n``; every object
carries ``version`` (must equal ``WIRE_VERSION``) and ``kind``:

=================  =========  ==============================================
kind               direction  payload
=================  =========  ==============================================
``hello``          peer→twin  sent once on connect: ``name``, optional ``pid``
``reset``          twin→peer  ``t0``, ``policy``, ``backfill``,
                              ``system`` (``n_nodes``, ``dt``, ``name``),
                              ``jobs`` (submit/limit/wall/nodes/priority/
                              account columns), ``system_digest``,
                              ``job_digest``
``reset_ack``      peer→twin  echoes both digests *recomputed by the peer*
                              plus ``n_jobs``
``poll``           twin→peer  ``t`` — simulated seconds
``running_set``    peer→twin  ``job_ids`` (``external.encode_running``)
``schedule_req``   twin→peer  sequential mode: ask for the full schedule
``schedule``       peer→twin  ``start``: per-job start seconds, ``null``
                              for never-started
``bye``            twin→peer  clean shutdown request
``error``          peer→twin  ``message`` — surfaced as ``ProtocolError``
=================  =========  ==============================================

The handshake is digest-checked: the twin sends canonical whole-second
job columns (the SWF contract — ``datasets/swf.py``) and the sha256 the
peer must recompute from *what it actually deserialized*; a mismatched
echo raises ``ProtocolError`` before any poll touches engine state.

Failure model
-------------
Framing/parse problems (garbage, truncated line, over-long frame, wrong
version, digest mismatch) raise ``ProtocolError`` — the peer speaks the
wrong dialect and is not retried. Connection problems (EOF from a dead
peer, socket timeout from a hung one) raise ``ConnectionError`` /
``TimeoutError`` — ``SchedulerBridge`` heals those by calling ``reset``
again, which for these peers means *re-dial* (``SocketPeer``) or
*kill, reap and respawn* (``SubprocessPeer``) followed by a full state
resync. ``SubprocessPeer`` keeps every ``Popen`` it ever spawned in
``spawned`` and reaps them all on ``close()`` — no zombies, ever.

``tools/reference_peer.py`` is the stdlib-only reference implementation
of the peer side (FastSimLike semantics), runnable as
``python -m tools.reference_peer``.
"""
from __future__ import annotations

import hashlib
import json
import os
import shlex
import shutil
import socket
import subprocess
import tempfile
from dataclasses import dataclass, field
from typing import IO

import numpy as np

from repro.core.external import (WIRE_VERSION, ProtocolError, decode_running)
from repro.datasets.base import JobSet
from repro.systems.config import SystemConfig

# Sized for the biggest legitimate frame: a reset envelope carries six
# full job columns (~100 bytes/job of JSON), so ~1e6 jobs fits with
# headroom. Anything past this is a confused peer, not a big answer —
# and write_frame enforces the same cap outbound, so an oversized twin
# payload fails loudly here instead of as a peer-side parse error.
MAX_FRAME_BYTES = 256 << 20


# ---------------------------------------------------------------------------
# Canonical digests (handshake).
# ---------------------------------------------------------------------------
def _digest(obj) -> str:
    """sha256 over the canonical (sorted-keys, no-spaces) JSON of ``obj``."""
    blob = json.dumps(obj, separators=(",", ":"), sort_keys=True)
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def system_digest(system: SystemConfig) -> str:
    """Digest of the system parameters a peer's schedule depends on."""
    return _digest({"v": WIRE_VERSION, "n_nodes": int(system.n_nodes),
                    "dt": float(system.dt)})


def job_digest(jobs: JobSet) -> str:
    """Digest of the SWF-preserved job columns, whole-second rounded.

    Only the columns the SWF roundtrip guarantees (submit / limit / wall /
    nodes / account — ``datasets/swf.py``) participate, rounded to whole
    seconds with banker's rounding (what both ``round`` and the SWF
    writer's ``:.0f`` do), so a peer that loaded the same trace from an
    SWF file computes the same digest as one fed over the wire.
    """
    def whole(col):  # np.round is half-even, same as round() peer-side
        return np.round(np.asarray(col)).astype(np.int64).tolist()

    return _digest({"v": WIRE_VERSION, "jobs": {
        "submit": whole(jobs.submit),
        "limit": whole(jobs.limit),
        "wall": whole(jobs.wall),
        "nodes": np.asarray(jobs.nodes).astype(np.int64).tolist(),
        "account": np.asarray(jobs.account).astype(np.int64).tolist(),
    }})


# ---------------------------------------------------------------------------
# NDJSON framing.
# ---------------------------------------------------------------------------
@dataclass
class WireCounters:
    """Monotonic per-connection framing counters (flight-recorder food).

    Counted at the framing layer so every peer kind (socket, subprocess,
    metrics sink) shares one definition of a frame/byte. ``bytes_in``
    counts delivered frames only — a rejected over-long or truncated line
    bumps ``frames_rejected`` instead, so in/out byte counts stay
    comparable across the twin and a compliant peer.
    """
    frames_out: int = 0
    bytes_out: int = 0
    frames_in: int = 0
    bytes_in: int = 0
    frames_rejected: int = 0

    def as_dict(self) -> dict:
        return {"frames_out": self.frames_out, "bytes_out": self.bytes_out,
                "frames_in": self.frames_in, "bytes_in": self.bytes_in,
                "frames_rejected": self.frames_rejected}


def write_frame(wfile: IO[bytes], msg: dict,
                counters: WireCounters | None = None) -> None:
    """Write one envelope as a newline-terminated JSON frame and flush.

    Enforces ``MAX_FRAME_BYTES`` outbound too: a compliant peer would
    reject an over-long line anyway, so failing here turns a confusing
    remote parse error into a local, diagnosable one."""
    line = json.dumps(msg, separators=(",", ":")).encode("utf-8") + b"\n"
    if len(line) > MAX_FRAME_BYTES:
        if counters is not None:
            counters.frames_rejected += 1
        raise ProtocolError(
            f"outbound {msg.get('kind')!r} frame is {len(line)} bytes, "
            f"over the {MAX_FRAME_BYTES}-byte protocol cap")
    wfile.write(line)
    wfile.flush()
    if counters is not None:
        counters.frames_out += 1
        counters.bytes_out += len(line)


def read_frame(rfile: IO[bytes],
               counters: WireCounters | None = None) -> dict:
    """Read one envelope; classify every way a peer can get it wrong.

    EOF (peer died) raises ``ConnectionError`` — a transport failure the
    bridge may heal by reconnecting. A frame that *arrives* but is
    over-long, truncated (no newline before EOF), non-JSON, or not an
    object raises ``ProtocolError`` — broken speech is not retried.
    Socket timeouts propagate as ``TimeoutError`` from the underlying
    file object.
    """
    line = rfile.readline(MAX_FRAME_BYTES + 1)
    if not line:
        raise ConnectionError("peer closed the connection (EOF)")
    if len(line) > MAX_FRAME_BYTES:
        if counters is not None:
            counters.frames_rejected += 1
        raise ProtocolError(f"frame exceeds {MAX_FRAME_BYTES} bytes")
    try:
        if not line.endswith(b"\n"):
            raise ProtocolError("truncated frame: EOF before newline")
        try:
            msg = json.loads(line)
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise ProtocolError(f"frame is not JSON: {e}") from e
        if not isinstance(msg, dict):
            raise ProtocolError(f"frame must be a JSON object, got "
                                f"{type(msg).__name__}")
    except ProtocolError:
        if counters is not None:
            counters.frames_rejected += 1
        raise
    if counters is not None:
        counters.frames_in += 1
        counters.bytes_in += len(line)
    return msg


def decode_schedule(msg: dict, n_jobs: int) -> np.ndarray:
    """Validate a ``schedule`` envelope; return start times (inf = never)."""
    if msg.get("version") != WIRE_VERSION:
        raise ProtocolError(f"wire version mismatch: peer speaks "
                            f"{msg.get('version')!r}")
    if msg.get("kind") != "schedule":
        raise ProtocolError(f"unexpected message kind {msg.get('kind')!r}")
    start = msg.get("start")
    if not isinstance(start, list) or len(start) != n_jobs:
        raise ProtocolError(f"schedule must list {n_jobs} start times, got "
                            f"{type(start).__name__}"
                            f"{'' if not isinstance(start, list) else f'[{len(start)}]'}")
    out = np.full((n_jobs,), np.inf, np.float64)
    for j, s in enumerate(start):
        if s is None:
            continue
        if not isinstance(s, (int, float)) or isinstance(s, bool):
            raise ProtocolError(f"schedule start[{j}] must be a number or "
                                f"null, got {type(s).__name__}")
        try:
            val = float(s)
        except OverflowError as e:  # arbitrary-precision JSON integer
            raise ProtocolError(f"schedule start[{j}] out of float "
                                f"range") from e
        if not np.isfinite(val):
            # json.loads accepts non-standard NaN/Infinity tokens; a
            # never-started job is spelled null, so a non-finite number
            # is a confused peer, not a big start time
            raise ProtocolError(f"schedule start[{j}] must be finite or "
                                f"null, got {s!r}")
        out[j] = val
    return out


def parse_address(addr: str) -> tuple[int, str | tuple[str, int]]:
    """``unix:/path`` or a bare path → AF_UNIX; ``host:port`` → TCP."""
    if addr.startswith("unix:"):
        return socket.AF_UNIX, addr[len("unix:"):]
    if addr.startswith("tcp:"):
        addr = addr[len("tcp:"):]
    if "/" in addr:
        return socket.AF_UNIX, addr
    host, _, port = addr.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"address must be unix:/path or host:port, "
                         f"got {addr!r}")
    return socket.AF_INET, (host, int(port))


def format_address(family: int, sockaddr) -> str:
    if family == getattr(socket, "AF_UNIX", -1):
        return f"unix:{sockaddr}"
    host, port = sockaddr
    return f"{host}:{port}"


# ---------------------------------------------------------------------------
# Client side: ExternalScheduler over a socket.
# ---------------------------------------------------------------------------
@dataclass
class SocketPeer:
    """``ExternalScheduler`` whose brain lives across a socket.

    ``reset`` (re)establishes the session from scratch — dial, ``hello``
    handshake, digest-checked ``reset`` exchange — which is exactly the
    resync ``SchedulerBridge`` needs its reconnect path to perform, so a
    mid-stream death or hang heals transparently. Plugs into
    ``run_plugin_mode`` / ``run_sequential_mode`` unchanged (the process
    boundary is behaviorally invisible).
    """
    address: str | None = None
    policy: str = "fcfs"
    backfill: str = "firstfit"
    timeout_s: float = 30.0            # per-reply socket budget
    handshake_timeout_s: float = 20.0  # connect + hello + reset_ack budget
    peer_hello: dict | None = None
    counters: WireCounters = field(default_factory=WireCounters)
    dials: int = 0                     # connection (re)establishments
    _sock: socket.socket | None = None
    _rfile: IO[bytes] | None = None
    _wfile: IO[bytes] | None = None
    _n_jobs: int = 0

    # -- connection lifecycle ----------------------------------------------
    def _dial(self) -> socket.socket:
        if self.address is None:
            raise ValueError("SocketPeer needs an address")
        family, sockaddr = parse_address(self.address)
        sock = socket.socket(family, socket.SOCK_STREAM)
        sock.settimeout(self.handshake_timeout_s)
        sock.connect(sockaddr)
        self.dials += 1
        return sock

    def _attach(self, sock: socket.socket) -> None:
        """Adopt a connected socket: buffered files + hello validation."""
        self._sock = sock
        self._rfile = sock.makefile("rb")
        self._wfile = sock.makefile("wb")
        hello = read_frame(self._rfile, self.counters)
        if hello.get("kind") != "hello":
            raise ProtocolError(f"expected hello, got "
                                f"{hello.get('kind')!r}")
        if hello.get("version") != WIRE_VERSION:
            raise ProtocolError(
                f"wire version mismatch: peer speaks "
                f"{hello.get('version')!r}, bridge speaks {WIRE_VERSION}")
        self.peer_hello = hello

    def _teardown_connection(self) -> None:
        for f in (self._wfile, self._rfile, self._sock):
            if f is not None:
                try:
                    f.close()
                except OSError:
                    pass
        self._sock = self._rfile = self._wfile = None

    def _establish(self) -> None:
        self._attach(self._dial())

    # -- ExternalScheduler protocol ----------------------------------------
    def reset(self, system: SystemConfig, jobs: JobSet, t0: float) -> None:
        """Fresh session: (re)connect, handshake, digest-checked resync."""
        self._teardown_connection()
        try:
            self._establish()
            self._n_jobs = len(jobs)
            sys_d, job_d = system_digest(system), job_digest(jobs)
            self._send({
                "version": WIRE_VERSION, "kind": "reset", "t0": float(t0),
                "policy": self.policy, "backfill": self.backfill,
                "system": {"n_nodes": int(system.n_nodes),
                           "dt": float(system.dt), "name": system.name},
                "system_digest": sys_d, "job_digest": job_d,
                "jobs": {
                    # .tolist() yields native floats/ints losslessly and
                    # avoids per-element numpy-scalar boxing on big sets
                    "submit": np.asarray(jobs.submit, np.float64).tolist(),
                    "limit": np.asarray(jobs.limit, np.float64).tolist(),
                    "wall": np.asarray(jobs.wall, np.float64).tolist(),
                    "nodes": np.asarray(jobs.nodes,
                                        np.int64).tolist(),
                    "priority": np.asarray(jobs.priority,
                                           np.float64).tolist(),
                    "account": np.asarray(jobs.account,
                                          np.int64).tolist(),
                },
            })
            ack = self._recv()
            if ack.get("kind") == "error":
                raise ProtocolError(f"peer rejected reset: "
                                    f"{ack.get('message')!r}")
            if ack.get("kind") != "reset_ack":
                raise ProtocolError(f"expected reset_ack, got "
                                    f"{ack.get('kind')!r}")
            if ack.get("version") != WIRE_VERSION:
                raise ProtocolError(f"wire version mismatch in reset_ack: "
                                    f"{ack.get('version')!r}")
            if ack.get("n_jobs") != len(jobs):
                raise ProtocolError(f"peer deserialized {ack.get('n_jobs')!r}"
                                    f" jobs, sent {len(jobs)}")
            if ack.get("system_digest") != sys_d or \
                    ack.get("job_digest") != job_d:
                raise ProtocolError(
                    "handshake digest mismatch: the peer's view of the "
                    "(system, jobs) state diverged from the twin's — "
                    f"system {ack.get('system_digest')!r} vs {sys_d!r}, "
                    f"jobs {ack.get('job_digest')!r} vs {job_d!r}")
            # handshake (hello + digest-checked reset_ack, which may
            # include the peer computing its whole schedule) ran under
            # handshake_timeout_s; polls get the tighter per-call budget
            self._sock.settimeout(self.timeout_s)
        except ProtocolError:
            # broken speech is terminal for the session: don't leak the
            # half-open connection (or, in SubprocessPeer, the process)
            self._teardown_connection()
            raise

    def poll_wire(self, t: float) -> dict:
        """One poll round-trip; returns the raw envelope for the bridge."""
        self._send({"version": WIRE_VERSION, "kind": "poll", "t": float(t)})
        reply = self._recv()
        if reply.get("kind") == "error":
            raise ProtocolError(f"peer error: {reply.get('message')!r}")
        return reply

    def running_at(self, t: float) -> np.ndarray:
        return decode_running(self.poll_wire(t), self._n_jobs or (1 << 31))

    @property
    def start(self) -> np.ndarray:
        """Full schedule (sequential mode): fetched over the wire."""
        self._send({"version": WIRE_VERSION, "kind": "schedule_req"})
        reply = self._recv()
        if reply.get("kind") == "error":
            raise ProtocolError(f"peer error: {reply.get('message')!r}")
        return decode_schedule(reply, self._n_jobs)

    # -- plumbing -----------------------------------------------------------
    def _send(self, msg: dict) -> None:
        if self._wfile is None:
            raise ConnectionError("not connected (reset first)")
        write_frame(self._wfile, msg, self.counters)

    def _recv(self) -> dict:
        if self._rfile is None:
            raise ConnectionError("not connected (reset first)")
        return read_frame(self._rfile, self.counters)

    def stats(self) -> dict:
        """Monotonic transport counters for the flight recorder."""
        return {"kind": type(self).__name__, "dials": self.dials,
                **self.counters.as_dict()}

    def close(self) -> None:
        """Best-effort ``bye``, then drop the connection."""
        if self._wfile is not None:
            try:
                self._send({"version": WIRE_VERSION, "kind": "bye"})
            except (OSError, ConnectionError):
                pass
        self._teardown_connection()

    def __enter__(self) -> "SocketPeer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclass
class SubprocessPeer(SocketPeer):
    """``SocketPeer`` that owns its peer process.

    The twin listens on a fresh Unix-domain socket (TCP loopback where
    AF_UNIX is unavailable), spawns ``cmd`` with ``--connect <address>``
    appended, and accepts the peer's dial-in within
    ``handshake_timeout_s`` — no bind race. Bridge-driven ``reset``
    kills, *reaps* and respawns the process (full resync); ``close()``
    tears everything down and asserts nothing is left unreaped. Every
    ``Popen`` ever spawned stays in ``spawned`` so tests can verify no
    zombies survive any fault path.
    """
    cmd: str | list[str] = ""
    cwd: str | None = None
    spawned: list = field(default_factory=list)
    _proc: subprocess.Popen | None = None
    _tmpdir: str | None = None

    def _spawn_cmd(self) -> list[str]:
        argv = shlex.split(self.cmd) if isinstance(self.cmd, str) \
            else list(self.cmd)
        if not argv:
            raise ValueError("SubprocessPeer needs a peer command")
        return argv

    def _establish(self) -> None:
        argv = self._spawn_cmd()  # validate before binding anything
        self._tmpdir = tempfile.mkdtemp(prefix="repro-peer-")
        if hasattr(socket, "AF_UNIX"):
            listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            listener.bind(os.path.join(self._tmpdir, "peer.sock"))
            address = f"unix:{os.path.join(self._tmpdir, 'peer.sock')}"
        else:  # pragma: no cover - non-POSIX fallback
            listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            listener.bind(("127.0.0.1", 0))
            address = "127.0.0.1:%d" % listener.getsockname()[1]
        listener.listen(1)
        listener.settimeout(self.handshake_timeout_s)
        log = open(os.path.join(self._tmpdir, "peer.log"), "ab")
        try:
            self._proc = subprocess.Popen(
                argv + ["--connect", address],
                stdin=subprocess.DEVNULL, stdout=log, stderr=log,
                cwd=self.cwd)
        except OSError:
            # spawn itself failed (bad command): nothing to accept, and
            # the retry must not leak this attempt's listener or tmpdir
            listener.close()
            self._reap()
            raise
        finally:
            log.close()
        self.spawned.append(self._proc)
        try:
            conn, _ = listener.accept()
        except (socket.timeout, TimeoutError) as e:
            self._reap()
            raise TimeoutError(
                f"peer {argv!r} did not connect within "
                f"{self.handshake_timeout_s}s") from e
        finally:
            listener.close()
        conn.settimeout(self.handshake_timeout_s)
        self.dials += 1
        self._attach(conn)

    def stats(self) -> dict:
        """Transport counters + process lifecycle (spawns/respawns)."""
        out = super().stats()
        out["spawns"] = len(self.spawned)
        out["respawns"] = max(len(self.spawned) - 1, 0)
        return out

    def _reap(self) -> None:
        """Terminate (escalating to kill) and wait() the child, if any;
        always drops this attempt's tmpdir, spawned or not."""
        proc = self._proc
        self._proc = None
        if proc is not None:
            if proc.poll() is None:
                proc.terminate()
                try:
                    proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:  # pragma: no cover
                    proc.kill()
                    proc.wait()
            else:
                proc.wait()  # already dead: collect the exit status
        if self._tmpdir is not None:
            shutil.rmtree(self._tmpdir, ignore_errors=True)
            self._tmpdir = None

    def _teardown_connection(self) -> None:
        super()._teardown_connection()
        self._reap()

    def __del__(self) -> None:  # safety net; close() is the contract
        try:
            self._teardown_connection()
        except Exception:
            pass
