"""Out-of-process scheduler peers: socket/subprocess transport (paper §4.2).

The bridge in ``core/external.py`` speaks a versioned wire format
(``WIRE_VERSION`` envelopes) but until now every peer ran in-process.
This module carries those same envelopes across a real process boundary:
newline-delimited JSON frames (one envelope per line, UTF-8) over a
Unix-domain or TCP socket, so a second scheduler implementation — any
language that can read lines of JSON — can couple to the twin.

Wire protocol (full reference: docs/external-scheduling.md)
-----------------------------------------------------------
Every frame is one JSON object terminated by ``\\n``; every object
carries ``version`` (must equal ``WIRE_VERSION``) and ``kind``:

=================  =========  ==============================================
kind               direction  payload
=================  =========  ==============================================
``hello``          peer→twin  sent once on connect: ``name``, optional ``pid``
``reset``          twin→peer  ``t0``, ``policy``, ``backfill``,
                              ``system`` (``n_nodes``, ``dt``, ``name``),
                              ``jobs`` (submit/limit/wall/nodes/priority/
                              account columns), ``system_digest``,
                              ``job_digest``
``reset_ack``      peer→twin  echoes both digests *recomputed by the peer*
                              plus ``n_jobs``
``poll``           twin→peer  ``t`` — simulated seconds
``running_set``    peer→twin  ``job_ids`` (``external.encode_running``)
``schedule_req``   twin→peer  sequential mode: ask for the full schedule
``schedule``       peer→twin  ``start``: per-job start seconds, ``null``
                              for never-started
``poll_batch``     twin→peer  ``ts`` — many timestamps, one roundtrip
``running_sets``   peer→twin  ``sets`` (``external.encode_running_sets``)
``bye``            twin→peer  clean shutdown request
``error``          peer→twin  ``message`` — surfaced as ``ProtocolError``
=================  =========  ==============================================

Peers may advertise capabilities in their hello (``caps`` list):
``bin1`` opts into the length-prefixed RBW1 *binary* frame dialect (see
the layout comment at ``BIN_MAGIC``) and ``batch1`` into batched polls.
Both are negotiated — a legacy peer that sends no caps gets plain NDJSON
frames and per-timestamp polls, bit-identical semantics either way.

The handshake is digest-checked: the twin sends canonical whole-second
job columns (the SWF contract — ``datasets/swf.py``) and the sha256 the
peer must recompute from *what it actually deserialized*; a mismatched
echo raises ``ProtocolError`` before any poll touches engine state.

Failure model
-------------
Framing/parse problems (garbage, truncated line, over-long frame, wrong
version, digest mismatch) raise ``ProtocolError`` — the peer speaks the
wrong dialect and is not retried. Connection problems (EOF from a dead
peer, socket timeout from a hung one) raise ``ConnectionError`` /
``TimeoutError`` — ``SchedulerBridge`` heals those by calling ``reset``
again, which for these peers means *re-dial* (``SocketPeer``) or
*kill, reap and respawn* (``SubprocessPeer``) followed by a full state
resync. ``SubprocessPeer`` keeps every ``Popen`` it ever spawned in
``spawned`` and reaps them all on ``close()`` — no zombies, ever.

``tools/reference_peer.py`` is the stdlib-only reference implementation
of the peer side (FastSimLike semantics), runnable as
``python -m tools.reference_peer``.
"""
from __future__ import annotations

import hashlib
import json
import os
import shlex
import shutil
import socket
import struct
import subprocess
import tempfile
from dataclasses import dataclass, field
from typing import IO

import numpy as np

from repro.core.external import WIRE_VERSION, ProtocolError, decode_running
from repro.datasets.base import JobSet
from repro.systems.config import SystemConfig

# Sized for the biggest legitimate frame: a reset envelope carries six
# full job columns (~100 bytes/job of JSON), so ~1e6 jobs fits with
# headroom. Anything past this is a confused peer, not a big answer —
# and write_frame enforces the same cap outbound, so an oversized twin
# payload fails loudly here instead of as a peer-side parse error.
MAX_FRAME_BYTES = 256 << 20

# Binary frame dialect (negotiated — see read_any_frame/write_bin_frame):
#   magic[4] ("RBW1") | u32 LE header bytes | u32 LE payload bytes |
#   UTF-8 JSON header | concatenated raw little-endian array bytes.
# The header is the envelope with every ndarray leaf replaced by a
# placeholder {"__bin__": index, "dtype": "<f8", "shape": [...]}; the
# payload carries the arrays' raw bytes in placeholder-index order. A
# binary frame can never be mistaken for NDJSON (frames there start with
# "{") and vice versa, so one reader speaks both dialects.
BIN_MAGIC = b"RBW1"
_BIN_LENS = struct.Struct("<II")
# capability tokens a peer may advertise in its hello frame
CAP_BINARY = "bin1"    # understands RBW1 binary frames
CAP_BATCH = "batch1"   # understands poll_batch / running_sets envelopes

# dtypes allowed on the binary wire: fixed-width little-endian numerics
# plus bool. Everything the job tables / schedules / running sets use.
_BIN_DTYPES = frozenset(["<f4", "<f8", "<i4", "<i8", "<u4", "<u8", "|b1"])


# ---------------------------------------------------------------------------
# Canonical digests (handshake).
# ---------------------------------------------------------------------------
def _digest(obj) -> str:
    """sha256 over the canonical (sorted-keys, no-spaces) JSON of ``obj``."""
    blob = json.dumps(obj, separators=(",", ":"), sort_keys=True)
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def system_digest(system: SystemConfig) -> str:
    """Digest of the system parameters a peer's schedule depends on."""
    return _digest({"v": WIRE_VERSION, "n_nodes": int(system.n_nodes),
                    "dt": float(system.dt)})


def job_digest(jobs: JobSet) -> str:
    """Digest of the SWF-preserved job columns, whole-second rounded.

    Only the columns the SWF roundtrip guarantees (submit / limit / wall /
    nodes / account — ``datasets/swf.py``) participate, rounded to whole
    seconds with banker's rounding (what both ``round`` and the SWF
    writer's ``:.0f`` do), so a peer that loaded the same trace from an
    SWF file computes the same digest as one fed over the wire.
    """
    def whole(col):  # np.round is half-even, same as round() peer-side
        return np.round(np.asarray(col)).astype(np.int64).tolist()

    return _digest({"v": WIRE_VERSION, "jobs": {
        "submit": whole(jobs.submit),
        "limit": whole(jobs.limit),
        "wall": whole(jobs.wall),
        "nodes": np.asarray(jobs.nodes).astype(np.int64).tolist(),
        "account": np.asarray(jobs.account).astype(np.int64).tolist(),
    }})


# ---------------------------------------------------------------------------
# NDJSON framing.
# ---------------------------------------------------------------------------
@dataclass
class WireCounters:
    """Monotonic per-connection framing counters (flight-recorder food).

    Counted at the framing layer so every peer kind (socket, subprocess,
    metrics sink) shares one definition of a frame/byte. ``bytes_in``
    counts delivered frames only — a rejected over-long or truncated line
    bumps ``frames_rejected`` instead, so in/out byte counts stay
    comparable across the twin and a compliant peer.
    """
    frames_out: int = 0
    bytes_out: int = 0
    frames_in: int = 0
    bytes_in: int = 0
    frames_rejected: int = 0

    def as_dict(self) -> dict:
        return {"frames_out": self.frames_out, "bytes_out": self.bytes_out,
                "frames_in": self.frames_in, "bytes_in": self.bytes_in,
                "frames_rejected": self.frames_rejected}


def write_frame(wfile: IO[bytes], msg: dict,
                counters: WireCounters | None = None) -> None:
    """Write one envelope as a newline-terminated JSON frame and flush.

    Enforces ``MAX_FRAME_BYTES`` outbound too: a compliant peer would
    reject an over-long line anyway, so failing here turns a confusing
    remote parse error into a local, diagnosable one. The size check runs
    on the JSON *text* before it is encoded and the newline is written
    separately, so an oversize envelope (a ~1e6-job reset gone wrong)
    fails fast after one materialization instead of three: UTF-8 output
    is never shorter than its str, so ``len(text) > cap`` alone proves
    the frame is over-long."""
    text = json.dumps(msg, separators=(",", ":"))
    if len(text) + 1 > MAX_FRAME_BYTES:
        if counters is not None:
            counters.frames_rejected += 1
        raise ProtocolError(
            f"outbound {msg.get('kind')!r} frame is >= {len(text) + 1} "
            f"bytes, over the {MAX_FRAME_BYTES}-byte protocol cap")
    line = text.encode("utf-8")
    n = len(line) + 1
    if n > MAX_FRAME_BYTES:  # pragma: no cover - non-ASCII heavy payload
        if counters is not None:
            counters.frames_rejected += 1
        raise ProtocolError(
            f"outbound {msg.get('kind')!r} frame is {n} bytes, "
            f"over the {MAX_FRAME_BYTES}-byte protocol cap")
    wfile.write(line)
    wfile.write(b"\n")
    wfile.flush()
    if counters is not None:
        counters.frames_out += 1
        counters.bytes_out += n


def read_frame(rfile: IO[bytes],
               counters: WireCounters | None = None) -> dict:
    """Read one envelope; classify every way a peer can get it wrong.

    EOF (peer died) raises ``ConnectionError`` — a transport failure the
    bridge may heal by reconnecting. A frame that *arrives* but is
    over-long, truncated (no newline before EOF), non-JSON, or not an
    object raises ``ProtocolError`` — broken speech is not retried.
    Socket timeouts propagate as ``TimeoutError`` from the underlying
    file object.
    """
    line = rfile.readline(MAX_FRAME_BYTES + 1)
    if not line:
        raise ConnectionError("peer closed the connection (EOF)")
    if len(line) > MAX_FRAME_BYTES:
        if counters is not None:
            counters.frames_rejected += 1
        raise ProtocolError(f"frame exceeds {MAX_FRAME_BYTES} bytes")
    try:
        if not line.endswith(b"\n"):
            raise ProtocolError("truncated frame: EOF before newline")
        try:
            msg = json.loads(line)
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise ProtocolError(f"frame is not JSON: {e}") from e
        if not isinstance(msg, dict):
            raise ProtocolError(f"frame must be a JSON object, got "
                                f"{type(msg).__name__}")
    except ProtocolError:
        if counters is not None:
            counters.frames_rejected += 1
        raise
    if counters is not None:
        counters.frames_in += 1
        counters.bytes_in += len(line)
    return msg


# ---------------------------------------------------------------------------
# Binary framing (the RBW1 fast path).
# ---------------------------------------------------------------------------
def _bin_hoist(obj, arrays: list):
    """Replace every ndarray leaf with a placeholder, collecting raw bytes.

    Returns the placeholder-bearing copy of ``obj``; ``arrays`` receives
    the little-endian raw bytes in placeholder-index order."""
    if isinstance(obj, np.ndarray):
        a = obj
        if a.dtype.byteorder == ">":  # pragma: no cover - big-endian host
            a = a.astype(a.dtype.newbyteorder("<"))
        dt = np.dtype(a.dtype.str)  # normalize '=' to explicit order
        if dt.str not in _BIN_DTYPES:
            raise ProtocolError(f"dtype {dt.str!r} is not a binary-wire "
                                f"dtype (allowed: {sorted(_BIN_DTYPES)})")
        arrays.append(np.ascontiguousarray(a).tobytes())
        return {"__bin__": len(arrays) - 1, "dtype": dt.str,
                "shape": list(a.shape)}
    if isinstance(obj, dict):
        if "__bin__" in obj:
            raise ProtocolError("'__bin__' is a reserved header key")
        return {k: _bin_hoist(v, arrays) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_bin_hoist(v, arrays) for v in obj]
    return obj


def _bin_restore(obj, payload: bytes, offsets: list, as_arrays: bool):
    """Inverse of ``_bin_hoist``: placeholders -> arrays (or lists)."""
    if isinstance(obj, dict):
        if "__bin__" in obj:
            try:
                idx = int(obj["__bin__"])
                dtype = np.dtype(obj["dtype"])
                shape = tuple(int(s) for s in obj["shape"])
                off, nbytes = offsets[idx]
            except (KeyError, TypeError, ValueError, IndexError) as e:
                raise ProtocolError(f"malformed binary placeholder: "
                                    f"{e}") from e
            a = np.frombuffer(payload, dtype, count=-1,
                              offset=off)[:nbytes // dtype.itemsize]
            a = a.reshape(shape)
            return a.copy() if as_arrays else a.tolist()
        return {k: _bin_restore(v, payload, offsets, as_arrays)
                for k, v in obj.items()}
    if isinstance(obj, list):
        return [_bin_restore(v, payload, offsets, as_arrays) for v in obj]
    return obj


def encode_bin_frame(msg: dict) -> tuple[bytes, bytes, list[bytes]]:
    """Encode one envelope as (prefix, header, payload chunks).

    The prefix is magic + both u32 lengths; the payload is returned as
    the per-array chunks so callers can write without concatenating a
    256 MB blob. Raises ``ProtocolError`` when the total frame would
    exceed ``MAX_FRAME_BYTES`` — checked from the chunk sizes *before*
    any large buffer is joined."""
    arrays: list[bytes] = []
    header_obj = _bin_hoist(msg, arrays)
    header = json.dumps(header_obj, separators=(",", ":")).encode("utf-8")
    payload_len = sum(len(c) for c in arrays)
    total = len(BIN_MAGIC) + _BIN_LENS.size + len(header) + payload_len
    if total > MAX_FRAME_BYTES:
        raise ProtocolError(
            f"outbound {msg.get('kind')!r} binary frame is {total} bytes, "
            f"over the {MAX_FRAME_BYTES}-byte protocol cap")
    prefix = BIN_MAGIC + _BIN_LENS.pack(len(header), payload_len)
    return prefix, header, arrays


def decode_bin_frame(header: bytes, payload: bytes,
                     as_arrays: bool = True) -> dict:
    """Decode an RBW1 (header, payload) pair back into an envelope.

    ``as_arrays=False`` materializes every array placeholder as nested
    Python lists — byte-for-byte the values the NDJSON dialect would have
    produced (float64/int64 JSON round-trips are exact), which is what
    the cross-dialect equivalence tests assert on."""
    try:
        obj = json.loads(header)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ProtocolError(f"binary frame header is not JSON: {e}") from e
    if not isinstance(obj, dict):
        raise ProtocolError(f"binary frame header must be a JSON object, "
                            f"got {type(obj).__name__}")
    # lay the arrays out: placeholder index -> (offset, nbytes)
    sizes: dict[int, int] = {}

    def walk(o):
        if isinstance(o, dict):
            if "__bin__" in o:
                try:
                    idx = int(o["__bin__"])
                    dtype = np.dtype(o["dtype"])
                    if dtype.str not in _BIN_DTYPES:
                        raise ProtocolError(
                            f"dtype {dtype.str!r} is not a binary-wire "
                            f"dtype")
                    shape = tuple(int(s) for s in o["shape"])
                    if any(s < 0 for s in shape):
                        raise ProtocolError("negative array dimension")
                except ProtocolError:
                    raise
                except (KeyError, TypeError, ValueError) as e:
                    raise ProtocolError(f"malformed binary placeholder: "
                                        f"{e}") from e
                n = dtype.itemsize * int(np.prod(shape, dtype=np.int64)) \
                    if shape else dtype.itemsize
                if idx in sizes:
                    raise ProtocolError(f"duplicate array index {idx}")
                sizes[idx] = n
                return
            for v in o.values():
                walk(v)
        elif isinstance(o, list):
            for v in o:
                walk(v)

    walk(obj)
    if sorted(sizes) != list(range(len(sizes))):
        raise ProtocolError(f"array indices must be 0..{len(sizes) - 1}, "
                            f"got {sorted(sizes)}")
    offsets, off = [], 0
    for i in range(len(sizes)):
        offsets.append((off, sizes[i]))
        off += sizes[i]
    if off != len(payload):
        raise ProtocolError(f"binary payload carries {len(payload)} bytes, "
                            f"header implies {off}")
    return _bin_restore(obj, payload, offsets, as_arrays)


def write_bin_frame(wfile: IO[bytes], msg: dict,
                    counters: WireCounters | None = None) -> None:
    """Write one envelope as an RBW1 binary frame and flush."""
    try:
        prefix, header, chunks = encode_bin_frame(msg)
    except ProtocolError:
        if counters is not None:
            counters.frames_rejected += 1
        raise
    wfile.write(prefix)
    wfile.write(header)
    for c in chunks:
        wfile.write(c)
    wfile.flush()
    if counters is not None:
        counters.frames_out += 1
        counters.bytes_out += len(prefix) + len(header) \
            + sum(len(c) for c in chunks)


def _read_exact(rfile: IO[bytes], n: int) -> bytes:
    """Read exactly ``n`` bytes; EOF mid-frame is broken speech."""
    buf = rfile.read(n)
    if buf is None or len(buf) < n:  # pragma: no branch
        raise ProtocolError(f"truncated binary frame: EOF after "
                            f"{0 if buf is None else len(buf)}/{n} bytes")
    return buf


def read_any_frame(rfile: IO[bytes],
                   counters: WireCounters | None = None,
                   as_arrays: bool = True) -> dict:
    """Read one frame of either dialect (NDJSON line or RBW1 binary).

    The first byte selects the dialect deterministically: NDJSON frames
    always start with ``{`` (json.dumps of an object), binary frames
    with the magic. Failure classification matches ``read_frame``: EOF
    before any byte is ``ConnectionError``; a frame that arrives broken
    (bad magic continuation, truncated binary body, over-long, non-JSON)
    is ``ProtocolError``."""
    first = rfile.read(1)
    if not first:
        raise ConnectionError("peer closed the connection (EOF)")
    if first == BIN_MAGIC[:1]:
        try:
            rest = _read_exact(rfile, len(BIN_MAGIC) - 1)
            if first + rest != BIN_MAGIC:
                raise ProtocolError(f"bad binary frame magic "
                                    f"{(first + rest)!r}")
            header_len, payload_len = _BIN_LENS.unpack(
                _read_exact(rfile, _BIN_LENS.size))
            total = len(BIN_MAGIC) + _BIN_LENS.size + header_len \
                + payload_len
            if total > MAX_FRAME_BYTES:
                raise ProtocolError(f"frame exceeds {MAX_FRAME_BYTES} "
                                    f"bytes")
            header = _read_exact(rfile, header_len)
            payload = _read_exact(rfile, payload_len)
            msg = decode_bin_frame(header, payload, as_arrays)
        except ProtocolError:
            if counters is not None:
                counters.frames_rejected += 1
            raise
        if counters is not None:
            counters.frames_in += 1
            counters.bytes_in += total
        return msg
    # NDJSON: the byte we took is the start of the line
    line = first + rfile.readline(MAX_FRAME_BYTES + 1)
    if len(line) > MAX_FRAME_BYTES:
        if counters is not None:
            counters.frames_rejected += 1
        raise ProtocolError(f"frame exceeds {MAX_FRAME_BYTES} bytes")
    try:
        if not line.endswith(b"\n"):
            raise ProtocolError("truncated frame: EOF before newline")
        try:
            msg = json.loads(line)
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise ProtocolError(f"frame is not JSON: {e}") from e
        if not isinstance(msg, dict):
            raise ProtocolError(f"frame must be a JSON object, got "
                                f"{type(msg).__name__}")
    except ProtocolError:
        if counters is not None:
            counters.frames_rejected += 1
        raise
    if counters is not None:
        counters.frames_in += 1
        counters.bytes_in += len(line)
    return msg


def decode_schedule(msg: dict, n_jobs: int) -> np.ndarray:
    """Validate a ``schedule`` envelope; return start times (inf = never).

    Two spellings, one meaning: the NDJSON dialect lists numbers with
    ``null`` for never-started; the binary dialect ships a float array
    where ``+inf`` is never-started (null has no fixed-width encoding).
    NaN / ``-inf`` are rejected in both."""
    if msg.get("version") != WIRE_VERSION:
        raise ProtocolError(f"wire version mismatch: peer speaks "
                            f"{msg.get('version')!r}")
    if msg.get("kind") != "schedule":
        raise ProtocolError(f"unexpected message kind {msg.get('kind')!r}")
    start = msg.get("start")
    if isinstance(start, np.ndarray):
        if start.ndim != 1 or start.shape[0] != n_jobs:
            raise ProtocolError(f"schedule must carry {n_jobs} start times, "
                                f"got shape {start.shape}")
        if not np.issubdtype(start.dtype, np.floating):
            raise ProtocolError(f"binary schedule must be float, got "
                                f"dtype={start.dtype}")
        out = start.astype(np.float64)
        bad = np.isnan(out) | (out == -np.inf)
        if bad.any():
            j = int(np.argmax(bad))
            raise ProtocolError(f"schedule start[{j}] must be finite or "
                                f"+inf, got {out[j]!r}")
        return out
    if not isinstance(start, list) or len(start) != n_jobs:
        raise ProtocolError(f"schedule must list {n_jobs} start times, got "
                            f"{type(start).__name__}"
                            f"{'' if not isinstance(start, list) else f'[{len(start)}]'}")
    out = np.full((n_jobs,), np.inf, np.float64)
    for j, s in enumerate(start):
        if s is None:
            continue
        if not isinstance(s, (int, float)) or isinstance(s, bool):
            raise ProtocolError(f"schedule start[{j}] must be a number or "
                                f"null, got {type(s).__name__}")
        try:
            val = float(s)
        except OverflowError as e:  # arbitrary-precision JSON integer
            raise ProtocolError(f"schedule start[{j}] out of float "
                                f"range") from e
        if not np.isfinite(val):
            # json.loads accepts non-standard NaN/Infinity tokens; a
            # never-started job is spelled null, so a non-finite number
            # is a confused peer, not a big start time
            raise ProtocolError(f"schedule start[{j}] must be finite or "
                                f"null, got {s!r}")
        out[j] = val
    return out


def parse_address(addr: str) -> tuple[int, str | tuple[str, int]]:
    """``unix:/path`` or a bare path → AF_UNIX; ``host:port`` → TCP."""
    if addr.startswith("unix:"):
        return socket.AF_UNIX, addr[len("unix:"):]
    if addr.startswith("tcp:"):
        addr = addr[len("tcp:"):]
    if "/" in addr:
        return socket.AF_UNIX, addr
    host, _, port = addr.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"address must be unix:/path or host:port, "
                         f"got {addr!r}")
    return socket.AF_INET, (host, int(port))


def format_address(family: int, sockaddr) -> str:
    if family == getattr(socket, "AF_UNIX", -1):
        return f"unix:{sockaddr}"
    host, port = sockaddr
    return f"{host}:{port}"


# ---------------------------------------------------------------------------
# Client side: ExternalScheduler over a socket.
# ---------------------------------------------------------------------------
@dataclass
class SocketPeer:
    """``ExternalScheduler`` whose brain lives across a socket.

    ``reset`` (re)establishes the session from scratch — dial, ``hello``
    handshake, digest-checked ``reset`` exchange — which is exactly the
    resync ``SchedulerBridge`` needs its reconnect path to perform, so a
    mid-stream death or hang heals transparently. Plugs into
    ``run_plugin_mode`` / ``run_sequential_mode`` unchanged (the process
    boundary is behaviorally invisible).
    """
    address: str | None = None
    policy: str = "fcfs"
    backfill: str = "firstfit"
    wire: str = "auto"                 # "auto" | "ndjson" | "binary"
    timeout_s: float = 30.0            # per-reply socket budget
    handshake_timeout_s: float = 20.0  # connect + hello + reset_ack budget
    peer_hello: dict | None = None
    counters: WireCounters = field(default_factory=WireCounters)
    dials: int = 0                     # connection (re)establishments
    _sock: socket.socket | None = None
    _rfile: IO[bytes] | None = None
    _wfile: IO[bytes] | None = None
    _n_jobs: int = 0
    _binary: bool = False              # negotiated per connection

    # -- connection lifecycle ----------------------------------------------
    def _dial(self) -> socket.socket:
        if self.address is None:
            raise ValueError("SocketPeer needs an address")
        family, sockaddr = parse_address(self.address)
        sock = socket.socket(family, socket.SOCK_STREAM)
        sock.settimeout(self.handshake_timeout_s)
        sock.connect(sockaddr)
        self.dials += 1
        return sock

    def _attach(self, sock: socket.socket) -> None:
        """Adopt a connected socket: buffered files + hello validation."""
        self._sock = sock
        self._rfile = sock.makefile("rb")
        self._wfile = sock.makefile("wb")
        hello = read_frame(self._rfile, self.counters)
        if hello.get("kind") != "hello":
            raise ProtocolError(f"expected hello, got "
                                f"{hello.get('kind')!r}")
        if hello.get("version") != WIRE_VERSION:
            raise ProtocolError(
                f"wire version mismatch: peer speaks "
                f"{hello.get('version')!r}, bridge speaks {WIRE_VERSION}")
        self.peer_hello = hello
        self._binary = self._negotiate_wire(hello)

    def _negotiate_wire(self, hello: dict) -> bool:
        """Pick the frame dialect from our policy + the peer's caps.

        ``auto`` upgrades to binary whenever the peer advertises
        ``CAP_BINARY`` and falls back to NDJSON otherwise (legacy peers
        send no ``caps`` at all); ``binary`` demands the capability and
        treats its absence as broken speech; ``ndjson`` pins the legacy
        dialect regardless of what the peer could do."""
        caps = hello.get("caps") or []
        if not isinstance(caps, list):
            raise ProtocolError(f"hello caps must be a list, got "
                                f"{type(caps).__name__}")
        if self.wire == "ndjson":
            return False
        if self.wire == "binary":
            if CAP_BINARY not in caps:
                raise ProtocolError(
                    f"wire=binary requested but peer "
                    f"{hello.get('name')!r} does not advertise "
                    f"{CAP_BINARY!r} (caps={caps!r})")
            return True
        if self.wire != "auto":
            raise ValueError(f"wire must be auto|ndjson|binary, "
                             f"got {self.wire!r}")
        return CAP_BINARY in caps

    @property
    def batch_capable(self) -> bool:
        """Whether the connected peer advertised batched polls."""
        caps = (self.peer_hello or {}).get("caps") or []
        return CAP_BATCH in caps

    def _teardown_connection(self) -> None:
        for f in (self._wfile, self._rfile, self._sock):
            if f is not None:
                try:
                    f.close()
                except OSError:
                    pass
        self._sock = self._rfile = self._wfile = None

    def _establish(self) -> None:
        self._attach(self._dial())

    # -- ExternalScheduler protocol ----------------------------------------
    def reset(self, system: SystemConfig, jobs: JobSet, t0: float) -> None:
        """Fresh session: (re)connect, handshake, digest-checked resync."""
        self._teardown_connection()
        try:
            self._establish()
            self._n_jobs = len(jobs)
            sys_d, job_d = system_digest(system), job_digest(jobs)
            # the binary dialect ships the columns as raw little-endian
            # arrays (same values — the digests don't change); NDJSON
            # spells them as JSON lists via .tolist(), which yields
            # native floats/ints losslessly without numpy-scalar boxing
            cols = {
                "submit": np.asarray(jobs.submit, np.float64),
                "limit": np.asarray(jobs.limit, np.float64),
                "wall": np.asarray(jobs.wall, np.float64),
                "nodes": np.asarray(jobs.nodes, np.int64),
                "priority": np.asarray(jobs.priority, np.float64),
                "account": np.asarray(jobs.account, np.int64),
            }
            if not self._binary:
                cols = {k: v.tolist() for k, v in cols.items()}
            self._send({
                "version": WIRE_VERSION, "kind": "reset", "t0": float(t0),
                "policy": self.policy, "backfill": self.backfill,
                "system": {"n_nodes": int(system.n_nodes),
                           "dt": float(system.dt), "name": system.name},
                "system_digest": sys_d, "job_digest": job_d,
                "jobs": cols,
            })
            ack = self._recv()
            if ack.get("kind") == "error":
                raise ProtocolError(f"peer rejected reset: "
                                    f"{ack.get('message')!r}")
            if ack.get("kind") != "reset_ack":
                raise ProtocolError(f"expected reset_ack, got "
                                    f"{ack.get('kind')!r}")
            if ack.get("version") != WIRE_VERSION:
                raise ProtocolError(f"wire version mismatch in reset_ack: "
                                    f"{ack.get('version')!r}")
            if ack.get("n_jobs") != len(jobs):
                raise ProtocolError(f"peer deserialized {ack.get('n_jobs')!r}"
                                    f" jobs, sent {len(jobs)}")
            if ack.get("system_digest") != sys_d or \
                    ack.get("job_digest") != job_d:
                raise ProtocolError(
                    "handshake digest mismatch: the peer's view of the "
                    "(system, jobs) state diverged from the twin's — "
                    f"system {ack.get('system_digest')!r} vs {sys_d!r}, "
                    f"jobs {ack.get('job_digest')!r} vs {job_d!r}")
            # handshake (hello + digest-checked reset_ack, which may
            # include the peer computing its whole schedule) ran under
            # handshake_timeout_s; polls get the tighter per-call budget
            self._sock.settimeout(self.timeout_s)
        except ProtocolError:
            # broken speech is terminal for the session: don't leak the
            # half-open connection (or, in SubprocessPeer, the process)
            self._teardown_connection()
            raise

    def poll_wire(self, t: float) -> dict:
        """One poll round-trip; returns the raw envelope for the bridge."""
        self._send({"version": WIRE_VERSION, "kind": "poll", "t": float(t)})
        reply = self._recv()
        if reply.get("kind") == "error":
            raise ProtocolError(f"peer error: {reply.get('message')!r}")
        return reply

    def poll_wire_batch(self, ts) -> dict:
        """One exchange answering many timestamps (``CAP_BATCH`` peers).

        ``SchedulerBridge.poll_many`` only calls this when
        ``batch_capable`` is true, and validates the reply with
        ``decode_running_sets``."""
        self._send({"version": WIRE_VERSION, "kind": "poll_batch",
                    "ts": [float(t) for t in ts]})
        reply = self._recv()
        if reply.get("kind") == "error":
            raise ProtocolError(f"peer error: {reply.get('message')!r}")
        return reply

    def running_at(self, t: float) -> np.ndarray:
        return decode_running(self.poll_wire(t), self._n_jobs or (1 << 31))

    @property
    def start(self) -> np.ndarray:
        """Full schedule (sequential mode): fetched over the wire."""
        self._send({"version": WIRE_VERSION, "kind": "schedule_req"})
        reply = self._recv()
        if reply.get("kind") == "error":
            raise ProtocolError(f"peer error: {reply.get('message')!r}")
        return decode_schedule(reply, self._n_jobs)

    # -- plumbing -----------------------------------------------------------
    def _send(self, msg: dict) -> None:
        if self._wfile is None:
            raise ConnectionError("not connected (reset first)")
        if self._binary:
            write_bin_frame(self._wfile, msg, self.counters)
        else:
            write_frame(self._wfile, msg, self.counters)

    def _recv(self) -> dict:
        if self._rfile is None:
            raise ConnectionError("not connected (reset first)")
        return read_any_frame(self._rfile, self.counters)

    def stats(self) -> dict:
        """Monotonic transport counters for the flight recorder."""
        return {"kind": type(self).__name__, "dials": self.dials,
                "wire": "binary" if self._binary else "ndjson",
                **self.counters.as_dict()}

    def close(self) -> None:
        """Best-effort ``bye``, then drop the connection."""
        if self._wfile is not None:
            try:
                self._send({"version": WIRE_VERSION, "kind": "bye"})
            except (OSError, ConnectionError):
                pass
        self._teardown_connection()

    def __enter__(self) -> "SocketPeer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclass
class SubprocessPeer(SocketPeer):
    """``SocketPeer`` that owns its peer process.

    The twin listens on a fresh Unix-domain socket (TCP loopback where
    AF_UNIX is unavailable), spawns ``cmd`` with ``--connect <address>``
    appended, and accepts the peer's dial-in within
    ``handshake_timeout_s`` — no bind race. Bridge-driven ``reset``
    kills, *reaps* and respawns the process (full resync); ``close()``
    tears everything down and asserts nothing is left unreaped. Every
    ``Popen`` ever spawned stays in ``spawned`` so tests can verify no
    zombies survive any fault path.
    """
    cmd: str | list[str] = ""
    cwd: str | None = None
    spawned: list = field(default_factory=list)
    _proc: subprocess.Popen | None = None
    _tmpdir: str | None = None

    def _spawn_cmd(self) -> list[str]:
        argv = shlex.split(self.cmd) if isinstance(self.cmd, str) \
            else list(self.cmd)
        if not argv:
            raise ValueError("SubprocessPeer needs a peer command")
        return argv

    def _establish(self) -> None:
        argv = self._spawn_cmd()  # validate before binding anything
        self._tmpdir = tempfile.mkdtemp(prefix="repro-peer-")
        if hasattr(socket, "AF_UNIX"):
            listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            listener.bind(os.path.join(self._tmpdir, "peer.sock"))
            address = f"unix:{os.path.join(self._tmpdir, 'peer.sock')}"
        else:  # pragma: no cover - non-POSIX fallback
            listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            listener.bind(("127.0.0.1", 0))
            address = "127.0.0.1:%d" % listener.getsockname()[1]
        listener.listen(1)
        listener.settimeout(self.handshake_timeout_s)
        log = open(os.path.join(self._tmpdir, "peer.log"), "ab")
        try:
            self._proc = subprocess.Popen(
                argv + ["--connect", address],
                stdin=subprocess.DEVNULL, stdout=log, stderr=log,
                cwd=self.cwd)
        except OSError:
            # spawn itself failed (bad command): nothing to accept, and
            # the retry must not leak this attempt's listener or tmpdir
            listener.close()
            self._reap()
            raise
        finally:
            log.close()
        self.spawned.append(self._proc)
        try:
            conn, _ = listener.accept()
        except (socket.timeout, TimeoutError) as e:
            self._reap()
            raise TimeoutError(
                f"peer {argv!r} did not connect within "
                f"{self.handshake_timeout_s}s") from e
        finally:
            listener.close()
        conn.settimeout(self.handshake_timeout_s)
        self.dials += 1
        self._attach(conn)

    def stats(self) -> dict:
        """Transport counters + process lifecycle (spawns/respawns)."""
        out = super().stats()
        out["spawns"] = len(self.spawned)
        out["respawns"] = max(len(self.spawned) - 1, 0)
        return out

    def _reap(self) -> None:
        """Terminate (escalating to kill) and wait() the child, if any;
        always drops this attempt's tmpdir, spawned or not."""
        proc = self._proc
        self._proc = None
        if proc is not None:
            if proc.poll() is None:
                proc.terminate()
                try:
                    proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:  # pragma: no cover
                    proc.kill()
                    proc.wait()
            else:
                proc.wait()  # already dead: collect the exit status
        if self._tmpdir is not None:
            shutil.rmtree(self._tmpdir, ignore_errors=True)
            self._tmpdir = None

    def _teardown_connection(self) -> None:
        super()._teardown_connection()
        self._reap()

    def __del__(self) -> None:  # safety net; close() is the contract
        try:
            self._teardown_connection()
        except Exception:
            pass
