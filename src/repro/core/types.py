"""Pytree state containers for the S-RAPS digital-twin engine.

Everything the simulation touches is a fixed-shape JAX array so the whole
forward-time loop compiles to a single ``lax.scan`` and batches of what-if
scenarios run under ``vmap`` / ``shard_map``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Job lifecycle states (values matter: they are stored in int32 arrays).
# ---------------------------------------------------------------------------
PENDING = 0     # known to the dataloader, not yet submitted (sim time < submit)
QUEUED = 1      # submitted, waiting for placement
RUNNING = 2     # placed on nodes
DONE = 3        # completed
DISMISSED = 4   # outside the simulation window (paper §3.2.2)

# Scheduling policies (paper §3.2.5 + §4.3 + §4.4). Traced integers so a
# vmapped scenario batch can sweep policies.
POLICY_REPLAY = 0
POLICY_FCFS = 1
POLICY_SJF = 2
POLICY_LJF = 3
POLICY_PRIORITY = 4
POLICY_ACCT_AVG_POWER = 5       # descending average account power
POLICY_ACCT_LOW_AVG_POWER = 6   # ascending average account power
POLICY_ACCT_EDP = 7             # ascending accumulated EDP
POLICY_ACCT_ED2P = 8            # ascending accumulated ED^2P
POLICY_ACCT_FUGAKU_PTS = 9      # descending Fugaku points (Solorzano et al.)
POLICY_ML = 10                  # ML-guided score S(X_i) (paper §4.4)
POLICY_CARBON = 11              # grid-aware: defer energy-heavy jobs while
                                # carbon intensity is above its rolling mean
POLICY_PRICE = 12               # analogous on the electricity-price signal
POLICY_THERMAL = 13             # cooling-aware: defer heat-dense jobs while
                                # the tower return temp approaches its limit

POLICY_NAMES = {
    "replay": POLICY_REPLAY,
    "fcfs": POLICY_FCFS,
    "sjf": POLICY_SJF,
    "ljf": POLICY_LJF,
    "priority": POLICY_PRIORITY,
    "acct_avg_power": POLICY_ACCT_AVG_POWER,
    "acct_low_avg_power": POLICY_ACCT_LOW_AVG_POWER,
    "acct_edp": POLICY_ACCT_EDP,
    "acct_ed2p": POLICY_ACCT_ED2P,
    "acct_fugaku_pts": POLICY_ACCT_FUGAKU_PTS,
    "ml": POLICY_ML,
    "carbon_aware": POLICY_CARBON,
    "price_aware": POLICY_PRICE,
    "thermal_aware": POLICY_THERMAL,
}

# Backfill modes (paper §3.2.5).
BF_NONE = 0       # strict in-order admission: first blocked job stalls the queue
BF_FIRSTFIT = 1   # skip blocked jobs, keep admitting anything that fits
BF_EASY = 2       # EASY: reservation for the head job, conservative backfill

BACKFILL_NAMES = {"none": BF_NONE, "first-fit": BF_FIRSTFIT, "firstfit": BF_FIRSTFIT,
                  "easy": BF_EASY}

INF = jnp.float32(jnp.inf)


def _register(cls):
    """Register a dataclass as a pytree (all fields are children)."""
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_dataclass(cls, data_fields=fields, meta_fields=[])
    return cls


# ---------------------------------------------------------------------------
# Static job table (inputs to the simulation; never mutated by the engine).
# ---------------------------------------------------------------------------
@_register
@dataclass
class JobTable:
    """Fixed-size (padded) job table. Shapes: [J] unless noted.

    Times are absolute seconds (float32) relative to the dataset origin.
    ``power_prof``/``util_prof`` are per-node traces sampled at
    ``SystemConfig.prof_dt``; scalar-only datasets (Fugaku, Lassen, Adastra)
    use P == 1. Missing samples are handled with last-observation-carried-
    forward by clamping the profile index (paper §3.2.2).
    """
    submit: jnp.ndarray        # f32[J] submit time
    limit: jnp.ndarray         # f32[J] requested walltime (s)
    wall: jnp.ndarray          # f32[J] actual runtime (s) -- ground truth
    nodes: jnp.ndarray         # i32[J] requested node count
    priority: jnp.ndarray      # f32[J] dataset-provided priority (higher = better)
    account: jnp.ndarray       # i32[J] issuing account id
    rec_start: jnp.ndarray     # f32[J] recorded start time (replay mode)
    first_node: jnp.ndarray    # i32[J] recorded first node of contiguous placement
    score: jnp.ndarray         # f32[J] ML / external score (higher = better)
    power_prof: jnp.ndarray    # f32[J, P] per-node power trace (W)
    util_prof: jnp.ndarray     # f32[J, P] utilization trace in [0, 1]
    valid: jnp.ndarray         # bool[J] padding mask
    # ML scoring basis (paper §4.4.2): exp(1/sqrt(X+1)) of the per-job
    # feature matrix, so the ranking score is *linear* in the alpha vector
    # (score = ml_basis @ alpha). The basis lives in the broadcast table
    # while alpha rides the traced Scenario axis — which is what lets a
    # whole ES population of alphas evaluate as ONE batched sweep
    # (repro.ml.train). ``None`` = no parameterized scoring (legacy
    # ``score`` column only).
    ml_basis: jnp.ndarray | None = None  # f32[J, K] or None
    # Measured per-node power replay (repro.traces, paper contribution 2):
    # recorded telemetry sampled at ``SystemConfig.prof_dt``, gathered by
    # the scan *instead of* evaluating the ``power_prof`` model whenever a
    # job's row carries a measurement — a negative sample is the
    # "no measurement" sentinel, so profile-less jobs (and padded rows,
    # filled with -1) fall back to the model bit-for-bit. ``None`` =
    # replay mode off, the compile-time fast path: the gather vanishes
    # and the graph is bit-identical to the pre-traces engine.
    power_profile: jnp.ndarray | None = None  # f32[J, Q] measured W, or None

    @property
    def num_jobs(self) -> int:
        return self.submit.shape[0]

    @property
    def prof_len(self) -> int:
        return self.power_prof.shape[1]


# ---------------------------------------------------------------------------
# Ledgers updated by the engine.
# ---------------------------------------------------------------------------
@_register
@dataclass
class AccountStats:
    """Per-account accumulators (paper §3.2.6 + §4.3). Shapes: [A]."""
    jobs_done: jnp.ndarray     # f32[A]
    node_hours: jnp.ndarray    # f32[A]
    energy: jnp.ndarray        # f32[A] Joules
    edp: jnp.ndarray           # f32[A] sum of E_job * turnaround
    ed2p: jnp.ndarray          # f32[A] sum of E_job * turnaround^2
    wait_sum: jnp.ndarray      # f32[A]
    turnaround_sum: jnp.ndarray  # f32[A]
    power_sum: jnp.ndarray     # f32[A] sum over jobs of avg per-node power
    fugaku_pts: jnp.ndarray    # f32[A]
    carbon_kg: jnp.ndarray     # f32[A] grid-signal-weighted emissions (kg CO2)
    cost: jnp.ndarray          # f32[A] electricity cost at the grid price ($)

    @staticmethod
    def zeros(num_accounts: int) -> "AccountStats":
        # one fresh buffer per field, NOT one shared array: the ledgers
        # ride the scan carry, which the engine runners donate — XLA
        # rejects donating the same buffer at two argument positions
        n = len(dataclasses.fields(AccountStats))
        return AccountStats(*(jnp.zeros((num_accounts,), jnp.float32)
                              for _ in range(n)))


@_register
@dataclass
class CoolingState:
    """Transient thermo-fluid state of the cooling plant (repro.cooling
    .model), hierarchical: halls -> CDU groups -> nodes.

    G = number of CDU groups, H = number of halls
    (``CoolingConfig.topology``); each hall owns a tower loop (basin +
    fan cells) serving its contiguous span of CDU groups. All
    temperatures in °C, flow in kg/s, fan staging in "active cells"
    (continuous in [0, cells installed in that hall]). A flat plant is
    H = 1.
    """
    t_supply: jnp.ndarray    # f32[G] CDU supply water temperature (°C)
    t_return: jnp.ndarray    # f32[G] CDU return water temperature (°C)
    mdot: jnp.ndarray        # f32[G] CDU water mass flow (kg/s, valve state)
    t_basin: jnp.ndarray     # f32[H] per-hall tower basin temperature (°C)
    fan_stages: jnp.ndarray  # f32[H] active tower cells per hall


@_register
@dataclass
class EventState:
    """Stochastic failure-process state (repro.events). Rides the scan
    carry only when the event layer is enabled (``events=`` on the engine
    runners); ``SimState.events is None`` is the compile-time "no events"
    fast path and keeps the pre-events graphs bit-identical.

    ``*_down_until`` hold the absolute sim time (s) each entity's repair
    completes: an entity is down while ``t < down_until`` — monotone time
    means a failed entity can never resurrect before its repair draw.
    N = nodes, G = CDU groups, C = installed tower cells.
    """
    node_down_until: jnp.ndarray   # f32[N] repair-complete time per node
    group_down_until: jnp.ndarray  # f32[G] repair-complete time per CDU group
    cell_down_until: jnp.ndarray   # f32[C] repair-complete time per tower cell
    # ride-through accumulators
    jobs_killed: jnp.ndarray       # f32[] jobs killed by failures
    jobs_requeued: jnp.ndarray     # f32[] killed jobs returned to the queue
    energy_lost_j: jnp.ndarray     # f32[] energy of killed jobs (not served)
    node_downtime_s: jnp.ndarray   # f32[] integral of down nodes x dt


@_register
@dataclass
class SimState:
    """Full engine state threaded through ``lax.scan``."""
    t: jnp.ndarray          # f32[] current simulation time (s)
    step: jnp.ndarray       # i32[] engine step index (grid-signal cursor)
    jstate: jnp.ndarray     # i32[J] job lifecycle state
    start: jnp.ndarray      # f32[J] realized start time (or +inf)
    end: jnp.ndarray        # f32[J] realized end time (or +inf)
    progress: jnp.ndarray   # f32[J] work-time since start (c*dt per step;
                            # == wall-clock elapsed when never throttled)
    jenergy: jnp.ndarray    # f32[J] accumulated job energy (J)
    node_job: jnp.ndarray   # i32[N] job id occupying each node, -1 when free
    free_count: jnp.ndarray  # i32[] number of free nodes
    accounts: AccountStats
    cooling: CoolingState
    # global accumulators
    energy_total: jnp.ndarray   # f32[] integral of facility input power (J)
    energy_it: jnp.ndarray      # f32[] integral of IT power (J)
    energy_loss: jnp.ndarray    # f32[] integral of conversion losses (J)
    completed: jnp.ndarray      # f32[] jobs completed inside the window
    emissions_kg: jnp.ndarray   # f32[] integral of facility power x carbon
    energy_cost: jnp.ndarray    # f32[] integral of facility power x price ($)
    energy_cooling: jnp.ndarray  # f32[] integral of cooling parasitics (J)
    heat_reuse_j: jnp.ndarray   # f32[] integral of exported (reused) heat (J)
    # stochastic failure-process state (repro.events); ``None`` =
    # compile-time "no event layer" (an empty pytree subtree, so every
    # existing runner/snapshot/stack path is untouched)
    events: EventState | None = None


@_register
@dataclass
class StepRecord:
    """One telemetry row per engine step (the ``ys`` of the scan)."""
    t: jnp.ndarray            # f32[]
    power_it: jnp.ndarray     # f32[] IT power (W)
    power_loss: jnp.ndarray   # f32[] rectifier+sivoc losses (W)
    power_cooling: jnp.ndarray  # f32[] cooling (tower fan + pumps) power (W)
    power_total: jnp.ndarray  # f32[] facility input power (W)
    pue: jnp.ndarray          # f32[]
    t_tower_return: jnp.ndarray  # f32[] water temp arriving at cooling towers
    util: jnp.ndarray         # f32[] busy nodes / total nodes
    n_queued: jnp.ndarray     # f32[]
    n_running: jnp.ndarray    # f32[]
    emissions_kg: jnp.ndarray   # f32[] CO2 emitted this step (kg)
    energy_cost: jnp.ndarray    # f32[] electricity cost this step ($)
    cap_w: jnp.ndarray          # f32[] active facility IT power cap (W)
    throttle_frac: jnp.ndarray  # f32[] 1 - DVFS cap factor (0 = unthrottled)
    # cooling-loop telemetry (repro.cooling.model)
    power_fan: jnp.ndarray      # f32[] tower fan power (W)
    power_pump: jnp.ndarray     # f32[] CDU pump power (W)
    q_reuse_w: jnp.ndarray      # f32[] heat exported for reuse (W)
    t_basin: jnp.ndarray        # f32[] tower basin temperature (°C)
    t_supply_max: jnp.ndarray   # f32[] hottest CDU supply temperature (°C)
    t_wetbulb: jnp.ndarray      # f32[] ambient wet-bulb driving the tower (°C)
    thermal_throttled: jnp.ndarray  # f32[] 1 when supply-temp admission gate on
    # per-hall telemetry (repro.systems.config.FacilityTopology; H = halls).
    # The scalar rows above stay facility aggregates — max / flow-weighted
    # mix over halls — so flat-plant (H = 1) series are unchanged.
    power_it_hall: jnp.ndarray      # f32[H] IT power landing in each hall (W)
    t_basin_hall: jnp.ndarray       # f32[H] per-hall basin temperature (°C)
    t_supply_max_hall: jnp.ndarray  # f32[H] hottest CDU supply per hall (°C)
    t_wetbulb_hall: jnp.ndarray     # f32[H] per-hall ambient wet-bulb (°C)
    cells_online: jnp.ndarray       # f32[H] tower cells available per hall
    # failure / ride-through telemetry (repro.events; zeros when the event
    # layer is off)
    nodes_down: jnp.ndarray         # f32[] nodes unavailable this step
    n_killed: jnp.ndarray           # f32[] jobs killed by failures this step
    overheat_hall: jnp.ndarray      # f32[H] per-hall setpoint-lost flag


# ---------------------------------------------------------------------------
# Per-run scenario parameters (traced; sweep them with vmap).
# ---------------------------------------------------------------------------
@_register
@dataclass
class Scenario:
    """Traced what-if knobs. Every knob after policy/backfill has a
    *neutral default*, so call sites construct Scenarios by keyword and
    adding a knob can never silently shift the meaning of an existing
    positional argument. ``Scenario.make`` converts to traced jnp leaves;
    raw-float construction (as used by ``engine.simulate_static``) keeps
    the values compile-time static."""
    policy: jnp.ndarray       # i32[] POLICY_*
    backfill: jnp.ndarray     # i32[] BF_*
    # weight applied to the account-derived key when mixing with base priority
    acct_weight: jnp.ndarray = 1.0   # f32[]
    # grid-aware knobs (repro.grid): deferral weights for the carbon/price
    # policies, and a multiplier on the facility power-cap schedule so a
    # single vmapped sweep can scan cap levels against one shared signal set.
    carbon_weight: jnp.ndarray = 1.0  # f32[] POLICY_CARBON deferral strength
    price_weight: jnp.ndarray = 1.0   # f32[] POLICY_PRICE deferral strength
    cap_scale: jnp.ndarray = 1.0      # f32[] scales GridSignals.cap_w
    # cooling-aware knobs (repro.cooling): deferral weight for the
    # thermal_aware policy, and an offset on the CDU supply setpoint so a
    # single vmapped sweep can scan setpoints against one compiled program.
    thermal_weight: jnp.ndarray = 1.0    # f32[] POLICY_THERMAL strength
    setpoint_delta_c: jnp.ndarray = 0.0  # f32[] offset on setpoint (°C)
    # maintenance what-if (repro.cooling + FacilityTopology): tower cells
    # taken offline. A scalar applies to every hall; a length-H vector
    # degrades halls individually (all scenarios in one sweep must agree
    # on the shape so the leaves stack).
    cells_offline: jnp.ndarray = 0.0     # f32[] or f32[H] cells offline
    # ML scoring coefficients (repro.ml.scoring): the POLICY_ML key is
    # -(table.score + table.ml_basis @ alpha), so a sweep can carry one
    # alpha vector *per scenario* — the ES training loop (repro.ml.train)
    # puts its whole population here. The scalar 0.0 default is neutral
    # (pure ``table.score`` ranking, the pre-training behavior).
    alpha: jnp.ndarray = 0.0             # f32[] or f32[K] scoring weights
    # stochastic failure knobs (repro.events; active only when the engine
    # runs with an ``events=EventConfig(...)``). Rates are hazards in
    # 1/s (0 = never fails); every knob is finite so scenario deltas ride
    # the serve wire as plain JSON numbers.
    failure_seed: jnp.ndarray = 0.0      # f32[] seed of the failure draws
    node_fail_rate: jnp.ndarray = 0.0    # f32[] per-node failure hazard (1/s)
    cdu_fail_rate: jnp.ndarray = 0.0     # f32[] per-CDU-group hazard (1/s)
    cell_fail_rate: jnp.ndarray = 0.0    # f32[] per-tower-cell hazard (1/s)
    # correlated common-cause fraction: probability scale of a *hall-wide*
    # CDU outage relative to the single-group hazard (0 = independent)
    failure_corr: jnp.ndarray = 0.0      # f32[] in [0, 1]
    repair_s: jnp.ndarray = 3600.0       # f32[] mean repair time (s)
    # grid demand-response event (cap step with a notice window); sentinel
    # values instead of inf: announce < 0 = no event, cap <= 0 = no cap
    dr_announce_s: jnp.ndarray = -1.0    # f32[] announcement time (s; <0 off)
    dr_notice_s: jnp.ndarray = 0.0       # f32[] notice window before the cap
    dr_duration_s: jnp.ndarray = 0.0     # f32[] how long the cap holds (s)
    dr_cap_w: jnp.ndarray = 0.0          # f32[] cap level during the event (W)

    @staticmethod
    def make(policy: str | int, backfill: str | int = "none",
             acct_weight: float = 1.0, carbon_weight: float = 1.0,
             price_weight: float = 1.0, cap_scale: float = 1.0,
             thermal_weight: float = 1.0,
             setpoint_delta_c: float = 0.0,
             cells_offline=0.0, alpha=0.0,
             failure_seed: float = 0.0, node_fail_rate: float = 0.0,
             cdu_fail_rate: float = 0.0, cell_fail_rate: float = 0.0,
             failure_corr: float = 0.0, repair_s: float = 3600.0,
             dr_announce_s: float = -1.0, dr_notice_s: float = 0.0,
             dr_duration_s: float = 0.0,
             dr_cap_w: float = 0.0) -> "Scenario":
        p = POLICY_NAMES[policy] if isinstance(policy, str) else policy
        b = BACKFILL_NAMES[backfill] if isinstance(backfill, str) else backfill
        return Scenario(
            policy=jnp.int32(p), backfill=jnp.int32(b),
            acct_weight=jnp.float32(acct_weight),
            carbon_weight=jnp.float32(carbon_weight),
            price_weight=jnp.float32(price_weight),
            cap_scale=jnp.float32(cap_scale),
            thermal_weight=jnp.float32(thermal_weight),
            setpoint_delta_c=jnp.float32(setpoint_delta_c),
            cells_offline=jnp.asarray(cells_offline, jnp.float32),
            alpha=jnp.asarray(alpha, jnp.float32),
            failure_seed=jnp.float32(failure_seed),
            node_fail_rate=jnp.float32(node_fail_rate),
            cdu_fail_rate=jnp.float32(cdu_fail_rate),
            cell_fail_rate=jnp.float32(cell_fail_rate),
            failure_corr=jnp.float32(failure_corr),
            repair_s=jnp.float32(repair_s),
            dr_announce_s=jnp.float32(dr_announce_s),
            dr_notice_s=jnp.float32(dr_notice_s),
            dr_duration_s=jnp.float32(dr_duration_s),
            dr_cap_w=jnp.float32(dr_cap_w))


def stack_scenarios(scens: list) -> "Scenario":
    """Stack a list of Scenario leaves for vmapped sweeps. Leaves are
    broadcast to a common shape first, so scenarios that keep a vector
    knob at its scalar default (e.g. ``cells_offline=0.0``) stack against
    scenarios that set it per hall."""
    def stack(*xs):
        shape = jnp.broadcast_shapes(*(jnp.shape(x) for x in xs))
        return jnp.stack([jnp.broadcast_to(x, shape) for x in xs])
    return jax.tree_util.tree_map(stack, *scens)
