"""Systems accounting (paper §3.2.6): per-account ledgers folded in as jobs
complete, enabling incentive policies (paper §4.3) and fairness metrics.

All folds are segment-sums over the job axis keyed by account id, so the
whole ledger update is O(J) and fully traceable.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import types as T
from repro.core.incentives import fugaku_points
from repro.systems.config import SystemConfig


def _segsum(values: jnp.ndarray, seg: jnp.ndarray, num: int) -> jnp.ndarray:
    return jax.ops.segment_sum(values, seg, num_segments=num)


def fold_completions(system: SystemConfig, table: T.JobTable,
                     accounts: T.AccountStats, done_now: jnp.ndarray,
                     start: jnp.ndarray, end: jnp.ndarray,
                     jenergy: jnp.ndarray) -> T.AccountStats:
    """Accumulate statistics of jobs that completed this step.

    Args:
      done_now: bool[J] jobs finishing at this engine step.
      start, end: f32[J] realized start/end times (s).
      jenergy: f32[J] accumulated per-job IT energy (J).
    Returns:
      Updated ledgers: node-hours, energy (J), EDP (J·s), ED²P (J·s²),
      wait/turnaround sums (s), average per-node power (W), Fugaku points.
      The carbon/cost columns are untouched here — they accrue per step at
      the then-current grid signal (``accrue_grid``).
    """
    A = accounts.energy.shape[0]
    m = done_now.astype(jnp.float32)
    nodes_f = table.nodes.astype(jnp.float32)
    wall = jnp.maximum(end - start, 1.0)
    wait = jnp.maximum(start - table.submit, 0.0)
    turn = jnp.maximum(end - table.submit, 0.0)
    node_hours = nodes_f * wall / 3600.0
    # average per-node power over the job's life
    avg_pnode = jenergy / jnp.maximum(nodes_f * wall, 1.0)
    pts = fugaku_points(system, node_hours, avg_pnode)
    acct = table.account

    def add(cur, vals):
        return cur + _segsum(vals * m, acct, A)

    return T.AccountStats(
        jobs_done=add(accounts.jobs_done, jnp.ones_like(m)),
        node_hours=add(accounts.node_hours, node_hours),
        energy=add(accounts.energy, jenergy),
        edp=add(accounts.edp, jenergy * turn),
        ed2p=add(accounts.ed2p, jenergy * turn * turn),
        wait_sum=add(accounts.wait_sum, wait),
        turnaround_sum=add(accounts.turnaround_sum, turn),
        power_sum=add(accounts.power_sum, avg_pnode),
        fugaku_pts=add(accounts.fugaku_pts, pts),
        carbon_kg=accounts.carbon_kg,   # accrued per step (accrue_grid)
        cost=accounts.cost,
    )


def accrue_grid(table: T.JobTable, accounts: T.AccountStats,
                job_energy_step: jnp.ndarray, carbon_gkwh: jnp.ndarray,
                price_kwh: jnp.ndarray) -> T.AccountStats:
    """Per-step grid accrual: attribute each job's IT energy this step to
    its account at the *current* carbon intensity and price, so accounts
    that shift load into clean/cheap windows provably accumulate less —
    the collect side of a low-carbon incentive (redeem via a scheduler
    policy, like the Fugaku points loop).

    Args:
      job_energy_step: f32[J] IT energy each job consumed this step (J).
      carbon_gkwh: f32[] carbon intensity now (g CO2 / kWh).
      price_kwh: f32[] electricity price now ($ / kWh).
    Returns:
      Ledgers with ``carbon_kg`` (kg CO2) and ``cost`` ($) advanced.
    """
    A = accounts.energy.shape[0]
    kwh = _segsum(job_energy_step, table.account, A) / 3.6e6
    return dataclasses.replace(
        accounts,
        carbon_kg=accounts.carbon_kg + kwh * carbon_gkwh * 1e-3,
        cost=accounts.cost + kwh * price_kwh)


# --- persistence (paper: "--accounts / --accounts-json": collect in one run,
# redeem in the next) --------------------------------------------------------
def to_json_dict(accounts: T.AccountStats) -> dict:
    import numpy as np
    return {k: np.asarray(v).tolist() for k, v in vars(accounts).items()}


def from_json_dict(d: dict) -> T.AccountStats:
    n = len(next(iter(d.values())))
    zeros = [0.0] * n  # ledgers saved before the grid fields existed
    return T.AccountStats(**{
        f.name: jnp.asarray(d.get(f.name, zeros), jnp.float32)
        for f in dataclasses.fields(T.AccountStats)})


def save_json(accounts: T.AccountStats, path: str) -> None:
    import json
    with open(path, "w") as f:
        json.dump(to_json_dict(accounts), f)


def load_json(path: str) -> T.AccountStats:
    import json
    with open(path) as f:
        return from_json_dict(json.load(f))
