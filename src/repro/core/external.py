"""External-scheduler integration (paper §3.2.4-§3.2.5, §4.2).

Two coupling modes, exactly as the paper describes for ScheduleFlow/FastSim:

* **plugin mode** — the external (event-based) scheduler keeps its own copy
  of the system state; S-RAPS polls it each forward-time step for the set of
  jobs that should be running, diffs against its own state, and asks the
  resource manager to place the new ones (``engine.external_step``).
* **sequential mode** — the external simulator runs to completion first
  ("thousands of times faster than real-time"), its schedule is transformed
  into recorded start times, and the compiled twin replays it
  (paper §4.2.2: "we found it was faster to run FastSim and RAPS
  sequentially").

``FastSimLike`` wraps the numpy event-driven scheduler (fast, batched event
processing); ``ScheduleFlowLike`` mimics an on-the-fly scheduler that
recomputes its plan on every triggered event (slow but faithful to the
paper's observation about frequent recalculation overhead).

Wire protocol (bridge hardening)
--------------------------------
The original coupling assumed a well-behaved in-process peer. The bridge
now speaks a *versioned* wire format: each poll answer is an envelope
``{"version": WIRE_VERSION, "kind": "running_set", "job_ids": [...]}``
(``encode_running`` / ``decode_running``), validated before it touches
engine state — version mismatches, non-integer ids, out-of-range ids and
duplicates all raise ``ProtocolError`` instead of corrupting the node
map. ``SchedulerBridge`` adds the per-call timeout/reconnect story: a
poll that exceeds ``BridgeConfig.timeout_s`` (measured wall time — an
in-process peer cannot be preempted, so the over-budget answer is
*discarded*) or raises a transport-ish error triggers a reconnect
(``peer.reset`` replay) and a bounded retry; persistent failure raises
``BridgeTimeout``. Legacy peers exposing only ``running_at`` are wrapped
transparently; peers exposing ``poll_wire`` are validated end-to-end.

Out-of-process peers speak the same envelopes over a newline-delimited
JSON socket: ``core/transport.py`` (``SocketPeer`` / ``SubprocessPeer``)
carries them across a real process boundary, and
``tools/reference_peer.py`` is the stdlib-only reference peer. Protocol
reference: docs/external-scheduling.md.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Iterable, Protocol

import numpy as np
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.base import JobSet
from repro.datasets.synthetic import event_schedule
from repro.obs.timing import LatencyHistogram
from repro.systems.config import SystemConfig

WIRE_VERSION = 1
WIRE_KIND_RUNNING = "running_set"
WIRE_KIND_RUNNING_SETS = "running_sets"  # batched poll_batch answer


class ProtocolError(RuntimeError):
    """The peer answered with a malformed / wrong-version wire message."""


class BridgeTimeout(RuntimeError):
    """The peer kept exceeding the per-call budget after reconnects."""


class ExternalScheduler(Protocol):
    """What S-RAPS needs from an external scheduling simulator."""

    def reset(self, system: SystemConfig, jobs: JobSet, t0: float) -> None: ...

    def running_at(self, t: float) -> np.ndarray:
        """Process events up to ``t``; return ids of jobs that should be
        running (FastSim plugin-mode contract: 'responds with a list of
        running jobs indexed by job ID')."""
        ...


# ---------------------------------------------------------------------------
# Wire format + bridge.
# ---------------------------------------------------------------------------
def encode_running(job_ids: Iterable[int]) -> dict:
    """Wrap a running-set answer in the versioned wire envelope."""
    return {"version": WIRE_VERSION, "kind": WIRE_KIND_RUNNING,
            "job_ids": [int(j) for j in job_ids]}


def decode_running(msg, n_jobs: int) -> np.ndarray:
    """Validate a wire envelope and return the running-set ids (i64[K]).

    Raises ``ProtocolError`` on anything a confused or wrong-version peer
    could send: not a dict, missing/mismatched version, wrong kind,
    non-integer ids, ids outside ``[0, n_jobs)``, duplicates.
    """
    if not isinstance(msg, dict):
        raise ProtocolError(f"wire message must be a dict envelope, "
                            f"got {type(msg).__name__}")
    ver = msg.get("version")
    if ver != WIRE_VERSION:
        raise ProtocolError(f"wire version mismatch: peer speaks {ver!r}, "
                            f"bridge speaks {WIRE_VERSION}")
    if msg.get("kind") != WIRE_KIND_RUNNING:
        raise ProtocolError(f"unexpected message kind {msg.get('kind')!r}")
    ids = msg.get("job_ids")
    if isinstance(ids, (list, tuple)) and \
            any(isinstance(x, bool) for x in ids):
        # JSON true/false would silently cast to 1/0 through np.asarray
        raise ProtocolError("job_ids must be integers, got booleans")
    try:
        arr = np.asarray(ids)
    except Exception as e:  # ragged / object payloads
        raise ProtocolError(f"job_ids not array-like: {e}") from e
    if arr.ndim != 1:
        # ndim before the empty-fastpath: a nested-but-empty payload like
        # [[]] has size 0 and must still be rejected, not silently passed
        raise ProtocolError(f"job_ids must be a flat integer list, got "
                            f"ndim={arr.ndim}")
    if arr.size == 0:
        return np.zeros((0,), np.int64)
    if not np.issubdtype(arr.dtype, np.integer):
        raise ProtocolError(f"job_ids must be a flat integer list, got "
                            f"dtype={arr.dtype}")
    arr = arr.astype(np.int64)
    if arr.min() < 0 or arr.max() >= n_jobs:
        raise ProtocolError(f"job id out of range [0, {n_jobs}): "
                            f"[{arr.min()}, {arr.max()}]")
    if np.unique(arr).size != arr.size:
        raise ProtocolError("duplicate job ids in running set")
    return arr


def encode_running_sets(sets: Iterable[Iterable[int]]) -> dict:
    """Wrap a batched running-set answer (one set per polled timestamp)."""
    return {"version": WIRE_VERSION, "kind": WIRE_KIND_RUNNING_SETS,
            "sets": [[int(j) for j in ids] for ids in sets]}


def decode_running_sets(msg, n_jobs: int, n_expected: int) -> list[np.ndarray]:
    """Validate a batched envelope; returns one id array per timestamp.

    Each inner set goes through the exact ``decode_running`` validation
    (version handled once at the envelope level), so a batched peer
    cannot sneak anything past the bridge that a per-poll peer could not.
    """
    if not isinstance(msg, dict):
        raise ProtocolError(f"wire message must be a dict envelope, "
                            f"got {type(msg).__name__}")
    ver = msg.get("version")
    if ver != WIRE_VERSION:
        raise ProtocolError(f"wire version mismatch: peer speaks {ver!r}, "
                            f"bridge speaks {WIRE_VERSION}")
    if msg.get("kind") != WIRE_KIND_RUNNING_SETS:
        raise ProtocolError(f"unexpected message kind {msg.get('kind')!r}")
    sets = msg.get("sets")
    if not isinstance(sets, (list, tuple)):
        raise ProtocolError(f"'sets' must be a list, got "
                            f"{type(sets).__name__}")
    if len(sets) != n_expected:
        raise ProtocolError(f"batched poll answered {len(sets)} sets for "
                            f"{n_expected} timestamps")
    return [decode_running({"version": WIRE_VERSION,
                            "kind": WIRE_KIND_RUNNING, "job_ids": ids},
                           n_jobs) for ids in sets]


# transport-style failures the bridge may heal by reconnecting; anything
# else raised by a peer is a peer bug and must surface with its own
# traceback (a reconnect would mask it and replay side effects)
TRANSPORT_ERRORS = (ConnectionError, OSError, TimeoutError)


@dataclass(frozen=True)
class BridgeConfig:
    """Per-call budget + retry policy for the external coupling.

    The default budget is deliberately generous: in-process peers cannot
    be preempted (the budget is enforced post-hoc) and a slow-but-correct
    peer — ScheduleFlowLike recomputes its whole plan per poll — must
    complete, not flap through reset/retry cycles. Tighten it for real
    out-of-process transports."""
    timeout_s: float = 30.0  # wall budget per poll (post-hoc for in-process)
    max_retries: int = 1     # reconnect+retry attempts after a failure


@dataclass
class SchedulerBridge:
    """Hardened coupling to an external scheduler.

    Validates every answer against the versioned wire format and owns the
    timeout/reconnect path: a poll that raises (transport-style failure)
    or blows its wall budget is discarded, the peer is *reconnected* — a
    fresh ``reset`` replaying (system, jobs, t0), the only resync an
    event-based peer supports — and the poll retried up to
    ``BridgeConfig.max_retries`` times; persistent failure raises
    ``BridgeTimeout``. ``ProtocolError`` is never retried: a peer that
    speaks the wrong dialect will keep speaking it.
    """
    peer: "ExternalScheduler"
    config: BridgeConfig = field(default_factory=BridgeConfig)
    reconnects: int = 0
    # flight-recorder counters (monotonic; surfaced via stats())
    polls: int = 0               # poll() calls answered successfully
    poll_failures: int = 0       # transport-style failures across attempts
    budget_exceeded: int = 0     # over-budget answers discarded post-hoc
    poll_latency: LatencyHistogram = field(default_factory=LatencyHistogram)
    on_event: object = None      # optional callable(event: str, fields: dict)
    _args: tuple | None = None

    def _emit(self, event: str, **fields) -> None:
        if self.on_event is not None:
            self.on_event(event, fields)

    def stats(self) -> dict:
        """Monotonic bridge counters + the peer's transport counters (when
        it exposes ``stats()`` — Socket/SubprocessPeer do), manifest- and
        ``fig7_external``-ready."""
        out = {"polls": self.polls, "poll_failures": self.poll_failures,
               "budget_exceeded": self.budget_exceeded,
               "reconnects": self.reconnects,
               "poll_latency": self.poll_latency.summary()}
        peer_stats = getattr(self.peer, "stats", None)
        if callable(peer_stats):
            out["peer"] = peer_stats()
        return out

    def reset(self, system: SystemConfig, jobs: JobSet, t0: float) -> None:
        """Resync the peer, retrying transport failures.

        An out-of-process peer can fail to *come up* (spawn or dial
        fails, handshake times out) exactly like it can fail mid-poll,
        so reset gets the same bounded-retry treatment. ``ProtocolError``
        (wrong version in hello, digest mismatch) is terminal — the peer
        will keep speaking the wrong dialect."""
        self._args = (system, jobs, t0)
        last: BaseException | None = None
        for attempt in range(self.config.max_retries + 1):
            try:
                self.peer.reset(system, jobs, t0)
                return
            except ProtocolError:
                raise
            except TRANSPORT_ERRORS as e:
                last = e
                if attempt < self.config.max_retries:
                    self.reconnects += 1
        raise BridgeTimeout(f"peer reset failed after "
                            f"{self.config.max_retries + 1} attempts: "
                            f"{last!r}")

    def _reconnect(self) -> str | None:
        """One reconnect attempt; returns an error note instead of letting
        a transport failure during the *resync itself* (e.g. a respawned
        subprocess that fails to dial) escape unwrapped — the poll retry
        loop owns the budget and converts persistent failure to
        ``BridgeTimeout``."""
        if self._args is None:
            raise BridgeTimeout("cannot reconnect before reset()")
        self.reconnects += 1
        self._emit("bridge_reconnect", reconnects=self.reconnects)
        try:
            self.peer.reset(*self._args)
            return None
        except TRANSPORT_ERRORS as e:
            return f"reconnect failed: {e!r}"

    def poll(self, t: float) -> np.ndarray:
        """Running-set ids at ``t``, validated; reconnects on failure."""
        n_jobs = len(self._args[1]) if self._args else 1 << 31
        last = "never polled"
        for attempt in range(self.config.max_retries + 1):
            retryable = attempt < self.config.max_retries
            t_call = time.perf_counter()
            try:
                if hasattr(self.peer, "poll_wire"):
                    ids = decode_running(self.peer.poll_wire(t), n_jobs)
                else:  # legacy peer: bare array, validated the same way
                    ids = decode_running(
                        encode_running(self.peer.running_at(t)), n_jobs)
            except ProtocolError:
                raise                       # malformed speech: not retryable
            except TRANSPORT_ERRORS as e:   # connection-style failure
                self.poll_failures += 1
                last = f"poll raised {e!r}"
                if retryable:               # no pointless trailing respawn
                    last = self._reconnect() or last
                continue
            took = time.perf_counter() - t_call
            self.poll_latency.record(took)
            if took > self.config.timeout_s:
                # in-process peers cannot be preempted: the budget is
                # enforced post-hoc and the stale answer discarded
                self.budget_exceeded += 1
                last = f"poll took {took:.3f}s > {self.config.timeout_s}s"
                if retryable:
                    last = self._reconnect() or last
                continue
            self.polls += 1
            return ids
        raise BridgeTimeout(f"peer unusable after "
                            f"{self.config.max_retries + 1} attempts: {last}")

    def poll_many(self, ts) -> list[np.ndarray]:
        """Running-set ids for several timestamps in one exchange.

        Uses the peer's ``poll_wire_batch`` when it both exists and the
        transport negotiated the batch capability (``batch_capable``);
        otherwise falls back to one ``poll`` per timestamp so callers
        never need to care which dialect the peer speaks. The batched
        path shares the per-call budget/retry machinery: the whole batch
        counts as one poll against ``timeout_s``.
        """
        ts = [float(t) for t in ts]
        if not ts:
            return []
        batch = getattr(self.peer, "poll_wire_batch", None)
        if batch is None or not getattr(self.peer, "batch_capable", True):
            return [self.poll(t) for t in ts]
        n_jobs = len(self._args[1]) if self._args else 1 << 31
        last = "never polled"
        for attempt in range(self.config.max_retries + 1):
            retryable = attempt < self.config.max_retries
            t_call = time.perf_counter()
            try:
                sets = decode_running_sets(batch(ts), n_jobs, len(ts))
            except ProtocolError:
                raise                       # malformed speech: not retryable
            except TRANSPORT_ERRORS as e:
                self.poll_failures += 1
                last = f"batched poll raised {e!r}"
                if retryable:
                    last = self._reconnect() or last
                continue
            took = time.perf_counter() - t_call
            self.poll_latency.record(took)
            if took > self.config.timeout_s:
                self.budget_exceeded += 1
                last = f"batched poll took {took:.3f}s > " \
                       f"{self.config.timeout_s}s"
                if retryable:
                    last = self._reconnect() or last
                continue
            self.polls += 1
            return sets
        raise BridgeTimeout(f"peer unusable after "
                            f"{self.config.max_retries + 1} attempts: {last}")


# ---------------------------------------------------------------------------
@dataclass
class FastSimLike:
    """Fast event-based Slurm-like emulator (Wilkinson et al. [41] stand-in).

    Precomputes the entire schedule on reset (event-driven, no time stepping)
    and answers ``running_at`` queries in O(log J) — the source of its
    hundreds-x real-time speedup.
    """
    policy: str = "fcfs"
    backfill: str = "firstfit"
    start: np.ndarray | None = None
    _jobs: JobSet | None = None

    def reset(self, system: SystemConfig, jobs: JobSet, t0: float) -> None:
        self._jobs = jobs
        self.start = event_schedule(jobs.submit, jobs.limit, jobs.wall,
                                    jobs.nodes, system.n_nodes, system.dt,
                                    policy=self.policy,
                                    backfill=self.backfill,
                                    priority=jobs.priority)

    def running_at(self, t: float) -> np.ndarray:
        s = self.start
        return np.nonzero((s <= t) & (s + self._jobs.wall > t))[0]

    def poll_wire(self, t: float) -> dict:
        """Versioned wire endpoint (bridge conformance)."""
        return encode_running(self.running_at(t))

    def poll_wire_batch(self, ts) -> dict:
        """Batched wire endpoint: one envelope for many timestamps."""
        return encode_running_sets(self.running_at(t) for t in ts)


@dataclass
class ScheduleFlowLike:
    """On-the-fly event scheduler (Gainaru et al. [18] stand-in): maintains an
    internal queue/system state and *recomputes the plan on every poll* —
    reproducing the overhead the paper reports for the ScheduleFlow coupling.
    """
    recompute_count: int = 0
    _state: dict | None = None

    def reset(self, system: SystemConfig, jobs: JobSet, t0: float) -> None:
        self._state = dict(system=system, jobs=jobs, t=t0,
                           free=system.n_nodes,
                           queue=[], started={}, finished=set(), cursor=0)

    def running_at(self, t: float) -> np.ndarray:
        st = self._state
        jobs: JobSet = st["jobs"]
        # ingest submissions up to t (events)
        order = np.argsort(jobs.submit, kind="stable")
        while st["cursor"] < len(jobs) and \
                jobs.submit[order[st["cursor"]]] <= t:
            st["queue"].append(int(order[st["cursor"]]))
            st["cursor"] += 1
        # completions
        for j, s in list(st["started"].items()):
            if s + jobs.wall[j] <= t:
                st["free"] += int(jobs.nodes[j])
                st["finished"].add(j)
                del st["started"][j]
        # full plan recomputation (the expensive part)
        self.recompute_count += 1
        st["queue"].sort(key=lambda q: (jobs.submit[q], q))
        placed = []
        for q in st["queue"]:
            need = int(jobs.nodes[q])
            if need <= st["free"]:
                st["free"] -= need
                st["started"][q] = t
                placed.append(q)
        for q in placed:
            st["queue"].remove(q)
        st["t"] = t
        return np.asarray(sorted(st["started"].keys()), dtype=np.int64)


# ---------------------------------------------------------------------------
# Coupling drivers.
# ---------------------------------------------------------------------------
def run_plugin_mode(system: SystemConfig, jobs: JobSet,
                    scheduler: ExternalScheduler, t0: float, t1: float,
                    pad_to: int | None = None, max_place: int = 64,
                    bridge_config: BridgeConfig | None = None,
                    scen: T.Scenario | None = None):
    """Plugin mode: poll the external scheduler between compiled steps.

    The peer is wrapped in a ``SchedulerBridge`` (versioned wire format,
    per-call timeout/reconnect) unless it already is one. ``scen`` routes
    the facility what-if knobs (cap scale, setpoint offset, cells
    offline) the external peer has no say over.

    Returns (final_state, history dict of numpy arrays, wall_seconds).
    """
    table = jobs.to_table(pad_to)
    st = eng.init_state(system, table, t0, t1)
    bridge = scheduler if isinstance(scheduler, SchedulerBridge) else \
        SchedulerBridge(scheduler, bridge_config or BridgeConfig())
    bridge.reset(system, jobs, t0)
    n_steps = int(round((t1 - t0) / system.dt))
    rows = []
    wall0 = time.perf_counter()
    running_prev: set[int] = set(np.nonzero(
        np.asarray(st.jstate) == T.RUNNING)[0].tolist())
    for i in range(n_steps):
        t = t0 + i * system.dt
        want = set(bridge.poll(t).tolist())
        new = sorted(want - running_prev)[:max_place]
        place = np.full((max_place,), -1, np.int32)
        place[:len(new)] = new
        st, rec = eng.external_step(system, table, st, jnp.asarray(place),
                                    scen=scen)
        # S-RAPS keeps its own copy of the system state (paper §4.2.2)
        running_prev = set(np.nonzero(
            np.asarray(st.jstate) == T.RUNNING)[0].tolist())
        rows.append(rec)
    wall = time.perf_counter() - wall0
    hist = {k: np.asarray([getattr(r, k) for r in rows])
            for k in vars(rows[0])}
    return st, hist, wall


def run_sequential_mode(system: SystemConfig, jobs: JobSet,
                        scheduler: ExternalScheduler, t0: float, t1: float,
                        pad_to: int | None = None,
                        scen: T.Scenario | None = None):
    """Sequential mode: external scheduler first, compiled replay second.

    ``scen`` routes the facility what-if knobs (cap scale, setpoint
    offset, cells offline) into the replay, exactly as in plugin mode;
    its policy/backfill fields are overridden to replay — the external
    schedule is the policy."""
    scheduler.reset(system, jobs, t0)
    sched_start = np.asarray(scheduler.start, dtype=np.float64)
    rescheduled = JobSet(
        submit=jobs.submit, limit=jobs.limit, wall=jobs.wall,
        nodes=jobs.nodes, priority=jobs.priority, account=jobs.account,
        rec_start=np.where(np.isfinite(sched_start), sched_start, t1 * 2),
        power_prof=jobs.power_prof, util_prof=jobs.util_prof,
        first_node=jobs.first_node, score=jobs.score,
        name=jobs.name + "+external")
    table = rescheduled.to_table(pad_to)
    scen = T.Scenario.make("replay") if scen is None else replace(
        scen, policy=jnp.int32(T.POLICY_REPLAY),
        backfill=jnp.int32(T.BF_NONE))
    return eng.simulate(system, table, scen, t0, t1)
