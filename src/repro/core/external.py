"""External-scheduler integration (paper §3.2.4-§3.2.5, §4.2).

Two coupling modes, exactly as the paper describes for ScheduleFlow/FastSim:

* **plugin mode** — the external (event-based) scheduler keeps its own copy
  of the system state; S-RAPS polls it each forward-time step for the set of
  jobs that should be running, diffs against its own state, and asks the
  resource manager to place the new ones (``engine.external_step``).
* **sequential mode** — the external simulator runs to completion first
  ("thousands of times faster than real-time"), its schedule is transformed
  into recorded start times, and the compiled twin replays it
  (paper §4.2.2: "we found it was faster to run FastSim and RAPS
  sequentially").

``FastSimLike`` wraps the numpy event-driven scheduler (fast, batched event
processing); ``ScheduleFlowLike`` mimics an on-the-fly scheduler that
recomputes its plan on every triggered event (slow but faithful to the
paper's observation about frequent recalculation overhead).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterable, Protocol

import numpy as np
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import types as T
from repro.datasets.base import JobSet
from repro.datasets.synthetic import event_schedule
from repro.systems.config import SystemConfig


class ExternalScheduler(Protocol):
    """What S-RAPS needs from an external scheduling simulator."""

    def reset(self, system: SystemConfig, jobs: JobSet, t0: float) -> None: ...

    def running_at(self, t: float) -> np.ndarray:
        """Process events up to ``t``; return ids of jobs that should be
        running (FastSim plugin-mode contract: 'responds with a list of
        running jobs indexed by job ID')."""
        ...


# ---------------------------------------------------------------------------
@dataclass
class FastSimLike:
    """Fast event-based Slurm-like emulator (Wilkinson et al. [41] stand-in).

    Precomputes the entire schedule on reset (event-driven, no time stepping)
    and answers ``running_at`` queries in O(log J) — the source of its
    hundreds-x real-time speedup.
    """
    policy: str = "fcfs"
    backfill: str = "firstfit"
    start: np.ndarray | None = None
    _jobs: JobSet | None = None

    def reset(self, system: SystemConfig, jobs: JobSet, t0: float) -> None:
        self._jobs = jobs
        self.start = event_schedule(jobs.submit, jobs.limit, jobs.wall,
                                    jobs.nodes, system.n_nodes, system.dt,
                                    policy=self.policy,
                                    backfill=self.backfill,
                                    priority=jobs.priority)

    def running_at(self, t: float) -> np.ndarray:
        s = self.start
        return np.nonzero((s <= t) & (s + self._jobs.wall > t))[0]


@dataclass
class ScheduleFlowLike:
    """On-the-fly event scheduler (Gainaru et al. [18] stand-in): maintains an
    internal queue/system state and *recomputes the plan on every poll* —
    reproducing the overhead the paper reports for the ScheduleFlow coupling.
    """
    recompute_count: int = 0
    _state: dict | None = None

    def reset(self, system: SystemConfig, jobs: JobSet, t0: float) -> None:
        self._state = dict(system=system, jobs=jobs, t=t0,
                           free=system.n_nodes,
                           queue=[], started={}, finished=set(), cursor=0)

    def running_at(self, t: float) -> np.ndarray:
        st = self._state
        jobs: JobSet = st["jobs"]
        # ingest submissions up to t (events)
        order = np.argsort(jobs.submit, kind="stable")
        while st["cursor"] < len(jobs) and \
                jobs.submit[order[st["cursor"]]] <= t:
            st["queue"].append(int(order[st["cursor"]]))
            st["cursor"] += 1
        # completions
        for j, s in list(st["started"].items()):
            if s + jobs.wall[j] <= t:
                st["free"] += int(jobs.nodes[j])
                st["finished"].add(j)
                del st["started"][j]
        # full plan recomputation (the expensive part)
        self.recompute_count += 1
        st["queue"].sort(key=lambda q: (jobs.submit[q], q))
        placed = []
        for q in st["queue"]:
            need = int(jobs.nodes[q])
            if need <= st["free"]:
                st["free"] -= need
                st["started"][q] = t
                placed.append(q)
        for q in placed:
            st["queue"].remove(q)
        st["t"] = t
        return np.asarray(sorted(st["started"].keys()), dtype=np.int64)


# ---------------------------------------------------------------------------
# Coupling drivers.
# ---------------------------------------------------------------------------
def run_plugin_mode(system: SystemConfig, jobs: JobSet,
                    scheduler: ExternalScheduler, t0: float, t1: float,
                    pad_to: int | None = None, max_place: int = 64):
    """Plugin mode: poll the external scheduler between compiled steps.

    Returns (final_state, history dict of numpy arrays, wall_seconds).
    """
    table = jobs.to_table(pad_to)
    st = eng.init_state(system, table, t0, t1)
    scheduler.reset(system, jobs, t0)
    n_steps = int(round((t1 - t0) / system.dt))
    rows = []
    wall0 = time.perf_counter()
    running_prev: set[int] = set(np.nonzero(
        np.asarray(st.jstate) == T.RUNNING)[0].tolist())
    for i in range(n_steps):
        t = t0 + i * system.dt
        want = set(scheduler.running_at(t).tolist())
        new = sorted(want - running_prev)[:max_place]
        place = np.full((max_place,), -1, np.int32)
        place[:len(new)] = new
        st, rec = eng.external_step(system, table, st, jnp.asarray(place))
        # S-RAPS keeps its own copy of the system state (paper §4.2.2)
        running_prev = set(np.nonzero(
            np.asarray(st.jstate) == T.RUNNING)[0].tolist())
        rows.append(rec)
    wall = time.perf_counter() - wall0
    hist = {k: np.asarray([getattr(r, k) for r in rows])
            for k in vars(rows[0])}
    return st, hist, wall


def run_sequential_mode(system: SystemConfig, jobs: JobSet,
                        scheduler: ExternalScheduler, t0: float, t1: float,
                        pad_to: int | None = None):
    """Sequential mode: external scheduler first, compiled replay second."""
    scheduler.reset(system, jobs, t0)
    sched_start = np.asarray(scheduler.start, dtype=np.float64)
    rescheduled = JobSet(
        submit=jobs.submit, limit=jobs.limit, wall=jobs.wall,
        nodes=jobs.nodes, priority=jobs.priority, account=jobs.account,
        rec_start=np.where(np.isfinite(sched_start), sched_start, t1 * 2),
        power_prof=jobs.power_prof, util_prof=jobs.util_prof,
        first_node=jobs.first_node, score=jobs.score,
        name=jobs.name + "+external")
    table = rescheduled.to_table(pad_to)
    scen = T.Scenario.make("replay")
    return eng.simulate(system, table, scen, t0, t1)
