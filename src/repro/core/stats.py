"""Run-level statistics (paper §3.2.6): scheduler metrics, fairness /
packing-efficiency metrics (AWRT, priority-weighted specific response time
after Goponenko et al. [21]), job-size histogram, and energy summaries.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core import types as T
from repro.systems.config import SystemConfig

# job-size classes by node count (paper: "histogram of job size scheduled
# (small, medium, large, by node count)")
SIZE_EDGES = (1, 8, 128)  # small <8, medium <128, large >=128


def summarize(system: SystemConfig, table: T.JobTable, final: T.SimState,
              hist: T.StepRecord) -> Dict[str, float]:
    """Reduce a run to its scalar summary metrics (paper §3.2.6).

    Args:
      system: the machine the run simulated (for dt / node counts).
      table: the job table the run consumed.
      final: final engine state (accumulators in J, kg, $).
      hist: per-step telemetry (powers in W, temperatures in °C).
    Returns:
      Flat dict of floats — scheduler metrics (s), energy (MWh), power
      (MW), PUE, emissions (kg), cost ($), and cooling-loop telemetry
      (°C / MWh).
    """
    done = np.asarray(final.jstate == T.DONE)
    start = np.asarray(final.start)
    end = np.asarray(final.end)
    submit = np.asarray(table.submit)
    nodes = np.asarray(table.nodes).astype(np.float64)
    prio = np.asarray(table.priority).astype(np.float64)
    jenergy = np.asarray(final.jenergy).astype(np.float64)

    done = done & np.isfinite(start) & np.isfinite(end)
    startz = np.where(done, start, 0.0)
    endz = np.where(done, end, 0.0)
    wall = np.where(done, endz - startz, 0.0)
    wait = np.where(done, np.maximum(startz - submit, 0.0), 0.0)
    turn = np.where(done, np.maximum(endz - submit, 0.0), 0.0)
    nh = nodes * wall / 3600.0
    n_done = max(int(done.sum()), 1)

    area = nh.sum() or 1.0
    awrt = float((turn * nh).sum() / area)
    pw = prio * nh
    psrt = float((turn * pw).sum() / (pw.sum() or 1.0))

    edp = float((jenergy * turn)[done].sum())
    ed2p = float((jenergy * turn * turn)[done].sum())

    sizes = nodes[done]
    hist_small = int((sizes < SIZE_EDGES[1]).sum())
    hist_medium = int(((sizes >= SIZE_EDGES[1]) & (sizes < SIZE_EDGES[2])).sum())
    hist_large = int((sizes >= SIZE_EDGES[2]).sum())

    p = np.asarray(hist.power_total, np.float64)
    it = np.asarray(hist.power_it, np.float64)
    sim_seconds = float(p.shape[-1] * system.dt)
    out = {
        "jobs_completed": float(done.sum()),
        "throughput_per_hour": float(done.sum()) / (sim_seconds / 3600.0),
        "avg_wait_s": float(wait[done].mean()) if done.any() else 0.0,
        "avg_turnaround_s": float(turn[done].mean()) if done.any() else 0.0,
        "awrt_s": awrt,
        "psrt_s": psrt,
        "avg_job_nodes": float(sizes.mean()) if done.any() else 0.0,
        "avg_job_energy_j": float(jenergy[done].mean()) if done.any() else 0.0,
        "avg_job_power_w": float((jenergy[done] / np.maximum(wall[done], 1.0)).mean()) if done.any() else 0.0,
        "edp": edp / max(n_done, 1),
        "ed2p": ed2p / max(n_done, 1),
        "hist_small": hist_small,
        "hist_medium": hist_medium,
        "hist_large": hist_large,
        "avg_system_power_mw": float(p.mean() / 1e6),
        "avg_it_power_mw": float(it.mean() / 1e6),
        "avg_util": float(np.asarray(hist.util, np.float64).mean()),
        "max_power_mw": float(p.max() / 1e6),
        "power_swing_mw": float((p.max() - p.min()) / 1e6),
        "avg_pue": float(np.asarray(hist.pue, np.float64).mean()),
        "total_energy_mwh": float(np.asarray(final.energy_total) / 3.6e9),
        "loss_energy_mwh": float(np.asarray(final.energy_loss) / 3.6e9),
        "power_efficiency": float(np.asarray(final.energy_it) /
                                  max(float(np.asarray(final.energy_total)), 1.0)),
        "carbon_kg_est": float(np.asarray(final.energy_total) / 3.6e9 * 370.0),
        # grid-aware accounting (signal-weighted; zero under neutral signals)
        "emissions_kg": float(np.asarray(final.emissions_kg)),
        "energy_cost_usd": float(np.asarray(final.energy_cost)),
        "avg_throttle_frac": float(
            np.asarray(hist.throttle_frac, np.float64).mean()),
        "throttled_steps": float(
            (np.asarray(hist.throttle_frac, np.float64) > 1e-6).sum()),
        # cooling-loop telemetry (repro.cooling): tower temps in °C,
        # parasitic/exported energies in MWh
        "t_tower_return_avg_c": float(
            np.asarray(hist.t_tower_return, np.float64).mean()),
        "t_tower_return_max_c": float(
            np.asarray(hist.t_tower_return, np.float64).max()),
        "t_supply_max_c": float(
            np.asarray(hist.t_supply_max, np.float64).max()),
        "t_basin_max_c": float(np.asarray(hist.t_basin, np.float64).max()),
        "avg_wetbulb_c": float(np.asarray(hist.t_wetbulb, np.float64).mean()),
        "cooling_energy_mwh": float(np.asarray(final.energy_cooling) / 3.6e9),
        "fan_energy_mwh": float(
            np.asarray(hist.power_fan, np.float64).sum() * system.dt / 3.6e9),
        "pump_energy_mwh": float(
            np.asarray(hist.power_pump, np.float64).sum() * system.dt / 3.6e9),
        "heat_reuse_mwh": float(np.asarray(final.heat_reuse_j) / 3.6e9),
        "thermal_throttled_steps": float(
            (np.asarray(hist.thermal_throttled, np.float64) > 0.5).sum()),
    }
    # ride-through scoring (repro.events): getattr-guarded so plugin-mode
    # callers that assemble partial histories keep working; all zeros when
    # the event layer is off
    ev = getattr(final, "events", None)
    nodes_down = getattr(hist, "nodes_down", None)
    if ev is not None:
        out["ride_jobs_killed"] = float(np.asarray(ev.jobs_killed))
        out["ride_jobs_requeued"] = float(np.asarray(ev.jobs_requeued))
        out["ride_energy_unserved_mwh"] = float(
            np.asarray(ev.energy_lost_j) / 3.6e9)
        out["ride_node_downtime_h"] = float(
            np.asarray(ev.node_downtime_s) / 3600.0)
    if ev is not None and nodes_down is not None:
        # recovery time: from the last step with nodes down to the first
        # later step where the queue has drained back to its depth at the
        # moment the first failure hit (horizon-censored; 0 = no failures)
        nd = np.asarray(nodes_down, np.float64)
        nq = np.asarray(hist.n_queued, np.float64)
        downs = np.nonzero(nd > 0.0)[0]
        if downs.size == 0:
            out["ride_recovery_s"] = 0.0
        else:
            first, last = int(downs[0]), int(downs[-1])
            later = np.nonzero(nq[last:] <= nq[first])[0]
            rec = int(later[0]) if later.size else nd.shape[-1] - last
            out["ride_recovery_s"] = float(rec * system.dt)
    # per-hall rows (FacilityTopology): IT-load share, basin peak, cells.
    # A flat plant contributes one hall with share 1.0.
    p_hall = np.asarray(hist.power_it_hall, np.float64)
    tb_hall = np.asarray(hist.t_basin_hall, np.float64)
    cells = np.asarray(hist.cells_online, np.float64)
    total = max(p_hall.sum(), 1.0)
    oh_hall = getattr(hist, "overheat_hall", None)
    for h in range(p_hall.shape[-1]):
        out[f"hall{h}_it_share"] = float(p_hall[..., h].sum() / total)
        out[f"hall{h}_basin_max_c"] = float(tb_hall[..., h].max())
        out[f"hall{h}_cells_online_min"] = float(cells[..., h].min())
        if oh_hall is not None:
            # per-hall overheat exposure: seconds the hall spent with its
            # supply setpoint lost (ride-through scoring, repro.events)
            out[f"hall{h}_overheat_s"] = float(
                (np.asarray(oh_hall, np.float64)[..., h] > 0.5).sum() *
                system.dt)
    return out


def format_stats(stats: Dict[str, float]) -> str:
    return "\n".join(f"{k:>24s} : {v:,.3f}" for k, v in stats.items())
