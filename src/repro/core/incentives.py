"""Incentive structures (paper §4.3), after Solorzano et al. [37]:
accounts *collect* points for power-efficient behavior and *redeem* them as
scheduling priority.

Fugaku points reward low average per-node power relative to a system
reference: an account running ``node_hours`` at average per-node power
``avg_pnode`` earns

    pts = node_hours * max(0, (P_ref - avg_pnode) / P_ref)

so frugal jobs earn up to their full node-hours in points while jobs at or
above the reference earn nothing. The redeeming phase is a scheduler policy
(``acct_fugaku_pts``) that sorts the queue by accumulated points (descending);
the other account policies (``acct_avg_power``, ``acct_low_avg_power``,
``acct_edp``, ``acct_ed2p``) are defined analogously — see
``repro.core.scheduler.policy_key``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.systems.config import SystemConfig


def fugaku_points(system: SystemConfig, node_hours: jnp.ndarray,
                  avg_pnode_w: jnp.ndarray) -> jnp.ndarray:
    p_ref = system.power.ref_node_w
    frac = (p_ref - avg_pnode_w) / p_ref
    return node_hours * jnp.clip(frac, 0.0, 1.0)
