"""S-RAPS core: the paper's contribution as a composable JAX module."""
from repro.core import types  # noqa: F401
from repro.core.engine import (  # noqa: F401
    simulate, simulate_sweep, init_state, engine_step, external_step)
from repro.core.types import Scenario, JobTable, SimState  # noqa: F401
