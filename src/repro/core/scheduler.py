"""Built-in scheduler: policy priority keys + bounded admission loop with
no-backfill / first-fit / EASY semantics (paper §3.2.4-§3.2.5).

Design notes
------------
* The policy and the backfill mode are **traced integers** (fields of
  ``Scenario``), so an entire sweep of scheduling configurations runs as one
  vmapped program — this is the TPU-native form of the paper's what-if studies.
* The admission loop is a ``lax.fori_loop`` over the first ``sched_budget``
  entries of the key-sorted queue: bounded work per cycle, like a production
  scheduler's main loop.
* EASY (Mu'alem & Feitelson): when the queue head cannot start, it receives a
  reservation at the *shadow time* (earliest time enough nodes free up, from
  the running jobs' end times); later jobs may backfill iff they fit now and
  either (a) finish before the shadow time (by their *requested* limit) or
  (b) use no more than the ``extra`` nodes spare at the shadow time.
* Shadow times use the running set at the top of the scheduling pass; jobs
  placed earlier in the same pass consume ``free_count`` but are not added to
  the release profile (they end after ``t + their wall``, which can only make
  the true shadow later — so our backfill test is conservative in case (a)
  and standard in case (b)).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.cooling import model as cmodel
from repro.core import resource_manager as rm
from repro.core import types as T
from repro.grid import signals as gsig
from repro.kernels.power_topo.ref import group_ids
from repro.systems.config import SystemConfig


# ---------------------------------------------------------------------------
# Priority keys (smaller key = scheduled earlier).
# ---------------------------------------------------------------------------
def policy_key(table: T.JobTable, accounts: T.AccountStats,
               scen: T.Scenario,
               grid: gsig.GridNow | None = None,
               thermal: cmodel.ThermalNow | None = None) -> jnp.ndarray:
    """f32[J] primary sort key for the selected policy (smaller = earlier).

    Args:
      table: static job table (times s, power W).
      accounts: per-account ledgers feeding the incentive policies.
      scen: traced scenario knobs (policy id, deferral weights).
      grid: grid-signal values at this step (g CO2/kWh, $/kWh, W); neutral
        when ``None``.
      thermal: cooling-pressure signals at this step (°C-derived, see
        ``repro.cooling.model.thermal_now``); neutral when ``None``.

    When ``scen.policy`` is a *Python int* (static-scenario fast path,
    docs/architecture.md) only the selected key is computed; traced
    policies compute the full stack and select (vmappable sweeps).
    """
    if grid is None:
        grid = gsig.now_neutral()
    if thermal is None:
        thermal = cmodel.thermal_neutral()
    acct = table.account

    def avg_pw():
        return accounts.power_sum[acct] / jnp.maximum(
            accounts.jobs_done[acct], 1.0)

    # grid-aware deferral (carbon_aware / price_aware): FCFS order plus a
    # penalty on *energy-heavy* jobs (node-seconds as the energy proxy)
    # while the signal sits above its rolling mean. Weight 0 == pure FCFS,
    # so a (weight x cap) sweep brackets the baseline.
    defer_cost = table.nodes.astype(jnp.float32) * table.limit

    def grid_key(now, ref, weight):
        excess = jnp.maximum(now - ref, 0.0) / jnp.maximum(ref, 1e-6)
        return table.submit + weight * excess * defer_cost

    # cooling-aware deferral (thermal_aware): FCFS order plus a penalty on
    # *heat-dense* jobs (estimated W x node·s, in kW·node·s so the scale
    # matches the grid policies) that ramps in as the hottest CDU return
    # temperature enters the soft band below its limit. Weight 0 == FCFS.
    defer_heat = defer_cost * table.power_prof[:, 0] * 1e-3

    def thermal_key():
        return table.submit + scen.thermal_weight * thermal.excess * \
            defer_heat

    # ML-guided key (paper §4.4.2): higher score = earlier. The score has a
    # static part (``table.score``, baked at attach time) plus a
    # *parameterized* part ``ml_basis @ scen.alpha`` — linear in the traced
    # alpha vector, so a vmapped sweep evaluates one alpha per scenario
    # against the shared basis (the ES population axis, repro.ml.train).
    # ``ml_basis is None`` is compile-time "legacy score only".
    def ml_key():
        s = table.score
        if table.ml_basis is not None:
            s = s + jnp.sum(table.ml_basis * scen.alpha, axis=-1)
        return -s

    builders = [
        lambda: table.rec_start,            # REPLAY: recorded order
        lambda: table.submit,               # FCFS
        lambda: table.limit,                # SJF
        lambda: -table.nodes.astype(jnp.float32),   # LJF
        lambda: -table.priority,            # PRIORITY (higher first)
        lambda: -avg_pw(),                  # ACCT_AVG_POWER (descending)
        avg_pw,                             # ACCT_LOW_AVG_POWER (ascending)
        lambda: accounts.edp[acct],         # ACCT_EDP (lower first)
        lambda: accounts.ed2p[acct],        # ACCT_ED2P
        lambda: -accounts.fugaku_pts[acct],  # ACCT_FUGAKU_PTS
        ml_key,                             # ML score (higher is better)
        lambda: grid_key(grid.carbon, grid.carbon_ref,
                         scen.carbon_weight),       # CARBON_AWARE
        lambda: grid_key(grid.price, grid.price_ref,
                         scen.price_weight),        # PRICE_AWARE
        thermal_key,                                # THERMAL_AWARE
    ]
    if isinstance(scen.policy, int):        # static fast path
        k = builders[scen.policy]()
        if T.POLICY_ACCT_AVG_POWER <= scen.policy <= T.POLICY_ACCT_FUGAKU_PTS:
            k = k * scen.acct_weight
        return k
    keys = jnp.stack([b() for b in builders])
    k = jnp.take(keys, scen.policy, axis=0)
    # account-derived keys mix with the scenario weight (lets a sweep soften
    # the incentive signal); neutral for the base policies.
    is_acct = (scen.policy >= T.POLICY_ACCT_AVG_POWER) & \
              (scen.policy <= T.POLICY_ACCT_FUGAKU_PTS)
    return jnp.where(is_acct, k * scen.acct_weight, k)


def queue_order(table: T.JobTable, st: T.SimState, accounts: T.AccountStats,
                scen: T.Scenario, grid: gsig.GridNow | None = None,
                thermal: cmodel.ThermalNow | None = None
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sorted queue: eligible jobs first by (key, submit). Returns
    (order i32[J], eligible bool[J])."""
    queued = st.jstate == T.QUEUED
    replay_gate = jnp.where(scen.policy == T.POLICY_REPLAY,
                            table.rec_start <= st.t, True)
    elig = queued & replay_gate & table.valid
    key = jnp.where(elig, policy_key(table, accounts, scen, grid, thermal),
                    jnp.inf)
    tie = jnp.where(elig, table.submit, jnp.inf)
    order = jnp.lexsort((tie, key))  # primary: key, secondary: submit
    return order.astype(jnp.int32), elig


# ---------------------------------------------------------------------------
# EASY shadow-time machinery.
# ---------------------------------------------------------------------------
def release_profile(table: T.JobTable, st: T.SimState):
    """Sorted *estimated* end times of running jobs and cumulative nodes they
    release. Faithful EASY uses the user-requested limit, not the (unknown)
    true runtime: est_end = start + limit.

    Returns (end_sorted f32[J], cum_nodes i32[J]).
    """
    running = st.jstate == T.RUNNING
    est_end = jnp.where(running, st.start + table.limit, jnp.inf)
    order = jnp.argsort(est_end)
    nodes_released = jnp.where(running, table.nodes, 0)[order]
    return est_end[order], jnp.cumsum(nodes_released)


def shadow_for(end_sorted: jnp.ndarray, cum_nodes: jnp.ndarray,
               free_now: jnp.ndarray, need: jnp.ndarray):
    """Earliest time ``need`` nodes are simultaneously free, and the surplus
    ("extra") nodes available at that time."""
    deficit = jnp.maximum(need - free_now, 0)
    k = jnp.searchsorted(cum_nodes, deficit, side="left")
    k = jnp.clip(k, 0, cum_nodes.shape[0] - 1)
    shadow_t = jnp.where(deficit == 0, jnp.float32(0.0), end_sorted[k])
    extra = free_now + cum_nodes[k] - need
    return shadow_t, jnp.maximum(extra, 0)


# ---------------------------------------------------------------------------
# Hall-aware placement (repro.systems.config.FacilityTopology).
# ---------------------------------------------------------------------------
def _hall_spans(system: SystemConfig):
    """Static (node_hall i32[N], sizes i32[H], first-node i32[H]) of the
    contiguous per-hall node spans (host-side numpy; trace-time
    constants)."""
    n_nodes, n_groups = system.n_nodes, system.cooling.n_groups
    gid = np.asarray(group_ids(n_nodes, n_groups))  # the single source of
    #                          the node->CDU rule (kernels/power_topo/ref)
    node_hall = np.asarray(system.cooling.hall_of_group(),
                           np.int32)[gid]
    sizes = np.bincount(node_hall, minlength=system.cooling.n_halls)
    first = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    return node_hall, sizes.astype(np.int32), first.astype(np.int32)


def hall_placement_plan(system: SystemConfig, st: T.SimState,
                        thermal: cmodel.ThermalNow, is_replay):
    """Node preference order + per-hall admission inputs for one pass.

    Nodes are ordered by their hall's cooling pressure (soft-band
    ``excess_hall``, with overheated halls pushed last), index-stable
    within a hall — so first-free placement drains into the coolest hall
    first and an overheating hall stops receiving work while any other
    hall has room. Replay keeps the identity order (the recorded
    placement is ground truth).

    Halls are contiguous node spans, so the permutation is built from an
    H-element sort plus an O(N) scatter — no per-step N·log N sort inside
    the scan (H is tens, N up to ~160k).

    Returns (order i32[N], node_ok bool[N], free_ok i32[]): the
    preference permutation, which nodes sit in a non-overheated hall, and
    how many of those are currently free (the per-job admission budget —
    a job may start iff it fits inside ``free_ok``).
    """
    node_hall_np, sizes_np, first_np = _hall_spans(system)
    node_hall = jnp.asarray(node_hall_np)
    sizes = jnp.asarray(sizes_np)
    first = jnp.asarray(first_np)
    H = system.cooling.n_halls
    node_ok = ~thermal.overheat_hall[node_hall]
    penalty_h = thermal.excess_hall + \
        1e3 * thermal.overheat_hall.astype(jnp.float32)
    penalty_h = penalty_h * jnp.where(is_replay, 0.0, 1.0)
    # stable H-sort of halls by pressure, then concatenate their spans:
    # out_start[h] = where hall h's span begins in the preference order
    hall_order = jnp.lexsort((jnp.arange(H), penalty_h))
    sz_sorted = sizes[hall_order]
    starts_sorted = jnp.cumsum(sz_sorted) - sz_sorted     # exclusive cumsum
    out_start = jnp.zeros((H,), jnp.int32).at[hall_order].set(
        starts_sorted.astype(jnp.int32))
    idx = jnp.arange(system.n_nodes, dtype=jnp.int32)
    pos = out_start[node_hall] + (idx - first[node_hall])
    order = jnp.zeros_like(idx).at[pos].set(idx)
    free_ok = jnp.sum(((st.node_job == -1) & node_ok).astype(jnp.int32))
    return order, node_ok, free_ok


# ---------------------------------------------------------------------------
# The scheduling pass.
# ---------------------------------------------------------------------------
def schedule_step(system: SystemConfig, table: T.JobTable, st: T.SimState,
                  scen: T.Scenario, grid: gsig.GridNow | None = None,
                  proj_pw: jnp.ndarray | None = None,
                  thermal: cmodel.ThermalNow | None = None,
                  dr=None) -> T.SimState:
    """One call of ``schedule`` (paper Algorithm step 3): reorder the queue by
    the selected policy and admit jobs under the selected backfill rule.

    Cap-aware admission: when a power-cap schedule is active
    (``grid.cap_w * scen.cap_scale`` finite), a job is only started if the
    projected IT power (``proj_pw``, the current raw draw, plus the added
    draw of jobs placed earlier in this pass) stays under the cap — the
    DVFS throttle (repro.grid.powercap) then only has to absorb profile
    ramps, not admission mistakes. A head job blocked *by the cap alone*
    halts admission under BF_NONE and BF_EASY (backfilled jobs would eat
    the headroom it is waiting for and starve it); first-fit stays greedy.
    ``grid is None`` (no signals) is compile-time: the cap machinery folds
    away entirely.

    Thermal admission throttling: when a hall's cooling loop has lost the
    supply setpoint by more than ``CoolingConfig.t_supply_margin_c``
    (``thermal.overheat_hall``, see repro.cooling.model.thermal_now),
    admission into *that hall* is deferred for this step — starting more
    work while its CDUs cannot hold setpoint only pushes the loop further
    from it. On a multi-hall topology, placement is hall-aware
    (``hall_placement_plan``): nodes are drained coolest-hall-first and a
    job is admitted only if it fits inside the non-overheated halls; a
    flat (1-hall) plant keeps the original all-or-nothing gate and
    identity placement order bit-for-bit. Replay is exempt (the recorded
    schedule is ground truth), and running jobs are untouched (heat
    relief comes from completions).

    Demand-response notice window: ``dr`` (a ``repro.events.DrNow``,
    grid path only) announces a coming cap step. During the notice
    window, a job whose *requested limit* runs into the event is only
    admitted if the projected power would still fit under the announced
    cap — the scheduler pre-positions for the cap instead of slamming
    into it. ``dr is None`` is compile-time "no DR machinery"."""
    has_grid = grid is not None
    is_replay = scen.policy == T.POLICY_REPLAY
    hall_aware = thermal is not None and system.cooling.n_halls > 1
    if hall_aware:
        order_nodes, node_ok, free_ok0 = hall_placement_plan(
            system, st, thermal, is_replay)
    else:
        order_nodes = node_ok = None
        free_ok0 = st.free_count
    thermal_ok = jnp.bool_(True) if thermal is None else ~thermal.overheat
    if has_grid:
        cap_active = grid.cap_w * scen.cap_scale
        if dr is not None:
            # an in-force DR event caps admission below the schedule
            cap_active = jnp.minimum(cap_active, dr.cap_now_w)
        # estimated power a job adds on start: first profile sample above
        # the idle floor its nodes already draw
        est_add_pw = jnp.maximum(
            table.power_prof[:, 0] - system.power.idle_node_w, 0.0) * \
            table.nodes.astype(jnp.float32)
    if proj_pw is None:
        proj_pw = jnp.float32(0.0)
    order, _elig = queue_order(table, st, st.accounts, scen, grid, thermal)
    static = isinstance(scen.backfill, int)
    if static and scen.backfill != T.BF_EASY:
        # static fast path: no reservation machinery needed
        end_sorted = jnp.zeros((1,), jnp.float32)
        cum_nodes = jnp.zeros((1,), jnp.int32)
    else:
        end_sorted, cum_nodes = release_profile(table, st)
    n_nodes = system.n_nodes
    t = st.t

    def body(i, carry):
        (node_job, jstate, start, end, free_count, free_ok, proj,
         blocked_any, head_blocked, head_capped,
         shadow_t, shadow_extra) = carry
        j = order[i]
        valid = jstate[j] == T.QUEUED
        # replay eligibility re-gate (queue_order already filtered, but jobs
        # whose recorded start is still in the future must keep waiting)
        valid &= jnp.where(is_replay, table.rec_start[j] <= t, True)
        need = table.nodes[j]

        # --- does it fit right now? ---
        # Placement is deterministic first-free (lowest-index free nodes);
        # the dataset generators use the same rule, so replay reproduces the
        # recorded occupancy without storing per-node assignments. On a
        # multi-hall plant the scan order is the hall-preference
        # permutation instead (coolest hall first, index-stable within a
        # hall; identity under replay and when every hall is equally cool).
        if hall_aware:
            sel = rm.firstfree_mask_ordered(node_job, need, order_nodes)
        else:
            sel = rm.firstfree_mask(node_job, need)
        fits = need <= free_count

        # --- EASY reservation for the first blocked (head) job ---
        first_block = valid & ~fits & ~head_blocked
        sh_t, sh_extra = shadow_for(end_sorted, cum_nodes, free_count, need)
        shadow_t = jnp.where(first_block, sh_t, shadow_t)
        shadow_extra = jnp.where(first_block, sh_extra, shadow_extra)

        # --- admission rule ---
        # a cap-blocked head has no node-shadow to reserve (power, not
        # nodes, is scarce): EASY halts instead, so backfill cannot eat
        # the headroom the head is waiting for
        easy_ok = ((t + table.limit[j] <= shadow_t) |
                   (need <= shadow_extra)) & ~head_capped
        if static:
            can_bf = {T.BF_NONE: ~blocked_any,
                      T.BF_FIRSTFIT: jnp.bool_(True),
                      T.BF_EASY: jnp.where(head_blocked | head_capped,
                                           easy_ok, True),
                      }[scen.backfill]
        else:
            can_bf = jnp.select(
                [scen.backfill == T.BF_NONE,
                 scen.backfill == T.BF_FIRSTFIT],
                [~blocked_any,
                 jnp.bool_(True)],
                jnp.where(head_blocked | head_capped, easy_ok, True),
            )
        # cap-aware admission: starting this job must not breach the cap
        if has_grid:
            cap_ok = proj + est_add_pw[j] <= cap_active
            if dr is not None:
                # notice-window pre-positioning: a job that would still be
                # running when the announced DR cap engages must also fit
                # under *that* cap
                runs_into = dr.in_notice & (t + table.limit[j] > dr.start_s)
                cap_ok &= ~runs_into | (proj + est_add_pw[j] <= dr.cap_w)
        else:
            cap_ok = jnp.bool_(True)
        # thermal admission: flat plant -> all-or-nothing gate; multi-hall
        # -> the job must fit inside the halls still holding setpoint
        # (preference ordering guarantees the selection stays there).
        # Like the cap, thermal is a non-node resource: a head blocked by
        # it feeds blocked_any/head_capped below so BF_NONE keeps FIFO
        # order and EASY halts instead of reserving a node-shadow.
        th_ok = (need <= free_ok) if hall_aware else thermal_ok
        # replay ignores backfill, the cap and the thermal gate: recorded
        # schedule is truth
        place = valid & fits & jnp.where(is_replay, True,
                                         can_bf & cap_ok & th_ok)

        # --- commit ---
        node_job = rm.place(node_job, sel, j, place)
        free_count = free_count - jnp.where(place, need, 0)
        if hall_aware:
            free_ok = free_ok - jnp.where(
                place, jnp.sum((sel & node_ok).astype(jnp.int32)), 0)
        # (on a flat plant free_ok is inert carry: the all-or-nothing gate
        # never reads it)
        if has_grid:
            proj = proj + jnp.where(place, est_add_pw[j], 0.0)
        jstate = jstate.at[j].set(jnp.where(place, T.RUNNING, jstate[j]))
        start = start.at[j].set(jnp.where(place, t, start[j]))
        end = end.at[j].set(jnp.where(place, t + table.wall[j], end[j]))

        blocked_any |= valid & (~fits | ~cap_ok | ~th_ok)
        head_blocked |= valid & ~fits
        head_capped |= valid & fits & (~cap_ok | ~th_ok)
        return (node_job, jstate, start, end, free_count, free_ok, proj,
                blocked_any, head_blocked, head_capped,
                shadow_t, shadow_extra)

    carry = (st.node_job, st.jstate, st.start, st.end, st.free_count,
             jnp.int32(free_ok0),
             jnp.float32(proj_pw), jnp.bool_(False), jnp.bool_(False),
             jnp.bool_(False), jnp.float32(jnp.inf), jnp.int32(0))
    K = min(system.sched_budget, table.num_jobs)
    (node_job, jstate, start, end, free_count,
     *_rest) = jax.lax.fori_loop(0, K, body, carry)

    return dataclasses.replace(st, jstate=jstate, start=start, end=end,
                               node_job=node_job, free_count=free_count)
