"""HPC system configurations (paper Table 1).

A ``SystemConfig`` is *static* (hashable) — it parameterizes the compiled
engine. Numbers are taken from the paper where stated and from the cited
public documentation otherwise; they are calibration targets for the
synthetic dataset generators, not claims about the real machines. The
power/cooling parasitics are sized so the simulated PUE lands near the
paper's note that Frontier's actual PUE averages ~1.06.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Tuple


@dataclass(frozen=True)
class PowerConfig:
    """util -> electrical power model for one node (repro.power.model)."""
    idle_node_w: float = 200.0       # node power at zero utilization
    peak_node_w: float = 1000.0      # node power at full utilization
    # rectifier efficiency eta(load) = c0 + c1*load + c2*load^2 (clipped)
    rect_c: Tuple[float, float, float] = (0.95, 0.05, -0.025)
    # secondary (sivoc / board VR) efficiency, same polynomial form
    sivoc_c: Tuple[float, float, float] = (0.97, 0.02, -0.01)
    rated_rack_kw: float = 300.0     # rectifier rated load per rack
    nodes_per_rack: int = 64
    ref_node_w: float = 800.0        # reference per-node power for Fugaku pts


@dataclass(frozen=True)
class FacilityTopology:
    """Hierarchical facility layout: halls -> CDU groups -> nodes.

    A *hall* is one machine room served by its own tower loop (basin +
    fan cells). CDU groups are assigned to halls by contiguous spans, and
    nodes map to CDU groups by contiguous spans (``kernels.power_topo.ref
    .group_ids``) — so the node->hall assignment is fully determined by
    this static description. The default (one hall, even splits) is the
    pre-hierarchy flat plant and reproduces its behavior exactly.

    ``groups_per_hall`` / ``cells_per_hall`` may be ``None`` (even split
    of ``CoolingConfig.n_groups`` / ``n_tower_cells``, first halls take
    the remainder) or explicit per-hall tuples summing to the config
    totals — ragged halls are allowed.
    """
    n_halls: int = 1
    groups_per_hall: Tuple[int, ...] | None = None
    cells_per_hall: Tuple[int, ...] | None = None

    def _split(self, total: int, explicit: Tuple[int, ...] | None,
               what: str) -> Tuple[int, ...]:
        if self.n_halls < 1:
            raise ValueError(f"n_halls must be >= 1, got {self.n_halls}")
        if explicit is not None:
            if len(explicit) != self.n_halls:
                raise ValueError(f"{what}: {len(explicit)} entries for "
                                 f"{self.n_halls} halls")
            if sum(explicit) != total:
                raise ValueError(f"{what}: sum {sum(explicit)} != {total}")
            if min(explicit) < 1:
                raise ValueError(f"{what}: every hall needs >= 1, "
                                 f"got {explicit}")
            return tuple(int(g) for g in explicit)
        base, rem = divmod(total, self.n_halls)
        if base < 1:
            raise ValueError(f"{what}: {total} cannot cover "
                             f"{self.n_halls} halls")
        return tuple(base + (1 if h < rem else 0)
                     for h in range(self.n_halls))

    def resolve_groups(self, n_groups: int) -> Tuple[int, ...]:
        """Per-hall CDU group counts (sums to ``n_groups``)."""
        return self._split(n_groups, self.groups_per_hall, "groups_per_hall")

    def resolve_cells(self, n_cells: int) -> Tuple[int, ...]:
        """Per-hall installed tower-cell counts (sums to ``n_cells``)."""
        return self._split(n_cells, self.cells_per_hall, "cells_per_hall")

    def hall_of_group(self, n_groups: int) -> Tuple[int, ...]:
        """Hall index of each CDU group (len ``n_groups``)."""
        out = []
        for h, g in enumerate(self.resolve_groups(n_groups)):
            out.extend([h] * g)
        return tuple(out)


@dataclass(frozen=True)
class CoolingConfig:
    """Transient CDU + cooling-tower loop parameters (repro.cooling.model).

    Units: temperatures °C, heat/power W, flow kg/s, conductance W/K,
    time constants s. Derived quantities (tower-cell conductance, basin
    thermal mass) default to ``None`` and are computed from the rated
    numbers — see ``cell_ua()`` / ``basin_mcp()`` — so per-system configs
    stay consistent when only the rated capacity is overridden.
    """
    n_groups: int = 8                # CDU groups (segment-reduce targets)
    mdot_kg_s: float = 40.0          # max water mass flow per CDU (kg/s)
    cp_j_kg_k: float = 4186.0        # specific heat of water (J/(kg·K))
    t_supply_setpoint_c: float = 25.0
    ua_w_k: float = 4.0e5            # facility HX conductance per group (W/K)
    tower_tau_s: float = 600.0       # basin/tower thermal time constant (s)
    t_wetbulb_c: float = 18.0        # default ambient wet-bulb (no weather)
    tower_approach_c: float = 4.0    # tower approach at design (°C above wb)
    n_tower_cells: int = 4
    cell_rated_heat_w: float = 2.5e6  # heat rejection per tower cell (W)
    fan_rated_w: float = 4.0e4       # tower fan rated power per cell (W)
    pump_w_per_group: float = 1.0e4  # CDU pump rated power (W, at full flow)
    # --- CDU valve/pump dynamics -------------------------------------------
    delta_t_design_c: float = 8.0    # design water ΔT across a CDU
    mdot_min_frac: float = 0.2       # valve floor as a fraction of mdot_kg_s
    tau_valve_s: float = 60.0        # flow slew time constant
    tau_hx_s: float = 120.0          # facility HX / supply-loop time constant
    # --- tower fan staging --------------------------------------------------
    tau_fan_s: float = 120.0         # fan staging slew time constant
    cell_ua_w_k: float | None = None  # tower-cell conductance at full fan
    basin_mcp_j_k: float | None = None  # basin thermal mass × cp (J/K)
    basin_margin_c: float = 3.0      # basin target sits this far below setpoint
    # fans-off ambient coupling (natural draft + windage), as a fraction of
    # the full-fan tower conductance; bidirectional — a heat wave warms an
    # idle basin toward the ambient wet-bulb through this path
    passive_ua_frac: float = 0.15
    # --- heat reuse / export (district-heating side stream) -----------------
    reuse_frac: float = 0.0          # fraction of return heat divertible
    reuse_max_w: float = 0.0         # export capacity cap (W)
    reuse_t_min_c: float = 30.0      # minimum return temp for useful export
    # --- thermal-aware scheduling limits ------------------------------------
    t_return_limit_c: float = 45.0   # hard limit on CDU return water temp
    thermal_margin_c: float = 5.0    # soft band below the limit (policy ramp)
    # supply excess (above setpoint) that halts admission: a last-resort
    # brake, sized to trip only after the thermal_aware deferral band —
    # ambient alone can push supply a few °C over setpoint in a heat wave
    t_supply_margin_c: float = 10.0
    # --- facility hierarchy (halls -> CDU groups -> nodes) ------------------
    topology: FacilityTopology = field(default_factory=FacilityTopology)

    @property
    def n_halls(self) -> int:
        return self.topology.n_halls

    def groups_per_hall(self) -> Tuple[int, ...]:
        return self.topology.resolve_groups(self.n_groups)

    def cells_per_hall(self) -> Tuple[int, ...]:
        return self.topology.resolve_cells(self.n_tower_cells)

    def hall_of_group(self) -> Tuple[int, ...]:
        return self.topology.hall_of_group(self.n_groups)

    def hall_weights(self) -> Tuple[float, ...]:
        """Fraction of the CDU fleet (and thus of the nominal heat load)
        served by each hall; splits hall-agnostic capacity knobs such as
        ``reuse_max_w``."""
        return tuple(g / self.n_groups for g in self.groups_per_hall())

    def cell_ua(self) -> float:
        """Tower-cell conductance (W/K) at full fan speed; rated heat over a
        6 °C basin-to-wet-bulb driving ΔT unless set explicitly."""
        return self.cell_ua_w_k if self.cell_ua_w_k is not None \
            else self.cell_rated_heat_w / 6.0

    def basin_mcp(self) -> float:
        """Facility-total basin thermal mass × cp (J/K): sized so the
        open-loop tower time constant is ``tower_tau_s`` at full-fan
        conductance."""
        return self.basin_mcp_j_k if self.basin_mcp_j_k is not None \
            else self.tower_tau_s * self.n_tower_cells * self.cell_ua()

    def basin_mcp_per_hall(self) -> Tuple[float, ...]:
        """Per-hall basin thermal mass × cp (J/K): each hall's basin scales
        with its installed cell count, so the per-hall open-loop time
        constant stays ``tower_tau_s``. Sums to ``basin_mcp()``."""
        total = self.basin_mcp()
        return tuple(total * c / self.n_tower_cells
                     for c in self.cells_per_hall())


@dataclass(frozen=True)
class GridConfig:
    """Grid-signal generators + DVFS power-capping limits (repro.grid).

    The *signals* themselves (carbon intensity, price, cap schedule) are
    precomputed arrays sampled at engine ``dt`` — see
    ``repro.grid.signals.synthetic_signals``; this config holds the static
    generator parameters and the throttle floor the cap-enforcement pass may
    not go below.
    """
    c_min: float = 0.5               # lowest DVFS cap factor (1 = no throttle)
    carbon_mean_gkwh: float = 350.0  # diurnal carbon intensity mean (g/kWh)
    carbon_amp_gkwh: float = 120.0   # diurnal swing amplitude
    price_mean_kwh: float = 0.08     # electricity price mean ($/kWh)
    price_amp_kwh: float = 0.04      # diurnal swing amplitude
    noise_frac: float = 0.05         # multiplicative AR(1) noise level
    ref_window_s: float = 6 * 3600.0  # rolling-mean window for "above average"
    peak_hours: Tuple[float, float] = (17.0, 21.0)  # evening price/cap peak


@dataclass(frozen=True)
class SystemConfig:
    name: str
    n_nodes: int
    prof_dt: float                   # telemetry sample period (s)
    scheduler: str                   # production scheduler (documentation)
    has_traces: bool                 # per-job time series vs scalar summary
    power: PowerConfig = field(default_factory=PowerConfig)
    cooling: CoolingConfig = field(default_factory=CoolingConfig)
    grid: GridConfig = field(default_factory=GridConfig)
    # engine defaults
    dt: float = 15.0                 # engine step (s)
    sched_budget: int = 32           # placement attempts per engine step

    def scaled(self, n_nodes: int) -> "SystemConfig":
        """A reduced-size variant for CPU tests: the cooling plant and rack
        fleet scale with the node count so PUE / loss fractions stay
        realistic. Per-group parameters are unchanged (each CDU still serves
        a similar node span)."""
        ratio = n_nodes / self.n_nodes
        # keep tower capacity proportional: resize cell count and rating so
        # cells * rating ~= ratio * original capacity; fan rating and the
        # heat-export cap follow so parasitic *fractions* stay realistic
        cells = max(int(round(self.cooling.n_tower_cells * ratio)), 1)
        cap = self.cooling.n_tower_cells * self.cooling.cell_rated_heat_w * ratio
        groups = max(int(round(self.cooling.n_groups * ratio)), 2)
        # explicit per-hall splits no longer sum to the scaled totals:
        # keep the hall count, fall back to even splits (clamped so every
        # hall keeps at least one group and one cell)
        halls = min(self.cooling.n_halls, groups, cells)
        cool = replace(
            self.cooling,
            n_groups=groups,
            n_tower_cells=cells,
            cell_rated_heat_w=cap / cells,
            fan_rated_w=self.cooling.fan_rated_w *
            (cap / cells) / self.cooling.cell_rated_heat_w,
            reuse_max_w=self.cooling.reuse_max_w * ratio,
            topology=FacilityTopology(n_halls=halls),
        )
        return replace(self, name=f"{self.name}-scaled{n_nodes}",
                       n_nodes=n_nodes, cooling=cool)


# --- Table 1 ---------------------------------------------------------------
FRONTIER = SystemConfig(
    name="frontier", n_nodes=9600, prof_dt=15.0, scheduler="slurm",
    has_traces=True, dt=15.0,
    power=PowerConfig(idle_node_w=700.0, peak_node_w=3200.0,
                      rect_c=(0.955, 0.045, -0.02), sivoc_c=(0.975, 0.02, -0.01),
                      rated_rack_kw=400.0, nodes_per_rack=128,
                      ref_node_w=2500.0),
    cooling=CoolingConfig(n_groups=25, mdot_kg_s=60.0, t_supply_setpoint_c=32.0,
                          t_wetbulb_c=20.0, ua_w_k=1.2e6, n_tower_cells=16,
                          reuse_frac=0.15, reuse_max_w=4.0e6,
                          reuse_t_min_c=34.0),
)

MARCONI100 = SystemConfig(
    name="marconi100", n_nodes=980, prof_dt=20.0, scheduler="slurm",
    has_traces=True, dt=20.0,
    power=PowerConfig(idle_node_w=240.0, peak_node_w=2200.0, ref_node_w=1600.0),
    cooling=CoolingConfig(n_groups=10, n_tower_cells=2, cell_rated_heat_w=1.5e6,
                          fan_rated_w=2.4e4, reuse_frac=0.2,
                          reuse_max_w=3.0e5, reuse_t_min_c=32.0),
)

FUGAKU = SystemConfig(
    name="fugaku", n_nodes=158976, prof_dt=60.0, scheduler="tcs",
    has_traces=False, dt=60.0,
    power=PowerConfig(idle_node_w=60.0, peak_node_w=180.0,
                      rect_c=(0.955, 0.04, -0.02), nodes_per_rack=384,
                      rated_rack_kw=70.0, ref_node_w=140.0),
    cooling=CoolingConfig(n_groups=32, mdot_kg_s=80.0, ua_w_k=1.5e6,
                          n_tower_cells=15),
)

LASSEN = SystemConfig(
    name="lassen", n_nodes=792, prof_dt=60.0, scheduler="lsf",
    has_traces=False, dt=30.0,
    power=PowerConfig(idle_node_w=260.0, peak_node_w=2400.0, ref_node_w=1800.0),
    cooling=CoolingConfig(n_groups=8, n_tower_cells=1, cell_rated_heat_w=2.5e6),
)

ADASTRA = SystemConfig(
    name="adastraMI250", n_nodes=356, prof_dt=30.0, scheduler="slurm",
    has_traces=False, dt=30.0,
    power=PowerConfig(idle_node_w=450.0, peak_node_w=2800.0, ref_node_w=2000.0),
    cooling=CoolingConfig(n_groups=4, t_supply_setpoint_c=30.0,
                          n_tower_cells=1, cell_rated_heat_w=1.5e6,
                          fan_rated_w=2.4e4),
)

SYSTEMS: Dict[str, SystemConfig] = {
    s.name: s for s in (FRONTIER, MARCONI100, FUGAKU, LASSEN, ADASTRA)
}
# aliases matching the paper's CLI
SYSTEMS["adastra"] = ADASTRA
SYSTEMS["marconi"] = MARCONI100


def get_system(name: str) -> SystemConfig:
    try:
        return SYSTEMS[name]
    except KeyError:
        raise KeyError(f"unknown system '{name}'; known: {sorted(SYSTEMS)}")
