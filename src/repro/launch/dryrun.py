import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape x mesh)
cell on placeholder devices, record memory/cost analysis + collectives.

MUST be run as its own process (`python -m repro.launch.dryrun ...`) — the
XLA_FLAGS lines above execute before any jax import, giving 512 host
devices. Smoke tests and benchmarks never import this module.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --both-meshes
  python -m repro.launch.dryrun --list
Each cell appends JSON to results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.obs.reporter import get_logger
from repro.roofline import analysis as roofline
from repro.training import train_step as ts

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"
_log = get_logger()


def input_specs(arch: str, shape: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    seq, gb, kind = SHAPES[shape]
    return ts.batch_struct(cfg, seq, gb, kind)


def run_cell(arch: str, shape: str, multi_pod: bool,
             verbose: bool = True, unroll: bool = False) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if unroll:
        # full unroll of the layer scans: XLA cost analysis then counts every
        # layer (a rolled while-loop body is costed once) — exact roofline
        # terms at the price of a slower compile.
        cfg = dataclasses.replace(cfg, scan_unroll=True)
    seq, gb, kind = SHAPES[shape]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape}__{mesh_name}" + ("__unroll" if unroll else "")

    if shape in cfg.skip_shapes:
        return dict(cell=cell_id, status="SKIP",
                    reason=f"{arch} is full-attention (or shape not "
                           f"meaningful)")

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.perf_counter()

    with mesh:
        if kind == "train":
            step, shardings, structs = ts.make_train_step(cfg, mesh, seq, gb)
            args = (structs["params"], structs["opt"], structs["batch"])
        elif kind == "prefill":
            step, shardings, structs = ts.make_prefill_step(cfg, mesh, seq,
                                                            gb)
            args = (structs["params"], structs["batch"])
        else:  # decode / long_decode
            step, shardings, structs = ts.make_decode_step(cfg, mesh, seq,
                                                           gb, kind)
            args = (structs["params"], structs["tokens"], structs["state"])

        lowered = step.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()

    peak_mem = None
    mem_detail = {}
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_detail[attr] = int(v)
        live = mem_detail.get("temp_size_in_bytes", 0) + \
            mem_detail.get("argument_size_in_bytes", 0)
        peak_mem = live

    model_flops = roofline.model_flops_for(cfg, seq, gb, kind)
    hbm_model = roofline.analytic_hbm_bytes(cfg, seq, gb, kind, chips)
    rf = roofline.analyze(arch, shape, mesh_name, chips, cost or {}, hlo,
                          model_flops, peak_mem, hbm_model)

    rec = dict(cell=cell_id, status="OK", kind=kind, chips=chips,
               seq_len=seq, global_batch=gb,
               params=cfg.param_count, active_params=cfg.active_param_count,
               lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
               memory=mem_detail, roofline=rf.as_dict(),
               hlo_bytes=len(hlo))
    if verbose:
        _log.info(json.dumps({k: v for k, v in rec.items()
                              if k not in ("memory",)}, indent=None,
                             default=str)[:600])
    return rec


def _compile_cell(cfg, seq, gb, kind, mesh):
    """Lower+compile one step; return (cost dict, collective dict, memory
    dict, timings)."""
    import time as _t
    from repro.roofline.analysis import collective_bytes
    t0 = _t.perf_counter()
    with mesh:
        if kind == "train":
            step, _, structs = ts.make_train_step(cfg, mesh, seq, gb)
            args = (structs["params"], structs["opt"], structs["batch"])
        elif kind == "prefill":
            step, _, structs = ts.make_prefill_step(cfg, mesh, seq, gb)
            args = (structs["params"], structs["batch"])
        else:
            step, _, structs = ts.make_decode_step(cfg, mesh, seq, gb, kind)
            args = (structs["params"], structs["tokens"], structs["state"])
        lowered = step.lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_d[attr] = int(v)
    return cost, coll, mem_d, _t.perf_counter() - t0


def _unit_layers(cfg) -> int:
    """Smallest repeatable layer unit for two-point extrapolation."""
    if cfg.family == "hybrid":
        return max(cfg.shared_attn_every, 1)
    if cfg.n_experts and cfg.moe_every > 1:
        return cfg.moe_every
    return 1


def run_cell_extrapolated(arch: str, shape: str, multi_pod: bool,
                          verbose: bool = True,
                          overrides: dict | None = None,
                          tag: str = "") -> dict:
    """Exact roofline terms via two-point layer extrapolation.

    XLA costs a rolled ``while`` body once, so a full-depth rolled compile
    under-counts per-layer work; full unroll is exact but compiles for many
    minutes. Every per-op metric is affine in the layer count, so two cheap
    *unrolled* compiles at L=unit and L=2*unit give
        body = c2 - c1,  rest = c1 - body,
        corrected(L) = rest + (L/unit) * body.
    Validated against a full qwen2.5-3b unroll.
    """
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    seq, gb, kind = SHAPES[shape]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape}__{mesh_name}__extrap" + \
        (f"__{tag}" if tag else "")
    if shape in cfg.skip_shapes:
        return dict(cell=cell_id, status="SKIP",
                    reason=f"{arch}: shape not meaningful")

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    unit = _unit_layers(cfg)
    n_units = cfg.n_layers / unit

    def variant(k):
        kw = dict(n_layers=k * unit, scan_unroll=True)
        if cfg.family == "encdec":
            kw["n_enc_layers"] = k
        return dataclasses.replace(cfg, **kw)

    c1, coll1, mem1, t1 = _compile_cell(variant(1), seq, gb, kind, mesh)
    c2, coll2, mem2, t2 = _compile_cell(variant(2), seq, gb, kind, mesh)

    def extrap(a1, a2, scale=n_units):
        body = a2 - a1
        rest = a1 - body
        return max(rest + scale * body, 0.0)

    cost = {
        "flops": extrap(float(c1.get("flops", 0)), float(c2.get("flops", 0))),
        "bytes accessed": extrap(float(c1.get("bytes accessed", 0)),
                                 float(c2.get("bytes accessed", 0))),
    }
    coll = {}
    for k in set(coll1) | set(coll2):
        coll[k] = extrap(float(coll1.get(k, 0)), float(coll2.get(k, 0)))
    mem_detail = {k: extrap(float(mem1.get(k, 0)), float(mem2.get(k, 0)))
                  for k in set(mem1) | set(mem2)}
    peak_mem = mem_detail.get("argument_size_in_bytes", 0) + \
        mem_detail.get("temp_size_in_bytes", 0)

    model_flops = roofline.model_flops_for(cfg, seq, gb, kind)
    hbm_model = roofline.analytic_hbm_bytes(cfg, seq, gb, kind, chips)
    # synthesize an "hlo text" substitute: feed collective bytes directly
    rf = roofline.analyze(arch, shape, mesh_name, chips, cost, "",
                          model_flops, peak_mem, hbm_model)
    rf.collective_bytes_per_device = float(coll.get("total_bytes", 0.0))
    rf.t_collective_s = rf.collective_bytes_per_device / 50e9
    terms = {"compute": rf.t_compute_s, "memory": rf.t_memory_s,
             "collective": rf.t_collective_s}
    rf.bottleneck = max(terms, key=terms.get)
    rf.collective_detail = {k: int(v) for k, v in coll.items()}

    rec = dict(cell=cell_id, status="OK", kind=kind, chips=chips,
               seq_len=seq, global_batch=gb, method="extrapolated",
               params=cfg.param_count, active_params=cfg.active_param_count,
               compile_s=round(t1 + t2, 1), memory=mem_detail,
               roofline=rf.as_dict())
    if verbose:
        _log.info(json.dumps({k: v for k, v in rec.items()
                              if k not in ("memory",)}, default=str)[:500])
    return rec


def run_twin_cell(multi_pod: bool, n_scenarios: int = 512,
                  system_name: str = "frontier", verbose: bool = True) -> dict:
    """Dry-run the paper's own workload: a what-if scenario sweep of the
    compiled twin, with the scenario axis sharded over every chip of the
    production mesh (DCDT what-if studies at pod scale)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import engine as eng
    from repro.core import types as T
    from repro.datasets.synthetic import WorkloadSpec, generate
    from repro.systems.config import get_system
    from repro.roofline.analysis import collective_bytes

    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"twin-{system_name}__sweep{n_scenarios}__{mesh_name}"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    sys_ = get_system(system_name)
    js = generate(sys_, WorkloadSpec(n_jobs=1238, duration_s=86400.0,
                                     trace_len=96, seed=1))
    table = js.to_table(1280)
    st0 = eng.init_state(sys_, table, 0.0, 86400.0)
    proto = T.Scenario.make("fcfs")   # field layout source of truth
    scen_struct = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((n_scenarios,), x.dtype), proto)
    axes = mesh.axis_names  # shard scenarios over ALL mesh axes
    scen_shard = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P(axes)), proto)
    n_steps = 256  # one compile unit; runtime scans further

    def sweep(table_, st0_, scen_):
        def one(s1):
            def body(st, _):
                return eng.engine_step(sys_, table_, st, s1)
            return jax.lax.scan(body, st0_, None, length=n_steps)
        return jax.vmap(one)(scen_)

    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(sweep, in_shardings=(None, None, scen_shard)).lower(
            table, st0, scen_struct)
        compiled = lowered.compile()
    dt = time.perf_counter() - t0
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    rec = dict(cell=cell_id, status="OK", chips=chips,
               scenarios=n_scenarios, steps=n_steps,
               compile_s=round(dt, 1),
               flops_per_device=float(cost.get("flops", 0)),
               argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
               collectives=collective_bytes(compiled.as_text()))
    if verbose:
        _log.info(json.dumps(rec, default=str)[:400])
    return rec


def save(rec: dict):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{rec['cell']}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for exact roofline costs")
    ap.add_argument("--extrapolate", action="store_true",
                    help="two-point layer extrapolation (exact + fast)")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override: key=value (perf experiments)")
    ap.add_argument("--tag", default="", help="suffix for the result cell id")
    ap.add_argument("--twin", action="store_true",
                    help="dry-run the twin scenario sweep instead of LM archs")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-cell progress lines on stderr")
    args = ap.parse_args()
    if args.quiet:
        import logging
        _log.setLevel(logging.WARNING)

    if args.list:
        for a in ARCHS:
            for s in SHAPES:
                print(a, s)
        return

    if args.twin:
        for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
            rec = run_twin_cell(mp)
            save(rec)
        return

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for a in archs:
        for s in shapes:
            for mp in meshes:
                try:
                    if args.extrapolate:
                        ov = {}
                        for kv in args.override:
                            k, _, v = kv.partition("=")
                            import ast
                            try:
                                ov[k] = ast.literal_eval(v)
                            except (ValueError, SyntaxError):
                                ov[k] = v
                        rec = run_cell_extrapolated(a, s, mp, overrides=ov,
                                                    tag=args.tag)
                    else:
                        rec = run_cell(a, s, mp, unroll=args.unroll)
                except Exception as e:  # noqa: BLE001
                    rec = dict(cell=f"{a}__{s}__"
                                    f"{'2x16x16' if mp else '16x16'}",
                               status="FAIL", error=f"{type(e).__name__}: "
                                                    f"{e}",
                               trace=traceback.format_exc()[-2000:])
                    n_fail += 1
                    _log.warning("%s FAIL %s", rec["cell"], rec["error"])
                save(rec)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
