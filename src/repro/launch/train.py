"""End-to-end LM training driver (deliverable b): trains any zoo arch on
synthetic token data with the production train_step (pjit shardings on the
host mesh when single-device, checkpoint/restart, straggler-tolerant logging).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b-smoke \\
      --steps 100 --batch 8 --seq 128

Fault tolerance: checkpoints every --ckpt-every steps to --ckpt-dir
(msgpack-free: numpy .npz of the param/opt pytree) and auto-resumes from the
latest one, so a killed run continues — the same mechanism a multi-pod
deployment would drive from a coordinator.
"""
from __future__ import annotations

import argparse
import pathlib
import pickle
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.common import split_tree
from repro.models.zoo import get_api
from repro.training import optimizer as opt
from repro.training import train_step as ts


def save_ckpt(path: pathlib.Path, step: int, params, opt_state):
    path.mkdir(parents=True, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten((params, opt_state))
    np.savez(path / f"ckpt_{step:06d}.npz",
             *[np.asarray(x) for x in flat])
    (path / f"ckpt_{step:06d}.treedef").write_bytes(
        pickle.dumps(treedef))
    # keep only the 2 most recent
    ckpts = sorted(path.glob("ckpt_*.npz"))
    for old in ckpts[:-2]:
        old.unlink()
        td = old.with_suffix(".treedef")
        if td.exists():
            td.unlink()


def load_latest(path: pathlib.Path):
    ckpts = sorted(path.glob("ckpt_*.npz"))
    if not ckpts:
        return None, 0
    latest = ckpts[-1]
    step = int(latest.stem.split("_")[1])
    treedef = pickle.loads(latest.with_suffix(".treedef").read_bytes())
    data = np.load(latest)
    flat = [jnp.asarray(data[k]) for k in data.files]
    params, opt_state = jax.tree_util.tree_unflatten(treedef, flat)
    return (params, opt_state), step


def synthetic_batch(cfg, key, batch, seq):
    """Learnable synthetic corpus: each row is an affine token progression
    t_{n+1} = (5 t_n + 7) mod V from a random start — a deterministic
    next-token function the model can drive loss toward zero on (pure
    random tokens would leave nothing to learn)."""
    start = jax.random.randint(key, (batch, 1), 0, cfg.vocab)
    a, c, V = 5, 7, cfg.vocab

    def body(carry, _):
        nxt = (carry * a + c) % V
        return nxt, carry
    _, toks = jax.lax.scan(body, start[:, 0], None, length=seq)
    b = {"tokens": toks.T.astype(jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(key, (batch, max(seq // 4, 8),
                                              cfg.d_model))
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(key, (batch, cfg.frontend_tokens,
                                               cfg.d_model))
    return b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    from repro.obs.reporter import Reporter, add_output_flags
    add_output_flags(ap)
    args = ap.parse_args(argv)
    rep = Reporter.from_flags(args)

    cfg = get_config(args.arch)
    api = get_api(cfg)
    mesh = make_host_mesh()
    ckpt_dir = pathlib.Path(args.ckpt_dir) / args.arch

    with mesh:
        step_fn, shardings, structs = ts.make_train_step(
            cfg, mesh, args.seq, args.batch,
            opt.AdamWConfig(lr=args.lr, warmup=min(20, args.steps // 5),
                            total_steps=args.steps,
                            moment_dtype=cfg.moment_dtype))
        restored, start_step = load_latest(ckpt_dir)
        key = jax.random.PRNGKey(args.seed)
        if restored is None:
            params, _ = split_tree(api.init(key))
            opt_state = opt.init(opt.AdamWConfig(
                moment_dtype=cfg.moment_dtype), params)
        else:
            params, opt_state = restored
            rep.info(f"resumed from step {start_step}")

        n_params = sum(int(np.prod(p.shape))
                       for p in jax.tree_util.tree_leaves(params))
        rep.info(f"arch={args.arch} params={n_params / 1e6:.1f}M "
                 f"tokens/step={args.batch * args.seq}")
        t_hist, losses = [], []
        for step in range(start_step, args.steps):
            key, sub = jax.random.split(key)
            batch = synthetic_batch(cfg, sub, args.batch, args.seq)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            t_hist.append(dt)
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                tps = args.batch * args.seq / np.mean(t_hist[-10:])
                rep.info(f"step {step:5d}  loss {loss:8.4f}  "
                         f"gnorm {float(metrics['grad_norm']):8.3f}  "
                         f"{tps:,.0f} tok/s  {dt * 1e3:.0f} ms/step")
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                save_ckpt(ckpt_dir, step + 1, params, opt_state)
        if losses:
            rep.result(f"final loss {losses[-1]:.4f} "
                       f"(delta {losses[-1] - losses[0]:+.4f})",
                       key="train",
                       value={"arch": args.arch, "steps": args.steps,
                              "final_loss": losses[-1],
                              "loss_delta": losses[-1] - losses[0]})
    rep.flush_json()
    return losses


if __name__ == "__main__":
    main()
