"""Allocator / XLA environment presets for the hot scan loop.

The two knobs that move the engine's wall-clock on CPU hosts are the
malloc implementation (tcmalloc beats glibc malloc under XLA's
allocation churn) and a handful of XLA flags. Both must be in the
environment *before* the process starts (``LD_PRELOAD``) or before JAX
first initializes its backends (``XLA_FLAGS``), so this module cannot
retrofit them — it is a report-and-hint layer:

- ``preset(name)`` returns the recommended variables for a named
  preset, for launcher scripts to export before exec'ing Python.
- ``apply(name)`` sets any not-yet-set recommendations into
  ``os.environ`` — only useful at the very top of a ``__main__``
  before anything imports jax; harmless but ineffective later.
- ``report(name)`` inspects the live process (environ plus
  ``/proc/self/maps`` for the actually-loaded allocator) and returns a
  JSON-able dict the run manifests embed, so a benchmark entry can be
  audited for its allocator/flag state after the fact.
"""
from __future__ import annotations

import os

# Candidate tcmalloc locations (Debian/Ubuntu multiarch, RHEL).
_TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib64/libtcmalloc.so.4",
)

PRESETS: dict[str, dict[str, str]] = {
    # Single-process scan throughput: one host device, step markers on
    # the outer while so profiles attribute time to scan iterations,
    # and tcmalloc when the host has it.
    "throughput": {
        "LD_PRELOAD": _TCMALLOC_PATHS[0],
        "XLA_FLAGS": ("--xla_force_host_platform_device_count=1 "
                      "--xla_step_marker_location=1"),
    },
    # Host-parallel sweeps (launch.mesh shards scenarios over host
    # devices): many virtual CPU devices, allocator as above.
    "sweep": {
        "LD_PRELOAD": _TCMALLOC_PATHS[0],
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    },
}


def preset(name: str = "throughput") -> dict[str, str]:
    """Recommended environment for ``name`` (KeyError on unknown)."""
    if name not in PRESETS:
        raise KeyError(f"unknown env preset {name!r}; "
                       f"have {sorted(PRESETS)}")
    return dict(PRESETS[name])


def apply(name: str = "throughput") -> dict[str, str]:
    """Set not-yet-set recommendations into ``os.environ``; returns the
    variables actually written. Must run before jax import to have any
    effect (``LD_PRELOAD`` needs a process restart regardless)."""
    written = {}
    for key, val in preset(name).items():
        if not os.environ.get(key):
            os.environ[key] = val
            written[key] = val
    return written


def _loaded_allocator() -> str:
    """Which malloc is actually mapped: "tcmalloc" | "jemalloc" |
    "glibc" | "unknown" (non-Linux)."""
    try:
        with open("/proc/self/maps") as f:
            maps = f.read()
    except OSError:
        return "unknown"
    if "tcmalloc" in maps:
        return "tcmalloc"
    if "jemalloc" in maps:
        return "jemalloc"
    return "glibc"


def report(name: str = "throughput") -> dict:
    """JSON-able audit of the live process against ``name``: the
    recommendation, what is actually set/loaded, and whether they
    agree. Embedded under ``env_preset`` in run manifests."""
    want = preset(name)
    active = {key: os.environ.get(key) for key in
              ("LD_PRELOAD", "XLA_FLAGS", "XLA_PYTHON_CLIENT_PREALLOCATE",
               "JAX_PLATFORMS", "OMP_NUM_THREADS")}
    allocator = _loaded_allocator()
    want_flags = set(want.get("XLA_FLAGS", "").split())
    have_flags = set((active.get("XLA_FLAGS") or "").split())
    return {
        "preset": name,
        "recommended": want,
        "active": {k: v for k, v in active.items() if v},
        "allocator": allocator,
        "satisfied": (allocator == "tcmalloc"
                      and want_flags <= have_flags),
    }
