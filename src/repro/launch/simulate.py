"""S-RAPS CLI (the paper's ``main.py`` equivalent).

  python -m repro.launch.simulate --system marconi100 -t 61000 -ff 4381000 \\
      --scheduler default --policy fcfs --backfill easy -o out/

Options mirror the paper's artifact: --system selects the dataloader,
--policy/--backfill the built-in scheduler, --scheduler external couples an
event-based external simulator (fastsim | scheduleflow), --accounts tracks
account ledgers, --accounts-json reloads them (incentive redeeming),
--sweep runs several policies in one compiled batch.

Subcommand ``train`` closes the ML scheduling loop (repro.ml.train,
docs/ml-scheduling.md): ES-optimize the scoring alpha against batched twin
rollouts, e.g. ``python -m repro.launch.simulate train --smoke``. A trained
checkpoint feeds back into evaluation via ``--policy ml --ml-alpha
<checkpoint.json or comma floats>``.

Real traces (repro.traces, docs/datasets.md): ``--trace`` ingests a
published job table or a joblive/jobprofile telemetry dump in place of
the synthetic dataset, ``--replay-power`` plays measured power back
verbatim, ``--weather-trace`` drives the cooling tower from recorded
ambient conditions, and subcommand ``calibrate`` fits the cooling-plant
parameters to recorded facility telemetry.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import secrets
import time
import types

import numpy as np

import dataclasses

from repro.core import accounts as acct_mod
from repro.core import engine as eng
from repro.core import external as ext
from repro.core import stats as stats_mod
from repro.core import types as T
from repro.datasets import loaders
from repro.launch import env as launch_env
from repro.ml.pipeline import MLSchedulerModel, attach_scores
from repro.systems.config import FacilityTopology, get_system


def _parse_time(s: str) -> float:
    units = {"s": 1.0, "m": 60.0, "h": 3600.0, "d": 86400.0}
    if s and s[-1] in units:
        return float(s[:-1]) * units[s[-1]]
    return float(s)


def build_system(name: str, scale: int = 0, halls: int = 0):
    """Resolve a system config with optional node scaling and a hall
    split (capacity-preserving re-rate so every hall gets >= 1 CDU
    group and >= 1 tower cell). Shared by the CLI entry points."""
    sys_ = get_system(name)
    if scale:
        sys_ = sys_.scaled(scale)
    if halls:
        cool = sys_.cooling
        # every hall needs >= 1 CDU group and >= 1 tower cell: re-rate the
        # fleet capacity-preservingly (more, smaller cells/CDUs — total
        # rated heat, flow, pump power and HX conductance unchanged) when
        # a scaled config is too coarse for the requested hall count
        cells = max(cool.n_tower_cells, halls)
        groups = max(cool.n_groups, halls)
        cell_k = cool.n_tower_cells / cells
        group_k = cool.n_groups / groups
        sys_ = dataclasses.replace(
            sys_, cooling=dataclasses.replace(
                cool,
                n_groups=groups,
                mdot_kg_s=cool.mdot_kg_s * group_k,
                ua_w_k=cool.ua_w_k * group_k,
                pump_w_per_group=cool.pump_w_per_group * group_k,
                n_tower_cells=cells,
                cell_rated_heat_w=cool.cell_rated_heat_w * cell_k,
                fan_rated_w=cool.fan_rated_w * cell_k,
                topology=FacilityTopology(n_halls=halls)))
    return sys_


def main(argv=None):
    import sys as _sys
    argv = list(_sys.argv[1:]) if argv is None else list(argv)
    if argv[:1] == ["train"]:
        # policy-training subcommand (repro.ml.train): ES over batched
        # twin rollouts; everything after "train" is its own arg set
        from repro.ml import train as ml_train
        return ml_train.main(argv[1:])
    if argv[:1] == ["serve"]:
        # twin-as-a-service (repro.serve, docs/serving.md): persistent
        # session with snapshot/fork branching over a socket
        from repro.serve import cli as serve_cli
        return serve_cli.main(argv[1:])
    if argv[:1] == ["calibrate"]:
        # cooling-plant calibration against recorded telemetry
        # (repro.traces.calibrate, docs/datasets.md)
        from repro.traces import calibrate as calibrate_cli
        return calibrate_cli.main(argv[1:])
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--system", default="marconi100")
    ap.add_argument("--scheduler", default="default",
                    choices=["default", "experimental", "fastsim",
                             "scheduleflow"])
    ap.add_argument("--policy", default="replay")
    ap.add_argument("--backfill", default=None,
                    help="backfill mode (default: none for built-in "
                         "schedulers, firstfit for external peers; an "
                         "explicit value always wins)")
    ap.add_argument("-ff", "--fastforward", default="0", type=str,
                    help="simulation start offset (s/m/h/d suffix)")
    ap.add_argument("-t", "--time", default="6h", type=str,
                    help="simulated duration")
    ap.add_argument("--jobs", type=int, default=1000)
    ap.add_argument("--days", type=float, default=None,
                    help="dataset horizon to generate (days)")
    # real-trace ingestion (repro.traces, docs/datasets.md)
    ap.add_argument("--trace", nargs="+", default=None, metavar="PATH",
                    help="replace the synthetic --system dataset with a "
                         "real trace: one job table (.parquet/.csv), one "
                         "cached trace .npz, or a joblive dir followed by "
                         "a jobprofile dir (RAPS-style telemetry)")
    ap.add_argument("--trace-cache", default=None, metavar="DIR",
                    help="content-addressed NPZ cache directory for "
                         "parsed telemetry (repeat runs skip the CSVs)")
    ap.add_argument("--replay-power", action="store_true",
                    help="replay measured per-node power profiles from "
                         "the trace instead of the power model (jobs "
                         "without a measurement keep the model)")
    ap.add_argument("--weather-trace", default=None, metavar="FILE",
                    help="measured weather CSV/NPZ (timestamp + wet-bulb "
                         "or dry-bulb/RH) driving the cooling tower "
                         "ambient (repro.traces.weather)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=int, default=0,
                    help="scale the system to N nodes (CPU-friendly)")
    ap.add_argument("--halls", type=int, default=0,
                    help="split the cooling plant into N halls "
                         "(FacilityTopology; per-hall towers/basins)")
    ap.add_argument("--cells-offline", default=None,
                    help="tower cells out for maintenance: a number "
                         "(every hall) or comma list (per hall), e.g. "
                         "'2,0,0,0'")
    # stochastic failure + demand-response layer (repro.events)
    ap.add_argument("--failure-rate", type=float, default=None,
                    help="per-node failure hazard (failures per node-DAY); "
                         "enables the stochastic failure layer")
    ap.add_argument("--cdu-failure-rate", type=float, default=None,
                    help="per-CDU-group failure hazard (per group-day)")
    ap.add_argument("--cell-failure-rate", type=float, default=None,
                    help="per-tower-cell failure hazard (per cell-day)")
    ap.add_argument("--failure-corr", type=float, default=0.0,
                    help="correlated common-cause scale in [0,1]: one "
                         "per-hall draw takes the hall's CDU groups "
                         "down together")
    ap.add_argument("--failure-seed", type=int, default=0,
                    help="failure-universe seed (deterministic draws)")
    ap.add_argument("--repair", default="1h", type=str,
                    help="mean repair time (s/m/h/d suffix)")
    ap.add_argument("--no-requeue", action="store_true",
                    help="killed jobs are dismissed instead of requeued")
    ap.add_argument("--dr-announce", default=None, type=str,
                    help="demand-response event: announcement time into "
                         "the run (s/m/h/d suffix); enables the DR layer")
    ap.add_argument("--dr-notice", default="30m", type=str,
                    help="notice window between announcement and the cap "
                         "engaging")
    ap.add_argument("--dr-duration", default="1h", type=str,
                    help="how long the DR cap holds")
    ap.add_argument("--dr-cap-mw", type=float, default=0.0,
                    help="DR cap level (MW)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: scale to 64 nodes, <=48 jobs, "
                         "30 minutes simulated")
    ap.add_argument("--external-cmd", default=None,
                    help="couple an out-of-process scheduler: spawn this "
                         "command as a subprocess peer (NDJSON socket "
                         "wire protocol, docs/external-scheduling.md), "
                         "e.g. 'python -m tools.reference_peer'")
    ap.add_argument("--external-socket", default=None,
                    help="couple a peer already listening at unix:/path "
                         "or host:port (see tools/reference_peer.py "
                         "--listen)")
    ap.add_argument("--external-mode", default="plugin",
                    choices=["plugin", "sequential"],
                    help="coupling mode for --external-cmd/--external-"
                         "socket (paper §4.2: per-step polling vs "
                         "schedule-then-replay)")
    ap.add_argument("--external-wire", default="auto",
                    choices=("auto", "ndjson", "binary"),
                    help="wire dialect for the external peer: auto "
                         "upgrades to binary frames when the peer "
                         "advertises the capability, ndjson pins the "
                         "legacy dialect, binary demands it (fails the "
                         "handshake on a legacy peer)")
    ap.add_argument("--external-timeout", type=float, default=30.0,
                    help="per-poll wall budget (s) for the external "
                         "bridge; also the socket recv timeout")
    ap.add_argument("--accounts", action="store_true")
    ap.add_argument("--accounts-json", default=None)
    ap.add_argument("--ml-alpha", default=None,
                    help="scoring alpha for --policy ml: a training "
                         "checkpoint JSON (repro.ml.train) or comma "
                         "floats, e.g. '1.2,0.8,1.1,0.3'")
    ap.add_argument("--sweep", nargs="*", default=None,
                    help="policy[:backfill] list to run as one batch")
    ap.add_argument("-o", "--output", default=None, nargs="?",
                    const="simulation_results")
    # flight recorder (docs/observability.md)
    ap.add_argument("--manifest", default=None, metavar="FILE",
                    help="write a schema-versioned run manifest JSON")
    ap.add_argument("--events", default=None, metavar="FILE",
                    help="write lifecycle events (compile/scan/checkpoint/"
                         "respawn) as NDJSON")
    ap.add_argument("--metrics", default=None, metavar="TARGET",
                    help="stream per-interval telemetry as NDJSON frames "
                         "to a file path, tcp:host:port, or unix:/path")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the run into DIR")
    from repro.obs.reporter import add_output_flags
    add_output_flags(ap)
    args = ap.parse_args(argv)

    if args.smoke:
        args.scale = args.scale or 64
        args.jobs = min(args.jobs, 48)
        args.time = "30m"
    sys_ = build_system(args.system, args.scale, args.halls)
    cells_offline = 0.0
    if args.cells_offline:
        parts = [float(x) for x in args.cells_offline.split(",")]
        cells_offline = parts[0] if len(parts) == 1 else tuple(parts)
    t0 = _parse_time(args.fastforward)
    t1 = t0 + _parse_time(args.time)
    days = args.days or max((t1 / 86400.0) * 1.25, 0.5)
    if args.trace:
        js = loaders.load_trace(args.trace, prof_dt=sys_.prof_dt,
                                cache_dir=args.trace_cache)
    else:
        js = loaders.load(args.system, n_jobs=args.jobs, days=days,
                          seed=args.seed)
    weather = None
    if args.weather_trace:
        from repro.traces.weather import load_weather
        n_steps = int(round((t1 - t0) / sys_.dt))
        weather = load_weather(args.weather_trace, n_steps, sys_.dt, t0=t0)
    if args.policy == "ml":
        alpha = None
        if args.ml_alpha:
            if pathlib.Path(args.ml_alpha).exists():
                from repro.ml.train import load_alpha
                alpha = load_alpha(args.ml_alpha)
            else:
                alpha = np.asarray(
                    [float(x) for x in args.ml_alpha.split(",")],
                    np.float32)
        # trained or default alpha is baked into the static score, so
        # every engine path (static / sweep / traced) ranks identically
        model = MLSchedulerModel.fit(js, k=5, alpha=alpha)
        attach_scores(js, model)
    js.assign_prepop_placement(t0, sys_.n_nodes)
    table = js.to_table(replay_power=args.replay_power)

    accounts = None
    if args.accounts_json:
        accounts = acct_mod.load_json(args.accounts_json)

    from repro import obs
    rep = obs.Reporter.from_flags(args)
    recorder = None
    if args.manifest or args.events:
        recorder = obs.RunRecorder(manifest_path=args.manifest,
                                   events_path=args.events)
        recorder.begin(
            sys_, command="sweep" if args.sweep else "simulate", argv=argv,
            scenario={"policy": args.policy,
                      "backfill": args.backfill or "none",
                      "scheduler": args.scheduler, "sweep": args.sweep,
                      "external_cmd": args.external_cmd,
                      "external_socket": args.external_socket,
                      "external_mode": args.external_mode,
                      "external_wire": args.external_wire,
                      "halls": args.halls,
                      "cells_offline": args.cells_offline,
                      "failure_rate_per_day": args.failure_rate,
                      "failure_seed": args.failure_seed,
                      "dr_cap_mw": args.dr_cap_mw,
                      "trace": args.trace,
                      "replay_power": args.replay_power,
                      "weather_trace": args.weather_trace,
                      "t0_s": t0, "duration_s": t1 - t0},
            seed=args.seed, jobs=js,
            extra={"env_preset": launch_env.report(
                "sweep" if args.sweep else "throughput"),
                   # content digests pin exactly which trace bytes
                   # produced this run (repro.traces provenance)
                   **_trace_digests(args)})
        recorder.event("run_start")
    timer = obs.SpanTimer(listener=recorder.span_listener
                          if recorder else None)
    if args.profile:
        import jax
        jax.profiler.start_trace(args.profile)

    wall0 = time.perf_counter()
    with obs.use(timer):
        runs, bridge = _run(args, sys_, js, table, accounts, t0, t1,
                            cells_offline, recorder, weather)
    wall = time.perf_counter() - wall0
    if args.profile:
        import jax
        jax.profiler.stop_trace()
        rep.info(f"profiler trace -> {args.profile}")

    sink = obs.MetricsSink(args.metrics) if args.metrics else None
    summaries = {}
    for (p, b), final, hist in runs:
        s = stats_mod.summarize(sys_, table, final, hist)
        label = f"{p}:{b}"
        summaries[label] = s
        if sink is not None:
            obs.stream_history(sink, recorder.run_id if recorder
                               else "anonymous", sys_, table, final, hist,
                               label=label, summary=s)
        rep.result(f"=== {args.system} policy={p} backfill={b} "
                   f"(sim {t1 - t0:.0f}s in {wall:.1f}s wall, "
                   f"{(t1 - t0) / wall:.0f}x realtime) ===\n" +
                   stats_mod.format_stats(s),
                   key=label, value=s)
        if args.output:
            out = pathlib.Path(args.output) / secrets.token_hex(4)
            out.mkdir(parents=True, exist_ok=True)
            np.savez(out / "history.npz",
                     **{k: np.asarray(getattr(hist, k))
                        for k in vars(hist) if not k.startswith("_")})
            (out / "stats.out").write_text(stats_mod.format_stats(s))
            with open(out / "job_history.csv", "w") as f:
                f.write("job,submit,start,end,nodes,account,state\n")
                st_ = np.asarray(final.start)
                en_ = np.asarray(final.end)
                js_ = np.asarray(final.jstate)
                for j in range(len(js)):
                    f.write(f"{j},{js.submit[j]:.0f},{st_[j]:.0f},"
                            f"{en_[j]:.0f},{js.nodes[j]},{js.account[j]},"
                            f"{js_[j]}\n")
            if args.accounts:
                acct_mod.save_json(final.accounts,
                                   str(out / "accounts.json"))
            rep.info(f"output -> {out}")
            rep.result_json("output_dir", str(out))
    if sink is not None:
        sink.close()
        rep.info(f"metrics: {sink.n_frames} frames -> {args.metrics}")
    if bridge is not None:
        rep.result_json("bridge", bridge.stats())
    if recorder is not None:
        recorder.event("run_end", wall_s=wall)
        counters = {"sweep_cache": dict(eng.SWEEP_CACHE_STATS)}
        if bridge is not None:
            counters["bridge"] = bridge.stats()
        if sink is not None:
            counters["metrics_frames"] = sink.n_frames
        recorder.finalize(spans=timer.summary(), counters=counters,
                          wall_s=wall, summaries=summaries)
        rep.info(f"manifest -> {args.manifest}" if args.manifest
                 else f"events -> {args.events}")
    rep.flush_json()


def _trace_digests(args) -> dict:
    """Content digests of any real traces feeding this run, for the
    manifest — empty when the run is fully synthetic."""
    from repro.traces import source_digest
    out = {}
    if args.trace:
        out["trace_digest"] = source_digest(*args.trace)
    if args.weather_trace:
        out["weather_trace_digest"] = source_digest(args.weather_trace)
    return out


def _failure_kwargs(args, t0):
    """Scenario knob kwargs for the failure/DR layer from CLI flags.

    Empty dict = the layer is off. CLI hazard rates are per entity-DAY
    (operator-friendly MTBF units); Scenario knobs are hazards in 1/s.
    ``--dr-announce`` is relative to the run start, the Scenario knob is
    absolute sim time."""
    per_day = 1.0 / 86400.0
    kw = {}
    if args.failure_rate is not None:
        kw["node_fail_rate"] = args.failure_rate * per_day
    if args.cdu_failure_rate is not None:
        kw["cdu_fail_rate"] = args.cdu_failure_rate * per_day
    if args.cell_failure_rate is not None:
        kw["cell_fail_rate"] = args.cell_failure_rate * per_day
    if kw:
        kw["failure_corr"] = args.failure_corr
        kw["failure_seed"] = float(args.failure_seed)
        kw["repair_s"] = _parse_time(args.repair)
    if args.dr_announce is not None and args.dr_cap_mw > 0:
        kw["dr_announce_s"] = t0 + _parse_time(args.dr_announce)
        kw["dr_notice_s"] = _parse_time(args.dr_notice)
        kw["dr_duration_s"] = _parse_time(args.dr_duration)
        kw["dr_cap_w"] = args.dr_cap_mw * 1e6
    return kw


def _run(args, sys_, js, table, accounts, t0, t1, cells_offline, recorder,
         weather=None):
    """Dispatch one CLI invocation to the right engine path.

    Returns (runs, bridge): ``runs`` is a list of ((policy, backfill),
    final, hist) and ``bridge`` the SchedulerBridge when an external
    coupling ran in plugin mode (its counters feed the manifest).
    ``weather`` (a measured trace, --weather-trace) reaches every
    compiled path; the external-scheduler bridges do not model ambient
    conditions, so combining them is a loud error rather than a
    silently-ignored flag."""
    backfill_cli = args.backfill or "none"
    bridge = None
    if weather is not None and (args.external_cmd or args.external_socket
                                or args.scheduler in ("fastsim",
                                                      "scheduleflow")):
        raise SystemExit("--weather-trace is not supported with external "
                         "scheduler coupling")
    fail_kw = _failure_kwargs(args, t0)
    events_cfg = None
    dr_signals = None
    if fail_kw:
        from repro.events import EventConfig
        events_cfg = EventConfig(requeue=not args.no_requeue)
        if "dr_cap_w" in fail_kw:
            # demand-response rides the grid-cap machinery: inject
            # neutral signals (zero carbon/price, uncapped) when no grid
            # trace drives the run
            from repro.grid import signals as gsig
            dr_signals = gsig.neutral(int(round((t1 - t0) / sys_.dt)))
    if args.external_cmd or args.external_socket:
        from repro.core import transport as tr
        policy = args.policy if args.policy != "replay" else "fcfs"
        # an explicit --backfill (including "none") reaches the peer;
        # only the unset default maps to FastSimLike's firstfit
        backfill = args.backfill or "firstfit"
        if args.external_cmd:
            peer = tr.SubprocessPeer(cmd=args.external_cmd, policy=policy,
                                     backfill=backfill,
                                     timeout_s=args.external_timeout,
                                     wire=args.external_wire)
        else:
            peer = tr.SocketPeer(address=args.external_socket,
                                 policy=policy, backfill=backfill,
                                 timeout_s=args.external_timeout,
                                 wire=args.external_wire)
        ext_scen = T.Scenario.make("replay", cells_offline=cells_offline)
        on_event = recorder.span_listener if recorder else None
        try:
            if args.external_mode == "sequential":
                # one-shot coupling: the peer is driven directly (the
                # bridge's poll retry policy has nothing to wrap here)
                final, hist = ext.run_sequential_mode(sys_, js, peer,
                                                      t0, t1, scen=ext_scen)
            else:
                bridge = ext.SchedulerBridge(
                    peer, ext.BridgeConfig(timeout_s=args.external_timeout),
                    on_event=on_event)
                final, hist, _ = ext.run_plugin_mode(sys_, js, bridge,
                                                     t0, t1, scen=ext_scen)
        finally:
            peer.close()
        if isinstance(hist, dict):  # plugin mode returns a dict of arrays
            hist = types.SimpleNamespace(**hist)
        runs = [((policy, f"external:{args.external_mode}"), final, hist)]
    elif args.scheduler in ("fastsim", "scheduleflow"):
        ext_scen = T.Scenario.make("replay", cells_offline=cells_offline)
        if args.scheduler == "fastsim":
            sched = ext.FastSimLike(policy=args.policy
                                    if args.policy != "replay" else "fcfs")
            final, hist = ext.run_sequential_mode(sys_, js, sched, t0, t1,
                                                  scen=ext_scen)
        else:
            # explicit bridge so its poll counters reach the manifest
            bridge = ext.SchedulerBridge(
                ext.ScheduleFlowLike(),
                on_event=recorder.span_listener if recorder else None)
            final, hist = ext.run_plugin_mode(sys_, js, bridge, t0, t1,
                                              scen=ext_scen)[:2]
        if isinstance(hist, dict):  # plugin mode returns a dict of arrays
            hist = types.SimpleNamespace(**hist)
        runs = [((args.policy, "external"), final, hist)]
    elif args.sweep:
        specs = []
        for s in args.sweep:
            p, _, b = s.partition(":")
            specs.append((p, b or "none"))
        scens = [T.Scenario.make(p, b, cells_offline=cells_offline,
                                 **fail_kw)
                 for p, b in specs]
        # shards the scenario axis over the visible devices (shard_map);
        # exactly simulate_sweep when only one device is present
        finals, hists = eng.simulate_sweep_sharded(sys_, table, scens,
                                                   t0, t1, accounts,
                                                   signals=dr_signals,
                                                   weather=weather,
                                                   events=events_cfg)
        import jax
        runs = [((p, b),
                 jax.tree_util.tree_map(lambda x, i=i: x[i], finals),
                 jax.tree_util.tree_map(lambda x, i=i: x[i], hists))
                for i, (p, b) in enumerate(specs)]
    elif fail_kw:
        # stochastic failures / demand-response: traced-scenario engine
        # with the event layer enabled (repro.events)
        scen = T.Scenario.make(args.policy, backfill_cli,
                               cells_offline=cells_offline, **fail_kw)
        final, hist = eng.simulate(sys_, table, scen, t0, t1, accounts,
                                   signals=dr_signals, weather=weather,
                                   events=events_cfg)
        runs = [((args.policy, backfill_cli), final, hist)]
    elif args.cells_offline:
        # maintenance knob is traced: run the traced-scenario engine
        scen = T.Scenario.make(args.policy, backfill_cli,
                               cells_offline=cells_offline)
        final, hist = eng.simulate(sys_, table, scen, t0, t1, accounts,
                                   weather=weather)
        runs = [((args.policy, backfill_cli), final, hist)]
    else:
        # single-policy runs take the static fast path (policy/backfill are
        # compile-time constants; docs/architecture.md)
        final, hist = eng.simulate_static(sys_, table, args.policy,
                                          backfill_cli, t0, t1, accounts,
                                          weather=weather)
        runs = [((args.policy, backfill_cli), final, hist)]
    return runs, bridge


if __name__ == "__main__":
    main()
