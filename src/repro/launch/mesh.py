"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the "pod"
axis carries cross-pod data parallelism (DCN-ish), "data" carries in-pod
FSDP/DP, "model" carries TP/EP.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""
from __future__ import annotations

import jax

from repro.parallel.sharding import mesh_axis_types_kwargs


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_types_kwargs(len(axes)))


def make_host_mesh():
    """Degenerate 1-device mesh for CPU tests (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         **mesh_axis_types_kwargs(2))


# Hardware constants for the roofline (TPU v5e-class, per chip).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link
HBM_BYTES = 16 * 1024**3       # capacity used for "does it fit" checks
